//! Quickstart — Listing 1 of the paper: counting GC bases in a DNA
//! sequence with POSIX tools from the `ubuntu` image, written against
//! the fluent pipeline-IR API in ~10 lines of driver code.
//!
//! The job deliberately chains TWO maps (extract the G/C bases, then
//! count them) so `explain()` shows the optimizer fusing them into one
//! container invocation per partition before lowering.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use mare::cluster::{Cluster, ClusterConfig};
use mare::dataset::Dataset;
use mare::mare::MaRe;

fn main() -> mare::error::Result<()> {
    // a "cluster": 4 workers x 2 vCPUs, stock images pulled from the
    // simulated registry (Docker Hub analogue)
    let registry = Arc::new(mare::tools::images::stock_registry(None));
    let cluster = Arc::new(Cluster::new(registry, None, ClusterConfig::sized(4, 2)));

    // the input genome, partitioned like sc.parallelize
    let genome = mare::workloads::gc::genome_text(42, 256, 80);
    let genome_rdd = Dataset::parallelize_text(&genome, "\n", 8);

    // Listing 1 as a logical pipeline: map, map (fused away), reduce
    let gc_count = MaRe::source(cluster, genome_rdd)
        .map("ubuntu", "grep -o '[GC]' /dna > /gc")
        .mounts("/dna", "/gc")
        .map("ubuntu", "wc -l /gc > /count")
        .mounts("/gc", "/count")
        .reduce("ubuntu", "awk '{s+=$1} END {print s}' /counts > /sum")
        .mounts("/counts", "/sum")
        .depth(2)
        .build()?;

    let result = gc_count.collect_text()?;
    let expected = mare::workloads::gc::oracle(&genome);
    println!("GC count (distributed, containerized): {result}");
    println!("GC count (driver-side oracle):         {expected}");
    assert_eq!(result, expected.to_string());

    // the plans MaRe built for this job: the two chained maps fuse into
    // a single physical stage op (one simulated container per partition)
    println!("\n{}", gc_count.explain());
    assert_eq!(gc_count.logical().num_maps(), 2);
    assert_eq!(gc_count.optimized().num_maps(), 1);
    println!(
        "simulated containers launched: {}",
        gc_count.container_launches()
    );
    Ok(())
}
