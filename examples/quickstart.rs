//! Quickstart — Listing 1 of the paper: counting GC bases in a DNA
//! sequence with POSIX tools from the `ubuntu` image, in ~15 lines of
//! driver code.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use mare::cluster::{Cluster, ClusterConfig};
use mare::dataset::Dataset;
use mare::mare::{MapSpec, MaRe, MountPoint, ReduceSpec};

fn main() -> mare::error::Result<()> {
    // a "cluster": 4 workers x 2 vCPUs, stock images pulled from the
    // simulated registry (Docker Hub analogue)
    let registry = Arc::new(mare::tools::images::stock_registry(None));
    let cluster = Arc::new(Cluster::new(registry, None, ClusterConfig::sized(4, 2)));

    // the input genome, partitioned like sc.parallelize
    let genome = mare::workloads::gc::genome_text(42, 256, 80);
    let genome_rdd = Dataset::parallelize_text(&genome, "\n", 8);

    // Listing 1, line for line
    let gc_count = MaRe::new(cluster, genome_rdd)
        .map(MapSpec {
            input_mount: MountPoint::text("/dna"),
            output_mount: MountPoint::text("/count"),
            image: "ubuntu".into(),
            command: "grep -o '[GC]' /dna | wc -l > /count".into(),
        })
        .reduce(ReduceSpec {
            input_mount: MountPoint::text("/counts"),
            output_mount: MountPoint::text("/sum"),
            image: "ubuntu".into(),
            command: "awk '{s+=$1} END {print s}' /counts > /sum".into(),
            depth: 2,
        });

    let result = gc_count.collect_text()?;
    let expected = mare::workloads::gc::oracle(&genome);
    println!("GC count (distributed, containerized): {result}");
    println!("GC count (driver-side oracle):         {expected}");
    assert_eq!(result, expected.to_string());

    // the physical plan MaRe compiled for this job
    let pp = mare::cluster::compile(gc_count.dataset().plan());
    println!("\nphysical plan:\n{}", pp.describe());
    Ok(())
}
