//! SNP calling — Listing 3 of the paper: BWA alignment (map),
//! chromosome-wise repartitionBy, GATK HaplotypeCaller (map, disk-backed
//! mounts), vcf-concat (reduce); reads ingested from (simulated) S3 like
//! the 1000-Genomes bucket.
//!
//! Because the read simulator plants a known truth set, this example
//! also scores the calls — something the paper could not do with real
//! 1KGP data.
//!
//! ```sh
//! make artifacts && cargo run --release --example snp_calling
//! ```

use mare::cluster::ClusterConfig;
use mare::config::{BackendKind, RunConfigFile, Workload};
use mare::storage::{StorageBackend, S3};
use mare::workloads::{driver, genreads, snp};

fn main() -> mare::error::Result<()> {
    let workers = 4usize;

    // one simulated individual: 4 chromosomes, 30x coverage, SNPs
    // planted at the human ~1/850 bp rate
    let sim = genreads::ReadSimConfig {
        seed: 0x1000_6e0e5, // "1000 genomes"
        chromosome_len: 3000,
        ..Default::default()
    };
    let (fastq, individual) = genreads::reads_fastq(&sim);
    println!(
        "simulated individual: {} chromosomes x {} bp, {} planted SNPs, {} reads",
        sim.chromosomes,
        sim.chromosome_len,
        individual.truth.len(),
        fastq.matches('@').count(),
    );

    // stage on "S3" (remote object store, WAN model) like s3://1000genomes
    let mut s3 = S3::new();
    s3.put("1000genomes/HG02666.fastq", fastq.into_bytes())?;
    let cfg = RunConfigFile {
        workload: Workload::Snp,
        backend: BackendKind::S3,
        scale: sim.chromosome_len,
        seed: sim.seed,
        ..Default::default()
    };
    let (reads_rdd, ingest) =
        driver::ingest_fastq(&s3, "1000genomes/HG02666.fastq", workers * 2, &cfg)?;
    println!(
        "ingested {} B from s3 with {} readers in {} (virtual, WAN)",
        ingest.bytes, ingest.readers, ingest.duration
    );

    // cluster with the alignment + vcftools images (reference baked into
    // mcapuccini/alignment, as in the paper) and the AOT runtime
    let cluster = mare::workloads::make_cluster(
        ClusterConfig::sized(workers, 8),
        Some(&mare::workloads::artifact_dir()),
        Some(&individual.reference),
    )?;

    // Listing 3 as a logical pipeline, optimized + lowered by build()
    let job = snp::pipeline(cluster, reads_rdd, workers);
    println!("\n{}", job.explain());
    let out = job.run()?;
    let calls = driver::parse_vcf_records(&out)?;
    print!("\n{}", out.report.summary());

    println!("\ncalled {} SNPs; first 5:", calls.len());
    for c in calls.iter().take(5) {
        println!(
            "  {}:{} {}>{} qual={:.1} gt={}",
            c.chrom, c.pos, c.ref_base, c.alt, c.qual, c.genotype
        );
    }

    let (tp, fp, fn_) = snp::score_calls(&calls, &individual.truth);
    let precision = tp as f64 / (tp + fp).max(1) as f64;
    let recall = tp as f64 / (tp + fn_).max(1) as f64;
    println!(
        "\nvs planted truth: tp={tp} fp={fp} fn={fn_} precision={precision:.3} recall={recall:.3}"
    );
    assert!(precision > 0.9, "precision collapsed: {precision}");
    assert!(recall > 0.5, "recall collapsed: {recall}");
    Ok(())
}
