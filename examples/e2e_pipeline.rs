//! End-to-end driver — exercises the FULL system on a real small
//! workload, proving all layers compose (the EXPERIMENTS.md E2E run):
//!
//!   storage backends (HDFS / Swift / S3) → parallel ingestion →
//!   MaRe primitives → stage compiler → locality scheduler → container
//!   engine → simulated tools → AOT Pallas kernels via PJRT →
//!   tree-reduce → driver-side collect — plus fault injection with
//!   lineage recovery, and the workflow-system baseline for contrast.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use std::sync::Arc;

use mare::cluster::{ClusterConfig, FaultSpec};
use mare::config::{BackendKind, RunConfigFile, Workload};
use mare::util::bench::Table;

fn main() -> mare::error::Result<()> {
    let wall = std::time::Instant::now();
    let mut table = Table::new(
        "E2E — all pipelines x backends (16x8 virtual cluster)",
        &["workload", "backend", "ingest", "makespan", "locality", "shuffled B", "digest"],
    );

    // --- all three pipelines over their natural backends
    let runs: Vec<(Workload, BackendKind, usize)> = vec![
        (Workload::Gc, BackendKind::Hdfs, 4096),
        (Workload::Vs, BackendKind::Hdfs, 384),
        (Workload::Vs, BackendKind::Swift, 384),
        (Workload::Snp, BackendKind::S3, 2500),
    ];
    for (workload, backend, scale) in runs {
        let mut cfg = RunConfigFile {
            workload,
            backend,
            scale,
            seed: 0xE2E,
            ..Default::default()
        };
        cfg.cluster = ClusterConfig::sized(16, 8);
        cfg.cluster.seed = cfg.seed;
        let res = mare::workloads::driver::run(&cfg)?;
        table.row(vec![
            format!("{workload:?}"),
            backend.name().into(),
            res.ingest.duration.to_string(),
            res.report.makespan.to_string(),
            format!("{:.0}%", res.report.locality_fraction() * 100.0),
            res.report.total_shuffled_bytes().to_string(),
            res.digest,
        ]);
    }
    table.print();
    table.save("e2e_pipeline");

    // --- fault tolerance: worker loss mid-VS, lineage recovery
    println!("\n== fault injection: lose worker 3 after the docking stage ==");
    let library = mare::workloads::genlib::library_sdf(0xE2E, 256);
    let ds = || {
        mare::dataset::Dataset::parallelize_text(
            &library,
            mare::workloads::vs::SDF_SEP,
            32,
        )
    };
    let clean_cluster = mare::workloads::make_cluster(
        ClusterConfig::sized(8, 8),
        Some(&mare::workloads::artifact_dir()),
        None,
    )?;
    let clean = mare::workloads::vs::pipeline(clean_cluster, ds(), 2).run()?;

    let faulty_cfg = ClusterConfig::sized(8, 8)
        .with_fault(FaultSpec::WorkerLoss { worker: 3, after_stage: 0 });
    let faulty_cluster = mare::workloads::make_cluster(
        faulty_cfg,
        Some(&mare::workloads::artifact_dir()),
        None,
    )?;
    let faulty = mare::workloads::vs::pipeline(faulty_cluster, ds(), 2).run()?;

    assert_eq!(
        clean.collect_text(mare::workloads::vs::SDF_SEP),
        faulty.collect_text(mare::workloads::vs::SDF_SEP),
        "lineage recovery must reproduce the fault-free result"
    );
    let recomputed: usize = faulty.report.stages.iter().map(|s| s.recomputed).sum();
    println!(
        "recovered: {recomputed} tasks recomputed, makespan {} (clean {}), identical top-30 ✓",
        faulty.report.makespan, clean.report.makespan
    );

    // --- workflow baseline contrast (the §1.4 claim)
    println!("\n== workflow-system baseline (decoupled store, no locality) ==");
    let genome = mare::workloads::gc::genome_text(0xE2E, 4096, 80);
    let mut cfg = RunConfigFile {
        workload: Workload::Gc,
        backend: BackendKind::Hdfs,
        scale: 4096,
        seed: 0xE2E,
        ..Default::default()
    };
    cfg.cluster = ClusterConfig::sized(8, 8);
    let mare_res = mare::workloads::driver::run(&cfg)?;

    let reg = mare::tools::images::stock_registry(None);
    let wf = mare::baseline::WorkflowEngine::new(
        Arc::new(mare::container::Engine::new(Arc::new(reg), None)),
        ClusterConfig::sized(8, 8),
    );
    let records: Vec<mare::dataset::Record> =
        genome.lines().map(mare::dataset::Record::text).collect();
    let steps = vec![
        mare::baseline::WfStep {
            name: "gc-map".into(),
            input_mount: mare::mare::MountPoint::text("/dna"),
            output_mount: mare::mare::MountPoint::text("/count"),
            image: "ubuntu".into(),
            command: "grep -o '[GC]' /dna | wc -l > /count".into(),
            tasks: 16,
        },
        mare::baseline::WfStep {
            name: "gc-sum".into(),
            input_mount: mare::mare::MountPoint::text("/counts"),
            output_mount: mare::mare::MountPoint::text("/sum"),
            image: "ubuntu".into(),
            command: "awk '{s+=$1} END {print s}' /counts > /sum".into(),
            tasks: 1,
        },
    ];
    let (_, wf_rep) = wf.run(&steps, records)?;
    println!(
        "MaRe {} vs workflow {} ({:.2}x) — locality + in-memory pipelining",
        mare_res.report.makespan,
        wf_rep.makespan,
        wf_rep.makespan.as_seconds() / mare_res.report.makespan.as_seconds()
    );

    println!("\nE2E complete in {:?} real wall-clock.", wall.elapsed());
    Ok(())
}
