//! Virtual Screening — Listing 2 of the paper: FRED docking over an SDF
//! molecular library (map), top-30 poses by Chemgauss4 score (reduce),
//! ingesting from a (simulated) HDFS co-located with the workers.
//!
//! Ends with the paper's own correctness protocol: "we ran sdsorter and
//! FRED on a single core against 1K molecules ... and we compared the
//! results with those produced by [the distributed code]".
//!
//! ```sh
//! make artifacts && cargo run --release --example virtual_screening
//! ```

use mare::cluster::ClusterConfig;
use mare::storage::{ingest_text, Hdfs, StorageBackend};
use mare::workloads::{genlib, vs};

fn main() -> mare::error::Result<()> {
    let workers = 8usize;
    let nmols = 1000usize; // the paper's 1K-molecule correctness sample

    // SureChEMBL stand-in, staged on co-located HDFS
    let library = genlib::library_sdf(0x5EED, nmols);
    let mut hdfs = Hdfs::new(workers, 64 << 10);
    hdfs.put("zinc/surechembl.sdf", library.clone().into_bytes())?;
    let (library_rdd, ingest) = ingest_text(
        &hdfs,
        "zinc/surechembl.sdf",
        vs::SDF_SEP,
        workers * 2,
        workers,
    )?;
    println!(
        "ingested {} B from hdfs with {} parallel readers in {} (virtual)",
        ingest.bytes, ingest.readers, ingest.duration
    );

    // cluster with the oe + sdsorter images and the AOT compute runtime
    let cluster = mare::workloads::make_cluster(
        ClusterConfig::sized(workers, 8),
        Some(&mare::workloads::artifact_dir()),
        None,
    )?;
    let runtime = cluster.runtime().expect("runtime loaded").clone();

    // Listing 2 as a logical pipeline, optimized + lowered by build()
    let top_poses = vs::pipeline(cluster, library_rdd, 2);
    println!("\n{}", top_poses.explain());
    let out = top_poses.run()?;
    let mols = mare::formats::sdf::parse_many(&out.collect_text(vs::SDF_SEP))?;

    println!("\ntop {} poses (of {nmols} molecules):", mols.len());
    for m in mols.iter().take(5) {
        println!(
            "  {:<18} {}",
            m.name,
            m.tags
                .get(mare::tools::fred::SCORE_TAG)
                .map(String::as_str)
                .unwrap_or("-")
        );
    }
    println!("  ...");
    print!("\n{}", out.report.summary());

    // --- the paper's single-core comparison
    let oracle = vs::oracle(&runtime, &library, vs::NBEST)?;
    let distributed = vs::scores(&mols);
    assert_eq!(distributed.len(), oracle.len());
    for ((dn, ds), (on, os)) in distributed.iter().zip(&oracle) {
        assert_eq!(dn, on, "pose order differs from single-core run");
        assert!((ds - os).abs() < 1e-3, "score differs: {ds} vs {os}");
    }
    println!("\nsingle-core vs distributed: top-{} identical ✓", vs::NBEST);
    Ok(())
}
