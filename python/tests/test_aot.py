# pytest: AOT lowering — every entry lowers to parseable HLO text with a
# consistent manifest ABI (the contract the rust runtime loads against).

from __future__ import annotations

import json
import os

import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    outdir = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build(outdir)
    return outdir, manifest


class TestAot:
    def test_all_entries_emitted(self, built):
        outdir, manifest = built
        assert set(manifest["entries"]) == set(aot.ENTRIES)
        for meta in manifest["entries"].values():
            assert os.path.exists(os.path.join(outdir, meta["file"]))

    def test_hlo_text_is_hlo(self, built):
        outdir, manifest = built
        for meta in manifest["entries"].values():
            text = open(os.path.join(outdir, meta["file"])).read()
            assert "HloModule" in text
            assert "ENTRY" in text
            # jax >= 0.5 proto ids overflow xla_extension 0.5.1; text is
            # the interchange format, so nothing serialized/binary here.
            assert text.isascii()

    def test_manifest_abi_shapes(self, built):
        _, manifest = built
        e = manifest["entries"]["docking"]
        assert e["inputs"][0]["shape"] == [model.DOCK_M, model.DOCK_F]
        assert e["inputs"][1]["shape"] == [model.DOCK_F, model.DOCK_P]
        assert e["outputs"][0]["shape"] == [model.DOCK_M]
        g = manifest["entries"]["genotype"]
        assert g["inputs"][0]["shape"] == [model.GL_S, 4]
        assert g["outputs"][0]["shape"] == [model.GL_S, 10]

    def test_goldens_are_finite(self, built):
        _, manifest = built
        for name, meta in manifest["entries"].items():
            for out in meta["outputs"]:
                assert np.isfinite(out["sum"]), (name, out)

    def test_deterministic_rebuild(self, built, tmp_path):
        """Same inputs -> byte-identical HLO text (cache correctness)."""
        outdir, manifest = built
        manifest2 = aot.build(str(tmp_path), entries=["gc_count"])
        a = manifest["entries"]["gc_count"]["sha256"]
        b = manifest2["entries"]["gc_count"]["sha256"]
        assert a == b

    def test_manifest_json_roundtrip(self, built):
        outdir, manifest = built
        on_disk = json.load(open(os.path.join(outdir, "manifest.json")))
        assert on_disk == json.loads(json.dumps(manifest))
