# pytest: kernel vs ref allclose — the CORE correctness signal.
#
# hypothesis sweeps shapes (multiples of the tile sizes) and block
# configurations; every Pallas kernel must match its pure-jnp oracle in
# kernels/ref.py bit-for-bit within float tolerance.

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import docking, gc_count, genotype, ref

RNG = np.random.default_rng(7)


def _feats(m, f):
    return RNG.normal(size=(m, f)).astype(np.float32)


# ---------------------------------------------------------------------------
# docking kernel
# ---------------------------------------------------------------------------
class TestDocking:
    def test_matches_ref_default_shape(self):
        x, w = _feats(128, 256), _feats(256, 32)
        got = docking.dock_scores(x, w)
        want = ref.dock_scores_ref(x, w)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @settings(max_examples=25, deadline=None)
    @given(
        mi=st.integers(1, 4),
        ki=st.integers(1, 4),
        pi=st.integers(1, 4),
    )
    def test_matches_ref_shape_sweep(self, mi, ki, pi):
        m, f, p = 64 * mi, 128 * ki, 32 * pi
        x, w = _feats(m, f), _feats(f, p)
        got = docking.dock_scores(x, w)
        want = ref.dock_scores_ref(x, w)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @settings(max_examples=12, deadline=None)
    @given(
        bm=st.sampled_from([32, 64, 128]),
        bp=st.sampled_from([16, 32]),
        bk=st.sampled_from([64, 128, 256]),
    )
    def test_block_shape_invariance(self, bm, bp, bk):
        """The tiling schedule must not change the numbers."""
        x, w = _feats(128, 256), _feats(256, 32)
        got = docking.dock_scores(x, w, bm=bm, bp=bp, bk=bk)
        want = ref.dock_scores_ref(x, w)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_rejects_nondivisible_shapes(self):
        x, w = _feats(100, 256), _feats(256, 32)
        with pytest.raises(AssertionError):
            docking.dock_scores(x, w)

    def test_score_upper_bound(self):
        """score = -raw - gauss <= -raw, and gauss term is <= beta."""
        x, w = _feats(128, 256), _feats(256, 32)
        raw = x @ w
        got = np.asarray(docking.dock_scores(x, w))
        tol = 1e-3 * (1.0 + np.abs(raw))  # K-blocked accumulation noise
        assert np.all(got <= -raw + tol)
        assert np.all(got >= -raw - docking.SHAPE_BETA - tol)

    def test_bf16_inputs_loose_tolerance(self):
        x = jnp.asarray(_feats(64, 128), jnp.bfloat16).astype(jnp.float32)
        w = jnp.asarray(_feats(128, 32), jnp.bfloat16).astype(jnp.float32)
        got = docking.dock_scores(x, w, bm=64, bp=32, bk=128)
        want = ref.dock_scores_ref(x, w)
        np.testing.assert_allclose(got, want, rtol=1e-2, atol=1e-2)


# ---------------------------------------------------------------------------
# genotype kernel
# ---------------------------------------------------------------------------
class TestGenotype:
    def _emit(self, err=0.01):
        from compile import model

        return np.asarray(model.log_emit_matrix(jnp.float32(err)))

    def test_matches_ref_default_shape(self):
        counts = RNG.integers(0, 50, size=(512, 4)).astype(np.float32)
        emit = self._emit()
        got = genotype.genotype_loglik(counts, emit)
        want = ref.genotype_loglik_ref(counts, emit)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(
        blocks=st.integers(1, 8),
        bs=st.sampled_from([64, 128, 256]),
        err=st.floats(1e-4, 0.2),
    )
    def test_shape_and_block_sweep(self, blocks, bs, err):
        s = bs * blocks
        counts = RNG.integers(0, 50, size=(s, 4)).astype(np.float32)
        emit = self._emit(err)
        got = genotype.genotype_loglik(counts, emit, bs=bs)
        want = ref.genotype_loglik_ref(counts, emit)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_pure_pileup_calls_homozygous(self):
        """All-A pileup must maximize the AA genotype (column 0)."""
        counts = np.zeros((128, 4), np.float32)
        counts[:, 0] = 30.0
        got = np.asarray(genotype.genotype_loglik(counts, self._emit(), bs=128))
        assert np.all(np.argmax(got, axis=1) == 0)

    def test_het_pileup_calls_het(self):
        """50/50 A/C pileup must maximize the AC genotype (column 1)."""
        counts = np.zeros((128, 4), np.float32)
        counts[:, 0] = 20.0
        counts[:, 1] = 20.0
        got = np.asarray(genotype.genotype_loglik(counts, self._emit(), bs=128))
        assert np.all(np.argmax(got, axis=1) == 1)

    def test_rejects_bad_shapes(self):
        with pytest.raises(AssertionError):
            genotype.genotype_loglik(
                np.zeros((100, 4), np.float32), self._emit()
            )


# ---------------------------------------------------------------------------
# gc_count kernel
# ---------------------------------------------------------------------------
class TestGcCount:
    @settings(max_examples=25, deadline=None)
    @given(blocks=st.integers(1, 8), seed=st.integers(0, 2**16))
    def test_matches_ref(self, blocks, seed):
        r = np.random.default_rng(seed)
        codes = r.choice(
            np.array([65, 67, 71, 84], np.int32), size=(512 * blocks,)
        )
        partials = gc_count.gc_partials(codes)
        assert int(np.sum(partials)) == int(ref.gc_count_ref(codes))

    def test_known_string(self):
        codes = np.frombuffer(b"GATTACAGC" + b"A" * 503, np.uint8).astype(
            np.int32
        )
        assert int(np.sum(gc_count.gc_partials(codes))) == 4

    def test_all_gc(self):
        codes = np.full((1024,), 71, np.int32)
        assert int(np.sum(gc_count.gc_partials(codes))) == 1024

    def test_no_gc(self):
        codes = np.full((1024,), 65, np.int32)
        assert int(np.sum(gc_count.gc_partials(codes))) == 0

    @settings(max_examples=10, deadline=None)
    @given(bn=st.sampled_from([128, 256, 512, 1024]))
    def test_block_invariance(self, bn):
        r = np.random.default_rng(3)
        codes = r.choice(np.array([65, 67, 71, 84], np.int32), size=(2048,))
        total = int(np.sum(gc_count.gc_partials(codes, bn=bn)))
        assert total == int(ref.gc_count_ref(codes))
