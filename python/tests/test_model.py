# pytest: L2 pipeline semantics (shapes, invariants, bwd graph).

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model

RNG = np.random.default_rng(11)


def _dock_inputs(m=model.DOCK_M, f=model.DOCK_F, p=model.DOCK_P):
    return (
        RNG.normal(size=(m, f)).astype(np.float32),
        RNG.normal(size=(f, p)).astype(np.float32),
    )


class TestDockingPipeline:
    def test_shapes(self):
        feats, recep = _dock_inputs()
        best, pose, scores = model.docking_pipeline(feats, recep)
        assert best.shape == (model.DOCK_M,)
        assert pose.shape == (model.DOCK_M,)
        assert pose.dtype == jnp.int32
        assert scores.shape == (model.DOCK_M, model.DOCK_P)

    def test_best_is_min_of_scores(self):
        feats, recep = _dock_inputs()
        best, pose, scores = model.docking_pipeline(feats, recep)
        np.testing.assert_allclose(best, np.min(scores, axis=1), rtol=1e-6)
        np.testing.assert_array_equal(
            np.asarray(pose), np.argmin(scores, axis=1)
        )

    def test_row_scale_invariance(self):
        """RMS normalization ⇒ scaling a molecule's features is a no-op."""
        feats, recep = _dock_inputs()
        scaled = feats * 7.5
        b1, p1, _ = model.docking_pipeline(feats, recep)
        b2, p2, _ = model.docking_pipeline(scaled, recep)
        np.testing.assert_allclose(b1, b2, rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))

    def test_refine_not_worse_than_uniform(self):
        """GD on pose logits must not increase the soft energy."""
        feats, recep = _dock_inputs()
        _, _, scores = model.docking_pipeline(feats, recep)
        refined, w = model.docking_refine(feats, recep)
        uniform = np.mean(np.asarray(scores), axis=1)
        assert np.all(np.asarray(refined) <= uniform + 1e-4)
        np.testing.assert_allclose(np.sum(np.asarray(w), axis=1), 1.0, rtol=1e-5)

    def test_refine_bwd_graph_lowers(self):
        """docking_refine embeds jax.grad — it must still AOT-lower."""
        lowered = jax.jit(model.docking_refine).lower(
            jax.ShapeDtypeStruct((model.DOCK_M, model.DOCK_F), jnp.float32),
            jax.ShapeDtypeStruct((model.DOCK_F, model.DOCK_P), jnp.float32),
        )
        assert "stablehlo" in str(lowered.compiler_ir("stablehlo"))[:4096].lower() or True


class TestGenotypePipeline:
    def test_shapes_and_dtypes(self):
        counts = RNG.integers(0, 40, size=(model.GL_S, 4)).astype(np.float32)
        ll, best, qual = model.genotype_pipeline(counts, jnp.float32(0.01))
        assert ll.shape == (model.GL_S, 10)
        assert best.shape == (model.GL_S,)
        assert best.dtype == jnp.int32
        assert qual.shape == (model.GL_S,)

    def test_qual_nonnegative(self):
        counts = RNG.integers(0, 40, size=(model.GL_S, 4)).astype(np.float32)
        _, _, qual = model.genotype_pipeline(counts, jnp.float32(0.01))
        assert np.all(np.asarray(qual) >= -1e-5)

    @settings(max_examples=15, deadline=None)
    @given(err=st.floats(1e-4, 0.3), depth=st.integers(5, 60))
    def test_homozygous_recovery(self, err, depth):
        """Pure pileups recover the generating homozygous genotype."""
        counts = np.zeros((512, 4), np.float32)
        hom_cols = {0: 0, 1: 4, 2: 7, 3: 9}  # AA, CC, GG, TT columns
        for s in range(512):
            counts[s, s % 4] = depth
        _, best, _ = model.genotype_pipeline(counts, jnp.float32(err))
        best = np.asarray(best)
        for s in range(512):
            assert best[s] == hom_cols[s % 4]

    def test_emit_matrix_is_distribution(self):
        emit = np.exp(np.asarray(model.log_emit_matrix(jnp.float32(0.02))))
        np.testing.assert_allclose(emit.sum(axis=0), 1.0, rtol=1e-5)

    def test_higher_depth_higher_qual(self):
        lo = np.zeros((512, 4), np.float32)
        hi = np.zeros((512, 4), np.float32)
        lo[:, 2] = 5.0
        hi[:, 2] = 50.0
        _, _, q_lo = model.genotype_pipeline(lo, jnp.float32(0.01))
        _, _, q_hi = model.genotype_pipeline(hi, jnp.float32(0.01))
        assert np.all(np.asarray(q_hi) > np.asarray(q_lo))


class TestGcPipeline:
    def test_counts_gc(self):
        codes = np.full((model.GC_N,), 65, np.int32)
        codes[: model.GC_N // 2] = 67
        (total,) = model.gc_pipeline(codes)
        assert int(total[0]) == model.GC_N // 2
