"""L1 Pallas kernel: Chemgauss-like docking score contraction.

The FLOP-dominant inner loop of the (simulated) FRED docking tool is a
``(molecules x features) @ (features x poses)`` contraction followed by a
smooth Gaussian shaping term — see DESIGN.md §2/§8.  The kernel is tiled
for the MXU: molecule/pose tiles sit in VMEM while the feature (K)
dimension is streamed block-by-block and accumulated in the output ref.

The shaping epilogue runs *inside* the kernel on the last K step so the
raw accumulator never round-trips to HBM (perf pass, EXPERIMENTS.md §Perf).

Pallas is lowered with ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls; real-TPU VMEM/MXU estimates live in DESIGN.md §8.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Chemgauss-like shaping constants (match kernels/ref.py exactly).
SHAPE_MU = 4.0
SHAPE_SIGMA = 2.0
SHAPE_BETA = 3.0

# Default tile sizes — chosen for MXU friendliness (128 lanes) and a VMEM
# footprint of ~(BM*BK + BK*BP + BM*BP)*4 B per step (see DESIGN.md §8).
BLOCK_M = 64
BLOCK_P = 32
BLOCK_K = 128


def _dock_kernel(x_ref, w_ref, o_ref, *, nk: int):
    """One (BM, BP) output tile; K streamed over ``nk`` grid steps."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        raw = o_ref[...]
        # Chemgauss-like smooth shaping: linear attraction + a Gaussian
        # well centred at SHAPE_MU.  Lower (more negative) is better.
        gauss = SHAPE_BETA * jnp.exp(
            -((raw - SHAPE_MU) ** 2) / (2.0 * SHAPE_SIGMA**2)
        )
        o_ref[...] = -raw - gauss


@functools.partial(jax.jit, static_argnames=("bm", "bp", "bk"))
def dock_scores(
    features: jax.Array,
    receptor: jax.Array,
    *,
    bm: int = BLOCK_M,
    bp: int = BLOCK_P,
    bk: int = BLOCK_K,
) -> jax.Array:
    """Score every molecule against every receptor pose.

    Args:
      features: (M, F) float32 per-molecule feature rows.
      receptor: (F, P) float32 per-pose receptor grid weights.
    Returns:
      (M, P) float32 pose scores (lower = better binding).
    """
    m, f = features.shape
    f2, p = receptor.shape
    assert f == f2, (f, f2)
    assert m % bm == 0 and p % bp == 0 and f % bk == 0, (m, f, p, bm, bp, bk)
    nk = f // bk
    grid = (m // bm, p // bp, nk)
    return pl.pallas_call(
        functools.partial(_dock_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bp), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bp), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, p), jnp.float32),
        interpret=True,
    )(features, receptor)
