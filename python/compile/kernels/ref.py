"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

pytest (python/tests/) asserts kernel == ref across shape/dtype sweeps;
the rust integration tests re-check the same numbers through the AOT
artifacts, closing the loop python -> HLO text -> PJRT -> rust.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .docking import SHAPE_BETA, SHAPE_MU, SHAPE_SIGMA
from .gc_count import ASCII_C, ASCII_G


def dock_scores_ref(features: jax.Array, receptor: jax.Array) -> jax.Array:
    """Oracle for kernels.docking.dock_scores."""
    raw = features.astype(jnp.float32) @ receptor.astype(jnp.float32)
    gauss = SHAPE_BETA * jnp.exp(-((raw - SHAPE_MU) ** 2) / (2.0 * SHAPE_SIGMA**2))
    return -raw - gauss


def genotype_loglik_ref(counts: jax.Array, log_emit: jax.Array) -> jax.Array:
    """Oracle for kernels.genotype.genotype_loglik."""
    return counts.astype(jnp.float32) @ log_emit.astype(jnp.float32)


def gc_count_ref(codes: jax.Array) -> jax.Array:
    """Oracle for kernels.gc_count (total count, not partials)."""
    is_gc = jnp.logical_or(codes == ASCII_G, codes == ASCII_C)
    return jnp.sum(is_gc.astype(jnp.int32))
