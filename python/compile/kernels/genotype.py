"""L1 Pallas kernel: per-site diploid genotype log-likelihoods.

The numeric core of the (simulated) GATK HaplotypeCaller: given per-site
base pileup counts ``(S, 4)`` and a per-genotype emission matrix
``(4, 10)`` of log base-emission probabilities, the log-likelihood of
genotype g at site s is ``counts[s] @ log_emit[:, g]`` — a skinny matmul
tiled over site blocks.  argmax / quality extraction happens in L2
(`model.genotype_pipeline`) where XLA fuses it with the kernel output.

interpret=True (CPU PJRT); TPU notes in DESIGN.md §8.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_BASES = 4  # A C G T
N_GENOTYPES = 10  # unordered diploid pairs of 4 alleles
BLOCK_S = 128  # sites per tile


def _gl_kernel(counts_ref, emit_ref, o_ref):
    o_ref[...] = jnp.dot(
        counts_ref[...], emit_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bs",))
def genotype_loglik(
    counts: jax.Array, log_emit: jax.Array, *, bs: int = BLOCK_S
) -> jax.Array:
    """Per-site genotype log-likelihoods.

    Args:
      counts: (S, 4) float32 pileup base counts per site.
      log_emit: (4, 10) float32 log P(read base | genotype).
    Returns:
      (S, 10) float32 log-likelihood of each genotype at each site.
    """
    s, nb = counts.shape
    nb2, ng = log_emit.shape
    assert nb == N_BASES and nb2 == N_BASES and ng == N_GENOTYPES
    assert s % bs == 0, (s, bs)
    return pl.pallas_call(
        _gl_kernel,
        grid=(s // bs,),
        in_specs=[
            pl.BlockSpec((bs, nb), lambda i: (i, 0)),
            pl.BlockSpec((nb, ng), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bs, ng), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, ng), jnp.float32),
        interpret=True,
    )(counts, log_emit)
