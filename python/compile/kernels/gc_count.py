"""L1 Pallas kernel: GC-content count over an ASCII base stream.

The paper's introductory example (Listing 1: ``grep -o '[GC]' | wc -l``).
Each grid step consumes a block of ASCII codes and emits a partial count;
L2 sums the partials.  interpret=True (CPU PJRT).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ASCII_G = 71
ASCII_C = 67
BLOCK_N = 512


def _gc_kernel(codes_ref, o_ref):
    codes = codes_ref[...]
    is_gc = jnp.logical_or(codes == ASCII_G, codes == ASCII_C)
    o_ref[...] = jnp.sum(is_gc.astype(jnp.int32), keepdims=True)


@functools.partial(jax.jit, static_argnames=("bn",))
def gc_partials(codes: jax.Array, *, bn: int = BLOCK_N) -> jax.Array:
    """Per-block G/C counts.

    Args:
      codes: (N,) int32 ASCII codes of DNA bases (padding must not be G/C).
    Returns:
      (N // bn,) int32 partial counts; sum for the total.
    """
    (n,) = codes.shape
    assert n % bn == 0, (n, bn)
    return pl.pallas_call(
        _gc_kernel,
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((bn,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n // bn,), jnp.int32),
        interpret=True,
    )(codes)
