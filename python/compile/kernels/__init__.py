# L1: Pallas kernels for the paper's compute hot-spots (docking score,
# genotype likelihood, GC count) + pure-jnp oracles in ref.py.
from . import docking, gc_count, genotype, ref  # noqa: F401
