# AOT lowering: jax/pallas -> HLO *text* artifacts for the rust runtime.
#
# HLO text (NOT lowered.compiler_ir(...).serialize() / HloModuleProto
# bytes) is the interchange format: jax >= 0.5 emits protos with 64-bit
# instruction ids that xla_extension 0.5.1 (the version the published
# `xla` 0.1.6 crate links) rejects with `proto.id() <= INT_MAX`.  The XLA
# text parser reassigns ids, so text round-trips cleanly — see
# /opt/xla-example/load_hlo and its README.
#
# Alongside each <entry>.hlo.txt we write manifest.json describing the
# artifact ABI (input/output shapes + dtypes + golden smoke vectors); the
# rust runtime validates against it at load time and the integration tests
# replay the goldens through PJRT.

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model

SCHEMA_VERSION = 2


def to_hlo_text(lowered) -> str:
    """StableHLO module -> XLA computation -> HLO text (see header)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _rng(seed: int):
    return np.random.default_rng(seed)


def _golden_inputs(name: str):
    """Deterministic smoke inputs per entry (replayed from rust)."""
    r = _rng(0xC0FFEE)
    if name in ("docking", "docking_refine"):
        feats = r.normal(size=(model.DOCK_M, model.DOCK_F)).astype(np.float32)
        recep = r.normal(size=(model.DOCK_F, model.DOCK_P)).astype(np.float32)
        return [feats, recep]
    if name == "genotype":
        counts = r.integers(0, 40, size=(model.GL_S, 4)).astype(np.float32)
        err = np.float32(0.01)
        return [counts, err]
    if name == "gc_count":
        codes = r.choice(
            np.array([65, 67, 71, 84], dtype=np.int32), size=(model.GC_N,)
        )
        return [codes]
    raise KeyError(name)


# Registry of AOT entry points: name -> (fn, input specs).
ENTRIES = {
    "docking": (
        model.docking_pipeline,
        [
            _spec((model.DOCK_M, model.DOCK_F), jnp.float32),
            _spec((model.DOCK_F, model.DOCK_P), jnp.float32),
        ],
    ),
    "docking_refine": (
        model.docking_refine,
        [
            _spec((model.DOCK_M, model.DOCK_F), jnp.float32),
            _spec((model.DOCK_F, model.DOCK_P), jnp.float32),
        ],
    ),
    "genotype": (
        model.genotype_pipeline,
        [
            _spec((model.GL_S, 4), jnp.float32),
            _spec((), jnp.float32),
        ],
    ),
    "gc_count": (
        model.gc_pipeline,
        [_spec((model.GC_N,), jnp.int32)],
    ),
}


def lower_entry(name: str):
    fn, specs = ENTRIES[name]
    return jax.jit(fn).lower(*specs)


def build(outdir: str, entries=None) -> dict:
    os.makedirs(outdir, exist_ok=True)
    manifest = {"schema": SCHEMA_VERSION, "entries": {}}
    for name in entries or ENTRIES:
        fn, specs = ENTRIES[name]
        lowered = lower_entry(name)
        text = to_hlo_text(lowered)
        path = os.path.join(outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)

        # Golden smoke vectors: run the jitted fn on deterministic inputs
        # and record flat checksums the rust side re-verifies via PJRT.
        inputs = _golden_inputs(name)
        outputs = jax.tree_util.tree_leaves(jax.jit(fn)(*inputs))
        goldens = []
        for out in outputs:
            arr = np.asarray(out)
            goldens.append(
                {
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "sum": float(np.sum(arr.astype(np.float64))),
                    "first": float(arr.reshape(-1)[0]) if arr.size else 0.0,
                }
            )
        manifest["entries"][name] = {
            "file": os.path.basename(path),
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "inputs": [
                {"shape": list(s.shape), "dtype": str(np.dtype(s.dtype))}
                for s in specs
            ],
            "outputs": goldens,
        }
        print(f"[aot] {name}: {len(text)} chars -> {path}")
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--entry", action="append", help="subset of entries")
    args = ap.parse_args()
    build(args.out, args.entry)


if __name__ == "__main__":
    main()
