# L2: JAX compute graphs for the containerized tools' numeric cores.
#
# Each pipeline below wraps an L1 Pallas kernel (kernels/) with the
# surrounding math the tool needs (normalization, argmax/quality
# extraction, gradient-based pose refinement) so that the whole thing
# lowers into ONE fused HLO module per tool.  aot.py lowers these with
# static AOT shapes (the rust side pads/batches to them) and the rust
# runtime executes the artifacts via PJRT — python never runs on the
# request path.

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import docking, gc_count, genotype

# ---------------------------------------------------------------------------
# Static AOT shapes (the rust coordinator batches records to these).
# ---------------------------------------------------------------------------
DOCK_M = 128  # molecules per batch
DOCK_F = 256  # feature dimension
DOCK_P = 32  # receptor poses
GL_S = 512  # pileup sites per batch
GC_N = 4096  # bases per batch
REFINE_STEPS = 3
REFINE_LR = 0.05

# Unordered diploid genotype enumeration over alleles A,C,G,T — the order
# is part of the artifact ABI (rust/src/tools/gatk.rs mirrors it).
GENOTYPES = [(a, b) for a in range(4) for b in range(a, 4)]
assert len(GENOTYPES) == genotype.N_GENOTYPES


def log_emit_matrix(err: jax.Array) -> jax.Array:
    """(4, 10) log P(read base | genotype) for a scalar error rate."""
    base = jnp.arange(4)
    # p(c|allele a) = 1-err if c == a else err/3
    p_given_allele = jnp.where(
        base[:, None] == base[None, :], 1.0 - err, err / 3.0
    )  # (read_base, allele)
    cols = []
    for a, b in GENOTYPES:
        cols.append(0.5 * (p_given_allele[:, a] + p_given_allele[:, b]))
    emit = jnp.stack(cols, axis=1)  # (4, 10)
    return jnp.log(emit)


# ---------------------------------------------------------------------------
# Docking (VS pipeline — the FRED tool core).
# ---------------------------------------------------------------------------
def docking_pipeline(features: jax.Array, receptor: jax.Array):
    """Best pose score + index per molecule.

    Returns (best_score (M,) f32, best_pose (M,) i32, scores (M, P) f32).
    """
    # Feature normalization is part of the tool, not the data generator:
    # rows are scaled to unit RMS so scores are library-independent.
    rms = jnp.sqrt(jnp.mean(features**2, axis=1, keepdims=True) + 1e-6)
    scores = docking.dock_scores(features / rms, receptor)
    best_pose = jnp.argmin(scores, axis=1).astype(jnp.int32)
    best_score = jnp.min(scores, axis=1)
    return best_score, best_pose, scores


def _refine_loss(weights: jax.Array, scores: jax.Array) -> jax.Array:
    """Soft pose-assignment energy: softmax-weighted score + entropy reg."""
    w = jax.nn.softmax(weights, axis=1)
    energy = jnp.sum(w * scores, axis=1)
    reg = 1e-2 * jnp.sum(w * jnp.log(w + 1e-9), axis=1)
    return jnp.sum(energy + reg)


def docking_refine(features: jax.Array, receptor: jax.Array):
    """Gradient-refined soft pose assignment (exercises the bwd graph).

    A few steps of gradient descent on per-molecule pose logits against
    the kernel-produced score surface.  Returns (refined_score (M,) f32,
    weights (M, P) f32).
    """
    _, _, scores = docking_pipeline(features, receptor)
    weights = jnp.zeros_like(scores)
    grad = jax.grad(_refine_loss)
    for _ in range(REFINE_STEPS):
        weights = weights - REFINE_LR * grad(weights, scores)
    w = jax.nn.softmax(weights, axis=1)
    refined = jnp.sum(w * scores, axis=1)
    return refined, w


# ---------------------------------------------------------------------------
# Genotype calling (SNP pipeline — the GATK tool core).
# ---------------------------------------------------------------------------
def genotype_pipeline(counts: jax.Array, err: jax.Array):
    """Per-site genotype call.

    Args:
      counts: (S, 4) f32 pileup base counts.
      err: scalar f32 sequencing error rate.
    Returns (loglik (S, 10) f32, best (S,) i32, qual (S,) f32).
    """
    loglik = genotype.genotype_loglik(counts, log_emit_matrix(err))
    best = jnp.argmax(loglik, axis=1).astype(jnp.int32)
    top = jnp.max(loglik, axis=1)
    # Phred-scaled distance to the runner-up genotype.
    masked = jnp.where(
        jax.nn.one_hot(best, genotype.N_GENOTYPES, dtype=bool), -jnp.inf, loglik
    )
    second = jnp.max(masked, axis=1)
    qual = (10.0 / jnp.log(10.0)) * (top - second)
    return loglik, best, qual


# ---------------------------------------------------------------------------
# GC count (Listing 1 — the quickstart tool core).
# ---------------------------------------------------------------------------
def gc_pipeline(codes: jax.Array):
    """Total G/C count over an ASCII base block. Returns ((1,) i32,)."""
    partials = gc_count.gc_partials(codes)
    return (jnp.sum(partials, keepdims=True),)
