//! Per-task cost models.
//!
//! A containerized task's virtual duration decomposes as
//!
//! ```text
//!   pull (once per image per worker)            container/registry
//! + container start                             fixed per task
//! + stage-in  (partition bytes -> mount point)  tmpfs or disk bandwidth
//! + compute   (tool model: fixed + per byte + per record)
//! + stage-out (output bytes <- mount point)
//! ```
//!
//! Tool models are calibrated against the paper's reported wall-clocks
//! (e.g. VS: ~2.2M molecules in ~3h on 128 vCPUs -> ~0.6 core-seconds
//! per molecule dominated by FRED). See `tools/*::cost_model`.

use super::Duration;

/// How a tool's compute time scales with its input.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    /// Fixed cost per invocation (startup of the wrapped binary).
    pub fixed: Duration,
    /// Seconds per input byte (parsing/IO-bound part).
    pub secs_per_byte: f64,
    /// Seconds per record (compute-bound part, e.g. per molecule).
    pub secs_per_record: f64,
    /// How many vCPU slots the tool saturates (bwa -t 8 => 8).
    pub cpus: u32,
}

impl CostModel {
    pub const fn free() -> Self {
        CostModel { fixed: Duration::ZERO, secs_per_byte: 0.0, secs_per_record: 0.0, cpus: 1 }
    }

    pub fn compute(&self, input_bytes: u64, records: u64) -> Duration {
        let secs = self.secs_per_byte * input_bytes as f64
            + self.secs_per_record * records as f64;
        self.fixed + Duration::seconds(secs)
    }
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel::free()
    }
}

/// Full accounted cost of one executed task (virtual), with the real
/// measured wall time kept alongside for the §Perf tables.
#[derive(Debug, Clone, Copy, Default)]
pub struct TaskCost {
    pub pull: Duration,
    pub container_start: Duration,
    pub stage_in: Duration,
    pub compute: Duration,
    pub stage_out: Duration,
    /// vCPU slots this task occupies while running.
    pub cpus: u32,
    /// Real wall-clock of the actual in-process execution.
    pub real: std::time::Duration,
}

impl TaskCost {
    /// Total virtual duration of the task on its worker.
    pub fn total(&self) -> Duration {
        self.pull + self.container_start + self.stage_in + self.compute + self.stage_out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_scales_linearly() {
        let m = CostModel {
            fixed: Duration::seconds(1.0),
            secs_per_byte: 1e-6,
            secs_per_record: 0.5,
            cpus: 1,
        };
        let d = m.compute(1_000_000, 10);
        assert!((d.as_seconds() - (1.0 + 1.0 + 5.0)).abs() < 1e-9);
    }

    #[test]
    fn free_model_is_zero() {
        assert_eq!(CostModel::free().compute(1 << 30, 1 << 20), Duration::ZERO);
    }

    #[test]
    fn task_cost_totals() {
        let c = TaskCost {
            pull: Duration::seconds(2.0),
            container_start: Duration::seconds(0.5),
            stage_in: Duration::seconds(0.25),
            compute: Duration::seconds(10.0),
            stage_out: Duration::seconds(0.25),
            cpus: 1,
            real: std::time::Duration::ZERO,
        };
        assert!((c.total().as_seconds() - 13.0).abs() < 1e-9);
    }
}
