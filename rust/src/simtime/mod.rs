//! Execution-driven discrete-event simulation of cluster time.
//!
//! Tasks *really execute* (real bytes through real tools, including the
//! PJRT artifacts), while their *durations* are charged to a virtual
//! clock against a calibrated cluster model (DESIGN.md §6). Weak-scaling
//! efficiency and speedup — the paper's metrics — are ratios of virtual
//! makespans, which makes the curves deterministic and lets a laptop
//! reproduce the shape of a 16-node OpenStack cluster.
//!
//! * [`VirtualTime`] / [`Duration`] — fixed-point virtual seconds.
//! * [`CostModel`] — per-task cost: container lifecycle + per-byte work.
//! * [`NetModel`] / [`DiskModel`] — transfer-time models.
//! * [`SlotSchedule`] — list-scheduling of weighted tasks onto vCPU
//!   slots, the core of stage makespan computation.

pub mod cost;
pub mod net;
pub mod schedule;

pub use cost::{CostModel, TaskCost};
pub use net::{DiskModel, NetModel};
pub use schedule::{
    SlotSchedule, SlotTask, SpecDecision, SpecOutcome, SpeculationPolicy, TaskPlacement,
};

/// Virtual time in microseconds (fixed point; f64 drift would make the
/// WSE tables flaky).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default, Hash)]
pub struct VirtualTime(pub u64);

/// Virtual duration in microseconds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default, Hash)]
pub struct Duration(pub u64);

impl VirtualTime {
    pub const ZERO: VirtualTime = VirtualTime(0);

    pub fn seconds(s: f64) -> Self {
        VirtualTime((s * 1e6).round() as u64)
    }

    pub fn as_seconds(self) -> f64 {
        self.0 as f64 * 1e-6
    }

    pub fn max(self, other: Self) -> Self {
        VirtualTime(self.0.max(other.0))
    }
}

impl Duration {
    pub const ZERO: Duration = Duration(0);

    pub fn seconds(s: f64) -> Self {
        Duration((s * 1e6).round() as u64)
    }

    pub fn micros(us: u64) -> Self {
        Duration(us)
    }

    pub fn as_seconds(self) -> f64 {
        self.0 as f64 * 1e-6
    }
}

impl std::ops::Add<Duration> for VirtualTime {
    type Output = VirtualTime;
    fn add(self, d: Duration) -> VirtualTime {
        VirtualTime(self.0 + d.0)
    }
}

impl std::ops::Add for Duration {
    type Output = Duration;
    fn add(self, d: Duration) -> Duration {
        Duration(self.0 + d.0)
    }
}

impl std::ops::AddAssign for Duration {
    fn add_assign(&mut self, d: Duration) {
        self.0 += d.0;
    }
}

impl std::ops::Sub for VirtualTime {
    type Output = Duration;
    fn sub(self, t: VirtualTime) -> Duration {
        Duration(self.0.saturating_sub(t.0))
    }
}

impl std::fmt::Display for VirtualTime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:.3}s", self.as_seconds())
    }
}

impl std::fmt::Display for Duration {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:.3}s", self.as_seconds())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let t = VirtualTime::seconds(1.0) + Duration::seconds(0.5);
        assert_eq!(t, VirtualTime::seconds(1.5));
        assert_eq!(t - VirtualTime::seconds(1.0), Duration::seconds(0.5));
        // saturating: no negative durations
        assert_eq!(VirtualTime::ZERO - t, Duration::ZERO);
    }

    #[test]
    fn display() {
        assert_eq!(VirtualTime::seconds(2.5).to_string(), "2.500s");
    }
}
