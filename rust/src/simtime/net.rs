//! Network and disk transfer-time models.
//!
//! Everything is a simple latency + bandwidth pipe, but with the two
//! features the paper's curves hinge on:
//!
//! * per-endpoint NIC caps (intra-cluster shuffles are limited by the
//!   slowest of sender/receiver), and
//! * an *aggregate* cap for external services (Swift's service pipe,
//!   S3's WAN egress) — this is what makes Figure 5's ingestion speedup
//!   flatten between 8 and 16 workers.

use super::Duration;

/// A latency + bandwidth pipe.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetModel {
    /// One-way latency per transfer.
    pub latency: Duration,
    /// Per-connection bandwidth, bytes/second.
    pub bw_bytes_per_sec: f64,
    /// Aggregate cap across all concurrent users of this pipe
    /// (bytes/second); `f64::INFINITY` when unconstrained.
    pub aggregate_bw: f64,
}

impl NetModel {
    pub fn new(latency_s: f64, bw: f64) -> Self {
        NetModel { latency: Duration::seconds(latency_s), bw_bytes_per_sec: bw, aggregate_bw: f64::INFINITY }
    }

    pub fn with_aggregate(mut self, agg: f64) -> Self {
        self.aggregate_bw = agg;
        self
    }

    /// Time for one transfer of `bytes` with `concurrency` equal sharers
    /// of the aggregate pipe.
    pub fn transfer(&self, bytes: u64, concurrency: u32) -> Duration {
        if bytes == 0 {
            return Duration::ZERO;
        }
        let per_conn = self
            .bw_bytes_per_sec
            .min(self.aggregate_bw / concurrency.max(1) as f64);
        self.latency + Duration::seconds(bytes as f64 / per_conn)
    }

    /// 10 GbE-ish intra-cluster link.
    pub fn lan() -> Self {
        NetModel::new(0.0002, 1.1e9)
    }

    /// Nearby object store (Swift at the cloud provider): good pipe but a
    /// shared service cap.
    pub fn swift_service() -> Self {
        NetModel::new(0.004, 400e6).with_aggregate(2.4e9)
    }

    /// Remote S3 over WAN: high latency, modest per-connection bandwidth,
    /// tight aggregate egress.
    pub fn s3_wan() -> Self {
        NetModel::new(0.070, 60e6).with_aggregate(500e6)
    }
}

/// Disk model for disk-backed mount points and spill files.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DiskModel {
    pub seek: Duration,
    pub bw_bytes_per_sec: f64,
}

impl DiskModel {
    /// Cloud-volume HDD-ish defaults (matching cPouta's ephemeral disks).
    pub fn hdd() -> Self {
        DiskModel { seek: Duration::seconds(0.008), bw_bytes_per_sec: 160e6 }
    }

    /// HDFS datanode sequential read: striped ephemeral disks + page
    /// cache — the co-location advantage of §1.3/Figure 3.
    pub fn datanode() -> Self {
        DiskModel { seek: Duration::seconds(0.004), bw_bytes_per_sec: 450e6 }
    }

    /// tmpfs: memory bandwidth, no seek. The paper's default mount.
    pub fn tmpfs() -> Self {
        DiskModel { seek: Duration::ZERO, bw_bytes_per_sec: 8e9 }
    }

    pub fn rw(&self, bytes: u64) -> Duration {
        if bytes == 0 {
            return Duration::ZERO;
        }
        self.seek + Duration::seconds(bytes as f64 / self.bw_bytes_per_sec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_bytes_is_free() {
        assert_eq!(NetModel::lan().transfer(0, 1), Duration::ZERO);
        assert_eq!(DiskModel::hdd().rw(0), Duration::ZERO);
    }

    #[test]
    fn aggregate_cap_bites_at_high_concurrency() {
        let s3 = NetModel::s3_wan();
        let one = s3.transfer(1 << 30, 1);
        let sixteen = s3.transfer(1 << 30, 16);
        // At concurrency 16 each connection gets 500/16 ≈ 31 MB/s < 60 MB/s.
        assert!(sixteen > one);
        let per_conn_16 = 500e6 / 16.0;
        let want = 0.070 + (1u64 << 30) as f64 / per_conn_16;
        assert!((sixteen.as_seconds() - want).abs() < 0.01, "{sixteen}");
    }

    #[test]
    fn lan_uncapped_by_concurrency() {
        let lan = NetModel::lan();
        assert_eq!(lan.transfer(1 << 20, 1), lan.transfer(1 << 20, 64));
    }

    #[test]
    fn tmpfs_much_faster_than_hdd() {
        let b = 256u64 << 20;
        assert!(DiskModel::tmpfs().rw(b) < DiskModel::hdd().rw(b));
    }
}
