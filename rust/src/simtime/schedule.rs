//! List scheduling of weighted tasks onto vCPU slots — the core of the
//! per-stage virtual makespan computation.
//!
//! Mirrors Spark's behaviour closely enough for the paper's curves:
//! tasks are offered in descending duration (LPT), each goes to its
//! locality-preferred worker if a slot frees up there no later than
//! `locality_wait` after the best remote slot (Spark's
//! `spark.locality.wait` analogue), else to the earliest-available
//! worker. Multi-cpu tasks (`spark.task.cpus`) occupy several slots of
//! one worker simultaneously.

use super::{Duration, VirtualTime};

/// One schedulable task.
#[derive(Debug, Clone, Copy)]
pub struct SlotTask {
    /// Caller's identifier (index into the stage's task vec).
    pub id: usize,
    pub duration: Duration,
    /// vCPU slots required on a single worker.
    pub cpus: u32,
    /// Preferred worker for data locality, if any.
    pub preferred: Option<usize>,
    /// Extra duration if scheduled *off* the preferred worker
    /// (remote read of the cached partition).
    pub remote_penalty: Duration,
    /// Earliest virtual time the task may start — its input partition's
    /// availability. ZERO for batch-materialized inputs; streamed
    /// ingest sets it to the partition's seal time so map tasks overlap
    /// the tail of materialization without reading unsealed bytes.
    pub release: VirtualTime,
}

/// Where a task ended up.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TaskPlacement {
    pub id: usize,
    pub worker: usize,
    pub start: VirtualTime,
    pub end: VirtualTime,
    pub local: bool,
}

/// When to race a straggling task — the analogue of Spark's
/// `spark.speculation.*` knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpeculationPolicy {
    /// Fraction of the stage's tasks that must have finished before any
    /// speculative copy launches (`spark.speculation.quantile`).
    pub quantile: f64,
    /// A running task is a straggler when its projected duration
    /// exceeds `multiplier x median(finished durations)`.
    pub multiplier: f64,
    /// At most this many speculative copies per stage.
    pub max_inflight: usize,
}

impl Default for SpeculationPolicy {
    fn default() -> Self {
        SpeculationPolicy { quantile: 0.75, multiplier: 1.5, max_inflight: 4 }
    }
}

/// One speculation race: the copy's placement, who won, and the end
/// time the stage commits for the task.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpecDecision {
    pub id: usize,
    pub copy_worker: usize,
    pub copy_start: VirtualTime,
    pub copy_end: VirtualTime,
    /// True when the copy finished first (the original was cancelled);
    /// false when the original won (the copy was cancelled).
    pub copy_wins: bool,
    pub committed_end: VirtualTime,
}

/// Everything a speculation pass did, for the stage report's audit:
/// every race launches exactly one copy and cancels exactly one loser,
/// so `cancelled() == speculated()` and `wins() <= speculated()`.
#[derive(Debug, Clone, Default)]
pub struct SpecOutcome {
    pub decisions: Vec<SpecDecision>,
}

impl SpecOutcome {
    /// Speculative copies launched.
    pub fn speculated(&self) -> usize {
        self.decisions.len()
    }

    /// Races the copy won (the original attempt was cancelled).
    pub fn wins(&self) -> usize {
        self.decisions.iter().filter(|d| d.copy_wins).count()
    }

    /// Attempts cancelled — one loser per race, whichever side lost.
    pub fn cancelled(&self) -> usize {
        self.decisions.len()
    }
}

/// Slot-level schedule over a set of workers.
#[derive(Debug)]
pub struct SlotSchedule {
    /// `slots[w][s]` = virtual time at which slot `s` of worker `w` frees.
    slots: Vec<Vec<VirtualTime>>,
    locality_wait: Duration,
    killed: Vec<bool>,
    /// Per-worker speed factor: every duration placed on worker `w` is
    /// scaled by `slowdown[w]` (1.0 = nominal, 4.0 = 4x slower — a
    /// planted straggler).
    slowdown: Vec<f64>,
}

impl SlotSchedule {
    pub fn new(workers: usize, vcpus_per_worker: u32) -> Self {
        SlotSchedule {
            slots: vec![vec![VirtualTime::ZERO; vcpus_per_worker as usize]; workers],
            locality_wait: Duration::seconds(3.0),
            killed: vec![false; workers],
            slowdown: vec![1.0; workers],
        }
    }

    pub fn with_locality_wait(mut self, wait: Duration) -> Self {
        self.locality_wait = wait;
        self
    }

    pub fn workers(&self) -> usize {
        self.slots.len()
    }

    /// Push a worker's earliest availability to at least `ready` (image
    /// pull, container-runtime warmup — anything that blocks the whole
    /// worker before its first task of the stage).
    pub fn delay_worker(&mut self, worker: usize, ready: VirtualTime) {
        for s in &mut self.slots[worker] {
            *s = (*s).max(ready);
        }
    }

    /// Remove a worker from further placement (simulated worker loss).
    /// Existing placements stand; makespan ignores the dead worker.
    pub fn kill_worker(&mut self, worker: usize) {
        self.killed[worker] = true;
    }

    /// Slow `worker` down by `factor`: every duration placed there is
    /// scaled by it. Out-of-range workers are ignored (a fault spec may
    /// name a worker a smaller cluster does not have).
    pub fn set_slowdown(&mut self, worker: usize, factor: f64) {
        assert!(factor > 0.0, "slowdown factor must be positive");
        if worker < self.slowdown.len() {
            self.slowdown[worker] = factor;
        }
    }

    fn scaled(d: Duration, factor: f64) -> Duration {
        if factor == 1.0 {
            d
        } else {
            Duration((d.0 as f64 * factor).round() as u64)
        }
    }

    /// Earliest time `cpus` slots are simultaneously free on `worker`.
    ///
    /// Slot vectors are kept sorted (see [`Self::reserve`]), so this is
    /// a direct index — the scheduler runs once per task per stage and
    /// was the top L3 hot spot before (clone + sort per probe,
    /// EXPERIMENTS.md §Perf).
    fn earliest_on(&self, worker: usize, cpus: u32) -> VirtualTime {
        let frees = &self.slots[worker];
        let need = (cpus as usize).min(frees.len());
        debug_assert!(frees.windows(2).all(|w| w[0] <= w[1]));
        frees[need - 1]
    }

    /// Reserve `cpus` slots on `worker` until `end`, keeping the slot
    /// vector sorted: the `cpus` earliest slots become `end`, which is
    /// ≥ every untouched earlier slot, so rotating them into place is a
    /// single in-place merge step.
    fn reserve(&mut self, worker: usize, cpus: u32, end: VirtualTime) {
        let slots = &mut self.slots[worker];
        let take = (cpus as usize).min(slots.len());
        // overwrite the `take` smallest (prefix, since sorted) ...
        for s in slots.iter_mut().take(take) {
            *s = end;
        }
        // ... and restore order: the prefix is now uniform `end`;
        // rotate it past every remaining element smaller than `end`
        let rest = &slots[take..];
        let shift = rest.partition_point(|&s| s < end);
        slots[..take + shift].rotate_left(take);
    }

    /// Schedule all tasks; returns placements (same order as input ids).
    pub fn run(&mut self, tasks: &[SlotTask]) -> Vec<TaskPlacement> {
        // LPT order: longest tasks first minimizes makespan skew.
        let mut order: Vec<usize> = (0..tasks.len()).collect();
        order.sort_by_key(|&i| std::cmp::Reverse(tasks[i].duration));

        let mut placements = Vec::with_capacity(tasks.len());
        for &i in &order {
            let t = tasks[i];
            let cpus = t.cpus.max(1);

            // Earliest option anywhere (live workers only).
            let (mut best_w, mut best_start) = (0usize, VirtualTime(u64::MAX));
            for w in 0..self.slots.len() {
                if self.killed[w] || (cpus as usize) > self.slots[w].len() {
                    continue;
                }
                let s = self.earliest_on(w, cpus).max(t.release);
                if s < best_start {
                    best_start = s;
                    best_w = w;
                }
            }
            assert!(
                best_start != VirtualTime(u64::MAX),
                "task wants {cpus} cpus but no worker has that many slots"
            );

            // Locality preference within the wait window. A preference
            // outside this cluster's worker range (data ingested for a
            // wider layout) is unsatisfiable here: the task schedules
            // anywhere, non-local, with the remote penalty — it must
            // never index past the worker tables.
            let (worker, start, local) = match t.preferred {
                Some(p)
                    if p < self.slots.len()
                        && !self.killed[p]
                        && (cpus as usize) <= self.slots[p].len() =>
                {
                    let ps = self.earliest_on(p, cpus).max(t.release);
                    if ps.0 <= best_start.0 + self.locality_wait.0 {
                        (p, ps, true)
                    } else {
                        (best_w, best_start, false)
                    }
                }
                _ => (best_w, best_start, t.preferred.is_none()),
            };

            let dur = if local {
                t.duration
            } else {
                t.duration + t.remote_penalty
            };
            let end = start + Self::scaled(dur, self.slowdown[worker]);
            self.reserve(worker, cpus, end);
            placements.push(TaskPlacement { id: t.id, worker, start, end, local });
        }
        placements.sort_by_key(|p| p.id);
        placements
    }

    /// Undo the tail of a reservation: `cpus` slots on `worker` that
    /// currently free at `old_end` free at `new_end` instead (the
    /// cancelled loser of a speculation race releases its slots the
    /// moment the winner commits). If later tasks already stacked onto
    /// those slots the slot value moved past `old_end` and nothing is
    /// reclaimed — conservative: the model then under-claims the win,
    /// never over-claims it.
    fn release_to(&mut self, worker: usize, cpus: u32, old_end: VirtualTime, new_end: VirtualTime) {
        let slots = &mut self.slots[worker];
        let take = (cpus as usize).min(slots.len());
        let mut done = 0usize;
        for s in slots.iter_mut().rev() {
            if done == take {
                break;
            }
            if *s == old_end {
                *s = new_end;
                done += 1;
            }
        }
        slots.sort();
    }

    /// [`Self::run`], then a speculation pass: once `policy.quantile`
    /// of the stage's tasks have finished (virtual time `t_q`), any
    /// task whose projected duration exceeds `policy.multiplier x
    /// median(finished)` gets a copy launched on the fastest-available
    /// other live worker (earliest projected *finish*, so a slowed
    /// worker loses even when its slot frees first; locality and
    /// release times still apply). The stage commits whichever attempt
    /// finishes first; the loser is cancelled and its slots reclaimed.
    ///
    /// Returns the committed placements (same order as ids, winners
    /// substituted) plus the race ledger for the launch-counter audit.
    pub fn run_speculated(
        &mut self,
        tasks: &[SlotTask],
        policy: &SpeculationPolicy,
    ) -> (Vec<TaskPlacement>, SpecOutcome) {
        let mut placements = self.run(tasks);
        let mut outcome = SpecOutcome::default();
        let n = placements.len();
        if n == 0 || policy.max_inflight == 0 {
            return (placements, outcome);
        }

        // The watermark: when `quantile` of the stage has finished, and
        // the median duration among those finishers.
        let need = ((policy.quantile * n as f64).ceil() as usize).clamp(1, n);
        let mut by_end: Vec<(VirtualTime, Duration)> =
            placements.iter().map(|p| (p.end, p.end - p.start)).collect();
        by_end.sort();
        let t_q = by_end[need - 1].0;
        let mut finished: Vec<Duration> = by_end[..need].iter().map(|&(_, d)| d).collect();
        finished.sort();
        let threshold = Self::scaled(finished[need / 2], policy.multiplier);

        // Stragglers, worst first, capped at the in-flight budget.
        let mut stragglers: Vec<usize> = (0..n)
            .filter(|&i| {
                placements[i].end > t_q && placements[i].end - placements[i].start > threshold
            })
            .collect();
        stragglers.sort_by_key(|&i| std::cmp::Reverse(placements[i].end));
        stragglers.truncate(policy.max_inflight);

        for i in stragglers {
            let orig = placements[i];
            let t = *tasks.iter().find(|t| t.id == orig.id).expect("placement without a task");
            let cpus = t.cpus.max(1);
            // Copy worker: live, not the original's, with enough slots;
            // earliest projected copy finish wins.
            let mut best: Option<(usize, VirtualTime, VirtualTime)> = None;
            for w in 0..self.slots.len() {
                if w == orig.worker || self.killed[w] || (cpus as usize) > self.slots[w].len() {
                    continue;
                }
                let start = self.earliest_on(w, cpus).max(t.release).max(t_q);
                // as in `run`: a task with no preference is local
                // anywhere; with one, off-preference pays the penalty
                let base = if t.preferred.is_none_or(|p| p == w) {
                    t.duration
                } else {
                    t.duration + t.remote_penalty
                };
                let end = start + Self::scaled(base, self.slowdown[w]);
                if best.is_none_or(|(_, _, e)| end < e) {
                    best = Some((w, start, end));
                }
            }
            let Some((w, copy_start, copy_end)) = best else { continue };
            if copy_end < orig.end {
                // The copy wins: the original is cancelled the moment
                // the copy finishes, so its slots free at that instant.
                self.reserve(w, cpus, copy_end);
                self.release_to(orig.worker, cpus, orig.end, copy_end);
                placements[i] = TaskPlacement {
                    id: orig.id,
                    worker: w,
                    start: copy_start,
                    end: copy_end,
                    local: t.preferred.is_none_or(|p| p == w),
                };
                outcome.decisions.push(SpecDecision {
                    id: orig.id,
                    copy_worker: w,
                    copy_start,
                    copy_end,
                    copy_wins: true,
                    committed_end: copy_end,
                });
            } else {
                // The original wins: the copy holds its slots until the
                // original's finish cancels it.
                self.reserve(w, cpus, orig.end.max(copy_start));
                outcome.decisions.push(SpecDecision {
                    id: orig.id,
                    copy_worker: w,
                    copy_start,
                    copy_end,
                    copy_wins: false,
                    committed_end: orig.end,
                });
            }
        }
        (placements, outcome)
    }

    /// Makespan so far (max slot free time over live workers).
    pub fn makespan(&self) -> VirtualTime {
        self.slots
            .iter()
            .zip(&self.killed)
            .filter(|(_, &k)| !k)
            .flat_map(|(w, _)| w.iter())
            .copied()
            .max()
            .unwrap_or(VirtualTime::ZERO)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(id: usize, secs: f64) -> SlotTask {
        SlotTask {
            id,
            duration: Duration::seconds(secs),
            cpus: 1,
            preferred: None,
            remote_penalty: Duration::ZERO,
            release: VirtualTime::ZERO,
        }
    }

    #[test]
    fn perfect_packing_on_equal_tasks() {
        // 16 x 1s tasks on 2 workers x 4 slots => 2 waves => 2s.
        let mut s = SlotSchedule::new(2, 4);
        let tasks: Vec<SlotTask> = (0..16).map(|i| task(i, 1.0)).collect();
        s.run(&tasks);
        assert_eq!(s.makespan(), VirtualTime::seconds(2.0));
    }

    #[test]
    fn weak_scaling_is_flat_for_embarrassingly_parallel() {
        // N workers, N*8 equal tasks: makespan independent of N.
        let mut spans = vec![];
        for n in [1usize, 2, 4, 8] {
            let mut s = SlotSchedule::new(n, 8);
            let tasks: Vec<SlotTask> = (0..n * 8 * 4).map(|i| task(i, 2.0)).collect();
            s.run(&tasks);
            spans.push(s.makespan());
        }
        assert!(spans.iter().all(|&m| m == spans[0]), "{spans:?}");
    }

    #[test]
    fn locality_preferred_when_cheap() {
        let mut s = SlotSchedule::new(2, 1);
        let t = SlotTask {
            id: 0,
            duration: Duration::seconds(1.0),
            cpus: 1,
            preferred: Some(1),
            remote_penalty: Duration::seconds(10.0),
            release: VirtualTime::ZERO,
        };
        let p = s.run(&[t]);
        assert_eq!(p[0].worker, 1);
        assert!(p[0].local);
    }

    #[test]
    fn falls_off_locality_when_preferred_worker_is_busy() {
        let mut s = SlotSchedule::new(2, 1).with_locality_wait(Duration::seconds(0.5));
        // Fill worker 0 for 100s, then prefer it: should run remote on 1.
        let filler = SlotTask {
            id: 0,
            duration: Duration::seconds(100.0),
            cpus: 1,
            preferred: Some(0),
            remote_penalty: Duration::ZERO,
            release: VirtualTime::ZERO,
        };
        let wants_zero = SlotTask {
            id: 1,
            duration: Duration::seconds(1.0),
            cpus: 1,
            preferred: Some(0),
            remote_penalty: Duration::seconds(2.0),
            release: VirtualTime::ZERO,
        };
        let p = s.run(&[filler, wants_zero]);
        assert_eq!(p[1].worker, 1);
        assert!(!p[1].local);
        // remote penalty applied
        assert_eq!(p[1].end - p[1].start, Duration::seconds(3.0));
    }

    #[test]
    fn out_of_range_preference_schedules_remote_without_panicking() {
        // data ingested for a wider cluster than this one: the hint
        // names a worker that does not exist here
        let mut s = SlotSchedule::new(2, 1);
        let t = SlotTask {
            id: 0,
            duration: Duration::seconds(1.0),
            cpus: 1,
            preferred: Some(7),
            remote_penalty: Duration::seconds(0.5),
            release: VirtualTime::ZERO,
        };
        let p = s.run(&[t]);
        assert!(p[0].worker < 2);
        assert!(!p[0].local, "an unsatisfiable preference is not local");
        // the read really is remote: penalty applied
        assert_eq!(p[0].end - p[0].start, Duration::seconds(1.5));
    }

    #[test]
    fn multicpu_task_occupies_whole_worker() {
        let mut s = SlotSchedule::new(1, 8);
        let big = SlotTask {
            id: 0,
            duration: Duration::seconds(4.0),
            cpus: 8,
            preferred: None,
            remote_penalty: Duration::ZERO,
            release: VirtualTime::ZERO,
        };
        let small = task(1, 1.0);
        let p = s.run(&[big, small]);
        // small must wait for the 8-cpu task (LPT runs big first)
        assert_eq!(p[1].start, VirtualTime::seconds(4.0));
    }

    #[test]
    fn release_time_gates_start_even_on_idle_workers() {
        // an idle cluster cannot start a task before its input is sealed
        let mut s = SlotSchedule::new(2, 1);
        let gated = SlotTask { release: VirtualTime::seconds(5.0), ..task(0, 1.0) };
        let free = task(1, 1.0);
        let p = s.run(&[gated, free]);
        assert_eq!(p[0].start, VirtualTime::seconds(5.0));
        assert_eq!(p[0].end, VirtualTime::seconds(6.0));
        // the unreleased task does not block the other worker
        assert_eq!(p[1].start, VirtualTime::ZERO);
        // locality still honored relative to the release clamp
        let mut s = SlotSchedule::new(2, 1);
        let local = SlotTask {
            preferred: Some(1),
            release: VirtualTime::seconds(2.0),
            ..task(0, 1.0)
        };
        let p = s.run(&[local]);
        assert_eq!(p[0].worker, 1);
        assert!(p[0].local);
        assert_eq!(p[0].start, VirtualTime::seconds(2.0));
    }

    #[test]
    fn slowdown_scales_placed_durations() {
        let mut s = SlotSchedule::new(2, 1);
        s.set_slowdown(0, 4.0);
        let p = s.run(&[task(0, 1.0), task(1, 1.0)]);
        // earliest-start ties break toward worker 0, which then runs
        // 4x slower; the other task lands on worker 1 at full speed
        assert_eq!(p[0].worker, 0);
        assert_eq!(p[0].end - p[0].start, Duration::seconds(4.0));
        assert_eq!(p[1].worker, 1);
        assert_eq!(p[1].end - p[1].start, Duration::seconds(1.0));
        // out-of-range factors are ignored, not a panic
        s.set_slowdown(99, 2.0);
    }

    #[test]
    fn speculation_rescues_a_planted_straggler() {
        // 8 equal 1s tasks on 4 workers x 2 slots, worker 0 planted 4x
        // slow: the two tasks stuck there straggle to 4s while the
        // other six finish at 1s. With the default policy the 75%
        // watermark passes at 1s, both stragglers get copies on fast
        // workers finishing at 2s, and the losers' slots are reclaimed.
        let mut s = SlotSchedule::new(4, 2);
        s.set_slowdown(0, 4.0);
        let tasks: Vec<SlotTask> = (0..8).map(|i| task(i, 1.0)).collect();
        let (p, spec) = s.run_speculated(&tasks, &SpeculationPolicy::default());
        assert_eq!(spec.speculated(), 2);
        assert_eq!(spec.wins(), 2);
        assert_eq!(spec.cancelled(), 2);
        for d in &spec.decisions {
            assert!(d.copy_wins);
            assert_ne!(d.copy_worker, 0, "a copy must leave the slow worker");
            assert_eq!(d.copy_start, VirtualTime::seconds(1.0));
            assert_eq!(d.committed_end, VirtualTime::seconds(2.0));
        }
        assert!(p.iter().all(|pl| pl.end <= VirtualTime::seconds(2.0)), "{p:?}");
        assert_eq!(s.makespan(), VirtualTime::seconds(2.0), "losers' slots reclaimed");
    }

    #[test]
    fn speculation_is_a_no_op_without_stragglers() {
        let tasks: Vec<SlotTask> = (0..8).map(|i| task(i, 1.0)).collect();
        let mut plain = SlotSchedule::new(2, 2);
        let expect = plain.run(&tasks);
        let mut s = SlotSchedule::new(2, 2);
        let (p, spec) = s.run_speculated(&tasks, &SpeculationPolicy::default());
        assert_eq!(p, expect);
        assert_eq!(spec.speculated(), 0);
        assert_eq!(s.makespan(), plain.makespan());
    }

    #[test]
    fn a_losing_copy_is_cancelled_and_the_original_stands() {
        // 4 x 1s tasks on 2 workers x 1 slot, worker 0 4x slow: by the
        // time the watermark passes (3s) the only other slot frees at
        // 3s, so the copy would finish at 4s — no earlier than the
        // original. The copy launches, loses the race and is cancelled.
        let mut s = SlotSchedule::new(2, 1);
        s.set_slowdown(0, 4.0);
        let tasks: Vec<SlotTask> = (0..4).map(|i| task(i, 1.0)).collect();
        let (p, spec) = s.run_speculated(&tasks, &SpeculationPolicy::default());
        assert_eq!(spec.speculated(), 1);
        assert_eq!(spec.wins(), 0);
        assert_eq!(spec.cancelled(), 1);
        let d = spec.decisions[0];
        assert!(!d.copy_wins);
        assert_eq!(d.committed_end, VirtualTime::seconds(4.0));
        assert_eq!(p[0].worker, 0, "the original placement stands");
        assert_eq!(s.makespan(), VirtualTime::seconds(4.0));
    }

    /// Regression alongside `out_of_range_preference_...`: speculation
    /// interacting with `kill_worker` / `delay_worker` — a speculative
    /// copy must never be placed on a killed worker, and a delayed
    /// worker gates the copy's start like any other placement.
    #[test]
    fn speculative_copies_never_land_on_killed_workers() {
        let pol = SpeculationPolicy { quantile: 0.5, multiplier: 1.5, max_inflight: 4 };
        // 3 workers, worker 2 dead, worker 0 planted 8x slow: rescue
        // copies may only use worker 1.
        let mut s = SlotSchedule::new(3, 1);
        s.kill_worker(2);
        s.set_slowdown(0, 8.0);
        let tasks: Vec<SlotTask> = (0..4).map(|i| task(i, 1.0)).collect();
        let (p, spec) = s.run_speculated(&tasks, &pol);
        assert!(!spec.decisions.is_empty(), "the planted straggler must be raced");
        for d in &spec.decisions {
            assert_eq!(d.copy_worker, 1, "never the killed worker, never the original's");
        }
        assert!(p.iter().all(|pl| pl.worker != 2));

        // a delayed worker cannot start a copy before it is ready
        let mut s = SlotSchedule::new(2, 1);
        s.set_slowdown(0, 8.0);
        s.delay_worker(1, VirtualTime::seconds(3.0));
        let (_, spec) = s.run_speculated(&[task(0, 1.0), task(1, 1.0)], &pol);
        assert!(!spec.decisions.is_empty());
        for d in &spec.decisions {
            assert!(d.copy_start >= VirtualTime::seconds(3.0));
        }

        // with the straggler's own worker the only one, no copy can
        // launch at all — speculation degrades to a no-op
        let mut s = SlotSchedule::new(1, 4);
        let mut tasks: Vec<SlotTask> = (0..6).map(|i| task(i, 1.0)).collect();
        tasks.push(task(6, 5.0));
        let (_, spec) = s.run_speculated(&tasks, &pol);
        assert_eq!(spec.speculated(), 0, "a straggler with nowhere to copy is left alone");
    }

    #[test]
    #[should_panic(expected = "no worker has that many slots")]
    fn rejects_oversized_tasks() {
        let mut s = SlotSchedule::new(2, 4);
        let t = SlotTask {
            id: 0,
            duration: Duration::seconds(1.0),
            cpus: 16,
            preferred: None,
            remote_penalty: Duration::ZERO,
            release: VirtualTime::ZERO,
        };
        s.run(&[t]);
    }
}
