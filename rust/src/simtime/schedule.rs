//! List scheduling of weighted tasks onto vCPU slots — the core of the
//! per-stage virtual makespan computation.
//!
//! Mirrors Spark's behaviour closely enough for the paper's curves:
//! tasks are offered in descending duration (LPT), each goes to its
//! locality-preferred worker if a slot frees up there no later than
//! `locality_wait` after the best remote slot (Spark's
//! `spark.locality.wait` analogue), else to the earliest-available
//! worker. Multi-cpu tasks (`spark.task.cpus`) occupy several slots of
//! one worker simultaneously.

use super::{Duration, VirtualTime};

/// One schedulable task.
#[derive(Debug, Clone, Copy)]
pub struct SlotTask {
    /// Caller's identifier (index into the stage's task vec).
    pub id: usize,
    pub duration: Duration,
    /// vCPU slots required on a single worker.
    pub cpus: u32,
    /// Preferred worker for data locality, if any.
    pub preferred: Option<usize>,
    /// Extra duration if scheduled *off* the preferred worker
    /// (remote read of the cached partition).
    pub remote_penalty: Duration,
    /// Earliest virtual time the task may start — its input partition's
    /// availability. ZERO for batch-materialized inputs; streamed
    /// ingest sets it to the partition's seal time so map tasks overlap
    /// the tail of materialization without reading unsealed bytes.
    pub release: VirtualTime,
}

/// Where a task ended up.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TaskPlacement {
    pub id: usize,
    pub worker: usize,
    pub start: VirtualTime,
    pub end: VirtualTime,
    pub local: bool,
}

/// Slot-level schedule over a set of workers.
#[derive(Debug)]
pub struct SlotSchedule {
    /// `slots[w][s]` = virtual time at which slot `s` of worker `w` frees.
    slots: Vec<Vec<VirtualTime>>,
    locality_wait: Duration,
    killed: Vec<bool>,
}

impl SlotSchedule {
    pub fn new(workers: usize, vcpus_per_worker: u32) -> Self {
        SlotSchedule {
            slots: vec![vec![VirtualTime::ZERO; vcpus_per_worker as usize]; workers],
            locality_wait: Duration::seconds(3.0),
            killed: vec![false; workers],
        }
    }

    pub fn with_locality_wait(mut self, wait: Duration) -> Self {
        self.locality_wait = wait;
        self
    }

    pub fn workers(&self) -> usize {
        self.slots.len()
    }

    /// Push a worker's earliest availability to at least `ready` (image
    /// pull, container-runtime warmup — anything that blocks the whole
    /// worker before its first task of the stage).
    pub fn delay_worker(&mut self, worker: usize, ready: VirtualTime) {
        for s in &mut self.slots[worker] {
            *s = (*s).max(ready);
        }
    }

    /// Remove a worker from further placement (simulated worker loss).
    /// Existing placements stand; makespan ignores the dead worker.
    pub fn kill_worker(&mut self, worker: usize) {
        self.killed[worker] = true;
    }

    /// Earliest time `cpus` slots are simultaneously free on `worker`.
    ///
    /// Slot vectors are kept sorted (see [`Self::reserve`]), so this is
    /// a direct index — the scheduler runs once per task per stage and
    /// was the top L3 hot spot before (clone + sort per probe,
    /// EXPERIMENTS.md §Perf).
    fn earliest_on(&self, worker: usize, cpus: u32) -> VirtualTime {
        let frees = &self.slots[worker];
        let need = (cpus as usize).min(frees.len());
        debug_assert!(frees.windows(2).all(|w| w[0] <= w[1]));
        frees[need - 1]
    }

    /// Reserve `cpus` slots on `worker` until `end`, keeping the slot
    /// vector sorted: the `cpus` earliest slots become `end`, which is
    /// ≥ every untouched earlier slot, so rotating them into place is a
    /// single in-place merge step.
    fn reserve(&mut self, worker: usize, cpus: u32, end: VirtualTime) {
        let slots = &mut self.slots[worker];
        let take = (cpus as usize).min(slots.len());
        // overwrite the `take` smallest (prefix, since sorted) ...
        for s in slots.iter_mut().take(take) {
            *s = end;
        }
        // ... and restore order: the prefix is now uniform `end`;
        // rotate it past every remaining element smaller than `end`
        let rest = &slots[take..];
        let shift = rest.partition_point(|&s| s < end);
        slots[..take + shift].rotate_left(take);
    }

    /// Schedule all tasks; returns placements (same order as input ids).
    pub fn run(&mut self, tasks: &[SlotTask]) -> Vec<TaskPlacement> {
        // LPT order: longest tasks first minimizes makespan skew.
        let mut order: Vec<usize> = (0..tasks.len()).collect();
        order.sort_by_key(|&i| std::cmp::Reverse(tasks[i].duration));

        let mut placements = Vec::with_capacity(tasks.len());
        for &i in &order {
            let t = tasks[i];
            let cpus = t.cpus.max(1);

            // Earliest option anywhere (live workers only).
            let (mut best_w, mut best_start) = (0usize, VirtualTime(u64::MAX));
            for w in 0..self.slots.len() {
                if self.killed[w] || (cpus as usize) > self.slots[w].len() {
                    continue;
                }
                let s = self.earliest_on(w, cpus).max(t.release);
                if s < best_start {
                    best_start = s;
                    best_w = w;
                }
            }
            assert!(
                best_start != VirtualTime(u64::MAX),
                "task wants {cpus} cpus but no worker has that many slots"
            );

            // Locality preference within the wait window. A preference
            // outside this cluster's worker range (data ingested for a
            // wider layout) is unsatisfiable here: the task schedules
            // anywhere, non-local, with the remote penalty — it must
            // never index past the worker tables.
            let (worker, start, local) = match t.preferred {
                Some(p)
                    if p < self.slots.len()
                        && !self.killed[p]
                        && (cpus as usize) <= self.slots[p].len() =>
                {
                    let ps = self.earliest_on(p, cpus).max(t.release);
                    if ps.0 <= best_start.0 + self.locality_wait.0 {
                        (p, ps, true)
                    } else {
                        (best_w, best_start, false)
                    }
                }
                _ => (best_w, best_start, t.preferred.is_none()),
            };

            let dur = if local {
                t.duration
            } else {
                t.duration + t.remote_penalty
            };
            let end = start + dur;
            self.reserve(worker, cpus, end);
            placements.push(TaskPlacement { id: t.id, worker, start, end, local });
        }
        placements.sort_by_key(|p| p.id);
        placements
    }

    /// Makespan so far (max slot free time over live workers).
    pub fn makespan(&self) -> VirtualTime {
        self.slots
            .iter()
            .zip(&self.killed)
            .filter(|(_, &k)| !k)
            .flat_map(|(w, _)| w.iter())
            .copied()
            .max()
            .unwrap_or(VirtualTime::ZERO)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(id: usize, secs: f64) -> SlotTask {
        SlotTask {
            id,
            duration: Duration::seconds(secs),
            cpus: 1,
            preferred: None,
            remote_penalty: Duration::ZERO,
            release: VirtualTime::ZERO,
        }
    }

    #[test]
    fn perfect_packing_on_equal_tasks() {
        // 16 x 1s tasks on 2 workers x 4 slots => 2 waves => 2s.
        let mut s = SlotSchedule::new(2, 4);
        let tasks: Vec<SlotTask> = (0..16).map(|i| task(i, 1.0)).collect();
        s.run(&tasks);
        assert_eq!(s.makespan(), VirtualTime::seconds(2.0));
    }

    #[test]
    fn weak_scaling_is_flat_for_embarrassingly_parallel() {
        // N workers, N*8 equal tasks: makespan independent of N.
        let mut spans = vec![];
        for n in [1usize, 2, 4, 8] {
            let mut s = SlotSchedule::new(n, 8);
            let tasks: Vec<SlotTask> = (0..n * 8 * 4).map(|i| task(i, 2.0)).collect();
            s.run(&tasks);
            spans.push(s.makespan());
        }
        assert!(spans.iter().all(|&m| m == spans[0]), "{spans:?}");
    }

    #[test]
    fn locality_preferred_when_cheap() {
        let mut s = SlotSchedule::new(2, 1);
        let t = SlotTask {
            id: 0,
            duration: Duration::seconds(1.0),
            cpus: 1,
            preferred: Some(1),
            remote_penalty: Duration::seconds(10.0),
            release: VirtualTime::ZERO,
        };
        let p = s.run(&[t]);
        assert_eq!(p[0].worker, 1);
        assert!(p[0].local);
    }

    #[test]
    fn falls_off_locality_when_preferred_worker_is_busy() {
        let mut s = SlotSchedule::new(2, 1).with_locality_wait(Duration::seconds(0.5));
        // Fill worker 0 for 100s, then prefer it: should run remote on 1.
        let filler = SlotTask {
            id: 0,
            duration: Duration::seconds(100.0),
            cpus: 1,
            preferred: Some(0),
            remote_penalty: Duration::ZERO,
            release: VirtualTime::ZERO,
        };
        let wants_zero = SlotTask {
            id: 1,
            duration: Duration::seconds(1.0),
            cpus: 1,
            preferred: Some(0),
            remote_penalty: Duration::seconds(2.0),
            release: VirtualTime::ZERO,
        };
        let p = s.run(&[filler, wants_zero]);
        assert_eq!(p[1].worker, 1);
        assert!(!p[1].local);
        // remote penalty applied
        assert_eq!(p[1].end - p[1].start, Duration::seconds(3.0));
    }

    #[test]
    fn out_of_range_preference_schedules_remote_without_panicking() {
        // data ingested for a wider cluster than this one: the hint
        // names a worker that does not exist here
        let mut s = SlotSchedule::new(2, 1);
        let t = SlotTask {
            id: 0,
            duration: Duration::seconds(1.0),
            cpus: 1,
            preferred: Some(7),
            remote_penalty: Duration::seconds(0.5),
            release: VirtualTime::ZERO,
        };
        let p = s.run(&[t]);
        assert!(p[0].worker < 2);
        assert!(!p[0].local, "an unsatisfiable preference is not local");
        // the read really is remote: penalty applied
        assert_eq!(p[0].end - p[0].start, Duration::seconds(1.5));
    }

    #[test]
    fn multicpu_task_occupies_whole_worker() {
        let mut s = SlotSchedule::new(1, 8);
        let big = SlotTask {
            id: 0,
            duration: Duration::seconds(4.0),
            cpus: 8,
            preferred: None,
            remote_penalty: Duration::ZERO,
            release: VirtualTime::ZERO,
        };
        let small = task(1, 1.0);
        let p = s.run(&[big, small]);
        // small must wait for the 8-cpu task (LPT runs big first)
        assert_eq!(p[1].start, VirtualTime::seconds(4.0));
    }

    #[test]
    fn release_time_gates_start_even_on_idle_workers() {
        // an idle cluster cannot start a task before its input is sealed
        let mut s = SlotSchedule::new(2, 1);
        let gated = SlotTask { release: VirtualTime::seconds(5.0), ..task(0, 1.0) };
        let free = task(1, 1.0);
        let p = s.run(&[gated, free]);
        assert_eq!(p[0].start, VirtualTime::seconds(5.0));
        assert_eq!(p[0].end, VirtualTime::seconds(6.0));
        // the unreleased task does not block the other worker
        assert_eq!(p[1].start, VirtualTime::ZERO);
        // locality still honored relative to the release clamp
        let mut s = SlotSchedule::new(2, 1);
        let local = SlotTask {
            preferred: Some(1),
            release: VirtualTime::seconds(2.0),
            ..task(0, 1.0)
        };
        let p = s.run(&[local]);
        assert_eq!(p[0].worker, 1);
        assert!(p[0].local);
        assert_eq!(p[0].start, VirtualTime::seconds(2.0));
    }

    #[test]
    #[should_panic(expected = "no worker has that many slots")]
    fn rejects_oversized_tasks() {
        let mut s = SlotSchedule::new(2, 4);
        let t = SlotTask {
            id: 0,
            duration: Duration::seconds(1.0),
            cpus: 16,
            preferred: None,
            remote_penalty: Duration::ZERO,
            release: VirtualTime::ZERO,
        };
        s.run(&[t]);
    }
}
