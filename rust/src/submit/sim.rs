//! Multi-driver simulation: N drivers, one queue, identical execution.
//!
//! The scale-out story of the wire format: a [`Driver`] is an
//! independent execution context (its own cluster, its own container
//! engine and launch counter). [`drain`] has a fleet of drivers pull
//! jobs from one shared [`JobQueue`]; [`crosscheck`] runs the *same*
//! encoded plan on every driver so callers can assert the
//! `Job::explain()` physical plans are byte-identical and the container
//! launch counters equal — the determinism contract a submitted plan
//! relies on (docs/WIRE_FORMAT.md §7).

use std::sync::Arc;

use crate::cluster::{Cluster, ClusterConfig};
use crate::error::{MareError, Result};
use crate::mare::{wire, MaRe};
use crate::util::json::Json;

use super::queue::{JobQueue, JobRecord, JobResult, JobStatus};
use super::{ingest_of, SourceSpec};

/// One simulated driver: a name plus its own cluster (and therefore
/// its own engine and container-launch counter).
pub struct Driver {
    pub name: String,
    config: ClusterConfig,
    cluster: Arc<Cluster>,
}

/// What executing a plan on one driver produced.
#[derive(Debug, Clone)]
pub struct Executed {
    /// `Job::explain()` — logical → optimized → physical plans.
    pub explain: String,
    /// Simulated container launches this job performed on this driver.
    pub launches: u64,
    /// Records in the collected output.
    pub records: u64,
    /// Tasks that ran on their locality-preferred worker, summed over
    /// stages (`StageReport::local_tasks`) — how HDFS- vs object-store-
    /// backed runs compare in the Figure 3 direction.
    pub local_tasks: u64,
}

impl Driver {
    pub fn new(name: impl Into<String>, config: ClusterConfig) -> Driver {
        let cluster = Self::assemble(&config, None);
        Driver { name: name.into(), config, cluster }
    }

    /// Same cluster-assembly path as `mare run` (workloads::make_cluster),
    /// with the artifact runtime when it loads (fred/gatk plans) and a
    /// runtime-less fallback otherwise (POSIX plans still execute).
    fn assemble(
        config: &ClusterConfig,
        reference: Option<&crate::formats::fasta::Reference>,
    ) -> Arc<Cluster> {
        let dir = crate::workloads::artifact_dir();
        crate::workloads::make_cluster(config.clone(), Some(&dir), reference)
            .or_else(|_| crate::workloads::make_cluster(config.clone(), None, reference))
            .expect("a cluster without a runtime always constructs")
    }

    pub fn cluster(&self) -> &Arc<Cluster> {
        &self.cluster
    }

    /// Decode and rebuild an encoded plan into a runnable job on THIS
    /// driver: materialize the source from the ingest label, re-run
    /// validation, the optimizer and the lowering.
    fn prepare(&self, envelope: &Json) -> Result<crate::mare::Job> {
        let pipeline = wire::decode(envelope)?;
        let (label, partitions) = ingest_of(&pipeline)?;
        let spec = SourceSpec::parse(&label);
        let (source, reference) =
            spec.materialize_with_reference(partitions, self.config.workers)?;
        // sources that imply a reference genome (gen:snp:) need it
        // baked into the registry's alignment image, so those jobs run
        // on a per-job cluster; everything else shares the driver's
        let cluster = match reference {
            Some(reference) => Self::assemble(&self.config, Some(&reference)),
            None => self.cluster.clone(),
        };
        MaRe::source(cluster, source).append_pipeline(&pipeline).build()
    }

    fn executed(job: &crate::mare::Job, out: &crate::cluster::RunOutput) -> Executed {
        let records = out.partitions.iter().map(|p| p.records.len() as u64).sum();
        let local_tasks = out.report.stages.iter().map(|s| s.local_tasks as u64).sum();
        Executed {
            explain: job.explain(),
            launches: job.container_launches(),
            records,
            local_tasks,
        }
    }

    /// Decode, rebuild and execute an encoded plan on THIS driver.
    pub fn execute(&self, envelope: &Json) -> Result<Executed> {
        let job = self.prepare(envelope)?;
        let out = job.run()?;
        Ok(Self::executed(&job, &out))
    }

    /// [`Self::execute`] through a stage checkpointer: completed stage
    /// boundaries persist as the run progresses, and a previous
    /// attempt's durable state seeds this run past the stages it
    /// already finished. A [`MareError::KilledMidRun`] abort is
    /// re-raised carrying the job's REAL launch counter — the partial
    /// work is real and a successor must not be billed for it twice.
    pub fn execute_checkpointed(
        &self,
        envelope: &Json,
        ckpt: &dyn crate::cluster::StageCheckpointer,
    ) -> Result<Executed> {
        let job = self.prepare(envelope)?;
        match job.run_checkpointed(ckpt) {
            Ok(out) => Ok(Self::executed(&job, &out)),
            Err(MareError::KilledMidRun { stages_done, .. }) => Err(MareError::KilledMidRun {
                stages_done,
                launches: job.container_launches(),
            }),
            Err(e) => Err(e),
        }
    }
}

/// Drain the shared queue: drivers claim jobs FIFO, round-robin, and
/// record outcomes (`done` with launch counts, or `failed` with the
/// error). Returns the finished records in execution order.
pub fn drain(queue: &JobQueue, drivers: &[Driver]) -> Result<Vec<JobRecord>> {
    if drivers.is_empty() {
        return Err(MareError::Submit("drain needs at least one driver".into()));
    }
    let mut finished = Vec::new();
    let mut turn = 0usize;
    while let Some(job) = queue.claim()? {
        let driver = &drivers[turn % drivers.len()];
        turn += 1;
        let (status, result) = match driver.execute(&job.plan) {
            Ok(ex) => (
                JobStatus::Done,
                JobResult {
                    driver: driver.name.clone(),
                    launches: ex.launches,
                    records: ex.records,
                    detail: "ok".into(),
                },
            ),
            Err(e) => (
                JobStatus::Failed,
                JobResult {
                    driver: driver.name.clone(),
                    launches: 0,
                    records: 0,
                    detail: e.to_string(),
                },
            ),
        };
        finished.push(queue.finish(job, status, result)?);
    }
    Ok(finished)
}

/// Run the SAME encoded plan on every driver. Callers assert the
/// returned executions agree — identical `explain`, equal `launches` —
/// which is exactly the acceptance check for plan portability.
pub fn crosscheck(envelope: &Json, drivers: &[Driver]) -> Result<Vec<Executed>> {
    drivers.iter().map(|d| d.execute(envelope)).collect()
}

/// [`crosscheck`], but every driver executes on its own OS thread,
/// concurrently. Results come back in driver order, so the assertions
/// are the same — byte-identical `Job::explain()`, equal launch
/// counts — with the added claim that the determinism contract holds
/// no matter WHICH thread ran the job (shared state in the engine,
/// registry or artifact runtime that is merely single-thread-
/// deterministic would surface here).
pub fn crosscheck_threaded(envelope: &Json, drivers: &[Driver]) -> Result<Vec<Executed>> {
    std::thread::scope(|scope| {
        let handles: Vec<_> =
            drivers.iter().map(|d| scope.spawn(move || d.execute(envelope))).collect();
        handles
            .into_iter()
            .map(|h| {
                h.join().unwrap_or_else(|_| {
                    Err(MareError::Submit("crosscheck thread panicked".into()))
                })
            })
            .collect()
    })
}

/// The determinism contract extended to CRASH RECOVERY. Driver
/// `drivers[0]` runs the plan through a checkpointer that is killed
/// after `after_stages` committed stage boundaries; `drivers[1]` (the
/// "successor" claiming the dead driver's job) resumes from the durable
/// state; `drivers[0]` also runs the plan uninterrupted on a fresh job.
/// Returns `(partial_launches, resumed, uninterrupted)`.
///
/// Callers assert the recovery contract:
/// * `resumed.explain == uninterrupted.explain` (byte-identical plans)
/// * `resumed.records == uninterrupted.records` (identical output)
/// * `resumed.launches < uninterrupted.launches` (checkpointed stages
///   were NOT re-run)
/// * `partial_launches + resumed.launches == uninterrupted.launches`
///   (stage-level exactly-once: every launch happened on exactly one
///   attempt)
pub fn crosscheck_resumed(
    envelope: &Json,
    drivers: &[Driver],
    after_stages: usize,
) -> Result<(u64, Executed, Executed)> {
    if drivers.len() < 2 {
        return Err(MareError::Submit("crosscheck_resumed needs two drivers".into()));
    }
    let store = crate::storage::MemCheckpoint::new();
    let killer = crate::storage::KillAfter::new(&store, after_stages);
    let partial = match drivers[0].execute_checkpointed(envelope, &killer) {
        Err(MareError::KilledMidRun { launches, .. }) => launches,
        Ok(_) => {
            return Err(MareError::Submit(format!(
                "kill after {after_stages} stages never fired — the plan has too few stages \
                 for a mid-run death"
            )))
        }
        Err(e) => return Err(e),
    };
    let resumed = drivers[1].execute_checkpointed(envelope, &store)?;
    let uninterrupted = drivers[0].execute(envelope)?;
    Ok((partial, resumed, uninterrupted))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_drivers() -> Vec<Driver> {
        vec![
            Driver::new("driver-0", ClusterConfig::sized(2, 2)),
            Driver::new("driver-1", ClusterConfig::sized(2, 2)),
        ]
    }

    /// Build the GC job with the fluent builder on a "home" driver and
    /// encode it — the plan artifact the other drivers receive.
    fn gc_plan_built_on_driver_a() -> (String, String) {
        let home = Driver::new("driver-a", ClusterConfig::sized(2, 2));
        let source = SourceSpec::parse("gen:gc:64").materialize(4, 2).unwrap();
        let job = MaRe::source(home.cluster().clone(), source)
            .map("ubuntu", "grep -o '[GC]' /dna | wc -l > /count")
            .mounts("/dna", "/count")
            .reduce("ubuntu", "awk '{s+=$1} END {print s}' /counts > /sum")
            .mounts("/counts", "/sum")
            .depth(2)
            .build()
            .unwrap();
        (wire::encode_string(job.logical()).unwrap(), job.explain())
    }

    #[test]
    fn a_plan_built_on_one_driver_executes_identically_on_others() {
        let (text, home_explain) = gc_plan_built_on_driver_a();
        let envelope = Json::parse(&text).unwrap();
        let drivers = two_drivers();
        let runs = crosscheck(&envelope, &drivers).unwrap();
        assert_eq!(runs.len(), 2);
        // byte-identical physical plans across drivers — and identical
        // to the plan the home driver built directly from the builder
        assert_eq!(runs[0].explain, runs[1].explain);
        assert_eq!(runs[0].explain, home_explain);
        // equal container-launch counters
        assert_eq!(runs[0].launches, runs[1].launches);
        assert!(runs[0].launches > 0, "the job must actually run containers");
        assert_eq!(runs[0].records, runs[1].records);

        // the threaded variant upholds the same contract concurrently:
        // byte-identical explains and launch counts, whichever thread
        // ran the job
        let threaded = crosscheck_threaded(&envelope, &drivers).unwrap();
        assert_eq!(threaded.len(), 2);
        for run in &threaded {
            assert_eq!(run.explain, home_explain);
            assert_eq!(run.launches, runs[0].launches);
        }
    }

    #[test]
    fn a_resumed_run_matches_an_uninterrupted_one() {
        let (text, home_explain) = gc_plan_built_on_driver_a();
        let envelope = Json::parse(&text).unwrap();
        let drivers = two_drivers();
        let (partial, resumed, full) = crosscheck_resumed(&envelope, &drivers, 1).unwrap();
        // the successor produced the SAME job as an uninterrupted run
        assert_eq!(resumed.explain, full.explain);
        assert_eq!(resumed.explain, home_explain);
        assert_eq!(resumed.records, full.records);
        // ...but skipped the checkpointed stage's containers
        assert!(partial > 0, "the killed attempt did real work");
        assert!(
            resumed.launches < full.launches,
            "resume must not re-run committed stages: {} vs {}",
            resumed.launches,
            full.launches
        );
        // stage-level exactly-once: every launch on exactly one attempt
        assert_eq!(partial + resumed.launches, full.launches);

        assert!(crosscheck_resumed(&envelope, &drivers[..1], 1).is_err());
        // more boundaries than the plan has stages: the kill never
        // fires and the harness reports it instead of "passing"
        assert!(crosscheck_resumed(&envelope, &drivers, 99).is_err());
    }

    #[test]
    fn drivers_drain_a_shared_queue_round_robin() {
        let dir = std::env::temp_dir()
            .join(format!("mare-sim-test-{}-drain", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let queue = JobQueue::open(dir).unwrap();

        let (text, _) = gc_plan_built_on_driver_a();
        let submitter = super::super::Submitter::new(ClusterConfig::sized(2, 2));
        for _ in 0..3 {
            submitter.submit(&queue, &text).unwrap();
        }
        // one plan with an unresolvable source fails cleanly
        let opaque = text.replace("gen:gc:64", "ftp://genome.txt");
        submitter.submit(&queue, &opaque).unwrap();

        let drivers = two_drivers();
        let finished = drain(&queue, &drivers).unwrap();
        assert_eq!(finished.len(), 4);

        let ok: Vec<&JobRecord> =
            finished.iter().filter(|j| j.status == JobStatus::Done).collect();
        assert_eq!(ok.len(), 3);
        // the same plan produced the same launch count on BOTH drivers
        let launches: Vec<u64> = ok.iter().map(|j| j.result.as_ref().unwrap().launches).collect();
        assert!(launches.windows(2).all(|w| w[0] == w[1]), "{launches:?}");
        let names: std::collections::HashSet<String> =
            ok.iter().map(|j| j.result.as_ref().unwrap().driver.clone()).collect();
        assert_eq!(names.len(), 2, "both drivers took work: {names:?}");

        let failed: Vec<&JobRecord> =
            finished.iter().filter(|j| j.status == JobStatus::Failed).collect();
        assert_eq!(failed.len(), 1);
        assert!(
            failed[0].result.as_ref().unwrap().detail.contains("not resolvable"),
            "{}",
            failed[0].result.as_ref().unwrap().detail
        );

        // queue is drained
        assert!(queue.claim().unwrap().is_none());
        assert!(drain(&queue, &[]).is_err());
    }
}
