//! Job submission: encoded plans as first-class, shippable jobs.
//!
//! PR 1 made the logical plan an engine-agnostic value; [`crate::mare::wire`]
//! made it a portable artifact. This module is the production-scale step
//! the ROADMAP called for on top of those two: a [`JobQueue`] (file-backed
//! spool shared by `mare submit` / `mare jobs` / `mare work`), a
//! [`Submitter`] doing admission control (decode → dry-run `build()` →
//! canonicalize → enqueue), and a multi-driver simulation ([`sim`])
//! demonstrating that a plan built on one driver executes *identically*
//! on any other — byte-identical `Job::explain()` physical plans and
//! equal container-launch counters. The [`pool`] module turns that
//! simulation into a real concurrency exercise: a threaded
//! [`WorkerPool`] whose workers contend for the spool's rename-locked
//! claims, with fault injection for the crash-recovery paths
//! (stale-hold sweep, `mare requeue`).
//!
//! Sources travel by *label*: the plan's `ingest` node carries a label
//! that every driver resolves with [`SourceSpec`] (`gen:gc:<lines>`,
//! `gen:vs:<molecules>`, `gen:snp:<chromosome_bp>`, `inline:<text>`),
//! regenerating identical records from a pinned seed. Storage URIs
//! (`hdfs://genome.txt`, `swift://…`, `s3://…`, `local://…`) resolve
//! through the [`crate::storage::StorageCatalog`], whose seeded object
//! population is equally pinned — so storage-backed plans execute
//! end-to-end with per-partition locality hints. Labels outside both
//! grammars still validate and enqueue, but only drivers that can
//! reach the named source may execute them.
//!
//! ```
//! use mare::cluster::ClusterConfig;
//! use mare::submit::{sim::Driver, SourceSpec, Submitter};
//!
//! let plan = r#"{
//!   "version": 1,
//!   "ops": [
//!     {"op": "ingest", "label": "gen:gc:16", "partitions": 2},
//!     {"op": "map", "image": "ubuntu",
//!      "command": "grep -o '[GC]' /dna | wc -l > /count",
//!      "input": {"kind": "text", "path": "/dna"},
//!      "output": {"kind": "text", "path": "/count"}},
//!     {"op": "collect"}
//!   ]
//! }"#;
//! // admission control: decode + dry-run build, nothing executes
//! let submitter = Submitter::new(ClusterConfig::sized(2, 2));
//! let validated = submitter.validate(plan).unwrap();
//! assert!(validated.executable);
//!
//! // any driver rebuilds and runs the same job
//! let driver = Driver::new("driver-0", ClusterConfig::sized(2, 2));
//! let run = driver.execute(&validated.envelope).unwrap();
//! assert!(run.launches > 0);
//! assert!(SourceSpec::parse("gen:gc:16").is_executable());
//! ```

pub mod pool;
pub mod queue;
pub mod sim;

pub use pool::{
    Death, DeathMode, FaultPlan, PoolConfig, PoolOutcome, PoolReport, ServeHooks, WorkerPool,
};
pub use queue::{
    filter_tenant, fmt_age, now_millis, render_dlq_table, render_jobs_table, ClaimOrder,
    ClaimStats, JobFailure, JobQueue, JobRecord, JobResult, JobStatus, STALE_CLAIM,
};
pub use sim::{crosscheck, crosscheck_resumed, crosscheck_threaded, drain, Driver, Executed};

use std::sync::Arc;

use crate::cluster::{Cluster, ClusterConfig};
use crate::dataset::Dataset;
use crate::error::{MareError, Result};
use crate::mare::{wire, MaRe, Pipeline, PipelineOp};
use crate::storage::{IngestReport, StorageCatalog, StorageUri};
use crate::util::json::Json;

/// Seed for regenerated `gen:` sources — pinned so every driver
/// materializes byte-identical records (same default as the CLI).
pub const GEN_SEED: u64 = 42;

/// Default job spool directory, shared by the CLI
/// (`mare submit`/`jobs`/`work`/`requeue`) and the REPL
/// (`:submit`/`:work`).
pub const DEFAULT_QUEUE_DIR: &str = ".mare/queue";

/// How a submitted plan's `ingest` label materializes into records on
/// the executing driver.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SourceSpec {
    /// `gen:gc:<lines>` — synthetic genome ([`crate::workloads::gc`]).
    GenGc { lines: usize },
    /// `gen:vs:<molecules>` — synthetic SDF library.
    GenVs { molecules: usize },
    /// `gen:snp:<chromosome_bp>` — synthetic FASTQ reads over the
    /// standard 8-chromosome simulated individual
    /// ([`crate::workloads::genreads`]).
    GenSnp { chromosome_bp: usize },
    /// `inline:<text>` — the records travel in the label itself.
    Inline { text: String },
    /// `hdfs://…` / `swift://…` / `s3://…` / `local://…` — resolved
    /// through the executing driver's [`StorageCatalog`], whose seeded
    /// deterministic object population makes every driver see the same
    /// store (see [`crate::storage::catalog`]).
    Storage { uri: StorageUri },
    /// Anything else (e.g. `ftp://x`): validate-only here.
    Opaque { label: String },
}

impl SourceSpec {
    /// Parse an `ingest` label. Never fails — unresolvable labels
    /// become [`SourceSpec::Opaque`].
    pub fn parse(label: &str) -> SourceSpec {
        if let Some(rest) = label.strip_prefix("gen:gc:") {
            if let Ok(lines) = rest.parse::<usize>() {
                return SourceSpec::GenGc { lines };
            }
        }
        if let Some(rest) = label.strip_prefix("gen:vs:") {
            if let Ok(molecules) = rest.parse::<usize>() {
                return SourceSpec::GenVs { molecules };
            }
        }
        if let Some(rest) = label.strip_prefix("gen:snp:") {
            if let Ok(chromosome_bp) = rest.parse::<usize>() {
                return SourceSpec::GenSnp { chromosome_bp };
            }
        }
        if let Some(text) = label.strip_prefix("inline:") {
            return SourceSpec::Inline { text: text.to_string() };
        }
        if let Some(uri) = StorageUri::parse(label) {
            return SourceSpec::Storage { uri };
        }
        SourceSpec::Opaque { label: label.to_string() }
    }

    /// Whether [`Self::materialize`] can succeed on any driver.
    pub fn is_executable(&self) -> bool {
        !matches!(self, SourceSpec::Opaque { .. })
    }

    /// The canonical label this spec round-trips through.
    pub fn label(&self) -> String {
        match self {
            SourceSpec::GenGc { lines } => format!("gen:gc:{lines}"),
            SourceSpec::GenVs { molecules } => format!("gen:vs:{molecules}"),
            SourceSpec::GenSnp { chromosome_bp } => format!("gen:snp:{chromosome_bp}"),
            SourceSpec::Inline { text } => format!("inline:{text}"),
            SourceSpec::Storage { uri } => uri.label(),
            SourceSpec::Opaque { label } => label.clone(),
        }
    }

    /// Materialize the dataset AND the reference genome the source
    /// implies (if any) from ONE generation pass — `gen:snp:` derives
    /// both from a single simulated individual instead of running the
    /// read simulation twice. `workers` is the executing cluster's
    /// width (storage sources lay blocks out over it for locality).
    pub fn materialize_with_reference(
        &self,
        partitions: usize,
        workers: usize,
    ) -> Result<(Dataset, Option<crate::formats::fasta::Reference>)> {
        match self {
            SourceSpec::GenSnp { .. } => {
                let (fastq, individual) =
                    crate::workloads::genreads::reads_fastq(&self.snp_sim());
                Ok((
                    Self::fastq_dataset(&fastq, partitions, self.label())?,
                    Some(individual.reference),
                ))
            }
            _ => Ok((self.materialize(partitions, workers)?, None)),
        }
    }

    /// Deterministically regenerate the source dataset ([`GEN_SEED`] is
    /// pinned, so every driver sees identical partitions; storage URIs
    /// resolve through the equally-pinned [`StorageCatalog`], carrying
    /// per-partition locality hints for block-colocated backends).
    pub fn materialize(&self, partitions: usize, workers: usize) -> Result<Dataset> {
        match self {
            SourceSpec::GenGc { lines } => Ok(Dataset::parallelize_text_labeled(
                &crate::workloads::gc::genome_text(GEN_SEED, *lines, 80),
                "\n",
                partitions,
                self.label(),
            )),
            SourceSpec::GenVs { molecules } => Ok(Dataset::parallelize_text_labeled(
                &crate::workloads::genlib::library_sdf(GEN_SEED, *molecules),
                crate::workloads::vs::SDF_SEP,
                partitions,
                self.label(),
            )),
            SourceSpec::GenSnp { .. } => {
                let (fastq, _) = crate::workloads::genreads::reads_fastq(&self.snp_sim());
                Self::fastq_dataset(&fastq, partitions, self.label())
            }
            SourceSpec::Inline { text } => {
                Ok(Dataset::parallelize_text_labeled(text, "\n", partitions, self.label()))
            }
            SourceSpec::Storage { uri } => {
                let (ds, _report) =
                    StorageCatalog::simulated(workers).resolve(uri, partitions)?;
                Ok(ds)
            }
            SourceSpec::Opaque { label } => Err(MareError::Submit(format!(
                "source `{label}` is not resolvable on this driver (executable labels: \
                 gen:gc:<lines>, gen:vs:<molecules>, gen:snp:<chromosome_bp>, \
                 inline:<text>, and storage URIs over {})",
                StorageCatalog::schemes().join("/")
            ))),
        }
    }

    /// [`Self::materialize`] for storage sources, also returning the
    /// [`IngestReport`] the catalog's ingestion measured (locality
    /// split, per-partition byte sizes). Non-storage sources report
    /// `None` — they never cross a storage pipe.
    pub fn materialize_with_ingest(
        &self,
        partitions: usize,
        workers: usize,
    ) -> Result<(Dataset, Option<IngestReport>)> {
        match self {
            SourceSpec::Storage { uri } => {
                let (ds, report) =
                    StorageCatalog::simulated(workers).resolve(uri, partitions)?;
                Ok((ds, Some(report)))
            }
            _ => Ok((self.materialize(partitions, workers)?, None)),
        }
    }

    /// [`Self::materialize_with_ingest`], streaming: storage sources
    /// resolve through [`StorageCatalog::resolve_streamed`], sealing
    /// each partition as its byte range lands so the cluster can
    /// release stage-0 tasks against sealed partitions
    /// ([`crate::cluster::Cluster::run_streamed`]) while the rest of
    /// the object is still in flight. Non-storage sources cross no
    /// storage pipe — there is nothing to overlap — so they fall back
    /// to batch materialization with no seals.
    pub fn materialize_streamed(
        &self,
        partitions: usize,
        workers: usize,
        on_seal: impl FnMut(&crate::storage::SealedPartition),
    ) -> Result<(Dataset, Option<IngestReport>)> {
        match self {
            SourceSpec::Storage { uri } => {
                let (ds, report) = StorageCatalog::simulated(workers)
                    .resolve_streamed(uri, partitions, on_seal)?;
                Ok((ds, Some(report)))
            }
            _ => Ok((self.materialize(partitions, workers)?, None)),
        }
    }

    /// A placeholder dataset with the declared partition count — enough
    /// for a dry-run `build()` (validation + optimizer), never executed.
    /// The partitions are empty (zero bytes), so the optimizer's
    /// observed-size planning sees no observation and falls back to
    /// nominal record sizes instead of mistaking placeholder bytes for
    /// a measurement.
    pub fn stub(&self, partitions: usize) -> Dataset {
        Dataset::parallelize_labeled(Vec::new(), partitions, self.label())
    }

    /// The reference genome the executing cluster must bake into its
    /// alignment image, for sources that imply one (`gen:snp:`). The
    /// reference regenerates from the same pinned seed as the reads,
    /// so every driver aligns against identical bytes.
    pub fn reference(&self) -> Option<crate::formats::fasta::Reference> {
        match self {
            SourceSpec::GenSnp { .. } => {
                let (_, individual) = crate::workloads::genreads::reads_fastq(&self.snp_sim());
                Some(individual.reference)
            }
            _ => None,
        }
    }

    /// Records are whole 4-line reads, like the driver's FASTQ-aware
    /// ingestion (line-splitting would break them).
    fn fastq_dataset(fastq: &str, partitions: usize, label: String) -> Result<Dataset> {
        let reads = crate::formats::fastq::parse_many(&fastq.into())?;
        let records: Vec<crate::dataset::Record> = reads
            .iter()
            .map(|r| crate::dataset::Record::text(r.to_fastq().trim_end().to_string()))
            .collect();
        Ok(Dataset::parallelize_labeled(records, partitions, label))
    }

    /// The one simulation config both the reads and the reference of a
    /// `gen:snp:` source derive from.
    fn snp_sim(&self) -> crate::workloads::genreads::ReadSimConfig {
        let chromosome_bp = match self {
            SourceSpec::GenSnp { chromosome_bp } => *chromosome_bp,
            _ => unreachable!("snp_sim is only called for GenSnp sources"),
        };
        crate::workloads::genreads::ReadSimConfig {
            seed: GEN_SEED,
            chromosomes: 8,
            chromosome_len: chromosome_bp.max(500),
            ..Default::default()
        }
    }
}

/// The plan's `ingest` node — first op, guaranteed by the wire codec's
/// structure rules.
pub fn ingest_of(pipeline: &Pipeline) -> Result<(String, usize)> {
    match pipeline.ops().first() {
        Some(PipelineOp::Ingest { label, partitions }) => Ok((label.clone(), *partitions)),
        _ => Err(MareError::Submit("plan has no ingest node".into())),
    }
}

/// A decoded, validated, canonicalized plan — what admission control
/// hands to the queue.
pub struct ValidatedPlan {
    /// The decoded logical plan.
    pub pipeline: Pipeline,
    /// Canonical v1 re-encoding (what gets enqueued; unknown envelope
    /// keys from the submission are dropped here — except the
    /// documented scheduling fields, preserved via `meta`).
    pub envelope: Json,
    /// The envelope's optional scheduling fields (`tenant`/`priority`),
    /// carried through canonicalization for the spool record.
    pub meta: wire::EnvelopeMeta,
    /// `ingest[..] -> ... -> collect` one-liner.
    pub summary: String,
    /// What the optimizer would rewrite.
    pub opt_summary: String,
    /// Whether `mare work` drivers can materialize the source.
    pub executable: bool,
}

/// Admission control for `mare submit`: decode → dry-run `build()`
/// (whole-job validation + optimizer passes) → canonical re-encode.
/// Nothing executes; bad plans are rejected before they reach the
/// queue, with the builder's full error list.
pub struct Submitter {
    cluster: Arc<Cluster>,
}

impl Submitter {
    pub fn new(config: ClusterConfig) -> Submitter {
        // validation never executes containers, so no artifact runtime;
        // the cluster still comes from the one assembly path `mare run`
        // uses (workloads::make_cluster)
        let cluster = crate::workloads::make_cluster(config, None, None)
            .expect("a cluster without a runtime always constructs");
        Submitter { cluster }
    }

    /// Decode and dry-run-build `text` without enqueueing it.
    pub fn validate(&self, text: &str) -> Result<ValidatedPlan> {
        let envelope_in =
            Json::parse(text).map_err(|e| wire::WireError::Syntax(e.to_string()))?;
        let pipeline = wire::decode(&envelope_in)?;
        // the documented scheduling fields survive canonicalization;
        // everything else unknown is dropped (the unknown-field rule)
        let meta = wire::decode_meta(&envelope_in)?;
        let (label, partitions) = ingest_of(&pipeline)?;
        let spec = SourceSpec::parse(&label);
        // validation is data-independent: build() only needs the
        // partition count, so admission stays O(1) in source size —
        // drivers materialize the real records at execution time. The
        // stub's zero-byte partitions keep placeholder sizes out of
        // the dry-run's auto depth planning (nominal fallback); the
        // driver re-plans against what its ingestion really measures.
        let source = spec.stub(partitions);
        let job = MaRe::source(self.cluster.clone(), source)
            .append_pipeline(&pipeline)
            .build()?;
        let summary =
            pipeline.ops().iter().map(|o| o.label()).collect::<Vec<_>>().join(" -> ");
        Ok(ValidatedPlan {
            envelope: wire::encode_with_meta(&pipeline, &meta)?,
            pipeline,
            meta,
            summary,
            opt_summary: job.opt_report().summary(),
            executable: spec.is_executable(),
        })
    }

    /// Validate then enqueue. Returns the assigned job id.
    ///
    /// When a resident `mare serve` daemon owns the spool (it published
    /// `serve-control.json` there), its advertised depth limit is
    /// enforced here: a full spool is a typed
    /// [`MareError::Backpressure`] refusal, never a hang or a silent
    /// drop. A control file whose heartbeat has gone stale belongs to a
    /// daemon that died without cleaning up — its limits are ignored
    /// (refusing submissions on behalf of a dead service helps nobody);
    /// hand-authored files carry no heartbeat and are always enforced.
    pub fn submit(&self, queue: &JobQueue, text: &str) -> Result<(u64, ValidatedPlan)> {
        let plan = self.validate(text)?;
        if let Some(control) = crate::serve::control::read(queue.dir())? {
            if control.max_depth > 0 && control.live(queue::now_millis()) {
                let (queued, held) = queue.pending()?;
                if queued + held >= control.max_depth {
                    return Err(MareError::Backpressure {
                        queued,
                        held,
                        max_depth: control.max_depth,
                    });
                }
            }
        }
        let id = queue.submit_meta(
            plan.envelope.clone(),
            plan.summary.clone(),
            plan.meta.tenant_or_default(),
            plan.meta.priority_or_default(),
        )?;
        Ok((id, plan))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn source_specs_parse_and_roundtrip_labels() {
        assert_eq!(SourceSpec::parse("gen:gc:64"), SourceSpec::GenGc { lines: 64 });
        assert_eq!(SourceSpec::parse("gen:vs:8"), SourceSpec::GenVs { molecules: 8 });
        assert_eq!(
            SourceSpec::parse("gen:snp:500"),
            SourceSpec::GenSnp { chromosome_bp: 500 }
        );
        assert_eq!(
            SourceSpec::parse("inline:ACGT\nGGCC"),
            SourceSpec::Inline { text: "ACGT\nGGCC".into() }
        );
        // storage URIs over registered schemes resolve (and execute)
        let spec = SourceSpec::parse("hdfs://genome.txt?lines=64");
        assert!(matches!(&spec, SourceSpec::Storage { uri } if uri.key == "genome.txt"));
        assert!(spec.is_executable());
        // unregistered schemes stay opaque
        assert_eq!(
            SourceSpec::parse("ftp://genome.txt"),
            SourceSpec::Opaque { label: "ftp://genome.txt".into() }
        );
        // malformed counts degrade to opaque, not panic
        assert!(matches!(SourceSpec::parse("gen:gc:lots"), SourceSpec::Opaque { .. }));

        for label in [
            "gen:gc:64",
            "gen:vs:8",
            "gen:snp:500",
            "inline:ACGT",
            "swift://x",
            "hdfs://genome.txt?lines=64",
            "ftp://x",
        ] {
            assert_eq!(SourceSpec::parse(label).label(), label);
        }
    }

    #[test]
    fn materialized_sources_are_deterministic() {
        let a = SourceSpec::parse("gen:gc:32").materialize(4, 2).unwrap();
        let b = SourceSpec::parse("gen:gc:32").materialize(4, 2).unwrap();
        assert_eq!(a.num_partitions(), 4);
        assert_eq!(a.describe(), b.describe());
        assert!(SourceSpec::parse("nope://x").materialize(2, 2).is_err());

        // storage sources materialize with locality + an ingest report
        let (ds, report) = SourceSpec::parse("hdfs://genome.txt?lines=64")
            .materialize_with_ingest(4, 2)
            .unwrap();
        assert_eq!(ds.num_partitions(), 4);
        let report = report.expect("storage sources measure ingestion");
        assert_eq!(report.partition_bytes.len(), 4);
        assert!(report.bytes > 0);
        match ds.plan().as_ref() {
            crate::dataset::Plan::Source { partitions, label } => {
                assert_eq!(label, "hdfs://genome.txt?lines=64");
                assert!(partitions.iter().all(|p| p.preferred_worker.is_some()));
            }
            _ => panic!("expected a source plan"),
        }
        // non-storage sources report no ingestion
        let (_, none) =
            SourceSpec::parse("gen:gc:8").materialize_with_ingest(2, 2).unwrap();
        assert!(none.is_none());

        // snp sources carry the matching reference genome; others don't
        assert!(SourceSpec::parse("gen:snp:500").reference().is_some());
        assert!(SourceSpec::parse("gen:gc:8").reference().is_none());

        // snp sources are whole 4-line FASTQ reads, not lines
        let reads = SourceSpec::parse("gen:snp:500").materialize(2, 2).unwrap();
        assert_eq!(reads.num_partitions(), 2);
        match reads.plan().as_ref() {
            crate::dataset::Plan::Source { partitions, .. } => {
                let r = partitions
                    .iter()
                    .flat_map(|p| p.records.iter())
                    .next()
                    .expect("generated reads");
                let text = r.as_text().unwrap();
                assert!(text.starts_with('@'), "{text}");
                assert_eq!(text.lines().count(), 4, "{text}");
            }
            _ => panic!("expected a source plan"),
        }
    }

    /// The streamed path is a drop-in for [`SourceSpec::materialize_with_ingest`]:
    /// identical dataset, identical accounting, plus per-partition seals
    /// for storage sources — and a clean batch fallback everywhere else.
    #[test]
    fn streamed_materialization_matches_batch() {
        let spec = SourceSpec::parse("hdfs://genome.txt?lines=64");
        let (batch, brep) = spec.materialize_with_ingest(4, 2).unwrap();
        let mut seals = Vec::new();
        let (streamed, srep) =
            spec.materialize_streamed(4, 2, |s| seals.push(s.index)).unwrap();
        assert_eq!(batch.describe(), streamed.describe());
        let (brep, srep) = (brep.unwrap(), srep.unwrap());
        assert_eq!(brep.bytes, srep.bytes);
        assert_eq!(brep.partition_bytes, srep.partition_bytes);
        assert_eq!(brep.duration, srep.duration);
        seals.sort_unstable();
        assert_eq!(seals, vec![0, 1, 2, 3]);
        assert!(srep.first_partition_ready < srep.fully_materialized, "{srep:?}");

        // non-storage sources: batch fallback, no seals, no report
        let (_, none) = SourceSpec::parse("gen:gc:8")
            .materialize_streamed(2, 2, |_| panic!("gen sources have no seals"))
            .unwrap();
        assert!(none.is_none());
    }

    #[test]
    fn submitter_accepts_good_plans_and_rejects_bad_ones() {
        let submitter = Submitter::new(crate::cluster::ClusterConfig::sized(2, 2));
        let good = r#"{
          "version": 1,
          "ops": [
            {"op": "ingest", "label": "gen:gc:16", "partitions": 2},
            {"op": "map", "image": "ubuntu", "command": "wc -l /in > /out",
             "input": {"kind": "text", "path": "/in"},
             "output": {"kind": "text", "path": "/out"}},
            {"op": "collect"}
          ]
        }"#;
        let v = submitter.validate(good).unwrap();
        assert!(v.executable);
        assert!(v.summary.contains("ingest[gen:gc:16]"), "{}", v.summary);
        assert!(v.summary.ends_with("collect"), "{}", v.summary);

        // wire-level rejection: unknown node kind
        let unknown_op = good.replace("\"op\": \"map\"", "\"op\": \"teleport\"");
        let err = submitter.validate(&unknown_op).unwrap_err().to_string();
        assert!(err.contains("unknown node kind"), "{err}");

        // builder-level rejection: empty image
        let empty_image = good.replace("\"image\": \"ubuntu\"", "\"image\": \"\"");
        let err = submitter.validate(&empty_image).unwrap_err().to_string();
        assert!(err.contains("image must not be empty"), "{err}");

        // storage sources validate (against a stub) AND are executable
        let storage = good.replace("gen:gc:16", "hdfs://genome.txt");
        let v = submitter.validate(&storage).unwrap();
        assert!(v.executable);

        // opaque sources validate (against a stub) but are not executable
        let opaque = good.replace("gen:gc:16", "ftp://genome.txt");
        let v = submitter.validate(&opaque).unwrap();
        assert!(!v.executable);
    }

    /// Regression: a control file left behind by a crashed daemon must
    /// not gate admission forever. Liveness comes from the heartbeat;
    /// hand-authored files (no heartbeat) keep their old always-on
    /// behavior.
    #[test]
    fn stale_daemon_control_files_stop_gating_admission() {
        use crate::serve::control::{self, Control};

        let dir = std::env::temp_dir()
            .join(format!("mare-submit-staleness-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let queue = JobQueue::open(dir.clone()).unwrap();
        let submitter = Submitter::new(crate::cluster::ClusterConfig::sized(2, 2));
        let plan = r#"{
          "version": 1,
          "ops": [
            {"op": "ingest", "label": "gen:gc:16", "partitions": 2},
            {"op": "map", "image": "ubuntu", "command": "wc -l /in > /out",
             "input": {"kind": "text", "path": "/in"},
             "output": {"kind": "text", "path": "/out"}},
            {"op": "collect"}
          ]
        }"#;

        let live = Control {
            max_depth: 1,
            drain: false,
            quotas: Vec::new(),
            max_attempts: 0,
            beat_ms: queue::now_millis(),
        };
        control::write(queue.dir(), &live).unwrap();
        submitter.submit(&queue, plan).unwrap();
        // fresh heartbeat + full spool: typed refusal
        let err = submitter.submit(&queue, plan).unwrap_err();
        assert!(matches!(err, MareError::Backpressure { .. }), "{err}");

        // identical limits, heartbeat long stale: the daemon is dead,
        // its depth limit no longer binds
        let mut stale = live.clone();
        stale.beat_ms = 1;
        control::write(queue.dir(), &stale).unwrap();
        submitter.submit(&queue, plan).unwrap();

        // hand-authored file (beat_ms 0): enforced unconditionally
        let mut authored = live.clone();
        authored.beat_ms = 0;
        control::write(queue.dir(), &authored).unwrap();
        let err = submitter.submit(&queue, plan).unwrap_err();
        assert!(matches!(err, MareError::Backpressure { .. }), "{err}");

        let _ = std::fs::remove_dir_all(&dir);
    }
}
