//! File-backed job queue: one spool directory, one JSON file per job.
//!
//! `mare submit` writes `job-NNNNNN.json` files holding the canonical
//! v1 plan envelope plus queue state; `mare jobs` lists them; `mare
//! work` (or any driver — the files are the coordination point, there
//! is no daemon) claims queued jobs FIFO and records outcomes. The
//! spool schema is documented alongside the plan envelope in
//! `docs/WIRE_FORMAT.md`.

use std::fs;
use std::path::{Path, PathBuf};

use crate::error::{MareError, Result};
use crate::util::json::Json;

/// Claim holds older than this are presumed abandoned by a dead worker
/// (live claims last milliseconds) and are swept back into the queue
/// on [`JobQueue::open`].
const STALE_CLAIM_SECS: u64 = 10;

/// Queue lifecycle of a submitted job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobStatus {
    Queued,
    Running,
    Done,
    Failed,
}

impl JobStatus {
    pub fn name(self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running => "running",
            JobStatus::Done => "done",
            JobStatus::Failed => "failed",
        }
    }

    pub fn parse(s: &str) -> Result<JobStatus> {
        match s {
            "queued" => Ok(JobStatus::Queued),
            "running" => Ok(JobStatus::Running),
            "done" => Ok(JobStatus::Done),
            "failed" => Ok(JobStatus::Failed),
            other => Err(MareError::Submit(format!("unknown job status `{other}`"))),
        }
    }
}

/// Execution outcome recorded by the driver that ran the job.
#[derive(Debug, Clone)]
pub struct JobResult {
    /// Name of the executing driver.
    pub driver: String,
    /// Simulated container launches the job performed.
    pub launches: u64,
    /// Records in the collected output.
    pub records: u64,
    /// `ok`, or the error that failed the job.
    pub detail: String,
}

/// One spool entry: a plan plus its queue state.
#[derive(Debug, Clone)]
pub struct JobRecord {
    pub id: u64,
    pub status: JobStatus,
    /// `ingest[..] -> ... -> collect` summary (display only).
    pub summary: String,
    /// The canonical v1 plan envelope, exactly as admitted.
    pub plan: Json,
    /// Present once a driver has executed (or failed) the job.
    pub result: Option<JobResult>,
}

impl JobRecord {
    pub fn to_json(&self) -> Json {
        let result = match &self.result {
            Some(r) => Json::obj(vec![
                ("driver", Json::str(r.driver.as_str())),
                ("launches", Json::Num(r.launches as f64)),
                ("records", Json::Num(r.records as f64)),
                ("detail", Json::str(r.detail.as_str())),
            ]),
            None => Json::Null,
        };
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("status", Json::str(self.status.name())),
            ("summary", Json::str(self.summary.as_str())),
            ("plan", self.plan.clone()),
            ("result", result),
        ])
    }

    pub fn from_json(json: &Json) -> Result<JobRecord> {
        let result = match json.get("result") {
            None | Some(Json::Null) => None,
            Some(r) => Some(JobResult {
                driver: r.req("driver")?.as_str()?.to_string(),
                launches: r.req("launches")?.as_u64()?,
                records: r.req("records")?.as_u64()?,
                detail: r.req("detail")?.as_str()?.to_string(),
            }),
        };
        Ok(JobRecord {
            id: json.req("id")?.as_u64()?,
            status: JobStatus::parse(json.req("status")?.as_str()?)?,
            summary: json.req("summary")?.as_str()?.to_string(),
            plan: json.req("plan")?.clone(),
            result,
        })
    }
}

/// The spool directory. Opening creates it and sweeps stale claim
/// holds (left by crashed workers) back into the queue; every
/// operation re-reads the files, so concurrent CLI invocations and
/// multiple drivers share one queue.
pub struct JobQueue {
    dir: PathBuf,
}

impl JobQueue {
    pub fn open(dir: impl Into<PathBuf>) -> Result<JobQueue> {
        let dir = dir.into();
        fs::create_dir_all(&dir)?;
        let queue = JobQueue { dir };
        queue.recover_stale_claims()?;
        Ok(queue)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn path_of(&self, id: u64) -> PathBuf {
        self.dir.join(format!("job-{id:06}.json"))
    }

    /// Claim holds are transient (they live for the few file ops inside
    /// one [`Self::claim`] call); a hold that is still present — and
    /// has AGED well past any live claim — when a process opens the
    /// queue belongs to a dead worker. Sweep it back so the job is
    /// claimable again rather than silently lost. The age gate keeps a
    /// fresh `open()` from yanking an in-flight claim out from under a
    /// live worker; if a holder is merely slower than the gate, the
    /// job may execute twice — recoverable — while silent loss is not.
    fn recover_stale_claims(&self) -> Result<()> {
        self.recover_claims_older_than(STALE_CLAIM_SECS)
    }

    fn recover_claims_older_than(&self, min_age_secs: u64) -> Result<()> {
        for entry in fs::read_dir(&self.dir)? {
            let entry = entry?;
            let name = entry.file_name();
            let name = name.to_string_lossy().to_string();
            let Some(stem) = name.strip_suffix(".claim") else {
                continue;
            };
            let age_secs = entry
                .metadata()
                .and_then(|m| m.modified())
                .ok()
                .and_then(|t| t.elapsed().ok())
                .map(|d| d.as_secs());
            // unreadable age counts as fresh: never sweep a hold we
            // cannot prove stale
            if age_secs.map(|a| a >= min_age_secs).unwrap_or(false) {
                let _ = fs::rename(entry.path(), self.dir.join(stem));
            }
        }
        Ok(())
    }

    /// Highest id present in the spool under ANY state — canonical,
    /// reservation marker, claim hold, or temp — so ids are never
    /// reused while a job's file is temporarily renamed aside.
    fn max_spool_id(&self) -> Result<u64> {
        let mut max = 0;
        for entry in fs::read_dir(&self.dir)? {
            let name = entry?.file_name();
            let name = name.to_string_lossy();
            if let Some(rest) = name.strip_prefix("job-") {
                let digits: String =
                    rest.chars().take_while(|c| c.is_ascii_digit()).collect();
                if let Ok(id) = digits.parse::<u64>() {
                    max = max.max(id);
                }
            }
        }
        Ok(max)
    }

    /// All jobs, sorted by id.
    pub fn list(&self) -> Result<Vec<JobRecord>> {
        let mut jobs = Vec::new();
        for entry in fs::read_dir(&self.dir)? {
            let entry = entry?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if !(name.starts_with("job-") && name.ends_with(".json")) {
                continue;
            }
            let text = match fs::read_to_string(entry.path()) {
                Ok(text) => text,
                // renamed away by a concurrent claimer between read_dir
                // and here — the job is held, not gone; skip it
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => continue,
                Err(e) => return Err(e.into()),
            };
            if text.trim().is_empty() {
                continue; // reservation marker: a submit() in progress
            }
            let json = Json::parse(&text)
                .map_err(|e| MareError::Submit(format!("spool file {name}: {e}")))?;
            jobs.push(JobRecord::from_json(&json)?);
        }
        jobs.sort_by_key(|j| j.id);
        Ok(jobs)
    }

    pub fn get(&self, id: u64) -> Result<JobRecord> {
        let text = fs::read_to_string(self.path_of(id))
            .map_err(|e| MareError::Submit(format!("job {id}: {e}")))?;
        let json = Json::parse(&text)?;
        JobRecord::from_json(&json)
    }

    /// Enqueue a validated plan; returns the assigned id.
    ///
    /// The id is reserved by atomically creating an empty canonical
    /// file (`create_new`; losers bump and retry — ids count files in
    /// ANY spool state, so a job held aside by a claimer keeps its id
    /// reserved). The content then lands via the atomic temp+rename in
    /// [`Self::write`], so readers see either the empty marker (which
    /// [`Self::list`] skips) or complete JSON — never a partial file.
    pub fn submit(&self, plan: Json, summary: String) -> Result<u64> {
        let mut id = self.max_spool_id()? + 1;
        loop {
            match fs::OpenOptions::new().write(true).create_new(true).open(self.path_of(id)) {
                Ok(_) => break,
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => id += 1,
                Err(e) => return Err(e.into()),
            }
        }
        let rec = JobRecord { id, status: JobStatus::Queued, summary, plan, result: None };
        self.write(&rec)?;
        Ok(id)
    }

    /// Persist a record atomically: the full content goes to a temp
    /// file that is renamed over the canonical path, so concurrent
    /// readers never observe truncated or partial JSON.
    pub fn write(&self, rec: &JobRecord) -> Result<()> {
        let tmp = self.dir.join(format!("job-{:06}.json.tmp", rec.id));
        fs::write(&tmp, rec.to_json().to_string_pretty())?;
        fs::rename(&tmp, self.path_of(rec.id))?;
        Ok(())
    }

    /// Claim the lowest-id queued job (FIFO), marking it running.
    ///
    /// The claim is a rename: exactly one claimant wins moving the
    /// spool file aside, so concurrent workers (processes included)
    /// never execute the same job twice. Losers skip to the next
    /// queued candidate; any failure under the hold restores the file
    /// instead of stranding the job.
    pub fn claim(&self) -> Result<Option<JobRecord>> {
        for candidate in self.list()? {
            if candidate.status != JobStatus::Queued {
                continue;
            }
            let path = self.path_of(candidate.id);
            let hold = path.with_extension("json.claim");
            if fs::rename(&path, &hold).is_err() {
                continue; // another worker claimed it first
            }
            // the rename is the lock; the held file is authoritative
            let text = match fs::read_to_string(&hold) {
                Ok(text) => text,
                // hold vanished: a recovering peer swept it back; retry
                Err(_) => continue,
            };
            // re-stamp the hold: rename preserves the submit-time
            // mtime, which would make any not-freshly-submitted job
            // look instantly "stale" to a racing open(); rewriting
            // pins the age gate to the CLAIM instant. (Sweepers only
            // rename holds, never read them, so this plain write
            // cannot be partially observed.)
            let _ = fs::write(&hold, &text);
            let mut job = match Json::parse(&text).and_then(|j| JobRecord::from_json(&j)) {
                Ok(job) => job,
                Err(e) => {
                    let _ = fs::rename(&hold, &path);
                    return Err(e);
                }
            };
            if job.status != JobStatus::Queued {
                fs::rename(&hold, &path)?;
                continue;
            }
            job.status = JobStatus::Running;
            // commit by renames only: the Running record lands in the
            // hold atomically (temp+rename), then the hold moves back
            // to the canonical path, consuming it. After the commit no
            // hold exists, so a stale-claim sweep can never resurrect
            // the Queued copy over a committed Running record. (A
            // sweep racing the *middle* of this claim can re-queue the
            // job and at worst run it twice — the documented recovery
            // tradeoff; it can no longer corrupt or lose state.)
            let tmp = self.dir.join(format!("job-{:06}.json.tmp", job.id));
            fs::write(&tmp, job.to_json().to_string_pretty())?;
            fs::rename(&tmp, &hold)?;
            if fs::rename(&hold, &path).is_err() {
                // a recovering peer swept the hold (carrying our fresh
                // Running record) to the canonical path between the two
                // renames — nobody would execute it, so put the job
                // back in the queue instead of stranding it `running`
                let _ = self.requeue(job.id);
                continue;
            }
            return Ok(Some(job));
        }
        Ok(None)
    }

    /// Record an execution outcome for a claimed job; returns the
    /// record exactly as persisted (callers should use it rather than
    /// re-reading the spool, which a concurrent `mare requeue` may
    /// have already rewritten).
    pub fn finish(
        &self,
        mut job: JobRecord,
        status: JobStatus,
        result: JobResult,
    ) -> Result<JobRecord> {
        job.status = status;
        job.result = Some(result);
        self.write(&job)?;
        Ok(job)
    }

    /// Put a job back in the queue, clearing any recorded result — the
    /// operator's recovery path (`mare requeue <id>`) for jobs stuck
    /// `running` after their worker died post-claim, and for re-running
    /// `failed`/`done` jobs.
    pub fn requeue(&self, id: u64) -> Result<JobRecord> {
        let mut job = self.get(id)?;
        job.status = JobStatus::Queued;
        job.result = None;
        self.write(&job)?;
        Ok(job)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_queue(name: &str) -> JobQueue {
        let dir = std::env::temp_dir()
            .join(format!("mare-queue-test-{}-{name}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        JobQueue::open(dir).unwrap()
    }

    fn plan() -> Json {
        Json::parse(
            r#"{"version": 1, "ops": [
                {"op": "ingest", "label": "gen:gc:8", "partitions": 2},
                {"op": "collect"}]}"#,
        )
        .unwrap()
    }

    #[test]
    fn submit_list_claim_finish_lifecycle() {
        let q = tmp_queue("lifecycle");
        assert!(q.list().unwrap().is_empty());
        assert!(q.claim().unwrap().is_none());

        let a = q.submit(plan(), "a".into()).unwrap();
        let b = q.submit(plan(), "b".into()).unwrap();
        assert_eq!((a, b), (1, 2));
        assert_eq!(q.list().unwrap().len(), 2);

        // FIFO claim flips queued -> running, persistently
        let claimed = q.claim().unwrap().unwrap();
        assert_eq!(claimed.id, 1);
        assert_eq!(q.get(1).unwrap().status, JobStatus::Running);
        assert_eq!(q.claim().unwrap().unwrap().id, 2);
        assert!(q.claim().unwrap().is_none());

        q.finish(
            claimed,
            JobStatus::Done,
            JobResult { driver: "d0".into(), launches: 4, records: 1, detail: "ok".into() },
        )
        .unwrap();
        let done = q.get(1).unwrap();
        assert_eq!(done.status, JobStatus::Done);
        let r = done.result.unwrap();
        assert_eq!((r.launches, r.records), (4, 1));
        assert_eq!(r.driver, "d0");

        // ids keep increasing past finished jobs
        assert_eq!(q.submit(plan(), "c".into()).unwrap(), 3);

        // requeue clears the result and makes the job claimable again
        let requeued = q.requeue(1).unwrap();
        assert_eq!(requeued.status, JobStatus::Queued);
        assert!(requeued.result.is_none());
        assert_eq!(q.claim().unwrap().unwrap().id, 1);
    }

    #[test]
    fn stale_claims_recover_and_held_ids_are_not_reused() {
        let q = tmp_queue("recover");
        let id = q.submit(plan(), "a".into()).unwrap();
        // simulate a worker that died mid-claim: the job sits in a hold
        let path = q.dir().join(format!("job-{id:06}.json"));
        let hold = q.dir().join(format!("job-{id:06}.json.claim"));
        fs::rename(&path, &hold).unwrap();
        assert!(q.list().unwrap().is_empty());
        // the held id stays reserved — a concurrent submit cannot take
        // it and have the claimer's write clobber the new job
        assert_eq!(q.submit(plan(), "b".into()).unwrap(), id + 1);
        // a fresh open() leaves FRESH holds alone (they may belong to a
        // live claim in another process)...
        let q2 = JobQueue::open(q.dir().to_path_buf()).unwrap();
        assert_eq!(q2.list().unwrap().len(), 1);
        // ...but once a hold has aged past any live claim, the sweep
        // returns the job to the queue
        q2.recover_claims_older_than(0).unwrap();
        let jobs = q2.list().unwrap();
        assert_eq!(jobs.len(), 2);
        assert_eq!((jobs[0].id, jobs[0].status), (id, JobStatus::Queued));
        assert_eq!(q2.claim().unwrap().unwrap().id, id);
    }

    #[test]
    fn spool_files_roundtrip_through_json() {
        let rec = JobRecord {
            id: 7,
            status: JobStatus::Failed,
            summary: "ingest -> collect".into(),
            plan: plan(),
            result: Some(JobResult {
                driver: "driver-1".into(),
                launches: 0,
                records: 0,
                detail: "container: image not found".into(),
            }),
        };
        let back = JobRecord::from_json(&rec.to_json()).unwrap();
        assert_eq!(back.id, 7);
        assert_eq!(back.status, JobStatus::Failed);
        assert_eq!(back.plan, rec.plan);
        assert_eq!(back.result.unwrap().detail, "container: image not found");

        assert!(JobStatus::parse("zombie").is_err());
        for s in [JobStatus::Queued, JobStatus::Running, JobStatus::Done, JobStatus::Failed] {
            assert_eq!(JobStatus::parse(s.name()).unwrap(), s);
        }
    }
}
