//! File-backed job queue: one spool directory, one JSON file per job.
//!
//! `mare submit` writes `job-NNNNNN.json` files holding the canonical
//! v1 plan envelope plus queue state; `mare jobs` lists them; `mare
//! work` (or any driver — the files are the coordination point, there
//! is no daemon) claims queued jobs FIFO and records outcomes. The
//! spool schema is documented alongside the plan envelope in
//! `docs/WIRE_FORMAT.md`.

use std::collections::{HashMap, HashSet};
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, SystemTime};

use crate::error::{MareError, Result};
use crate::util::json::Json;

/// Claim holds older than this are presumed abandoned by a dead worker
/// (live claims last milliseconds) and are swept back into the queue
/// on [`JobQueue::open`] — and by [`JobQueue::sweep_stale`], which a
/// running worker pool calls from its idle loop. The same threshold
/// gates [`JobQueue::requeue`]: a `running` record younger than this is
/// presumed to belong to a live worker.
pub const STALE_CLAIM: Duration = Duration::from_secs(10);

/// How many full scan passes [`JobQueue::claim`] makes when every
/// queued candidate it saw was snatched by a competing claimer, and the
/// cap on the exponential backoff slept between passes. Bounded so a
/// contended claim costs at most a few milliseconds before reporting
/// "nothing claimable" back to the caller's own retry loop.
const CLAIM_ROUNDS: u32 = 4;
const CLAIM_BACKOFF_CAP: Duration = Duration::from_millis(16);

/// Temp files carry a process-unique + monotonic suffix so two threads
/// persisting the same job id (e.g. a `finish` racing a `requeue`)
/// never interleave writes to one temp path — each write lands whole
/// via its own rename, and the canonical file holds one writer's
/// complete record, never a splice of both.
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// What one [`JobQueue::claim`] scan observed — how contended the spool
/// was, for worker-pool reports.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ClaimStats {
    /// Queued candidates another claimer snatched first (rename lost).
    pub conflicts: u64,
    /// Backoff sleeps taken between contended scan passes.
    pub backoffs: u64,
    /// Queued candidates the final scan pass saw. When a claim comes
    /// back empty, `queued_seen == 0` tells the caller the spool had
    /// nothing claimable in sight — a worker pool combines it with
    /// [`JobQueue::held_count`] to decide termination without
    /// re-parsing every spool record.
    pub queued_seen: u64,
    /// Spool records actually read + JSON-parsed across this claim's
    /// scan passes. Records unchanged since the last scan are served
    /// from the claim-scan index (see [`JobQueue::list`]) at the cost
    /// of a `stat`, so a resident fleet idling over a big spool of
    /// finished jobs reports `parsed == 0` here — the cache-efficiency
    /// signal `mare serve` surfaces as `spool_parses`.
    pub parsed: u64,
}

/// A claim-order policy: reorders the queued candidates of one scan
/// pass in place (front is claimed first). See
/// [`JobQueue::claim_with_stats_ordered`].
pub type ClaimOrder<'a> = &'a (dyn Fn(&mut Vec<JobRecord>) + Sync);

/// Milliseconds since the Unix epoch — the stamp embedded in claim-hold
/// file names (see [`JobQueue::sweep_stale`]) and in
/// [`JobRecord::stamp_ms`]/[`JobRecord::claimed_ms`].
pub fn now_millis() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// Outcome of one rename-locked claim attempt on a single candidate.
enum ClaimAttempt {
    /// This claimer won the rename and committed the job `running`.
    Won(JobRecord),
    /// A competing claimer (or sweeper) touched the file first —
    /// worth rescanning after a backoff.
    Contended,
    /// The job turned out not to be claimable (finished or requeued
    /// under us) — not contention, don't back off for it.
    Gone,
}

/// Queue lifecycle of a submitted job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobStatus {
    Queued,
    Running,
    Done,
    Failed,
}

impl JobStatus {
    pub fn name(self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running => "running",
            JobStatus::Done => "done",
            JobStatus::Failed => "failed",
        }
    }

    pub fn parse(s: &str) -> Result<JobStatus> {
        match s {
            "queued" => Ok(JobStatus::Queued),
            "running" => Ok(JobStatus::Running),
            "done" => Ok(JobStatus::Done),
            "failed" => Ok(JobStatus::Failed),
            other => Err(MareError::Submit(format!("unknown job status `{other}`"))),
        }
    }
}

/// One recorded failed attempt: who was executing (or holding) the job
/// and what went wrong. Accumulated on the spool record so a job that
/// reaches the dead-letter queue carries its full failure history.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobFailure {
    /// Unix millis when the failure was recorded.
    pub at_ms: u64,
    /// The worker/driver involved (e.g. `serve-2`), or the supervisor
    /// that recovered the orphan.
    pub worker: String,
    /// What happened: the execution error, or the death note.
    pub detail: String,
}

impl JobFailure {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("at_ms", Json::Num(self.at_ms as f64)),
            ("worker", Json::str(self.worker.as_str())),
            ("detail", Json::str(self.detail.as_str())),
        ])
    }

    pub fn from_json(json: &Json) -> Result<JobFailure> {
        Ok(JobFailure {
            at_ms: match json.get("at_ms") {
                None | Some(Json::Null) => 0,
                Some(v) => v.as_u64()?,
            },
            worker: json.req("worker")?.as_str()?.to_string(),
            detail: json.req("detail")?.as_str()?.to_string(),
        })
    }
}

/// Execution outcome recorded by the driver that ran the job.
#[derive(Debug, Clone)]
pub struct JobResult {
    /// Name of the executing driver.
    pub driver: String,
    /// Simulated container launches the job performed.
    pub launches: u64,
    /// Records in the collected output.
    pub records: u64,
    /// `ok`, or the error that failed the job.
    pub detail: String,
}

/// One spool entry: a plan plus its queue state.
#[derive(Debug, Clone)]
pub struct JobRecord {
    pub id: u64,
    pub status: JobStatus,
    /// `ingest[..] -> ... -> collect` summary (display only).
    pub summary: String,
    /// Admission/accounting bucket (denormalized from the envelope's
    /// optional `tenant` key at submit so schedulers never re-parse
    /// plans). Legacy spool files read back as `"default"`.
    pub tenant: String,
    /// Claim-order tie-break within a tenant (higher first; may be
    /// negative). Legacy spool files read back as 0.
    pub priority: i64,
    /// Unix millis of the last state transition (submit, claim commit,
    /// finish, requeue) — what `mare jobs` renders as the state age.
    /// Legacy spool files read back as 0 ("age unknown").
    pub stamp_ms: u64,
    /// Unix millis of the claim that moved this record `running`;
    /// preserved through `finish` (audit trail), cleared on requeue.
    pub claimed_ms: Option<u64>,
    /// Global claim sequence number a resident scheduler assigned when
    /// the claim committed — the fair-share audit trail. In-memory
    /// between claim and finish; never set by one-shot claims.
    pub claim_seq: Option<u64>,
    /// Execution attempts consumed so far: incremented by every claim
    /// commit, reset by `mare dlq retry` (a fresh lease). Legacy spool
    /// files read back as 0 — absent means zero, and zero is never
    /// written, so records without attempts stay byte-stable through
    /// transitions that don't touch the counter.
    pub attempts: u64,
    /// Per-attempt failure context (execution errors, worker-death
    /// notes), appended as failures happen and preserved through
    /// requeues — what `mare dlq show` surfaces. Legacy spool files
    /// read back as empty.
    pub failures: Vec<JobFailure>,
    /// The canonical v1 plan envelope, exactly as admitted.
    pub plan: Json,
    /// Present once a driver has executed (or failed) the job.
    pub result: Option<JobResult>,
}

impl JobRecord {
    pub fn to_json(&self) -> Json {
        let result = match &self.result {
            Some(r) => Json::obj(vec![
                ("driver", Json::str(r.driver.as_str())),
                ("launches", Json::Num(r.launches as f64)),
                ("records", Json::Num(r.records as f64)),
                ("detail", Json::str(r.detail.as_str())),
            ]),
            None => Json::Null,
        };
        let opt_num = |v: Option<u64>| match v {
            Some(n) => Json::Num(n as f64),
            None => Json::Null,
        };
        let mut fields = vec![
            ("id", Json::Num(self.id as f64)),
            ("status", Json::str(self.status.name())),
            ("summary", Json::str(self.summary.as_str())),
            ("tenant", Json::str(self.tenant.as_str())),
            ("priority", Json::Num(self.priority as f64)),
            ("stamp_ms", Json::Num(self.stamp_ms as f64)),
            ("claimed_ms", opt_num(self.claimed_ms)),
            ("claim_seq", opt_num(self.claim_seq)),
        ];
        // absent-means-zero: never write an empty counter/history, so a
        // legacy record's bytes survive transitions that don't own them
        if self.attempts > 0 {
            fields.push(("attempts", Json::Num(self.attempts as f64)));
        }
        if !self.failures.is_empty() {
            fields.push((
                "failures",
                Json::arr(self.failures.iter().map(JobFailure::to_json)),
            ));
        }
        fields.push(("plan", self.plan.clone()));
        fields.push(("result", result));
        Json::obj(fields)
    }

    pub fn from_json(json: &Json) -> Result<JobRecord> {
        let result = match json.get("result") {
            None | Some(Json::Null) => None,
            Some(r) => Some(JobResult {
                driver: r.req("driver")?.as_str()?.to_string(),
                launches: r.req("launches")?.as_u64()?,
                records: r.req("records")?.as_u64()?,
                detail: r.req("detail")?.as_str()?.to_string(),
            }),
        };
        // scheduling fields default when absent, so spool files written
        // before the serve subsystem stay readable (and vice versa:
        // older readers ignore keys they don't know)
        let opt_num = |key: &'static str| -> Result<Option<u64>> {
            match json.get(key) {
                None | Some(Json::Null) => Ok(None),
                Some(v) => Ok(Some(v.as_u64()?)),
            }
        };
        Ok(JobRecord {
            id: json.req("id")?.as_u64()?,
            status: JobStatus::parse(json.req("status")?.as_str()?)?,
            summary: json.req("summary")?.as_str()?.to_string(),
            tenant: match json.get("tenant") {
                None | Some(Json::Null) => crate::mare::wire::DEFAULT_TENANT.to_string(),
                Some(v) => v.as_str()?.to_string(),
            },
            priority: match json.get("priority") {
                None | Some(Json::Null) => 0,
                Some(v) => v.as_i64()?,
            },
            stamp_ms: opt_num("stamp_ms")?.unwrap_or(0),
            claimed_ms: opt_num("claimed_ms")?,
            claim_seq: opt_num("claim_seq")?,
            attempts: opt_num("attempts")?.unwrap_or(0),
            failures: match json.get("failures") {
                None | Some(Json::Null) => Vec::new(),
                Some(v) => v
                    .as_arr()?
                    .iter()
                    .map(JobFailure::from_json)
                    .collect::<Result<Vec<_>>>()?,
            },
            plan: json.req("plan")?.clone(),
            result,
        })
    }
}

/// One claim-scan index entry: the parse of a spool file at a known
/// file identity. Every spool rewrite goes through temp + rename
/// ([`JobQueue::persist_at`]), so a changed record ALWAYS lands on a
/// fresh inode — `(ino, len, mtime)` matching can never serve a stale
/// parse, no matter how coarse the filesystem's timestamps are.
struct CachedParse {
    ino: u64,
    len: u64,
    mtime: Option<SystemTime>,
    rec: JobRecord,
}

/// `(ino, len, mtime)` of a spool file — what the claim-scan index
/// keys cache validity on. Inode 0 on platforms without one degrades
/// to `(len, mtime)` matching, still safe for rename-published files
/// on any filesystem with sub-rewrite timestamp granularity.
fn file_identity(meta: &fs::Metadata) -> (u64, u64, Option<SystemTime>) {
    #[cfg(unix)]
    let ino = std::os::unix::fs::MetadataExt::ino(meta);
    #[cfg(not(unix))]
    let ino = 0u64;
    (ino, meta.len(), meta.modified().ok())
}

/// The spool directory. Opening creates it and sweeps stale claim
/// holds (left by crashed workers) back into the queue; every
/// operation re-reads the files, so concurrent CLI invocations and
/// multiple drivers share one queue.
///
/// Scans keep a per-instance claim-scan index (parse cache keyed by
/// canonical path + file identity): the files stay the coordination
/// point — other processes' writes are picked up by identity change —
/// but an unchanged record costs one `stat` instead of a read + parse
/// on every scan. A resident `mare serve` fleet polling a spool of
/// mostly-finished jobs goes from O(jobs) parses per idle tick to
/// zero.
pub struct JobQueue {
    dir: PathBuf,
    scan_cache: Mutex<HashMap<PathBuf, CachedParse>>,
}

impl JobQueue {
    pub fn open(dir: impl Into<PathBuf>) -> Result<JobQueue> {
        let dir = dir.into();
        fs::create_dir_all(&dir)?;
        let queue = JobQueue { dir, scan_cache: Mutex::new(HashMap::new()) };
        queue.sweep_stale(STALE_CLAIM)?;
        Ok(queue)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn path_of(&self, id: u64) -> PathBuf {
        self.dir.join(format!("job-{id:06}.json"))
    }

    /// A claim-hold path for `id`, stamped with the claim instant IN
    /// THE NAME: `job-NNNNNN.json.claim-<unix_millis>`. The stamp
    /// travels atomically with the rename that takes the hold, so
    /// there is never a moment when a freshly taken hold advertises
    /// the canonical file's old mtime — a mid-run sweep racing such a
    /// window would steal a live claim and double-run the job.
    fn hold_path(&self, id: u64) -> PathBuf {
        self.dir.join(format!("job-{id:06}.json.claim-{}", now_millis()))
    }

    /// Whether any claim hold (any stamp) exists for `id`.
    fn has_hold(&self, id: u64) -> Result<bool> {
        let prefix = format!("job-{id:06}.json.claim");
        for entry in fs::read_dir(&self.dir)? {
            if entry?.file_name().to_string_lossy().starts_with(&prefix) {
                return Ok(true);
            }
        }
        Ok(false)
    }

    /// Claim holds are transient (they live for the few file ops inside
    /// one [`Self::claim`] call); a hold that is still present — and
    /// has AGED well past any live claim — belongs to a dead worker.
    /// Sweep it back so the job is claimable again rather than silently
    /// lost. The age gate keeps the sweep from yanking an in-flight
    /// claim out from under a live worker; if a holder is merely slower
    /// than the gate, the job may execute twice — recoverable — while
    /// silent loss is not.
    ///
    /// Callable MID-RUN (a worker pool's idle loop calls it between
    /// claim scans, so a pool whose worker dies holding a claim recovers
    /// the job without waiting for the next process start), as well as
    /// from [`Self::open`]. Returns how many holds were swept back.
    /// Aged-out temp files (crashed writers) are deleted as a side
    /// effect; live ones are far younger than any sane `min_age`.
    pub fn sweep_stale(&self, min_age: Duration) -> Result<usize> {
        let mut swept = 0;
        for entry in fs::read_dir(&self.dir)? {
            let entry = entry?;
            let name = entry.file_name();
            let name = name.to_string_lossy().to_string();
            let mtime_age = entry
                .metadata()
                .and_then(|m| m.modified())
                .ok()
                .and_then(|t| t.elapsed().ok());
            if let Some((stem, stamp)) = name.split_once(".claim") {
                // the stamp in the hold's NAME is authoritative — it
                // was written atomically by the claiming rename. Bare
                // `.claim` holds (older states, hand-made test spools)
                // fall back to the file mtime; an unreadable age counts
                // as fresh, since a hold we cannot prove stale must
                // never be swept out from under a live claimer.
                let age = stamp
                    .strip_prefix('-')
                    .and_then(|s| s.parse::<u64>().ok())
                    .map(|t| Duration::from_millis(now_millis().saturating_sub(t)))
                    .or(mtime_age);
                if age.map(|a| a >= min_age).unwrap_or(false)
                    && fs::rename(entry.path(), self.dir.join(stem)).is_ok()
                {
                    swept += 1;
                }
            } else if name.contains(".json.tmp-")
                && mtime_age.map(|a| a >= min_age).unwrap_or(false)
            {
                let _ = fs::remove_file(entry.path());
            }
        }
        Ok(swept)
    }

    /// Highest id present in the spool under ANY state — canonical,
    /// reservation marker, claim hold, temp, or dead-lettered — so ids
    /// are never reused while a job's file is temporarily renamed aside
    /// (and a `dlq retry` never collides with a later submission).
    fn max_spool_id(&self) -> Result<u64> {
        let mut max = 0;
        let dlq = self.dlq_dir();
        let dirs = [Some(self.dir.as_path()), dlq.exists().then_some(dlq.as_path())];
        for dir in dirs.into_iter().flatten() {
            for entry in fs::read_dir(dir)? {
                let name = entry?.file_name();
                let name = name.to_string_lossy();
                if let Some(rest) = name.strip_prefix("job-") {
                    let digits: String =
                        rest.chars().take_while(|c| c.is_ascii_digit()).collect();
                    if let Ok(id) = digits.parse::<u64>() {
                        max = max.max(id);
                    }
                }
            }
        }
        Ok(max)
    }

    /// All jobs, sorted by id.
    pub fn list(&self) -> Result<Vec<JobRecord>> {
        Ok(self.scan()?.0)
    }

    /// [`Self::list`] through the claim-scan index; also returns how
    /// many records were actually read + parsed (the cache misses).
    fn scan(&self) -> Result<(Vec<JobRecord>, u64)> {
        let mut jobs = Vec::new();
        let mut parsed = 0u64;
        let mut seen: HashSet<PathBuf> = HashSet::new();
        for entry in fs::read_dir(&self.dir)? {
            let entry = entry?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if !(name.starts_with("job-") && name.ends_with(".json")) {
                continue;
            }
            let path = entry.path();
            // identity is taken BEFORE the read: if a rewrite slips in
            // between, the cache holds the newer content under the
            // older identity and the next scan simply re-parses — an
            // extra parse, never a stale record
            let identity = match entry.metadata() {
                Ok(meta) => file_identity(&meta),
                // renamed away by a concurrent claimer between read_dir
                // and here — the job is held, not gone; skip it
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => continue,
                Err(e) => return Err(e.into()),
            };
            if identity.1 == 0 {
                continue; // reservation marker: a submit() in progress
            }
            {
                let cache = self.scan_cache.lock().unwrap();
                if let Some(hit) = cache.get(&path) {
                    if (hit.ino, hit.len, hit.mtime) == identity {
                        jobs.push(hit.rec.clone());
                        seen.insert(path);
                        continue;
                    }
                }
            }
            let text = match fs::read_to_string(&path) {
                Ok(text) => text,
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => continue,
                Err(e) => return Err(e.into()),
            };
            if text.trim().is_empty() {
                continue; // reservation marker: a submit() in progress
            }
            let json = Json::parse(&text)
                .map_err(|e| MareError::Submit(format!("spool file {name}: {e}")))?;
            let rec = JobRecord::from_json(&json)?;
            parsed += 1;
            jobs.push(rec.clone());
            self.scan_cache.lock().unwrap().insert(
                path.clone(),
                CachedParse { ino: identity.0, len: identity.1, mtime: identity.2, rec },
            );
            seen.insert(path);
        }
        // entries whose file left the live spool (held by a claimer,
        // dead-lettered, hand-deleted) are dropped so the index tracks
        // the directory instead of growing without bound
        self.scan_cache.lock().unwrap().retain(|path, _| seen.contains(path));
        jobs.sort_by_key(|j| j.id);
        Ok((jobs, parsed))
    }

    pub fn get(&self, id: u64) -> Result<JobRecord> {
        let text = fs::read_to_string(self.path_of(id))
            .map_err(|e| MareError::Submit(format!("job {id}: {e}")))?;
        let json = Json::parse(&text)?;
        JobRecord::from_json(&json)
    }

    /// Enqueue a validated plan; returns the assigned id.
    ///
    /// The id is reserved by atomically creating an empty canonical
    /// file (`create_new`; losers bump and retry — ids count files in
    /// ANY spool state, so a job held aside by a claimer keeps its id
    /// reserved). The content then lands via the atomic temp+rename in
    /// [`Self::write`], so readers see either the empty marker (which
    /// [`Self::list`] skips) or complete JSON — never a partial file.
    pub fn submit(&self, plan: Json, summary: String) -> Result<u64> {
        self.submit_meta(plan, summary, crate::mare::wire::DEFAULT_TENANT, 0)
    }

    /// [`Self::submit`] with explicit scheduling metadata (tenant and
    /// priority, denormalized from the envelope at admission).
    pub fn submit_meta(
        &self,
        plan: Json,
        summary: String,
        tenant: &str,
        priority: i64,
    ) -> Result<u64> {
        let mut id = self.max_spool_id()? + 1;
        loop {
            match fs::OpenOptions::new().write(true).create_new(true).open(self.path_of(id)) {
                Ok(_) => break,
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => id += 1,
                Err(e) => return Err(e.into()),
            }
        }
        let rec = JobRecord {
            id,
            status: JobStatus::Queued,
            summary,
            tenant: tenant.to_string(),
            priority,
            stamp_ms: now_millis(),
            claimed_ms: None,
            claim_seq: None,
            attempts: 0,
            failures: Vec::new(),
            plan,
            result: None,
        };
        self.write(&rec)?;
        Ok(id)
    }

    /// A writer-unique temp path for job `id`. The `job-<id>` prefix
    /// keeps the id reserved in [`Self::max_spool_id`] while the
    /// canonical file is renamed aside; the pid + sequence suffix keeps
    /// two concurrent writers of the SAME id (finish racing requeue) on
    /// separate temp files, so each rename publishes one complete
    /// record instead of the two writers splicing through a shared path.
    fn tmp_path(&self, id: u64) -> PathBuf {
        let seq = TMP_SEQ.fetch_add(1, Ordering::Relaxed);
        self.dir.join(format!("job-{id:06}.json.tmp-{}-{seq}", std::process::id()))
    }

    /// The one atomic-persist idiom every spool rewrite goes through:
    /// full content to a writer-unique temp file, renamed over `dest`,
    /// so concurrent readers never observe truncated or partial JSON.
    fn persist_at(&self, rec: &JobRecord, dest: &Path) -> Result<()> {
        let tmp = self.tmp_path(rec.id);
        fs::write(&tmp, rec.to_json().to_string_pretty())?;
        fs::rename(&tmp, dest)?;
        Ok(())
    }

    /// Persist a record atomically at its canonical path.
    pub fn write(&self, rec: &JobRecord) -> Result<()> {
        self.persist_at(rec, &self.path_of(rec.id))
    }

    /// Claim the lowest-id queued job (FIFO), marking it running.
    ///
    /// The claim is a rename: exactly one claimant wins moving the
    /// spool file aside, so concurrent workers (processes included)
    /// never execute the same job twice. Losers skip to the next
    /// queued candidate; any failure under the hold restores the file
    /// instead of stranding the job.
    pub fn claim(&self) -> Result<Option<JobRecord>> {
        Ok(self.claim_with_stats()?.0)
    }

    /// [`Self::claim`] plus contention statistics. When a whole scan
    /// pass saw queued candidates but lost every rename race, the scan
    /// backs off (bounded exponential: 1ms, 2ms, 4ms, capped at 16ms,
    /// at most 4 passes) and rescans — under an 8-thread pool hammering
    /// one FIFO head, the immediate rescan would otherwise stampede the
    /// directory with `read_dir` + rename traffic that mostly loses
    /// again.
    pub fn claim_with_stats(&self) -> Result<(Option<JobRecord>, ClaimStats)> {
        self.claim_with_stats_ordered(None)
    }

    /// [`Self::claim_with_stats`] with a policy-driven claim order. The
    /// callback reorders each scan pass's queued candidates (front is
    /// claimed first); `None` keeps the FIFO id order every one-shot
    /// claimer uses. This is the ONE seam a resident scheduler needs in
    /// the spool protocol: ordering is advisory (who wins a contended
    /// candidate is still decided by the rename), so mixed-policy
    /// claimers on one spool stay exactly-once.
    pub fn claim_with_stats_ordered(
        &self,
        order: Option<ClaimOrder<'_>>,
    ) -> Result<(Option<JobRecord>, ClaimStats)> {
        let mut stats = ClaimStats::default();
        for round in 0..CLAIM_ROUNDS {
            if round > 0 {
                stats.backoffs += 1;
                let backoff = Duration::from_millis(1 << (round - 1));
                std::thread::sleep(backoff.min(CLAIM_BACKOFF_CAP));
            }
            let mut contended = false;
            let (jobs, parsed) = self.scan()?;
            stats.parsed += parsed;
            let mut candidates: Vec<JobRecord> =
                jobs.into_iter().filter(|j| j.status == JobStatus::Queued).collect();
            stats.queued_seen = candidates.len() as u64;
            if let Some(order) = order {
                order(&mut candidates);
            }
            for candidate in candidates {
                match self.try_claim_one(candidate.id)? {
                    ClaimAttempt::Won(job) => return Ok((Some(job), stats)),
                    ClaimAttempt::Contended => {
                        contended = true;
                        stats.conflicts += 1;
                    }
                    ClaimAttempt::Gone => {}
                }
            }
            if !contended {
                break; // genuinely nothing claimable — don't spin
            }
        }
        Ok((None, stats))
    }

    /// One rename-locked claim attempt on job `id`.
    fn try_claim_one(&self, id: u64) -> Result<ClaimAttempt> {
        let path = self.path_of(id);
        // the hold's name carries the claim stamp, atomically with the
        // locking rename itself — a racing sweep always sees this hold
        // as fresh, never the canonical file's submit-time mtime
        let hold = self.hold_path(id);
        if fs::rename(&path, &hold).is_err() {
            return Ok(ClaimAttempt::Contended); // another claimer won
        }
        // the rename is the lock; the held file is authoritative
        let text = match fs::read_to_string(&hold) {
            Ok(text) => text,
            // hold vanished: a recovering peer swept it back; retry
            Err(_) => return Ok(ClaimAttempt::Contended),
        };
        let mut job = match Json::parse(&text).and_then(|j| JobRecord::from_json(&j)) {
            Ok(job) => job,
            Err(e) => {
                let _ = fs::rename(&hold, &path);
                return Err(e);
            }
        };
        if job.status != JobStatus::Queued {
            fs::rename(&hold, &path)?;
            return Ok(ClaimAttempt::Gone); // finished/requeued under us
        }
        job.status = JobStatus::Running;
        let claim_instant = now_millis();
        job.stamp_ms = claim_instant;
        job.claimed_ms = Some(claim_instant);
        // every claim commit consumes one attempt — the dead-letter
        // gate counts leases handed out, not just recorded errors, so
        // a worker that dies holding the lease still burned one
        job.attempts += 1;
        // commit by renames only: the Running record lands in the
        // hold atomically (temp+rename), then the hold moves back
        // to the canonical path, consuming it. After the commit no
        // hold exists, so a stale-claim sweep can never resurrect
        // the Queued copy over a committed Running record. (A
        // sweep racing the *middle* of this claim can re-queue the
        // job and at worst run it twice — the documented recovery
        // tradeoff; it can no longer corrupt or lose state.)
        self.persist_at(&job, &hold)?;
        if fs::rename(&hold, &path).is_err() {
            // a recovering peer swept the hold (carrying our fresh
            // Running record) to the canonical path between the two
            // renames — nobody would execute it, so put the job
            // back in the queue instead of stranding it `running`.
            // Forced: the swept record says `running` and is fresh,
            // which the operator-facing age gate would refuse.
            let _ = self.requeue_with(job.id, Duration::ZERO, true);
            return Ok(ClaimAttempt::Contended);
        }
        Ok(ClaimAttempt::Won(job))
    }

    /// Fault-injection hook for crash-recovery tests and the worker
    /// pool's death simulation: perform ONLY the first half of a claim
    /// — the rename that takes the hold — then abandon it. This leaves
    /// exactly the on-disk state a worker leaves when it dies mid-claim
    /// (a `.claim` hold, stamped at the claim instant), which only
    /// [`Self::sweep_stale`] can recover. Returns the held job's id.
    pub fn claim_abandon(&self) -> Result<Option<u64>> {
        for candidate in self.list()? {
            if candidate.status != JobStatus::Queued {
                continue;
            }
            let path = self.path_of(candidate.id);
            // the stamped name marks the claim instant, like a real claim
            let hold = self.hold_path(candidate.id);
            if fs::rename(&path, &hold).is_err() {
                continue;
            }
            return Ok(Some(candidate.id));
        }
        Ok(None)
    }

    /// Claim holds currently present (any stamp) — a cheap name scan,
    /// no record parsing. Held jobs may return via
    /// [`Self::sweep_stale`] once they age out, so a worker pool keeps
    /// polling while any exist.
    pub fn held_count(&self) -> Result<usize> {
        let mut held = 0;
        for entry in fs::read_dir(&self.dir)? {
            if entry?.file_name().to_string_lossy().contains(".json.claim") {
                held += 1;
            }
        }
        Ok(held)
    }

    /// `(queued, held)` spool counts: queued jobs are claimable now;
    /// held jobs may come back via the stale sweep, so nothing is
    /// finished-for-good until BOTH are zero. (Parses every record —
    /// the pool's hot idle path avoids this via
    /// [`ClaimStats::queued_seen`] + [`Self::held_count`].)
    pub fn pending(&self) -> Result<(usize, usize)> {
        let queued =
            self.list()?.iter().filter(|j| j.status == JobStatus::Queued).count();
        Ok((queued, self.held_count()?))
    }

    /// Record an execution outcome for a claimed job; returns the
    /// record exactly as persisted (callers should use it rather than
    /// re-reading the spool, which a concurrent `mare requeue` may
    /// have already rewritten).
    pub fn finish(
        &self,
        mut job: JobRecord,
        status: JobStatus,
        result: JobResult,
    ) -> Result<JobRecord> {
        job.status = status;
        job.stamp_ms = now_millis();
        // a failed execution is one recorded failure context — the
        // dead-letter queue's evidence trail accumulates here
        if status == JobStatus::Failed {
            job.failures.push(JobFailure {
                at_ms: job.stamp_ms,
                worker: result.driver.clone(),
                detail: result.detail.clone(),
            });
        }
        job.result = Some(result);
        self.write(&job)?;
        Ok(job)
    }

    /// Put a job back in the queue, clearing any recorded result — the
    /// operator's recovery path (`mare requeue <id>`) for jobs stuck
    /// `running` after their worker died post-claim, and for re-running
    /// `failed`/`done` jobs. A `running` record younger than
    /// [`STALE_CLAIM`] is presumed to belong to a live worker and is
    /// refused (requeueing it would make a second worker execute the
    /// job concurrently); see [`Self::requeue_with`] to tune or force.
    pub fn requeue(&self, id: u64) -> Result<JobRecord> {
        self.requeue_with(id, STALE_CLAIM, false)
    }

    /// [`Self::requeue`] with an explicit liveness threshold. The
    /// rewrite is rename-locked like a claim (the canonical file moves
    /// to the `.claim` hold for the read-modify-write), so a requeue
    /// can never interleave with a claim's own read-modify-write: one
    /// of the two renames loses and reports contention instead of both
    /// writing. `force` skips the liveness gate — the operator insisting
    /// the claiming worker is dead, accepting a double execution if not.
    pub fn requeue_with(&self, id: u64, min_age: Duration, force: bool) -> Result<JobRecord> {
        self.requeue_noting(id, min_age, force, None)
    }

    /// [`Self::requeue_with`] that also appends a failure context to the
    /// record's history — how a supervisor recovering a dead worker's
    /// orphan charges the death against the job's attempt budget. The
    /// existing attempt counter and failure history always survive the
    /// requeue (only the fields a requeue owns are rewritten).
    pub fn requeue_noting(
        &self,
        id: u64,
        min_age: Duration,
        force: bool,
        note: Option<JobFailure>,
    ) -> Result<JobRecord> {
        let path = self.path_of(id);
        // stamped name: a racing sweep sees OUR hold as fresh (see
        // hold_path), while the held file keeps the record's mtime
        let hold = self.hold_path(id);
        if fs::rename(&path, &hold).is_err() {
            return Err(if self.has_hold(id)? {
                MareError::Submit(format!(
                    "job {id} is mid-claim by a worker right now — retry in a moment"
                ))
            } else {
                MareError::Submit(format!(
                    "job {id}: not found in spool {}",
                    self.dir.display()
                ))
            });
        }
        // the record's age, measured UNDER the lock from the held
        // file's mtime (the rename preserved it): for a `running`
        // record this is the time since the claim committed. A claim
        // sliding in just before our rename already refreshed it, so
        // it cannot be mistaken for a stale record.
        let age = fs::metadata(&hold)
            .and_then(|m| m.modified())
            .ok()
            .and_then(|t| t.elapsed().ok());
        let text = match fs::read_to_string(&hold) {
            Ok(text) => text,
            Err(_) => {
                // a sweeper raced us and already returned the job
                return Err(MareError::Submit(format!(
                    "job {id} was swept back to the queue concurrently — retry"
                )));
            }
        };
        let mut job = match Json::parse(&text).and_then(|j| JobRecord::from_json(&j)) {
            Ok(job) => job,
            Err(e) => {
                let _ = fs::rename(&hold, &path);
                return Err(e);
            }
        };
        // liveness gate, checked under the lock
        if job.status == JobStatus::Running
            && !force
            && age.map(|a| a < min_age).unwrap_or(true)
        {
            // restore — unless the claiming worker's `finish` (which is
            // not rename-locked; it owns the job) landed a newer record
            // on the canonical path while we held the lock. hard_link
            // is the atomic no-clobber restore: it fails if a record
            // exists, and then the newer result must be kept, not
            // overwritten by our stale `running` copy. It also keeps
            // the original commit mtime, so operator retries watch the
            // age GROW toward the gate instead of resetting it.
            if fs::hard_link(&hold, &path).is_ok() || path.exists() {
                let _ = fs::remove_file(&hold);
            } else {
                // filesystem without hard links (exFAT, some network
                // mounts): fall back to a plain rename. The no-clobber
                // guarantee narrows to a window, but deleting the
                // job's only record would be strictly worse.
                let _ = fs::rename(&hold, &path);
            }
            return Err(MareError::Submit(format!(
                "job {id} is running and its record is fresh — the claiming worker is \
                 presumed alive, and requeueing now would execute the job twice; retry \
                 once the record is {}s old, or force the requeue",
                min_age.as_secs()
            )));
        }
        job.status = JobStatus::Queued;
        job.result = None;
        job.stamp_ms = now_millis();
        job.claimed_ms = None;
        job.claim_seq = None;
        if let Some(note) = note {
            job.failures.push(note);
        }
        self.persist_at(&job, &hold)?;
        // consume the hold; if a sweeper beat us to this rename, it
        // moved our committed Queued copy to the canonical path itself,
        // so the requeue still landed
        let _ = fs::rename(&hold, &path);
        Ok(job)
    }

    // ------------------------------------------------- dead-letter queue

    /// The dead-letter spool: a `dlq/` subdirectory of the queue, same
    /// one-JSON-file-per-job layout. A job lands here when its attempt
    /// counter reaches the service's `max_attempts` budget; it leaves
    /// only via [`Self::dlq_retry`].
    pub fn dlq_dir(&self) -> PathBuf {
        self.dir.join("dlq")
    }

    fn dlq_path(&self, id: u64) -> PathBuf {
        self.dlq_dir().join(format!("job-{id:06}.json"))
    }

    /// Where a job's stage checkpoints live (see
    /// `storage::checkpoint::CheckpointStore` — the layout is shared so
    /// the queue can drop a job's checkpoint state when the job leaves
    /// the live spool).
    pub fn checkpoint_dir(&self) -> PathBuf {
        self.dir.join("checkpoints")
    }

    fn clear_checkpoints(&self, id: u64) {
        let _ = fs::remove_dir_all(self.checkpoint_dir().join(format!("job-{id:06}")));
    }

    /// Move an exhausted job out of the live spool into `dlq/`, via the
    /// same rename-locked protocol as a claim: the canonical file moves
    /// to a stamped hold (one winner), is verified not to be mid-flight
    /// `running`, then renames into the dead-letter spool. The record's
    /// BYTES are untouched — dead-lettering is purely a relocation, so
    /// the attempt counter and failure history arrive exactly as the
    /// last transition persisted them. A crash between the two renames
    /// leaves only the hold, which the ordinary stale sweep returns to
    /// the live spool — the job is dead-lettered again on the next
    /// sweep, never lost and never duplicated.
    pub fn dead_letter(&self, id: u64) -> Result<JobRecord> {
        let path = self.path_of(id);
        let hold = self.hold_path(id);
        if fs::rename(&path, &hold).is_err() {
            return Err(MareError::Submit(format!(
                "job {id}: not movable to the dead-letter queue right now (claimed, \
                 already dead-lettered, or not in spool {})",
                self.dir.display()
            )));
        }
        let text = match fs::read_to_string(&hold) {
            Ok(text) => text,
            Err(_) => {
                return Err(MareError::Submit(format!(
                    "job {id} was swept back to the queue concurrently — retry"
                )))
            }
        };
        let job = match Json::parse(&text).and_then(|j| JobRecord::from_json(&j)) {
            Ok(job) => job,
            Err(e) => {
                let _ = fs::rename(&hold, &path);
                return Err(e);
            }
        };
        if job.status == JobStatus::Running {
            let _ = fs::rename(&hold, &path);
            return Err(MareError::Submit(format!(
                "job {id} is running — requeue it before dead-lettering"
            )));
        }
        fs::create_dir_all(self.dlq_dir())?;
        fs::rename(&hold, self.dlq_path(id))?;
        self.clear_checkpoints(id);
        Ok(job)
    }

    /// All dead-lettered jobs, sorted by id.
    pub fn dlq_list(&self) -> Result<Vec<JobRecord>> {
        if !self.dlq_dir().exists() {
            return Ok(Vec::new());
        }
        let mut jobs = Vec::new();
        for entry in fs::read_dir(self.dlq_dir())? {
            let entry = entry?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if !(name.starts_with("job-") && name.ends_with(".json")) {
                continue;
            }
            let text = match fs::read_to_string(entry.path()) {
                Ok(text) => text,
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => continue,
                Err(e) => return Err(e.into()),
            };
            let json = Json::parse(&text)
                .map_err(|e| MareError::Submit(format!("dlq file {name}: {e}")))?;
            jobs.push(JobRecord::from_json(&json)?);
        }
        jobs.sort_by_key(|j| j.id);
        Ok(jobs)
    }

    pub fn dlq_get(&self, id: u64) -> Result<JobRecord> {
        let text = fs::read_to_string(self.dlq_path(id))
            .map_err(|e| MareError::Submit(format!("dlq job {id}: {e}")))?;
        let json = Json::parse(&text)?;
        JobRecord::from_json(&json)
    }

    /// Send a dead-lettered job back to the live spool with a fresh
    /// lease: status `queued`, result cleared, attempt counter reset to
    /// zero (the operator explicitly granted a new budget). The failure
    /// HISTORY is preserved — a redriven job keeps its evidence trail.
    /// Rename-locked like every other transition: the dlq file moves to
    /// a hold in the live spool, the rewrite lands in the hold, and the
    /// final rename publishes it; a crash mid-way leaves a hold the
    /// stale sweep returns to the live spool.
    pub fn dlq_retry(&self, id: u64) -> Result<JobRecord> {
        let hold = self.hold_path(id);
        if fs::rename(self.dlq_path(id), &hold).is_err() {
            return Err(MareError::Submit(format!(
                "job {id}: not in the dead-letter queue of spool {}",
                self.dir.display()
            )));
        }
        let text = fs::read_to_string(&hold)?;
        let mut job = Json::parse(&text).and_then(|j| JobRecord::from_json(&j))?;
        job.status = JobStatus::Queued;
        job.result = None;
        job.stamp_ms = now_millis();
        job.claimed_ms = None;
        job.claim_seq = None;
        job.attempts = 0;
        self.persist_at(&job, &hold)?;
        let _ = fs::rename(&hold, self.path_of(id));
        Ok(job)
    }
}

/// Compact state age for operator tables: how long ago `stamp_ms`
/// happened, as seen from `now_ms`. Pre-serve spool files carry no
/// stamp (0) and render as `-`; so does a stamp from the future (clock
/// skew between submitting hosts must not render as a huge age).
pub fn fmt_age(now_ms: u64, stamp_ms: u64) -> String {
    if stamp_ms == 0 || stamp_ms > now_ms {
        return "-".to_string();
    }
    let s = (now_ms - stamp_ms) / 1000;
    if s < 1 {
        "<1s".to_string()
    } else if s < 120 {
        format!("{s}s")
    } else if s < 120 * 60 {
        format!("{}m", s / 60)
    } else if s < 48 * 3600 {
        format!("{}h", s / 3600)
    } else {
        format!("{}d", s / 86400)
    }
}

/// The `mare jobs` table: one row per job with its state AGE (time
/// since the last state transition — a `running` row that keeps aging
/// is a stuck job, the thing this view exists to surface) and tenant.
/// Failed rows carry their error detail on an indented follow-up line.
pub fn render_jobs_table(jobs: &[JobRecord], now_ms: u64) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:>6}  {:<8}{:>6}  {:<10}{:>9}  {}\n",
        "ID", "STATUS", "AGE", "TENANT", "LAUNCHES", "PLAN"
    ));
    for job in jobs {
        let launches =
            job.result.as_ref().map(|r| r.launches.to_string()).unwrap_or_else(|| "-".into());
        out.push_str(&format!(
            "{:>6}  {:<8}{:>6}  {:<10}{:>9}  {}\n",
            job.id,
            job.status.name(),
            fmt_age(now_ms, job.stamp_ms),
            job.tenant,
            launches,
            job.summary
        ));
        if let Some(r) = &job.result {
            if r.detail != "ok" {
                out.push_str(&format!("{:>6}  {}\n", "", r.detail));
            }
        }
    }
    out
}

/// Tenant scoping for `mare jobs --tenant <t>`: `None` keeps every job.
pub fn filter_tenant(jobs: Vec<JobRecord>, tenant: Option<&str>) -> Vec<JobRecord> {
    match tenant {
        None => jobs,
        Some(t) => jobs.into_iter().filter(|j| j.tenant == t).collect(),
    }
}

/// The `mare dlq list` table: attempt budget spent and the most recent
/// failure context per dead-lettered job (the full history is one
/// `mare dlq show <id>` away).
pub fn render_dlq_table(jobs: &[JobRecord], now_ms: u64) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:>6}  {:>8}{:>6}  {:<10}{}\n",
        "ID", "ATTEMPTS", "AGE", "TENANT", "LAST FAILURE"
    ));
    for job in jobs {
        let last = job
            .failures
            .last()
            .map(|f| format!("{}: {}", f.worker, f.detail))
            .unwrap_or_else(|| "-".into());
        out.push_str(&format!(
            "{:>6}  {:>8}{:>6}  {:<10}{}\n",
            job.id,
            job.attempts,
            fmt_age(now_ms, job.stamp_ms),
            job.tenant,
            last
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_queue(name: &str) -> JobQueue {
        let dir = std::env::temp_dir()
            .join(format!("mare-queue-test-{}-{name}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        JobQueue::open(dir).unwrap()
    }

    fn plan() -> Json {
        Json::parse(
            r#"{"version": 1, "ops": [
                {"op": "ingest", "label": "gen:gc:8", "partitions": 2},
                {"op": "collect"}]}"#,
        )
        .unwrap()
    }

    #[test]
    fn submit_list_claim_finish_lifecycle() {
        let q = tmp_queue("lifecycle");
        assert!(q.list().unwrap().is_empty());
        assert!(q.claim().unwrap().is_none());

        let a = q.submit(plan(), "a".into()).unwrap();
        let b = q.submit(plan(), "b".into()).unwrap();
        assert_eq!((a, b), (1, 2));
        assert_eq!(q.list().unwrap().len(), 2);

        // FIFO claim flips queued -> running, persistently
        let claimed = q.claim().unwrap().unwrap();
        assert_eq!(claimed.id, 1);
        assert_eq!(q.get(1).unwrap().status, JobStatus::Running);
        assert_eq!(q.claim().unwrap().unwrap().id, 2);
        assert!(q.claim().unwrap().is_none());

        q.finish(
            claimed,
            JobStatus::Done,
            JobResult { driver: "d0".into(), launches: 4, records: 1, detail: "ok".into() },
        )
        .unwrap();
        let done = q.get(1).unwrap();
        assert_eq!(done.status, JobStatus::Done);
        let r = done.result.unwrap();
        assert_eq!((r.launches, r.records), (4, 1));
        assert_eq!(r.driver, "d0");

        // ids keep increasing past finished jobs
        assert_eq!(q.submit(plan(), "c".into()).unwrap(), 3);

        // requeue clears the result and makes the job claimable again
        let requeued = q.requeue(1).unwrap();
        assert_eq!(requeued.status, JobStatus::Queued);
        assert!(requeued.result.is_none());
        assert_eq!(q.claim().unwrap().unwrap().id, 1);
    }

    #[test]
    fn stale_claims_recover_and_held_ids_are_not_reused() {
        let q = tmp_queue("recover");
        let id = q.submit(plan(), "a".into()).unwrap();
        // simulate a worker that died mid-claim: the job sits in a hold
        let path = q.dir().join(format!("job-{id:06}.json"));
        let hold = q.dir().join(format!("job-{id:06}.json.claim"));
        fs::rename(&path, &hold).unwrap();
        assert!(q.list().unwrap().is_empty());
        // the held id stays reserved — a concurrent submit cannot take
        // it and have the claimer's write clobber the new job
        assert_eq!(q.submit(plan(), "b".into()).unwrap(), id + 1);
        // a fresh open() leaves FRESH holds alone (they may belong to a
        // live claim in another process)...
        let q2 = JobQueue::open(q.dir().to_path_buf()).unwrap();
        assert_eq!(q2.list().unwrap().len(), 1);
        // ...but once a hold has aged past any live claim, the sweep
        // returns the job to the queue
        assert_eq!(q2.sweep_stale(Duration::ZERO).unwrap(), 1);
        let jobs = q2.list().unwrap();
        assert_eq!(jobs.len(), 2);
        assert_eq!((jobs[0].id, jobs[0].status), (id, JobStatus::Queued));
        assert_eq!(q2.claim().unwrap().unwrap().id, id);
    }

    /// Regression (ISSUE 4 satellite): stale holds used to be swept only
    /// at `open()` — a pool whose worker died mid-run leaked the job
    /// until the next process start. `sweep_stale` is callable mid-run.
    #[test]
    fn sweep_stale_recovers_abandoned_holds_without_reopening() {
        let q = tmp_queue("midrun-sweep");
        let a = q.submit(plan(), "a".into()).unwrap();
        let b = q.submit(plan(), "b".into()).unwrap();

        // a worker dies mid-claim: hold taken, never committed
        assert_eq!(q.claim_abandon().unwrap(), Some(a));
        assert_eq!(q.pending().unwrap(), (1, 1));
        // the held job is invisible to claims...
        assert_eq!(q.claim().unwrap().unwrap().id, b);
        assert!(q.claim().unwrap().is_none());

        // ...a fresh hold survives an age-gated sweep (live claims must
        // never be yanked)...
        assert_eq!(q.sweep_stale(STALE_CLAIM).unwrap(), 0);
        // ...and the SAME open queue recovers it once it ages out
        assert_eq!(q.sweep_stale(Duration::ZERO).unwrap(), 1);
        assert_eq!(q.pending().unwrap(), (1, 0));
        assert_eq!(q.claim().unwrap().unwrap().id, a);
    }

    #[test]
    fn requeue_refuses_fresh_running_records_unless_forced() {
        let q = tmp_queue("requeue-gate");
        let id = q.submit(plan(), "a".into()).unwrap();
        let job = q.claim().unwrap().unwrap();
        assert_eq!(job.id, id);

        // freshly `running` = presumed live: the age-gated requeue
        // refuses rather than risking a double execution
        let err = q.requeue(id).unwrap_err().to_string();
        assert!(err.contains("presumed alive"), "{err}");
        assert_eq!(q.get(id).unwrap().status, JobStatus::Running);

        // a zero threshold treats any running record as dead…
        assert_eq!(q.requeue_with(id, Duration::ZERO, false).unwrap().status, JobStatus::Queued);
        // …and force skips the gate entirely
        let job = q.claim().unwrap().unwrap();
        assert_eq!(job.id, id);
        assert_eq!(q.requeue_with(id, STALE_CLAIM, true).unwrap().status, JobStatus::Queued);

        // done/failed jobs requeue freely (intentional re-runs)
        let job = q.claim().unwrap().unwrap();
        let done = q
            .finish(
                job,
                JobStatus::Done,
                JobResult { driver: "d".into(), launches: 1, records: 1, detail: "ok".into() },
            )
            .unwrap();
        assert_eq!(done.status, JobStatus::Done);
        assert_eq!(q.requeue(id).unwrap().status, JobStatus::Queued);

        // unknown ids get a spool-specific error, not a claim hint
        let err = q.requeue(99).unwrap_err().to_string();
        assert!(err.contains("not found in spool"), "{err}");
    }

    #[test]
    fn claim_stats_report_contention_shape() {
        let q = tmp_queue("claim-stats");
        // empty queue: no candidates, no conflicts, no backoffs
        let (job, stats) = q.claim_with_stats().unwrap();
        assert!(job.is_none());
        assert_eq!(stats, ClaimStats::default());

        // a clean single-claim run sees no contention either, and the
        // scan reports the candidate it observed
        q.submit(plan(), "a".into()).unwrap();
        let (job, stats) = q.claim_with_stats().unwrap();
        assert!(job.is_some());
        assert_eq!(stats.conflicts, 0);
        assert_eq!(stats.queued_seen, 1);

        // drained again: nothing in sight (what a pool's idle loop
        // combines with held_count() to decide termination)
        let (job, stats) = q.claim_with_stats().unwrap();
        assert!(job.is_none());
        assert_eq!(stats.queued_seen, 0);
        assert_eq!(q.held_count().unwrap(), 0);
    }

    /// The claim-scan index: unchanged spool records are served from
    /// the cache (one `stat`, no parse); records rewritten by ANY
    /// writer — including a different queue instance on the same spool
    /// — are re-parsed by identity change; a drained idle scan parses
    /// nothing at all.
    #[test]
    fn claim_scan_index_reparses_only_changed_records() {
        let q = tmp_queue("scan-index");
        for i in 0..6 {
            q.submit(plan(), format!("j{i}")).unwrap();
        }

        // cold index: the first scan parses the whole spool
        let (job, stats) = q.claim_with_stats().unwrap();
        assert_eq!(job.unwrap().id, 1);
        assert_eq!(stats.parsed, 6, "cold scan parses everything");

        // each later claim re-parses exactly the ONE record the
        // previous claim rewrote (queued -> running, fresh inode)
        for id in 2..=6u64 {
            let (job, stats) = q.claim_with_stats().unwrap();
            assert_eq!(job.unwrap().id, id);
            assert_eq!(stats.parsed, 1, "claim {id} re-parsed more than the last rewrite");
        }

        // drain tail: the 6th claim's rewrite costs one last parse,
        // then the idle loop stats 6 running records and parses none
        let (job, stats) = q.claim_with_stats().unwrap();
        assert!(job.is_none());
        assert_eq!(stats.parsed, 1);
        let (job, stats) = q.claim_with_stats().unwrap();
        assert!(job.is_none());
        assert_eq!((stats.parsed, stats.queued_seen), (0, 0));

        // a SECOND instance on the same spool is a foreign writer: its
        // rewrite lands on a fresh inode and invalidates our entry
        let q2 = JobQueue::open(q.dir().to_path_buf()).unwrap();
        q2.requeue_with(3, Duration::ZERO, true).unwrap();
        let (job, stats) = q.claim_with_stats().unwrap();
        assert_eq!(job.unwrap().id, 3);
        assert_eq!(stats.parsed, 1, "only the foreign rewrite re-parses");
        // both instances agree on the spool contents throughout
        assert_eq!(q.list().unwrap().len(), q2.list().unwrap().len());
    }

    #[test]
    fn spool_files_roundtrip_through_json() {
        let rec = JobRecord {
            id: 7,
            status: JobStatus::Failed,
            summary: "ingest -> collect".into(),
            tenant: "alpha".into(),
            priority: -2,
            stamp_ms: 1_700_000_000_123,
            claimed_ms: Some(1_700_000_000_100),
            claim_seq: Some(41),
            attempts: 2,
            failures: vec![JobFailure {
                at_ms: 1_700_000_000_050,
                worker: "driver-0".into(),
                detail: "container: image not found".into(),
            }],
            plan: plan(),
            result: Some(JobResult {
                driver: "driver-1".into(),
                launches: 0,
                records: 0,
                detail: "container: image not found".into(),
            }),
        };
        let back = JobRecord::from_json(&rec.to_json()).unwrap();
        assert_eq!(back.id, 7);
        assert_eq!(back.status, JobStatus::Failed);
        assert_eq!(back.plan, rec.plan);
        assert_eq!(back.result.unwrap().detail, "container: image not found");
        assert_eq!(back.tenant, "alpha");
        assert_eq!(back.priority, -2);
        assert_eq!(back.stamp_ms, 1_700_000_000_123);
        assert_eq!(back.claimed_ms, Some(1_700_000_000_100));
        assert_eq!(back.claim_seq, Some(41));
        assert_eq!(back.attempts, 2);
        assert_eq!(back.failures, rec.failures);

        assert!(JobStatus::parse("zombie").is_err());
        for s in [JobStatus::Queued, JobStatus::Running, JobStatus::Done, JobStatus::Failed] {
            assert_eq!(JobStatus::parse(s.name()).unwrap(), s);
        }
    }

    /// Spool files written before the serve subsystem carry none of the
    /// scheduling fields — they must read back with the documented
    /// defaults, not an error (the same unknown/absent-field tolerance
    /// the wire envelope guarantees).
    #[test]
    fn legacy_spool_files_read_back_with_default_scheduling_fields() {
        let legacy = Json::parse(
            r#"{"id": 3, "status": "queued", "summary": "ingest -> collect",
                "plan": {"version": 1, "ops": []}, "result": null}"#,
        )
        .unwrap();
        let rec = JobRecord::from_json(&legacy).unwrap();
        assert_eq!(rec.tenant, crate::mare::wire::DEFAULT_TENANT);
        assert_eq!(rec.priority, 0);
        assert_eq!(rec.stamp_ms, 0);
        assert_eq!(rec.claimed_ms, None);
        assert_eq!(rec.claim_seq, None);
        assert_eq!(rec.attempts, 0);
        assert!(rec.failures.is_empty());
        // absent-means-zero both ways: re-encoding a legacy record does
        // not materialize empty attempt fields
        let encoded = rec.to_json();
        assert!(encoded.get("attempts").is_none(), "{encoded}");
        assert!(encoded.get("failures").is_none(), "{encoded}");
    }

    #[test]
    fn claims_stamp_transitions_and_requeue_clears_them() {
        let q = tmp_queue("stamps");
        let before = now_millis();
        let id = q.submit(plan(), "a".into()).unwrap();
        let queued = q.get(id).unwrap();
        assert!(queued.stamp_ms >= before, "submit stamps the record");
        assert_eq!(queued.claimed_ms, None);

        let job = q.claim().unwrap().unwrap();
        assert_eq!(job.claimed_ms, Some(job.stamp_ms));
        assert!(job.stamp_ms >= queued.stamp_ms);
        // the claim stamp is persisted, not just in-memory
        assert_eq!(q.get(id).unwrap().claimed_ms, job.claimed_ms);

        let done = q
            .finish(
                job,
                JobStatus::Done,
                JobResult { driver: "d".into(), launches: 1, records: 1, detail: "ok".into() },
            )
            .unwrap();
        // finish preserves the claim stamp (audit trail) and restamps
        assert!(done.claimed_ms.is_some());
        assert!(done.stamp_ms >= done.claimed_ms.unwrap());

        let requeued = q.requeue(id).unwrap();
        assert_eq!(requeued.claimed_ms, None);
        assert_eq!(requeued.claim_seq, None);
    }

    /// The policy seam: an ordering callback decides which queued
    /// candidate a claim takes first; `None` stays FIFO by id.
    #[test]
    fn ordered_claims_follow_the_policy_fifo_otherwise() {
        let q = tmp_queue("ordered-claims");
        for (tenant, priority) in [("bulk", 0), ("bulk", 0), ("urgent", 5)] {
            q.submit_meta(plan(), tenant.to_string(), tenant, priority).unwrap();
        }

        // policy: highest priority first, id as tie-break
        let by_priority: &(dyn Fn(&mut Vec<JobRecord>) + Sync) =
            &|c: &mut Vec<JobRecord>| c.sort_by_key(|j| (std::cmp::Reverse(j.priority), j.id));
        let (job, _) = q.claim_with_stats_ordered(Some(by_priority)).unwrap();
        let job = job.unwrap();
        assert_eq!((job.tenant.as_str(), job.id), ("urgent", 3));

        // un-ordered claims keep the FIFO contract
        assert_eq!(q.claim().unwrap().unwrap().id, 1);
        assert_eq!(q.claim_with_stats_ordered(None).unwrap().0.unwrap().id, 2);
    }

    #[test]
    fn jobs_table_renders_age_tenant_and_error_detail() {
        let now = 1_700_000_100_000; // stamps below are relative to this
        let mk = |id, status, tenant: &str, stamp_ms, result| JobRecord {
            id,
            status,
            summary: "ingest[gen:gc:8] -> collect".into(),
            tenant: tenant.into(),
            priority: 0,
            stamp_ms,
            claimed_ms: None,
            claim_seq: None,
            attempts: 0,
            failures: Vec::new(),
            plan: plan(),
            result,
        };
        let jobs = vec![
            mk(1, JobStatus::Done, "alpha", now - 4_000, Some(JobResult {
                driver: "d0".into(),
                launches: 6,
                records: 2,
                detail: "ok".into(),
            })),
            mk(2, JobStatus::Running, "beta", now - 150_000, None),
            mk(3, JobStatus::Failed, "default", 0, Some(JobResult {
                driver: "d1".into(),
                launches: 0,
                records: 0,
                detail: "container: image not found".into(),
            })),
        ];
        let table = render_jobs_table(&jobs, now);
        let lines: Vec<&str> = table.lines().collect();
        assert_eq!(lines.len(), 5, "header + 3 rows + 1 detail line:\n{table}");
        assert!(lines[0].contains("AGE") && lines[0].contains("TENANT"), "{table}");
        // done row: age and tenant and launches
        assert!(lines[1].contains(" 4s") && lines[1].contains("alpha"), "{table}");
        assert!(lines[1].contains("6"), "{table}");
        // the stuck-running row ages in minutes — the operator's cue
        assert!(lines[2].contains(" 2m") && lines[2].contains("beta"), "{table}");
        // legacy record (no stamp) renders "-", not a bogus epoch age
        assert!(lines[3].contains(" -") && lines[3].contains("default"), "{table}");
        assert!(lines[4].contains("image not found"), "{table}");

        assert_eq!(fmt_age(now, now), "<1s");
        assert_eq!(fmt_age(now, now - 90 * 60 * 1000), "90m");
        assert_eq!(fmt_age(now, now - 3 * 86_400_000), "3d");
        assert_eq!(fmt_age(now, now + 5_000), "-", "future stamps (clock skew) render '-'");
    }

    /// `mare jobs --tenant` is a pure view: filtering then rendering
    /// shows exactly the tenant's rows, with the same columns as the
    /// unfiltered table.
    #[test]
    fn jobs_table_filters_by_tenant() {
        let q = tmp_queue("tenant-filter");
        for tenant in ["alpha", "beta", "alpha"] {
            q.submit_meta(plan(), format!("{tenant} job"), tenant, 0).unwrap();
        }
        let all = q.list().unwrap();
        assert_eq!(filter_tenant(all.clone(), None).len(), 3);
        let alpha = filter_tenant(all.clone(), Some("alpha"));
        assert_eq!(alpha.iter().map(|j| j.id).collect::<Vec<_>>(), vec![1, 3]);
        assert!(filter_tenant(all, Some("nobody")).is_empty());

        let table = render_jobs_table(&alpha, now_millis());
        let lines: Vec<&str> = table.lines().collect();
        assert_eq!(lines.len(), 3, "header + 2 alpha rows:\n{table}");
        assert!(lines[1].contains("alpha") && lines[2].contains("alpha"), "{table}");
        assert!(!table.contains("beta"), "{table}");
    }

    /// Claims consume attempts; failures accumulate context; requeues
    /// preserve both; `dead_letter` is a pure relocation and
    /// `dlq_retry` grants a fresh lease (counter reset, history kept).
    #[test]
    fn attempts_accumulate_and_dead_letter_round_trips() {
        let q = tmp_queue("dlq");
        let id = q.submit(plan(), "poison".into()).unwrap();
        assert_eq!(q.get(id).unwrap().attempts, 0);

        for attempt in 1..=2u64 {
            let job = q.claim().unwrap().unwrap();
            assert_eq!(job.attempts, attempt, "each claim consumes one attempt");
            let failed = q
                .finish(
                    job,
                    JobStatus::Failed,
                    JobResult {
                        driver: format!("d{attempt}"),
                        launches: 0,
                        records: 0,
                        detail: "tool not found: frobnicate".into(),
                    },
                )
                .unwrap();
            assert_eq!(failed.failures.len(), attempt as usize);
            if attempt < 2 {
                let requeued = q.requeue_with(id, Duration::ZERO, false).unwrap();
                // requeue owns status/result/claim stamps — NOT the
                // attempt counter or the failure history
                assert_eq!(requeued.attempts, attempt);
                assert_eq!(requeued.failures.len(), attempt as usize);
            }
        }

        // dead-letter: record relocates byte-identically
        let before = fs::read_to_string(q.path_of(id)).unwrap();
        let dead = q.dead_letter(id).unwrap();
        assert_eq!(dead.attempts, 2);
        assert_eq!(dead.failures.len(), 2);
        assert!(q.get(id).is_err(), "gone from the live spool");
        assert!(q.list().unwrap().is_empty());
        assert_eq!(q.dlq_list().unwrap().len(), 1);
        let after = fs::read_to_string(q.dlq_dir().join(format!("job-{id:06}.json"))).unwrap();
        assert_eq!(before, after, "dead-lettering never rewrites the record");
        assert!(q.dead_letter(id).is_err(), "already dead-lettered");

        // ids stay reserved while the job sits in dlq/
        let next = q.submit(plan(), "later".into()).unwrap();
        assert!(next > id, "dlq ids must not be reused, got {next}");

        // the dlq table shows the budget spent and the last context
        let table = render_dlq_table(&q.dlq_list().unwrap(), now_millis());
        assert!(table.contains("ATTEMPTS"), "{table}");
        assert!(table.contains("frobnicate"), "{table}");

        // retry: fresh lease, history intact, claimable again
        let retried = q.dlq_retry(id).unwrap();
        assert_eq!(retried.status, JobStatus::Queued);
        assert_eq!(retried.attempts, 0);
        assert_eq!(retried.failures.len(), 2);
        assert!(retried.result.is_none());
        assert!(q.dlq_list().unwrap().is_empty());
        assert!(q.dlq_retry(id).is_err(), "no longer in the dlq");
        let claimed = q.claim_with_stats_ordered(None).unwrap().0.unwrap();
        assert_eq!((claimed.id, claimed.attempts), (id, 1));
    }

    #[test]
    fn dead_letter_refuses_running_jobs() {
        let q = tmp_queue("dlq-running");
        let id = q.submit(plan(), "live".into()).unwrap();
        q.claim().unwrap().unwrap();
        let err = q.dead_letter(id).unwrap_err().to_string();
        assert!(err.contains("running"), "{err}");
        assert_eq!(q.get(id).unwrap().status, JobStatus::Running, "restored intact");
    }

    /// An orphan requeue charges the death against the job's budget:
    /// the supervisor's failure note lands in the history and the
    /// claim-time attempt survives.
    #[test]
    fn requeue_noting_appends_the_death_context() {
        let q = tmp_queue("requeue-noting");
        let id = q.submit(plan(), "orphan".into()).unwrap();
        let job = q.claim().unwrap().unwrap();
        assert_eq!(job.attempts, 1);
        let requeued = q
            .requeue_noting(
                id,
                Duration::ZERO,
                true,
                Some(JobFailure {
                    at_ms: now_millis(),
                    worker: "serve-3".into(),
                    detail: "worker died leaving job running".into(),
                }),
            )
            .unwrap();
        assert_eq!(requeued.status, JobStatus::Queued);
        assert_eq!(requeued.attempts, 1);
        assert_eq!(requeued.failures.len(), 1);
        assert!(requeued.failures[0].detail.contains("died"), "{:?}", requeued.failures);
    }
}
