//! Threaded worker pool: N OS threads contending for one shared
//! file-backed [`JobQueue`].
//!
//! [`sim::drain`](super::sim::drain) hands jobs to drivers round-robin
//! from a single thread, which never exercises the spool's rename-locked
//! claims under real contention. The pool does: every worker owns a
//! [`Driver`] (its own cluster, engine and launch counter) and runs a
//! claim → execute → finish loop against the SAME spool directory, so
//! claim races, the mid-run stale-hold sweep and `mare requeue`
//! recovery are hammered the way a multi-node deployment would (the
//! ROADMAP's threaded-contention item; the paper's near-linear scaling
//! claim is only credible if the coordination point survives this).
//!
//! Crash recovery is testable, not just theoretical: a [`FaultPlan`]
//! kills chosen workers at chosen points in the claim protocol —
//! [`DeathMode::MidClaim`] leaves a `.claim` hold that only the
//! age-gated [`JobQueue::sweep_stale`] (called from every idle worker)
//! can recover, [`DeathMode::AfterClaim`] leaves the job stuck
//! `running`, recoverable only by `mare requeue`, and
//! [`DeathMode::MidRun`] kills a worker mid-execution after it has
//! committed stage checkpoints — the successor resumes the job from
//! the last committed boundary instead of starting over. Deaths can
//! target a worker index or (`*`, with a job filter) whichever worker
//! claims a given job, with a fleet-wide fire budget. The headline
//! stress gates over this module live in `rust/tests/pool_stress.rs`
//! and `rust/tests/failure_matrix.rs` and run as dedicated CI jobs.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use crate::cluster::{ClusterConfig, StageCheckpointer};
use crate::error::{MareError, Result};
use crate::storage::{CheckpointStore, KillAfter, MemCheckpoint};

use super::queue::{ClaimOrder, ClaimStats, JobQueue, JobRecord, JobResult, JobStatus, STALE_CLAIM};
use super::sim::Driver;

/// Observation + policy seam a resident scheduler (`mare serve`) plugs
/// into the worker loop. Every method has a no-op default, so a hooks
/// impl states only what it cares about; everything is called from
/// worker threads and must be `Sync`.
///
/// The seam is deliberately thin: hooks ORDER claims, OBSERVE
/// progress, and VETO further claiming (drain) — they never touch the
/// spool protocol itself, so exactly-once still rests entirely on the
/// queue's rename locking no matter what a hooks impl does.
pub trait ServeHooks: Sync {
    /// Reorder one claim scan's queued candidates (front claims first).
    fn order(&self, _candidates: &mut Vec<JobRecord>) {}
    /// A claim committed: the job just moved `running` in this worker.
    /// The record is the worker's in-memory copy — mutations (e.g.
    /// stamping a claim sequence number) persist when `finish` writes.
    fn claimed(&self, _worker: usize, _job: &mut JobRecord) {}
    /// A claim scan completed (won or not) with these contention stats.
    fn scanned(&self, _stats: &ClaimStats) {}
    /// A job finished; `record` is exactly what was persisted.
    fn finished(&self, _worker: usize, _record: &JobRecord) {}
    /// An idle sweep returned `count` stale holds to the queue.
    fn swept(&self, _count: u64) {}
    /// Liveness heartbeat, once per loop iteration.
    fn beat(&self, _worker: usize) {}
    /// When true, workers finish in-flight work and exit instead of
    /// claiming more — the drain contract.
    fn draining(&self) -> bool {
        false
    }
    /// A fault-injected death fired. `orphaned_running` carries the job
    /// id left stuck `running` (an [`DeathMode::AfterClaim`] or
    /// [`DeathMode::MidRun`] death) so a supervisor can force-requeue
    /// it; `None` for a mid-claim death, whose hold the ordinary stale
    /// sweep recovers.
    fn died(&self, _worker: usize, _orphaned_running: Option<u64>) {}
    /// A dying worker reports the container launches it committed
    /// before a [`DeathMode::MidRun`] death — real work (it is
    /// checkpointed; a successor will not repeat it) that must reach
    /// the supervisor's ledger even though the job never finished.
    fn progressed(&self, _worker: usize, _launches: u64) {}
}

/// Where in the claim/execute protocol a fault-injected worker dies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeathMode {
    /// Die between the claim's rename and its commit: the `.claim`
    /// hold stays on disk, invisible to claims, recoverable only by
    /// the stale-hold sweep once it ages past the gate.
    MidClaim,
    /// Die right after the claim commits: the job is stuck `running`
    /// with no hold, recoverable only by `mare requeue`.
    AfterClaim,
    /// Die mid-execution, after `after_stages` stage boundaries have
    /// committed to the job's checkpoint store. The job is stuck
    /// `running` like [`DeathMode::AfterClaim`], but real work already
    /// happened — a successor claiming the requeued job resumes from
    /// the checkpoint instead of starting over.
    MidRun { after_stages: u64 },
}

/// One injected death.
///
/// Worker-targeted (`worker: Some(w)`): worker `w` dies on its
/// `nth_claim`-th claim (1-based), optionally only if that claim is of
/// job `job`.
///
/// Wildcard (`worker: None`, requires `job`): WHICHEVER worker claims
/// job `job` dies, and `nth_claim` becomes a fire *budget* — the first
/// `nth_claim` qualifying claims die, later ones survive. This is what
/// makes "kill the job's claimer K times, then watch attempt K+1"
/// deterministic without knowing which worker wins each claim race.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Death {
    pub worker: Option<usize>,
    pub nth_claim: u64,
    pub mode: DeathMode,
    pub job: Option<u64>,
}

/// The pool's injected deaths — empty in production.
///
/// Clones share the wildcard fire budgets (the counters are `Arc`ed),
/// so handing the same plan to N workers still fires each wildcard
/// death at most `nth_claim` times fleet-wide.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    pub deaths: Vec<Death>,
    /// Per-death fire counters, parallel to `deaths` (only wildcard
    /// deaths consume theirs).
    spent: Vec<Arc<AtomicU64>>,
}

impl FaultPlan {
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// Parse a `--fault` CLI spec: comma-separated
    /// `TARGET:N:MODE[:jID]` entries.
    ///
    /// * `TARGET` — a worker index, or `*` for "whichever worker
    ///   qualifies" (wildcard deaths REQUIRE a job filter)
    /// * `N` — the worker's N-th claim (worker-targeted) or the fire
    ///   budget (wildcard)
    /// * `MODE` — `hold` (die mid-claim, leaving a `.claim` hold),
    ///   `running` (die right after the claim commits), or
    ///   `midrun[@S]` (die mid-execution after committing `S` stage
    ///   checkpoints; default 1)
    /// * `jID` — only claims of job ID fire the death. `hold` deaths
    ///   cannot be job-targeted: they happen before the claim commits,
    ///   when the job id is still unknown.
    ///
    /// Examples: `2:3:hold` — worker 2 dies taking its 3rd claim.
    /// `*:2:running:j1` — the first two claimers of job 1 die.
    /// `*:1:midrun@2:j4` — job 4's first claimer dies after
    /// checkpointing two stages.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut deaths = Vec::new();
        for one in spec.split(',') {
            let one = one.trim();
            let err = || {
                MareError::Config(format!(
                    "bad fault `{one}` (want worker|*:N:hold|running|midrun[@S][:jID], \
                     e.g. 2:3:hold or *:2:running:j1)"
                ))
            };
            let parts: Vec<&str> = one.split(':').collect();
            let (w, k, m, j) = match parts.as_slice() {
                [w, k, m] => (*w, *k, *m, None),
                [w, k, m, j] => (*w, *k, *m, Some(*j)),
                _ => return Err(err()),
            };
            let worker = if w == "*" { None } else { Some(w.parse().map_err(|_| err())?) };
            let nth_claim: u64 = k.parse().map_err(|_| err())?;
            if nth_claim == 0 {
                return Err(err());
            }
            let mode = match m {
                "hold" => DeathMode::MidClaim,
                "running" => DeathMode::AfterClaim,
                _ => {
                    let rest = m.strip_prefix("midrun").ok_or_else(err)?;
                    let after_stages = match rest.strip_prefix('@') {
                        Some(n) => n.parse().map_err(|_| err())?,
                        None if rest.is_empty() => 1,
                        None => return Err(err()),
                    };
                    if after_stages == 0 {
                        return Err(err());
                    }
                    DeathMode::MidRun { after_stages }
                }
            };
            let job = match j {
                Some(j) => {
                    Some(j.strip_prefix('j').ok_or_else(err)?.parse().map_err(|_| err())?)
                }
                None => None,
            };
            if worker.is_none() && job.is_none() {
                return Err(MareError::Config(format!(
                    "fault `{one}`: a wildcard death needs a job filter \
                     (`*:N:mode:jID`) — without one it would kill arbitrary \
                     claims until the budget ran out"
                )));
            }
            if mode == DeathMode::MidClaim && job.is_some() {
                return Err(MareError::Config(format!(
                    "fault `{one}`: `hold` deaths fire BEFORE the claim commits, \
                     when the job id is unknown — they cannot be job-targeted"
                )));
            }
            deaths.push(Death { worker, nth_claim, mode, job });
        }
        let spent = deaths.iter().map(|_| Arc::new(AtomicU64::new(0))).collect();
        Ok(FaultPlan { deaths, spent })
    }

    /// Pre-claim deaths (`hold`): only worker-targeted entries — the
    /// job id does not exist yet at this protocol point.
    fn fires_mid_claim(&self, worker: usize, claim_no: u64) -> Option<Death> {
        self.deaths.iter().copied().find(|d| {
            d.mode == DeathMode::MidClaim && d.worker == Some(worker) && d.nth_claim == claim_no
        })
    }

    /// Post-claim deaths (the job is known). Worker-targeted entries
    /// fire on the worker's exact claim number; wildcard entries fire
    /// while their shared budget lasts (one unit consumed per fire).
    fn fires_with_job(
        &self,
        worker: usize,
        claim_no: u64,
        job: u64,
        want: fn(&DeathMode) -> bool,
    ) -> Option<Death> {
        for (i, d) in self.deaths.iter().enumerate() {
            if !want(&d.mode) {
                continue;
            }
            if d.job.is_some_and(|j| j != job) {
                continue;
            }
            match d.worker {
                Some(w) => {
                    if w == worker && d.nth_claim == claim_no {
                        return Some(*d);
                    }
                }
                None => {
                    let budget = d.nth_claim;
                    let won = self.spent[i]
                        .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |s| {
                            (s < budget).then_some(s + 1)
                        })
                        .is_ok();
                    if won {
                        return Some(*d);
                    }
                }
            }
        }
        None
    }

    fn fires_after_claim(&self, worker: usize, claim_no: u64, job: u64) -> Option<Death> {
        self.fires_with_job(worker, claim_no, job, |m| *m == DeathMode::AfterClaim)
    }

    fn fires_mid_run(&self, worker: usize, claim_no: u64, job: u64) -> Option<Death> {
        self.fires_with_job(worker, claim_no, job, |m| matches!(m, DeathMode::MidRun { .. }))
    }
}

/// Worker-pool configuration.
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// OS threads claiming from the shared queue.
    pub workers: usize,
    /// Cluster shape each worker's driver executes on. One shape for
    /// the whole pool: the determinism contract (byte-identical
    /// `Job::explain()`, equal launch counts) is per cluster shape.
    pub cluster: ClusterConfig,
    /// Claim holds older than this are presumed abandoned and swept
    /// back into the queue by idle workers.
    pub stale_after: Duration,
    /// Base idle sleep between empty claim scans; doubles (capped at
    /// 8x) while the queue stays empty-but-pending.
    pub poll: Duration,
    /// Injected worker deaths (crash-recovery testing).
    pub faults: FaultPlan,
    /// Root directory for per-job stage checkpoints (usually the
    /// queue's `checkpoints/` sibling — [`JobQueue::checkpoint_dir`]).
    /// `None` disables checkpointing: jobs always run from scratch and
    /// a mid-run death's partial work is lost.
    pub checkpoints: Option<PathBuf>,
}

impl PoolConfig {
    pub fn new(workers: usize, cluster: ClusterConfig) -> PoolConfig {
        PoolConfig {
            workers,
            cluster,
            stale_after: STALE_CLAIM,
            poll: Duration::from_millis(20),
            faults: FaultPlan::none(),
            checkpoints: None,
        }
    }
}

/// What one worker did with its life.
#[derive(Debug, Clone)]
pub struct PoolReport {
    pub worker: String,
    /// Jobs this worker claimed (committed `running`).
    pub claimed: u64,
    /// Jobs it executed through to `done`/`failed`.
    pub jobs_run: u64,
    /// Container launches across its executed jobs.
    pub launches: u64,
    /// Claim rename races lost to competing workers.
    pub claim_conflicts: u64,
    /// Backoff sleeps its contended claim scans took.
    pub claim_backoffs: u64,
    /// Stale holds it swept back into the queue while idle.
    pub swept: u64,
    /// Set when a [`Death`] killed this worker, describing how.
    pub died: Option<String>,
}

impl PoolReport {
    fn new(worker: String) -> PoolReport {
        PoolReport {
            worker,
            claimed: 0,
            jobs_run: 0,
            launches: 0,
            claim_conflicts: 0,
            claim_backoffs: 0,
            swept: 0,
            died: None,
        }
    }

    /// `pool-3: 7 jobs, 42 launches, 5 conflicts` (+ death note).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{}: {} jobs, {} launches, {} conflicts",
            self.worker, self.jobs_run, self.launches, self.claim_conflicts
        );
        if self.swept > 0 {
            s.push_str(&format!(", swept {}", self.swept));
        }
        if let Some(death) = &self.died {
            s.push_str(&format!(" [{death}]"));
        }
        s
    }
}

/// Everything a pool run produced.
#[derive(Debug)]
pub struct PoolOutcome {
    /// Finished records, id order, exactly as persisted by `finish`.
    pub finished: Vec<JobRecord>,
    /// Per-worker reports, worker-index order.
    pub reports: Vec<PoolReport>,
}

impl PoolOutcome {
    /// Total container launches across every worker — the exactly-once
    /// audit: this equals the sum of per-job single-driver launch
    /// counts iff no job executed twice and none was lost. (A doubly
    /// executed job hides in per-record results — the second `finish`
    /// overwrites the first — but not in the workers' own counters.)
    pub fn total_launches(&self) -> u64 {
        self.reports.iter().map(|r| r.launches).sum()
    }

    pub fn total_conflicts(&self) -> u64 {
        self.reports.iter().map(|r| r.claim_conflicts).sum()
    }
}

/// The pool itself: [`WorkerPool::run`] blocks until the spool is
/// drained (no queued jobs, no claim holds) and every worker exited.
pub struct WorkerPool {
    config: PoolConfig,
}

impl WorkerPool {
    pub fn new(config: PoolConfig) -> WorkerPool {
        WorkerPool { config }
    }

    /// Spawn the workers and drain the queue.
    ///
    /// Jobs stuck `running` by an [`DeathMode::AfterClaim`] death are
    /// NOT drained here — they are indistinguishable from a live
    /// worker's in-flight execution, which is exactly why recovering
    /// them is an explicit operator action (`mare requeue`).
    pub fn run(&self, queue: &JobQueue) -> Result<PoolOutcome> {
        self.run_hooked(queue, None, false)
    }

    /// [`Self::run`] with [`ServeHooks`] observing/steering the workers
    /// — still one-shot: the pool exits once the spool is drained OR
    /// the hooks report draining.
    pub fn run_with_hooks(&self, queue: &JobQueue, hooks: &dyn ServeHooks) -> Result<PoolOutcome> {
        self.run_hooked(queue, Some(hooks), false)
    }

    /// Resident mode — the worker fleet of a `mare serve` daemon.
    /// Workers NEVER exit on an empty spool; they idle (sweeping stale
    /// holds) and keep serving new submissions until the hooks report
    /// draining, then finish in-flight work and exit. Blocks until the
    /// whole fleet has exited.
    pub fn run_resident(&self, queue: &JobQueue, hooks: &dyn ServeHooks) -> Result<PoolOutcome> {
        self.run_hooked(queue, Some(hooks), true)
    }

    fn run_hooked(
        &self,
        queue: &JobQueue,
        hooks: Option<&dyn ServeHooks>,
        resident: bool,
    ) -> Result<PoolOutcome> {
        if self.config.workers == 0 {
            return Err(MareError::Submit("worker pool needs at least one worker".into()));
        }
        for death in &self.config.faults.deaths {
            if let Some(w) = death.worker {
                if w >= self.config.workers {
                    return Err(MareError::Submit(format!(
                        "fault targets worker {w} but the pool has {}",
                        self.config.workers
                    )));
                }
            }
        }
        // someone must outlive the fault plan, or a held job's sweep
        // never happens and the pool cannot drain. Worst case: every
        // worker-targeted death kills a distinct worker AND every unit
        // of wildcard budget kills yet another.
        let doomed: std::collections::HashSet<usize> =
            self.config.faults.deaths.iter().filter_map(|d| d.worker).collect();
        let wildcard_budget: u64 = self
            .config
            .faults
            .deaths
            .iter()
            .filter(|d| d.worker.is_none())
            .map(|d| d.nth_claim)
            .sum();
        if doomed.len() as u64 + wildcard_budget >= self.config.workers as u64 {
            return Err(MareError::Submit(
                "fault plan kills every worker — at least one must survive to \
                 recover held jobs"
                    .into(),
            ));
        }

        let outcomes: Vec<Result<(PoolReport, Vec<JobRecord>)>> = thread::scope(|scope| {
            let handles: Vec<_> = (0..self.config.workers)
                .map(|idx| {
                    let config = &self.config;
                    scope.spawn(move || worker_loop(idx, config, queue, hooks, resident))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| {
                        Err(MareError::Submit("pool worker panicked".into()))
                    })
                })
                .collect()
        });

        let mut finished = Vec::new();
        let mut reports = Vec::new();
        for outcome in outcomes {
            let (report, jobs) = outcome?;
            reports.push(report);
            finished.extend(jobs);
        }
        finished.sort_by_key(|j| j.id);
        Ok(PoolOutcome { finished, reports })
    }
}

/// One worker's life: claim → (maybe die) → execute → finish, sweeping
/// stale holds while idle — until the spool has nothing claimable left
/// (one-shot), or until the hooks report draining (resident).
fn worker_loop(
    idx: usize,
    config: &PoolConfig,
    queue: &JobQueue,
    hooks: Option<&dyn ServeHooks>,
    resident: bool,
) -> Result<(PoolReport, Vec<JobRecord>)> {
    let name = if resident { format!("serve-{idx}") } else { format!("pool-{idx}") };
    let driver = Driver::new(name.clone(), config.cluster.clone());
    let mut report = PoolReport::new(name);
    let mut finished = Vec::new();
    let mut idle_rounds: u32 = 0;
    // the policy closure adapting hooks to the queue's ClaimOrder seam
    let order_fn = |candidates: &mut Vec<JobRecord>| {
        if let Some(h) = hooks {
            h.order(candidates);
        }
    };
    let order: Option<ClaimOrder<'_>> = hooks.map(|_| &order_fn as ClaimOrder<'_>);
    loop {
        if let Some(h) = hooks {
            h.beat(idx);
            // the drain contract: checked BEFORE claiming, so a
            // draining worker finishes what it already claimed and
            // takes nothing new
            if h.draining() {
                return Ok((report, finished));
            }
        }
        // a MidClaim death replaces the worker's next claim: take the
        // hold, then "die" with it. The death is STICKY — a doomed
        // worker never claims normally again (falling through after a
        // momentarily-empty scan would advance its claim count past
        // the death and orphan the fault), it only retries the fatal
        // claim until it lands one or the spool drains
        if let Some(death) = config.faults.fires_mid_claim(idx, report.claimed + 1) {
            if let Some(id) = queue.claim_abandon()? {
                report.died = Some(format!(
                    "died mid-claim #{}, holding job {id}",
                    death.nth_claim
                ));
                if let Some(h) = hooks {
                    h.died(idx, None); // the hold recovers via the sweep
                }
                return Ok((report, finished));
            }
            if !resident {
                let (queued, held) = queue.pending()?;
                if queued == 0 && held == 0 {
                    return Ok((report, finished)); // drained before it could die
                }
            }
            thread::sleep(config.poll);
            continue;
        }
        let (job, stats) = queue.claim_with_stats_ordered(order)?;
        report.claim_conflicts += stats.conflicts;
        report.claim_backoffs += stats.backoffs;
        if let Some(h) = hooks {
            h.scanned(&stats);
        }
        let Some(mut job) = job else {
            let swept = queue.sweep_stale(config.stale_after)?;
            report.swept += swept as u64;
            if swept > 0 {
                if let Some(h) = hooks {
                    h.swept(swept as u64);
                }
            }
            // ONE-SHOT: drained when the scan saw nothing queued, this
            // sweep returned nothing to the queue, and no hold can come
            // back later — checked via the claim scan's own observation
            // + a cheap name count, NOT a second full parse of every
            // spool record on every idle beat. (`running` jobs belong
            // to live workers finishing up, or to dead ones awaiting
            // an operator requeue.)
            // RESIDENT: an empty spool is just a quiet moment — idle
            // and keep serving until drained via the hooks.
            if !resident && stats.queued_seen == 0 && swept == 0 && queue.held_count()? == 0 {
                return Ok((report, finished));
            }
            // work may arrive or come back later — bounded exponential
            // idle backoff
            thread::sleep(config.poll.saturating_mul(1u32 << idle_rounds.min(3)));
            idle_rounds += 1;
            continue;
        };
        idle_rounds = 0;
        report.claimed += 1;
        if let Some(h) = hooks {
            h.claimed(idx, &mut job);
        }
        if let Some(death) = config.faults.fires_after_claim(idx, report.claimed, job.id) {
            report.died = Some(format!(
                "died after claim #{} committed, leaving job {} running",
                death.nth_claim, job.id
            ));
            if let Some(h) = hooks {
                h.died(idx, Some(job.id)); // stuck running — requeueable
            }
            return Ok((report, finished));
        }
        // per-job checkpoint store (durable when a checkpoint root is
        // configured; an in-memory stand-in otherwise, so a mid-run
        // death still fires deterministically either way)
        let ckpt_dir =
            config.checkpoints.as_ref().map(|root| root.join(format!("job-{:06}", job.id)));
        let midrun = config.faults.fires_mid_run(idx, report.claimed, job.id);
        let outcome = if ckpt_dir.is_some() || midrun.is_some() {
            let store: Box<dyn StageCheckpointer> = match &ckpt_dir {
                Some(dir) => Box::new(CheckpointStore::open(dir, &job.plan)),
                None => Box::new(MemCheckpoint::new()),
            };
            match midrun {
                Some(death) => {
                    let DeathMode::MidRun { after_stages } = death.mode else {
                        unreachable!("fires_mid_run only returns MidRun deaths")
                    };
                    let killer = KillAfter::new(store.as_ref(), after_stages as usize);
                    driver.execute_checkpointed(&job.plan, &killer)
                }
                None => driver.execute_checkpointed(&job.plan, store.as_ref()),
            }
        } else {
            driver.execute(&job.plan)
        };
        if let Err(MareError::KilledMidRun { stages_done, launches }) = &outcome {
            let (stages_done, launches) = (*stages_done, *launches);
            // the fault took this worker mid-execution: the job stays
            // `running` (requeueable, like AfterClaim), but the partial
            // launches were REAL, checkpointed work — they go on this
            // worker's ledger and up to the supervisor, because the
            // successor will NOT repeat them
            report.launches += launches;
            report.died = Some(format!(
                "died mid-run on job {}, {stages_done} stages checkpointed, \
                 {launches} launches",
                job.id
            ));
            if let Some(h) = hooks {
                h.progressed(idx, launches);
                h.died(idx, Some(job.id));
            }
            return Ok((report, finished));
        }
        let (status, result) = match outcome {
            Ok(ex) => (
                JobStatus::Done,
                JobResult {
                    driver: driver.name.clone(),
                    launches: ex.launches,
                    records: ex.records,
                    detail: "ok".into(),
                },
            ),
            Err(e) => (
                JobStatus::Failed,
                JobResult {
                    driver: driver.name.clone(),
                    launches: 0,
                    records: 0,
                    detail: e.to_string(),
                },
            ),
        };
        // a finished job needs no resume state; failed jobs KEEP theirs
        // (a retry resumes past the stages that did succeed)
        if status == JobStatus::Done {
            if let Some(dir) = &ckpt_dir {
                let _ = std::fs::remove_dir_all(dir);
            }
        }
        report.jobs_run += 1;
        report.launches += result.launches;
        let record = queue.finish(job, status, result)?;
        if let Some(h) = hooks {
            h.finished(idx, &record);
        }
        finished.push(record);
    }
}

/// Compile-time proof the pool's sharing is sound: the queue handle is
/// borrowed by every worker thread and drivers run whole jobs inside
/// them, so everything the submit/storage path materializes must stay
/// `Send + Sync`. If a non-thread-safe handle ever sneaks into the
/// cluster, registry, artifact runtime or dataset types, this stops
/// compiling — long before a stress test flakes.
#[allow(dead_code)]
fn assert_thread_safe() {
    fn ok<T: Send + Sync>() {}
    ok::<JobQueue>();
    ok::<Driver>();
    ok::<JobRecord>();
    ok::<PoolConfig>();
    ok::<crate::storage::StorageCatalog>();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::submit::Submitter;

    fn tmp_queue(name: &str) -> JobQueue {
        let dir = std::env::temp_dir()
            .join(format!("mare-pool-test-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        JobQueue::open(dir).unwrap()
    }

    fn gc_plan() -> String {
        r#"{
          "version": 1,
          "ops": [
            {"op": "ingest", "label": "gen:gc:16", "partitions": 2},
            {"op": "map", "image": "ubuntu",
             "command": "grep -o '[GC]' /dna | wc -l > /count",
             "input": {"kind": "text", "path": "/dna"},
             "output": {"kind": "text", "path": "/count"}},
            {"op": "collect"}
          ]
        }"#
        .to_string()
    }

    #[test]
    fn fault_specs_parse_and_reject_garbage() {
        let plan = FaultPlan::parse("2:3:hold, 0:1:running").unwrap();
        assert_eq!(plan.deaths.len(), 2);
        assert_eq!(
            plan.deaths[0],
            Death { worker: Some(2), nth_claim: 3, mode: DeathMode::MidClaim, job: None }
        );
        assert_eq!(
            plan.deaths[1],
            Death { worker: Some(0), nth_claim: 1, mode: DeathMode::AfterClaim, job: None }
        );
        assert_eq!(plan.fires_mid_claim(2, 3), Some(plan.deaths[0]));
        assert_eq!(plan.fires_mid_claim(2, 2), None);
        assert_eq!(plan.fires_mid_claim(1, 3), None);
        // a non-job-filtered `running` death fires whatever job arrives
        assert_eq!(plan.fires_after_claim(0, 1, 42), Some(plan.deaths[1]));
        assert_eq!(plan.fires_after_claim(0, 2, 42), None);

        // the extended grammar: wildcard targets, job filters, midrun
        let plan = FaultPlan::parse("*:2:running:j1, 1:1:midrun, *:1:midrun@3:j7").unwrap();
        assert_eq!(
            plan.deaths[0],
            Death { worker: None, nth_claim: 2, mode: DeathMode::AfterClaim, job: Some(1) }
        );
        assert_eq!(
            plan.deaths[1],
            Death {
                worker: Some(1),
                nth_claim: 1,
                mode: DeathMode::MidRun { after_stages: 1 },
                job: None
            }
        );
        assert_eq!(
            plan.deaths[2],
            Death {
                worker: None,
                nth_claim: 1,
                mode: DeathMode::MidRun { after_stages: 3 },
                job: Some(7)
            }
        );
        // job filters screen out other jobs
        assert_eq!(plan.fires_after_claim(0, 5, 2), None);
        assert!(plan.fires_mid_run(3, 9, 7).is_some());
        assert_eq!(plan.fires_mid_run(3, 9, 8), None);

        for bad in [
            "2:3",
            "x:1:hold",
            "1:y:hold",
            "1:0:hold",
            "1:2:explode",
            "",
            "*:1:running",      // wildcard without a job filter
            "*:1:hold:j2",      // hold cannot be job-targeted
            "1:1:hold:j2",      // (either way)
            "1:1:midrun@0",     // zero stages makes no mid-run point
            "1:1:midrun@x",
            "1:1:midrunner",
            "1:1:running:2",    // job filter must be jN
            "*:0:running:j1",   // zero budget
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad} should not parse");
        }
    }

    #[test]
    fn wildcard_budgets_are_shared_across_clones_and_exhaust() {
        let plan = FaultPlan::parse("*:2:running:j5").unwrap();
        let clone = plan.clone();
        assert!(plan.fires_after_claim(0, 1, 5).is_some());
        assert!(clone.fires_after_claim(3, 7, 5).is_some(), "clones share the budget");
        assert!(plan.fires_after_claim(1, 2, 5).is_none(), "budget exhausted");
        assert!(clone.fires_after_claim(1, 2, 5).is_none());
    }

    #[test]
    fn pool_rejects_unrunnable_configs() {
        let q = tmp_queue("badcfg");
        let cluster = ClusterConfig::sized(2, 2);

        let pool = WorkerPool::new(PoolConfig::new(0, cluster.clone()));
        assert!(pool.run(&q).is_err());

        let mut cfg = PoolConfig::new(2, cluster.clone());
        cfg.faults = FaultPlan::parse("5:1:hold").unwrap();
        assert!(WorkerPool::new(cfg).run(&q).unwrap_err().to_string().contains("worker 5"));

        let mut cfg = PoolConfig::new(2, cluster.clone());
        cfg.faults = FaultPlan::parse("0:1:hold,1:1:running").unwrap();
        let err = WorkerPool::new(cfg).run(&q).unwrap_err().to_string();
        assert!(err.contains("at least one must survive"), "{err}");

        // wildcard budgets count toward the same immortality guarantee
        let mut cfg = PoolConfig::new(2, cluster);
        cfg.faults = FaultPlan::parse("*:2:running:j1").unwrap();
        let err = WorkerPool::new(cfg).run(&q).unwrap_err().to_string();
        assert!(err.contains("at least one must survive"), "{err}");
    }

    #[test]
    fn a_small_pool_drains_a_queue_exactly_once() {
        let q = tmp_queue("drain");
        let cluster = ClusterConfig::sized(2, 2);
        let submitter = Submitter::new(cluster.clone());
        for _ in 0..6 {
            submitter.submit(&q, &gc_plan()).unwrap();
        }

        let pool = WorkerPool::new(PoolConfig::new(3, cluster.clone()));
        let outcome = pool.run(&q).unwrap();

        assert_eq!(outcome.finished.len(), 6);
        assert!(outcome.finished.iter().all(|j| j.status == JobStatus::Done));
        // the same plan yields the same launch count on every worker —
        // and the workers' own counters agree with the per-job records,
        // so nothing ran twice
        let per_job: Vec<u64> = outcome
            .finished
            .iter()
            .map(|j| j.result.as_ref().unwrap().launches)
            .collect();
        assert!(per_job.windows(2).all(|w| w[0] == w[1]), "{per_job:?}");
        assert_eq!(outcome.total_launches(), per_job.iter().sum::<u64>());
        assert_eq!(outcome.reports.len(), 3);
        assert!(outcome.reports.iter().all(|r| r.died.is_none()));

        // drained spool: an immediate rerun has nothing to do
        let rerun = pool.run(&q).unwrap();
        assert!(rerun.finished.is_empty());
    }

    /// The claim-scan index through the hooks seam: draining N jobs
    /// must cost far fewer record parses than the cache-less scanner,
    /// which paid at least `queued_seen` parses on EVERY scan. With
    /// one worker the schedule is sequential: the cold scan parses all
    /// N, every later scan re-parses only the record the worker itself
    /// last rewrote — ~2N parses total against ~N^2/2 without the
    /// index.
    #[test]
    fn scanned_hook_reports_index_hits_not_full_reparses() {
        use std::sync::atomic::{AtomicU64, Ordering};

        #[derive(Default)]
        struct ScanLedger {
            parsed: AtomicU64,
            naive: AtomicU64,
        }
        impl ServeHooks for ScanLedger {
            fn scanned(&self, stats: &ClaimStats) {
                self.parsed.fetch_add(stats.parsed, Ordering::Relaxed);
                self.naive.fetch_add(stats.queued_seen, Ordering::Relaxed);
            }
        }

        let q = tmp_queue("scan-ledger");
        let cluster = ClusterConfig::sized(2, 2);
        let submitter = Submitter::new(cluster.clone());
        let jobs = 12u64;
        for _ in 0..jobs {
            submitter.submit(&q, &gc_plan()).unwrap();
        }

        let ledger = ScanLedger::default();
        let pool = WorkerPool::new(PoolConfig::new(1, cluster));
        let outcome = pool.run_with_hooks(&q, &ledger).unwrap();
        assert_eq!(outcome.finished.len(), jobs as usize);

        let parsed = ledger.parsed.load(Ordering::Relaxed);
        let naive = ledger.naive.load(Ordering::Relaxed);
        assert!(parsed >= jobs, "every record must be parsed at least once: {parsed}");
        assert!(
            parsed <= 2 * jobs,
            "scans re-parsed unchanged records: {parsed} parses for {jobs} jobs"
        );
        // the cache-less floor for the same scan schedule (12+11+...+1)
        assert!(
            naive >= jobs * (jobs + 1) / 2,
            "scan schedule changed — naive floor {naive} too small to compare against"
        );
        assert!(parsed * 2 < naive, "index saved nothing: {parsed} vs naive {naive}");
    }
}
