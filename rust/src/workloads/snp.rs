//! SNP-calling pipeline — Listing 3, verbatim: BWA alignment (map),
//! chromosome-wise `repartitionBy`, GATK HaplotypeCaller (map,
//! disk-backed mounts), vcf-concat (reduce).

use std::sync::Arc;

use crate::cluster::Cluster;
use crate::dataset::{Dataset, Record};
use crate::error::Result;
use crate::formats::vcf::{self, VcfRecord};
use crate::mare::{Job, MaRe, MountPoint};
use crate::tools::posix::decompress;

/// Listing 3 lines 5–10: align + convert to SAM text.
pub fn bwa_command() -> String {
    "bwa mem -t 8 \
     -p /ref/human_g1k_v37.fasta \
     /in.fastq \
     | samtools view > /out.sam"
        .to_string()
}

/// Listing 3 lines 18–32: header, sort, index, call, zip.
pub fn gatk_command() -> String {
    "cat /ref/human_g1k_v37.dict /in.sam > /in.hdr.sam\n\
     gatk AddOrReplaceReadGroups --INPUT=/in.hdr.sam --OUTPUT=/in.hdr.sort.rg.bam --SORT_ORDER=coordinate\n\
     gatk BuildBamIndex --INPUT=/in.hdr.sort.rg.bam\n\
     gatk HaplotypeCallerSpark -R /ref/human_g1k_v37.fasta -I /in.hdr.sort.rg.bam -O /out/$RANDOM.g.vcf\n\
     gzip /out/*"
        .to_string()
}

/// Listing 3 lines 39–40: merge + zip.
pub fn vcf_concat_command() -> String {
    "vcf-concat /in/*.vcf.gz | gzip -c > /out/merged.$RANDOM.g.vcf.gz".to_string()
}

/// Listing 3 as a MaRe pipeline. `num_nodes` is the paper's
/// `numberOfNodes` (chromosome-group partition count); disk-backed
/// mounts mirror the TMPDIR override of §1.3.2.
pub fn pipeline(cluster: Arc<Cluster>, reads: Dataset, num_nodes: usize) -> Job {
    MaRe::source(cluster, reads)
        .map("mcapuccini/alignment:latest", bwa_command())
        .mounts("/in.fastq", "/out.sam")
        // the registered "chromosome" key keeps this plan serializable
        // (mare::wire), so the SNP job can be submitted to any driver
        .repartition_by_named("chromosome", num_nodes.max(1))
        .disk_mounts(true)
        .map("mcapuccini/alignment:latest", gatk_command())
        .input_mount(MountPoint::text("/in.sam"))
        .output_mount(MountPoint::binary("/out"))
        .reduce("opengenomics/vcftools-tools:latest", vcf_concat_command())
        .binary_mounts("/in", "/out")
        .depth(2)
        .build()
        .expect("the SNP pipeline is statically valid")
}

/// Run end-to-end and parse the merged VCF out of the final gzipped
/// record.
pub fn run(cluster: Arc<Cluster>, reads: Dataset, num_nodes: usize) -> Result<Vec<VcfRecord>> {
    let out = pipeline(cluster, reads, num_nodes).run()?;
    let records = out.collect_records();
    let mut calls = Vec::new();
    for r in &records {
        if let Record::Binary { name, bytes } = r {
            let text = if name.ends_with(".gz") {
                String::from_utf8(decompress(bytes)?)
                    .map_err(|_| crate::error::MareError::Storage(format!("{name}: not UTF-8")))?
            } else {
                String::from_utf8(bytes.to_vec())
                    .map_err(|_| crate::error::MareError::Storage(format!("{name}: not UTF-8")))?
            };
            calls.extend(vcf::parse_many(&text.into())?);
        }
    }
    calls.sort_by(|a, b| (a.chrom.clone(), a.pos).cmp(&(b.chrom.clone(), b.pos)));
    Ok(calls)
}

/// Score pipeline calls against the generator's truth set:
/// (true positives, false positives, false negatives).
pub fn score_calls(
    calls: &[VcfRecord],
    truth: &[super::genreads::PlantedSnp],
) -> (usize, usize, usize) {
    use std::collections::HashSet;
    let truth_set: HashSet<(String, u64)> =
        truth.iter().map(|t| (t.chrom.clone(), t.pos as u64 + 1)).collect();
    let call_set: HashSet<(String, u64)> =
        calls.iter().map(|c| (c.chrom.to_string(), c.pos)).collect();
    let tp = call_set.intersection(&truth_set).count();
    let fp = call_set.difference(&truth_set).count();
    let fn_ = truth_set.difference(&call_set).count();
    (tp, fp, fn_)
}
