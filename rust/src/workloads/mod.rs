//! Evaluation workloads: generators + the paper's three pipelines.
//!
//! * [`genlib`] — synthetic SDF molecule library (SureChEMBL stand-in)
//! * [`genreads`] — synthetic genome/reads + planted-SNP truth set
//!   (1000-Genomes stand-in)
//! * [`gc`] — Listing 1: GC count
//! * [`vs`] — Listing 2: virtual screening (FRED + sdsorter)
//! * [`snp`] — Listing 3: SNP calling (BWA + GATK + vcftools)
//! * [`kmer`] — k-mer counting (the shuffle-heavy combine showcase)

pub mod driver;
pub mod gc;
pub mod genlib;
pub mod genreads;
pub mod kmer;
pub mod snp;
pub mod vs;

use std::sync::Arc;

use crate::cluster::{Cluster, ClusterConfig};
use crate::error::Result;
use crate::formats::fasta::Reference;
use crate::runtime::ToolRuntime;
use crate::tools::images;

/// Receptor seed baked into the stock `mcapuccini/oe` deployment.
pub const RECEPTOR_SEED: u64 = 0x41_56_49_44;

/// A cluster with the stock images and, if provided, the PJRT runtime
/// (required by fred/gatk; Listing 1's POSIX pipelines run without it).
pub fn make_cluster(
    config: ClusterConfig,
    artifact_dir: Option<&str>,
    reference: Option<&Reference>,
) -> Result<Arc<Cluster>> {
    let registry = Arc::new(images::stock_registry(reference));
    let runtime = match artifact_dir {
        Some(dir) => Some(ToolRuntime::new(dir, RECEPTOR_SEED)?),
        None => None,
    };
    Ok(Arc::new(Cluster::new(registry, runtime, config)))
}

/// Locate `artifacts/` relative to the crate root (works from tests,
/// examples and benches).
pub fn artifact_dir() -> String {
    std::env::var("MARE_ARTIFACTS").unwrap_or_else(|_| {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    })
}
