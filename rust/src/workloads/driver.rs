//! Shared experiment driver: a resolved [`RunConfigFile`] → generated
//! data on the configured storage backend → ingestion → pipeline →
//! report. The `mare run` subcommand, the examples and the benches all
//! go through here, so every number in EXPERIMENTS.md has one code path.

use crate::cluster::RunReport;
use crate::config::{BackendKind, RunConfigFile, Workload};
use crate::dataset::Dataset;
use crate::error::Result;
use crate::mare::{wire, Job, MaRe};
use crate::storage::{ingest_text, IngestReport, StorageBackend};

use super::{gc, genlib, genreads, kmer, snp, vs};

/// Everything a run produces.
pub struct DriverResult {
    pub ingest: IngestReport,
    pub report: RunReport,
    /// Workload-specific result digest (GC count, top poses, SNP calls).
    pub digest: String,
}

/// Build the configured backend holding `key` = `bytes`. Construction
/// goes through the storage catalog's one backend-assembly path
/// ([`crate::storage::StorageCatalog::open`]), so the experiment driver
/// and submitted storage-URI plans share the same block-size/placement
/// policy.
pub fn make_backend(kind: BackendKind, workers: usize, key: &str, bytes: Vec<u8>) -> Result<Box<dyn StorageBackend>> {
    let catalog = crate::storage::StorageCatalog::simulated(workers);
    let mut backend = catalog.open(kind, bytes.len() as u64);
    backend.put(key, bytes)?;
    Ok(backend)
}

/// Run the configured workload end-to-end.
pub fn run(cfg: &RunConfigFile) -> Result<DriverResult> {
    match cfg.workload {
        Workload::Gc => run_gc(cfg),
        Workload::Vs => run_vs(cfg),
        Workload::Snp => run_snp(cfg),
        Workload::Kmer => run_kmer(cfg),
    }
}

/// Round-trip a job's logical plan through the wire codec and rebuild
/// it over the same source. Every `mare run` executes the REBUILT job,
/// so the direct path and the `mare submit` path share one artifact:
/// any plan this driver can run, it can also persist and resubmit
/// (docs/WIRE_FORMAT.md). Drift between the two is a bug, caught by
/// the debug assertion.
fn reship(job: Job) -> Result<Job> {
    let encoded = wire::encode(job.logical())?;
    let decoded = wire::decode(&encoded)?;
    let rebuilt = MaRe::source(job.cluster().clone(), job.source().clone())
        .append_pipeline(&decoded)
        .build()?;
    debug_assert_eq!(
        rebuilt.explain(),
        job.explain(),
        "wire round-trip changed the plan"
    );
    Ok(rebuilt)
}

/// Default partition count: 2 waves per vCPU-bound stage.
fn partitions(cfg: &RunConfigFile) -> usize {
    cfg.cluster.workers * 2
}

fn run_gc(cfg: &RunConfigFile) -> Result<DriverResult> {
    let genome = gc::genome_text(cfg.seed, cfg.scale, 80);
    let backend =
        make_backend(cfg.backend, cfg.cluster.workers, "genome.txt", genome.into_bytes())?;
    let (ds, ingest) = ingest_text(
        backend.as_ref(),
        "genome.txt",
        "\n",
        partitions(cfg),
        cfg.cluster.workers,
    )?;
    let cluster = super::make_cluster(cfg.cluster.clone(), None, None)?;
    let pipeline = reship(gc::pipeline(cluster, ds))?;
    crate::log_debug!("gc job:\n{}", pipeline.explain());
    let out = pipeline.run()?;
    let digest = format!("gc_count={}", out.collect_text("\n").trim());
    Ok(DriverResult { ingest, report: out.report, digest })
}

fn run_vs(cfg: &RunConfigFile) -> Result<DriverResult> {
    let library = genlib::library_sdf(cfg.seed, cfg.scale);
    let backend =
        make_backend(cfg.backend, cfg.cluster.workers, "library.sdf", library.into_bytes())?;
    let (ds, ingest) = ingest_text(
        backend.as_ref(),
        "library.sdf",
        vs::SDF_SEP,
        partitions(cfg),
        cfg.cluster.workers,
    )?;
    let cluster = super::make_cluster(cfg.cluster.clone(), Some(&cfg.artifacts), None)?;
    let pipeline = reship(vs::pipeline(cluster, ds, cfg.reduce_depth))?;
    crate::log_debug!("vs job:\n{}", pipeline.explain());
    let out = pipeline.run()?;
    let text = out.collect_text(vs::SDF_SEP);
    let top = crate::formats::sdf::parse_many(&text)?;
    let digest = format!(
        "top_poses={} best={}",
        top.len(),
        top.first().map(|m| m.name.as_str()).unwrap_or("-")
    );
    Ok(DriverResult { ingest, report: out.report, digest })
}

fn run_kmer(cfg: &RunConfigFile) -> Result<DriverResult> {
    // same seeded genome generator as GC — the workloads differ in
    // shuffle regime (map-side shrink vs ~7x inflation), not in input
    let genome = kmer::genome_text(cfg.seed, cfg.scale, 80);
    let backend =
        make_backend(cfg.backend, cfg.cluster.workers, "genome.txt", genome.into_bytes())?;
    let (ds, ingest) = ingest_text(
        backend.as_ref(),
        "genome.txt",
        "\n",
        partitions(cfg),
        cfg.cluster.workers,
    )?;
    let cluster = super::make_cluster(cfg.cluster.clone(), None, None)?;
    let pipeline = reship(kmer::pipeline(cluster, ds, cfg.cluster.workers, true))?;
    crate::log_debug!("kmer job:\n{}", pipeline.explain());
    let out = pipeline.run()?;
    let distinct = out.collect_text("\n").lines().filter(|l| !l.trim().is_empty()).count();
    let shipped = out.report.total_shuffled_bytes();
    let saved = out.report.total_pre_combine_bytes() - shipped;
    let digest = format!("kmers={distinct} shuffled={shipped}B combiner_saved={saved}B");
    Ok(DriverResult { ingest, report: out.report, digest })
}

fn run_snp(cfg: &RunConfigFile) -> Result<DriverResult> {
    // 8 chromosomes: enough for chromosome-wise grouping to matter, and
    // (like the paper's 25-chromosome cap, §1.3.2) fewer than the
    // largest cluster's worker count — the gatk stage's max parallelism
    let sim = genreads::ReadSimConfig {
        seed: cfg.seed,
        chromosomes: 8,
        chromosome_len: cfg.scale.max(500),
        ..Default::default()
    };
    let (fastq, individual) = genreads::reads_fastq(&sim);
    // the paper ingests *compressed* FASTQ from S3 ("~30GB compressed
    // FASTQ files"); store gzipped and decompress at ingestion
    let gz = crate::tools::posix::compress(fastq.as_bytes())?;
    let backend =
        make_backend(cfg.backend, cfg.cluster.workers, "reads.fastq.gz", gz)?;
    // FASTQ records are 4-line blocks; ingest whole reads, not lines
    let (ds, ingest) =
        ingest_fastq(backend.as_ref(), "reads.fastq.gz", partitions(cfg), cfg)?;
    let cluster = super::make_cluster(
        cfg.cluster.clone(),
        Some(&cfg.artifacts),
        Some(&individual.reference),
    )?;
    let pipeline = reship(snp::pipeline(cluster, ds, cfg.cluster.workers))?;
    crate::log_debug!("snp job:\n{}", pipeline.explain());
    let out = pipeline.run()?;
    let calls = parse_vcf_records(&out)?;
    let (tp, fp, fn_) = snp::score_calls(&calls, &individual.truth);
    let digest = format!("snps={} tp={tp} fp={fp} fn={fn_}", calls.len());
    Ok(DriverResult { ingest, report: out.report, digest })
}

/// Decode the final gzipped-VCF records of an SNP run.
pub fn parse_vcf_records(
    out: &crate::cluster::RunOutput,
) -> Result<Vec<crate::formats::vcf::VcfRecord>> {
    let mut calls = Vec::new();
    for r in out.partitions.iter().flat_map(|p| p.records.iter()) {
        if let crate::dataset::Record::Binary { name, bytes } = r {
            let text = if name.ends_with(".gz") {
                String::from_utf8(crate::tools::posix::decompress(bytes)?)
                    .map_err(|_| crate::error::MareError::Storage(format!("{name}: not UTF-8")))?
            } else {
                String::from_utf8(bytes.to_vec())
                    .map_err(|_| crate::error::MareError::Storage(format!("{name}: not UTF-8")))?
            };
            calls.extend(crate::formats::vcf::parse_many(&text.into())?);
        }
    }
    calls.sort_by(|a, b| (a.chrom.clone(), a.pos).cmp(&(b.chrom.clone(), b.pos)));
    Ok(calls)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;

    #[test]
    fn all_workload_plans_survive_the_wire() {
        use crate::mare::wire;
        let mk = || {
            crate::workloads::make_cluster(ClusterConfig::sized(2, 2), None, None).unwrap()
        };
        let gc = crate::workloads::gc::pipeline(
            mk(),
            Dataset::parallelize_text("GATTACA\nGGCC", "\n", 2),
        );
        let vs = crate::workloads::vs::pipeline(
            mk(),
            Dataset::parallelize_text(
                "molA\n$$$$\nmolB",
                crate::workloads::vs::SDF_SEP,
                2,
            ),
            2,
        );
        let snp = crate::workloads::snp::pipeline(
            mk(),
            Dataset::parallelize_text("@r/1\nACGT\n+\nIIII", "\x00", 2),
            2,
        );
        let km = crate::workloads::kmer::pipeline(
            mk(),
            Dataset::parallelize_text("GATTACAGATTACA\nGGCCGGCC", "\n", 2),
            2,
            true,
        );
        for job in [gc, vs, snp, km] {
            let text = wire::encode_string(job.logical()).unwrap();
            let decoded = wire::decode_str(&text).unwrap();
            assert_eq!(decoded.describe(), job.logical().describe());
            // reship() debug-asserts explain() equality internally
            let rebuilt = reship(job).unwrap();
            assert!(rebuilt.explain().contains("physical plan:"));
        }
    }

    #[test]
    fn make_backend_spreads_blocks_over_workers() {
        let b = make_backend(BackendKind::Hdfs, 4, "k", vec![0u8; 2 << 20]).unwrap();
        let blocks = b.blocks("k").unwrap();
        assert!(blocks.len() >= 4, "{} blocks", blocks.len());
        let hosts: std::collections::HashSet<_> =
            blocks.iter().filter_map(|x| x.primary).collect();
        assert!(hosts.len() >= 3, "{hosts:?}");
    }

    #[test]
    fn make_backend_kinds() {
        for (kind, name) in [
            (BackendKind::Hdfs, "hdfs"),
            (BackendKind::Swift, "swift"),
            (BackendKind::S3, "s3"),
            (BackendKind::Local, "local"),
        ] {
            let b = make_backend(kind, 2, "k", b"x".to_vec()).unwrap();
            assert_eq!(b.name(), name);
            assert_eq!(b.get("k").unwrap(), b"x");
        }
    }

    #[test]
    fn ingest_fastq_decompresses_gz_and_partitions_reads() {
        let sim = crate::workloads::genreads::ReadSimConfig {
            seed: 9,
            chromosomes: 2,
            chromosome_len: 600,
            coverage: 5.0,
            ..Default::default()
        };
        let (fastq, _) = crate::workloads::genreads::reads_fastq(&sim);
        let n_reads = fastq.matches("\n+\n").count();
        let gz = crate::tools::posix::compress(fastq.as_bytes()).unwrap();

        let mut cfg = RunConfigFile::default();
        cfg.cluster = ClusterConfig::sized(2, 2);
        let backend = make_backend(BackendKind::S3, 2, "r.fastq.gz", gz).unwrap();
        let (ds, rep) =
            ingest_fastq(backend.as_ref(), "r.fastq.gz", 4, &cfg).unwrap();
        assert_eq!(ds.num_partitions(), 4);
        assert!(rep.bytes > 0);
        match ds.plan().as_ref() {
            crate::dataset::Plan::Source { partitions, .. } => {
                let total: usize = partitions.iter().map(|p| p.len()).sum();
                assert_eq!(total, n_reads);
                // every record is a well-formed 4-line FASTQ block
                for p in partitions {
                    for r in &p.records {
                        let t = r.as_text().unwrap();
                        assert!(t.starts_with('@'), "{t}");
                        assert_eq!(t.lines().count(), 4, "{t}");
                    }
                }
            }
            _ => panic!("expected source"),
        }
    }

    #[test]
    fn ingest_fastq_rejects_garbage() {
        let cfg = RunConfigFile::default();
        let backend =
            make_backend(BackendKind::Local, 1, "bad.fastq", b"not fastq".to_vec())
                .unwrap();
        assert!(ingest_fastq(backend.as_ref(), "bad.fastq", 1, &cfg).is_err());
    }
}

/// FASTQ-aware ingestion: records are whole reads (4 lines), the record
/// separator trick used for SDF does not apply; `.gz` objects are
/// decompressed transparently (1KGP hosts compressed FASTQ).
pub fn ingest_fastq(
    backend: &dyn StorageBackend,
    key: &str,
    num_partitions: usize,
    cfg: &RunConfigFile,
) -> Result<(Dataset, IngestReport)> {
    // split on read boundaries: "\n@" is ambiguous (quality lines may
    // start with @), so split every 4 lines via the parser
    let bytes = backend.get(key)?;
    let plain;
    let text = if key.ends_with(".gz") {
        plain = crate::tools::posix::decompress(bytes)?;
        std::str::from_utf8(&plain)
            .map_err(|_| crate::error::MareError::Storage(format!("{key}: not UTF-8")))?
    } else {
        std::str::from_utf8(bytes)
            .map_err(|_| crate::error::MareError::Storage(format!("{key}: not UTF-8")))?
    };
    let reads = crate::formats::fastq::parse_many(&text.into())?;
    let records: Vec<crate::dataset::Record> = reads
        .iter()
        .map(|r| crate::dataset::Record::text(r.to_fastq().trim_end().to_string()))
        .collect();

    let n = num_partitions.max(1);
    let mut parts: Vec<crate::dataset::Partition> = Vec::with_capacity(n);
    let total = records.len();
    let mut it = records.into_iter();
    let blocks = backend.blocks(key)?;
    for i in 0..n {
        let count = total / n + usize::from(i < total % n);
        let recs: Vec<crate::dataset::Record> = it.by_ref().take(count).collect();
        let primary = blocks.get(i * blocks.len() / n).and_then(|b| b.primary);
        parts.push(crate::dataset::Partition { records: recs, preferred_worker: primary });
    }
    let report =
        crate::storage::ingest::account(backend, &parts, cfg.cluster.workers.max(1), 0);
    Ok((
        Dataset::from_partitions(parts, format!("{}://{key}", backend.name())),
        report,
    ))
}
