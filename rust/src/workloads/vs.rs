//! Virtual-screening pipeline — Listing 2, verbatim: FRED docking over
//! an SDF library (map), top-30 poses by Chemgauss4 score (reduce),
//! through the fluent pipeline-IR API.

use std::sync::Arc;

use crate::cluster::Cluster;
use crate::dataset::Dataset;
use crate::error::Result;
use crate::formats::sdf::{self, Molecule};
use crate::mare::{Job, MaRe};
use crate::tools::fred::SCORE_TAG;

/// SDF record separator (Listing 2 line 2).
pub const SDF_SEP: &str = "\n$$$$\n";
/// Poses kept by the reduce (Listing 2: `-nbest=30`).
pub const NBEST: usize = 30;

/// The FRED map command (Listing 2 lines 5–11).
pub fn fred_command() -> String {
    "fred -receptor /var/openeye/hiv1_protease.oeb \
     -hitlist_size 0 \
     -conftest none \
     -dbase /in.sdf \
     -docked_molecule_file /out.sdf"
        .to_string()
}

/// The sdsorter reduce command (Listing 2 lines 16–21).
pub fn sdsorter_command(nbest: usize) -> String {
    format!(
        "sdsorter -reversesort=\"FRED Chemgauss4 score\" \
         -keep-tag=\"FRED Chemgauss4 score\" \
         -nbest={nbest} \
         /in.sdf /out.sdf"
    )
}

/// Listing 2 as a MaRe pipeline.
pub fn pipeline(cluster: Arc<Cluster>, library: Dataset, depth: usize) -> Job {
    MaRe::source(cluster, library)
        .map("mcapuccini/oe:latest", fred_command())
        .mounts_sep("/in.sdf", "/out.sdf", SDF_SEP)
        .reduce("mcapuccini/sdsorter:latest", sdsorter_command(NBEST))
        .mounts_sep("/in.sdf", "/out.sdf", SDF_SEP)
        .depth(depth.max(1))
        .build()
        .expect("the VS pipeline is statically valid")
}

/// Run and parse the top poses.
pub fn run(cluster: Arc<Cluster>, library: Dataset, depth: usize) -> Result<Vec<Molecule>> {
    let out = pipeline(cluster, library, depth).run()?;
    let text = out.collect_text(SDF_SEP);
    sdf::parse_many(&text)
}

/// Single-core oracle: dock every molecule through the same runtime and
/// keep the top N — the paper's own correctness check ("we ran sdsorter
/// and FRED on a single core against 1K molecules ... and compared").
pub fn oracle(
    runtime: &crate::runtime::ToolRuntime,
    library_sdf: &str,
    nbest: usize,
) -> Result<Vec<(String, f32)>> {
    let mols = sdf::parse_many(library_sdf)?;
    let mut features = Vec::with_capacity(mols.len() * crate::runtime::abi::DOCK_F);
    for m in &mols {
        features.extend(crate::tools::fred::featurize(m));
    }
    let results = runtime.dock(&features, mols.len())?;
    let mut scored: Vec<(String, f32)> = mols
        .iter()
        .zip(&results)
        .map(|(m, r)| (m.name.clone(), -r.score))
        .collect();
    scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then_with(|| a.0.cmp(&b.0)));
    scored.truncate(nbest);
    Ok(scored)
}

/// Scores of pipeline output, comparable with [`oracle`].
pub fn scores(mols: &[Molecule]) -> Vec<(String, f32)> {
    mols.iter()
        .map(|m| (m.name.clone(), m.tag_f32(SCORE_TAG).unwrap_or(f32::NAN)))
        .collect()
}
