//! k-mer statistics pipeline — the shuffle-heavy workload that
//! motivates map-side combining.
//!
//! ```text
//! kmerize -k 4 /seq > /kmers          # map: every window, `<kmer>\t1`
//! repartitionBy[kmer_prefix -> P]     # group equal kmers together
//! kmeragg /kmers > /counts  .combine  # reduce: sum counts per kmer
//! ```
//!
//! The map inflates every input byte into a ~7-byte singleton line, so
//! the shuffle dominates end-to-end cost — the opposite regime from the
//! paper's GC pipeline, where the map shrinks each partition to one
//! number. With `.combine()` the optimizer pushes `kmeragg` below the
//! shuffle boundary and the singletons collapse to at most `4^k`
//! distinct keys per map partition before a byte moves, which is where
//! the `combiner_cuts_shuffle_bytes` ratio comes from.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::cluster::Cluster;
use crate::dataset::Dataset;
use crate::error::Result;
use crate::mare::pipeline::KMER_PREFIX_LEN;
use crate::mare::{Job, MaRe};

pub use super::gc::genome_text;

/// The window size — kept equal to the `kmer_prefix` key length so the
/// named key groups exactly by kmer.
pub const K: usize = KMER_PREFIX_LEN;

/// Build the k-mer counting job. `combine: false` is the ablation
/// baseline: same logical plan minus the `.combine()` declaration.
pub fn pipeline(
    cluster: Arc<Cluster>,
    genome: Dataset,
    partitions: usize,
    combine: bool,
) -> Job {
    let mut b = MaRe::source(cluster, genome)
        .map("mare/kmer:latest", format!("kmerize -k {K} /seq > /kmers"))
        .mounts("/seq", "/kmers")
        .repartition_by_named("kmer_prefix", partitions)
        .reduce("mare/kmer:latest", "kmeragg /kmers > /counts")
        .mounts("/kmers", "/counts");
    if combine {
        b = b.combine();
    }
    b.build().expect("the kmer pipeline is statically valid")
}

/// Run end-to-end: sorted `<kmer>\t<count>` lines.
pub fn run(cluster: Arc<Cluster>, genome: Dataset, partitions: usize) -> Result<String> {
    pipeline(cluster, genome, partitions, true).collect_text()
}

/// Driver-side oracle: the same sorted `<kmer>\t<count>` rendering.
pub fn oracle(genome: &str, k: usize) -> String {
    let mut counts: BTreeMap<&str, u64> = BTreeMap::new();
    for line in genome.lines() {
        let seq = line.trim();
        if seq.len() < k {
            continue;
        }
        for start in 0..=seq.len() - k {
            *counts.entry(&seq[start..start + k]).or_insert(0) += 1;
        }
    }
    counts.iter().map(|(kmer, n)| format!("{kmer}\t{n}")).collect::<Vec<_>>().join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;
    use crate::tools::images;

    fn cluster() -> Arc<Cluster> {
        Arc::new(Cluster::new(
            Arc::new(images::stock_registry(None)),
            None,
            ClusterConfig::sized(4, 2),
        ))
    }

    fn genome() -> String {
        genome_text(29, 256, 64)
    }

    #[test]
    fn matches_oracle_across_partitionings() {
        let genome = genome();
        let want = oracle(&genome, K);
        for (source_parts, shuffle_parts) in [(1usize, 1usize), (4, 4), (16, 3)] {
            let ds = Dataset::parallelize_text(&genome, "\n", source_parts);
            assert_eq!(
                run(cluster(), ds, shuffle_parts).unwrap(),
                want,
                "source={source_parts} shuffle={shuffle_parts}"
            );
        }
    }

    #[test]
    fn combiner_cuts_shuffle_bytes_at_least_4x_with_identical_results() {
        let genome = genome();
        let run_with = |combine: bool| {
            let ds = Dataset::parallelize_text(&genome, "\n", 4);
            let job = pipeline(cluster(), ds, 4, combine);
            let out = job.run().unwrap();
            (out.collect_text("\n"), out.report.total_shuffled_bytes())
        };
        let (with, on_bytes) = run_with(true);
        let (without, off_bytes) = run_with(false);
        assert_eq!(with, without, "combining must not change the result");
        assert_eq!(with.trim_end(), oracle(&genome, K));
        assert!(
            on_bytes * 4 <= off_bytes,
            "combiner must cut shuffled bytes >= 4x: on={on_bytes} off={off_bytes}"
        );
    }

    #[test]
    fn explain_shows_the_pushed_combiner() {
        let ds = Dataset::parallelize_text(&genome(), "\n", 4);
        let job = pipeline(cluster(), ds, 4, true);
        let s = job.explain();
        assert!(s.contains("+combine kmeragg"), "{s}");
        assert!(s.contains("1 combiner pushed below the shuffle"), "{s}");
    }
}
