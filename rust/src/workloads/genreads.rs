//! Synthetic genome + sequencing-read generator (the 1000-Genomes
//! HG02666 substitute, DESIGN.md §3).
//!
//! Builds a multi-chromosome reference, plants heterozygous/homozygous
//! SNPs at a controlled rate (humans: ~1/850 bp, §1.3.2), then emits
//! FASTQ reads sampled uniformly with sequencing errors — the same
//! dataflow 30x-coverage resequencing gives the paper's SNP pipeline.
//! Everything is seed-deterministic, and the planted truth set is
//! returned so tests can score the pipeline's calls.

use crate::formats::fasta::{Contig, Reference};
use crate::formats::fastq::{self, FastqRead};
use crate::util::rng::Rng;

/// One planted variant (the truth set).
#[derive(Debug, Clone, PartialEq)]
pub struct PlantedSnp {
    pub chrom: String,
    /// 0-based position in the reference.
    pub pos: usize,
    pub ref_base: u8,
    pub alt_base: u8,
    /// true: both haplotypes carry alt (expect 1/1); false: het (0/1).
    pub homozygous: bool,
}

/// Generator parameters.
#[derive(Debug, Clone)]
pub struct ReadSimConfig {
    pub seed: u64,
    pub chromosomes: usize,
    pub chromosome_len: usize,
    /// SNP rate per bp (humans ≈ 1/850).
    pub snp_rate: f64,
    pub read_len: usize,
    /// Mean coverage depth (the paper's data is 30x).
    pub coverage: f64,
    /// Per-base sequencing error rate.
    pub error_rate: f64,
}

impl Default for ReadSimConfig {
    fn default() -> Self {
        ReadSimConfig {
            seed: 1000,
            chromosomes: 4,
            chromosome_len: 4000,
            snp_rate: 1.0 / 850.0,
            read_len: 100,
            coverage: 30.0,
            error_rate: 0.01,
        }
    }
}

/// A generated individual: reference, diploid sample genome, truth set.
pub struct Individual {
    pub reference: Reference,
    /// Two haplotypes per chromosome (sample genome with planted SNPs).
    pub haplotypes: Vec<[Vec<u8>; 2]>,
    pub truth: Vec<PlantedSnp>,
}

const BASES: [u8; 4] = [b'A', b'C', b'G', b'T'];

fn other_base(rng: &mut Rng, b: u8) -> u8 {
    loop {
        let c = BASES[rng.below(4)];
        if c != b {
            return c;
        }
    }
}

/// Build the reference + sample haplotypes + truth set.
pub fn individual(cfg: &ReadSimConfig) -> Individual {
    let mut rng = Rng::new(cfg.seed);
    let mut contigs = Vec::with_capacity(cfg.chromosomes);
    let mut haplotypes = Vec::with_capacity(cfg.chromosomes);
    let mut truth = Vec::new();

    for c in 0..cfg.chromosomes {
        let name = format!("chr{}", c + 1);
        // human-like size skew: chr1 is ~5x chr21; lengths taper from
        // ~1.55x the mean down to ~0.45x (mean preserved). This is what
        // makes the chromosome-grouped GATK stage straggle (§1.3.2).
        let w = if cfg.chromosomes > 1 {
            1.55 - 1.1 * c as f64 / (cfg.chromosomes - 1) as f64
        } else {
            1.0
        };
        let len = ((cfg.chromosome_len as f64 * w).round() as usize).max(cfg.read_len);
        let seq: Vec<u8> = (0..len).map(|_| BASES[rng.below(4)]).collect();
        let mut hap0 = seq.clone();
        let mut hap1 = seq.clone();
        for pos in 0..seq.len() {
            if rng.f64() < cfg.snp_rate {
                let alt = other_base(&mut rng, seq[pos]);
                let homozygous = rng.bool(0.5);
                hap0[pos] = alt;
                if homozygous {
                    hap1[pos] = alt;
                }
                truth.push(PlantedSnp {
                    chrom: name.clone(),
                    pos,
                    ref_base: seq[pos],
                    alt_base: alt,
                    homozygous,
                });
            }
        }
        contigs.push(Contig { name, seq });
        haplotypes.push([hap0, hap1]);
    }

    Individual { reference: Reference { contigs }, haplotypes, truth }
}

/// Emit FASTQ reads of the individual at the configured coverage.
pub fn reads(cfg: &ReadSimConfig, ind: &Individual) -> Vec<FastqRead> {
    let mut rng = Rng::new(cfg.seed ^ 0x5EED_5EED);
    let mut out = Vec::new();
    let mut read_id = 0u64;
    for (ci, contig) in ind.reference.contigs.iter().enumerate() {
        if contig.seq.len() < cfg.read_len {
            continue;
        }
        let n_reads =
            (contig.seq.len() as f64 * cfg.coverage / cfg.read_len as f64).round() as usize;
        for _ in 0..n_reads {
            let hap = &ind.haplotypes[ci][rng.below(2)];
            let start = rng.below(hap.len() - cfg.read_len + 1);
            let mut seq = hap[start..start + cfg.read_len].to_vec();
            for b in seq.iter_mut() {
                if rng.f64() < cfg.error_rate {
                    *b = other_base(&mut rng, *b);
                }
            }
            out.push(FastqRead {
                id: format!("sim.{read_id}/1").into(),
                seq: seq.into(),
                qual: vec![b'I'; cfg.read_len].into(),
            });
            read_id += 1;
        }
    }
    out
}

/// Full FASTQ document (Listing 3's `readsRDD` payload).
pub fn reads_fastq(cfg: &ReadSimConfig) -> (String, Individual) {
    let ind = individual(cfg);
    let r = reads(cfg, &ind);
    (fastq::write_many(&r), ind)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> ReadSimConfig {
        ReadSimConfig {
            seed: 7,
            chromosomes: 2,
            chromosome_len: 1500,
            coverage: 10.0,
            ..Default::default()
        }
    }

    #[test]
    fn deterministic() {
        let (a, _) = reads_fastq(&small());
        let (b, _) = reads_fastq(&small());
        assert_eq!(a, b);
    }

    #[test]
    fn coverage_approximately_met() {
        let cfg = small();
        let ind = individual(&cfg);
        let r = reads(&cfg, &ind);
        let total_bases: usize = r.iter().map(|x| x.seq.len()).sum();
        let genome: usize = ind.reference.total_len();
        let cov = total_bases as f64 / genome as f64;
        assert!((cov - cfg.coverage).abs() < 1.0, "coverage {cov}");
    }

    #[test]
    fn truth_set_rate_plausible() {
        let cfg = ReadSimConfig { chromosome_len: 20_000, ..small() };
        let ind = individual(&cfg);
        let rate = ind.truth.len() as f64 / ind.reference.total_len() as f64;
        // 1/850 ± slack
        assert!((0.0003..0.004).contains(&rate), "snp rate {rate}");
        // alt never equals ref
        assert!(ind.truth.iter().all(|s| s.ref_base != s.alt_base));
    }

    #[test]
    fn reads_parse_as_fastq() {
        let (text, _) = reads_fastq(&small());
        let parsed = crate::formats::fastq::parse_many(&text.into()).unwrap();
        assert!(!parsed.is_empty());
        assert!(parsed.iter().all(|r| r.seq.len() == 100));
    }

    #[test]
    fn most_reads_align_to_their_individual() {
        let cfg = small();
        let ind = individual(&cfg);
        let r = reads(&cfg, &ind);
        let idx = crate::tools::bwa::RefIndex::build(ind.reference.clone());
        let aligned = r.iter().filter(|x| idx.align(&x.seq).is_some()).count();
        let frac = aligned as f64 / r.len() as f64;
        assert!(frac > 0.9, "aligned fraction {frac}");
    }
}
