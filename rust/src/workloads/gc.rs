//! GC-count pipeline — Listing 1, verbatim, through the fluent
//! pipeline-IR API.

use std::sync::Arc;

use crate::cluster::Cluster;
use crate::dataset::Dataset;
use crate::error::Result;
use crate::mare::{Job, MaRe};
use crate::util::rng::Rng;

/// Listing 1: count G/C occurrences in a genome with POSIX tools from
/// the `ubuntu` image.
pub fn pipeline(cluster: Arc<Cluster>, genome: Dataset) -> Job {
    MaRe::source(cluster, genome)
        .map("ubuntu", "grep -o '[GC]' /dna | wc -l > /count")
        .mounts("/dna", "/count")
        .reduce("ubuntu", "awk '{s+=$1} END {print s}' /counts > /sum")
        .mounts("/counts", "/sum")
        .depth(2)
        .build()
        .expect("the GC pipeline is statically valid")
}

/// Run end-to-end and parse the count.
pub fn run(cluster: Arc<Cluster>, genome: Dataset) -> Result<u64> {
    let text = pipeline(cluster, genome).collect_text()?;
    text.trim().parse().map_err(|_| {
        crate::error::MareError::Dataset(format!("gc pipeline returned non-count `{text}`"))
    })
}

/// Deterministic synthetic DNA (one line per record).
pub fn genome_text(seed: u64, lines: usize, line_len: usize) -> String {
    let mut rng = Rng::new(seed);
    let mut out = String::with_capacity(lines * (line_len + 1));
    for _ in 0..lines {
        for _ in 0..line_len {
            out.push(['A', 'C', 'G', 'T'][rng.below(4)]);
        }
        out.push('\n');
    }
    out
}

/// Driver-side oracle.
pub fn oracle(genome: &str) -> u64 {
    genome.chars().filter(|c| *c == 'G' || *c == 'C' || *c == 'g' || *c == 'c').count()
        as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;
    use crate::container::Registry;
    use crate::tools::images;

    fn cluster() -> Arc<Cluster> {
        let mut reg = Registry::new();
        reg.push(images::ubuntu());
        Arc::new(Cluster::new(Arc::new(reg), None, ClusterConfig::sized(4, 2)))
    }

    #[test]
    fn matches_oracle_across_partitionings() {
        let genome = genome_text(11, 64, 80);
        let want = oracle(&genome);
        for parts in [1usize, 3, 16] {
            let ds = Dataset::parallelize_text(&genome, "\n", parts);
            assert_eq!(run(cluster(), ds).unwrap(), want, "parts={parts}");
        }
    }

    #[test]
    fn empty_genome_counts_zero() {
        // grep matches nothing; awk prints empty sum => "" parse fails;
        // guard: single empty record
        let ds = Dataset::parallelize_text("AATT", "\n", 1);
        assert_eq!(run(cluster(), ds).unwrap(), 0);
    }
}
