//! Synthetic molecular-library generator (the SureChEMBL substitute,
//! DESIGN.md §3).
//!
//! The paper screens ~2.2 M molecules from SureChEMBL/ZINC. The bench
//! varies data *volume*, not chemistry, so we generate deterministic,
//! structurally plausible small molecules: 8–48 heavy atoms, organic
//! element distribution, 3D coordinates clustered like a conformer.
//! Seeded: the same (seed, index) always yields the same molecule, so
//! distributed and single-core runs can be compared molecule-by-molecule
//! (the paper's own 1 K-sample correctness check).

use std::collections::BTreeMap;

use crate::formats::sdf::{self, Atom, Molecule};
use crate::util::rng::Rng;

/// Organic elements with rough SureChEMBL abundances.
const ELEMENTS: [(&str, f64); 7] = [
    ("C", 0.68),
    ("N", 0.10),
    ("O", 0.12),
    ("S", 0.03),
    ("F", 0.03),
    ("Cl", 0.03),
    ("P", 0.01),
];

/// Generate molecule `index` of library `seed`.
pub fn molecule(seed: u64, index: u64) -> Molecule {
    let mut rng = Rng::new(seed ^ index.wrapping_mul(0x9E3779B97F4A7C15));
    let natoms = rng.range(8, 48);
    // conformer-ish: atoms on a random-walk backbone + jitter
    let (mut x, mut y, mut z) = (0f32, 0f32, 0f32);
    // coordinates quantized to the SDF's 4-decimal precision so
    // serialization round-trips exactly (distributed-vs-oracle checks
    // compare molecules structurally)
    let q = |v: f32| (v * 1e4).round() / 1e4;
    let atoms = (0..natoms)
        .map(|_| {
            x += rng.range_f32(-1.6, 1.6);
            y += rng.range_f32(-1.6, 1.6);
            z += rng.range_f32(-1.6, 1.6);
            Atom { x: q(x), y: q(y), z: q(z), element: pick_element(&mut rng).to_string() }
        })
        .collect();
    let mut tags = BTreeMap::new();
    tags.insert("SureChEMBL ID".into(), format!("SCHEMBL{:08}", index + 1));
    Molecule { name: format!("SCHEMBL{:08}", index + 1), atoms, tags }
}

fn pick_element(rng: &mut Rng) -> &'static str {
    let mut p = rng.f64();
    for (e, w) in ELEMENTS {
        if p < w {
            return e;
        }
        p -= w;
    }
    "C"
}

/// Generate a library of `n` molecules as SDF text (Listing 2's
/// `libraryRDD` payload, separator `\n$$$$\n`).
pub fn library_sdf(seed: u64, n: usize) -> String {
    let mols: Vec<Molecule> = (0..n as u64).map(|i| molecule(seed, i)).collect();
    sdf::write_many(&mols)
}

/// Average serialized size of one molecule (bytes) — sizing helper for
/// benches that target a byte budget.
pub fn avg_molecule_bytes(seed: u64) -> usize {
    let sample = library_sdf(seed, 64);
    sample.len() / 64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed_and_index() {
        assert_eq!(molecule(1, 5), molecule(1, 5));
        assert_ne!(molecule(1, 5), molecule(1, 6));
        assert_ne!(molecule(1, 5), molecule(2, 5));
    }

    #[test]
    fn library_roundtrips_through_sdf() {
        let text = library_sdf(7, 20);
        let mols = sdf::parse_many(&text).unwrap();
        assert_eq!(mols.len(), 20);
        assert_eq!(mols[3], molecule(7, 3));
        assert!(mols.iter().all(|m| (8..48).contains(&m.atoms.len())));
    }

    #[test]
    fn molecules_are_mostly_carbon() {
        let mols: Vec<Molecule> = (0..100).map(|i| molecule(3, i)).collect();
        let (c, total) = mols.iter().flat_map(|m| &m.atoms).fold((0u32, 0u32), |(c, t), a| {
            (c + u32::from(a.element == "C"), t + 1)
        });
        let frac = c as f64 / total as f64;
        assert!((0.55..0.8).contains(&frac), "carbon fraction {frac}");
    }

    #[test]
    fn ids_are_stable_and_unique() {
        let text = library_sdf(1, 50);
        let mols = sdf::parse_many(&text).unwrap();
        let ids: std::collections::HashSet<_> = mols.iter().map(|m| &m.name).collect();
        assert_eq!(ids.len(), 50);
        assert!(mols[0].tags.contains_key("SureChEMBL ID"));
    }
}
