//! Run configuration: CLI flags / JSON config file → a fully-resolved
//! [`RunConfigFile`] describing cluster shape, storage backend, workload
//! and scale. The `mare` binary and the benches share this so every
//! experiment is reproducible from a single description.

use crate::cluster::{ClusterConfig, FaultSpec, SpeculationPolicy};
use crate::error::{MareError, Result};
use crate::simtime::Duration;
use crate::util::cli::Args;
use crate::util::json::Json;

/// Which storage backend serves the input.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Hdfs,
    Swift,
    S3,
    Local,
    /// Real filesystem objects (`file:///abs/path`): the key IS the
    /// path. Unlike the simulated stores, `file://` objects are
    /// *writable* through the catalog (checkpoint state lives here) and
    /// are NOT deterministically populated, so they cannot serve as
    /// ingest sources.
    File,
}

impl BackendKind {
    /// Every registered backend, in registry order — the ONE table the
    /// scheme lists elsewhere (storage catalog, error messages) derive
    /// from, so adding a backend here propagates everywhere.
    pub const ALL: [BackendKind; 5] = [
        BackendKind::Hdfs,
        BackendKind::Swift,
        BackendKind::S3,
        BackendKind::Local,
        BackendKind::File,
    ];

    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "hdfs" => Ok(BackendKind::Hdfs),
            "swift" => Ok(BackendKind::Swift),
            "s3" => Ok(BackendKind::S3),
            "local" => Ok(BackendKind::Local),
            "file" => Ok(BackendKind::File),
            other => Err(MareError::Config(format!(
                "unknown storage backend `{other}` (hdfs|swift|s3|local|file)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Hdfs => "hdfs",
            BackendKind::Swift => "swift",
            BackendKind::S3 => "s3",
            BackendKind::Local => "local",
            BackendKind::File => "file",
        }
    }
}

/// Which pipeline to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Workload {
    Gc,
    Vs,
    Snp,
    Kmer,
}

impl Workload {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "gc" => Ok(Workload::Gc),
            "vs" | "virtual-screening" => Ok(Workload::Vs),
            "snp" | "snp-calling" => Ok(Workload::Snp),
            "kmer" | "kmer-stats" => Ok(Workload::Kmer),
            other => Err(MareError::Config(format!(
                "unknown workload `{other}` (gc|vs|snp|kmer)"
            ))),
        }
    }
}

/// A fully-resolved run description.
#[derive(Debug, Clone)]
pub struct RunConfigFile {
    pub workload: Workload,
    pub backend: BackendKind,
    pub cluster: ClusterConfig,
    /// Scale knob: molecules for VS, reads for SNP, lines for GC/kmer.
    pub scale: usize,
    pub seed: u64,
    /// Tree-reduce depth (VS / GC).
    pub reduce_depth: usize,
    pub artifacts: String,
}

impl Default for RunConfigFile {
    fn default() -> Self {
        RunConfigFile {
            workload: Workload::Gc,
            backend: BackendKind::Hdfs,
            cluster: ClusterConfig::paper(),
            scale: 1000,
            seed: 42,
            reduce_depth: 2,
            artifacts: crate::workloads::artifact_dir(),
        }
    }
}

impl RunConfigFile {
    /// From CLI flags (`--workload vs --workers 16 --vcpus 8 ...`),
    /// optionally starting from `--config file.json`.
    pub fn from_args(args: &Args) -> Result<Self> {
        let mut cfg = match args.flag("config") {
            Some(path) => Self::from_json_file(path)?,
            None => Self::default(),
        };
        if let Some(w) = args.flag("workload") {
            cfg.workload = Workload::parse(w)?;
        }
        if let Some(b) = args.flag("storage") {
            cfg.backend = BackendKind::parse(b)?;
        }
        let workers = args.flag_usize("workers", cfg.cluster.workers)?;
        let vcpus = args.flag_usize("vcpus", cfg.cluster.vcpus_per_worker as usize)?;
        let mut cluster = ClusterConfig::sized(workers, vcpus as u32);
        cluster.locality_wait = cfg.cluster.locality_wait;
        cluster.seed = args.flag_u64("seed", cfg.seed)?;
        cluster.fault = cfg.cluster.fault;
        cluster.speculation = cfg.cluster.speculation;
        // `--fault` is shared with the pool's worker-death grammar
        // (`W:K:hold|running|midrun[@S]`, parsed by `mare work`/`mare
        // serve` into a FaultPlan) — only the straggler form `W:slow:F`
        // targets the simulated cluster, so that's the one we claim
        if let Some(spec) = args.flag("fault") {
            if spec.contains(":slow:") {
                cluster.fault = Some(FaultSpec::parse(spec).map_err(MareError::Config)?);
            }
        }
        if args.flag_bool("speculate") {
            cluster.speculation = Some(SpeculationPolicy::default());
        }
        cfg.cluster = cluster;
        cfg.scale = args.flag_usize("scale", cfg.scale)?;
        cfg.seed = args.flag_u64("seed", cfg.seed)?;
        cfg.reduce_depth = args.flag_usize("reduce-depth", cfg.reduce_depth)?;
        if let Some(a) = args.flag("artifacts") {
            cfg.artifacts = a.to_string();
        }
        Ok(cfg)
    }

    pub fn from_json_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut cfg = Self::default();
        if let Some(w) = j.get("workload") {
            cfg.workload = Workload::parse(w.as_str()?)?;
        }
        if let Some(b) = j.get("storage") {
            cfg.backend = BackendKind::parse(b.as_str()?)?;
        }
        if let Some(c) = j.get("cluster") {
            let workers = c.get("workers").map(|v| v.as_usize()).transpose()?.unwrap_or(16);
            let vcpus = c.get("vcpus").map(|v| v.as_usize()).transpose()?.unwrap_or(8);
            cfg.cluster = ClusterConfig::sized(workers, vcpus as u32);
            if let Some(lw) = c.get("locality_wait_s") {
                cfg.cluster.locality_wait = Duration::seconds(lw.as_f64()?);
            }
            if let Some(f) = c.get("fault") {
                cfg.cluster.fault =
                    Some(FaultSpec::parse(f.as_str()?).map_err(MareError::Config)?);
            }
            if let Some(s) = c.get("speculate") {
                if s.as_bool()? {
                    cfg.cluster.speculation = Some(SpeculationPolicy::default());
                }
            }
        }
        if let Some(s) = j.get("scale") {
            cfg.scale = s.as_usize()?;
        }
        if let Some(s) = j.get("seed") {
            cfg.seed = s.as_u64()?;
            cfg.cluster.seed = cfg.seed;
        }
        if let Some(d) = j.get("reduce_depth") {
            cfg.reduce_depth = d.as_usize()?;
        }
        if let Some(a) = j.get("artifacts") {
            cfg.artifacts = a.as_str()?.to_string();
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn defaults_match_paper_testbed() {
        let cfg = RunConfigFile::default();
        assert_eq!(cfg.cluster.workers, 16);
        assert_eq!(cfg.cluster.vcpus_per_worker, 8);
        assert_eq!(cfg.reduce_depth, 2);
    }

    #[test]
    fn cli_flags_override() {
        let cfg = RunConfigFile::from_args(&args(&[
            "run",
            "--workload",
            "vs",
            "--storage=swift",
            "--workers",
            "4",
            "--vcpus",
            "2",
            "--scale",
            "500",
        ]))
        .unwrap();
        assert_eq!(cfg.workload, Workload::Vs);
        assert_eq!(cfg.backend, BackendKind::Swift);
        assert_eq!(cfg.cluster.workers, 4);
        assert_eq!(cfg.cluster.vcpus_per_worker, 2);
        assert_eq!(cfg.scale, 500);
    }

    #[test]
    fn json_config_parses() {
        let j = Json::parse(
            r#"{"workload":"snp","storage":"s3",
                "cluster":{"workers":8,"vcpus":8,"locality_wait_s":1.5},
                "scale":2000,"seed":7,"reduce_depth":3}"#,
        )
        .unwrap();
        let cfg = RunConfigFile::from_json(&j).unwrap();
        assert_eq!(cfg.workload, Workload::Snp);
        assert_eq!(cfg.backend, BackendKind::S3);
        assert_eq!(cfg.cluster.workers, 8);
        assert_eq!(cfg.cluster.locality_wait, Duration::seconds(1.5));
        assert_eq!(cfg.reduce_depth, 3);
        assert_eq!(cfg.cluster.seed, 7);
    }

    #[test]
    fn straggler_and_speculation_flags_reach_the_cluster() {
        let cfg = RunConfigFile::from_args(&args(&[
            "run",
            "--fault",
            "0:slow:4",
            "--speculate",
        ]))
        .unwrap();
        assert_eq!(cfg.cluster.fault, Some(FaultSpec::SlowWorker { worker: 0, factor: 4.0 }));
        assert_eq!(cfg.cluster.speculation, Some(SpeculationPolicy::default()));

        // the pool's worker-death grammar is NOT ours to claim: `mare
        // work --fault 1:2:hold` must pass through to FaultPlan::parse
        let cfg = RunConfigFile::from_args(&args(&["work", "--fault", "1:2:hold"])).unwrap();
        assert_eq!(cfg.cluster.fault, None);
        assert_eq!(cfg.cluster.speculation, None);

        // a malformed straggler spec is an error, not a silent ignore
        assert!(RunConfigFile::from_args(&args(&["run", "--fault", "x:slow:4"])).is_err());
    }

    #[test]
    fn json_config_wires_fault_and_speculation() {
        let j = Json::parse(
            r#"{"cluster":{"workers":4,"vcpus":2,"fault":"1:slow:3","speculate":true}}"#,
        )
        .unwrap();
        let cfg = RunConfigFile::from_json(&j).unwrap();
        assert_eq!(cfg.cluster.fault, Some(FaultSpec::SlowWorker { worker: 1, factor: 3.0 }));
        assert_eq!(cfg.cluster.speculation, Some(SpeculationPolicy::default()));

        // CLI flags layered on a config file keep the file's settings
        // (no flag given) and can still override the shape
        let base = r#"{"cluster":{"workers":4,"vcpus":2,"speculate":true}}"#;
        let dir = std::env::temp_dir().join("mare_cfg_spec_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        std::fs::write(&path, base).unwrap();
        let cfg = RunConfigFile::from_args(&args(&[
            "run",
            "--config",
            path.to_str().unwrap(),
            "--workers",
            "8",
        ]))
        .unwrap();
        assert_eq!(cfg.cluster.workers, 8);
        assert_eq!(cfg.cluster.speculation, Some(SpeculationPolicy::default()));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn backend_registry_is_self_consistent() {
        // ALL is the one table: every entry round-trips name -> parse
        for k in BackendKind::ALL {
            assert_eq!(BackendKind::parse(k.name()).unwrap(), k);
        }
    }

    #[test]
    fn bad_values_error_helpfully() {
        assert!(BackendKind::parse("gcs").is_err());
        assert!(Workload::parse("montecarlo").is_err());
        assert!(RunConfigFile::from_args(&args(&["run", "--workers", "x"])).is_err());
    }
}
