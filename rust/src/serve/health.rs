//! The operator surface: `serve-health.json` and `serve-stats.json`.
//!
//! Both files live in the spool directory next to the jobs they
//! describe and are rewritten atomically (temp+rename) every
//! supervisor tick, so `watch cat serve-health.json` — or any poller —
//! always reads one complete snapshot and never a torn write.
//! `serve-health.json` answers "is the service OK right now" (depth
//! vs limit, worker liveness, per-tenant progress); `serve-stats.json`
//! is the counter dump monitoring systems scrape. A final snapshot of
//! both is written after the worker fleet joins, so post-mortem reads
//! (and the cross-process stress gate's audits) see exact totals.

use std::fs;
use std::path::Path;

use crate::error::Result;
use crate::metrics::counters::CounterSnapshot;
use crate::util::json::Json;

/// File names inside the spool directory.
pub const HEALTH_FILE: &str = "serve-health.json";
pub const STATS_FILE: &str = "serve-stats.json";

/// Per-tenant progress snapshot for the health file.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TenantHealth {
    pub tenant: String,
    pub queued: u64,
    pub running: u64,
    pub completed: u64,
}

/// Per-worker liveness/throughput row for the stats file.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct WorkerHealth {
    pub worker: String,
    pub claimed: u64,
    pub jobs_run: u64,
    pub launches: u64,
    /// Milliseconds since this worker's last heartbeat at snapshot
    /// time; `None` once the worker has exited (drain or death).
    pub beat_age_ms: Option<u64>,
    /// The injected/diagnosed death note, if the worker died.
    pub died: Option<String>,
}

/// Everything one supervisor tick knows — rendered into both files.
#[derive(Debug, Clone, Default)]
pub struct HealthReport {
    pub pid: u32,
    pub started_ms: u64,
    pub tick: u64,
    pub draining: bool,
    pub final_snapshot: bool,
    pub queued: u64,
    pub held: u64,
    pub max_depth: u64,
    pub tenants: Vec<TenantHealth>,
    pub workers: Vec<WorkerHealth>,
    pub counters: CounterSnapshot,
}

impl HealthReport {
    /// The `serve-health.json` schema.
    pub fn health_json(&self) -> Json {
        let tenants = Json::Obj(
            self.tenants
                .iter()
                .map(|t| {
                    (
                        t.tenant.clone(),
                        Json::obj(vec![
                            ("queued", Json::Num(t.queued as f64)),
                            ("running", Json::Num(t.running as f64)),
                            ("completed", Json::Num(t.completed as f64)),
                        ]),
                    )
                })
                .collect(),
        );
        let alive = self.workers.iter().filter(|w| w.beat_age_ms.is_some()).count();
        Json::obj(vec![
            ("pid", Json::Num(self.pid as f64)),
            ("started_ms", Json::Num(self.started_ms as f64)),
            ("tick", Json::Num(self.tick as f64)),
            ("draining", Json::Bool(self.draining)),
            ("final", Json::Bool(self.final_snapshot)),
            (
                "depth",
                Json::obj(vec![
                    ("queued", Json::Num(self.queued as f64)),
                    ("held", Json::Num(self.held as f64)),
                    ("max_depth", Json::Num(self.max_depth as f64)),
                ]),
            ),
            (
                "workers",
                Json::obj(vec![
                    ("alive", Json::Num(alive as f64)),
                    ("total", Json::Num(self.workers.len() as f64)),
                ]),
            ),
            ("tenants", tenants),
        ])
    }

    /// The `serve-stats.json` schema: the counter dump plus per-worker
    /// rows.
    pub fn stats_json(&self) -> Json {
        let workers = Json::Arr(
            self.workers
                .iter()
                .map(|w| {
                    Json::obj(vec![
                        ("worker", Json::str(w.worker.as_str())),
                        ("claimed", Json::Num(w.claimed as f64)),
                        ("jobs_run", Json::Num(w.jobs_run as f64)),
                        ("launches", Json::Num(w.launches as f64)),
                        (
                            "beat_age_ms",
                            match w.beat_age_ms {
                                Some(a) => Json::Num(a as f64),
                                None => Json::Null,
                            },
                        ),
                        (
                            "died",
                            match &w.died {
                                Some(d) => Json::str(d.as_str()),
                                None => Json::Null,
                            },
                        ),
                    ])
                })
                .collect(),
        );
        let mut fields = vec![
            ("pid".to_string(), Json::Num(self.pid as f64)),
            ("tick".to_string(), Json::Num(self.tick as f64)),
            ("final".to_string(), Json::Bool(self.final_snapshot)),
        ];
        if let Json::Obj(counters) = self.counters.to_json() {
            fields.extend(counters);
        }
        fields.push(("workers".to_string(), workers));
        Json::Obj(fields)
    }

    /// Write both files atomically into `dir`.
    pub fn publish(&self, dir: &Path) -> Result<()> {
        write_json(dir, HEALTH_FILE, &self.health_json())?;
        write_json(dir, STATS_FILE, &self.stats_json())
    }
}

/// The spool's atomic-publish idiom for operator files.
fn write_json(dir: &Path, name: &str, json: &Json) -> Result<()> {
    let tmp = dir.join(format!(
        "{name}.tmp-{}-{}",
        std::process::id(),
        crate::submit::queue::now_millis()
    ));
    fs::write(&tmp, json.to_string_pretty())?;
    fs::rename(&tmp, dir.join(name))?;
    Ok(())
}

/// Read and parse an operator file; `Ok(None)` when it does not exist
/// (daemon never started / already cleaned up).
pub fn read_json(dir: &Path, name: &str) -> Result<Option<Json>> {
    match fs::read_to_string(dir.join(name)) {
        Ok(text) => Ok(Some(Json::parse(&text)?)),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
        Err(e) => Err(e.into()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::counters::ServeCounters;

    fn report() -> HealthReport {
        let counters = ServeCounters::default();
        ServeCounters::add(&counters.claims, 5);
        ServeCounters::add(&counters.launches, 20);
        HealthReport {
            pid: 4242,
            started_ms: 1_700_000_000_000,
            tick: 17,
            draining: false,
            final_snapshot: false,
            queued: 3,
            held: 1,
            max_depth: 64,
            tenants: vec![
                TenantHealth { tenant: "alpha".into(), queued: 2, running: 1, completed: 4 },
                TenantHealth { tenant: "beta".into(), queued: 1, running: 0, completed: 1 },
            ],
            workers: vec![
                WorkerHealth {
                    worker: "serve-0".into(),
                    claimed: 3,
                    jobs_run: 3,
                    launches: 12,
                    beat_age_ms: Some(40),
                    died: None,
                },
                WorkerHealth {
                    worker: "serve-1".into(),
                    claimed: 2,
                    jobs_run: 1,
                    launches: 8,
                    beat_age_ms: None,
                    died: Some("injected: died mid-claim".into()),
                },
            ],
            counters: counters.snapshot(),
        }
    }

    #[test]
    fn health_json_reports_depth_liveness_and_tenants() {
        let h = report().health_json();
        let depth = h.req("depth").unwrap();
        assert_eq!(depth.req("queued").unwrap().as_u64().unwrap(), 3);
        assert_eq!(depth.req("max_depth").unwrap().as_u64().unwrap(), 64);
        let workers = h.req("workers").unwrap();
        assert_eq!(workers.req("alive").unwrap().as_u64().unwrap(), 1);
        assert_eq!(workers.req("total").unwrap().as_u64().unwrap(), 2);
        let alpha = h.req("tenants").unwrap().req("alpha").unwrap();
        assert_eq!(alpha.req("completed").unwrap().as_u64().unwrap(), 4);
    }

    #[test]
    fn stats_json_carries_counters_and_worker_rows() {
        let s = report().stats_json();
        assert_eq!(s.req("claims").unwrap().as_u64().unwrap(), 5);
        assert_eq!(s.req("launches").unwrap().as_u64().unwrap(), 20);
        let rows = s.req("workers").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].req("launches").unwrap().as_u64().unwrap(), 12);
        assert!(matches!(rows[0].req("died").unwrap(), Json::Null));
        assert!(rows[1]
            .req("died")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("mid-claim"));
    }

    #[test]
    fn publish_lands_both_files_atomically_and_read_back() {
        let dir = std::env::temp_dir()
            .join(format!("mare-serve-health-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();

        assert_eq!(read_json(&dir, HEALTH_FILE).unwrap(), None);
        report().publish(&dir).unwrap();
        let health = read_json(&dir, HEALTH_FILE).unwrap().unwrap();
        assert_eq!(health.req("pid").unwrap().as_u64().unwrap(), 4242);
        let stats = read_json(&dir, STATS_FILE).unwrap().unwrap();
        assert_eq!(stats.req("tick").unwrap().as_u64().unwrap(), 17);
        // no temp litter left behind
        let litter: Vec<String> = fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().to_string())
            .filter(|n| n.contains(".tmp-"))
            .collect();
        assert!(litter.is_empty(), "{litter:?}");

        let _ = fs::remove_dir_all(&dir);
    }
}
