//! The resident daemon loop behind `mare serve`.
//!
//! A [`ServeDaemon`] owns a persistent [`WorkerPool`] fleet in resident
//! mode plus one supervisor loop. The workers claim/execute/finish
//! against the shared spool exactly as `mare work` does — same rename
//! protocol, same exactly-once guarantees — while the daemon's
//! [`ServeHooks`] impl layers the service semantics on top:
//!
//! * claim ordering via the [`FairShare`] policy (weights from the
//!   control file, reloaded every tick),
//! * a monotone claim sequence stamped into each record so fairness is
//!   auditable post-hoc from the spool alone,
//! * counters + per-worker cells feeding the atomic
//!   `serve-health.json`/`serve-stats.json` snapshots each tick,
//! * self-healing: jobs a crashed worker left stuck `running` are
//!   force-requeued by the supervisor (the one-shot pool leaves them
//!   for `mare requeue`; a resident service must not),
//! * drain: the control file's flag flips the hooks' `draining()`
//!   answer within one tick, workers finish in-flight work and exit,
//!   and a final snapshot with exact totals is published.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::thread;
use std::time::Duration;

use crate::error::{MareError, Result};
use crate::metrics::counters::ServeCounters;
use crate::submit::pool::{PoolConfig, PoolOutcome, ServeHooks, WorkerPool};
use crate::submit::queue::{now_millis, ClaimStats, JobFailure, JobQueue, JobRecord, JobStatus};

use super::control::{self, Control};
use super::health::{HealthReport, TenantHealth, WorkerHealth};
use super::policy::FairShare;

/// Everything `mare serve` is configured with.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// The worker fleet (size, cluster shape, fault plan, poll/sweep
    /// cadence) — shared with the one-shot `mare work` path.
    pub pool: PoolConfig,
    /// Supervisor cadence: control reload, orphan requeue, health
    /// publish.
    pub tick: Duration,
    /// Admission depth limit advertised in the control file; 0 = none.
    pub max_depth: usize,
    /// Initial tenant weight table (control-file reloads override it).
    pub quotas: Vec<(String, u64)>,
    /// Dead-letter threshold advertised in the control file: a job
    /// whose attempt counter reaches this is moved to `dlq/` by the
    /// supervisor sweep instead of being retried. 0 disables both the
    /// sweep and automatic retries (failed jobs stay `failed`).
    pub max_attempts: u64,
}

impl ServeConfig {
    pub fn new(pool: PoolConfig) -> ServeConfig {
        ServeConfig {
            pool,
            tick: Duration::from_millis(200),
            max_depth: 256,
            quotas: Vec::new(),
            max_attempts: 0,
        }
    }
}

/// What a completed (drained) service run reports.
#[derive(Debug)]
pub struct ServeOutcome {
    /// The fleet's authoritative per-worker reports and finish records.
    pub outcome: PoolOutcome,
    /// Supervisor ticks executed before the fleet exited.
    pub ticks: u64,
    /// Jobs the supervisor force-requeued after worker deaths.
    pub orphans_requeued: u64,
}

/// Per-worker atomic cell the hooks write and the supervisor snapshots.
#[derive(Debug, Default)]
struct WorkerCell {
    claimed: AtomicU64,
    jobs_run: AtomicU64,
    launches: AtomicU64,
    beat_ms: AtomicU64,
}

/// The daemon's [`ServeHooks`] impl — all interior-mutable, shared
/// between N worker threads and the supervisor.
struct DaemonHooks {
    policy: Mutex<FairShare>,
    counters: ServeCounters,
    draining: AtomicBool,
    claim_seq: AtomicU64,
    cells: Vec<WorkerCell>,
    /// Dead-letter threshold, reloaded from the control file each tick
    /// so operators can tune it on a live daemon.
    max_attempts: AtomicU64,
    /// `(worker, job id)` pairs left stuck `running` by after-claim
    /// deaths, awaiting the supervisor's force-requeue — the worker
    /// index travels along so the requeue can charge the death against
    /// the job's failure history.
    orphans: Mutex<Vec<(usize, u64)>>,
    /// (worker, note) for every death observed so far.
    deaths: Mutex<Vec<(usize, String)>>,
}

impl DaemonHooks {
    fn new(config: &ServeConfig) -> DaemonHooks {
        DaemonHooks {
            policy: Mutex::new(FairShare::new(&config.quotas)),
            counters: ServeCounters::default(),
            draining: AtomicBool::new(false),
            claim_seq: AtomicU64::new(0),
            cells: (0..config.pool.workers).map(|_| WorkerCell::default()).collect(),
            max_attempts: AtomicU64::new(config.max_attempts),
            orphans: Mutex::new(Vec::new()),
            deaths: Mutex::new(Vec::new()),
        }
    }
}

impl ServeHooks for DaemonHooks {
    fn order(&self, candidates: &mut Vec<JobRecord>) {
        // exhausted jobs are the sweep's to dead-letter, not a worker's
        // to claim — withholding them here closes the race where a
        // worker burns an attempt K+1 while the supervisor is moving
        // the job to dlq/
        let k = self.max_attempts.load(Ordering::Relaxed);
        if k > 0 {
            candidates.retain(|job| job.attempts < k);
        }
        self.policy.lock().unwrap().order(candidates);
    }

    fn claimed(&self, worker: usize, job: &mut JobRecord) {
        // the fairness audit trail: a monotone, daemon-wide sequence
        // stamped into the record, persisted when the worker finishes
        job.claim_seq = Some(self.claim_seq.fetch_add(1, Ordering::Relaxed) + 1);
        self.policy.lock().unwrap().claimed(&job.tenant);
        ServeCounters::add(&self.counters.claims, 1);
        ServeCounters::add(&self.cells[worker].claimed, 1);
    }

    fn scanned(&self, stats: &ClaimStats) {
        ServeCounters::add(&self.counters.claim_conflicts, stats.conflicts);
        ServeCounters::add(&self.counters.claim_backoffs, stats.backoffs);
        ServeCounters::add(&self.counters.spool_parses, stats.parsed);
    }

    fn finished(&self, worker: usize, record: &JobRecord) {
        let launches = record.result.as_ref().map(|r| r.launches).unwrap_or(0);
        ServeCounters::add(&self.counters.launches, launches);
        match record.status {
            JobStatus::Failed => ServeCounters::add(&self.counters.jobs_failed, 1),
            _ => ServeCounters::add(&self.counters.jobs_done, 1),
        }
        ServeCounters::add(&self.cells[worker].jobs_run, 1);
        ServeCounters::add(&self.cells[worker].launches, launches);
        self.policy.lock().unwrap().finished(&record.tenant);
    }

    fn swept(&self, count: u64) {
        ServeCounters::add(&self.counters.swept, count);
    }

    fn beat(&self, worker: usize) {
        self.cells[worker].beat_ms.store(now_millis(), Ordering::Relaxed);
    }

    fn draining(&self) -> bool {
        self.draining.load(Ordering::Acquire)
    }

    fn died(&self, worker: usize, orphaned_running: Option<u64>) {
        let note = match orphaned_running {
            Some(id) => {
                self.orphans.lock().unwrap().push((worker, id));
                format!("died leaving job {id} running")
            }
            None => "died mid-claim holding a job".to_string(),
        };
        self.deaths.lock().unwrap().push((worker, note));
    }

    fn progressed(&self, worker: usize, launches: u64) {
        // launches a mid-run death already performed: real container
        // work, credited before the worker's report is lost
        ServeCounters::add(&self.counters.launches, launches);
        ServeCounters::add(&self.cells[worker].launches, launches);
    }
}

/// The resident service: construct with a [`ServeConfig`], then
/// [`run`](ServeDaemon::run) blocks until drained.
pub struct ServeDaemon {
    config: ServeConfig,
}

impl ServeDaemon {
    pub fn new(config: ServeConfig) -> ServeDaemon {
        ServeDaemon { config }
    }

    /// Publish the control file (claiming the spool and clearing any
    /// stale drain flag from a previous daemon), run the fleet + the
    /// supervisor until a drain lands, then publish the final snapshot.
    pub fn run(&self, queue: &JobQueue) -> Result<ServeOutcome> {
        control::write(
            queue.dir(),
            &Control {
                max_depth: self.config.max_depth,
                drain: false,
                quotas: self.config.quotas.clone(),
                max_attempts: self.config.max_attempts,
                beat_ms: now_millis(),
            },
        )?;
        let hooks = DaemonHooks::new(&self.config);
        let pool = WorkerPool::new(self.config.pool.clone());
        let started_ms = now_millis();
        let done = AtomicBool::new(false);
        let mut ticks: u64 = 0;
        let mut orphans_requeued: u64 = 0;
        let mut max_depth = self.config.max_depth as u64;

        let pool_result = thread::scope(|scope| {
            let handle = scope.spawn(|| {
                let result = pool.run_resident(queue, &hooks);
                done.store(true, Ordering::Release);
                result
            });
            // the supervisor: runs on this thread until the fleet exits.
            // Each tick is best-effort — a transient spool error must
            // not kill the service, so per-tick failures are dropped
            // and the next tick retries.
            while !done.load(Ordering::Acquire) {
                ticks += 1;
                let _ = self.tick_once(
                    queue,
                    &hooks,
                    &mut max_depth,
                    &mut orphans_requeued,
                    started_ms,
                    ticks,
                );
                thread::sleep(self.config.tick);
            }
            handle
                .join()
                .unwrap_or_else(|_| Err(MareError::Submit("serve worker fleet panicked".into())))
        });
        let outcome = pool_result?;

        // the fleet is gone: recover everything it left behind so a
        // drained spool holds only `queued` and `done` work — any
        // remaining hold is ownerless (sweep with no age gate) and any
        // `running` job is a dead worker's orphan (force-requeue)
        let swept = queue.sweep_stale(Duration::ZERO)?;
        ServeCounters::add(&hooks.counters.swept, swept as u64);
        for job in queue.list()? {
            if job.status == JobStatus::Running {
                let note = JobFailure {
                    at_ms: now_millis(),
                    worker: "serve-supervisor".into(),
                    detail: "worker died leaving the job running; recovered at drain".into(),
                };
                queue.requeue_noting(job.id, Duration::ZERO, true, Some(note))?;
                orphans_requeued += 1;
                ServeCounters::add(&hooks.counters.orphans_requeued, 1);
            }
        }
        // one last dead-letter pass so the drained spool never holds a
        // job past its attempt budget — a failure landing between the
        // final supervisor tick and the fleet's exit still reaches dlq/
        let k = hooks.max_attempts.load(Ordering::Relaxed);
        if k > 0 {
            for job in queue.list()? {
                if job.attempts >= k
                    && matches!(job.status, JobStatus::Failed | JobStatus::Queued)
                    && queue.dead_letter(job.id).is_ok()
                {
                    ServeCounters::add(&hooks.counters.dead_lettered, 1);
                }
            }
        }

        let mut report = self.snapshot(queue, &hooks, max_depth, started_ms, ticks)?;
        report.draining = true;
        report.final_snapshot = true;
        // final worker rows come from the joined fleet's authoritative
        // reports, not the racy cells — post-mortem audits sum these
        report.workers = outcome
            .reports
            .iter()
            .map(|r| WorkerHealth {
                worker: r.worker.clone(),
                claimed: r.claimed,
                jobs_run: r.jobs_run,
                launches: r.launches,
                beat_age_ms: None,
                died: r.died.clone(),
            })
            .collect();
        report.publish(queue.dir())?;

        Ok(ServeOutcome { outcome, ticks, orphans_requeued })
    }

    /// One supervisor tick: reload control, heal orphans, sweep, publish.
    fn tick_once(
        &self,
        queue: &JobQueue,
        hooks: &DaemonHooks,
        max_depth: &mut u64,
        orphans_requeued: &mut u64,
        started_ms: u64,
        tick: u64,
    ) -> Result<()> {
        // settings reload + heartbeat in one locked read-modify-write:
        // submitters watch `beat_ms` to know the advertised limits are
        // still backed by a live daemon (control::BEAT_STALE_MS)
        if let Ok(c) = control::update(queue.dir(), |c| c.beat_ms = now_millis()) {
            *max_depth = c.max_depth as u64;
            hooks.max_attempts.store(c.max_attempts, Ordering::Relaxed);
            hooks.policy.lock().unwrap().set_weights(&c.quotas);
            if c.drain {
                hooks.draining.store(true, Ordering::Release);
            }
        }
        let orphans: Vec<(usize, u64)> = std::mem::take(&mut *hooks.orphans.lock().unwrap());
        for (worker, id) in orphans {
            let note = JobFailure {
                at_ms: now_millis(),
                worker: format!("serve-{worker}"),
                detail: "worker died leaving the job running; requeued by the supervisor"
                    .into(),
            };
            match queue.requeue_noting(id, Duration::ZERO, true, Some(note)) {
                Ok(_) => {
                    *orphans_requeued += 1;
                    ServeCounters::add(&hooks.counters.orphans_requeued, 1);
                }
                // contended this tick (e.g. the record is mid-rename):
                // put it back, the next tick retries
                Err(_) => hooks.orphans.lock().unwrap().push((worker, id)),
            }
        }
        // workers sweep while idle; the supervisor sweeps too so a
        // fully-busy (or decimated) fleet still recovers dead holds
        let swept = queue.sweep_stale(self.config.pool.stale_after)?;
        if swept > 0 {
            ServeCounters::add(&hooks.counters.swept, swept as u64);
        }
        // the dead-letter sweep: exhausted jobs leave the live spool;
        // failed-but-under-budget jobs get another attempt (unless a
        // drain is winding the service down — then they keep their
        // `failed` record for the operator)
        let k = hooks.max_attempts.load(Ordering::Relaxed);
        if k > 0 {
            let draining = hooks.draining.load(Ordering::Acquire);
            for job in queue.list()? {
                match job.status {
                    JobStatus::Failed | JobStatus::Queued if job.attempts >= k => {
                        if queue.dead_letter(job.id).is_ok() {
                            ServeCounters::add(&hooks.counters.dead_lettered, 1);
                        }
                    }
                    JobStatus::Failed if !draining => {
                        if queue.requeue_with(job.id, Duration::ZERO, true).is_ok() {
                            ServeCounters::add(&hooks.counters.retried, 1);
                        }
                    }
                    _ => {}
                }
            }
        }
        self.snapshot(queue, hooks, *max_depth, started_ms, tick)?
            .publish(queue.dir())
    }

    /// Assemble one [`HealthReport`] from the spool + the hooks' cells.
    fn snapshot(
        &self,
        queue: &JobQueue,
        hooks: &DaemonHooks,
        max_depth: u64,
        started_ms: u64,
        tick: u64,
    ) -> Result<HealthReport> {
        let (queued, held) = queue.pending()?;
        let now = now_millis();

        // per-tenant queued/running straight from the spool; completed
        // from the policy's tallies (finish records may be swept away
        // by operators, the tally is the service's own memory)
        let mut tenants: Vec<TenantHealth> = Vec::new();
        {
            let policy = hooks.policy.lock().unwrap();
            for name in policy.tenants() {
                tenants.push(TenantHealth {
                    tenant: name.clone(),
                    completed: policy.completed_of(&name),
                    ..TenantHealth::default()
                });
            }
        }
        for job in queue.list()? {
            let pos = match tenants.iter().position(|t| t.tenant == job.tenant) {
                Some(p) => p,
                None => {
                    tenants.push(TenantHealth {
                        tenant: job.tenant.clone(),
                        ..TenantHealth::default()
                    });
                    tenants.len() - 1
                }
            };
            match job.status {
                JobStatus::Queued => tenants[pos].queued += 1,
                JobStatus::Running => tenants[pos].running += 1,
                _ => {}
            }
        }

        let deaths = hooks.deaths.lock().unwrap();
        let workers = hooks
            .cells
            .iter()
            .enumerate()
            .map(|(idx, cell)| {
                let died = deaths
                    .iter()
                    .find(|(w, _)| *w == idx)
                    .map(|(_, note)| note.clone());
                let beat = cell.beat_ms.load(Ordering::Relaxed);
                WorkerHealth {
                    worker: format!("serve-{idx}"),
                    claimed: cell.claimed.load(Ordering::Relaxed),
                    jobs_run: cell.jobs_run.load(Ordering::Relaxed),
                    launches: cell.launches.load(Ordering::Relaxed),
                    beat_age_ms: if died.is_none() && beat > 0 {
                        Some(now.saturating_sub(beat))
                    } else {
                        None
                    },
                    died,
                }
            })
            .collect();

        Ok(HealthReport {
            pid: std::process::id(),
            started_ms,
            tick,
            draining: hooks.draining.load(Ordering::Acquire),
            final_snapshot: false,
            queued: queued as u64,
            held: held as u64,
            max_depth,
            tenants,
            workers,
            counters: hooks.counters.snapshot(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;
    use crate::serve::health::{self, HEALTH_FILE, STATS_FILE};
    use crate::submit::Submitter;

    fn tmp_queue(name: &str) -> JobQueue {
        let dir = std::env::temp_dir()
            .join(format!("mare-serve-daemon-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        JobQueue::open(dir).unwrap()
    }

    fn plan(tenant: &str) -> String {
        format!(
            r#"{{
              "version": 1,
              "tenant": "{tenant}",
              "ops": [
                {{"op": "ingest", "label": "gen:gc:8", "partitions": 2}},
                {{"op": "map", "image": "ubuntu",
                 "command": "grep -o '[GC]' /dna | wc -l > /count",
                 "input": {{"kind": "text", "path": "/dna"}},
                 "output": {{"kind": "text", "path": "/count"}}}},
                {{"op": "collect"}}
              ]
            }}"#
        )
    }

    /// In-process end-to-end: submit across tenants, run the daemon in
    /// a thread, drain via the control file, audit the exit state and
    /// the operator files.
    #[test]
    fn daemon_serves_tenants_then_drains_clean() {
        let queue = tmp_queue("drain-clean");
        let shape = ClusterConfig::sized(2, 2);
        let submitter = Submitter::new(shape.clone());
        for i in 0..8 {
            let tenant = if i % 2 == 0 { "alpha" } else { "beta" };
            submitter.submit(&queue, &plan(tenant)).unwrap();
        }

        let mut config = ServeConfig::new(PoolConfig::new(2, shape.clone()));
        config.tick = Duration::from_millis(20);
        config.max_depth = 64;
        config.quotas = vec![("alpha".into(), 2), ("beta".into(), 1)];
        let daemon = ServeDaemon::new(config);

        let outcome = thread::scope(|scope| {
            let handle = scope.spawn(|| daemon.run(&queue));
            // wait until the fleet works the spool dry, then drain
            loop {
                let all = queue.list().unwrap();
                if !all.is_empty() && all.iter().all(|j| j.status == JobStatus::Done) {
                    break;
                }
                thread::sleep(Duration::from_millis(10));
            }
            control::request_drain(queue.dir()).unwrap();
            handle.join().unwrap()
        })
        .unwrap();

        assert!(outcome.ticks >= 1);
        let done = queue.list().unwrap();
        assert_eq!(done.len(), 8);
        assert!(done.iter().all(|j| j.status == JobStatus::Done));
        // claim sequences were stamped and persisted — the fairness
        // audit trail exists in the spool itself
        let mut seqs: Vec<u64> = done.iter().map(|j| j.claim_seq.unwrap()).collect();
        seqs.sort_unstable();
        assert_eq!(seqs, (1..=8).collect::<Vec<u64>>());

        // final snapshot: exact totals from the joined fleet
        let stats = health::read_json(queue.dir(), STATS_FILE).unwrap().unwrap();
        assert!(stats.req("final").unwrap().as_bool().unwrap());
        let rows = stats.req("workers").unwrap().as_arr().unwrap();
        let claimed: u64 = rows.iter().map(|r| r.req("claimed").unwrap().as_u64().unwrap()).sum();
        assert_eq!(claimed, 8);
        let healthf = health::read_json(queue.dir(), HEALTH_FILE).unwrap().unwrap();
        assert!(healthf.req("draining").unwrap().as_bool().unwrap());
        let alpha = healthf.req("tenants").unwrap().req("alpha").unwrap();
        assert_eq!(alpha.req("completed").unwrap().as_u64().unwrap(), 4);

        let _ = std::fs::remove_dir_all(queue.dir());
    }

    /// The failure lifecycle end-to-end, in process: a poison job fails
    /// every attempt, the sweep retries it until the budget is spent,
    /// then relocates it to `dlq/` with its full failure history.
    #[test]
    fn failed_jobs_retry_until_the_budget_then_dead_letter() {
        let queue = tmp_queue("dlq-lifecycle");
        let shape = ClusterConfig::sized(2, 2);
        // `frobnicate` is not in the simulated image: parses and admits
        // fine, fails at execution — submitted via the queue API so no
        // admission dry-run rejects it first
        let poison = plan("alpha").replace(
            "grep -o '[GC]' /dna | wc -l > /count",
            "frobnicate /dna > /count",
        );
        let id = queue
            .submit(crate::util::json::Json::parse(&poison).unwrap(), "poison".into())
            .unwrap();

        let mut config = ServeConfig::new(PoolConfig::new(2, shape));
        config.tick = Duration::from_millis(20);
        config.max_attempts = 2;
        let daemon = ServeDaemon::new(config);

        thread::scope(|scope| {
            let handle = scope.spawn(|| daemon.run(&queue));
            let mut waited = 0;
            while queue.dlq_list().unwrap().is_empty() {
                waited += 1;
                assert!(waited < 1_000, "job never reached the dead-letter queue");
                thread::sleep(Duration::from_millis(10));
            }
            control::request_drain(queue.dir()).unwrap();
            handle.join().unwrap()
        })
        .unwrap();

        let dead = queue.dlq_list().unwrap();
        assert_eq!(dead.len(), 1);
        assert_eq!(dead[0].id, id);
        assert_eq!(dead[0].status, JobStatus::Failed);
        assert_eq!(dead[0].attempts, 2, "the whole attempt budget was spent");
        assert_eq!(dead[0].failures.len(), 2, "one failure context per attempt");
        assert!(
            dead[0].failures.iter().all(|f| f.detail.contains("frobnicate")),
            "{:?}",
            dead[0].failures
        );
        assert!(queue.list().unwrap().is_empty(), "live spool drained clean");

        let stats = health::read_json(queue.dir(), STATS_FILE).unwrap().unwrap();
        assert_eq!(stats.req("retried").unwrap().as_u64().unwrap(), 1);
        assert_eq!(stats.req("dead_lettered").unwrap().as_u64().unwrap(), 1);
        // the daemon heartbeat landed in the control file
        let c = control::read(queue.dir()).unwrap().unwrap();
        assert!(c.beat_ms > 0, "supervisor ticks stamp the heartbeat");
        assert_eq!(c.max_attempts, 2);

        let _ = std::fs::remove_dir_all(queue.dir());
    }
}
