//! Fair-share + priority claim ordering — the scheduling policy of the
//! resident job service.
//!
//! Stride-style fair sharing: every tenant has a weight (its admission
//! quota, `--quota alpha=3,beta=1`; unlisted tenants weigh 1), and the
//! policy tracks how many claims each tenant has received. A tenant's
//! *virtual time* is `claims / weight`; each claim scan hands the next
//! job to the backlogged tenant with the LOWEST virtual time, so over
//! any backlogged window tenants receive claims proportionally to
//! their weights — weight 3 gets 3× the throughput of weight 1,
//! regardless of submission order (FIFO would give whoever spooled
//! first). Within a tenant, higher `priority` goes first, then FIFO by
//! id.
//!
//! Tenants appearing mid-run start at the current minimum virtual time
//! rather than zero — a late tenant gets its fair share from now on,
//! not a retroactive credit that would starve everyone else while it
//! "catches up".

use std::collections::BTreeMap;

use crate::error::{MareError, Result};
use crate::submit::queue::JobRecord;

/// Mutable fair-share state: weights (from the operator's quotas) plus
/// per-tenant claim/completion tallies. The serve daemon guards one
/// instance with a mutex and consults it from every claim scan.
#[derive(Debug, Clone, Default)]
pub struct FairShare {
    weights: BTreeMap<String, u64>,
    claims: BTreeMap<String, u64>,
    completed: BTreeMap<String, u64>,
}

impl FairShare {
    pub fn new(quotas: &[(String, u64)]) -> FairShare {
        let mut fs = FairShare::default();
        fs.set_weights(quotas);
        fs
    }

    /// Replace the weight table (a control-file reload). Claim tallies
    /// survive — reloading quotas mid-run adjusts the shares from here
    /// on instead of resetting history.
    pub fn set_weights(&mut self, quotas: &[(String, u64)]) {
        self.weights = quotas.iter().cloned().collect();
    }

    pub fn weight(&self, tenant: &str) -> u64 {
        self.weights.get(tenant).copied().unwrap_or(1).max(1)
    }

    /// Virtual time comparison without floats: `claims_a / weight_a`
    /// vs `claims_b / weight_b` cross-multiplied.
    fn vtime_less(&self, a: &str, b: &str) -> bool {
        let (ca, cb) = (self.claims.get(a).copied().unwrap_or(0), self.claims.get(b).copied().unwrap_or(0));
        ca * self.weight(b) < cb * self.weight(a)
    }

    /// First sight of a tenant: floor its claim tally so its virtual
    /// time equals the current minimum (integer-rounded down) instead
    /// of zero.
    fn note_tenant(&mut self, tenant: &str) {
        if self.claims.contains_key(tenant) {
            return;
        }
        let w = self.weight(tenant);
        let floor = self
            .claims
            .iter()
            .map(|(t, c)| c * w / self.weight(t))
            .min()
            .unwrap_or(0);
        self.claims.insert(tenant.to_string(), floor);
    }

    /// The claim-order policy: sort one scan's queued candidates so the
    /// front of the vec is the job the fleet should claim next.
    /// Ordering is advisory — exactly-once still comes from the spool's
    /// rename protocol, so a stale sort costs fairness slack, never
    /// correctness.
    pub fn order(&mut self, candidates: &mut Vec<JobRecord>) {
        for job in candidates.iter() {
            self.note_tenant(&job.tenant);
        }
        candidates.sort_by(|a, b| {
            if a.tenant != b.tenant {
                if self.vtime_less(&a.tenant, &b.tenant) {
                    return std::cmp::Ordering::Less;
                }
                if self.vtime_less(&b.tenant, &a.tenant) {
                    return std::cmp::Ordering::Greater;
                }
                // equal virtual time: stable tenant-name tie-break so
                // concurrent scans agree on one order
                return a.tenant.cmp(&b.tenant).then(
                    b.priority.cmp(&a.priority).then(a.id.cmp(&b.id)),
                );
            }
            b.priority.cmp(&a.priority).then(a.id.cmp(&b.id))
        });
    }

    /// Account a committed claim.
    pub fn claimed(&mut self, tenant: &str) {
        self.note_tenant(tenant);
        *self.claims.get_mut(tenant).expect("note_tenant inserted") += 1;
    }

    /// Account a finished job (done or failed — both consumed capacity).
    pub fn finished(&mut self, tenant: &str) {
        *self.completed.entry(tenant.to_string()).or_insert(0) += 1;
    }

    /// Tenants seen so far (union of quota table and observed jobs).
    pub fn tenants(&self) -> Vec<String> {
        let mut names: Vec<String> =
            self.weights.keys().chain(self.claims.keys()).cloned().collect();
        names.sort();
        names.dedup();
        names
    }

    pub fn claims_of(&self, tenant: &str) -> u64 {
        self.claims.get(tenant).copied().unwrap_or(0)
    }

    pub fn completed_of(&self, tenant: &str) -> u64 {
        self.completed.get(tenant).copied().unwrap_or(0)
    }
}

/// Parse the CLI quota table: `alpha=3,beta=1` → `[("alpha",3),
/// ("beta",1)]`. Weights must be >= 1 (a zero quota is starvation by
/// another name — reject it loudly rather than silently parking a
/// tenant forever).
pub fn parse_quotas(spec: &str) -> Result<Vec<(String, u64)>> {
    let mut quotas = Vec::new();
    for part in spec.split(',').filter(|p| !p.trim().is_empty()) {
        let (tenant, weight) = part.split_once('=').ok_or_else(|| {
            MareError::Config(format!(
                "--quota wants tenant=weight[,tenant=weight...], got `{part}`"
            ))
        })?;
        let tenant = tenant.trim();
        let weight: u64 = weight.trim().parse().map_err(|_| {
            MareError::Config(format!("--quota {tenant}: weight must be an integer"))
        })?;
        if tenant.is_empty() {
            return Err(MareError::Config("--quota: empty tenant name".into()));
        }
        if weight == 0 {
            return Err(MareError::Config(format!(
                "--quota {tenant}=0: a zero weight would starve the tenant; use >= 1"
            )));
        }
        quotas.push((tenant.to_string(), weight));
    }
    Ok(quotas)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::submit::queue::{JobRecord, JobStatus};
    use crate::util::json::Json;

    fn job(id: u64, tenant: &str, priority: i64) -> JobRecord {
        JobRecord {
            id,
            status: JobStatus::Queued,
            summary: String::new(),
            tenant: tenant.into(),
            priority,
            stamp_ms: 0,
            claimed_ms: None,
            claim_seq: None,
            attempts: 0,
            failures: Vec::new(),
            plan: Json::Null,
            result: None,
        }
    }

    /// Simulate a backlogged spool: every tenant always has work, and
    /// each round the policy's front choice is claimed.
    fn simulate(fs: &mut FairShare, tenants: &[&str], rounds: usize) -> BTreeMap<String, u64> {
        let mut shares: BTreeMap<String, u64> = BTreeMap::new();
        for round in 0..rounds {
            let mut candidates: Vec<JobRecord> = tenants
                .iter()
                .enumerate()
                .map(|(i, t)| job((round * tenants.len() + i + 1) as u64, t, 0))
                .collect();
            fs.order(&mut candidates);
            let winner = &candidates[0];
            fs.claimed(&winner.tenant);
            *shares.entry(winner.tenant.clone()).or_insert(0) += 1;
        }
        shares
    }

    #[test]
    fn backlogged_tenants_share_claims_by_weight() {
        let mut fs = FairShare::new(&[("alpha".into(), 3), ("beta".into(), 1)]);
        let shares = simulate(&mut fs, &["alpha", "beta", "gamma"], 500);
        // weights 3:1:1 over 500 claims → 300/100/100
        assert_eq!(shares["alpha"], 300);
        assert_eq!(shares["beta"], 100);
        assert_eq!(shares["gamma"], 100, "unlisted tenants weigh 1");
    }

    #[test]
    fn priority_breaks_ties_within_a_tenant_fifo_otherwise() {
        let mut fs = FairShare::new(&[]);
        let mut candidates = vec![job(1, "t", 0), job(2, "t", 5), job(3, "t", 5)];
        fs.order(&mut candidates);
        let ids: Vec<u64> = candidates.iter().map(|j| j.id).collect();
        // higher priority first; FIFO inside a priority band
        assert_eq!(ids, vec![2, 3, 1]);

        // negative priority parks work behind the default band
        let mut candidates = vec![job(4, "t", -1), job(5, "t", 0)];
        fs.order(&mut candidates);
        assert_eq!(candidates[0].id, 5);
    }

    #[test]
    fn late_tenants_start_at_the_current_virtual_time_not_zero() {
        let mut fs = FairShare::new(&[]);
        // one tenant accumulates 100 claims...
        let _ = simulate(&mut fs, &["old"], 100);
        // ...then a newcomer arrives: it must NOT monopolize the next
        // 100 claims catching up, only get its fair (equal) share
        let shares = simulate(&mut fs, &["old", "new"], 40);
        assert!(
            shares["new"] <= 21,
            "no retroactive credit: {shares:?}"
        );
        assert!(shares["old"] >= 19, "{shares:?}");
    }

    #[test]
    fn reload_adjusts_future_shares_without_resetting_history() {
        let mut fs = FairShare::new(&[("a".into(), 1), ("b".into(), 1)]);
        let _ = simulate(&mut fs, &["a", "b"], 100);
        fs.set_weights(&[("a".into(), 3), ("b".into(), 1)]);
        let shares = simulate(&mut fs, &["a", "b"], 200);
        // post-reload claims tilt toward the raised weight; exact split
        // depends on pre-reload history, so assert the direction
        assert!(shares["a"] > 2 * shares["b"], "{shares:?}");
    }

    #[test]
    fn quota_specs_parse_and_reject_zero_weights() {
        assert_eq!(
            parse_quotas("alpha=3, beta=1").unwrap(),
            vec![("alpha".to_string(), 3), ("beta".to_string(), 1)]
        );
        assert_eq!(parse_quotas("").unwrap(), vec![]);
        assert!(parse_quotas("alpha").is_err());
        assert!(parse_quotas("alpha=x").is_err());
        assert!(parse_quotas("alpha=0").is_err());
        assert!(parse_quotas("=3").is_err());
    }
}
