//! The `serve-control.json` file: how a resident daemon and the CLI
//! talk across processes without a socket.
//!
//! The daemon writes the file (atomic temp+rename, like every spool
//! write) into the spool directory when it starts, advertising its
//! admission settings; it re-reads the file every supervisor tick, so
//! an operator editing `max_depth`/`quotas` — or `mare serve --drain`
//! flipping the `drain` flag — takes effect within one tick. Submitter
//! processes read it at admission time to enforce backpressure: no
//! daemon, no file, no depth limit.

use std::fs;
use std::path::Path;

use crate::error::{MareError, Result};
use crate::util::json::Json;

/// File name inside the spool directory.
pub const CONTROL_FILE: &str = "serve-control.json";

/// The advertised service settings.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Control {
    /// Refuse new submissions while `queued + held >= max_depth`.
    /// 0 disables the depth limit.
    pub max_depth: usize,
    /// Drain requested: stop claiming, finish in-flight work, exit 0.
    pub drain: bool,
    /// Tenant weight table (see `serve::policy`).
    pub quotas: Vec<(String, u64)>,
}

impl Control {
    pub fn to_json(&self) -> Json {
        let quotas = Json::Obj(
            self.quotas.iter().map(|(t, w)| (t.clone(), Json::Num(*w as f64))).collect(),
        );
        Json::obj(vec![
            ("version", Json::Num(1.0)),
            ("max_depth", Json::Num(self.max_depth as f64)),
            ("drain", Json::Bool(self.drain)),
            ("quotas", quotas),
        ])
    }

    pub fn from_json(json: &Json) -> Result<Control> {
        let mut quotas = Vec::new();
        if let Some(q) = json.get("quotas") {
            for (tenant, weight) in q.as_obj()? {
                quotas.push((tenant.clone(), weight.as_u64()?));
            }
        }
        Ok(Control {
            max_depth: json.req("max_depth")?.as_usize()?,
            drain: json.req("drain")?.as_bool()?,
            quotas,
        })
    }
}

fn control_path(dir: &Path) -> std::path::PathBuf {
    dir.join(CONTROL_FILE)
}

/// Atomically publish `control` into the spool directory.
pub fn write(dir: &Path, control: &Control) -> Result<()> {
    let tmp = dir.join(format!(
        "{CONTROL_FILE}.tmp-{}-{}",
        std::process::id(),
        crate::submit::queue::now_millis()
    ));
    fs::write(&tmp, control.to_json().to_string_pretty())?;
    fs::rename(&tmp, control_path(dir))?;
    Ok(())
}

/// Read the advertised settings; `Ok(None)` when no daemon has ever
/// published into this spool. A file that exists but does not parse is
/// an error — admission control must not silently degrade to
/// "unlimited" because the control file was half-edited.
pub fn read(dir: &Path) -> Result<Option<Control>> {
    let text = match fs::read_to_string(control_path(dir)) {
        Ok(text) => text,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e.into()),
    };
    let json = Json::parse(&text)
        .map_err(|e| MareError::Submit(format!("{CONTROL_FILE}: {e}")))?;
    Ok(Some(Control::from_json(&json)?))
}

/// `mare serve --drain`: flip the drain flag on the advertised
/// settings (read-modify-write; the rename publish keeps readers
/// whole). Errors when no daemon owns the spool — there is nothing to
/// drain, and writing a fresh control file would impose admission
/// limits no daemon advertised.
pub fn request_drain(dir: &Path) -> Result<Control> {
    let mut control = read(dir)?.ok_or_else(|| {
        MareError::Submit(format!(
            "no {CONTROL_FILE} in {} — no serve daemon owns this spool",
            dir.display()
        ))
    })?;
    control.drain = true;
    write(dir, &control)?;
    Ok(control)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("mare-serve-control-{}-{name}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn control_roundtrips_and_drain_flips_in_place() {
        let dir = tmp_dir("roundtrip");
        assert_eq!(read(&dir).unwrap(), None, "no daemon, no control file");

        let control = Control {
            max_depth: 64,
            drain: false,
            quotas: vec![("alpha".into(), 3), ("beta".into(), 1)],
        };
        write(&dir, &control).unwrap();
        assert_eq!(read(&dir).unwrap(), Some(control.clone()));

        let drained = request_drain(&dir).unwrap();
        assert!(drained.drain);
        assert_eq!(drained.max_depth, 64, "drain preserves the other settings");
        assert_eq!(read(&dir).unwrap().unwrap().quotas, control.quotas);

        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn draining_an_unowned_spool_is_a_typed_refusal() {
        let dir = tmp_dir("unowned");
        let err = request_drain(&dir).unwrap_err().to_string();
        assert!(err.contains("no serve daemon owns"), "{err}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_control_files_error_rather_than_meaning_unlimited() {
        let dir = tmp_dir("corrupt");
        fs::write(dir.join(CONTROL_FILE), "{half a file").unwrap();
        assert!(read(&dir).is_err());
        let _ = fs::remove_dir_all(&dir);
    }
}
