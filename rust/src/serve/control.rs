//! The `serve-control.json` file: how a resident daemon and the CLI
//! talk across processes without a socket.
//!
//! The daemon writes the file (atomic temp+rename, like every spool
//! write) into the spool directory when it starts, advertising its
//! admission settings; it re-reads the file every supervisor tick, so
//! an operator editing `max_depth`/`quotas` — or `mare serve --drain`
//! flipping the `drain` flag — takes effect within one tick. Submitter
//! processes read it at admission time to enforce backpressure: no
//! daemon, no file, no depth limit.

use std::fs;
use std::path::Path;

use crate::error::{MareError, Result};
use crate::util::json::Json;

/// File name inside the spool directory.
pub const CONTROL_FILE: &str = "serve-control.json";

/// How long a daemon heartbeat stays fresh. Past this, submitters
/// treat the control file as a leftover from a dead daemon and stop
/// enforcing its admission limits.
pub const BEAT_STALE_MS: u64 = 10_000;

/// The advertised service settings.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Control {
    /// Refuse new submissions while `queued + held >= max_depth`.
    /// 0 disables the depth limit.
    pub max_depth: usize,
    /// Drain requested: stop claiming, finish in-flight work, exit 0.
    pub drain: bool,
    /// Tenant weight table (see `serve::policy`).
    pub quotas: Vec<(String, u64)>,
    /// Dead-letter threshold: jobs that have failed this many attempts
    /// are moved to `dlq/` by the daemon's sweep. 0 disables the DLQ
    /// (failed jobs stay `failed` for manual `mare requeue`).
    pub max_attempts: u64,
    /// Daemon heartbeat, stamped every supervisor tick. 0 means the
    /// file was hand-authored (or written by a daemon predating the
    /// heartbeat) — such files are enforced unconditionally.
    pub beat_ms: u64,
}

impl Control {
    pub fn to_json(&self) -> Json {
        let quotas = Json::Obj(
            self.quotas.iter().map(|(t, w)| (t.clone(), Json::Num(*w as f64))).collect(),
        );
        Json::obj(vec![
            ("version", Json::Num(1.0)),
            ("max_depth", Json::Num(self.max_depth as f64)),
            ("drain", Json::Bool(self.drain)),
            ("quotas", quotas),
            ("max_attempts", Json::Num(self.max_attempts as f64)),
            ("beat_ms", Json::Num(self.beat_ms as f64)),
        ])
    }

    pub fn from_json(json: &Json) -> Result<Control> {
        let mut quotas = Vec::new();
        if let Some(q) = json.get("quotas") {
            for (tenant, weight) in q.as_obj()? {
                quotas.push((tenant.clone(), weight.as_u64()?));
            }
        }
        Ok(Control {
            max_depth: json.req("max_depth")?.as_usize()?,
            drain: json.req("drain")?.as_bool()?,
            quotas,
            max_attempts: json
                .get("max_attempts")
                .map(|v| v.as_u64())
                .transpose()?
                .unwrap_or(0),
            beat_ms: json.get("beat_ms").map(|v| v.as_u64()).transpose()?.unwrap_or(0),
        })
    }

    /// Is the daemon that wrote this file still alive, as far as its
    /// heartbeat shows? Hand-authored files (`beat_ms == 0`) are always
    /// "live" — they carry no liveness signal and are enforced as
    /// written, which is also what every pre-heartbeat control file
    /// gets. A clock that reads *behind* the stamp (NTP step) counts as
    /// live too: `saturating_sub` makes the age 0, never a huge number.
    pub fn live(&self, now_ms: u64) -> bool {
        self.beat_ms == 0 || now_ms.saturating_sub(self.beat_ms) <= BEAT_STALE_MS
    }
}

fn control_path(dir: &Path) -> std::path::PathBuf {
    dir.join(CONTROL_FILE)
}

/// A lock file held for the duration of a control read-modify-write.
/// Two writers RMW the control file: the daemon (heartbeat, every
/// tick) and `mare serve --drain` (flip the flag, once). Without
/// mutual exclusion the beat stamp can overwrite a drain request that
/// landed between the daemon's read and write — and a lost drain is a
/// daemon that never exits.
const CONTROL_LOCK: &str = "serve-control.lock";

/// A lock older than this belongs to a dead process and is broken.
const LOCK_STALE_MS: u64 = 2_000;

fn with_lock<T>(dir: &Path, f: impl FnOnce() -> Result<T>) -> Result<T> {
    let lock = dir.join(CONTROL_LOCK);
    loop {
        match fs::OpenOptions::new().write(true).create_new(true).open(&lock) {
            Ok(mut fh) => {
                use std::io::Write;
                let _ = write!(fh, "{}", crate::submit::queue::now_millis());
                break;
            }
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                // stale-holder recovery: a lock stamped long ago was
                // left by a process that died mid-update
                let stamp = fs::read_to_string(&lock)
                    .ok()
                    .and_then(|s| s.trim().parse::<u64>().ok())
                    .unwrap_or(0);
                let now = crate::submit::queue::now_millis();
                if now.saturating_sub(stamp) > LOCK_STALE_MS {
                    let _ = fs::remove_file(&lock);
                } else {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
    let result = f();
    let _ = fs::remove_file(&lock);
    result
}

/// Read-modify-write the advertised settings under the control lock.
/// Errors when no control file exists — there is nothing to update,
/// and inventing one would impose settings no daemon advertised.
pub fn update(dir: &Path, mutate: impl FnOnce(&mut Control)) -> Result<Control> {
    with_lock(dir, || {
        let mut control = read(dir)?.ok_or_else(|| {
            MareError::Submit(format!(
                "no {CONTROL_FILE} in {} — no serve daemon owns this spool",
                dir.display()
            ))
        })?;
        mutate(&mut control);
        write(dir, &control)?;
        Ok(control)
    })
}

/// Atomically publish `control` into the spool directory.
pub fn write(dir: &Path, control: &Control) -> Result<()> {
    let tmp = dir.join(format!(
        "{CONTROL_FILE}.tmp-{}-{}",
        std::process::id(),
        crate::submit::queue::now_millis()
    ));
    fs::write(&tmp, control.to_json().to_string_pretty())?;
    fs::rename(&tmp, control_path(dir))?;
    Ok(())
}

/// Read the advertised settings; `Ok(None)` when no daemon has ever
/// published into this spool. A file that exists but does not parse is
/// an error — admission control must not silently degrade to
/// "unlimited" because the control file was half-edited.
pub fn read(dir: &Path) -> Result<Option<Control>> {
    let text = match fs::read_to_string(control_path(dir)) {
        Ok(text) => text,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e.into()),
    };
    let json = Json::parse(&text)
        .map_err(|e| MareError::Submit(format!("{CONTROL_FILE}: {e}")))?;
    Ok(Some(Control::from_json(&json)?))
}

/// `mare serve --drain`: flip the drain flag on the advertised
/// settings (locked read-modify-write; the rename publish keeps
/// readers whole). Errors when no daemon owns the spool — there is
/// nothing to drain, and writing a fresh control file would impose
/// admission limits no daemon advertised.
pub fn request_drain(dir: &Path) -> Result<Control> {
    update(dir, |control| control.drain = true)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("mare-serve-control-{}-{name}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn control_roundtrips_and_drain_flips_in_place() {
        let dir = tmp_dir("roundtrip");
        assert_eq!(read(&dir).unwrap(), None, "no daemon, no control file");

        let control = Control {
            max_depth: 64,
            drain: false,
            quotas: vec![("alpha".into(), 3), ("beta".into(), 1)],
            max_attempts: 3,
            beat_ms: 1_000,
        };
        write(&dir, &control).unwrap();
        assert_eq!(read(&dir).unwrap(), Some(control.clone()));

        let drained = request_drain(&dir).unwrap();
        assert!(drained.drain);
        assert_eq!(drained.max_depth, 64, "drain preserves the other settings");
        assert_eq!(read(&dir).unwrap().unwrap().quotas, control.quotas);

        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn draining_an_unowned_spool_is_a_typed_refusal() {
        let dir = tmp_dir("unowned");
        let err = request_drain(&dir).unwrap_err().to_string();
        assert!(err.contains("no serve daemon owns"), "{err}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_control_files_error_rather_than_meaning_unlimited() {
        let dir = tmp_dir("corrupt");
        fs::write(dir.join(CONTROL_FILE), "{half a file").unwrap();
        assert!(read(&dir).is_err());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn concurrent_updates_do_not_lose_either_writer() {
        let dir = tmp_dir("locked-rmw");
        let base = Control {
            max_depth: 1,
            drain: false,
            quotas: Vec::new(),
            max_attempts: 0,
            beat_ms: 0,
        };
        write(&dir, &base).unwrap();
        std::thread::scope(|s| {
            s.spawn(|| {
                for _ in 0..50 {
                    update(&dir, |c| c.beat_ms += 1).unwrap();
                }
            });
            s.spawn(|| {
                for _ in 0..50 {
                    update(&dir, |c| c.max_attempts += 1).unwrap();
                }
            });
        });
        let c = read(&dir).unwrap().unwrap();
        assert_eq!((c.beat_ms, c.max_attempts), (50, 50), "no lost updates under the lock");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn files_without_the_new_fields_parse_as_disabled() {
        // a control file written by a pre-DLQ daemon (or by hand)
        let json = Json::parse(r#"{"max_depth": 8, "drain": false, "quotas": {}}"#).unwrap();
        let control = Control::from_json(&json).unwrap();
        assert_eq!(control.max_attempts, 0);
        assert_eq!(control.beat_ms, 0);
    }

    #[test]
    fn liveness_follows_the_heartbeat_but_hand_authored_files_are_forever() {
        let mut control = Control {
            max_depth: 8,
            drain: false,
            quotas: Vec::new(),
            max_attempts: 0,
            beat_ms: 0,
        };
        // no heartbeat: no liveness signal, always enforced
        assert!(control.live(0));
        assert!(control.live(u64::MAX));
        // fresh heartbeat: live; stale heartbeat: dead daemon
        control.beat_ms = 100_000;
        assert!(control.live(100_000 + BEAT_STALE_MS));
        assert!(!control.live(100_000 + BEAT_STALE_MS + 1));
        // clock behind the stamp (NTP step): still live, not a wrap
        assert!(control.live(50_000));
    }
}
