//! `mare serve` — the resident, multi-tenant job service.
//!
//! One daemon per spool directory: it owns a persistent worker fleet
//! (the same [`WorkerPool`](crate::submit::pool::WorkerPool) the
//! one-shot `mare work` uses, in resident mode) and layers service
//! semantics over the file-spool protocol without changing it:
//!
//! * [`policy`] — stride-style fair-share claim ordering with tenant
//!   weights and per-tenant priorities. Ordering is advisory; the
//!   spool's rename locking still decides every contended claim, so
//!   exactly-once survives any mix of policies on one spool.
//! * [`control`] — `serve-control.json`, the socketless control plane:
//!   the daemon advertises its admission settings, submitters read
//!   them to enforce backpressure, `mare serve --drain` flips the
//!   drain flag, and the daemon re-reads every tick.
//! * [`health`] — `serve-health.json` / `serve-stats.json`, rewritten
//!   atomically each supervisor tick, plus a final exact snapshot when
//!   the fleet drains.
//! * [`daemon`] — the loop that ties them together: fleet + supervisor,
//!   claim-sequence stamping for post-hoc fairness audits, and
//!   self-healing requeue of jobs that dead workers left `running`.

pub mod control;
pub mod daemon;
pub mod health;
pub mod policy;

pub use control::{request_drain, Control, CONTROL_FILE};
pub use daemon::{ServeConfig, ServeDaemon, ServeOutcome};
pub use health::{HealthReport, TenantHealth, WorkerHealth, HEALTH_FILE, STATS_FILE};
pub use policy::{parse_quotas, FairShare};
