//! HDFS model: block-based store co-located with the workers.
//!
//! The paper's setup: "HDFS daemons ran in the worker nodes, allowing
//! for near-zero network communication". Objects split into fixed-size
//! blocks; block `b` of object `k` has its primary replica on worker
//! `(hash(k) + b) % workers` (plus `replication-1` followers on the next
//! workers), so a large file spreads evenly. A local read moves at disk
//! speed; a remote read crosses the LAN.

use std::collections::BTreeMap;

use crate::error::{MareError, Result};
use crate::simtime::{DiskModel, Duration, NetModel};

use super::{BlockInfo, StorageBackend};

pub const DEFAULT_BLOCK_SIZE: u64 = 128 << 20;
pub const DEFAULT_REPLICATION: usize = 3;

pub struct Hdfs {
    objects: BTreeMap<String, Vec<u8>>,
    workers: usize,
    block_size: u64,
    replication: usize,
    disk: DiskModel,
    net: NetModel,
}

impl Hdfs {
    pub fn new(workers: usize, block_size: u64) -> Self {
        Hdfs {
            objects: BTreeMap::new(),
            workers: workers.max(1),
            block_size: block_size.max(1),
            replication: DEFAULT_REPLICATION,
            disk: DiskModel::datanode(),
            net: NetModel::lan(),
        }
    }

    pub fn with_replication(mut self, r: usize) -> Self {
        self.replication = r.max(1);
        self
    }

    fn key_hash(key: &str) -> u64 {
        let mut h = 0xcbf29ce484222325u64;
        for b in key.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }

    /// All replica hosts of block `index` of `key`.
    pub fn replicas(&self, key: &str, index: usize) -> Vec<usize> {
        let base = (Self::key_hash(key) as usize + index) % self.workers;
        (0..self.replication.min(self.workers))
            .map(|r| (base + r) % self.workers)
            .collect()
    }
}

impl StorageBackend for Hdfs {
    fn name(&self) -> &'static str {
        "hdfs"
    }

    fn put(&mut self, key: &str, bytes: Vec<u8>) -> Result<()> {
        self.objects.insert(key.to_string(), bytes);
        Ok(())
    }

    fn get(&self, key: &str) -> Result<&[u8]> {
        self.objects
            .get(key)
            .map(|v| v.as_slice())
            .ok_or_else(|| MareError::Storage(format!("hdfs: no such object `{key}`")))
    }

    fn list(&self) -> Vec<&str> {
        self.objects.keys().map(|s| s.as_str()).collect()
    }

    fn blocks(&self, key: &str) -> Result<Vec<BlockInfo>> {
        let len = self.get(key)?.len() as u64;
        let n = len.div_ceil(self.block_size).max(1);
        Ok((0..n as usize)
            .map(|i| BlockInfo {
                index: i,
                len: (len - i as u64 * self.block_size).min(self.block_size),
                primary: Some(self.replicas(key, i)[0]),
            })
            .collect())
    }

    fn read_time(
        &self,
        reader_worker: usize,
        primary: Option<usize>,
        bytes: u64,
        _concurrency: u32,
    ) -> Duration {
        match primary {
            // short-circuit local read: straight off the datanode disk
            Some(p) if p == reader_worker => self.disk.rw(bytes),
            // remote: datanode disk + one LAN hop
            _ => self.disk.rw(bytes) + self.net.transfer(bytes, 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blocks_split_and_spread() {
        let mut h = Hdfs::new(4, 100);
        h.put("big", vec![0u8; 350]).unwrap();
        let blocks = h.blocks("big").unwrap();
        assert_eq!(blocks.len(), 4);
        assert_eq!(blocks[0].len, 100);
        assert_eq!(blocks[3].len, 50);
        // consecutive blocks land on consecutive workers
        let hosts: Vec<usize> = blocks.iter().map(|b| b.primary.unwrap()).collect();
        for w in 0..4 {
            assert!(hosts.contains(&w), "{hosts:?}");
        }
    }

    #[test]
    fn replication_gives_distinct_hosts() {
        let h = Hdfs::new(8, 100).with_replication(3);
        let reps = h.replicas("k", 0);
        assert_eq!(reps.len(), 3);
        let set: std::collections::HashSet<_> = reps.iter().collect();
        assert_eq!(set.len(), 3);
    }

    #[test]
    fn local_read_beats_remote() {
        let mut h = Hdfs::new(4, 1 << 20);
        h.put("k", vec![0u8; 1 << 20]).unwrap();
        let primary = h.blocks("k").unwrap()[0].primary.unwrap();
        let local = h.read_time(primary, Some(primary), 1 << 20, 1);
        let remote = h.read_time((primary + 1) % 4, Some(primary), 1 << 20, 1);
        assert!(local < remote);
    }

    #[test]
    fn missing_object_errors() {
        let h = Hdfs::new(2, 100);
        assert!(h.get("nope").is_err());
        assert!(h.blocks("nope").is_err());
    }

    #[test]
    fn empty_object_has_one_empty_block() {
        let mut h = Hdfs::new(2, 100);
        h.put("e", vec![]).unwrap();
        let blocks = h.blocks("e").unwrap();
        assert_eq!(blocks.len(), 1);
        assert_eq!(blocks[0].len, 0);
    }
}
