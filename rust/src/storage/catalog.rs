//! Storage catalog: named backends resolvable from `scheme://key` URIs.
//!
//! The submit subsystem ships plans whose `ingest` node carries a source
//! *label*. Before this module, only `gen:`/`inline:` labels executed;
//! storage-backed labels (`hdfs://genome.txt`) validated and enqueued
//! but died at execution. The catalog closes that seam: it is a registry
//! of the named backends of the evaluation (§1.3 — `hdfs://`, `swift://`,
//! `s3://`, plus `local://` for tests), and it resolves a [`StorageUri`]
//! into an ingested [`Dataset`] with per-partition locality hints and an
//! [`IngestReport`] (the quantities behind Figures 3 and 5).
//!
//! Every driver constructs its catalog independently, so the store
//! contents must be a pure function of the URI: objects are **populated
//! deterministically** from a pinned seed mixed with the object key
//! (the same trick `gen:` labels use). Two drivers resolving
//! `hdfs://genome.txt?lines=256` therefore see byte-identical objects,
//! which is what keeps the multi-driver crosscheck
//! (`submit::sim::crosscheck`) byte-identical for storage-backed plans.
//!
//! URI grammar: `scheme://key[?name=value&...]`
//!
//! * `scheme` — one of [`StorageCatalog::schemes`]
//! * `key` — object name; a `*` makes it a glob over generated objects
//!   (ingested as binary records, the paper's `BinaryFiles` semantics)
//! * params — sizing knobs: `lines=N` (text objects), `molecules=N`
//!   (`.sdf` objects), `objects=N` + `bytes=N` (globs)
//!
//! ```
//! use mare::storage::{StorageCatalog, StorageUri};
//!
//! let uri = StorageUri::parse("hdfs://genome.txt?lines=64").unwrap();
//! let catalog = StorageCatalog::simulated(4);
//! let (ds, report) = catalog.resolve(&uri, 8).unwrap();
//! assert_eq!(ds.num_partitions(), 8);
//! // HDFS blocks live on the workers: every partition carries a hint
//! assert_eq!(report.local_reads + report.remote_reads, 8);
//! assert!(report.bytes > 0);
//! ```

use crate::config::BackendKind;
use crate::dataset::Dataset;
use crate::error::{MareError, Result};

use super::ingest::{
    ingest_objects_as, ingest_text_as, ingest_text_streamed_as, IngestReport, SealedPartition,
};
use super::{Hdfs, LocalFs, StorageBackend, Swift, S3};

/// Seed for deterministic object population — pinned to the same value
/// as [`crate::submit::GEN_SEED`] so storage-backed sources are as
/// reproducible across drivers as `gen:` sources.
pub const CATALOG_SEED: u64 = 42;

/// A parsed storage label: `scheme://key[?name=value&...]`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StorageUri {
    /// Which registered backend serves the object.
    pub kind: BackendKind,
    /// Object key (may contain one `*` — a glob over generated objects).
    pub key: String,
    /// Sizing parameters, in label order.
    pub params: Vec<(String, String)>,
}

impl StorageUri {
    /// Parse a storage label. Returns `None` for anything that is not a
    /// well-formed URI over a registered scheme (such labels stay
    /// opaque to the submit subsystem).
    pub fn parse(label: &str) -> Option<StorageUri> {
        let (scheme, rest) = label.split_once("://")?;
        let kind = BackendKind::parse(scheme).ok()?;
        let (key, query) = match rest.split_once('?') {
            Some((k, q)) => (k, Some(q)),
            None => (rest, None),
        };
        if key.is_empty() {
            return None;
        }
        let mut params = Vec::new();
        if let Some(query) = query {
            for pair in query.split('&') {
                let (name, value) = pair.split_once('=')?;
                if name.is_empty() {
                    return None;
                }
                params.push((name.to_string(), value.to_string()));
            }
        }
        Some(StorageUri { kind, key: key.to_string(), params })
    }

    /// The canonical label this URI round-trips through
    /// ([`Self::parse`] of it yields `self` back).
    pub fn label(&self) -> String {
        let mut s = format!("{}://{}", self.kind.name(), self.key);
        for (i, (name, value)) in self.params.iter().enumerate() {
            s.push(if i == 0 { '?' } else { '&' });
            s.push_str(name);
            s.push('=');
            s.push_str(value);
        }
        s
    }

    /// Numeric sizing parameter, falling back to `default`.
    pub fn usize_param(&self, name: &str, default: usize) -> usize {
        self.params
            .iter()
            .find(|(n, _)| n == name)
            .and_then(|(_, v)| v.parse().ok())
            .unwrap_or(default)
    }

    /// Whether the key globs over many objects (`BinaryFiles` ingest).
    pub fn is_glob(&self) -> bool {
        self.key.contains('*')
    }

    /// Record separator of the object's text format, by extension
    /// (`.sdf` objects split on the SDF molecule delimiter).
    pub fn sep(&self) -> &'static str {
        if self.key.ends_with(".sdf") {
            crate::workloads::vs::SDF_SEP
        } else {
            "\n"
        }
    }
}

/// Mix the object key into the population seed so distinct keys hold
/// distinct (but pinned) content — the crate's one stable string hash
/// ([`crate::dataset::Partitioner::hash_key`]), so the cross-driver
/// determinism contract hangs off a single implementation.
fn key_hash(key: &str) -> u64 {
    crate::dataset::Partitioner::hash_key(key)
}

/// The registry of named backends, with deterministic seeded object
/// population (see the module docs). One catalog per executing driver;
/// backends are constructed per [`Self::resolve`] call because the
/// in-memory models are cheap and the contents are pure functions of
/// `(seed, uri)`.
pub struct StorageCatalog {
    workers: usize,
    seed: u64,
    /// Out-of-tree backends, registered by scheme (see [`Self::register`]).
    /// Unlike the built-in schemes these arrive PRE-POPULATED: the
    /// catalog ingests whatever the caller `put` into them instead of a
    /// seeded population.
    registered: Vec<(String, Box<dyn StorageBackend>)>,
}

impl StorageCatalog {
    /// The catalog every simulated driver uses ([`CATALOG_SEED`]).
    pub fn simulated(workers: usize) -> StorageCatalog {
        StorageCatalog { workers: workers.max(1), seed: CATALOG_SEED, registered: Vec::new() }
    }

    /// A catalog with a custom population seed (tests, what-if runs).
    pub fn with_seed(workers: usize, seed: u64) -> StorageCatalog {
        StorageCatalog { workers: workers.max(1), seed, registered: Vec::new() }
    }

    /// Built-in scheme names, in registry order (derived from
    /// [`BackendKind::ALL`] so the lists cannot drift).
    pub fn schemes() -> Vec<&'static str> {
        BackendKind::ALL.iter().map(|k| k.name()).collect()
    }

    /// Register an out-of-tree backend under `scheme`, joining the
    /// fixed [`BackendKind::ALL`] table for THIS catalog instance.
    /// Built-in schemes cannot be shadowed, and a scheme registers at
    /// most once. Registered schemes resolve through
    /// [`Self::resolve_label`]; on the submit wire their labels are not
    /// [`StorageUri`]s, so they travel under the foreign-scheme-ignored
    /// rule (opaque, validate-only) and only execute on a driver whose
    /// catalog has the backend registered.
    pub fn register(&mut self, scheme: &str, backend: Box<dyn StorageBackend>) -> Result<()> {
        if scheme.is_empty() || !scheme.chars().all(|c| c.is_ascii_alphanumeric() || c == '-') {
            return Err(MareError::Storage(format!(
                "`{scheme}` is not a valid scheme name (ascii alphanumeric / `-`)"
            )));
        }
        if BackendKind::parse(scheme).is_ok() {
            return Err(MareError::Storage(format!(
                "scheme `{scheme}` is built in and cannot be shadowed"
            )));
        }
        if self.registered.iter().any(|(s, _)| s == scheme) {
            return Err(MareError::Storage(format!("scheme `{scheme}` is already registered")));
        }
        self.registered.push((scheme.to_string(), backend));
        Ok(())
    }

    /// Scheme names registered via [`Self::register`], in order.
    pub fn registered_schemes(&self) -> Vec<&str> {
        self.registered.iter().map(|(s, _)| s.as_str()).collect()
    }

    /// The registered backend + object key a label addresses, if its
    /// scheme was [`Self::register`]ed (query params are sizing knobs
    /// for seeded populations — registered backends hold real objects,
    /// so the key stops at `?`).
    fn registered_for<'a>(&'a self, label: &'a str) -> Option<(&'a dyn StorageBackend, &'a str)> {
        let (scheme, rest) = label.split_once("://")?;
        let (_, backend) = self.registered.iter().find(|(s, _)| s == scheme)?;
        let key = rest.split('?').next().unwrap_or(rest);
        if key.is_empty() {
            return None;
        }
        Some((backend.as_ref(), key))
    }

    /// Construct the backend a scheme names. HDFS picks a block size
    /// that spreads `total_bytes` over all workers; this is now the ONE
    /// block-size policy (`workloads::driver::make_backend` delegates
    /// here). The floor is 4 KiB where the seed's driver used 64 KiB —
    /// that floor collapsed any sub-`workers*256KiB` input onto a
    /// single block, hiding block locality exactly at test scales.
    pub fn open(&self, kind: BackendKind, total_bytes: u64) -> Box<dyn StorageBackend> {
        match kind {
            BackendKind::Hdfs => {
                let block = (total_bytes / (self.workers as u64 * 4)).max(4 << 10);
                Box::new(Hdfs::new(self.workers, block))
            }
            BackendKind::Swift => Box::new(Swift::new()),
            BackendKind::S3 => Box::new(S3::new()),
            BackendKind::Local => Box::new(LocalFs::new()),
            // file:// has no simulated population model — resolve()
            // refuses it before ever opening; an empty local store is
            // returned only for API symmetry
            BackendKind::File => Box::new(LocalFs::new()),
        }
    }

    /// Deterministic content of one (non-glob) object. `.sdf` keys hold
    /// a synthetic molecule library; everything else holds genome-style
    /// text lines — both from the pure workload generators, seeded by
    /// `(catalog seed, key)`.
    pub fn object_bytes(&self, uri: &StorageUri) -> Vec<u8> {
        let seed = self.seed ^ key_hash(&uri.key);
        if uri.key.ends_with(".sdf") {
            let molecules = uri.usize_param("molecules", 64).max(1);
            crate::workloads::genlib::library_sdf(seed, molecules).into_bytes()
        } else {
            let lines = uri.usize_param("lines", 256).max(1);
            crate::workloads::gc::genome_text(seed, lines, 80).into_bytes()
        }
    }

    /// Deterministic object set of a glob key: `objects=N` objects of
    /// `bytes=B` pseudo-random bytes each, named by substituting the
    /// `*` with the object index.
    pub fn glob_objects(&self, uri: &StorageUri) -> Vec<(String, Vec<u8>)> {
        let n = uri.usize_param("objects", 4).max(1);
        let size = uri.usize_param("bytes", 1024).max(1);
        let mut rng = crate::util::rng::Rng::new(self.seed ^ key_hash(&uri.key));
        (0..n)
            .map(|i| {
                let name = uri.key.replacen('*', &i.to_string(), 1);
                let mut bytes = vec![0u8; size];
                for b in &mut bytes {
                    *b = rng.below(256) as u8;
                }
                (name, bytes)
            })
            .collect()
    }

    /// Resolve a URI end-to-end: populate the backend deterministically,
    /// then ingest — [`ingest_text_as`] for single objects (per-partition
    /// block-locality hints), [`ingest_objects_as`] for globs (one binary
    /// record per object). The dataset is labeled with the canonical URI
    /// so re-encoding a job built over it round-trips the label.
    pub fn resolve(
        &self,
        uri: &StorageUri,
        partitions: usize,
    ) -> Result<(Dataset, IngestReport)> {
        if uri.kind == BackendKind::File {
            return Err(MareError::Storage(
                "file:// objects are real files, not deterministic populations — \
                 they cannot serve as ingest sources (use put_object/fetch_object)"
                    .into(),
            ));
        }
        let label = uri.label();
        if uri.is_glob() {
            let objects = self.glob_objects(uri);
            let total: u64 = objects.iter().map(|(_, b)| b.len() as u64).sum();
            let mut backend = self.open(uri.kind, total);
            for (k, b) in &objects {
                backend.put(k, b.clone())?;
            }
            let keys: Vec<&str> = objects.iter().map(|(k, _)| k.as_str()).collect();
            ingest_objects_as(backend.as_ref(), &keys, partitions, self.workers, &label)
        } else {
            let bytes = self.object_bytes(uri);
            let mut backend = self.open(uri.kind, bytes.len() as u64);
            backend.put(&uri.key, bytes)?;
            ingest_text_as(backend.as_ref(), &uri.key, uri.sep(), partitions, self.workers, &label)
        }
    }

    /// [`Self::resolve`], but each text partition is sealed — handed to
    /// `on_seal` — as soon as its byte range has been read, so the
    /// cluster can release map tasks against sealed partitions while
    /// later ones are still in flight. Glob (binary-objects) sources
    /// have no record-streaming shape — whole objects are the records —
    /// so they fall back to batch semantics: no early seals, and the
    /// report pins `first_partition_ready == fully_materialized`.
    pub fn resolve_streamed(
        &self,
        uri: &StorageUri,
        partitions: usize,
        on_seal: impl FnMut(&SealedPartition),
    ) -> Result<(Dataset, IngestReport)> {
        if uri.kind == BackendKind::File {
            return Err(MareError::Storage(
                "file:// objects are real files, not deterministic populations — \
                 they cannot serve as ingest sources (use put_object/fetch_object)"
                    .into(),
            ));
        }
        let label = uri.label();
        if uri.is_glob() {
            return self.resolve(uri, partitions);
        }
        let bytes = self.object_bytes(uri);
        let mut backend = self.open(uri.kind, bytes.len() as u64);
        backend.put(&uri.key, bytes)?;
        ingest_text_streamed_as(
            backend.as_ref(),
            &uri.key,
            uri.sep(),
            partitions,
            self.workers,
            &label,
            on_seal,
        )
    }

    /// Write one object through a URI — the catalog's WRITE path. Only
    /// `file://` URIs are writable: the key is a filesystem path, the
    /// write is temp+rename atomic (readers never observe a torn
    /// object), and parent directories are created on demand. The
    /// simulated stores stay read-only seeded populations; asking them
    /// to persist is an error, not a silent in-memory write that would
    /// evaporate with the process.
    pub fn put_object(&self, uri: &StorageUri, bytes: &[u8]) -> Result<()> {
        if uri.kind != BackendKind::File {
            return Err(MareError::Storage(format!(
                "{}:// is a simulated read-only population; only file:// objects are writable",
                uri.kind.name()
            )));
        }
        let path = std::path::Path::new(&uri.key);
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let tmp = path.with_extension(format!("tmp-{}", std::process::id()));
        std::fs::write(&tmp, bytes)?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Read one `file://` object back as a zero-copy [`Shared`] buffer
    /// (one read into the refcounted allocation; consumers slice views
    /// out of it). `Ok(None)` when the object does not exist — absence
    /// is a normal answer for checkpoint state, not an error.
    pub fn fetch_object(&self, uri: &StorageUri) -> Result<Option<crate::util::bytes::Shared>> {
        if uri.kind != BackendKind::File {
            return Err(MareError::Storage(format!(
                "{}:// objects are resolved as ingest sources, not fetched; \
                 only file:// supports fetch_object",
                uri.kind.name()
            )));
        }
        match std::fs::read(&uri.key) {
            Ok(bytes) => Ok(Some(crate::util::bytes::Shared::from_vec(bytes))),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(e.into()),
        }
    }

    /// Delete one `file://` object; deleting a missing object is fine.
    pub fn delete_object(&self, uri: &StorageUri) -> Result<()> {
        if uri.kind != BackendKind::File {
            return Err(MareError::Storage(format!(
                "{}:// objects cannot be deleted; only file:// is writable",
                uri.kind.name()
            )));
        }
        match std::fs::remove_file(&uri.key) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(e.into()),
        }
    }

    /// [`Self::resolve`] from a raw label. Schemes registered via
    /// [`Self::register`] resolve first (against the backend's real
    /// objects, record separator by key extension); everything else
    /// must be a built-in storage URI.
    pub fn resolve_label(
        &self,
        label: &str,
        partitions: usize,
    ) -> Result<(Dataset, IngestReport)> {
        if let Some((backend, key)) = self.registered_for(label) {
            let sep = if key.ends_with(".sdf") { crate::workloads::vs::SDF_SEP } else { "\n" };
            return ingest_text_as(backend, key, sep, partitions, self.workers, label);
        }
        let uri = StorageUri::parse(label).ok_or_else(|| {
            MareError::Storage(format!(
                "`{label}` is not a storage URI (schemes: {})",
                Self::schemes().join(", ")
            ))
        })?;
        self.resolve(&uri, partitions)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::Plan;

    #[test]
    fn uris_parse_and_roundtrip() {
        let uri = StorageUri::parse("hdfs://genome.txt?lines=128").unwrap();
        assert_eq!(uri.kind, BackendKind::Hdfs);
        assert_eq!(uri.key, "genome.txt");
        assert_eq!(uri.usize_param("lines", 1), 128);
        assert_eq!(uri.label(), "hdfs://genome.txt?lines=128");
        assert!(!uri.is_glob());
        assert_eq!(uri.sep(), "\n");

        let sdf = StorageUri::parse("swift://library.sdf").unwrap();
        assert_eq!(sdf.sep(), crate::workloads::vs::SDF_SEP);

        let glob = StorageUri::parse("s3://shards/part-*.bin?objects=3&bytes=64").unwrap();
        assert!(glob.is_glob());
        assert_eq!(glob.usize_param("objects", 1), 3);
        assert_eq!(glob.label(), "s3://shards/part-*.bin?objects=3&bytes=64");

        for label in ["ftp://x", "hdfs://", "hdfs:/x", "gen:gc:8", "hdfs://k?=v", "hdfs://k?x"] {
            assert!(StorageUri::parse(label).is_none(), "{label}");
        }
    }

    /// All partitions' records, flattened (for content comparison).
    fn records_of(ds: &Dataset) -> Vec<crate::dataset::Record> {
        match ds.plan().as_ref() {
            Plan::Source { partitions, .. } => {
                partitions.iter().flat_map(|p| p.records.iter().cloned()).collect()
            }
            _ => panic!("expected a source plan"),
        }
    }

    #[test]
    fn resolution_is_deterministic_across_catalogs() {
        let uri = StorageUri::parse("hdfs://genome.txt?lines=64").unwrap();
        let (a, ra) = StorageCatalog::simulated(4).resolve(&uri, 8).unwrap();
        let (b, rb) = StorageCatalog::simulated(4).resolve(&uri, 8).unwrap();
        assert_eq!(records_of(&a), records_of(&b));
        assert_eq!(ra, rb);
        // distinct keys hold distinct content
        let other = StorageUri::parse("hdfs://other.txt?lines=64").unwrap();
        let (c, _) = StorageCatalog::simulated(4).resolve(&other, 8).unwrap();
        assert_ne!(records_of(&a), records_of(&c));
    }

    #[test]
    fn hdfs_resolution_carries_locality_object_stores_do_not() {
        let parts = |label: &str| {
            let uri = StorageUri::parse(label).unwrap();
            let (ds, rep) = StorageCatalog::simulated(4).resolve(&uri, 8).unwrap();
            match ds.plan().as_ref() {
                Plan::Source { partitions, .. } => (partitions.clone(), rep),
                _ => panic!("expected a source plan"),
            }
        };
        let (hdfs, hrep) = parts("hdfs://genome.txt?lines=256");
        assert!(hdfs.iter().all(|p| p.preferred_worker.is_some()));
        assert_eq!(hrep.local_reads, 8);
        assert_eq!(hrep.remote_reads, 0);

        let (swift, srep) = parts("swift://genome.txt?lines=256");
        assert!(swift.iter().all(|p| p.preferred_worker.is_none()));
        assert_eq!(srep.local_reads, 0);
        assert_eq!(srep.remote_reads, 8);
    }

    #[test]
    fn glob_resolution_yields_binary_records() {
        let uri = StorageUri::parse("swift://mol-*.gz?objects=5&bytes=32").unwrap();
        let (ds, rep) = StorageCatalog::simulated(2).resolve(&uri, 2).unwrap();
        assert_eq!(ds.num_partitions(), 2);
        assert!(rep.bytes > 5 * 32); // payload + names
        match ds.plan().as_ref() {
            Plan::Source { partitions, label } => {
                assert_eq!(label, "swift://mol-*.gz?objects=5&bytes=32");
                let total: usize = partitions.iter().map(|p| p.records.len()).sum();
                assert_eq!(total, 5);
                assert!(partitions[0].records[0].is_binary());
            }
            _ => panic!("expected a source plan"),
        }
    }

    #[test]
    fn sdf_objects_parse_as_molecules() {
        let uri = StorageUri::parse("local://library.sdf?molecules=6").unwrap();
        let (ds, _) = StorageCatalog::simulated(2).resolve(&uri, 3).unwrap();
        match ds.plan().as_ref() {
            Plan::Source { partitions, .. } => {
                let total: usize = partitions.iter().map(|p| p.records.len()).sum();
                assert_eq!(total, 6);
            }
            _ => panic!("expected a source plan"),
        }
    }

    #[test]
    fn file_objects_write_fetch_and_delete() {
        let dir = std::env::temp_dir().join(format!("mare-catalog-{}", std::process::id()));
        let path = dir.join("nested").join("state.bin");
        let uri = StorageUri::parse(&format!("file://{}", path.display())).unwrap();
        assert_eq!(uri.kind, BackendKind::File);
        let cat = StorageCatalog::simulated(2);

        assert!(cat.fetch_object(&uri).unwrap().is_none(), "absence is Ok(None)");
        cat.put_object(&uri, b"abc").unwrap();
        assert_eq!(cat.fetch_object(&uri).unwrap().unwrap().as_slice(), b"abc");
        cat.put_object(&uri, b"defg").unwrap(); // atomic replace
        assert_eq!(cat.fetch_object(&uri).unwrap().unwrap().as_slice(), b"defg");
        cat.delete_object(&uri).unwrap();
        assert!(cat.fetch_object(&uri).unwrap().is_none());
        cat.delete_object(&uri).unwrap(); // idempotent

        // simulated schemes refuse the write path; file:// refuses ingest
        let sim = StorageUri::parse("hdfs://x").unwrap();
        assert!(cat.put_object(&sim, b"x").is_err());
        assert!(cat.fetch_object(&sim).is_err());
        assert!(cat.delete_object(&sim).is_err());
        assert!(cat.resolve(&uri, 2).is_err(), "file:// is not an ingest source");

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resolve_label_rejects_non_uris() {
        let err = StorageCatalog::simulated(2)
            .resolve_label("gen:gc:8", 2)
            .unwrap_err()
            .to_string();
        assert!(err.contains("not a storage URI"), "{err}");
    }

    /// Out-of-tree backends join the scheme table via `register`, and
    /// their labels travel the submit wire under the existing
    /// foreign-scheme-ignored rule: opaque (validate-only) on drivers
    /// without the backend, resolvable on a catalog that registered it.
    #[test]
    fn registered_schemes_resolve_and_stay_opaque_on_the_wire() {
        let mut cat = StorageCatalog::simulated(2);
        // built-in schemes cannot be shadowed; bad names are refused
        assert!(cat.register("hdfs", Box::new(LocalFs::new())).is_err());
        assert!(cat.register("", Box::new(LocalFs::new())).is_err());
        assert!(cat.register("no/slash", Box::new(LocalFs::new())).is_err());

        // a registered backend resolves its REAL objects (no seeded
        // population) — params are stripped from the key
        let mut b = LocalFs::new();
        b.put("data.txt", b"a\nb\nc\nd".to_vec()).unwrap();
        cat.register("ceph", Box::new(b)).unwrap();
        assert!(cat.register("ceph", Box::new(LocalFs::new())).is_err(), "no duplicates");
        assert_eq!(cat.registered_schemes(), vec!["ceph"]);

        let (ds, rep) = cat.resolve_label("ceph://data.txt?ignored=1", 2).unwrap();
        assert_eq!(ds.num_partitions(), 2);
        assert_eq!(rep.bytes, 4); // four 1-byte records
        let texts: Vec<String> = records_of(&ds)
            .iter()
            .map(|r| r.as_text().unwrap().to_string())
            .collect();
        assert_eq!(texts, vec!["a", "b", "c", "d"]);
        // missing objects error instead of silently populating
        assert!(cat.resolve_label("ceph://nope.txt", 2).is_err());

        // the wire: an unknown registered scheme is not a StorageUri,
        // so it round-trips as an opaque label (validate-only)
        assert!(StorageUri::parse("ceph://data.txt").is_none());
        let spec = crate::submit::SourceSpec::parse("ceph://data.txt?ignored=1");
        assert!(!spec.is_executable(), "foreign schemes are validate-only");
        assert_eq!(spec.label(), "ceph://data.txt?ignored=1", "label survives the wire");
    }

    /// Streamed resolution seals every text partition early and yields
    /// the same dataset/accounting as batch; glob sources fall back to
    /// batch semantics (no early seals).
    #[test]
    fn streamed_resolution_seals_early_and_matches_batch() {
        let uri = StorageUri::parse("hdfs://genome.txt?lines=256").unwrap();
        let cat = StorageCatalog::simulated(4);
        let (batch, brep) = cat.resolve(&uri, 8).unwrap();
        let mut seals = 0usize;
        let (streamed, srep) = cat.resolve_streamed(&uri, 8, |_| seals += 1).unwrap();
        assert_eq!(seals, 8);
        assert_eq!(records_of(&batch), records_of(&streamed));
        assert_eq!(srep.bytes, brep.bytes);
        assert_eq!(srep.duration, brep.duration);
        assert!(srep.first_partition_ready < srep.fully_materialized, "{srep:?}");
        assert_eq!(brep.first_partition_ready, brep.fully_materialized);

        let glob = StorageUri::parse("swift://m-*.bin?objects=3&bytes=16").unwrap();
        let (_, grep) = cat
            .resolve_streamed(&glob, 2, |_| panic!("globs must not seal early"))
            .unwrap();
        assert_eq!(grep.first_partition_ready, grep.fully_materialized);
    }
}
