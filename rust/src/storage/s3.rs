//! S3 model: a *remote* object store behind a WAN ("in this case the
//! analysis accessed data from a remote location"). High latency, modest
//! per-connection bandwidth, and a tight aggregate egress pipe — the
//! combination behind Figure 5: ingestion speedup near-ideal to 4
//! workers, levelling off at 8–16 as the shared pipe saturates.

use std::collections::BTreeMap;

use crate::error::{MareError, Result};
use crate::simtime::{Duration, NetModel};

use super::{BlockInfo, StorageBackend};

/// S3 multipart chunk granularity for ranged reads.
pub const PART_SIZE: u64 = 64 << 20;

pub struct S3 {
    objects: BTreeMap<String, Vec<u8>>,
    net: NetModel,
}

impl S3 {
    pub fn new() -> Self {
        S3 { objects: BTreeMap::new(), net: NetModel::s3_wan() }
    }

    pub fn with_net(mut self, net: NetModel) -> Self {
        self.net = net;
        self
    }
}

impl Default for S3 {
    fn default() -> Self {
        Self::new()
    }
}

impl StorageBackend for S3 {
    fn name(&self) -> &'static str {
        "s3"
    }

    fn put(&mut self, key: &str, bytes: Vec<u8>) -> Result<()> {
        self.objects.insert(key.to_string(), bytes);
        Ok(())
    }

    fn get(&self, key: &str) -> Result<&[u8]> {
        self.objects
            .get(key)
            .map(|v| v.as_slice())
            .ok_or_else(|| MareError::Storage(format!("s3: no such object `{key}`")))
    }

    fn list(&self) -> Vec<&str> {
        self.objects.keys().map(|s| s.as_str()).collect()
    }

    fn blocks(&self, key: &str) -> Result<Vec<BlockInfo>> {
        let len = self.get(key)?.len() as u64;
        let n = len.div_ceil(PART_SIZE).max(1);
        Ok((0..n as usize)
            .map(|i| BlockInfo {
                index: i,
                len: (len - i as u64 * PART_SIZE).min(PART_SIZE),
                primary: None,
            })
            .collect())
    }

    fn read_time(
        &self,
        _reader_worker: usize,
        _primary: Option<usize>,
        bytes: u64,
        concurrency: u32,
    ) -> Duration {
        self.net.transfer(bytes, concurrency)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wan_latency_dominates_small_reads() {
        let s = S3::new();
        let t = s.read_time(0, None, 1024, 1);
        // ≥ 70 ms latency floor
        assert!(t >= Duration::seconds(0.070), "{t}");
    }

    #[test]
    fn figure5_shape_speedup_flattens() {
        // static input, N parallel readers each fetching 1/N: speedup
        // should be near-linear to 4, then flatten by 16.
        let s = S3::new();
        let total: u64 = 8 << 30;
        let t1 = s.read_time(0, None, total, 1).as_seconds();
        let speedup = |n: u64| {
            let per = s.read_time(0, None, total / n, n as u32).as_seconds();
            t1 / per
        };
        let s4 = speedup(4);
        let s16 = speedup(16);
        assert!(s4 > 3.5, "speedup(4) = {s4}");
        // aggregate cap: 500 MB/s vs 60 MB/s per conn => ceiling ~8.3x
        assert!(s16 < 10.0, "speedup(16) = {s16}");
        assert!(s16 > s4);
    }
}
