//! Storage backends — the heterogeneous ingestion sources of the
//! evaluation (§1.3): HDFS co-located with the workers, Swift provided
//! "nearby" by the cloud, S3 behind a WAN.
//!
//! Each backend is an object store plus a *placement/transfer model*:
//! where an object's blocks physically live (locality hints for the
//! scheduler) and what pipe a worker reads them through. The three
//! models are exactly what produces Figure 3's HDFS>Swift gap and
//! Figure 5's flattening ingestion speedup.
//!
//! * [`hdfs`] — block-based, blocks host-assigned round-robin with
//!   replication, local reads at disk speed
//! * [`swift`] — provider object store: good pipe, shared service cap
//! * [`s3`] — remote object store: WAN latency + tight aggregate egress
//! * [`local`] — driver-side store for tests and small examples
//! * [`ingest`] — parallel read of objects into a [`Dataset`] with
//!   locality metadata + virtual ingestion timing
//! * [`catalog`] — registry of named backends resolving `scheme://key`
//!   URIs into ingested datasets (deterministic seeded population, so
//!   storage-backed plans execute identically on every driver); also
//!   the `file://` WRITE path (real-disk objects, temp+rename atomic)
//! * [`checkpoint`] — stage-boundary state persisted through `file://`
//!   objects, the durable half of crash-recoverable job execution

pub mod catalog;
pub mod checkpoint;
pub mod hdfs;
pub mod ingest;
pub mod local;
pub mod s3;
pub mod swift;

use crate::error::Result;
use crate::simtime::Duration;

pub use catalog::{StorageCatalog, StorageUri};
pub use checkpoint::{plan_fingerprint, CheckpointStore, KillAfter, MemCheckpoint};
pub use hdfs::Hdfs;
pub use ingest::{ingest_text, IngestReport, SealedPartition};
pub use local::LocalFs;
pub use s3::S3;
pub use swift::Swift;

/// Where one block of an object lives, and what reading it costs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BlockInfo {
    /// Index of this block within its object.
    pub index: usize,
    /// Byte length.
    pub len: u64,
    /// Worker hosting the primary replica (None: not on any worker —
    /// external object stores).
    pub primary: Option<usize>,
}

/// A storage backend: named objects + a placement/transfer model.
pub trait StorageBackend: Send + Sync {
    fn name(&self) -> &'static str;

    fn put(&mut self, key: &str, bytes: Vec<u8>) -> Result<()>;

    fn get(&self, key: &str) -> Result<&[u8]>;

    fn list(&self) -> Vec<&str>;

    /// Block layout of an object (drives partition locality).
    fn blocks(&self, key: &str) -> Result<Vec<BlockInfo>>;

    /// Virtual time for `reader_worker` to fetch `bytes` of a block whose
    /// primary replica is `primary`, with `concurrency` simultaneous
    /// readers sharing the backend's pipes.
    fn read_time(
        &self,
        reader_worker: usize,
        primary: Option<usize>,
        bytes: u64,
        concurrency: u32,
    ) -> Duration;

    /// Total bytes across all objects.
    fn total_bytes(&self) -> u64 {
        // default: sum over list(); backends may override
        self.list()
            .iter()
            .map(|k| self.get(k).map(|b| b.len() as u64).unwrap_or(0))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backends_report_names_and_bytes() {
        let mut h: Box<dyn StorageBackend> = Box::new(Hdfs::new(4, 1 << 20));
        let mut s: Box<dyn StorageBackend> = Box::new(Swift::new());
        let mut a: Box<dyn StorageBackend> = Box::new(S3::new());
        for b in [&mut h, &mut s, &mut a] {
            b.put("k", vec![1, 2, 3]).unwrap();
            assert_eq!(b.total_bytes(), 3);
            assert_eq!(b.list(), vec!["k"]);
        }
        assert_eq!(h.name(), "hdfs");
        assert_eq!(s.name(), "swift");
        assert_eq!(a.name(), "s3");
    }
}
