//! Stage-checkpoint persistence: the durable state behind
//! crash-recoverable job execution.
//!
//! [`CheckpointStore`] implements
//! [`StageCheckpointer`](crate::cluster::StageCheckpointer) over the
//! storage catalog's `file://` write path: after every stage boundary
//! it overwrites ONE state object (`<dir>/state.ckpt`, temp+rename
//! atomic) holding the number of completed stages plus the exact
//! post-shuffle partitions the next stage consumes. A successor worker
//! opening the same directory resumes from the last committed boundary
//! instead of re-running the whole plan — for a depth-K tree reduce
//! that means re-entering at the last finished level.
//!
//! The frame is bound to its plan by a fingerprint
//! ([`plan_fingerprint`]): a checkpoint written for a different plan
//! (spool id reuse, operator copying directories around) is silently
//! ignored rather than fed into the wrong job. Corrupt or truncated
//! frames are ignored the same way — **losing a checkpoint never loses
//! a job**, it only costs a from-scratch re-run.
//!
//! Decoding is zero-copy: record payloads come back as
//! [`Shared`]/[`SharedStr`] views slicing the one read buffer, so a
//! resume materializes no per-record allocations beyond the `Vec`
//! spines.
//!
//! ## Frame layout (all integers little-endian u64)
//!
//! ```text
//! "MARECKP1"  magic (8 bytes)
//! fingerprint  plan binding
//! stages_done  boundaries committed
//! npartitions
//!   per partition:
//!     preferred   worker hint (u64::MAX = none)
//!     nrecords
//!       per record:
//!         tag u8       0 = text, 1 = binary
//!         text:        len, bytes (UTF-8)
//!         binary:      name_len, name, len, bytes
//! ```

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::cluster::StageCheckpointer;
use crate::config::BackendKind;
use crate::dataset::{Partition, Record};
use crate::error::{MareError, Result};
use crate::util::bytes::{Shared, SharedStr};
use crate::util::json::Json;

use super::catalog::{StorageCatalog, StorageUri};

/// Frame magic: format name + version. Bump the digit on layout
/// changes; old frames then fail the magic check and are ignored
/// (re-run from scratch) instead of being misparsed.
pub const CKPT_MAGIC: &[u8; 8] = b"MARECKP1";

/// Stable fingerprint binding a checkpoint to its plan: FNV-1a over the
/// plan's canonical JSON text. Not cryptographic — it guards against
/// *accidents* (id reuse, copied spool dirs), not adversaries.
pub fn plan_fingerprint(plan: &Json) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in plan.to_string().bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn corrupt(detail: &str) -> MareError {
    MareError::Checkpoint(format!("corrupt frame: {detail}"))
}

/// Serialize one committed boundary.
fn encode(fingerprint: u64, done: usize, parts: &[Partition]) -> Vec<u8> {
    let payload: usize = parts
        .iter()
        .map(|p| 16 + p.records.iter().map(|r| 9 + r.size_bytes() as usize + 8).sum::<usize>())
        .sum();
    let mut out = Vec::with_capacity(32 + payload);
    out.extend_from_slice(CKPT_MAGIC);
    out.extend_from_slice(&fingerprint.to_le_bytes());
    out.extend_from_slice(&(done as u64).to_le_bytes());
    out.extend_from_slice(&(parts.len() as u64).to_le_bytes());
    for p in parts {
        let pref = p.preferred_worker.map(|w| w as u64).unwrap_or(u64::MAX);
        out.extend_from_slice(&pref.to_le_bytes());
        out.extend_from_slice(&(p.records.len() as u64).to_le_bytes());
        for r in &p.records {
            match r {
                Record::Text(s) => {
                    out.push(0);
                    out.extend_from_slice(&(s.len() as u64).to_le_bytes());
                    out.extend_from_slice(s.as_str().as_bytes());
                }
                Record::Binary { name, bytes } => {
                    out.push(1);
                    out.extend_from_slice(&(name.len() as u64).to_le_bytes());
                    out.extend_from_slice(name.as_bytes());
                    out.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
                    out.extend_from_slice(bytes.as_slice());
                }
            }
        }
    }
    out
}

/// Bounds-checked reader over the one fetched buffer; payload reads are
/// O(1) sub-views, not copies.
struct Cursor {
    buf: Shared,
    off: usize,
}

impl Cursor {
    fn take(&mut self, n: usize) -> Result<Shared> {
        let end = self.off.checked_add(n).ok_or_else(|| corrupt("length overflow"))?;
        if end > self.buf.len() {
            return Err(corrupt("truncated"));
        }
        let view = self.buf.slice(self.off, end);
        self.off = end;
        Ok(view)
    }

    fn u64(&mut self) -> Result<u64> {
        let view = self.take(8)?;
        let mut b = [0u8; 8];
        b.copy_from_slice(view.as_slice());
        Ok(u64::from_le_bytes(b))
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?.as_slice()[0])
    }

    fn len(&mut self) -> Result<usize> {
        let n = self.u64()?;
        // a claimed length beyond the buffer is corruption, not an
        // invitation to allocate
        if n > self.buf.len() as u64 {
            return Err(corrupt("length exceeds frame"));
        }
        Ok(n as usize)
    }
}

/// Deserialize a frame: `(fingerprint, stages_done, partitions)`.
fn decode(buf: Shared) -> Result<(u64, usize, Vec<Partition>)> {
    let mut c = Cursor { buf, off: 0 };
    if c.take(8)?.as_slice() != CKPT_MAGIC {
        return Err(corrupt("bad magic"));
    }
    let fingerprint = c.u64()?;
    let done = c.u64()? as usize;
    let nparts = c.len()?;
    let mut parts = Vec::new();
    for _ in 0..nparts {
        let pref = c.u64()?;
        let preferred_worker = (pref != u64::MAX).then_some(pref as usize);
        let nrecords = c.len()?;
        let mut records = Vec::new();
        for _ in 0..nrecords {
            let record = match c.u8()? {
                0 => {
                    let n = c.len()?;
                    let s = SharedStr::from_shared(c.take(n)?)
                        .map_err(|_| corrupt("text record is not UTF-8"))?;
                    Record::Text(s)
                }
                1 => {
                    let n = c.len()?;
                    let name = String::from_utf8(c.take(n)?.as_slice().to_vec())
                        .map_err(|_| corrupt("binary name is not UTF-8"))?;
                    let n = c.len()?;
                    Record::Binary { name, bytes: c.take(n)? }
                }
                t => return Err(corrupt(&format!("unknown record tag {t}"))),
            };
            records.push(record);
        }
        parts.push(Partition { records, preferred_worker });
    }
    if c.off != c.buf.len() {
        return Err(corrupt("trailing bytes"));
    }
    Ok((fingerprint, done, parts))
}

/// Durable stage checkpoints for one job, stored as a single `file://`
/// object under the job's checkpoint directory.
pub struct CheckpointStore {
    catalog: StorageCatalog,
    uri: StorageUri,
    fingerprint: u64,
}

impl CheckpointStore {
    /// A store over `<dir>/state.ckpt`, bound to `plan`. The directory
    /// need not exist yet — the first commit creates it.
    pub fn open(dir: &Path, plan: &Json) -> CheckpointStore {
        let path = dir.join("state.ckpt");
        CheckpointStore {
            catalog: StorageCatalog::simulated(1),
            uri: StorageUri {
                kind: BackendKind::File,
                key: path.display().to_string(),
                params: Vec::new(),
            },
            fingerprint: plan_fingerprint(plan),
        }
    }

    /// The `file://` label the state lives behind (logs, tests).
    pub fn label(&self) -> String {
        self.uri.label()
    }

    /// Drop the persisted state (job finished — nothing to resume).
    pub fn clear(&self) -> Result<()> {
        self.catalog.delete_object(&self.uri)
    }
}

impl StageCheckpointer for CheckpointStore {
    fn resume(&self) -> Option<(usize, Vec<Partition>)> {
        // any failure to read or parse means "no usable checkpoint":
        // the job re-runs from the source rather than dying over state
        // that exists purely as an optimization
        let buf = self.catalog.fetch_object(&self.uri).ok()??;
        let (fingerprint, done, parts) = decode(buf).ok()?;
        if fingerprint != self.fingerprint {
            return None; // a different plan's state (id reuse) — ignore
        }
        Some((done, parts))
    }

    fn committed(&self, done: usize, parts: &[Partition]) -> Result<()> {
        self.catalog.put_object(&self.uri, &encode(self.fingerprint, done, parts))
    }
}

/// Fault-injection wrapper: delegates to `inner`, then aborts the run
/// with [`MareError::KilledMidRun`] once `after` boundaries have been
/// committed by THIS attempt (boundaries skipped via resume were
/// committed by a previous life and do not count). The `launches` field
/// travels as 0 here — the layer that owns the launch counter (the
/// driver) enriches it before reporting.
pub struct KillAfter<'a> {
    inner: &'a dyn StageCheckpointer,
    after: usize,
    commits: AtomicUsize,
}

impl<'a> KillAfter<'a> {
    pub fn new(inner: &'a dyn StageCheckpointer, after: usize) -> KillAfter<'a> {
        KillAfter { inner, after: after.max(1), commits: AtomicUsize::new(0) }
    }
}

impl StageCheckpointer for KillAfter<'_> {
    fn resume(&self) -> Option<(usize, Vec<Partition>)> {
        self.inner.resume()
    }

    fn committed(&self, done: usize, parts: &[Partition]) -> Result<()> {
        self.inner.committed(done, parts)?;
        if self.commits.fetch_add(1, Ordering::SeqCst) + 1 >= self.after {
            return Err(MareError::KilledMidRun { stages_done: done, launches: 0 });
        }
        Ok(())
    }
}

/// In-memory checkpointer for unit tests and same-process crosschecks —
/// the protocol without the filesystem.
#[derive(Default)]
pub struct MemCheckpoint {
    state: Mutex<Option<(usize, Vec<Partition>)>>,
}

impl MemCheckpoint {
    pub fn new() -> MemCheckpoint {
        MemCheckpoint::default()
    }

    /// Number of stages the stored boundary covers (None: never
    /// committed).
    pub fn stages_done(&self) -> Option<usize> {
        self.state.lock().unwrap().as_ref().map(|(d, _)| *d)
    }
}

impl StageCheckpointer for MemCheckpoint {
    fn resume(&self) -> Option<(usize, Vec<Partition>)> {
        self.state.lock().unwrap().clone()
    }

    fn committed(&self, done: usize, parts: &[Partition]) -> Result<()> {
        *self.state.lock().unwrap() = Some((done, parts.to_vec()));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_parts() -> Vec<Partition> {
        vec![
            Partition::with_locality(
                vec![Record::text("ACGT"), Record::binary("shard-0.gz", vec![1u8, 2, 3])],
                2,
            ),
            Partition::new(vec![Record::text("")]),
            Partition::new(Vec::new()),
        ]
    }

    #[test]
    fn frames_roundtrip_bytes_and_locality() {
        let parts = sample_parts();
        let frame = encode(7, 3, &parts);
        let (fp, done, back) = decode(Shared::from_vec(frame)).unwrap();
        assert_eq!(fp, 7);
        assert_eq!(done, 3);
        assert_eq!(back, parts);
    }

    #[test]
    fn corrupt_frames_error_not_panic() {
        let good = encode(7, 1, &sample_parts());
        // truncations at every prefix length must all error cleanly
        for cut in 0..good.len() {
            assert!(decode(Shared::from_vec(good[..cut].to_vec())).is_err(), "cut {cut}");
        }
        // bad magic
        let mut bad = good.clone();
        bad[0] ^= 0xff;
        assert!(decode(Shared::from_vec(bad)).is_err());
        // trailing garbage
        let mut long = good.clone();
        long.push(0);
        assert!(decode(Shared::from_vec(long)).is_err());
        // absurd claimed length must not trigger a giant allocation
        let mut lying = good;
        let n = lying.len();
        lying[n - 1] = 0xff; // corrupt the final payload length bytes
        assert!(decode(Shared::from_vec(lying)).is_err());
    }

    #[test]
    fn store_persists_resumes_and_clears() {
        let dir = std::env::temp_dir().join(format!("mare-ckpt-{}", std::process::id()));
        let plan = Json::parse(r#"{"v":1,"pipeline":[]}"#).unwrap();
        let store = CheckpointStore::open(&dir, &plan);
        assert!(store.label().starts_with("file://"));
        assert!(store.resume().is_none(), "no state yet");

        let parts = sample_parts();
        store.committed(2, &parts).unwrap();
        let (done, back) = store.resume().unwrap();
        assert_eq!(done, 2);
        assert_eq!(back, parts);

        // a store bound to a DIFFERENT plan ignores this state
        let other = Json::parse(r#"{"v":2,"pipeline":[]}"#).unwrap();
        assert!(CheckpointStore::open(&dir, &other).resume().is_none());

        // corrupt state on disk: resume falls back to from-scratch
        store.committed(3, &parts).unwrap();
        let path = dir.join("state.ckpt");
        std::fs::write(&path, b"MARECKP1 but then nonsense").unwrap();
        assert!(store.resume().is_none());

        store.committed(4, &parts).unwrap();
        store.clear().unwrap();
        assert!(store.resume().is_none());
        store.clear().unwrap(); // idempotent

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn kill_after_counts_only_this_attempts_commits() {
        let mem = MemCheckpoint::new();
        mem.committed(1, &sample_parts()).unwrap();

        let killer = KillAfter::new(&mem, 2);
        assert_eq!(killer.resume().unwrap().0, 1, "resume passes through");
        killer.committed(2, &sample_parts()).unwrap();
        let err = killer.committed(3, &sample_parts()).unwrap_err();
        match err {
            MareError::KilledMidRun { stages_done, launches } => {
                assert_eq!(stages_done, 3);
                assert_eq!(launches, 0);
            }
            other => panic!("expected KilledMidRun, got {other}"),
        }
        // the inner store committed BEFORE the kill — state is durable
        assert_eq!(mem.stages_done(), Some(3));
    }
}
