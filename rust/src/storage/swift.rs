//! Swift model: the provider's object store, decoupled from the workers
//! but *near* them ("by setting up the cluster on cPouta, we ran the
//! analyses close to Swift, thus enabling fast ingestion"). No locality
//! — every read crosses the service pipe, which has a healthy
//! per-connection bandwidth and a shared aggregate cap.

use std::collections::BTreeMap;

use crate::error::{MareError, Result};
use crate::simtime::{Duration, NetModel};

use super::{BlockInfo, StorageBackend};

/// Swift segments large objects; 256 MiB keeps partition/block mapping
/// comparable to HDFS runs.
pub const SEGMENT_SIZE: u64 = 256 << 20;

pub struct Swift {
    objects: BTreeMap<String, Vec<u8>>,
    net: NetModel,
}

impl Swift {
    pub fn new() -> Self {
        Swift { objects: BTreeMap::new(), net: NetModel::swift_service() }
    }

    pub fn with_net(mut self, net: NetModel) -> Self {
        self.net = net;
        self
    }
}

impl Default for Swift {
    fn default() -> Self {
        Self::new()
    }
}

impl StorageBackend for Swift {
    fn name(&self) -> &'static str {
        "swift"
    }

    fn put(&mut self, key: &str, bytes: Vec<u8>) -> Result<()> {
        self.objects.insert(key.to_string(), bytes);
        Ok(())
    }

    fn get(&self, key: &str) -> Result<&[u8]> {
        self.objects
            .get(key)
            .map(|v| v.as_slice())
            .ok_or_else(|| MareError::Storage(format!("swift: no such object `{key}`")))
    }

    fn list(&self) -> Vec<&str> {
        self.objects.keys().map(|s| s.as_str()).collect()
    }

    fn blocks(&self, key: &str) -> Result<Vec<BlockInfo>> {
        let len = self.get(key)?.len() as u64;
        let n = len.div_ceil(SEGMENT_SIZE).max(1);
        Ok((0..n as usize)
            .map(|i| BlockInfo {
                index: i,
                len: (len - i as u64 * SEGMENT_SIZE).min(SEGMENT_SIZE),
                primary: None, // not on any worker
            })
            .collect())
    }

    fn read_time(
        &self,
        _reader_worker: usize,
        _primary: Option<usize>,
        bytes: u64,
        concurrency: u32,
    ) -> Duration {
        self.net.transfer(bytes, concurrency)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_locality_hints() {
        let mut s = Swift::new();
        s.put("k", vec![0u8; 100]).unwrap();
        assert!(s.blocks("k").unwrap().iter().all(|b| b.primary.is_none()));
    }

    #[test]
    fn aggregate_cap_slows_concurrent_readers() {
        let s = Swift::new();
        let one = s.read_time(0, None, 1 << 30, 1);
        let many = s.read_time(0, None, 1 << 30, 32);
        assert!(many > one);
    }

    #[test]
    fn reader_identity_is_irrelevant() {
        let s = Swift::new();
        assert_eq!(s.read_time(0, None, 1 << 20, 4), s.read_time(7, Some(3), 1 << 20, 4));
    }
}
