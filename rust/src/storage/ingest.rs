//! Parallel ingestion: storage object(s) → [`Dataset`] partitions with
//! locality metadata + virtual ingestion timing.
//!
//! Every worker reads its share of the object concurrently, through the
//! backend's transfer model. The returned [`IngestReport`] is the
//! quantity behind Figure 5 (speedup = t(1 reader)/t(N readers)), and
//! the per-partition locality hints are what lets HDFS-backed runs beat
//! Swift in Figure 3.

use crate::dataset::{split_records, Dataset, Partition, Record};
use crate::error::{MareError, Result};
use crate::simtime::Duration;

use super::StorageBackend;

/// Virtual-time account of one ingestion.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IngestReport {
    pub bytes: u64,
    /// Distinct workers that read in parallel.
    pub readers: usize,
    /// Virtual wall time of the parallel read (max over readers).
    pub duration: Duration,
}

/// Ingest a text object, splitting on `sep` (the paper's `TextFile`
/// semantics), into `num_partitions` partitions spread over `workers`.
pub fn ingest_text(
    backend: &dyn StorageBackend,
    key: &str,
    sep: &str,
    num_partitions: usize,
    workers: usize,
) -> Result<(Dataset, IngestReport)> {
    let bytes = backend.get(key)?;
    let total = bytes.len() as u64;
    let text = std::str::from_utf8(bytes)
        .map_err(|_| MareError::Storage(format!("{key}: not UTF-8 text")))?;
    let records = split_records(text, sep);
    let blocks = backend.blocks(key)?;

    let n = num_partitions.max(1);
    let workers = workers.max(1);
    let total_records = records.len();

    // contiguous chunks; partition locality = primary of the block its
    // first byte falls in
    let mut partitions: Vec<Partition> = Vec::with_capacity(n);
    let mut it = records.into_iter();
    let mut byte_cursor = 0u64;
    for i in 0..n {
        let count = total_records / n + usize::from(i < total_records % n);
        let recs: Vec<Record> = it.by_ref().take(count).map(Record::text).collect();
        let part_bytes: u64 = recs.iter().map(Record::size_bytes).sum();
        let primary = block_at(&blocks, byte_cursor).and_then(|b| b.primary);
        byte_cursor += part_bytes;
        partitions.push(Partition { records: recs, preferred_worker: primary });
    }

    let report = account(backend, &partitions, workers, total);
    let label = format!("{}://{key}", backend.name());
    Ok((Dataset::from_partitions(partitions, label), report))
}

/// Ingest many objects as binary records (one record per object — the
/// paper's `BinaryFiles` semantics), one partition per `num_partitions`.
pub fn ingest_objects(
    backend: &dyn StorageBackend,
    keys: &[&str],
    num_partitions: usize,
    workers: usize,
) -> Result<(Dataset, IngestReport)> {
    let n = num_partitions.max(1);
    let workers = workers.max(1);
    let mut records = Vec::with_capacity(keys.len());
    let mut total = 0u64;
    for k in keys {
        let bytes = backend.get(k)?.to_vec();
        total += bytes.len() as u64;
        records.push(Record::binary(*k, bytes));
    }

    let mut partitions: Vec<Partition> = (0..n).map(|_| Partition::new(vec![])).collect();
    for (i, (k, r)) in keys.iter().zip(records).enumerate() {
        let p = i % n;
        if partitions[p].records.is_empty() {
            partitions[p].preferred_worker =
                backend.blocks(k)?.first().and_then(|b| b.primary);
        }
        partitions[p].records.push(r);
    }

    let report = account(backend, &partitions, workers, total);
    let label = format!("{}://[{} objects]", backend.name(), keys.len());
    Ok((Dataset::from_partitions(partitions, label), report))
}

fn block_at<'a>(
    blocks: &'a [super::BlockInfo],
    byte: u64,
) -> Option<&'a super::BlockInfo> {
    let mut cursor = 0u64;
    for b in blocks {
        if byte < cursor + b.len.max(1) {
            return Some(b);
        }
        cursor += b.len;
    }
    blocks.last()
}

/// Parallel-read accounting: each partition is read by its locality
/// worker (or round-robin), all readers share the backend pipe. Public
/// so format-aware ingest paths (e.g. FASTQ in `workloads::driver`) can
/// account their own partitioning.
pub fn account(
    backend: &dyn StorageBackend,
    partitions: &[Partition],
    workers: usize,
    _total: u64,
) -> IngestReport {
    let mut per_worker = vec![Duration::ZERO; workers];
    let mut used = vec![false; workers];
    let readers: Vec<usize> = partitions
        .iter()
        .enumerate()
        .map(|(i, p)| p.preferred_worker.unwrap_or(i % workers).min(workers - 1))
        .collect();
    let concurrency = {
        for &r in &readers {
            used[r] = true;
        }
        used.iter().filter(|&&u| u).count().max(1) as u32
    };
    let mut bytes = 0u64;
    for (p, &reader) in partitions.iter().zip(&readers) {
        let b = p.size_bytes();
        bytes += b;
        per_worker[reader] += backend.read_time(reader, p.preferred_worker, b, concurrency);
    }
    IngestReport {
        bytes,
        readers: concurrency as usize,
        duration: per_worker.into_iter().max().unwrap_or(Duration::ZERO),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{Hdfs, StorageBackend, Swift, S3};

    fn text_object(lines: usize) -> String {
        (0..lines).map(|i| format!("record-{i:06}")).collect::<Vec<_>>().join("\n")
    }

    #[test]
    fn hdfs_ingest_carries_locality() {
        let mut h = Hdfs::new(4, 1024);
        h.put("data", text_object(500).into_bytes()).unwrap();
        let (ds, rep) = ingest_text(&h, "data", "\n", 8, 4).unwrap();
        assert_eq!(ds.num_partitions(), 8);
        assert!(rep.bytes > 0);
        // every partition has an HDFS locality hint
        match ds.plan().as_ref() {
            crate::dataset::Plan::Source { partitions, .. } => {
                assert!(partitions.iter().all(|p| p.preferred_worker.is_some()));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn object_store_ingest_has_no_locality() {
        let mut s = Swift::new();
        s.put("data", text_object(100).into_bytes()).unwrap();
        let (ds, _) = ingest_text(&s, "data", "\n", 4, 4).unwrap();
        match ds.plan().as_ref() {
            crate::dataset::Plan::Source { partitions, .. } => {
                assert!(partitions.iter().all(|p| p.preferred_worker.is_none()));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn more_workers_ingest_faster_until_cap() {
        let mut s3 = S3::new();
        s3.put("big", vec![b'x'; 8 << 20].into_iter().map(|b| b).collect::<Vec<u8>>())
            .unwrap();
        // make it line-structured so splitting works
        let mut s3 = S3::new();
        let line = "x".repeat(1023);
        let doc: String = (0..8192).map(|_| format!("{line}\n")).collect();
        s3.put("big", doc.into_bytes()).unwrap();

        let t = |workers: usize| {
            ingest_text(&s3, "big", "\n", workers * 2, workers).unwrap().1.duration.as_seconds()
        };
        let t1 = t(1);
        let t4 = t(4);
        let t16 = t(16);
        assert!(t4 < t1, "t1={t1} t4={t4}");
        assert!(t16 <= t4);
        // flattening: 16 workers nowhere near 16x
        assert!(t1 / t16 < 12.0, "speedup {}", t1 / t16);
    }

    #[test]
    fn binary_objects_one_record_each() {
        let mut s = Swift::new();
        for i in 0..5 {
            s.put(&format!("f{i}.gz"), vec![i as u8; 10]).unwrap();
        }
        let keys: Vec<&str> = s.list();
        let (ds, rep) = ingest_objects(&s, &keys, 2, 2).unwrap();
        assert_eq!(ds.num_partitions(), 2);
        assert_eq!(rep.bytes, 75); // 5 x (10 payload + 5 name) bytes
        match ds.plan().as_ref() {
            crate::dataset::Plan::Source { partitions, .. } => {
                let total: usize = partitions.iter().map(|p| p.len()).sum();
                assert_eq!(total, 5);
                assert!(partitions[0].records[0].is_binary());
            }
            _ => panic!(),
        }
    }

    #[test]
    fn missing_key_errors() {
        let s = Swift::new();
        assert!(ingest_text(&s, "nope", "\n", 1, 1).is_err());
    }
}
