//! Parallel ingestion: storage object(s) → [`Dataset`] partitions with
//! locality metadata + virtual ingestion timing.
//!
//! Every worker reads its share of the object concurrently, through the
//! backend's transfer model. The returned [`IngestReport`] is the
//! quantity behind Figure 5 (speedup = t(1 reader)/t(N readers)), and
//! the per-partition locality hints are what lets HDFS-backed runs beat
//! Swift in Figure 3.
//!
//! Two ingest shapes share the partitioning code:
//!
//! * **batch** ([`ingest_text_as`]) — partitions become visible only
//!   once the whole object has materialized;
//! * **streamed** ([`ingest_text_streamed_as`]) — each partition's
//!   `Shared` view is yielded through a seal callback as soon as its
//!   byte range has been read, so the cluster can start map tasks while
//!   later partitions are still in flight. Both shapes produce
//!   byte-identical partitions and byte accounting (property-tested in
//!   `rust/tests/prop_invariants.rs`); they differ only in the
//!   `first_partition_ready` ledger entry.

use crate::dataset::{Dataset, Partition, Record, Splitter};
use crate::error::{MareError, Result};
use crate::simtime::Duration;
use crate::util::bytes::{SegmentWriter, Shared, SharedStr};

use super::StorageBackend;

/// Virtual-time account of one ingestion.
#[derive(Debug, Clone, PartialEq)]
pub struct IngestReport {
    pub bytes: u64,
    /// Distinct workers that read in parallel.
    pub readers: usize,
    /// Virtual wall time of the parallel read (max over readers).
    pub duration: Duration,
    /// Observed payload bytes of each ingested partition, in partition
    /// order — what the optimizer's auto reduce-depth planning consumes
    /// instead of nominal record sizes (`mare::opt::OptEnv`).
    pub partition_bytes: Vec<u64>,
    /// Partitions read by the worker hosting their primary replica.
    pub local_reads: usize,
    /// Partitions read across the network (no locality hint, or a hint
    /// outside this cluster's worker range).
    pub remote_reads: usize,
    /// Virtual time at which the first partition became available to
    /// the scheduler. Batch ingest publishes nothing before the whole
    /// object lands, so this equals [`IngestReport::fully_materialized`]
    /// there; streamed ingest seals each partition as its byte range
    /// finishes, so this is strictly earlier whenever more than one
    /// seal happens (the overlap the streaming path buys, as a ledger).
    pub first_partition_ready: Duration,
    /// Virtual time at which the whole object finished materializing
    /// (identical to [`IngestReport::duration`]).
    pub fully_materialized: Duration,
}

/// Ingest a text object, splitting on `sep` (the paper's `TextFile`
/// semantics), into `num_partitions` partitions spread over `workers`.
pub fn ingest_text(
    backend: &dyn StorageBackend,
    key: &str,
    sep: &str,
    num_partitions: usize,
    workers: usize,
) -> Result<(Dataset, IngestReport)> {
    let label = format!("{}://{key}", backend.name());
    ingest_text_as(backend, key, sep, num_partitions, workers, &label)
}

/// [`ingest_text`] with an explicit dataset label (the storage catalog
/// labels datasets with the full canonical URI, params included, so
/// jobs built over them re-encode to the submitted label).
pub fn ingest_text_as(
    backend: &dyn StorageBackend,
    key: &str,
    sep: &str,
    num_partitions: usize,
    workers: usize,
    label: &str,
) -> Result<(Dataset, IngestReport)> {
    let (text, total) = materialize_object(backend, key)?;
    let partitions =
        partition_text(&text, sep, num_partitions.max(1), &backend.blocks(key)?);
    let report = account(backend, &partitions, workers.max(1), total);
    Ok((Dataset::from_partitions(partitions, label.to_string()), report))
}

/// Stream a text object's bytes off the backend through an
/// exact-capacity [`SegmentWriter`] in bounded chunks (still exactly
/// ONE copy off the backend — the chunking models arrival, not extra
/// allocation).
const STREAM_CHUNK: usize = 64 << 10;

fn materialize_object(backend: &dyn StorageBackend, key: &str) -> Result<(SharedStr, u64)> {
    let src = backend.get(key)?;
    let mut w = SegmentWriter::with_capacity(src.len());
    for chunk in src.chunks(STREAM_CHUNK.max(1)) {
        w.push(chunk);
    }
    let buf = w.finish();
    let total = buf.len() as u64;
    let text = SharedStr::from_shared(buf)
        .map_err(|_| MareError::Storage(format!("{key}: not UTF-8 text")))?;
    Ok((text, total))
}

/// Contiguous record chunks over the scanner's exact byte ranges;
/// partition locality = primary of the block holding its first
/// record's true byte offset (the pre-scanner path approximated this
/// with a payload+separator cursor).
fn partition_text(
    text: &SharedStr,
    sep: &str,
    n: usize,
    blocks: &[super::BlockInfo],
) -> Vec<Partition> {
    let ranges = Splitter::new(sep).record_ranges(text.as_str());
    let total_records = ranges.len();
    let mut partitions: Vec<Partition> = Vec::with_capacity(n);
    let mut cursor = 0usize;
    for i in 0..n {
        let count = total_records / n + usize::from(i < total_records % n);
        let chunk = &ranges[cursor..cursor + count];
        cursor += count;
        let recs: Vec<Record> =
            chunk.iter().map(|&(s, e)| Record::Text(text.slice(s, e))).collect();
        let start_byte =
            chunk.first().map(|&(s, _)| s as u64).unwrap_or(text.len() as u64);
        let primary = block_at(blocks, start_byte).and_then(|b| b.primary);
        partitions.push(Partition { records: recs, preferred_worker: primary });
    }
    partitions
}

/// One partition sealed by streamed ingest: its records are final (O(1)
/// views of the object buffer) and its byte range finished arriving at
/// `ready_at` virtual time.
#[derive(Debug, Clone)]
pub struct SealedPartition {
    /// Position in the dataset's partition order.
    pub index: usize,
    pub partition: Partition,
    pub ready_at: Duration,
}

/// [`ingest_text_as`], but each partition is sealed — handed to
/// `on_seal` — as soon as its byte range has been read by its assigned
/// reader, in ascending `ready_at` order. The returned dataset and
/// byte accounting are identical to the batch path; only
/// `first_partition_ready` differs (min seal time instead of full
/// materialization).
pub fn ingest_text_streamed_as(
    backend: &dyn StorageBackend,
    key: &str,
    sep: &str,
    num_partitions: usize,
    workers: usize,
    label: &str,
    mut on_seal: impl FnMut(&SealedPartition),
) -> Result<(Dataset, IngestReport)> {
    let (text, total) = materialize_object(backend, key)?;
    let partitions =
        partition_text(&text, sep, num_partitions.max(1), &backend.blocks(key)?);
    let (report, seals) =
        account_with_seals(backend, &partitions, workers.max(1), total);
    let mut order: Vec<usize> = (0..partitions.len()).collect();
    order.sort_by_key(|&i| seals[i]);
    for i in order {
        on_seal(&SealedPartition {
            index: i,
            partition: partitions[i].clone(), // refcount bumps, no copy
            ready_at: seals[i],
        });
    }
    Ok((Dataset::from_partitions(partitions, label.to_string()), report))
}

/// Ingest many objects as binary records (one record per object — the
/// paper's `BinaryFiles` semantics), one partition per `num_partitions`.
pub fn ingest_objects(
    backend: &dyn StorageBackend,
    keys: &[&str],
    num_partitions: usize,
    workers: usize,
) -> Result<(Dataset, IngestReport)> {
    let label = format!("{}://[{} objects]", backend.name(), keys.len());
    ingest_objects_as(backend, keys, num_partitions, workers, &label)
}

/// [`ingest_objects`] with an explicit dataset label (see
/// [`ingest_text_as`]).
pub fn ingest_objects_as(
    backend: &dyn StorageBackend,
    keys: &[&str],
    num_partitions: usize,
    workers: usize,
    label: &str,
) -> Result<(Dataset, IngestReport)> {
    let n = num_partitions.max(1);
    let workers = workers.max(1);
    let mut records = Vec::with_capacity(keys.len());
    let mut total = 0u64;
    for k in keys {
        // one copy off the backend into a shared payload; everything
        // downstream (mounts, shuffle, collect) is a refcount bump
        let bytes = Shared::copy_from_slice(backend.get(k)?);
        total += bytes.len() as u64;
        records.push(Record::binary(*k, bytes));
    }

    let mut partitions: Vec<Partition> = (0..n).map(|_| Partition::new(vec![])).collect();
    for (i, (k, r)) in keys.iter().zip(records).enumerate() {
        let p = i % n;
        if partitions[p].records.is_empty() {
            partitions[p].preferred_worker =
                backend.blocks(k)?.first().and_then(|b| b.primary);
        }
        partitions[p].records.push(r);
    }

    let report = account(backend, &partitions, workers, total);
    Ok((Dataset::from_partitions(partitions, label.to_string()), report))
}

/// The block whose byte range contains `byte`. Zero-length blocks
/// occupy no byte range and are skipped — widening them to one byte
/// (as the seed did) shifted every subsequent block's range.
fn block_at<'a>(
    blocks: &'a [super::BlockInfo],
    byte: u64,
) -> Option<&'a super::BlockInfo> {
    let mut cursor = 0u64;
    for b in blocks {
        if b.len > 0 && byte < cursor + b.len {
            return Some(b);
        }
        cursor += b.len;
    }
    // past the end (trailing separator bytes): the last real block
    blocks.iter().rev().find(|b| b.len > 0).or_else(|| blocks.last())
}

/// Parallel-read accounting: each partition is read by its locality
/// worker (or round-robin), all readers share the backend pipe. Public
/// so format-aware ingest paths (e.g. FASTQ in `workloads::driver`) can
/// account their own partitioning.
///
/// A locality hint outside this cluster's worker range (the ingest
/// layout was computed for a larger cluster) is spread deterministically
/// by modulo — clamping to the last worker piled every high-index hint
/// onto it — and is accounted as a remote read, since the hinted worker
/// does not exist here.
pub fn account(
    backend: &dyn StorageBackend,
    partitions: &[Partition],
    workers: usize,
    total: u64,
) -> IngestReport {
    let (mut report, _) = account_with_seals(backend, partitions, workers, total);
    // batch semantics: nothing is visible before the whole object lands
    report.first_partition_ready = report.fully_materialized;
    report
}

/// [`account`] that also returns each partition's **seal time** — the
/// virtual time its assigned reader finished reading it, with reads on
/// one reader happening in partition order. The report's
/// `first_partition_ready` is the minimum seal (streamed semantics);
/// [`account`] overwrites it back to `fully_materialized` for batch.
pub fn account_with_seals(
    backend: &dyn StorageBackend,
    partitions: &[Partition],
    workers: usize,
    _total: u64,
) -> (IngestReport, Vec<Duration>) {
    let mut per_worker = vec![Duration::ZERO; workers];
    let mut used = vec![false; workers];
    let readers: Vec<usize> = partitions
        .iter()
        .enumerate()
        .map(|(i, p)| match p.preferred_worker {
            Some(w) if w < workers => w,
            Some(w) => w % workers,
            None => i % workers,
        })
        .collect();
    let concurrency = {
        for &r in &readers {
            used[r] = true;
        }
        used.iter().filter(|&&u| u).count().max(1) as u32
    };
    let mut bytes = 0u64;
    let mut partition_bytes = Vec::with_capacity(partitions.len());
    let mut local_reads = 0usize;
    let mut remote_reads = 0usize;
    let mut seals = Vec::with_capacity(partitions.len());
    for (p, &reader) in partitions.iter().zip(&readers) {
        let b = p.size_bytes();
        bytes += b;
        partition_bytes.push(b);
        if p.preferred_worker == Some(reader) {
            local_reads += 1;
        } else {
            remote_reads += 1;
        }
        per_worker[reader] += backend.read_time(reader, p.preferred_worker, b, concurrency);
        seals.push(per_worker[reader]);
    }
    let duration = per_worker.into_iter().max().unwrap_or(Duration::ZERO);
    let report = IngestReport {
        bytes,
        readers: concurrency as usize,
        duration,
        partition_bytes,
        local_reads,
        remote_reads,
        first_partition_ready: seals.iter().copied().min().unwrap_or(duration),
        fully_materialized: duration,
    };
    (report, seals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{Hdfs, StorageBackend, Swift, S3};

    fn text_object(lines: usize) -> String {
        (0..lines).map(|i| format!("record-{i:06}")).collect::<Vec<_>>().join("\n")
    }

    #[test]
    fn hdfs_ingest_carries_locality() {
        let mut h = Hdfs::new(4, 1024);
        h.put("data", text_object(500).into_bytes()).unwrap();
        let (ds, rep) = ingest_text(&h, "data", "\n", 8, 4).unwrap();
        assert_eq!(ds.num_partitions(), 8);
        assert!(rep.bytes > 0);
        // every partition has an HDFS locality hint
        match ds.plan().as_ref() {
            crate::dataset::Plan::Source { partitions, .. } => {
                assert!(partitions.iter().all(|p| p.preferred_worker.is_some()));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn object_store_ingest_has_no_locality() {
        let mut s = Swift::new();
        s.put("data", text_object(100).into_bytes()).unwrap();
        let (ds, _) = ingest_text(&s, "data", "\n", 4, 4).unwrap();
        match ds.plan().as_ref() {
            crate::dataset::Plan::Source { partitions, .. } => {
                assert!(partitions.iter().all(|p| p.preferred_worker.is_none()));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn more_workers_ingest_faster_until_cap() {
        let mut s3 = S3::new();
        s3.put("big", vec![b'x'; 8 << 20].into_iter().map(|b| b).collect::<Vec<u8>>())
            .unwrap();
        // make it line-structured so splitting works
        let mut s3 = S3::new();
        let line = "x".repeat(1023);
        let doc: String = (0..8192).map(|_| format!("{line}\n")).collect();
        s3.put("big", doc.into_bytes()).unwrap();

        let t = |workers: usize| {
            ingest_text(&s3, "big", "\n", workers * 2, workers).unwrap().1.duration.as_seconds()
        };
        let t1 = t(1);
        let t4 = t(4);
        let t16 = t(16);
        assert!(t4 < t1, "t1={t1} t4={t4}");
        assert!(t16 <= t4);
        // flattening: 16 workers nowhere near 16x
        assert!(t1 / t16 < 12.0, "speedup {}", t1 / t16);
    }

    #[test]
    fn binary_objects_one_record_each() {
        let mut s = Swift::new();
        for i in 0..5 {
            s.put(&format!("f{i}.gz"), vec![i as u8; 10]).unwrap();
        }
        let keys: Vec<&str> = s.list();
        let (ds, rep) = ingest_objects(&s, &keys, 2, 2).unwrap();
        assert_eq!(ds.num_partitions(), 2);
        assert_eq!(rep.bytes, 75); // 5 x (10 payload + 5 name) bytes
        match ds.plan().as_ref() {
            crate::dataset::Plan::Source { partitions, .. } => {
                let total: usize = partitions.iter().map(|p| p.len()).sum();
                assert_eq!(total, 5);
                assert!(partitions[0].records[0].is_binary());
            }
            _ => panic!(),
        }
    }

    #[test]
    fn missing_key_errors() {
        let s = Swift::new();
        assert!(ingest_text(&s, "nope", "\n", 1, 1).is_err());
    }

    /// Regression: `byte_cursor` must include the separator bytes
    /// between records — summing only record payloads attributed
    /// partitions to earlier HDFS blocks than their true byte ranges.
    #[test]
    fn partition_locality_maps_to_exact_block_boundaries() {
        // 40 records x (9 payload + 1 sep) bytes = 400 bytes; 100-byte
        // blocks; 4 partitions of 10 records = exactly one block each
        let mut h = Hdfs::new(4, 100);
        let doc: String = (0..40).map(|i| format!("{i:09}\n")).collect();
        h.put("data", doc.into_bytes()).unwrap();
        let blocks = h.blocks("data").unwrap();
        assert_eq!(blocks.len(), 4);

        let (ds, rep) = ingest_text(&h, "data", "\n", 4, 4).unwrap();
        match ds.plan().as_ref() {
            crate::dataset::Plan::Source { partitions, .. } => {
                for (i, p) in partitions.iter().enumerate() {
                    // partition i starts at byte i*100 — block i exactly;
                    // the payload-only cursor (i*90) put partitions 1-3
                    // in earlier blocks
                    assert_eq!(
                        p.preferred_worker, blocks[i].primary,
                        "partition {i} attributed off its true block"
                    );
                }
            }
            _ => panic!("expected a source plan"),
        }
        // with the cursor fixed, every read is block-local
        assert_eq!(rep.local_reads, 4);
        assert_eq!(rep.remote_reads, 0);
        assert_eq!(rep.partition_bytes, vec![90, 90, 90, 90]);
    }

    /// Regression: out-of-range locality hints (ingest layout computed
    /// for a larger cluster) must spread deterministically and count as
    /// remote reads — clamping piled them all onto the last worker.
    #[test]
    fn out_of_range_hints_spread_and_count_remote() {
        let h = Hdfs::new(16, 100);
        let parts: Vec<Partition> = (0..8)
            .map(|i| Partition {
                records: vec![Record::text("x".repeat(100))],
                preferred_worker: Some(i), // hints 0..8, cluster of 2
            })
            .collect();
        let rep = account(&h, &parts, 2, 800);
        // modulo spread: both workers read, not just the last one
        assert_eq!(rep.readers, 2);
        // hints 0 and 1 are in range (local); 2..8 are foreign (remote)
        assert_eq!(rep.local_reads, 2);
        assert_eq!(rep.remote_reads, 6);
        assert_eq!(rep.bytes, 800);
    }

    /// Streamed ingest must seal every partition (ascending ready_at,
    /// final records) and show the overlap in the ledger: with several
    /// partitions per reader, the first seal lands strictly before full
    /// materialization, while the partitions and byte accounting stay
    /// identical to the batch path.
    #[test]
    fn streamed_ingest_seals_early_and_matches_batch() {
        let mut h = Hdfs::new(4, 100);
        let doc: String = (0..40).map(|i| format!("{i:09}\n")).collect();
        h.put("data", doc.into_bytes()).unwrap();

        let (batch_ds, batch_rep) = ingest_text_as(&h, "data", "\n", 8, 4, "l").unwrap();
        let mut seals: Vec<SealedPartition> = Vec::new();
        let (ds, rep) =
            ingest_text_streamed_as(&h, "data", "\n", 8, 4, "l", |s| seals.push(s.clone()))
                .unwrap();

        // every partition sealed exactly once, in ascending ready_at
        assert_eq!(seals.len(), 8);
        assert!(seals.windows(2).all(|w| w[0].ready_at <= w[1].ready_at));
        let mut seen: Vec<usize> = seals.iter().map(|s| s.index).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..8).collect::<Vec<_>>());

        // the streaming win, as a ledger
        assert!(rep.first_partition_ready < rep.fully_materialized, "{rep:?}");
        assert_eq!(rep.fully_materialized, rep.duration);
        // batch publishes nothing early
        assert_eq!(batch_rep.first_partition_ready, batch_rep.fully_materialized);

        // identical partitions + identical byte accounting
        assert_eq!(rep.bytes, batch_rep.bytes);
        assert_eq!(rep.partition_bytes, batch_rep.partition_bytes);
        assert_eq!(rep.readers, batch_rep.readers);
        assert_eq!(rep.local_reads, batch_rep.local_reads);
        assert_eq!(rep.duration, batch_rep.duration);
        match (ds.plan().as_ref(), batch_ds.plan().as_ref()) {
            (
                crate::dataset::Plan::Source { partitions: a, .. },
                crate::dataset::Plan::Source { partitions: b, .. },
            ) => {
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(x.records, y.records);
                    assert_eq!(x.preferred_worker, y.preferred_worker);
                }
            }
            _ => panic!("expected source plans"),
        }
        // sealed records are views of the object buffer, not copies
        for s in &seals {
            for r in &s.partition.records {
                if let Record::Text(t) = r {
                    assert!(t.as_shared().ref_count() > 2, "sealed record was copied");
                }
            }
        }
    }

    /// Regression: a zero-length block occupies no byte range — the
    /// seed's `len.max(1)` shifted every subsequent block's range.
    #[test]
    fn block_at_skips_zero_length_blocks() {
        let blocks = vec![
            super::super::BlockInfo { index: 0, len: 0, primary: Some(7) },
            super::super::BlockInfo { index: 1, len: 100, primary: Some(1) },
            super::super::BlockInfo { index: 2, len: 100, primary: Some(2) },
        ];
        // byte 0 is the first byte of block 1, not the empty block 0
        assert_eq!(block_at(&blocks, 0).unwrap().index, 1);
        assert_eq!(block_at(&blocks, 99).unwrap().index, 1);
        assert_eq!(block_at(&blocks, 100).unwrap().index, 2);
        // past the end: the last REAL block, not a phantom
        assert_eq!(block_at(&blocks, 500).unwrap().index, 2);
        // all-empty objects still resolve to something
        let empty = vec![super::super::BlockInfo { index: 0, len: 0, primary: None }];
        assert_eq!(block_at(&empty, 0).unwrap().index, 0);
    }
}
