//! Driver-local store — free reads, no locality. Used by tests, the
//! quickstart example, and as the decoupled shared store of the
//! workflow-system baseline (where its *metered* variant applies).

use std::collections::BTreeMap;

use crate::error::{MareError, Result};
use crate::simtime::{DiskModel, Duration};

use super::{BlockInfo, StorageBackend};

pub struct LocalFs {
    objects: BTreeMap<String, Vec<u8>>,
    /// Metered variant: charge reads at disk speed (workflow baseline's
    /// shared-store traffic); unmetered reads are free.
    disk: Option<DiskModel>,
}

impl LocalFs {
    pub fn new() -> Self {
        LocalFs { objects: BTreeMap::new(), disk: None }
    }

    /// Shared-store variant: all reads/writes cross a disk+NFS-ish pipe.
    pub fn metered(disk: DiskModel) -> Self {
        LocalFs { objects: BTreeMap::new(), disk: Some(disk) }
    }
}

impl Default for LocalFs {
    fn default() -> Self {
        Self::new()
    }
}

impl StorageBackend for LocalFs {
    fn name(&self) -> &'static str {
        "local"
    }

    fn put(&mut self, key: &str, bytes: Vec<u8>) -> Result<()> {
        self.objects.insert(key.to_string(), bytes);
        Ok(())
    }

    fn get(&self, key: &str) -> Result<&[u8]> {
        self.objects
            .get(key)
            .map(|v| v.as_slice())
            .ok_or_else(|| MareError::Storage(format!("local: no such object `{key}`")))
    }

    fn list(&self) -> Vec<&str> {
        self.objects.keys().map(|s| s.as_str()).collect()
    }

    fn blocks(&self, key: &str) -> Result<Vec<BlockInfo>> {
        let len = self.get(key)?.len() as u64;
        Ok(vec![BlockInfo { index: 0, len, primary: None }])
    }

    fn read_time(
        &self,
        _reader_worker: usize,
        _primary: Option<usize>,
        bytes: u64,
        _concurrency: u32,
    ) -> Duration {
        match self.disk {
            Some(d) => d.rw(bytes),
            None => Duration::ZERO,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unmetered_reads_are_free() {
        let mut l = LocalFs::new();
        l.put("k", vec![0; 1000]).unwrap();
        assert_eq!(l.read_time(0, None, 1000, 1), Duration::ZERO);
    }

    #[test]
    fn metered_reads_cost_disk_time() {
        let l = LocalFs::metered(DiskModel::hdd());
        assert!(l.read_time(0, None, 1 << 20, 1) > Duration::ZERO);
    }
}
