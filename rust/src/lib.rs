//! # MaRe — MapReduce-oriented processing with application containers
//!
//! A from-scratch reproduction of *"MaRe: a MapReduce-Oriented Framework
//! for Processing Big Data with Application Containers"* (Capuccini,
//! Dahlö, Toor, Spjuth, 2018) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the MaRe programming model ([`mare`]): a
//!   fluent, validating builder records a **logical pipeline IR**
//!   ([`mare::pipeline`]), an optimizer ([`mare::opt`]) fuses
//!   consecutive containerized maps and plans reduce-tree depths while
//!   it can still see the whole job, and the lowering translates the
//!   optimized plan onto a Spark-like substrate built here: a
//!   partitioned, lineage-tracked dataset ([`dataset`]), a DAG/stage
//!   compiler and locality-aware task scheduler over a simulated
//!   cluster ([`cluster`]), a Docker-like container engine with an
//!   in-memory filesystem and a mini shell ([`container`]), pluggable
//!   storage backends modelling HDFS / Swift / S3 ([`storage`]), and an
//!   execution-driven discrete-event simulation of cluster time
//!   ([`simtime`]).
//! * **L2/L1 (build time)** — JAX compute graphs calling Pallas kernels,
//!   AOT-lowered to HLO text (`python/compile/`). On the request path
//!   the artifact runtime ([`runtime`]) executes their graphs through a
//!   bit-faithful pure-rust interpreter ([`runtime::native`]) whose ABI
//!   is cross-checked against `artifacts/manifest.json` when present;
//!   a PJRT/XLA execution backend is future work for environments that
//!   ship the native XLA libraries. Python never runs at request time.
//!
//! The paper's evaluation pipelines (virtual screening, SNP calling, GC
//! count) live in [`workloads`]; every figure in the paper is regenerated
//! by a bench in `rust/benches/` (see DESIGN.md §5).
//!
//! Logical plans are also *portable artifacts*: [`mare::wire`] codes the
//! pipeline IR to/from the documented v1 JSON envelope
//! (`docs/WIRE_FORMAT.md`), and [`submit`] builds a job-submission
//! subsystem on top — a file-backed queue, admission control, and a
//! multi-driver simulation in which any driver executes a submitted
//! plan identically. See `docs/ARCHITECTURE.md` for the module map.

pub mod baseline;
pub mod cluster;
pub mod config;
pub mod container;
pub mod dataset;
pub mod metrics;
pub mod error;
pub mod formats;
pub mod mare;
pub mod perf;
pub mod repl;
pub mod runtime;
pub mod serve;
pub mod simtime;
pub mod storage;
pub mod submit;
pub mod tools;
pub mod util;
pub mod workloads;

pub use error::{MareError, Result};
