//! Lazy execution plan with lineage (the RDD DAG analogue).
//!
//! A [`Plan`] is an immutable, refcounted lineage tree. Nothing executes
//! until the cluster runs it (`cluster::runner`). The stage compiler
//! turns a plan into pipelined stages exactly like Spark: chains of
//! `MapPartitions` fuse into one stage (no shuffle); `Repartition` /
//! `Coalesce` cut stages and shuffle.
//!
//! Lineage is also the fault-tolerance mechanism: when a simulated worker
//! dies, its materialized partitions are recomputed by re-running the
//! plan suffix (see `cluster::fault`).

use std::sync::Arc;

use crate::error::Result;
use crate::simtime::CostModel;

use super::record::{Partition, Record};

/// A per-partition transformation (the paper's containerized command, or
/// a native closure for tests/internal ops).
pub trait PartitionOp: Send + Sync {
    /// Transform one partition's records. `ctx` identifies the partition
    /// and provides a deterministic per-task RNG seed ($RANDOM etc).
    fn apply(&self, ctx: &TaskContext, records: Vec<Record>) -> Result<Vec<Record>>;

    /// Virtual-cost model of the wrapped tool.
    fn cost_model(&self) -> CostModel {
        CostModel::free()
    }

    /// Container image this op runs in (None = native/no container).
    fn image(&self) -> Option<&str> {
        None
    }

    /// Whether the op's mount points are disk-backed (vs tmpfs).
    fn uses_disk_mount(&self) -> bool {
        false
    }

    /// Whether (input, output) are streamed over stdin/stdout instead of
    /// materialized mounts (no stage-in/out cost; §1.4 future work).
    fn streams(&self) -> (bool, bool) {
        (false, false)
    }

    /// Human-readable label for plans and reports.
    fn label(&self) -> String {
        "op".into()
    }
}

/// Execution context handed to each task.
#[derive(Debug, Clone, Copy)]
pub struct TaskContext {
    pub partition: usize,
    pub num_partitions: usize,
    pub attempt: u32,
    /// Deterministic seed for this (partition, attempt).
    pub seed: u64,
}

/// How `Repartition` assigns records to output partitions.
pub enum Partitioner {
    /// Hash of a record key (the paper's `keyBy` + HashPartitioner).
    HashByKey { key_fn: Arc<dyn Fn(&Record) -> String + Send + Sync>, num: usize },
    /// Sample-based range partitioning by a record key (the TeraSort
    /// idiom): cut points are planned from a frequency-weighted sample
    /// of the *observed* keys at shuffle time, so skewed key
    /// distributions spread across partitions instead of piling onto
    /// whichever bucket the hot keys hash into. Equal keys still always
    /// land in the same partition.
    ///
    /// When `observed` carries exact key frequencies from a prior
    /// shuffle of the same key space (`ShuffleStats::key_freqs`), cut
    /// planning uses them via [`range_cuts_weighted`] instead of the
    /// in-shuffle stride sample — the stride can systematically miss
    /// hot keys whose records cluster between sample positions, the
    /// measured histogram cannot.
    RangeByKey {
        key_fn: Arc<dyn Fn(&Record) -> String + Send + Sync>,
        num: usize,
        observed: Option<Arc<Vec<(String, u64)>>>,
    },
    /// Concatenate-and-chop into `num` roughly equal partitions
    /// (Spark `repartition(n)` without keys; used by tree-reduce).
    Balanced { num: usize },
}

impl Clone for Partitioner {
    fn clone(&self) -> Self {
        match self {
            Partitioner::HashByKey { key_fn, num } => {
                Partitioner::HashByKey { key_fn: key_fn.clone(), num: *num }
            }
            Partitioner::RangeByKey { key_fn, num, observed } => Partitioner::RangeByKey {
                key_fn: key_fn.clone(),
                num: *num,
                observed: observed.clone(),
            },
            Partitioner::Balanced { num } => Partitioner::Balanced { num: *num },
        }
    }
}

impl std::fmt::Debug for Partitioner {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Partitioner::HashByKey { num, .. } => write!(f, "HashByKey({num})"),
            Partitioner::RangeByKey { num, observed: None, .. } => {
                write!(f, "RangeByKey({num})")
            }
            Partitioner::RangeByKey { num, observed: Some(_), .. } => {
                write!(f, "RangeByKey({num}, observed)")
            }
            Partitioner::Balanced { num } => write!(f, "Balanced({num})"),
        }
    }
}

impl Partitioner {
    pub fn num_partitions(&self) -> usize {
        match self {
            Partitioner::HashByKey { num, .. }
            | Partitioner::RangeByKey { num, .. }
            | Partitioner::Balanced { num } => *num,
        }
    }

    /// The key function, when this partitioner routes by key.
    pub fn key_fn(&self) -> Option<&Arc<dyn Fn(&Record) -> String + Send + Sync>> {
        match self {
            Partitioner::HashByKey { key_fn, .. }
            | Partitioner::RangeByKey { key_fn, .. } => Some(key_fn),
            Partitioner::Balanced { .. } => None,
        }
    }

    /// Stable string hash (FNV-1a) — record routing must be
    /// deterministic across runs for the benches to be reproducible.
    pub fn hash_key(key: &str) -> u64 {
        let mut h = 0xcbf29ce484222325u64;
        for b in key.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }
}

/// Cap on how many keys range-cut planning sorts; beyond it keys are
/// sampled at a deterministic stride (TeraSort samples, we stride — no
/// RNG, reproducible routing).
pub const RANGE_SAMPLE_CAP: usize = 1024;

/// Plan `num - 1` ascending cut points from a key sample. Duplicates in
/// the sample are KEPT, so the cuts are frequency-weighted quantiles:
/// heavily repeated keys pull cut points toward themselves and their
/// neighbours spread over the remaining partitions. Equal cuts (one key
/// dominating several quantiles) are tolerated — routing stays correct,
/// partitions between equal cuts are just empty.
pub fn range_cuts(mut sample: Vec<String>, num: usize) -> Vec<String> {
    sample.sort_unstable();
    let n = sample.len();
    if n == 0 || num <= 1 {
        return Vec::new();
    }
    (1..num)
        .map(|j| {
            // upper edge of the j-th of `num` equal-frequency slices
            let idx = (j * n).div_ceil(num).clamp(1, n) - 1;
            sample[idx].clone()
        })
        .collect()
}

/// [`range_cuts`] over an exact key histogram instead of a flat sample:
/// plan `num - 1` ascending cut points from `(key, count)` frequencies,
/// equivalent to expanding every key `count` times and running
/// [`range_cuts`] — without materializing the expansion. This is the
/// planning path for `Partitioner::RangeByKey { observed: Some(..) }`,
/// fed from a prior shuffle's `ShuffleStats::key_freqs`.
pub fn range_cuts_weighted(freqs: &[(String, u64)], num: usize) -> Vec<String> {
    if num <= 1 {
        return Vec::new();
    }
    let mut sorted: Vec<(&str, u64)> =
        freqs.iter().filter(|&&(_, c)| c > 0).map(|(k, c)| (k.as_str(), *c)).collect();
    sorted.sort_unstable();
    let total: u64 = sorted.iter().map(|&(_, c)| c).sum();
    if total == 0 {
        return Vec::new();
    }
    let mut cuts = Vec::with_capacity(num - 1);
    let mut it = sorted.iter();
    let mut cur = it.next().expect("total > 0 implies a key");
    let mut below = 0u64; // records on keys strictly before `cur`
    for j in 1..num {
        // 1-based rank of the record closing the j-th equal-frequency
        // slice — the same rank `range_cuts` indexes in its flat sample
        let target = ((j as u64) * total).div_ceil(num as u64).clamp(1, total);
        while below + cur.1 < target {
            below += cur.1;
            match it.next() {
                Some(next) => cur = next,
                None => break,
            }
        }
        cuts.push(cur.0.to_string());
    }
    cuts
}

/// Bucket of `key` under ascending `cuts`: the number of cut points
/// `< key` — keys `<= cuts[0]` route to partition 0, keys above the
/// last cut to partition `cuts.len()`.
pub fn range_bucket(cuts: &[String], key: &str) -> usize {
    cuts.partition_point(|c| c.as_str() < key)
}

/// Deterministic stride-sample of the keys of `records` chains, capped
/// at [`RANGE_SAMPLE_CAP`] total.
pub fn range_sample_keys<'a, I>(parts: I, total: usize, key_fn: &KeyFnRef) -> Vec<String>
where
    I: IntoIterator<Item = &'a [Record]>,
{
    let stride = (total / RANGE_SAMPLE_CAP).max(1);
    let mut keys = Vec::with_capacity(total.min(RANGE_SAMPLE_CAP) + 1);
    let mut i = 0usize;
    for records in parts {
        for r in records {
            if i % stride == 0 {
                keys.push(key_fn(r));
            }
            i += 1;
        }
    }
    keys
}

/// Shared key-function handle (alias to keep signatures readable).
pub type KeyFnRef = Arc<dyn Fn(&Record) -> String + Send + Sync>;

/// The lineage tree.
pub enum Plan {
    /// Materialized input partitions (parallelize / storage ingest).
    Source { partitions: Vec<Partition>, label: String },
    /// Narrow transformation: one task per partition, no shuffle.
    MapPartitions { parent: Arc<Plan>, op: Arc<dyn PartitionOp> },
    /// Wide transformation: shuffle into a new partitioning. `combine`
    /// is an optional map-side combiner (an associative + commutative
    /// aggregation op the optimizer pushed below the shuffle): it runs
    /// once per map-side partition BEFORE records are routed, so only
    /// partial aggregates cross the simulated interconnect.
    Repartition {
        parent: Arc<Plan>,
        partitioner: Partitioner,
        combine: Option<Arc<dyn PartitionOp>>,
    },
}

impl Plan {
    pub fn num_partitions(&self) -> usize {
        match self {
            Plan::Source { partitions, .. } => partitions.len(),
            Plan::MapPartitions { parent, .. } => parent.num_partitions(),
            Plan::Repartition { partitioner, .. } => partitioner.num_partitions(),
        }
    }

    /// Depth of the lineage chain (for reports/tests).
    pub fn depth(&self) -> usize {
        match self {
            Plan::Source { .. } => 1,
            Plan::MapPartitions { parent, .. } | Plan::Repartition { parent, .. } => {
                1 + parent.depth()
            }
        }
    }

    /// Number of shuffle boundaries in the lineage.
    pub fn num_shuffles(&self) -> usize {
        match self {
            Plan::Source { .. } => 0,
            Plan::MapPartitions { parent, .. } => parent.num_shuffles(),
            Plan::Repartition { parent, .. } => 1 + parent.num_shuffles(),
        }
    }

    pub fn label(&self) -> String {
        match self {
            Plan::Source { label, .. } => format!("source[{label}]"),
            Plan::MapPartitions { op, .. } => format!("map[{}]", op.label()),
            Plan::Repartition { partitioner, combine, .. } => match combine {
                Some(c) => format!("shuffle[{partitioner:?}, +combine {}]", c.label()),
                None => format!("shuffle[{partitioner:?}]"),
            },
        }
    }

    /// Pretty lineage description, leaf-to-root.
    pub fn describe(&self) -> String {
        let mut out = Vec::new();
        let mut cur = self;
        loop {
            out.push(cur.label());
            match cur {
                Plan::Source { .. } => break,
                Plan::MapPartitions { parent, .. } | Plan::Repartition { parent, .. } => {
                    cur = parent
                }
            }
        }
        out.reverse();
        out.join(" -> ")
    }
}

/// Route one partition's records to `num` output buckets.
pub fn route(partitioner: &Partitioner, records: Vec<Record>) -> Vec<Vec<Record>> {
    route_from(partitioner, records, 0)
}

/// Route with a per-source-partition `salt` staggering the balanced
/// round-robin. Without the salt, N partitions holding one record each
/// would all route to bucket 0 (Spark staggers by partition id for the
/// same reason).
///
/// `RangeByKey` here plans its cuts from THIS call's records only — the
/// single-partition fallback. The shuffle service
/// (`cluster::shuffle`) plans ONE global cut set over all map outputs
/// and routes with [`route_with_cuts`] so every source partition agrees
/// on the key ranges.
pub fn route_from(
    partitioner: &Partitioner,
    records: Vec<Record>,
    salt: usize,
) -> Vec<Vec<Record>> {
    if let Partitioner::RangeByKey { key_fn, num, observed } = partitioner {
        let cuts = match observed {
            Some(freqs) => range_cuts_weighted(freqs, *num),
            None => {
                let total = records.len();
                let sample =
                    range_sample_keys(std::iter::once(records.as_slice()), total, key_fn);
                range_cuts(sample, *num)
            }
        };
        return route_with_cuts(&cuts, *num, key_fn, records);
    }
    let num = partitioner.num_partitions();
    let mut buckets: Vec<Vec<Record>> = (0..num).map(|_| Vec::new()).collect();
    match partitioner {
        Partitioner::HashByKey { key_fn, .. } => {
            for r in records {
                let key = key_fn(&r);
                let b = (Partitioner::hash_key(&key) % num as u64) as usize;
                buckets[b].push(r);
            }
        }
        Partitioner::RangeByKey { .. } => unreachable!("handled above"),
        Partitioner::Balanced { .. } => {
            for (i, r) in records.into_iter().enumerate() {
                buckets[(salt + i) % num].push(r);
            }
        }
    }
    buckets
}

/// Route records into `num` buckets under pre-planned range `cuts`
/// (see [`range_cuts`] / [`range_bucket`]).
pub fn route_with_cuts(
    cuts: &[String],
    num: usize,
    key_fn: &KeyFnRef,
    records: Vec<Record>,
) -> Vec<Vec<Record>> {
    let mut buckets: Vec<Vec<Record>> = (0..num).map(|_| Vec::new()).collect();
    for r in records {
        let b = range_bucket(cuts, &key_fn(r)).min(num.saturating_sub(1));
        buckets[b].push(r);
    }
    buckets
}

/// A native (non-container) op from a closure — used by internal
/// machinery and tests.
pub struct ClosureOp<F> {
    pub f: F,
    pub name: String,
}

impl<F> PartitionOp for ClosureOp<F>
where
    F: Fn(&TaskContext, Vec<Record>) -> Result<Vec<Record>> + Send + Sync,
{
    fn apply(&self, ctx: &TaskContext, records: Vec<Record>) -> Result<Vec<Record>> {
        (self.f)(ctx, records)
    }

    fn label(&self) -> String {
        self.name.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn src(n: usize) -> Arc<Plan> {
        let parts = (0..n)
            .map(|i| Partition::new(vec![Record::text(format!("r{i}"))]))
            .collect();
        Arc::new(Plan::Source { partitions: parts, label: "test".into() })
    }

    #[test]
    fn plan_shape_accessors() {
        let p = src(4);
        let mapped = Arc::new(Plan::MapPartitions {
            parent: p,
            op: Arc::new(ClosureOp { f: |_: &TaskContext, r| Ok(r), name: "id".into() }),
        });
        let shuffled = Arc::new(Plan::Repartition {
            parent: mapped,
            partitioner: Partitioner::Balanced { num: 2 },
            combine: None,
        });
        assert_eq!(shuffled.num_partitions(), 2);
        assert_eq!(shuffled.depth(), 3);
        assert_eq!(shuffled.num_shuffles(), 1);
        assert!(shuffled.describe().contains("source[test] -> map[id] -> shuffle"));
    }

    #[test]
    fn hash_routing_groups_same_keys() {
        let key_fn: Arc<dyn Fn(&Record) -> String + Send + Sync> =
            Arc::new(|r: &Record| r.as_text().unwrap()[..1].to_string());
        let p = Partitioner::HashByKey { key_fn, num: 4 };
        let records = vec![
            Record::text("a1"),
            Record::text("b1"),
            Record::text("a2"),
            Record::text("b2"),
        ];
        let buckets = route(&p, records);
        // all a* together, all b* together
        for bucket in &buckets {
            let prefixes: std::collections::HashSet<_> =
                bucket.iter().map(|r| &r.as_text().unwrap()[..1]).collect();
            assert!(prefixes.len() <= 1, "{buckets:?}");
        }
        let total: usize = buckets.iter().map(|b| b.len()).sum();
        assert_eq!(total, 4);
    }

    #[test]
    fn balanced_routing_is_even() {
        let p = Partitioner::Balanced { num: 3 };
        let records: Vec<Record> = (0..10).map(|i| Record::text(format!("{i}"))).collect();
        let buckets = route(&p, records);
        let sizes: Vec<usize> = buckets.iter().map(|b| b.len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        assert!(sizes.iter().all(|&s| s == 3 || s == 4), "{sizes:?}");
    }

    #[test]
    fn hash_is_stable() {
        assert_eq!(Partitioner::hash_key("chr1"), Partitioner::hash_key("chr1"));
        assert_ne!(Partitioner::hash_key("chr1"), Partitioner::hash_key("chr2"));
    }

    #[test]
    fn range_cuts_are_weighted_quantiles() {
        // uniform sample: cuts split evenly
        let sample: Vec<String> = (0..8).map(|i| format!("k{i}")).collect();
        let cuts = range_cuts(sample, 4);
        assert_eq!(cuts, vec!["k1", "k3", "k5"]);
        // a dominating key pulls the cuts toward itself
        let mut skewed = vec!["hot".to_string(); 6];
        skewed.push("a".into());
        skewed.push("z".into());
        let cuts = range_cuts(skewed, 4);
        assert!(cuts.iter().filter(|c| c.as_str() == "hot").count() >= 2, "{cuts:?}");
        // degenerate inputs
        assert!(range_cuts(vec![], 4).is_empty());
        assert!(range_cuts(vec!["x".into()], 1).is_empty());
    }

    #[test]
    fn weighted_cuts_match_the_expanded_sample() {
        // range_cuts_weighted(histogram) must equal range_cuts(expansion)
        let freqs: Vec<(String, u64)> = vec![
            ("a".into(), 3),
            ("b".into(), 1),
            ("hot".into(), 9),
            ("z".into(), 2),
        ];
        let mut expanded: Vec<String> = Vec::new();
        for (k, c) in &freqs {
            for _ in 0..*c {
                expanded.push(k.clone());
            }
        }
        for num in [1usize, 2, 3, 4, 7, 20] {
            assert_eq!(
                range_cuts_weighted(&freqs, num),
                range_cuts(expanded.clone(), num),
                "num={num}"
            );
        }
        // zero-count keys are ignored, degenerate inputs yield no cuts
        assert_eq!(
            range_cuts_weighted(&[("x".into(), 0), ("y".into(), 4)], 2),
            vec!["y".to_string()]
        );
        assert!(range_cuts_weighted(&[], 4).is_empty());
        assert!(range_cuts_weighted(&[("x".into(), 0)], 4).is_empty());
    }

    #[test]
    fn observed_frequencies_replan_the_routing_cuts() {
        // 1 "a" + 9 "m" records: the flat sample's median key is "m",
        // so the cut lands at "m" and BOTH keys route at-or-below it —
        // bucket 0 takes everything. A histogram weighting "a" as the
        // heavy key cuts at "a" instead and the two keys separate,
        // proving route() consults `observed` over the sample.
        let key_fn: KeyFnRef = Arc::new(|r: &Record| r.as_text().unwrap()[..1].to_string());
        let records: Vec<Record> = std::iter::once(Record::text("a0"))
            .chain((0..9).map(|i| Record::text(format!("m{i}"))))
            .collect();
        let sizes = |buckets: Vec<Vec<Record>>| -> Vec<usize> {
            buckets.iter().map(|b| b.len()).collect()
        };
        let p = Partitioner::RangeByKey { key_fn: key_fn.clone(), num: 2, observed: None };
        assert_eq!(sizes(route(&p, records.clone())), vec![10, 0]);
        let observed = Arc::new(vec![("a".to_string(), 9u64), ("m".to_string(), 1u64)]);
        let p = Partitioner::RangeByKey { key_fn, num: 2, observed: Some(observed) };
        assert_eq!(sizes(route(&p, records)), vec![1, 9]);
    }

    #[test]
    fn range_bucket_is_monotone_and_groups_equal_keys() {
        let cuts = vec!["b".to_string(), "d".to_string(), "d".to_string()];
        assert_eq!(range_bucket(&cuts, "a"), 0);
        assert_eq!(range_bucket(&cuts, "b"), 0);
        assert_eq!(range_bucket(&cuts, "c"), 1);
        assert_eq!(range_bucket(&cuts, "d"), 1);
        assert_eq!(range_bucket(&cuts, "e"), 3);
    }

    #[test]
    fn range_routing_groups_keys_and_conserves_records() {
        let key_fn: KeyFnRef = Arc::new(|r: &Record| r.as_text().unwrap()[..1].to_string());
        let p = Partitioner::RangeByKey { key_fn, num: 3, observed: None };
        let records: Vec<Record> = "a1 a2 b1 b2 c1 c2 c3 c4"
            .split(' ')
            .map(Record::text)
            .collect();
        let buckets = route(&p, records);
        assert_eq!(buckets.iter().map(|b| b.len()).sum::<usize>(), 8);
        // a key is never split across buckets (grouping invariant)
        let mut key_bucket: std::collections::HashMap<&str, usize> =
            std::collections::HashMap::new();
        for (i, bucket) in buckets.iter().enumerate() {
            for r in bucket {
                let k = &r.as_text().unwrap()[..1];
                assert_eq!(*key_bucket.entry(k).or_insert(i), i, "{buckets:?}");
            }
        }
        // range order: every key in bucket i <= every key in bucket i+1
        let maxes: Vec<Option<&str>> = buckets
            .iter()
            .map(|b| b.iter().map(|r| r.as_text().unwrap()).max())
            .collect();
        let non_empty: Vec<&str> = maxes.into_iter().flatten().collect();
        let mut sorted = non_empty.clone();
        sorted.sort_unstable();
        assert_eq!(non_empty, sorted);
    }
}
