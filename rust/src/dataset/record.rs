//! Dataset records: the unit MaRe mounts into containers.
//!
//! Mirrors the paper's two mount-point semantics: a *text* record is one
//! separator-delimited chunk of a `TextFile` mount; a *binary* record is
//! one distinct file of a `BinaryFiles` mount directory.
//!
//! Record payloads are [`Shared`]/[`SharedStr`] views: cloning a record
//! (or a whole [`Partition`]) bumps refcounts instead of duplicating
//! payload bytes, so task retries, shuffle routing and driver-side
//! collects never re-allocate data. [`Record::deep_clone`] reproduces
//! the old owned-buffer behaviour for before/after benchmarking; it is
//! counted by [`crate::util::bytes::payload_copies`].

use crate::util::bytes::{Shared, SharedStr};

/// One dataset record.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Record {
    /// A text chunk (one line, one SDF molecule, one SAM record, ...).
    Text(SharedStr),
    /// A named binary file (e.g. a gzipped VCF shard).
    Binary { name: String, bytes: Shared },
}

impl Record {
    pub fn text(s: impl Into<SharedStr>) -> Record {
        Record::Text(s.into())
    }

    pub fn binary(name: impl Into<String>, bytes: impl Into<Shared>) -> Record {
        Record::Binary { name: name.into(), bytes: bytes.into() }
    }

    /// Payload size in bytes (what the cost models meter).
    pub fn size_bytes(&self) -> u64 {
        match self {
            Record::Text(s) => s.len() as u64,
            Record::Binary { name, bytes } => (name.len() + bytes.len()) as u64,
        }
    }

    pub fn as_text(&self) -> Option<&str> {
        match self {
            Record::Text(s) => Some(s.as_str()),
            Record::Binary { .. } => None,
        }
    }

    pub fn is_binary(&self) -> bool {
        matches!(self, Record::Binary { .. })
    }

    /// Duplicate the payload into a private allocation (the pre-shared
    /// clone semantics; counted as payload deep-copies — benches and
    /// the copy-counter tests use this as the "old way" baseline).
    pub fn deep_clone(&self) -> Record {
        match self {
            Record::Text(s) => Record::Text(SharedStr::from_string(s.to_owned_string())),
            Record::Binary { name, bytes } => {
                Record::Binary { name: name.clone(), bytes: bytes.deep_clone() }
            }
        }
    }
}

/// One partition: a slice of the dataset plus locality metadata.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Partition {
    pub records: Vec<Record>,
    /// Worker holding this partition's data (HDFS block host / cache
    /// owner); None means no locality information.
    pub preferred_worker: Option<usize>,
}

impl Partition {
    pub fn new(records: Vec<Record>) -> Self {
        Partition { records, preferred_worker: None }
    }

    pub fn with_locality(records: Vec<Record>, worker: usize) -> Self {
        Partition { records, preferred_worker: Some(worker) }
    }

    pub fn size_bytes(&self) -> u64 {
        self.records.iter().map(Record::size_bytes).sum()
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Duplicate every record payload (see [`Record::deep_clone`]).
    pub fn deep_clone(&self) -> Partition {
        Partition {
            records: self.records.iter().map(Record::deep_clone).collect(),
            preferred_worker: self.preferred_worker,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        assert_eq!(Record::text("abc").size_bytes(), 3);
        assert_eq!(Record::binary("f", vec![0; 10]).size_bytes(), 11);
        let p = Partition::new(vec![Record::text("ab"), Record::binary("x", vec![1, 2, 3])]);
        assert_eq!(p.size_bytes(), 2 + 4);
        assert_eq!(p.len(), 2);
    }

    #[test]
    fn text_accessor() {
        assert_eq!(Record::text("x").as_text(), Some("x"));
        assert_eq!(Record::binary("x", vec![]).as_text(), None);
    }

    #[test]
    fn clone_shares_payload_deep_clone_does_not() {
        let payload = Shared::from_vec(vec![9u8; 256]);
        let r = Record::binary("f.bin", payload.clone());
        let shallow = r.clone();
        // payload + record + shallow clone = 3 views of one allocation
        assert_eq!(payload.ref_count(), 3);
        let deep = r.deep_clone();
        assert_eq!(payload.ref_count(), 3, "deep clone must not share");
        assert_eq!(deep, shallow);
    }
}
