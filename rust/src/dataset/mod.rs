//! Partitioned, lineage-tracked dataset — the RDD analogue.
//!
//! [`Dataset`] is a cheap lazy handle over a [`plan::Plan`]; operations
//! extend the lineage, `cluster::Cluster::run` executes it. Construction
//! helpers mirror the Spark API surface MaRe uses: `parallelize_*`
//! (driver-side data) and `storage::ingest` (backend reads with
//! locality metadata).

pub mod plan;
pub mod record;

use std::sync::Arc;

pub use plan::{ClosureOp, PartitionOp, Partitioner, Plan, TaskContext};
pub use record::{Partition, Record};

/// Lazy, immutable dataset handle (clones share lineage).
#[derive(Clone)]
pub struct Dataset {
    plan: Arc<Plan>,
}

impl Dataset {
    pub fn from_plan(plan: Arc<Plan>) -> Self {
        Dataset { plan }
    }

    pub fn plan(&self) -> &Arc<Plan> {
        &self.plan
    }

    // ------------------------------------------------------ constructors

    /// Split `text` on `sep` into records, then pack into `num_partitions`.
    pub fn parallelize_text(text: &str, sep: &str, num_partitions: usize) -> Self {
        Self::parallelize_text_labeled(text, sep, num_partitions, "parallelize")
    }

    /// [`Self::parallelize_text`] recording `label` as the source label.
    /// The submit subsystem resolves `gen:`/`inline:` labels back to
    /// data, so plans over such sources are executable on any driver
    /// (see `docs/WIRE_FORMAT.md`).
    ///
    /// The text is copied into ONE shared buffer; every record is an
    /// O(1) slice of it ([`Splitter::split`]).
    pub fn parallelize_text_labeled(
        text: &str,
        sep: &str,
        num_partitions: usize,
        label: impl Into<String>,
    ) -> Self {
        let buf = crate::util::bytes::SharedStr::from(text);
        let records: Vec<Record> = Splitter::new(sep)
            .split(&buf)
            .into_iter()
            .map(Record::Text)
            .collect();
        Self::parallelize_labeled(records, num_partitions, label)
    }

    /// Pack records into `num_partitions` (round-robin, like
    /// `sc.parallelize`), no locality info.
    pub fn parallelize(records: Vec<Record>, num_partitions: usize) -> Self {
        Self::parallelize_labeled(records, num_partitions, "parallelize")
    }

    /// [`Self::parallelize`] with an explicit source label.
    pub fn parallelize_labeled(
        records: Vec<Record>,
        num_partitions: usize,
        label: impl Into<String>,
    ) -> Self {
        let n = num_partitions.max(1);
        let mut parts: Vec<Vec<Record>> = (0..n).map(|_| Vec::new()).collect();
        let total = records.len();
        // contiguous chunks (matches Spark's slicing, keeps order)
        let mut it = records.into_iter();
        for (i, part) in parts.iter_mut().enumerate() {
            let count = total / n + usize::from(i < total % n);
            part.extend(it.by_ref().take(count));
        }
        let partitions = parts.into_iter().map(Partition::new).collect();
        Dataset::from_plan(Arc::new(Plan::Source { partitions, label: label.into() }))
    }

    /// Pre-partitioned source (storage ingest paths use this to carry
    /// block locality).
    pub fn from_partitions(partitions: Vec<Partition>, label: impl Into<String>) -> Self {
        Dataset::from_plan(Arc::new(Plan::Source { partitions, label: label.into() }))
    }

    // ----------------------------------------------------- transformations

    /// Narrow per-partition transformation (fuses into the current stage).
    pub fn map_partitions(&self, op: Arc<dyn PartitionOp>) -> Dataset {
        Dataset::from_plan(Arc::new(Plan::MapPartitions { parent: self.plan.clone(), op }))
    }

    /// Wide transformation: hash-partition by a record key
    /// (`repartitionBy` in the paper).
    pub fn repartition_by_key(
        &self,
        key_fn: Arc<dyn Fn(&Record) -> String + Send + Sync>,
        num: usize,
    ) -> Dataset {
        Dataset::from_plan(Arc::new(Plan::Repartition {
            parent: self.plan.clone(),
            partitioner: Partitioner::HashByKey { key_fn, num: num.max(1) },
            combine: None,
        }))
    }

    /// Wide transformation: skew-aware sample-based range partitioning
    /// by a record key, with an optional map-side combiner that runs
    /// per source partition before records are routed (what
    /// `PipelineOp::RepartitionBy` lowers to; see
    /// `cluster::shuffle::shuffle_combined`).
    pub fn repartition_by_key_range(
        &self,
        key_fn: Arc<dyn Fn(&Record) -> String + Send + Sync>,
        num: usize,
        combine: Option<Arc<dyn PartitionOp>>,
    ) -> Dataset {
        Dataset::from_plan(Arc::new(Plan::Repartition {
            parent: self.plan.clone(),
            partitioner: Partitioner::RangeByKey { key_fn, num: num.max(1), observed: None },
            combine,
        }))
    }

    /// [`Self::repartition_by_key_range`] planning its cuts from a
    /// measured key histogram instead of the in-shuffle stride sample —
    /// feed a prior stage's `ShuffleStats::key_freqs` when the SAME key
    /// space is reshuffled. Exact frequencies beat the stride on skew
    /// the stride systematically misses (hot keys clustered between
    /// sample positions); see `plan::range_cuts_weighted`.
    pub fn repartition_by_key_range_observed(
        &self,
        key_fn: Arc<dyn Fn(&Record) -> String + Send + Sync>,
        num: usize,
        combine: Option<Arc<dyn PartitionOp>>,
        observed: Arc<Vec<(String, u64)>>,
    ) -> Dataset {
        Dataset::from_plan(Arc::new(Plan::Repartition {
            parent: self.plan.clone(),
            partitioner: Partitioner::RangeByKey {
                key_fn,
                num: num.max(1),
                observed: Some(observed),
            },
            combine,
        }))
    }

    /// Wide transformation: rebalance into `num` partitions (the
    /// tree-reduce shrink step).
    pub fn repartition(&self, num: usize) -> Dataset {
        Dataset::from_plan(Arc::new(Plan::Repartition {
            parent: self.plan.clone(),
            partitioner: Partitioner::Balanced { num: num.max(1) },
            combine: None,
        }))
    }

    // ------------------------------------------------------------ queries

    pub fn num_partitions(&self) -> usize {
        self.plan.num_partitions()
    }

    pub fn describe(&self) -> String {
        self.plan.describe()
    }
}

/// Scanner-backed record splitter — the ONE entry point for turning a
/// text buffer into TextFile records (the paper's semantics: records
/// joined by `sep`, e.g. "\n$$$$\n" for SDF, with whitespace-only
/// chunks dropped). Separator search runs through the SWAR kernels in
/// [`crate::util::scan`]; [`Splitter::split`] yields O(1) views of the
/// source buffer, [`Splitter::record_ranges`] exposes the exact byte
/// offsets (what `storage::ingest` uses for block-accurate locality).
///
/// `parallelize_text`, `storage::ingest` and the TextFile stage-out
/// boundary all go through this type.
#[derive(Debug, Clone)]
pub struct Splitter {
    sep: String,
}

impl Splitter {
    pub fn new(sep: impl Into<String>) -> Splitter {
        Splitter { sep: sep.into() }
    }

    pub fn sep(&self) -> &str {
        &self.sep
    }

    /// Exact byte ranges `[start, end)` of the record chunks of `text`
    /// (whitespace-only chunks dropped). An empty separator means
    /// "don't split": the whole text is one record (or none, if empty).
    ///
    /// Byte-level matching of a valid-UTF-8 separator in valid-UTF-8
    /// text always lands on char boundaries (ASCII bytes never occur
    /// inside multi-byte sequences, and lead/continuation byte ranges
    /// are disjoint), so the ranges are safe to slice with.
    pub fn record_ranges(&self, text: &str) -> Vec<(usize, usize)> {
        if self.sep.is_empty() {
            return if text.is_empty() { vec![] } else { vec![(0, text.len())] };
        }
        crate::util::scan::split_ranges(text.as_bytes(), self.sep.as_bytes())
            .into_iter()
            .filter(|&(s, e)| !text[s..e].trim().is_empty())
            .collect()
    }

    /// Zero-copy split: every record is an O(1) slice of `text`'s
    /// buffer. Chunk semantics are byte-identical to
    /// [`Splitter::split_owned`] (property-tested in
    /// `rust/tests/prop_invariants.rs`).
    pub fn split(&self, text: &crate::util::bytes::SharedStr) -> Vec<crate::util::bytes::SharedStr> {
        self.record_ranges(text.as_str())
            .into_iter()
            .map(|(s, e)| text.slice(s, e))
            .collect()
    }

    /// Owned split (fresh `String` per record) — the pre-zero-copy
    /// behaviour, kept for benchmarking and driver-side callers that
    /// need owned chunks.
    pub fn split_owned(&self, text: &str) -> Vec<String> {
        self.record_ranges(text)
            .into_iter()
            .map(|(s, e)| text[s..e].to_string())
            .collect()
    }
}

/// Join records with a separator for mounting (inverse of
/// [`Splitter::split_owned`]; a trailing separator is added so
/// round-trips are stable for tools that append).
pub fn join_records(records: &[String], sep: &str) -> String {
    if records.is_empty() {
        return String::new();
    }
    let mut out = records.join(sep);
    out.push_str(sep);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitter_exposes_exact_ranges() {
        let sp = Splitter::new("\n$$$$\n");
        let text = "mol1\n$$$$\nmol2\n$$$$\n";
        assert_eq!(sp.record_ranges(text), vec![(0, 4), (10, 14)]);
        assert_eq!(sp.split_owned(text), vec!["mol1", "mol2"]);
        // empty separator: whole text is one record
        assert_eq!(Splitter::new("").record_ranges("abc"), vec![(0, 3)]);
        assert!(Splitter::new("").record_ranges("").is_empty());
    }

    #[test]
    fn parallelize_balances_contiguously() {
        let ds = Dataset::parallelize_text("a\nb\nc\nd\ne", "\n", 2);
        match ds.plan().as_ref() {
            Plan::Source { partitions, .. } => {
                assert_eq!(partitions.len(), 2);
                assert_eq!(partitions[0].len(), 3);
                assert_eq!(partitions[1].len(), 2);
                assert_eq!(partitions[0].records[0], Record::text("a"));
                assert_eq!(partitions[1].records[0], Record::text("d"));
            }
            _ => panic!("expected source"),
        }
    }

    #[test]
    fn empty_dataset_is_fine() {
        let ds = Dataset::parallelize(vec![], 4);
        assert_eq!(ds.num_partitions(), 4);
    }

    #[test]
    fn split_owned_custom_separator() {
        let text = "mol1\n$$$$\nmol2\n$$$$\n";
        let recs = Splitter::new("\n$$$$\n").split_owned(text);
        assert_eq!(recs, vec!["mol1", "mol2"]);
    }

    #[test]
    fn zero_copy_split_matches_owned() {
        for (text, sep) in [
            ("a\nb\nc", "\n"),
            ("a\nb\nc\n", "\n"),
            ("mol1\n$$$$\nmol2\n$$$$\n", "\n$$$$\n"),
            ("", "\n"),
            ("\n\n", "\n"),
            ("  \n x \n", "\n"),
            ("no-sep-here", "|"),
            ("whole", ""),
        ] {
            let sp = Splitter::new(sep);
            let buf = crate::util::bytes::SharedStr::from(text);
            let shared: Vec<String> =
                sp.split(&buf).iter().map(|s| s.as_str().to_string()).collect();
            assert_eq!(shared, sp.split_owned(text), "text={text:?} sep={sep:?}");
        }
        // and the slices really share the source allocation
        let buf = crate::util::bytes::SharedStr::from("a\nb");
        let parts = Splitter::new("\n").split(&buf);
        assert_eq!(parts.len(), 2);
        assert_eq!(buf.as_shared().ref_count(), 3);
    }

    #[test]
    fn split_join_roundtrip() {
        let recs = vec!["a".to_string(), "b".to_string()];
        let joined = join_records(&recs, "\n$$$$\n");
        assert_eq!(Splitter::new("\n$$$$\n").split_owned(&joined), recs);
    }

    #[test]
    fn lineage_grows() {
        let ds = Dataset::parallelize_text("a\nb", "\n", 2)
            .map_partitions(Arc::new(ClosureOp {
                f: |_: &TaskContext, r| Ok(r),
                name: "id".into(),
            }))
            .repartition(1);
        assert_eq!(ds.num_partitions(), 1);
        assert_eq!(ds.plan().depth(), 3);
        assert_eq!(ds.plan().num_shuffles(), 1);
    }
}
