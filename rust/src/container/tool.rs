//! The tool trait: what a binary inside a container image looks like.
//!
//! Real Docker runs arbitrary ELF binaries; our simulated engine runs
//! `Tool` implementations against the container's [`Vfs`]. The
//! domain tools (fred, gatk) reach the AOT compute through the
//! [`ToolRuntime`] handle carried in the context — that is the paper's
//! "containerized tool wrapping heavy numeric code" path.

use std::collections::BTreeMap;

use crate::error::Result;
use crate::runtime::ToolRuntime;
use crate::util::rng::Rng;

use super::vfs::Vfs;

/// Execution context for one tool invocation inside a container.
pub struct ToolCtx<'a> {
    /// argv[1..] (argv[0] is the tool name).
    pub args: Vec<String>,
    /// Bytes piped into stdin.
    pub stdin: Vec<u8>,
    /// The container filesystem (volumes already bound).
    pub fs: &'a mut Vfs,
    /// Environment (includes RANDOM, MARE_PARTITION, ...).
    pub env: &'a BTreeMap<String, String>,
    /// PJRT runtime for compute-heavy tools (None in plain images).
    pub runtime: Option<&'a ToolRuntime>,
    /// Deterministic per-invocation RNG.
    pub rng: Rng,
}

impl<'a> ToolCtx<'a> {
    /// Stdin as UTF-8.
    pub fn stdin_string(&self) -> Result<String> {
        String::from_utf8(self.stdin.clone())
            .map_err(|_| crate::error::MareError::Shell("stdin is not UTF-8".into()))
    }

    /// Flag helper: `--key=value` or `-key value` styles used by the
    /// paper's commands.
    pub fn flag_value(&self, name: &str) -> Option<String> {
        let eq_prefix = format!("{name}=");
        let mut it = self.args.iter();
        while let Some(a) = it.next() {
            if let Some(v) = a.strip_prefix(&eq_prefix) {
                return Some(v.to_string());
            }
            if a == name {
                return it.next().cloned();
            }
        }
        None
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.args.iter().any(|a| a == name || a.starts_with(&format!("{name}=")))
    }

    /// Positional args (not starting with '-').
    pub fn positionals(&self) -> Vec<&str> {
        let mut out = Vec::new();
        let mut skip_next = false;
        for (i, a) in self.args.iter().enumerate() {
            if skip_next {
                skip_next = false;
                continue;
            }
            if a.starts_with('-') {
                // a flag that takes a separate value consumes the next
                // token only if the token is clearly a value for it; we
                // can't know generally, so tools that mix styles use
                // flag_value() and slice positionals themselves.
                let _ = i;
                continue;
            }
            out.push(a.as_str());
        }
        out
    }
}

/// Result of a tool run.
#[derive(Debug, Default, Clone)]
pub struct ToolOutput {
    pub stdout: Vec<u8>,
    pub status: i32,
}

impl ToolOutput {
    pub fn ok(stdout: Vec<u8>) -> Result<ToolOutput> {
        Ok(ToolOutput { stdout, status: 0 })
    }

    pub fn ok_str(stdout: impl Into<String>) -> Result<ToolOutput> {
        Self::ok(stdout.into().into_bytes())
    }

    pub fn empty() -> Result<ToolOutput> {
        Self::ok(Vec::new())
    }
}

/// A binary installed in a container image.
pub trait Tool: Send + Sync {
    fn name(&self) -> &'static str;
    fn run(&self, ctx: &mut ToolCtx) -> Result<ToolOutput>;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::vfs::Vfs;

    fn ctx_with_args<'a>(
        fs: &'a mut Vfs,
        env: &'a BTreeMap<String, String>,
        args: &[&str],
    ) -> ToolCtx<'a> {
        ToolCtx {
            args: args.iter().map(|s| s.to_string()).collect(),
            stdin: vec![],
            fs,
            env,
            runtime: None,
            rng: Rng::new(1),
        }
    }

    #[test]
    fn flag_value_both_styles() {
        let mut fs = Vfs::disk();
        let env = BTreeMap::new();
        let ctx = ctx_with_args(
            &mut fs,
            &env,
            &["-receptor", "/r.oeb", "--INPUT=/in.sam", "-nbest=30"],
        );
        assert_eq!(ctx.flag_value("-receptor").as_deref(), Some("/r.oeb"));
        assert_eq!(ctx.flag_value("--INPUT").as_deref(), Some("/in.sam"));
        assert_eq!(ctx.flag_value("-nbest").as_deref(), Some("30"));
        assert_eq!(ctx.flag_value("-missing"), None);
        assert!(ctx.has_flag("--INPUT"));
    }
}
