//! In-memory container filesystem (the tmpfs the paper mounts volumes
//! on), with optional capacity limits and a disk-backed flavour.
//!
//! Paths are absolute, `/`-separated, normalized; directories are
//! implicit (created by writing files under them), like an object store.
//! The `Backing` kind does not change behaviour — it drives the virtual
//! cost accounting (tmpfs vs disk bandwidth) and the capacity default,
//! mirroring the paper's §Data Handling: tmpfs by default, disk for
//! partitions that exceed it.

use std::collections::BTreeMap;

use crate::error::{MareError, Result};
use crate::util::bytes::Shared;

/// What the filesystem is "backed" by (cost accounting + capacity).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backing {
    Tmpfs,
    Disk,
}

/// In-memory filesystem. File contents are [`Shared`] buffers, so
/// binding an input volume or slicing records out of an output mount
/// never duplicates payload bytes.
#[derive(Debug, Clone)]
pub struct Vfs {
    files: BTreeMap<String, Shared>,
    capacity: Option<u64>,
    used: u64,
    backing: Backing,
    /// Peak usage (for tmpfs-capacity diagnostics + cost models).
    peak: u64,
}

/// Normalize a path: force leading '/', collapse '//' and '.', reject '..'.
pub fn normalize(path: &str) -> Result<String> {
    let mut parts: Vec<&str> = Vec::new();
    for part in path.split('/') {
        match part {
            "" | "." => {}
            ".." => {
                return Err(MareError::Container(format!("`..` not allowed in `{path}`")))
            }
            p => parts.push(p),
        }
    }
    if parts.is_empty() {
        return Ok("/".to_string());
    }
    Ok(format!("/{}", parts.join("/")))
}

impl Vfs {
    pub fn new(backing: Backing, capacity: Option<u64>) -> Self {
        Vfs { files: BTreeMap::new(), capacity, used: 0, backing, peak: 0 }
    }

    pub fn tmpfs(capacity: u64) -> Self {
        Vfs::new(Backing::Tmpfs, Some(capacity))
    }

    pub fn disk() -> Self {
        Vfs::new(Backing::Disk, None)
    }

    pub fn backing(&self) -> Backing {
        self.backing
    }

    pub fn used_bytes(&self) -> u64 {
        self.used
    }

    pub fn peak_bytes(&self) -> u64 {
        self.peak
    }

    fn charge(&mut self, old: u64, new: u64) -> Result<()> {
        let next = self.used - old + new;
        if let Some(cap) = self.capacity {
            if next > cap {
                return Err(MareError::Container(format!(
                    "no space left on {:?} mount: need {next} bytes, capacity {cap} \
                     (use a disk-backed mount for large partitions)",
                    self.backing
                )));
            }
        }
        self.used = next;
        self.peak = self.peak.max(next);
        Ok(())
    }

    pub fn write(&mut self, path: &str, bytes: impl Into<Shared>) -> Result<()> {
        let bytes = bytes.into();
        let path = normalize(path)?;
        let old = self.files.get(&path).map(|b| b.len() as u64).unwrap_or(0);
        self.charge(old, bytes.len() as u64)?;
        self.files.insert(path, bytes);
        Ok(())
    }

    pub fn append(&mut self, path: &str, bytes: &[u8]) -> Result<()> {
        let path = normalize(path)?;
        let old = self.files.get(&path).map(|b| b.len() as u64).unwrap_or(0);
        self.charge(old, old + bytes.len() as u64)?;
        // files are immutable shared buffers: append rebuilds the file
        // once (`>>` is rare in the paper's commands; `>` stays cheap)
        let mut buf = Vec::with_capacity(old as usize + bytes.len());
        if let Some(existing) = self.files.get(&path) {
            buf.extend_from_slice(existing.as_slice());
        }
        buf.extend_from_slice(bytes);
        self.files.insert(path, Shared::from_vec(buf));
        Ok(())
    }

    pub fn read(&self, path: &str) -> Result<&[u8]> {
        let path = normalize(path)?;
        self.files
            .get(&path)
            .map(|v| v.as_slice())
            .ok_or_else(|| MareError::Container(format!("no such file: {path}")))
    }

    /// Zero-copy read: a [`Shared`] view of the file's buffer (what the
    /// TextFile stage-out boundary slices records from).
    pub fn read_shared(&self, path: &str) -> Result<Shared> {
        let path = normalize(path)?;
        self.files
            .get(&path)
            .cloned()
            .ok_or_else(|| MareError::Container(format!("no such file: {path}")))
    }

    pub fn read_string(&self, path: &str) -> Result<String> {
        String::from_utf8(self.read(path)?.to_vec())
            .map_err(|_| MareError::Container(format!("{path}: not UTF-8")))
    }

    pub fn exists(&self, path: &str) -> bool {
        normalize(path).map(|p| self.files.contains_key(&p)).unwrap_or(false)
    }

    pub fn remove(&mut self, path: &str) -> Result<()> {
        let path = normalize(path)?;
        match self.files.remove(&path) {
            Some(b) => {
                self.used -= b.len() as u64;
                Ok(())
            }
            None => Err(MareError::Container(format!("no such file: {path}"))),
        }
    }

    /// All file paths (sorted).
    pub fn list_all(&self) -> Vec<&str> {
        self.files.keys().map(|s| s.as_str()).collect()
    }

    /// Files directly or transitively under a directory.
    pub fn list_dir(&self, dir: &str) -> Result<Vec<&str>> {
        let dir = normalize(dir)?;
        let prefix = if dir == "/" { "/".to_string() } else { format!("{dir}/") };
        Ok(self
            .files
            .keys()
            .filter(|k| k.starts_with(&prefix))
            .map(|s| s.as_str())
            .collect())
    }

    /// Shell-glob match over all paths. Supports `*` (within a path
    /// segment) and `?`; e.g. `/in/*.vcf.gz`.
    pub fn glob(&self, pattern: &str) -> Result<Vec<&str>> {
        let pattern = normalize(pattern)?;
        Ok(self
            .files
            .keys()
            .filter(|k| glob_match(&pattern, k))
            .map(|s| s.as_str())
            .collect())
    }

    /// Take ownership of all files (used to extract output mounts;
    /// zero-copy — the buffers move out as [`Shared`] views).
    pub fn take_dir(&mut self, dir: &str) -> Result<Vec<(String, Shared)>> {
        let names: Vec<String> = self.list_dir(dir)?.into_iter().map(String::from).collect();
        let mut out = Vec::with_capacity(names.len());
        for n in names {
            let bytes = self.files.remove(&n).unwrap();
            self.used -= bytes.len() as u64;
            out.push((n, bytes));
        }
        Ok(out)
    }
}

/// Match `pattern` against `path`, `*`/`?` within segments.
pub fn glob_match(pattern: &str, path: &str) -> bool {
    let psegs: Vec<&str> = pattern.split('/').collect();
    let fsegs: Vec<&str> = path.split('/').collect();
    if psegs.len() != fsegs.len() {
        return false;
    }
    psegs.iter().zip(&fsegs).all(|(p, f)| seg_match(p, f))
}

fn seg_match(pat: &str, s: &str) -> bool {
    // classic backtracking wildcard match
    let p: Vec<char> = pat.chars().collect();
    let t: Vec<char> = s.chars().collect();
    let (mut pi, mut ti) = (0usize, 0usize);
    let (mut star, mut mark) = (usize::MAX, 0usize);
    while ti < t.len() {
        if pi < p.len() && (p[pi] == '?' || p[pi] == t[ti]) {
            pi += 1;
            ti += 1;
        } else if pi < p.len() && p[pi] == '*' {
            star = pi;
            mark = ti;
            pi += 1;
        } else if star != usize::MAX {
            pi = star + 1;
            mark += 1;
            ti = mark;
        } else {
            return false;
        }
    }
    while pi < p.len() && p[pi] == '*' {
        pi += 1;
    }
    pi == p.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_roundtrip() {
        let mut fs = Vfs::disk();
        fs.write("/a/b.txt", b"hello".to_vec()).unwrap();
        assert_eq!(fs.read("/a/b.txt").unwrap(), b"hello");
        assert_eq!(fs.read_string("a/b.txt").unwrap(), "hello"); // normalized
        assert!(fs.exists("/a/b.txt"));
        assert_eq!(fs.used_bytes(), 5);
    }

    #[test]
    fn normalize_paths() {
        assert_eq!(normalize("//a//b/./c").unwrap(), "/a/b/c");
        assert_eq!(normalize("/").unwrap(), "/");
        assert!(normalize("/a/../b").is_err());
    }

    #[test]
    fn capacity_enforced_with_helpful_error() {
        let mut fs = Vfs::tmpfs(10);
        fs.write("/x", vec![0; 8]).unwrap();
        let err = fs.write("/y", vec![0; 8]).unwrap_err().to_string();
        assert!(err.contains("no space left"), "{err}");
        // overwrite within budget is fine
        fs.write("/x", vec![0; 10]).unwrap();
        assert_eq!(fs.peak_bytes(), 10);
    }

    #[test]
    fn append_and_remove_track_usage() {
        let mut fs = Vfs::disk();
        fs.append("/log", b"ab").unwrap();
        fs.append("/log", b"cd").unwrap();
        assert_eq!(fs.read_string("/log").unwrap(), "abcd");
        fs.remove("/log").unwrap();
        assert_eq!(fs.used_bytes(), 0);
        assert!(fs.remove("/log").is_err());
    }

    #[test]
    fn list_dir_and_take() {
        let mut fs = Vfs::disk();
        fs.write("/out/a.vcf", b"1".to_vec()).unwrap();
        fs.write("/out/b.vcf", b"2".to_vec()).unwrap();
        fs.write("/other", b"3".to_vec()).unwrap();
        assert_eq!(fs.list_dir("/out").unwrap().len(), 2);
        let taken = fs.take_dir("/out").unwrap();
        assert_eq!(taken.len(), 2);
        assert!(!fs.exists("/out/a.vcf"));
        assert_eq!(fs.used_bytes(), 1);
    }

    #[test]
    fn globbing() {
        let mut fs = Vfs::disk();
        fs.write("/in/x.vcf.gz", vec![]).unwrap();
        fs.write("/in/y.vcf.gz", vec![]).unwrap();
        fs.write("/in/z.txt", vec![]).unwrap();
        fs.write("/in/sub/w.vcf.gz", vec![]).unwrap();
        assert_eq!(fs.glob("/in/*.vcf.gz").unwrap().len(), 2);
        assert_eq!(fs.glob("/in/?.txt").unwrap(), vec!["/in/z.txt"]);
        assert_eq!(fs.glob("/in/*/*.vcf.gz").unwrap(), vec!["/in/sub/w.vcf.gz"]);
    }

    #[test]
    fn glob_match_edge_cases() {
        assert!(glob_match("/a/*", "/a/b"));
        assert!(!glob_match("/a/*", "/a/b/c"));
        assert!(glob_match("/a/*b*", "/a/xbyz"));
        assert!(glob_match("/*", "/x"));
        assert!(!glob_match("/a", "/b"));
    }
}
