//! Container images and the registry.
//!
//! An [`Image`] is a named bundle of tools (binaries) + baked-in files
//! (e.g. the reference genome under `/ref`, as in the paper's
//! `mcapuccini/alignment` image) + a size that drives the pull-cost
//! model. The [`Registry`] plays Docker Hub: the engine "pulls" an image
//! the first time a worker uses it, which the scheduler charges as
//! virtual time.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::error::{MareError, Result};
use crate::util::bytes::Shared;

use super::tool::Tool;

/// An immutable container image.
pub struct Image {
    pub name: String,
    /// Compressed image size (pull cost model input).
    pub size_bytes: u64,
    tools: BTreeMap<&'static str, Arc<dyn Tool>>,
    /// Files baked into the image (path -> content). [`Shared`], so
    /// binding them into every container launch is a refcount bump,
    /// not a copy of (e.g.) the reference genome per task.
    files: Vec<(String, Shared)>,
}

impl Image {
    pub fn builder(name: impl Into<String>) -> ImageBuilder {
        ImageBuilder {
            name: name.into(),
            size_bytes: 64 << 20, // 64 MiB default
            tools: BTreeMap::new(),
            files: Vec::new(),
        }
    }

    pub fn tool(&self, name: &str) -> Result<&Arc<dyn Tool>> {
        self.tools
            .get(name)
            .ok_or_else(|| MareError::ToolNotFound(name.to_string(), self.name.clone()))
    }

    pub fn tool_names(&self) -> Vec<&'static str> {
        self.tools.keys().copied().collect()
    }

    pub fn baked_files(&self) -> &[(String, Shared)] {
        &self.files
    }
}

impl std::fmt::Debug for Image {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Image")
            .field("name", &self.name)
            .field("size_bytes", &self.size_bytes)
            .field("tools", &self.tool_names())
            .field("files", &self.files.len())
            .finish()
    }
}

/// Builder (the `Dockerfile` analogue).
pub struct ImageBuilder {
    name: String,
    size_bytes: u64,
    tools: BTreeMap<&'static str, Arc<dyn Tool>>,
    files: Vec<(String, Shared)>,
}

impl ImageBuilder {
    pub fn size(mut self, bytes: u64) -> Self {
        self.size_bytes = bytes;
        self
    }

    pub fn tool(mut self, t: Arc<dyn Tool>) -> Self {
        self.tools.insert(t.name(), t);
        self
    }

    pub fn file(mut self, path: impl Into<String>, bytes: impl Into<Shared>) -> Self {
        self.files.push((path.into(), bytes.into()));
        self
    }

    pub fn build(self) -> Arc<Image> {
        Arc::new(Image {
            name: self.name,
            size_bytes: self.size_bytes,
            tools: self.tools,
            files: self.files,
        })
    }
}

/// The image registry (Docker Hub analogue).
#[derive(Default)]
pub struct Registry {
    images: BTreeMap<String, Arc<Image>>,
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    pub fn push(&mut self, image: Arc<Image>) {
        self.images.insert(image.name.clone(), image);
    }

    pub fn pull(&self, name: &str) -> Result<Arc<Image>> {
        self.images.get(name).cloned().ok_or_else(|| {
            MareError::Container(format!(
                "image `{name}` not found in registry (have: {:?})",
                self.images.keys().collect::<Vec<_>>()
            ))
        })
    }

    pub fn names(&self) -> Vec<&str> {
        self.images.keys().map(|s| s.as_str()).collect()
    }
}

impl std::fmt::Debug for Registry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Registry").field("images", &self.names()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::tool::{ToolCtx, ToolOutput};

    struct NoopTool;
    impl Tool for NoopTool {
        fn name(&self) -> &'static str {
            "noop"
        }
        fn run(&self, _ctx: &mut ToolCtx) -> Result<ToolOutput> {
            ToolOutput::empty()
        }
    }

    #[test]
    fn builder_and_lookup() {
        let img = Image::builder("ubuntu")
            .size(30 << 20)
            .tool(Arc::new(NoopTool))
            .file("/etc/os-release", b"ubuntu".to_vec())
            .build();
        assert!(img.tool("noop").is_ok());
        let err = match img.tool("bash") {
            Err(e) => e.to_string(),
            Ok(_) => panic!("expected missing tool"),
        };
        assert!(err.contains("bash") && err.contains("ubuntu"), "{err}");
        assert_eq!(img.baked_files().len(), 1);
    }

    #[test]
    fn registry_pull() {
        let mut reg = Registry::new();
        reg.push(Image::builder("a").build());
        assert!(reg.pull("a").is_ok());
        assert!(reg.pull("b").is_err());
        assert_eq!(reg.names(), vec!["a"]);
    }
}
