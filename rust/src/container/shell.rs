//! Mini POSIX-ish shell interpreter for container commands.
//!
//! Exactly the subset the paper's listings use (and a little margin):
//!
//! * backslash-newline continuation, `;` / newline / `&&` sequencing
//! * pipelines `a | b | c`
//! * redirections `> f`, `>> f`, `< f`
//! * single/double quotes; `$VAR`, `${VAR}` expansion (double quotes
//!   expand, single quotes don't); `$RANDOM` from a deterministic
//!   per-task RNG
//! * glob expansion (`/in/*.vcf.gz`) against the container [`Vfs`]
//!
//! Runs with `set -e` semantics: a non-zero tool status aborts the
//! command (the paper's pipelines assume success).

use std::collections::BTreeMap;

use crate::error::{MareError, Result};
use crate::runtime::ToolRuntime;
use crate::util::rng::Rng;

use super::image::Image;
use super::tool::{ToolCtx, ToolOutput};
use super::vfs::Vfs;

/// One parsed simple command within a pipeline.
#[derive(Debug, Clone, PartialEq)]
pub struct SimpleCmd {
    pub argv: Vec<String>,
    pub stdin_file: Option<String>,
    pub stdout_file: Option<(String, bool)>, // (path, append)
}

/// A `|`-connected pipeline.
#[derive(Debug, Clone, PartialEq)]
pub struct Pipeline {
    pub cmds: Vec<SimpleCmd>,
}

/// Token from the lexer: text + whether quoting suppressed expansion.
#[derive(Debug, Clone, PartialEq)]
struct Token {
    text: String,
    /// true if any part was single-quoted (no glob expansion).
    literal: bool,
}

/// The shell: executes scripts against an image's tool table and a Vfs.
pub struct Shell<'a> {
    pub image: &'a Image,
    pub env: BTreeMap<String, String>,
    pub runtime: Option<&'a ToolRuntime>,
    pub rng: Rng,
    /// Bytes fed to the first command of the script that reads stdin
    /// (the MaRe streaming mount, §1.4 future work). Consumed once.
    pub stdin: Vec<u8>,
}

impl<'a> Shell<'a> {
    pub fn new(image: &'a Image, env: BTreeMap<String, String>, rng: Rng) -> Self {
        Shell { image, env, runtime: None, rng, stdin: Vec::new() }
    }

    /// Run a whole script; returns the captured stdout of the last
    /// pipeline that wasn't redirected.
    pub fn run(&mut self, script: &str, fs: &mut Vfs) -> Result<Vec<u8>> {
        let mut last_stdout = Vec::new();
        for line in split_commands(script) {
            let pipelines = self.parse_line(&line, fs)?;
            for p in pipelines {
                if p.cmds.is_empty() {
                    continue;
                }
                last_stdout = self.run_pipeline(&p, fs)?;
            }
        }
        Ok(last_stdout)
    }

    fn parse_line(&mut self, line: &str, fs: &Vfs) -> Result<Vec<Pipeline>> {
        let tokens = tokenize(line)?;
        if tokens.is_empty() {
            return Ok(vec![]);
        }
        let mut pipelines = Vec::new();
        let mut cur = Pipeline { cmds: vec![] };
        let mut cmd = SimpleCmd { argv: vec![], stdin_file: None, stdout_file: None };
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            match t.text.as_str() {
                "|" if !t.literal => {
                    if cmd.argv.is_empty() {
                        return Err(MareError::Shell(format!("empty pipeline segment: {line}")));
                    }
                    cur.cmds.push(std::mem::replace(
                        &mut cmd,
                        SimpleCmd { argv: vec![], stdin_file: None, stdout_file: None },
                    ));
                }
                ">" | ">>" if !t.literal => {
                    let path = tokens
                        .get(i + 1)
                        .ok_or_else(|| MareError::Shell(format!("`{}` wants a path", t.text)))?;
                    cmd.stdout_file =
                        Some((self.expand(&path.text)?, t.text == ">>"));
                    i += 1;
                }
                "<" if !t.literal => {
                    let path = tokens
                        .get(i + 1)
                        .ok_or_else(|| MareError::Shell("`<` wants a path".into()))?;
                    cmd.stdin_file = Some(self.expand(&path.text)?);
                    i += 1;
                }
                _ => {
                    let expanded = if t.literal { t.text.clone() } else { self.expand(&t.text)? };
                    // glob expansion on unquoted words containing wildcards
                    if !t.literal && (expanded.contains('*') || expanded.contains('?'))
                        && expanded.starts_with('/')
                    {
                        let matches = fs.glob(&expanded)?;
                        if matches.is_empty() {
                            // bash passes the pattern through when nothing
                            // matches; tools then fail with "no such file",
                            // which is the more debuggable behaviour.
                            cmd.argv.push(expanded);
                        } else {
                            cmd.argv.extend(matches.into_iter().map(String::from));
                        }
                    } else {
                        cmd.argv.push(expanded);
                    }
                }
            }
            i += 1;
        }
        if !cmd.argv.is_empty() {
            cur.cmds.push(cmd);
        }
        if !cur.cmds.is_empty() {
            pipelines.push(cur);
        }
        Ok(pipelines)
    }

    /// `$VAR`, `${VAR}`, `$RANDOM`.
    fn expand(&mut self, s: &str) -> Result<String> {
        let bytes = s.as_bytes();
        let mut out = String::new();
        let mut i = 0;
        while i < bytes.len() {
            if bytes[i] == b'$' && i + 1 < bytes.len() {
                let (name, consumed) = if bytes[i + 1] == b'{' {
                    let end = s[i + 2..]
                        .find('}')
                        .ok_or_else(|| MareError::Shell(format!("unclosed ${{ in `{s}`")))?;
                    (s[i + 2..i + 2 + end].to_string(), end + 3)
                } else {
                    let rest = &s[i + 1..];
                    let len = rest
                        .find(|c: char| !(c.is_ascii_alphanumeric() || c == '_'))
                        .unwrap_or(rest.len());
                    (rest[..len].to_string(), len + 1)
                };
                if name.is_empty() {
                    out.push('$');
                    i += 1;
                    continue;
                }
                let val = if name == "RANDOM" {
                    (self.rng.next_u64() % 32768).to_string()
                } else {
                    self.env.get(&name).cloned().unwrap_or_default()
                };
                out.push_str(&val);
                i += consumed;
            } else {
                out.push(bytes[i] as char);
                i += 1;
            }
        }
        Ok(out)
    }

    fn run_pipeline(&mut self, p: &Pipeline, fs: &mut Vfs) -> Result<Vec<u8>> {
        let mut stdin: Vec<u8>;
        let mut stdout: Vec<u8> = Vec::new();
        for (i, cmd) in p.cmds.iter().enumerate() {
            if let Some(path) = &cmd.stdin_file {
                stdin = fs.read(path)?.to_vec();
            } else if i > 0 {
                stdin = std::mem::take(&mut stdout);
            } else {
                // head of a pipeline: the container's streamed input, if
                // any (first consumer wins)
                stdin = std::mem::take(&mut self.stdin);
            }

            let tool_name = &cmd.argv[0];
            let tool = self.image.tool(tool_name)?;
            let mut ctx = ToolCtx {
                args: cmd.argv[1..].to_vec(),
                stdin: std::mem::take(&mut stdin),
                fs,
                env: &self.env,
                runtime: self.runtime,
                rng: self.rng.fork(i as u64),
            };
            let out: ToolOutput = tool.run(&mut ctx)?;
            if out.status != 0 {
                return Err(MareError::Shell(format!(
                    "`{}` exited with status {} in image `{}`",
                    cmd.argv.join(" "),
                    out.status,
                    self.image.name
                )));
            }
            stdout = out.stdout;

            if let Some((path, append)) = &cmd.stdout_file {
                if *append {
                    fs.append(path, &stdout)?;
                } else {
                    fs.write(path, std::mem::take(&mut stdout))?;
                }
                stdout = Vec::new();
            }
        }
        Ok(stdout)
    }
}

/// Split a script into logical commands: join `\`-continuations, then
/// split on newline / `;` / `&&` outside quotes.
pub fn split_commands(script: &str) -> Vec<String> {
    let joined = script.replace("\\\n", " ");
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut chars = joined.chars().peekable();
    let mut quote: Option<char> = None;
    while let Some(c) = chars.next() {
        match quote {
            Some(q) => {
                cur.push(c);
                if c == q {
                    quote = None;
                }
            }
            None => match c {
                '\'' | '"' => {
                    quote = Some(c);
                    cur.push(c);
                }
                '\n' | ';' => {
                    if !cur.trim().is_empty() {
                        out.push(cur.trim().to_string());
                    }
                    cur.clear();
                }
                '&' if chars.peek() == Some(&'&') => {
                    chars.next();
                    if !cur.trim().is_empty() {
                        out.push(cur.trim().to_string());
                    }
                    cur.clear();
                }
                c => cur.push(c),
            },
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur.trim().to_string());
    }
    out
}

/// Tokenize one command respecting quotes; `|`, `>`, `>>`, `<` become
/// standalone tokens when unquoted.
fn tokenize(line: &str) -> Result<Vec<Token>> {
    let mut out: Vec<Token> = Vec::new();
    let mut cur = String::new();
    let mut literal = false;
    let mut has_content = false;
    let chars: Vec<char> = line.chars().collect();
    let mut i = 0;

    macro_rules! flush {
        () => {
            if has_content || !cur.is_empty() {
                out.push(Token { text: std::mem::take(&mut cur), literal });
                #[allow(unused_assignments)]
                {
                    literal = false;
                    has_content = false;
                }
            }
        };
    }

    while i < chars.len() {
        let c = chars[i];
        match c {
            ' ' | '\t' => {
                flush!();
            }
            '\'' => {
                literal = true;
                has_content = true;
                i += 1;
                while i < chars.len() && chars[i] != '\'' {
                    cur.push(chars[i]);
                    i += 1;
                }
                if i >= chars.len() {
                    return Err(MareError::Shell(format!("unterminated quote: {line}")));
                }
            }
            '"' => {
                has_content = true;
                i += 1;
                while i < chars.len() && chars[i] != '"' {
                    cur.push(chars[i]);
                    i += 1;
                }
                if i >= chars.len() {
                    return Err(MareError::Shell(format!("unterminated quote: {line}")));
                }
            }
            '|' | '<' => {
                flush!();
                out.push(Token { text: c.to_string(), literal: false });
            }
            '>' => {
                flush!();
                if chars.get(i + 1) == Some(&'>') {
                    out.push(Token { text: ">>".into(), literal: false });
                    i += 1;
                } else {
                    out.push(Token { text: ">".into(), literal: false });
                }
            }
            c => {
                cur.push(c);
                has_content = true;
            }
        }
        i += 1;
    }
    flush!();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_handles_continuations_and_separators() {
        let script = "a one \\\n  two\nb; c && d";
        assert_eq!(split_commands(script), vec!["a one    two", "b", "c", "d"]);
    }

    #[test]
    fn split_respects_quotes() {
        let script = "awk '{s+=$1} END {print s}' /in > /out";
        assert_eq!(split_commands(script).len(), 1);
        let script2 = "echo 'a;b' ; echo c";
        assert_eq!(split_commands(script2), vec!["echo 'a;b'", "echo c"]);
    }

    #[test]
    fn tokenize_pipeline_and_redirects() {
        let toks = tokenize("grep -o '[GC]' /dna | wc -l > /count").unwrap();
        let texts: Vec<&str> = toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(texts, vec!["grep", "-o", "[GC]", "/dna", "|", "wc", "-l", ">", "/count"]);
        assert!(toks[2].literal); // single-quoted
    }

    #[test]
    fn tokenize_double_gt() {
        let toks = tokenize("x >> /log").unwrap();
        assert_eq!(toks[1].text, ">>");
    }

    #[test]
    fn tokenize_rejects_unterminated() {
        assert!(tokenize("echo 'oops").is_err());
    }
}
