//! Docker-like container engine substrate.
//!
//! The paper uses Docker for exactly four things: (1) image distribution,
//! (2) an isolated filesystem per run, (3) volume binds for partition
//! data, (4) running a shell command against bundled tools. This module
//! rebuilds that contract in-process:
//!
//! * [`vfs`] — the container filesystem (tmpfs-capped or disk-backed)
//! * [`image`] — images + registry (Docker Hub analogue)
//! * [`tool`] — the "binary" trait; domain tools call the PJRT runtime
//! * [`shell`] — the command interpreter (pipes, redirects, globs, $RANDOM)
//! * [`engine`] — pull → bake → bind → run → collect

pub mod engine;
pub mod image;
pub mod shell;
pub mod tool;
pub mod vfs;

pub use engine::{Engine, RunConfig, RunOutcome, DEFAULT_TMPFS_CAPACITY};
pub use image::{Image, ImageBuilder, Registry};
pub use shell::Shell;
pub use tool::{Tool, ToolCtx, ToolOutput};
pub use vfs::{Backing, Vfs};

/// Mount backing choice exposed at the MaRe API level.
pub type MountKind = Backing;
