//! The container engine: pull image, bake files, bind volumes, run the
//! command through the mini-shell, hand back the filesystem.
//!
//! Functionally faithful to what MaRe needs from Docker: an isolated fs
//! per container, volumes in/out, deterministic environment. All *cost*
//! accounting (pull, start, stage-in/out) happens in the cluster layer —
//! the engine is pure execution.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::error::Result;
use crate::runtime::ToolRuntime;
use crate::util::bytes::Shared;
use crate::util::rng::Rng;

use super::image::Registry;
use super::shell::Shell;
use super::vfs::{Backing, Vfs};

/// Default tmpfs capacity per container (half of a worker's 32 GB in the
/// paper's setup would be 16 GB; scaled down for in-process runs).
pub const DEFAULT_TMPFS_CAPACITY: u64 = 256 << 20;

/// One container run.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub image: String,
    pub command: String,
    pub env: BTreeMap<String, String>,
    /// Files pre-bound into the container (input volumes). [`Shared`]
    /// buffers: binding them into the container VFS is a refcount bump.
    pub input_files: Vec<(String, Shared)>,
    /// Disk-backed mount space instead of tmpfs (paper: TMPDIR on disk).
    pub disk_backed: bool,
    /// tmpfs capacity (ignored for disk).
    pub tmpfs_capacity: u64,
    /// Deterministic seed for $RANDOM etc.
    pub seed: u64,
    /// Bytes streamed to the command's stdin (the streaming mount of
    /// §1.4 future work; empty = no stream).
    pub stdin: Vec<u8>,
}

impl RunConfig {
    pub fn new(image: impl Into<String>, command: impl Into<String>) -> Self {
        RunConfig {
            image: image.into(),
            command: command.into(),
            env: BTreeMap::new(),
            input_files: Vec::new(),
            disk_backed: false,
            tmpfs_capacity: DEFAULT_TMPFS_CAPACITY,
            seed: 0,
            stdin: Vec::new(),
        }
    }

    pub fn stdin(mut self, bytes: Vec<u8>) -> Self {
        self.stdin = bytes;
        self
    }

    pub fn input(mut self, path: impl Into<String>, bytes: impl Into<Shared>) -> Self {
        self.input_files.push((path.into(), bytes.into()));
        self
    }

    pub fn env_var(mut self, k: impl Into<String>, v: impl Into<String>) -> Self {
        self.env.insert(k.into(), v.into());
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn disk(mut self, disk: bool) -> Self {
        self.disk_backed = disk;
        self
    }
}

/// What a finished container leaves behind.
#[derive(Debug)]
pub struct RunOutcome {
    /// The container filesystem (read output mounts from here).
    pub fs: Vfs,
    /// Captured stdout of the last non-redirected pipeline.
    pub stdout: Vec<u8>,
    /// Total bytes written by the run (stage-out cost model input).
    pub bytes_written: u64,
}

/// The engine: a registry plus the shared compute runtime for
/// compute-backed tools.
#[derive(Clone)]
pub struct Engine {
    registry: Arc<Registry>,
    runtime: Option<ToolRuntime>,
    /// Containers launched through this engine (clones share the
    /// counter) — the optimizer's fusion win is asserted against it.
    launches: Arc<AtomicU64>,
}

impl Engine {
    pub fn new(registry: Arc<Registry>, runtime: Option<ToolRuntime>) -> Self {
        Engine { registry, runtime, launches: Arc::new(AtomicU64::new(0)) }
    }

    /// Total simulated container launches so far (shared across clones).
    pub fn launch_count(&self) -> u64 {
        self.launches.load(Ordering::Relaxed)
    }

    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    pub fn runtime(&self) -> Option<&ToolRuntime> {
        self.runtime.as_ref()
    }

    /// Run one container to completion.
    pub fn run(&self, cfg: &RunConfig) -> Result<RunOutcome> {
        self.launches.fetch_add(1, Ordering::Relaxed);
        let image = self.registry.pull(&cfg.image)?;

        let mut fs = if cfg.disk_backed {
            Vfs::disk()
        } else {
            Vfs::new(Backing::Tmpfs, Some(cfg.tmpfs_capacity))
        };

        // Bake image files (never charged against the volume capacity in
        // real Docker; here they share the fs, so baked files get a free
        // pass by building them into an uncapped fs first).
        for (path, bytes) in image.baked_files() {
            fs.write(path, bytes.clone())?;
        }
        for (path, bytes) in &cfg.input_files {
            fs.write(path, bytes.clone())?;
        }
        let baseline = fs.used_bytes();

        let mut env = cfg.env.clone();
        env.entry("HOME".into()).or_insert_with(|| "/root".into());
        env.entry("HOSTNAME".into()).or_insert_with(|| "mare-container".into());

        let mut shell = Shell::new(&image, env, Rng::new(cfg.seed));
        shell.runtime = self.runtime.as_ref();
        shell.stdin = cfg.stdin.clone();
        let stdout = shell.run(&cfg.command, &mut fs)?;

        let bytes_written = fs.peak_bytes().saturating_sub(baseline);
        Ok(RunOutcome { fs, stdout, bytes_written })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::image::Image;
    use crate::container::tool::{Tool, ToolCtx, ToolOutput};

    /// `upper <in >out`-style test tool: uppercases stdin.
    struct Upper;
    impl Tool for Upper {
        fn name(&self) -> &'static str {
            "upper"
        }
        fn run(&self, ctx: &mut ToolCtx) -> Result<ToolOutput> {
            ToolOutput::ok(ctx.stdin.to_ascii_uppercase())
        }
    }

    /// reads a file arg, writes stdout
    struct CatTest;
    impl Tool for CatTest {
        fn name(&self) -> &'static str {
            "cat"
        }
        fn run(&self, ctx: &mut ToolCtx) -> Result<ToolOutput> {
            let mut out = Vec::new();
            for a in ctx.args.clone() {
                out.extend_from_slice(ctx.fs.read(&a)?);
            }
            ToolOutput::ok(out)
        }
    }

    fn engine() -> Engine {
        let mut reg = Registry::new();
        reg.push(
            Image::builder("test")
                .tool(Arc::new(Upper))
                .tool(Arc::new(CatTest))
                .file("/etc/motd", b"hi".to_vec())
                .build(),
        );
        Engine::new(Arc::new(reg), None)
    }

    #[test]
    fn run_pipeline_with_mounts() {
        let e = engine();
        let cfg = RunConfig::new("test", "cat /in | upper > /out")
            .input("/in", b"hello".to_vec());
        let out = e.run(&cfg).unwrap();
        assert_eq!(out.fs.read("/out").unwrap(), b"HELLO");
    }

    #[test]
    fn baked_files_visible() {
        let e = engine();
        let cfg = RunConfig::new("test", "cat /etc/motd > /o");
        let out = e.run(&cfg).unwrap();
        assert_eq!(out.fs.read("/o").unwrap(), b"hi");
    }

    #[test]
    fn unknown_image_fails() {
        let e = engine();
        assert!(e.run(&RunConfig::new("nope", "upper")).is_err());
    }

    #[test]
    fn unknown_tool_fails_with_image_name() {
        let e = engine();
        let err = e.run(&RunConfig::new("test", "bash -c hi")).unwrap_err().to_string();
        assert!(err.contains("bash") && err.contains("test"), "{err}");
    }

    #[test]
    fn random_is_deterministic_per_seed() {
        let e = engine();
        let run = |seed| {
            let cfg = RunConfig::new("test", "cat /in > /o.$RANDOM")
                .input("/in", b"x".to_vec())
                .seed(seed);
            e.run(&cfg).unwrap().fs.list_all().join(",")
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn launch_counter_shared_across_clones() {
        let e = engine();
        let e2 = e.clone();
        let cfg = RunConfig::new("test", "cat /in > /out").input("/in", b"x".to_vec());
        e.run(&cfg).unwrap();
        e2.run(&cfg).unwrap();
        assert_eq!(e.launch_count(), 2);
        assert_eq!(e2.launch_count(), 2);
    }

    #[test]
    fn tmpfs_capacity_propagates() {
        let e = engine();
        let mut cfg = RunConfig::new("test", "cat /in > /copy").input("/in", vec![b'x'; 100]);
        cfg.tmpfs_capacity = 150; // input (100) + copy (100) > 150
        let err = e.run(&cfg).unwrap_err().to_string();
        assert!(err.contains("no space left"), "{err}");
        // disk-backed succeeds
        let cfg = cfg.disk(true);
        assert!(e.run(&cfg).is_ok());
    }
}
