//! `mare` CLI — leader entrypoint.
//!
//! ```text
//! mare run  --workload gc|vs|snp --storage hdfs|swift|s3|local
//!           [--workers N] [--vcpus M] [--scale S] [--seed K]
//!           [--reduce-depth D] [--config file.json] [--artifacts DIR]
//! mare plan --workload gc|vs|snp ...        # logical -> optimized -> physical
//! mare inspect [--artifacts DIR]            # artifacts + stock images
//! mare help
//! ```

use mare::config::{RunConfigFile, Workload};
use mare::error::Result;
use mare::util::cli::Args;

const HELP: &str = "\
mare — MapReduce-oriented processing with application containers
(rust + JAX + Pallas reproduction of Capuccini et al., 2018)

USAGE:
  mare run   [options]   run a workload end-to-end, print the report
  mare plan  [options]   print the logical -> optimized -> physical plans
  mare shell [options]   interactive session (the paper's Zeppelin workflow)
  mare inspect           show AOT artifacts and stock container images
  mare help              this text

OPTIONS (run/plan):
  --workload gc|vs|snp    pipeline to run              [gc]
  --storage hdfs|swift|s3|local   ingestion backend    [hdfs]
  --workers N             cluster workers              [16]
  --vcpus M               vCPUs per worker             [8]
  --scale S               lines / molecules / chromosome-bp   [1000]
  --seed K                workload + cluster seed      [42]
  --reduce-depth D        tree-reduce depth K          [2]
  --config FILE           JSON config (flags override it)
  --artifacts DIR         AOT artifact dir             [./artifacts]
";

fn main() -> std::process::ExitCode {
    mare::util::logging::init(mare::util::logging::Level::Info);
    match dispatch() {
        Ok(()) => std::process::ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::ExitCode::FAILURE
        }
    }
}

fn dispatch() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("plan") => cmd_plan(&args),
        Some("shell") => cmd_shell(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("help") | None => {
            println!("{HELP}");
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown subcommand `{other}`\n{HELP}");
            Err(mare::error::MareError::Config(format!("unknown subcommand `{other}`")))
        }
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = RunConfigFile::from_args(args)?;
    mare::log_info!(
        "run: workload={:?} storage={} cluster={}x{} scale={}",
        cfg.workload,
        cfg.backend.name(),
        cfg.cluster.workers,
        cfg.cluster.vcpus_per_worker,
        cfg.scale
    );
    let res = mare::workloads::driver::run(&cfg)?;
    println!("== ingestion ==");
    println!(
        "backend={} bytes={} readers={} virtual={}",
        cfg.backend.name(),
        res.ingest.bytes,
        res.ingest.readers,
        res.ingest.duration
    );
    println!("== run ==");
    print!("{}", res.report.summary());
    println!("== result ==");
    println!("{}", res.digest);
    println!("(real wall-clock: {:?})", res.report.real);
    Ok(())
}

fn cmd_plan(args: &Args) -> Result<()> {
    let cfg = RunConfigFile::from_args(args)?;
    // a small dataset is enough to compile the plan; nothing executes
    let cluster = mare::workloads::make_cluster(cfg.cluster.clone(), None, None)?;
    let ds = match cfg.workload {
        Workload::Gc => mare::dataset::Dataset::parallelize_text(
            &mare::workloads::gc::genome_text(cfg.seed, 16, 80),
            "\n",
            cfg.cluster.workers * 2,
        ),
        Workload::Vs => mare::dataset::Dataset::parallelize_text(
            &mare::workloads::genlib::library_sdf(cfg.seed, 8),
            mare::workloads::vs::SDF_SEP,
            cfg.cluster.workers * 2,
        ),
        Workload::Snp => mare::dataset::Dataset::parallelize_text(
            "@r/1\nACGT\n+\nIIII",
            "\x00",
            cfg.cluster.workers * 2,
        ),
    };
    let job = match cfg.workload {
        Workload::Gc => mare::workloads::gc::pipeline(cluster, ds),
        Workload::Vs => mare::workloads::vs::pipeline(cluster, ds, cfg.reduce_depth),
        Workload::Snp => mare::workloads::snp::pipeline(cluster, ds, cfg.cluster.workers),
    };
    print!("{}", job.explain());
    Ok(())
}

fn cmd_shell(args: &Args) -> Result<()> {
    use std::io::{BufRead, Write};
    let cfg = RunConfigFile::from_args(args)?;
    // runtime is optional: POSIX-only sessions work without artifacts
    let runtime_dir = std::path::Path::new(&cfg.artifacts)
        .join("manifest.json")
        .exists()
        .then_some(cfg.artifacts.as_str());
    let mut session = mare::repl::Session::with_config(cfg.cluster.clone(), runtime_dir)?;
    println!("mare interactive shell — `help` for commands, `quit` to leave");
    println!("{}", session.status());

    let stdin = std::io::stdin();
    let mut line = String::new();
    loop {
        print!("mare> ");
        std::io::stdout().flush().ok();
        line.clear();
        if stdin.lock().read_line(&mut line)? == 0 {
            break; // EOF
        }
        match session.eval(&line) {
            Ok(out) if out.is_empty() => {}
            Ok(out) => println!("{out}"),
            Err(e) if mare::repl::is_quit(&e) => break,
            Err(e) => println!("error: {e}"),
        }
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let dir = args.flag_or("artifacts", &mare::workloads::artifact_dir());
    println!("== artifacts ({dir}) ==");
    match mare::runtime::Manifest::load(std::path::Path::new(&dir)) {
        Ok(m) => {
            for (name, e) in &m.entries {
                let ins: Vec<String> =
                    e.inputs.iter().map(|t| format!("{}{:?}", t.dtype, t.shape)).collect();
                let outs: Vec<String> =
                    e.outputs.iter().map(|t| format!("{}{:?}", t.dtype, t.shape)).collect();
                println!(
                    "  {:<16} {} -> {}   ({})",
                    name,
                    ins.join(", "),
                    outs.join(", "),
                    e.file
                );
            }
        }
        Err(e) => println!("  (unavailable: {e})"),
    }
    println!("== stock images ==");
    let reg = mare::tools::images::stock_registry(None);
    for name in reg.names() {
        let img = reg.pull(name)?;
        let mut tools = img.tool_names();
        tools.truncate(8);
        println!(
            "  {:<36} {:>5} MiB, tools: {}, ...",
            img.name,
            img.size_bytes >> 20,
            tools.join(", ")
        );
    }
    println!("  mcapuccini/alignment:latest          (baked per-run with the reference genome)");
    Ok(())
}
