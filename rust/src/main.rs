//! `mare` CLI — leader entrypoint.
//!
//! ```text
//! mare run  --workload gc|vs|snp|kmer --storage hdfs|swift|s3|local
//!           [--workers N] [--vcpus M] [--scale S] [--seed K]
//!           [--reduce-depth D] [--config file.json] [--artifacts DIR]
//! mare plan --workload gc|vs|snp|kmer [--json]   # logical -> optimized -> physical
//! mare submit <plan.json> [--queue DIR]     # validate + enqueue a wire plan
//! mare jobs [--queue DIR] [--tenant T]      # list queued/running/done/failed
//! mare work [--queue DIR] [--workers N] [--fault W:K:hold|running|midrun[@S]]
//!                                           # threaded worker pool drains the queue
//! mare serve [--queue DIR] [--workers N] [--max-depth D] [--quota t=w,...]
//!           [--max-attempts K]              # resident multi-tenant job service
//! mare serve --drain [--queue DIR]          # ask the resident daemon to exit
//! mare requeue <id> [--queue DIR] [--force] # put a stuck/finished job back
//! mare dlq list|show <id>|retry <id>        # inspect/redrive dead-lettered jobs
//! mare inspect [--artifacts DIR]            # artifacts + stock images
//! mare help
//! ```

use mare::config::{RunConfigFile, Workload};
use mare::error::Result;
use mare::util::cli::Args;

const HELP: &str = "\
mare — MapReduce-oriented processing with application containers
(rust + JAX + Pallas reproduction of Capuccini et al., 2018)

USAGE:
  mare run   [options]   run a workload end-to-end, print the report
  mare plan  [options]   print the logical -> optimized -> physical plans
                         (--json: emit the v1 wire envelope instead,
                          submittable via `mare submit`; with an explicit
                          --storage, the plan ingests from a storage URI
                          like hdfs://genome.txt, still executable under
                          `mare work` via the simulated storage catalog)
  mare shell [options]   interactive session (the paper's Zeppelin workflow;
                         `:save`/`:load` persist plans as wire JSON)
  mare submit <plan.json> [--queue DIR]
                         validate a wire plan (docs/WIRE_FORMAT.md) and
                         enqueue it on the spool directory
  mare jobs  [--queue DIR] [--tenant T]
                         list submitted jobs with status + launch counts
                         (--tenant narrows the table to one tenant)
  mare work  [--queue DIR] [--workers N]
                         spin a pool of N worker THREADS that
                         concurrently claim and run queued jobs
  mare serve [--queue DIR] [--workers N] [--max-depth D] [--quota t=w,...]
                         resident job service: a persistent worker fleet
                         with fair-share + priority claim ordering over
                         envelope `tenant`/`priority` fields, admission
                         backpressure at --max-depth, self-healing
                         requeue of dead workers' jobs, and atomic
                         serve-health.json / serve-stats.json snapshots
                         in the spool every tick
  mare serve --drain [--queue DIR]
                         flip the drain flag in serve-control.json: the
                         daemon stops claiming, finishes in-flight jobs,
                         publishes a final snapshot and exits 0
  mare requeue <id> [--queue DIR] [--force]
                         put a job back in the queue (recovers jobs
                         stuck `running` after a worker died; also
                         re-runs `failed`/`done` jobs). Fresh `running`
                         records are presumed live and refused unless
                         --force
  mare dlq list [--queue DIR]
                         list dead-lettered jobs (moved to dlq/ by the
                         serve daemon once a job spends its attempt
                         budget; see --max-attempts)
  mare dlq show <id> [--queue DIR]
                         full failure history of one dead-lettered job
  mare dlq retry <id> [--queue DIR]
                         redrive a dead-lettered job: back to `queued`
                         with a fresh attempt budget (the failure
                         history is preserved)
  mare bench [--pr N] [--out FILE] [--filter S]
                         run the data-plane hot-path micro-benchmarks
                         and archive them as BENCH_<N>.json (repo-root
                         perf trajectory; see README \"Benchmarks\")
  mare inspect           show AOT artifacts and stock container images
  mare help              this text

OPTIONS (run/plan):
  --workload gc|vs|snp|kmer   pipeline to run          [gc]
  --storage hdfs|swift|s3|local   ingestion backend    [hdfs]
  --workers N             cluster workers              [16]
  --vcpus M               vCPUs per worker             [8]
  --scale S               lines / molecules / chromosome-bp   [1000]
  --seed K                workload + cluster seed      [42]
  --reduce-depth D        tree-reduce depth K          [2]
  --config FILE           JSON config (flags override it)
  --artifacts DIR         AOT artifact dir             [./artifacts]
  --fault W:slow:F        plant a deterministic straggler: worker W runs
                          F times slower for the whole run (nothing
                          fails; the worker just drags its stages)
  --speculate             speculative execution: race straggling tasks
                          with a copy on another worker, first finisher
                          wins (Spark-default policy: quantile 0.75,
                          multiplier 1.5, <= 4 copies per stage)

OPTIONS (submit/jobs/work/requeue):
  --queue DIR             job spool directory          [.mare/queue]
  --workers N             worker threads for work      [2]
                          (cluster shape per worker comes from --config/
                          --vcpus; for `work`, --workers sizes the POOL)
  --drivers N             deprecated alias for --workers
  --fault W:K:hold|running|midrun[@S][:jID]
                          inject a worker death: worker W (or `*` for
                          any worker, with :jID selecting the job) dies
                          on its K-th claim — holding the claim (`hold`;
                          recovered by the stale sweep), leaving the job
                          running (`running`; recover with `mare
                          requeue`), or mid-execution after S committed
                          stages (`midrun@S`; the successor resumes from
                          the checkpoint). Comma-separate for several.
  --stale-ms T            claim holds older than T ms are swept [10000]
  --force                 requeue even a fresh `running` record

OPTIONS (serve):
  --workers N             resident worker threads      [4]
  --max-depth D           refuse submissions while queued+held >= D
                          (0 = unlimited)              [256]
  --quota t=w[,t=w...]    tenant fair-share weights; unlisted tenants
                          weigh 1. Editable at runtime: the daemon
                          re-reads serve-control.json every tick
  --max-attempts K        dead-letter a job after K failed attempts
                          (0 = keep failed jobs in the live spool) [0]
  --tick-ms T             supervisor cadence (control reload, orphan
                          requeue, health publish)     [200]
  --drain                 request drain instead of starting a daemon
";

/// Default job spool directory shared by submit/jobs/work/requeue.
const DEFAULT_QUEUE: &str = mare::submit::DEFAULT_QUEUE_DIR;

fn main() -> std::process::ExitCode {
    mare::util::logging::init(mare::util::logging::Level::Info);
    match dispatch() {
        Ok(()) => std::process::ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::ExitCode::FAILURE
        }
    }
}

fn dispatch() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("plan") => cmd_plan(&args),
        Some("shell") => cmd_shell(&args),
        Some("submit") => cmd_submit(&args),
        Some("jobs") => cmd_jobs(&args),
        Some("work") => cmd_work(&args),
        Some("serve") => cmd_serve(&args),
        Some("requeue") => cmd_requeue(&args),
        Some("dlq") => cmd_dlq(&args),
        Some("bench") => cmd_bench(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("help") | None => {
            println!("{HELP}");
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown subcommand `{other}`\n{HELP}");
            Err(mare::error::MareError::Config(format!("unknown subcommand `{other}`")))
        }
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = RunConfigFile::from_args(args)?;
    mare::log_info!(
        "run: workload={:?} storage={} cluster={}x{} scale={}",
        cfg.workload,
        cfg.backend.name(),
        cfg.cluster.workers,
        cfg.cluster.vcpus_per_worker,
        cfg.scale
    );
    let res = mare::workloads::driver::run(&cfg)?;
    println!("== ingestion ==");
    println!(
        "backend={} bytes={} readers={} virtual={}",
        cfg.backend.name(),
        res.ingest.bytes,
        res.ingest.readers,
        res.ingest.duration
    );
    println!("== run ==");
    print!("{}", res.report.summary());
    println!("== result ==");
    println!("{}", res.digest);
    println!("(real wall-clock: {:?})", res.report.real);
    Ok(())
}

fn cmd_plan(args: &Args) -> Result<()> {
    let cfg = RunConfigFile::from_args(args)?;
    // a small dataset is enough to compile the plan; nothing executes.
    // sources come from gen: labels — or, when --storage is passed
    // explicitly, from storage URIs the executing driver resolves
    // through its catalog — so `--json` plans stay executable after
    // `mare submit` / under `mare work` (docs/WIRE_FORMAT.md §4)
    let cluster = mare::workloads::make_cluster(cfg.cluster.clone(), None, None)?;
    let storage_backed = args.flag("storage").is_some();
    let label = match (cfg.workload, storage_backed) {
        (Workload::Gc | Workload::Kmer, true) => {
            format!("{}://genome.txt?lines=16", cfg.backend.name())
        }
        (Workload::Vs, true) => format!("{}://library.sdf?molecules=8", cfg.backend.name()),
        // kmer shares the GC genome generator: gen:gc: labels resolve
        // to the same seeded text on every executing driver
        (Workload::Gc | Workload::Kmer, false) => "gen:gc:16".to_string(),
        (Workload::Vs, false) => "gen:vs:8".to_string(),
        (Workload::Snp, _) => {
            if storage_backed {
                // not a silent ignore: the user asked for a storage
                // source they won't get
                eprintln!(
                    "note: snp plans always ingest `gen:snp:` — the reference genome \
                     must be baked into the alignment image, which only gen:snp: \
                     sources imply; --storage {} is ignored for this workload",
                    cfg.backend.name()
                );
            }
            "gen:snp:500".to_string()
        }
    };
    let label = label.as_str();
    // a stub with the right label + partition count is all a plan
    // needs (same O(1) admission trick as Submitter::validate);
    // executing drivers materialize the real records from the label
    let ds = mare::submit::SourceSpec::parse(label).stub(cfg.cluster.workers * 2);
    let job = match cfg.workload {
        Workload::Gc => mare::workloads::gc::pipeline(cluster, ds),
        Workload::Vs => mare::workloads::vs::pipeline(cluster, ds, cfg.reduce_depth),
        Workload::Snp => mare::workloads::snp::pipeline(cluster, ds, cfg.cluster.workers),
        Workload::Kmer => {
            mare::workloads::kmer::pipeline(cluster, ds, cfg.cluster.workers, true)
        }
    };
    if args.flag_bool("json") {
        // the v1 wire envelope (docs/WIRE_FORMAT.md) — submittable as-is
        println!("{}", mare::mare::wire::encode_string(job.logical())?);
    } else {
        print!("{}", job.explain());
    }
    Ok(())
}

fn cmd_submit(args: &Args) -> Result<()> {
    let Some(path) = args.positional.first() else {
        return Err(mare::error::MareError::Config(
            "usage: mare submit <plan.json> [--queue DIR]".into(),
        ));
    };
    let text = std::fs::read_to_string(path)?;
    let cfg = RunConfigFile::from_args(args)?;
    let queue = mare::submit::JobQueue::open(args.flag_or("queue", DEFAULT_QUEUE))?;
    let submitter = mare::submit::Submitter::new(cfg.cluster);
    let (id, plan) = submitter.submit(&queue, &text)?;
    println!("job {id} queued in {}", queue.dir().display());
    println!("  plan:      {}", plan.summary);
    println!("  optimizer: {}", plan.opt_summary);
    if !plan.executable {
        println!(
            "  note: source is not resolvable by simulated drivers \
             (gen:/inline: labels and hdfs://|swift://|s3://|local:// \
             URIs execute under `mare work`)"
        );
    }
    Ok(())
}

fn cmd_jobs(args: &Args) -> Result<()> {
    let queue = mare::submit::JobQueue::open(args.flag_or("queue", DEFAULT_QUEUE))?;
    let tenant = args.flag("tenant");
    let jobs = mare::submit::filter_tenant(queue.list()?, tenant);
    if jobs.is_empty() {
        match tenant {
            Some(t) => println!("no jobs for tenant `{t}` in {}", queue.dir().display()),
            None => println!("no jobs in {}", queue.dir().display()),
        }
        return Ok(());
    }
    print!("{}", mare::submit::render_jobs_table(&jobs, mare::submit::now_millis()));
    Ok(())
}

fn cmd_dlq(args: &Args) -> Result<()> {
    const USAGE: &str = "usage: mare dlq list|show <id>|retry <id> [--queue DIR]";
    let queue = mare::submit::JobQueue::open(args.flag_or("queue", DEFAULT_QUEUE))?;
    let id_arg = |args: &Args| -> Result<u64> {
        args.positional.get(1).and_then(|s| s.parse().ok()).ok_or_else(|| {
            mare::error::MareError::Config(USAGE.into())
        })
    };
    match args.positional.first().map(|s| s.as_str()) {
        Some("list") | None => {
            let jobs = queue.dlq_list()?;
            if jobs.is_empty() {
                println!("dead-letter queue of {} is empty", queue.dir().display());
                return Ok(());
            }
            print!("{}", mare::submit::render_dlq_table(&jobs, mare::submit::now_millis()));
        }
        Some("show") => {
            let job = queue.dlq_get(id_arg(args)?)?;
            let now = mare::submit::now_millis();
            println!("job {} ({})", job.id, job.summary);
            println!("  tenant:   {}  priority: {}", job.tenant, job.priority);
            println!(
                "  attempts: {} (dead-lettered {} ago)",
                job.attempts,
                mare::submit::fmt_age(now, job.stamp_ms)
            );
            for (i, f) in job.failures.iter().enumerate() {
                println!(
                    "  attempt {}: [{} ago, {}] {}",
                    i + 1,
                    mare::submit::fmt_age(now, f.at_ms),
                    f.worker,
                    f.detail
                );
            }
            println!("  redrive with: mare dlq retry {}", job.id);
        }
        Some("retry") => {
            let job = queue.dlq_retry(id_arg(args)?)?;
            println!(
                "job {} redriven: queued with a fresh attempt budget ({})",
                job.id, job.summary
            );
        }
        Some(other) => {
            return Err(mare::error::MareError::Config(format!(
                "unknown dlq action `{other}`\n{USAGE}"
            )));
        }
    }
    Ok(())
}

fn cmd_requeue(args: &Args) -> Result<()> {
    let id: u64 = args
        .positional
        .first()
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| {
            mare::error::MareError::Config("usage: mare requeue <id> [--queue DIR]".into())
        })?;
    let queue = mare::submit::JobQueue::open(args.flag_or("queue", DEFAULT_QUEUE))?;
    let job = if args.flag_bool("force") {
        queue.requeue_with(id, std::time::Duration::ZERO, true)?
    } else {
        queue.requeue(id)?
    };
    println!("job {} requeued ({})", job.id, job.summary);
    Ok(())
}

fn cmd_work(args: &Args) -> Result<()> {
    // for `work`, --workers sizes the POOL (threads), not the simulated
    // cluster: strip it before resolving the run config so each
    // worker's driver keeps the configured cluster shape
    let mut cluster_args = args.clone();
    cluster_args.flags.remove("workers");
    let cfg = RunConfigFile::from_args(&cluster_args)?;
    let queue = mare::submit::JobQueue::open(args.flag_or("queue", DEFAULT_QUEUE))?;

    let legacy = args.flag_usize("drivers", 2)?; // pre-pool flag name
    let workers = args.flag_usize("workers", legacy)?.max(1);
    let mut pool_cfg = mare::submit::PoolConfig::new(workers, cfg.cluster.clone());
    if let Some(spec) = args.flag("fault") {
        pool_cfg.faults = mare::submit::FaultPlan::parse(spec)?;
    }
    let stale_default = pool_cfg.stale_after.as_millis() as u64;
    pool_cfg.stale_after =
        std::time::Duration::from_millis(args.flag_u64("stale-ms", stale_default)?);
    // stage checkpoints live next to the spool: a killed worker's
    // successor resumes the job from the last committed stage
    pool_cfg.checkpoints = Some(queue.checkpoint_dir());

    let outcome = mare::submit::WorkerPool::new(pool_cfg).run(&queue)?;
    if outcome.finished.is_empty() {
        println!("queue {} is empty", queue.dir().display());
    }
    for job in &outcome.finished {
        let r = job.result.as_ref().expect("drained jobs carry a result");
        println!(
            "job {} -> {} on {} (launches={}, records={}{})",
            job.id,
            job.status.name(),
            r.driver,
            r.launches,
            r.records,
            if r.detail == "ok" { String::new() } else { format!(", {}", r.detail) },
        );
    }
    println!("pool: {} workers, {} claim conflicts", workers, outcome.total_conflicts());
    for report in &outcome.reports {
        println!("  {}", report.summary());
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let queue = mare::submit::JobQueue::open(args.flag_or("queue", DEFAULT_QUEUE))?;
    if args.flag_bool("drain") {
        let control = mare::serve::request_drain(queue.dir())?;
        println!(
            "drain requested for {} — the daemon (max-depth {}) finishes \
             in-flight work and exits",
            queue.dir().display(),
            control.max_depth
        );
        return Ok(());
    }
    // like `work`, --workers sizes the resident FLEET (threads), not
    // the simulated cluster each worker drives
    let mut cluster_args = args.clone();
    cluster_args.flags.remove("workers");
    let cfg = RunConfigFile::from_args(&cluster_args)?;

    let workers = args.flag_usize("workers", 4)?.max(1);
    let mut pool_cfg = mare::submit::PoolConfig::new(workers, cfg.cluster.clone());
    if let Some(spec) = args.flag("fault") {
        pool_cfg.faults = mare::submit::FaultPlan::parse(spec)?;
    }
    let stale_default = pool_cfg.stale_after.as_millis() as u64;
    pool_cfg.stale_after =
        std::time::Duration::from_millis(args.flag_u64("stale-ms", stale_default)?);
    pool_cfg.checkpoints = Some(queue.checkpoint_dir());

    let mut serve_cfg = mare::serve::ServeConfig::new(pool_cfg);
    serve_cfg.tick = std::time::Duration::from_millis(args.flag_u64("tick-ms", 200)?.max(1));
    serve_cfg.max_depth = args.flag_usize("max-depth", 256)?;
    serve_cfg.max_attempts = args.flag_u64("max-attempts", 0)?;
    if let Some(spec) = args.flag("quota") {
        serve_cfg.quotas = mare::serve::parse_quotas(spec)?;
    }

    println!(
        "serving {} with {workers} workers (tick {:?}, max-depth {}, max-attempts {}{})",
        queue.dir().display(),
        serve_cfg.tick,
        serve_cfg.max_depth,
        serve_cfg.max_attempts,
        if serve_cfg.quotas.is_empty() {
            String::new()
        } else {
            format!(
                ", quotas {}",
                serve_cfg
                    .quotas
                    .iter()
                    .map(|(t, w)| format!("{t}={w}"))
                    .collect::<Vec<_>>()
                    .join(",")
            )
        }
    );
    println!("drain with: mare serve --drain --queue {}", queue.dir().display());

    let outcome = mare::serve::ServeDaemon::new(serve_cfg).run(&queue)?;
    println!(
        "drained after {} ticks ({} orphaned jobs requeued)",
        outcome.ticks, outcome.orphans_requeued
    );
    for report in &outcome.outcome.reports {
        println!("  {}", report.summary());
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let pr = args.flag_u64("pr", 10)?;
    let out = args
        .flag("out")
        .map(String::from)
        .unwrap_or_else(|| format!("BENCH_{pr}.json"));
    let filter = args.flag("filter").map(String::from);

    // bench-smoke greps this to assert the scalar fallback is what ran
    // under MARE_SCAN_FORCE_SCALAR=1
    println!("scan kernel: {}", mare::util::scan::active_kernel());

    let mut b = mare::util::bench::Bench::with_filter("micro_hotpath", filter);
    mare::perf::hotpath_cases(&mut b);

    println!();
    println!("{:<20} {:>14} {:>14} {:>9}", "comparison", "old median", "new median", "speedup");
    for c in mare::perf::comparisons(b.timings()) {
        println!(
            "{:<20} {:>11.0} ns {:>11.0} ns {:>8.2}x",
            c.name, c.old_median_ns, c.new_median_ns, c.speedup()
        );
    }
    println!();
    println!(
        "{:<28} {:>12} {:>11} {:>6} {:>10}",
        "speculation (simtime)", "makespan", "speculated", "wins", "cancelled"
    );
    for r in mare::perf::speculation_ledger()? {
        println!(
            "{:<28} {:>9.1} ms {:>11} {:>6} {:>10}",
            r.mode, r.makespan_ms, r.speculated, r.spec_wins, r.spec_cancelled
        );
    }

    mare::perf::write_bench_json(std::path::Path::new(&out), pr, b.timings())?;
    println!("\narchived {} timings -> {out}", b.timings().len());
    Ok(())
}

fn cmd_shell(args: &Args) -> Result<()> {
    use std::io::{BufRead, Write};
    let cfg = RunConfigFile::from_args(args)?;
    // runtime is optional: POSIX-only sessions work without artifacts
    let runtime_dir = std::path::Path::new(&cfg.artifacts)
        .join("manifest.json")
        .exists()
        .then_some(cfg.artifacts.as_str());
    let mut session = mare::repl::Session::with_config(cfg.cluster.clone(), runtime_dir)?;
    println!("mare interactive shell — `help` for commands, `quit` to leave");
    println!("{}", session.status());

    let stdin = std::io::stdin();
    let mut line = String::new();
    loop {
        print!("mare> ");
        std::io::stdout().flush().ok();
        line.clear();
        if stdin.lock().read_line(&mut line)? == 0 {
            break; // EOF
        }
        match session.eval(&line) {
            Ok(out) if out.is_empty() => {}
            Ok(out) => println!("{out}"),
            Err(e) if mare::repl::is_quit(&e) => break,
            Err(e) => println!("error: {e}"),
        }
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let dir = args.flag_or("artifacts", &mare::workloads::artifact_dir());
    println!("== artifacts ({dir}) ==");
    match mare::runtime::Manifest::load(std::path::Path::new(&dir)) {
        Ok(m) => {
            for (name, e) in &m.entries {
                let ins: Vec<String> =
                    e.inputs.iter().map(|t| format!("{}{:?}", t.dtype, t.shape)).collect();
                let outs: Vec<String> =
                    e.outputs.iter().map(|t| format!("{}{:?}", t.dtype, t.shape)).collect();
                println!(
                    "  {:<16} {} -> {}   ({})",
                    name,
                    ins.join(", "),
                    outs.join(", "),
                    e.file
                );
            }
        }
        Err(e) => println!("  (unavailable: {e})"),
    }
    println!("== stock images ==");
    let reg = mare::tools::images::stock_registry(None);
    for name in reg.names() {
        let img = reg.pull(name)?;
        let mut tools = img.tool_names();
        tools.truncate(8);
        println!(
            "  {:<36} {:>5} MiB, tools: {}, ...",
            img.name,
            img.size_bytes >> 20,
            tools.join(", ")
        );
    }
    println!("  mcapuccini/alignment:latest          (baked per-run with the reference genome)");
    Ok(())
}
