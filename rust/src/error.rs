//! Crate-wide error type.

use thiserror::Error;

/// Every fallible MaRe operation returns this.
#[derive(Error, Debug)]
pub enum MareError {
    /// Artifact loading / PJRT compilation / execution failures.
    #[error("runtime: {0}")]
    Runtime(String),

    /// Artifact ABI mismatch against artifacts/manifest.json.
    #[error("artifact ABI mismatch for `{entry}`: {detail}")]
    AbiMismatch { entry: String, detail: String },

    /// Container engine failures (unknown image, bad mount, tool error).
    #[error("container: {0}")]
    Container(String),

    /// Mini-shell parse / execution errors inside a container.
    #[error("shell: {0}")]
    Shell(String),

    /// Unknown tool in an image's tool table.
    #[error("tool `{0}` not found in image `{1}`")]
    ToolNotFound(String, String),

    /// Storage backend errors (missing object, capacity, bad range).
    #[error("storage: {0}")]
    Storage(String),

    /// Scheduler / cluster errors.
    #[error("cluster: {0}")]
    Cluster(String),

    /// Dataset / plan errors (empty lineage, bad partition count).
    #[error("dataset: {0}")]
    Dataset(String),

    /// Data-format parse errors (SDF / FASTQ / SAM / VCF).
    #[error("format {format}: {detail}")]
    Format { format: &'static str, detail: String },

    /// Configuration errors.
    #[error("config: {0}")]
    Config(String),

    #[error(transparent)]
    Io(#[from] std::io::Error),

    /// JSON parse / shape errors (util::json).
    #[error("json: {0}")]
    Json(String),
}

impl From<xla::Error> for MareError {
    fn from(e: xla::Error) -> Self {
        MareError::Runtime(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, MareError>;
