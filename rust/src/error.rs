//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (offline build environment — no
//! `thiserror`; see the note in Cargo.toml).

/// Every fallible MaRe operation returns this.
#[derive(Debug)]
pub enum MareError {
    /// Artifact loading / compilation / execution failures.
    Runtime(String),

    /// Artifact ABI mismatch against artifacts/manifest.json.
    AbiMismatch { entry: String, detail: String },

    /// Container engine failures (unknown image, bad mount, tool error).
    Container(String),

    /// Mini-shell parse / execution errors inside a container.
    Shell(String),

    /// Unknown tool in an image's tool table.
    ToolNotFound(String, String),

    /// Storage backend errors (missing object, capacity, bad range).
    Storage(String),

    /// Scheduler / cluster errors.
    Cluster(String),

    /// Dataset / plan errors (empty lineage, bad partition count).
    Dataset(String),

    /// Data-format parse errors (SDF / FASTQ / SAM / VCF).
    Format { format: &'static str, detail: String },

    /// Configuration errors.
    Config(String),

    /// Pipeline builder / optimizer validation errors.
    Pipeline(String),

    Io(std::io::Error),

    /// JSON parse / shape errors (util::json).
    Json(String),

    /// Wire-format encode/decode errors (mare::wire).
    Wire(crate::mare::wire::WireError),

    /// Job-submission / queue errors (submit).
    Submit(String),

    /// Admission refused: the spool is at the depth limit a resident
    /// `mare serve` daemon advertised in its control file. Retryable —
    /// the submitter should back off and resubmit, or the operator can
    /// raise `--max-depth`.
    Backpressure { queued: usize, held: usize, max_depth: usize },

    /// Checkpoint state could not be written or read back (corrupt
    /// frame, fingerprint clash, unwritable store). Execution falls
    /// back to a from-scratch run; losing a checkpoint never loses a
    /// job.
    Checkpoint(String),

    /// A fault-injected mid-run death (`--fault W:N:midrun@S`): the
    /// worker stopped after committing `stages_done` stage checkpoints
    /// and `launches` container launches. Carried as an error so the
    /// abort travels the normal failure path, with enough context for
    /// the worker's exactly-once accounting — the partial launches are
    /// real work a successor must NOT repeat.
    KilledMidRun { stages_done: usize, launches: u64 },
}

impl std::fmt::Display for MareError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MareError::Runtime(m) => write!(f, "runtime: {m}"),
            MareError::AbiMismatch { entry, detail } => {
                write!(f, "artifact ABI mismatch for `{entry}`: {detail}")
            }
            MareError::Container(m) => write!(f, "container: {m}"),
            MareError::Shell(m) => write!(f, "shell: {m}"),
            MareError::ToolNotFound(tool, image) => {
                write!(f, "tool `{tool}` not found in image `{image}`")
            }
            MareError::Storage(m) => write!(f, "storage: {m}"),
            MareError::Cluster(m) => write!(f, "cluster: {m}"),
            MareError::Dataset(m) => write!(f, "dataset: {m}"),
            MareError::Format { format, detail } => write!(f, "format {format}: {detail}"),
            MareError::Config(m) => write!(f, "config: {m}"),
            MareError::Pipeline(m) => write!(f, "pipeline: {m}"),
            MareError::Io(e) => write!(f, "{e}"),
            MareError::Json(m) => write!(f, "json: {m}"),
            MareError::Wire(e) => write!(f, "wire: {e}"),
            MareError::Submit(m) => write!(f, "submit: {m}"),
            MareError::Backpressure { queued, held, max_depth } => write!(
                f,
                "backpressure: spool depth {} (queued {queued} + held {held}) is at the \
                 service limit {max_depth}; retry later or raise --max-depth",
                queued + held
            ),
            MareError::Checkpoint(m) => write!(f, "checkpoint: {m}"),
            MareError::KilledMidRun { stages_done, launches } => write!(
                f,
                "killed mid-run after {stages_done} checkpointed stages ({launches} launches)"
            ),
        }
    }
}

impl std::error::Error for MareError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            MareError::Io(e) => Some(e),
            MareError::Wire(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for MareError {
    fn from(e: std::io::Error) -> Self {
        MareError::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, MareError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_prefixed_and_informative() {
        assert_eq!(MareError::Runtime("x".into()).to_string(), "runtime: x");
        assert_eq!(
            MareError::AbiMismatch { entry: "dock".into(), detail: "bad".into() }.to_string(),
            "artifact ABI mismatch for `dock`: bad"
        );
        assert_eq!(
            MareError::ToolNotFound("bash".into(), "ubuntu".into()).to_string(),
            "tool `bash` not found in image `ubuntu`"
        );
        assert_eq!(MareError::Pipeline("empty image".into()).to_string(), "pipeline: empty image");
        let bp = MareError::Backpressure { queued: 7, held: 1, max_depth: 8 };
        let text = bp.to_string();
        assert!(text.contains("backpressure"), "{text}");
        assert!(text.contains("depth 8"), "{text}");
        assert!(text.contains("limit 8"), "{text}");
    }

    #[test]
    fn io_errors_convert() {
        let e: MareError = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(matches!(e, MareError::Io(_)));
        assert!(std::error::Error::source(&e).is_some());
    }
}
