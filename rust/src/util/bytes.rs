//! Byte buffers: the zero-copy shared-buffer layer of the data plane,
//! plus byte-size formatting/parsing helpers shared by configs and
//! reports.
//!
//! [`Shared`] is an `Arc`-backed immutable buffer with O(1) slicing —
//! cloning one (or a [`Record`](crate::dataset::Record) holding one) is
//! a pointer bump, not a deep copy. [`SharedStr`] is a `Shared` whose
//! bytes are validated UTF-8 once at construction. Together they are
//! what lets a record payload travel ingest → task → mount → shuffle →
//! collect without being re-allocated at every boundary (see
//! docs/ARCHITECTURE.md "Data plane & buffer ownership").
//!
//! The module keeps a global **payload-copy counter**: every time bytes
//! are copied *out of an existing `Shared`* into a fresh owned
//! allocation ([`Shared::to_vec`], [`Shared::deep_clone`]), the counter
//! ticks. The engine's zero-copy invariant — a map-only happy path
//! performs zero payload deep-copies — is asserted against it in
//! `rust/tests/zero_copy.rs`. Creating a `Shared` from foreign bytes
//! (ingest, a tool's fresh output) is *creation*, not a copy, and does
//! not count; neither does materializing a mount file through
//! [`SegmentWriter`] (the file is a new artifact, not a duplicated
//! record payload).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

// ------------------------------------------------------------ counters

/// Global payload deep-copy counter (events, not bytes).
static PAYLOAD_COPIES: AtomicU64 = AtomicU64::new(0);

/// Number of payload deep-copy events since process start. Monotonic;
/// tests measure deltas around the code under test.
pub fn payload_copies() -> u64 {
    PAYLOAD_COPIES.load(Ordering::Relaxed)
}

/// Record one payload deep-copy event (bytes left a `Shared` into a new
/// owned allocation).
pub fn note_payload_copy() {
    PAYLOAD_COPIES.fetch_add(1, Ordering::Relaxed);
}

// -------------------------------------------------------------- Shared

/// An immutable, refcounted byte buffer with O(1) slicing.
///
/// `clone()` bumps a refcount; [`Shared::slice`] returns a view into
/// the same allocation. The only ways to duplicate the payload are
/// [`Shared::to_vec`] / [`Shared::deep_clone`], which tick the global
/// [`payload_copies`] counter.
#[derive(Clone)]
pub struct Shared {
    buf: Arc<[u8]>,
    off: usize,
    len: usize,
}

impl Shared {
    /// An empty buffer (no allocation shared with anything).
    pub fn empty() -> Shared {
        Shared { buf: Arc::from(Vec::new()), off: 0, len: 0 }
    }

    /// Take ownership of `v` (one move into the refcounted allocation;
    /// creation, not a counted copy).
    pub fn from_vec(v: Vec<u8>) -> Shared {
        let len = v.len();
        Shared { buf: Arc::from(v), off: 0, len }
    }

    /// Copy foreign bytes in (creation, not a counted copy — the source
    /// is not a `Shared`). One allocation + memcpy, straight into the
    /// refcounted buffer.
    pub fn copy_from_slice(b: &[u8]) -> Shared {
        Shared { buf: Arc::from(b), off: 0, len: b.len() }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The viewed bytes.
    pub fn as_slice(&self) -> &[u8] {
        &self.buf[self.off..self.off + self.len]
    }

    /// O(1) sub-view `[start, end)` of this buffer (same allocation).
    pub fn slice(&self, start: usize, end: usize) -> Shared {
        assert!(start <= end && end <= self.len, "slice {start}..{end} of {}", self.len);
        Shared { buf: self.buf.clone(), off: self.off + start, len: end - start }
    }

    /// Copy the viewed bytes into a fresh `Vec` (counted as a payload
    /// deep-copy).
    pub fn to_vec(&self) -> Vec<u8> {
        note_payload_copy();
        self.as_slice().to_vec()
    }

    /// A `Shared` over a fresh private allocation (counted) — the old
    /// owned-buffer behaviour, kept for before/after benchmarking.
    pub fn deep_clone(&self) -> Shared {
        Shared::from_vec(self.to_vec())
    }

    /// How many `Shared` views share this allocation (diagnostics).
    pub fn ref_count(&self) -> usize {
        Arc::strong_count(&self.buf)
    }
}

impl std::ops::Deref for Shared {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl AsRef<[u8]> for Shared {
    fn as_ref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl Default for Shared {
    fn default() -> Shared {
        Shared::empty()
    }
}

impl PartialEq for Shared {
    fn eq(&self, other: &Shared) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for Shared {}

impl PartialEq<[u8]> for Shared {
    fn eq(&self, other: &[u8]) -> bool {
        self.as_slice() == other
    }
}

impl PartialEq<&[u8]> for Shared {
    fn eq(&self, other: &&[u8]) -> bool {
        self.as_slice() == *other
    }
}

impl PartialEq<Vec<u8>> for Shared {
    fn eq(&self, other: &Vec<u8>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl std::hash::Hash for Shared {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        self.as_slice().hash(state);
    }
}

impl std::fmt::Debug for Shared {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Shared({} B)", self.len)
    }
}

impl From<Vec<u8>> for Shared {
    fn from(v: Vec<u8>) -> Shared {
        Shared::from_vec(v)
    }
}

impl From<&[u8]> for Shared {
    fn from(b: &[u8]) -> Shared {
        Shared::copy_from_slice(b)
    }
}

impl From<String> for Shared {
    fn from(s: String) -> Shared {
        Shared::from_vec(s.into_bytes())
    }
}

impl From<&str> for Shared {
    fn from(s: &str) -> Shared {
        Shared::copy_from_slice(s.as_bytes())
    }
}

impl From<SharedStr> for Shared {
    fn from(s: SharedStr) -> Shared {
        s.raw
    }
}

// ----------------------------------------------------------- SharedStr

/// A [`Shared`] buffer validated as UTF-8 once at construction.
///
/// Derefs to `str`, so call sites that held a `String` keep compiling;
/// clones and [`SharedStr::slice`] are O(1) views like `Shared`.
#[derive(Clone, Default, Eq)]
pub struct SharedStr {
    raw: Shared,
}

impl SharedStr {
    /// Take ownership of a `String` (no copy; UTF-8 by construction).
    pub fn from_string(s: String) -> SharedStr {
        SharedStr { raw: Shared::from_vec(s.into_bytes()) }
    }

    /// Validate `raw` as UTF-8 and wrap it (no copy on success).
    pub fn from_shared(raw: Shared) -> Result<SharedStr, std::str::Utf8Error> {
        std::str::from_utf8(raw.as_slice())?;
        Ok(SharedStr { raw })
    }

    pub fn as_str(&self) -> &str {
        // SAFETY: every constructor validates UTF-8 (`from_string` by
        // the `String` type, `from_shared` explicitly, `slice` by the
        // char-boundary assertions), and the buffer is immutable.
        unsafe { std::str::from_utf8_unchecked(self.raw.as_slice()) }
    }

    /// The underlying byte view.
    pub fn as_shared(&self) -> &Shared {
        &self.raw
    }

    pub fn len(&self) -> usize {
        self.raw.len()
    }

    pub fn is_empty(&self) -> bool {
        self.raw.is_empty()
    }

    /// O(1) sub-view `[start, end)`; both indices must lie on char
    /// boundaries.
    pub fn slice(&self, start: usize, end: usize) -> SharedStr {
        let s = self.as_str();
        assert!(
            s.is_char_boundary(start) && s.is_char_boundary(end),
            "slice {start}..{end} off char boundary"
        );
        SharedStr { raw: self.raw.slice(start, end) }
    }

    /// Copy out an owned `String` (counted as a payload deep-copy).
    pub fn to_owned_string(&self) -> String {
        note_payload_copy();
        self.as_str().to_string()
    }
}

impl std::ops::Deref for SharedStr {
    type Target = str;
    fn deref(&self) -> &str {
        self.as_str()
    }
}

impl AsRef<str> for SharedStr {
    fn as_ref(&self) -> &str {
        self.as_str()
    }
}

impl PartialEq for SharedStr {
    fn eq(&self, other: &SharedStr) -> bool {
        self.as_str() == other.as_str()
    }
}

impl PartialEq<str> for SharedStr {
    fn eq(&self, other: &str) -> bool {
        self.as_str() == other
    }
}

impl PartialEq<&str> for SharedStr {
    fn eq(&self, other: &&str) -> bool {
        self.as_str() == *other
    }
}

impl PartialEq<String> for SharedStr {
    fn eq(&self, other: &String) -> bool {
        self.as_str() == other.as_str()
    }
}

impl PartialOrd for SharedStr {
    fn partial_cmp(&self, other: &SharedStr) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for SharedStr {
    fn cmp(&self, other: &SharedStr) -> std::cmp::Ordering {
        self.as_str().cmp(other.as_str())
    }
}

impl std::hash::Hash for SharedStr {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        self.as_str().hash(state);
    }
}

impl std::fmt::Debug for SharedStr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        std::fmt::Debug::fmt(self.as_str(), f)
    }
}

impl std::fmt::Display for SharedStr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl From<String> for SharedStr {
    fn from(s: String) -> SharedStr {
        SharedStr::from_string(s)
    }
}

impl From<&str> for SharedStr {
    fn from(s: &str) -> SharedStr {
        SharedStr { raw: Shared::copy_from_slice(s.as_bytes()) }
    }
}

impl From<&String> for SharedStr {
    fn from(s: &String) -> SharedStr {
        SharedStr::from(s.as_str())
    }
}

// ------------------------------------------------------- SegmentWriter

/// Builds one contiguous buffer from many segments with a single
/// exact-capacity allocation — the mount materializer (a partition's
/// records joined by a separator into ONE container file) uses this
/// instead of the old `Vec<String>` + `join` + `into_bytes` triple
/// copy.
pub struct SegmentWriter {
    buf: Vec<u8>,
}

impl SegmentWriter {
    /// A writer pre-sized to `capacity` bytes (pass the exact final
    /// length to guarantee one allocation).
    pub fn with_capacity(capacity: usize) -> SegmentWriter {
        SegmentWriter { buf: Vec::with_capacity(capacity) }
    }

    pub fn push(&mut self, segment: &[u8]) {
        self.buf.extend_from_slice(segment);
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// The finished buffer as a `Shared` (handed to the container VFS
    /// without further copies).
    pub fn finish(self) -> Shared {
        Shared::from_vec(self.buf)
    }

    /// The finished buffer as owned bytes (stdin staging).
    pub fn into_vec(self) -> Vec<u8> {
        self.buf
    }
}

// ------------------------------------------------- size format helpers

/// Human-readable byte size ("1.50 GiB").
pub fn human(bytes: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Parse "512", "64KiB", "1.5 GiB", "2GB" (decimal suffixes are 1024-based
/// here; cluster configs don't care about the SI distinction).
pub fn parse(s: &str) -> Option<u64> {
    let s = s.trim();
    let split = s.find(|c: char| !(c.is_ascii_digit() || c == '.'))?;
    let (num, unit) = if split == 0 { return None } else { s.split_at(split) };
    let num: f64 = num.parse().ok()?;
    let mult: u64 = match unit.trim().to_ascii_lowercase().as_str() {
        "b" | "" => 1,
        "k" | "kb" | "kib" => 1 << 10,
        "m" | "mb" | "mib" => 1 << 20,
        "g" | "gb" | "gib" => 1 << 30,
        "t" | "tb" | "tib" => 1u64 << 40,
        _ => return None,
    };
    Some((num * mult as f64) as u64)
}

/// Parse with a pure-number fallback ("4096" -> 4096 bytes).
pub fn parse_or_number(s: &str) -> Option<u64> {
    s.trim().parse::<u64>().ok().or_else(|| parse(s))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats_round_numbers() {
        assert_eq!(human(0), "0 B");
        assert_eq!(human(512), "512 B");
        assert_eq!(human(1536), "1.50 KiB");
        assert_eq!(human(3 << 30), "3.00 GiB");
    }

    #[test]
    fn parses_suffixes() {
        assert_eq!(parse("64KiB"), Some(64 << 10));
        assert_eq!(parse("1.5 GiB"), Some((1.5 * (1u64 << 30) as f64) as u64));
        assert_eq!(parse("2GB"), Some(2 << 30));
        assert_eq!(parse_or_number("4096"), Some(4096));
        assert_eq!(parse("x"), None);
    }

    #[test]
    fn shared_slices_share_the_allocation() {
        let s = Shared::from_vec(b"hello world".to_vec());
        let hello = s.slice(0, 5);
        let world = s.slice(6, 11);
        assert_eq!(hello.as_slice(), b"hello");
        assert_eq!(world.as_slice(), b"world");
        // three views, one allocation
        assert_eq!(s.ref_count(), 3);
        // clones are views too
        let c = world.clone();
        assert_eq!(s.ref_count(), 4);
        assert_eq!(c, world);
    }

    #[test]
    fn clone_is_not_a_counted_copy_but_to_vec_is() {
        let s = Shared::from_vec(vec![7u8; 1024]);
        let _view = s.clone();
        let _sub = s.slice(0, 512);
        // other tests may bump the global counter concurrently, so only
        // assert our own contribution: to_vec adds at least one event
        let mid = payload_copies();
        let v = s.to_vec();
        assert_eq!(v.len(), 1024);
        assert!(payload_copies() >= mid + 1);
        let d = s.deep_clone();
        assert_eq!(d, s);
        assert_eq!(d.ref_count(), 1);
    }

    #[test]
    fn shared_str_validates_and_slices() {
        let s = SharedStr::from_string("héllo\nwörld".to_string());
        assert_eq!(s.as_str(), "héllo\nwörld");
        let first = s.slice(0, 6); // "héllo" is 6 bytes
        assert_eq!(first.as_str(), "héllo");
        assert_eq!(first, "héllo");
        // invalid UTF-8 rejected without copying
        assert!(SharedStr::from_shared(Shared::from_vec(vec![0xff, 0xfe])).is_err());
        // valid round-trips
        let ok = SharedStr::from_shared(Shared::from_vec(b"ok".to_vec())).unwrap();
        assert_eq!(ok.as_str(), "ok");
    }

    #[test]
    #[should_panic(expected = "char boundary")]
    fn shared_str_slice_enforces_boundaries() {
        let s = SharedStr::from_string("é".to_string());
        let _ = s.slice(0, 1); // mid-codepoint
    }

    #[test]
    fn segment_writer_concatenates_exactly() {
        let mut w = SegmentWriter::with_capacity(10);
        w.push(b"ab");
        w.push(b"");
        w.push(b"cde");
        assert_eq!(w.len(), 5);
        assert_eq!(w.finish().as_slice(), b"abcde");
    }
}
