//! Byte-size formatting/parsing helpers shared by configs and reports.

/// Human-readable byte size ("1.50 GiB").
pub fn human(bytes: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Parse "512", "64KiB", "1.5 GiB", "2GB" (decimal suffixes are 1024-based
/// here; cluster configs don't care about the SI distinction).
pub fn parse(s: &str) -> Option<u64> {
    let s = s.trim();
    let split = s.find(|c: char| !(c.is_ascii_digit() || c == '.'))?;
    let (num, unit) = if split == 0 { return None } else { s.split_at(split) };
    let num: f64 = num.parse().ok()?;
    let mult: u64 = match unit.trim().to_ascii_lowercase().as_str() {
        "b" | "" => 1,
        "k" | "kb" | "kib" => 1 << 10,
        "m" | "mb" | "mib" => 1 << 20,
        "g" | "gb" | "gib" => 1 << 30,
        "t" | "tb" | "tib" => 1u64 << 40,
        _ => return None,
    };
    Some((num * mult as f64) as u64)
}

/// Parse with a pure-number fallback ("4096" -> 4096 bytes).
pub fn parse_or_number(s: &str) -> Option<u64> {
    s.trim().parse::<u64>().ok().or_else(|| parse(s))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats_round_numbers() {
        assert_eq!(human(0), "0 B");
        assert_eq!(human(512), "512 B");
        assert_eq!(human(1536), "1.50 KiB");
        assert_eq!(human(3 << 30), "3.00 GiB");
    }

    #[test]
    fn parses_suffixes() {
        assert_eq!(parse("64KiB"), Some(64 << 10));
        assert_eq!(parse("1.5 GiB"), Some((1.5 * (1u64 << 30) as f64) as u64));
        assert_eq!(parse("2GB"), Some(2 << 30));
        assert_eq!(parse_or_number("4096"), Some(4096));
        assert_eq!(parse("x"), None);
    }
}
