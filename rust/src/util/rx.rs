//! Mini regular-expression engine (offline substitute for the `regex`
//! crate — see the note in Cargo.toml).
//!
//! Supports exactly the POSIX-ish subset the paper's `grep` commands
//! use, with margin:
//!
//! * literals, `.`
//! * character classes `[GC]`, ranges `[a-z0-9]`, negation `[^x]`
//! * escapes `\d \w \s \D \W \S` and escaped metacharacters (`\.`)
//! * anchors `^` / `$`
//! * greedy quantifiers `*` `+` `?` on the previous atom
//! * groups `(ab|cd)` with alternation
//!
//! Backtracking matcher over `char`s; leftmost-first, greedy — the grep
//! semantics the listings rely on (`grep -o '[GC]'`).

/// A compiled pattern.
#[derive(Debug, Clone)]
pub struct Rx {
    nodes: Vec<Node>,
}

#[derive(Debug, Clone)]
struct Node {
    atom: Atom,
    quant: Quant,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Quant {
    One,
    Opt,
    Star,
    Plus,
}

#[derive(Debug, Clone)]
enum Atom {
    Char(char),
    Any,
    Class { neg: bool, items: Vec<ClassItem> },
    /// Alternation of sequences: `(ab|cd)`.
    Group(Vec<Vec<Node>>),
    Start,
    End,
}

#[derive(Debug, Clone)]
enum ClassItem {
    Char(char),
    Range(char, char),
    Digit(bool),
    Word(bool),
    Space(bool),
}

impl ClassItem {
    fn matches(&self, c: char) -> bool {
        match *self {
            ClassItem::Char(x) => c == x,
            ClassItem::Range(a, b) => a <= c && c <= b,
            ClassItem::Digit(pos) => c.is_ascii_digit() == pos,
            ClassItem::Word(pos) => (c.is_alphanumeric() || c == '_') == pos,
            ClassItem::Space(pos) => c.is_whitespace() == pos,
        }
    }
}

impl Rx {
    /// Compile a pattern; errors describe the offending construct.
    pub fn new(pattern: &str) -> Result<Rx, String> {
        let chars: Vec<char> = pattern.chars().collect();
        let (alts, consumed) = parse_alternation(&chars, 0)?;
        if consumed != chars.len() {
            return Err(format!("unbalanced `)` at offset {consumed} in `{pattern}`"));
        }
        let nodes = if alts.len() == 1 {
            alts.into_iter().next().unwrap()
        } else {
            vec![Node { atom: Atom::Group(alts), quant: Quant::One }]
        };
        Ok(Rx { nodes })
    }

    /// Whether the pattern matches anywhere in `hay`.
    pub fn is_match(&self, hay: &str) -> bool {
        self.find(hay).is_some()
    }

    /// Leftmost match as (start, end) byte-free char offsets resolved to
    /// the matched substring.
    pub fn find<'h>(&self, hay: &'h str) -> Option<&'h str> {
        let chars: Vec<char> = hay.chars().collect();
        for start in 0..=chars.len() {
            if let Some(end) = match_seq(&self.nodes, &chars, start) {
                return Some(slice_of(hay, start, end));
            }
        }
        None
    }

    /// All non-overlapping leftmost matches (like `regex::find_iter`).
    /// Empty matches advance by one char so iteration always terminates.
    pub fn find_all<'h>(&self, hay: &'h str) -> Vec<&'h str> {
        let chars: Vec<char> = hay.chars().collect();
        let mut out = Vec::new();
        let mut start = 0usize;
        while start <= chars.len() {
            match match_seq(&self.nodes, &chars, start) {
                Some(end) => {
                    out.push(slice_of(hay, start, end));
                    start = if end > start { end } else { start + 1 };
                }
                None => start += 1,
            }
        }
        // drop empty matches: grep -o never prints them
        out.retain(|m| !m.is_empty());
        out
    }
}

/// Char-offset substring (patterns and hay are small; O(n) is fine).
fn slice_of(hay: &str, start: usize, end: usize) -> &str {
    let mut it = hay.char_indices().map(|(i, _)| i).chain(std::iter::once(hay.len()));
    let b0 = it.by_ref().nth(start).unwrap_or(hay.len());
    let b1 = if end > start {
        hay[b0..]
            .char_indices()
            .map(|(i, _)| b0 + i)
            .chain(std::iter::once(hay.len()))
            .nth(end - start)
            .unwrap_or(hay.len())
    } else {
        b0
    };
    &hay[b0..b1]
}

// ------------------------------------------------------------- parser

type ParseResult<T> = Result<T, String>;

/// Parse alternatives until `)` or end-of-pattern; returns (alts, next).
fn parse_alternation(chars: &[char], mut i: usize) -> ParseResult<(Vec<Vec<Node>>, usize)> {
    let mut alts: Vec<Vec<Node>> = Vec::new();
    let mut seq: Vec<Node> = Vec::new();
    while i < chars.len() {
        match chars[i] {
            ')' => break,
            '|' => {
                alts.push(std::mem::take(&mut seq));
                i += 1;
            }
            '*' | '+' | '?' => {
                let q = match chars[i] {
                    '*' => Quant::Star,
                    '+' => Quant::Plus,
                    _ => Quant::Opt,
                };
                let last = seq
                    .last_mut()
                    .ok_or_else(|| format!("quantifier `{}` with nothing to repeat", chars[i]))?;
                if last.quant != Quant::One {
                    return Err("stacked quantifiers are not supported".into());
                }
                if matches!(last.atom, Atom::Start | Atom::End) {
                    return Err("cannot quantify an anchor".into());
                }
                last.quant = q;
                i += 1;
            }
            '(' => {
                let (inner, next) = parse_alternation(chars, i + 1)?;
                if next >= chars.len() || chars[next] != ')' {
                    return Err("unbalanced `(`".into());
                }
                seq.push(Node { atom: Atom::Group(inner), quant: Quant::One });
                i = next + 1;
            }
            '[' => {
                let (class, next) = parse_class(chars, i + 1)?;
                seq.push(Node { atom: class, quant: Quant::One });
                i = next;
            }
            '.' => {
                seq.push(Node { atom: Atom::Any, quant: Quant::One });
                i += 1;
            }
            '^' => {
                seq.push(Node { atom: Atom::Start, quant: Quant::One });
                i += 1;
            }
            '$' => {
                seq.push(Node { atom: Atom::End, quant: Quant::One });
                i += 1;
            }
            '\\' => {
                let c = *chars.get(i + 1).ok_or("trailing backslash")?;
                seq.push(Node { atom: escape_atom(c), quant: Quant::One });
                i += 2;
            }
            c => {
                seq.push(Node { atom: Atom::Char(c), quant: Quant::One });
                i += 1;
            }
        }
    }
    alts.push(seq);
    Ok((alts, i))
}

fn escape_atom(c: char) -> Atom {
    let item = match c {
        'd' => Some(ClassItem::Digit(true)),
        'D' => Some(ClassItem::Digit(false)),
        'w' => Some(ClassItem::Word(true)),
        'W' => Some(ClassItem::Word(false)),
        's' => Some(ClassItem::Space(true)),
        'S' => Some(ClassItem::Space(false)),
        'n' => return Atom::Char('\n'),
        't' => return Atom::Char('\t'),
        _ => None,
    };
    match item {
        Some(it) => Atom::Class { neg: false, items: vec![it] },
        None => Atom::Char(c),
    }
}

/// Parse a `[...]` body starting after `[`; returns (atom, index past `]`).
fn parse_class(chars: &[char], mut i: usize) -> ParseResult<(Atom, usize)> {
    let mut items = Vec::new();
    let neg = chars.get(i) == Some(&'^');
    if neg {
        i += 1;
    }
    let mut first = true;
    while i < chars.len() {
        let c = chars[i];
        if c == ']' && !first {
            return Ok((Atom::Class { neg, items }, i + 1));
        }
        first = false;
        if c == '\\' {
            let e = *chars.get(i + 1).ok_or("trailing backslash in class")?;
            match escape_atom(e) {
                Atom::Char(lit) => items.push(ClassItem::Char(lit)),
                Atom::Class { items: mut sub, .. } => items.append(&mut sub),
                _ => unreachable!("escape_atom yields Char or Class"),
            }
            i += 2;
            continue;
        }
        // range `a-z` (a `-` at the edge is a literal)
        if chars.get(i + 1) == Some(&'-') && chars.get(i + 2).map(|&c| c != ']').unwrap_or(false)
        {
            items.push(ClassItem::Range(c, chars[i + 2]));
            i += 3;
        } else {
            items.push(ClassItem::Char(c));
            i += 1;
        }
    }
    Err("unbalanced `[`".into())
}

// ------------------------------------------------------------ matcher
//
// The engine is end-set based: every construct reports ALL positions it
// can stop at (greedy-first, deduped), so quantifiers and groups
// backtrack through each other — `(ab|a)+b` retries the shorter
// alternative, `(ab*)b` gives back a `b` from inside the group.

/// Match `nodes` at `pos`; returns the (greedy) end of the first match.
fn match_seq(nodes: &[Node], hay: &[char], pos: usize) -> Option<usize> {
    seq_ends(nodes, hay, pos).into_iter().next()
}

/// All end positions `nodes` can reach from `pos`, greedy-first.
fn seq_ends(nodes: &[Node], hay: &[char], pos: usize) -> Vec<usize> {
    let Some((node, rest)) = nodes.split_first() else {
        return vec![pos];
    };
    let mut out = Vec::new();
    match node.quant {
        Quant::One => {
            for end in atom_ends(&node.atom, hay, pos) {
                merge(&mut out, seq_ends(rest, hay, end));
            }
        }
        Quant::Opt => {
            for end in atom_ends(&node.atom, hay, pos) {
                merge(&mut out, seq_ends(rest, hay, end));
            }
            merge(&mut out, seq_ends(rest, hay, pos));
        }
        Quant::Star => repeat_ends(&node.atom, 0, rest, hay, pos, &mut out),
        Quant::Plus => repeat_ends(&node.atom, 1, rest, hay, pos, &mut out),
    }
    out
}

fn merge(out: &mut Vec<usize>, ends: Vec<usize>) {
    for e in ends {
        if !out.contains(&e) {
            out.push(e);
        }
    }
}

/// Ends reachable by >= `min` repetitions of `atom` followed by `rest`.
/// More repetitions are tried before fewer (greedy); every step must
/// strictly advance, so recursion depth is bounded by the hay length.
fn repeat_ends(
    atom: &Atom,
    min: usize,
    rest: &[Node],
    hay: &[char],
    pos: usize,
    out: &mut Vec<usize>,
) {
    for end in atom_ends(atom, hay, pos) {
        if end > pos {
            repeat_ends(atom, min.saturating_sub(1), rest, hay, end, out);
        }
    }
    if min == 0 {
        merge(out, seq_ends(rest, hay, pos));
    }
}

/// All end positions `atom` can reach from `pos` (greedy order).
fn atom_ends(atom: &Atom, hay: &[char], pos: usize) -> Vec<usize> {
    match atom {
        Atom::Char(c) => {
            if hay.get(pos) == Some(c) {
                vec![pos + 1]
            } else {
                vec![]
            }
        }
        Atom::Any => {
            if pos < hay.len() {
                vec![pos + 1]
            } else {
                vec![]
            }
        }
        Atom::Class { neg, items } => match hay.get(pos) {
            Some(&c) if items.iter().any(|it| it.matches(c)) != *neg => vec![pos + 1],
            _ => vec![],
        },
        Atom::Group(alts) => {
            let mut out = Vec::new();
            for alt in alts {
                merge(&mut out, seq_ends(alt, hay, pos));
            }
            out
        }
        Atom::Start => {
            if pos == 0 {
                vec![pos]
            } else {
                vec![]
            }
        }
        Atom::End => {
            if pos == hay.len() {
                vec![pos]
            } else {
                vec![]
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn listing1_class_matches_gc_bases() {
        let rx = Rx::new("[GC]").unwrap();
        assert_eq!(rx.find_all("GATTACA"), vec!["G", "C"]);
        assert_eq!(rx.find_all("GCGC").len(), 4);
        assert!(!rx.is_match("ATTA"));
    }

    #[test]
    fn literals_and_any() {
        let rx = Rx::new("a.c").unwrap();
        assert!(rx.is_match("xabcx"));
        assert!(!rx.is_match("ac"));
        assert_eq!(Rx::new("G").unwrap().find_all("GG"), vec!["G", "G"]);
    }

    #[test]
    fn ranges_and_negation() {
        let rx = Rx::new("[a-c1-3]").unwrap();
        assert_eq!(rx.find_all("zb2x"), vec!["b", "2"]);
        let neg = Rx::new("[^0-9]").unwrap();
        assert_eq!(neg.find_all("a1b"), vec!["a", "b"]);
    }

    #[test]
    fn quantifiers_are_greedy() {
        let rx = Rx::new("ab+").unwrap();
        assert_eq!(rx.find("xabbbc"), Some("abbb"));
        let star = Rx::new("ab*c").unwrap();
        assert!(star.is_match("ac"));
        assert!(star.is_match("abbc"));
        let opt = Rx::new("colou?r").unwrap();
        assert!(opt.is_match("color") && opt.is_match("colour"));
    }

    #[test]
    fn anchors() {
        let rx = Rx::new("^chr[0-9]+$").unwrap();
        assert!(rx.is_match("chr12"));
        assert!(!rx.is_match("xchr12"));
        assert!(!rx.is_match("chr12x"));
    }

    #[test]
    fn groups_and_alternation() {
        let rx = Rx::new("(foo|ba[rz])").unwrap();
        assert!(rx.is_match("xxfoo"));
        assert!(rx.is_match("barx"));
        assert!(rx.is_match("baz"));
        assert!(!rx.is_match("bax"));
    }

    #[test]
    fn quantified_groups_backtrack_across_alternatives() {
        // the greedy branch (ab) must be retried as (a) so the trailing
        // `b` can match — real grep semantics
        let rx = Rx::new("(ab|a)+b").unwrap();
        assert!(rx.is_match("ab"));
        assert!(rx.is_match("aab"));
        assert!(rx.is_match("abab"));
        assert!(!rx.is_match("a"));
        let star = Rx::new("(ab|a)*b").unwrap();
        assert!(star.is_match("b"));
        assert!(star.is_match("ab"));
    }

    #[test]
    fn quantifiers_inside_groups_give_back_characters() {
        // b* inside the group must release one `b` for the tail
        let rx = Rx::new("(ab*)b").unwrap();
        assert!(rx.is_match("abb"));
        assert!(rx.is_match("ab"));
        assert!(!rx.is_match("a"));
        assert_eq!(rx.find("xabbbz"), Some("abbb"));
        // nested: group-with-plus under a plus
        let nested = Rx::new("(a+b)+c").unwrap();
        assert!(nested.is_match("abaabc"));
        assert!(!nested.is_match("aab"));
    }

    #[test]
    fn escapes() {
        let rx = Rx::new(r"\d+\.\d+").unwrap();
        assert_eq!(rx.find("v1.25 "), Some("1.25"));
        assert!(Rx::new(r"\w+").unwrap().is_match("x_1"));
        assert!(Rx::new(r"\s").unwrap().is_match("a b"));
    }

    #[test]
    fn bad_patterns_error() {
        assert!(Rx::new("[GC").is_err());
        assert!(Rx::new("(ab").is_err());
        assert!(Rx::new("*x").is_err());
        assert!(Rx::new("ab)").is_err());
    }

    #[test]
    fn unicode_safe_slicing() {
        let rx = Rx::new("é").unwrap();
        assert_eq!(rx.find_all("café é"), vec!["é", "é"]);
    }
}
