//! Micro-bench harness driving `rust/benches/*` (offline substitute for
//! criterion; the Cargo.toml bench targets use `harness = false`).
//!
//! Benches do two things here:
//! 1. timing loops with warmup + robust statistics (`Bench::time`), and
//! 2. paper-figure regeneration tables (`Table`), which print the same
//!    rows/series the paper reports and are archived as JSON under
//!    `target/mare-bench/` so EXPERIMENTS.md can reference exact runs.

use std::time::{Duration, Instant};

use crate::util::json::Json;

/// One timing sample set with robust stats.
#[derive(Debug, Clone)]
pub struct Timing {
    pub name: String,
    pub iters: u32,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Timing {
    pub fn throughput(&self, per_iter_items: f64) -> f64 {
        per_iter_items / self.median.as_secs_f64()
    }
}

/// Bench context: filters from argv (substring match like criterion).
pub struct Bench {
    filter: Option<String>,
    timings: Vec<Timing>,
    name: String,
    /// Per-case measurement budget; `None` falls back to the
    /// `MARE_BENCH_MS` env var (read, never written) or 800 ms.
    budget_ms: Option<u64>,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        let filter = std::env::args().skip(1).find(|a| !a.starts_with('-'));
        Bench::with_filter(name, filter)
    }

    /// A bench with an explicit substring filter (the `mare bench` CLI
    /// drives the same cases without going through argv).
    pub fn with_filter(name: &str, filter: Option<String>) -> Self {
        println!("== bench: {name} ==");
        Bench { filter, timings: Vec::new(), name: name.to_string(), budget_ms: None }
    }

    /// Pin the per-case measurement budget explicitly (tests use this
    /// instead of mutating the process environment, which is racy in
    /// the parallel test binary).
    pub fn budget_ms(mut self, ms: u64) -> Self {
        self.budget_ms = Some(ms);
        self
    }

    /// All timings recorded so far (aggregation, e.g. `mare bench`).
    pub fn timings(&self) -> &[Timing] {
        &self.timings
    }

    fn enabled(&self, case: &str) -> bool {
        self.filter.as_ref().map(|f| case.contains(f.as_str())).unwrap_or(true)
    }

    /// Time `f` with warmup; target ~`budget` of total measurement.
    pub fn time<F: FnMut()>(&mut self, case: &str, mut f: F) -> Option<Timing> {
        if !self.enabled(case) {
            return None;
        }
        // Warmup + calibration: find iters that fit the budget.
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().max(Duration::from_nanos(100));
        let budget = Duration::from_millis(self.budget_ms.unwrap_or_else(|| {
            std::env::var("MARE_BENCH_MS").ok().and_then(|v| v.parse().ok()).unwrap_or(800)
        }));
        let iters = (budget.as_nanos() / once.as_nanos()).clamp(5, 1000) as u32;

        let mut samples = Vec::with_capacity(iters as usize);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed());
        }
        samples.sort();
        let median = samples[samples.len() / 2];
        let mean = samples.iter().sum::<Duration>() / iters;
        let timing = Timing {
            name: case.to_string(),
            iters,
            mean,
            median,
            min: samples[0],
            max: *samples.last().unwrap(),
        };
        println!(
            "  {case:<44} median {:>10.3?}  mean {:>10.3?}  ({iters} iters)",
            timing.median, timing.mean
        );
        self.timings.push(timing.clone());
        Some(timing)
    }

    /// Persist all timings under target/mare-bench/<bench>.json.
    pub fn finish(&self) {
        let dir = std::path::Path::new("target/mare-bench");
        let _ = std::fs::create_dir_all(dir);
        let entries: Vec<Json> = self
            .timings
            .iter()
            .map(|t| {
                Json::obj(vec![
                    ("name", Json::str(t.name.clone())),
                    ("iters", Json::num(t.iters as f64)),
                    ("median_ns", Json::num(t.median.as_nanos() as f64)),
                    ("mean_ns", Json::num(t.mean.as_nanos() as f64)),
                    ("min_ns", Json::num(t.min.as_nanos() as f64)),
                    ("max_ns", Json::num(t.max.as_nanos() as f64)),
                ])
            })
            .collect();
        let _ = std::fs::write(
            dir.join(format!("{}.json", self.name)),
            Json::obj(vec![("bench", Json::str(self.name.clone())), ("timings", Json::Arr(entries))])
                .to_string_pretty(),
        );
    }
}

/// Paper-style results table (printed + archived as JSON).
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n-- {} --", self.title);
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("  {:<w$}", c, w = widths[i]));
            }
            println!("{s}");
        };
        line(&self.headers);
        line(&widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>());
        for row in &self.rows {
            line(row);
        }
    }

    /// Archive under target/mare-bench/<slug>.table.json.
    pub fn save(&self, slug: &str) {
        let dir = std::path::Path::new("target/mare-bench");
        let _ = std::fs::create_dir_all(dir);
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| Json::Arr(r.iter().map(|c| Json::str(c.clone())).collect()))
            .collect();
        let j = Json::obj(vec![
            ("title", Json::str(self.title.clone())),
            ("headers", Json::Arr(self.headers.iter().map(|h| Json::str(h.clone())).collect())),
            ("rows", Json::Arr(rows)),
        ]);
        let _ = std::fs::write(dir.join(format!("{slug}.table.json")), j.to_string_pretty());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_shape_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_arity() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
