//! Mini property-testing loop (offline substitute for proptest).
//!
//! `check(name, cases, |rng| ...)` runs the property against `cases`
//! deterministically-seeded random inputs. On failure it re-runs the same
//! case to confirm, then panics with the reproducing seed so the case can
//! be pinned: `check_seed(name, seed, f)`.

use crate::util::rng::Rng;

/// Outcome of one property case.
pub type PropResult = Result<(), String>;

/// Run `cases` random cases of property `f`.
pub fn check<F: Fn(&mut Rng) -> PropResult>(name: &str, cases: u64, f: F) {
    let base = fixed_base_seed(name);
    for i in 0..cases {
        let seed = base.wrapping_add(i.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property `{name}` failed on case {i}/{cases}\n  seed: {seed:#x}\n  {msg}\n\
                 reproduce with: check_seed(\"{name}\", {seed:#x}, ...)"
            );
        }
    }
}

/// Re-run a single failing seed.
pub fn check_seed<F: Fn(&mut Rng) -> PropResult>(name: &str, seed: u64, f: F) {
    let mut rng = Rng::new(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("property `{name}` failed at pinned seed {seed:#x}: {msg}");
    }
}

/// Seeds are derived from the property name so adding properties does not
/// reshuffle others' cases; `MARE_PROP_SEED` overrides for exploration.
fn fixed_base_seed(name: &str) -> u64 {
    if let Ok(s) = std::env::var("MARE_PROP_SEED") {
        if let Ok(v) = s.parse::<u64>() {
            return v;
        }
    }
    // FNV-1a over the name.
    let mut h = 0xcbf29ce484222325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 50, |rng| {
            let a = rng.below(1000) as i64;
            let b = rng.below(1000) as i64;
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn failing_property_reports_seed() {
        check("always-fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn seeds_stable_per_name() {
        assert_eq!(fixed_base_seed("x"), fixed_base_seed("x"));
        assert_ne!(fixed_base_seed("x"), fixed_base_seed("y"));
    }
}
