//! Deterministic RNG (SplitMix64) + the distributions the generators and
//! simulators need. Offline substitute for the `rand` crate; determinism
//! is load-bearing: every benchmark and synthetic dataset is seed-stable.

/// SplitMix64 — tiny, fast, passes BigCrush for our purposes.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Derive an independent stream (for per-partition / per-worker rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n). Unbiased enough for simulation use.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f32().max(1e-9);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Exponential with the given rate (for Poisson-ish event gaps).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-12).ln() / rate
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            items.swap(i, self.below(i + 1));
        }
    }

    /// True with probability p.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(123);
        let mut b = Rng::new(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forked_streams_diverge() {
        let mut root = Rng::new(5);
        let mut x = root.fork(1);
        let mut y = root.fork(2);
        let xs: Vec<u64> = (0..8).map(|_| x.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| y.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let f = r.f32();
            assert!((0.0..1.0).contains(&f));
            let n = r.range(3, 10);
            assert!((3..10).contains(&n));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(42);
        let n = 50_000;
        let samples: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = samples.iter().sum::<f32>() / n as f32;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
