//! Byte-exact LZ77-style codec (offline substitute for `flate2` — see
//! the note in Cargo.toml).
//!
//! The simulated `gzip`/`gunzip`/`zcat` tools and the compressed-FASTQ
//! ingestion path only need a deterministic, self-inverse codec whose
//! output is smaller than its input for the repetitive text the
//! workloads produce (genomes, FASTQ, VCF); nothing outside the
//! simulation ever reads the bytes, so the container format is ours:
//!
//! ```text
//! magic "MGZ1" | u64-le original length | tokens...
//! token 0x00..=0x7F: literal run of (byte+1) bytes following
//! token 0x80..=0xFF: match, len = (byte & 0x7f) + 3, then u16-le distance
//! ```

use crate::error::{MareError, Result};

const MAGIC: &[u8; 4] = b"MGZ1";
const MIN_MATCH: usize = 3;
const MAX_MATCH: usize = 127 + MIN_MATCH;
const MAX_DIST: usize = u16::MAX as usize;
const HASH_BITS: u32 = 15;

fn hash3(data: &[u8], i: usize) -> usize {
    let v = (data[i] as u32) | ((data[i + 1] as u32) << 8) | ((data[i + 2] as u32) << 16);
    (v.wrapping_mul(0x9E3779B1) >> (32 - HASH_BITS)) as usize
}

/// Compress `data`; always succeeds, output is self-describing.
pub fn compress(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() / 2 + 16);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(data.len() as u64).to_le_bytes());

    let mut table = vec![usize::MAX; 1 << HASH_BITS];
    let mut lit_start = 0usize;
    let mut i = 0usize;

    let flush_literals = |out: &mut Vec<u8>, from: usize, to: usize| {
        let mut s = from;
        while s < to {
            let n = (to - s).min(128);
            out.push((n - 1) as u8);
            out.extend_from_slice(&data[s..s + n]);
            s += n;
        }
    };

    while i < data.len() {
        let mut emitted = false;
        if i + MIN_MATCH <= data.len() {
            let h = hash3(data, i);
            let cand = table[h];
            table[h] = i;
            if cand != usize::MAX && i - cand <= MAX_DIST {
                let limit = (data.len() - i).min(MAX_MATCH);
                let mut len = 0usize;
                while len < limit && data[cand + len] == data[i + len] {
                    len += 1;
                }
                if len >= MIN_MATCH {
                    flush_literals(&mut out, lit_start, i);
                    out.push(0x80 | (len - MIN_MATCH) as u8);
                    out.extend_from_slice(&((i - cand) as u16).to_le_bytes());
                    i += len;
                    lit_start = i;
                    emitted = true;
                }
            }
        }
        if !emitted {
            i += 1;
        }
    }
    flush_literals(&mut out, lit_start, data.len());
    out
}

/// Decompress a [`compress`] blob; errors on bad magic or truncation.
pub fn decompress(data: &[u8]) -> Result<Vec<u8>> {
    if data.len() < 12 || &data[..4] != MAGIC {
        return Err(MareError::Shell("gunzip: not in mare-gzip format".into()));
    }
    let want = u64::from_le_bytes(data[4..12].try_into().unwrap()) as usize;
    // The header length is untrusted: cap the reservation by the codec's
    // real expansion bound (a 3-byte match token emits <= MAX_MATCH
    // bytes) and let the final length check reject lying headers —
    // reserving u64::MAX would abort instead of erroring.
    let bound = data.len().saturating_mul(MAX_MATCH / MIN_MATCH + 1);
    let mut out = Vec::with_capacity(want.min(bound));
    let mut i = 12usize;
    while i < data.len() {
        let tok = data[i];
        i += 1;
        if tok < 0x80 {
            let n = tok as usize + 1;
            if i + n > data.len() {
                return Err(MareError::Shell("gunzip: truncated literal run".into()));
            }
            out.extend_from_slice(&data[i..i + n]);
            i += n;
        } else {
            let len = (tok & 0x7F) as usize + MIN_MATCH;
            if i + 2 > data.len() {
                return Err(MareError::Shell("gunzip: truncated match token".into()));
            }
            let dist = u16::from_le_bytes([data[i], data[i + 1]]) as usize;
            i += 2;
            if dist == 0 || dist > out.len() {
                return Err(MareError::Shell("gunzip: match distance out of range".into()));
            }
            // byte-by-byte: overlapping copies (dist < len) are the
            // RLE-ish case and must see freshly written bytes
            let start = out.len() - dist;
            for k in 0..len {
                let b = out[start + k];
                out.push(b);
            }
        }
    }
    if out.len() != want {
        return Err(MareError::Shell(format!(
            "gunzip: corrupt stream ({} bytes, header says {want})",
            out.len()
        )));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_text() {
        let doc = "the quick brown fox jumps over the lazy dog\n".repeat(100);
        let c = compress(doc.as_bytes());
        assert!(c.len() < doc.len(), "{} !< {}", c.len(), doc.len());
        assert_eq!(decompress(&c).unwrap(), doc.as_bytes());
    }

    #[test]
    fn roundtrip_empty_and_tiny() {
        for case in [&b""[..], b"a", b"ab", b"abc"] {
            assert_eq!(decompress(&compress(case)).unwrap(), case);
        }
    }

    #[test]
    fn roundtrip_random_bytes() {
        let mut rng = Rng::new(7);
        let data: Vec<u8> = (0..10_000).map(|_| rng.below(256) as u8).collect();
        assert_eq!(decompress(&compress(&data)).unwrap(), data);
    }

    #[test]
    fn roundtrip_overlapping_runs() {
        // dist < len exercises the overlapping-copy path
        let data = vec![b'G'; 5000];
        let c = compress(&data);
        assert!(c.len() < 200, "run-length case should crush: {}", c.len());
        assert_eq!(decompress(&c).unwrap(), data);
    }

    #[test]
    fn genome_like_text_compresses() {
        let genome = crate::workloads::gc::genome_text(3, 200, 80);
        let c = compress(genome.as_bytes());
        assert!(c.len() < genome.len());
        assert_eq!(decompress(&c).unwrap(), genome.as_bytes());
    }

    #[test]
    fn rejects_garbage() {
        assert!(decompress(b"not compressed").is_err());
        assert!(decompress(b"").is_err());
        let mut c = compress(b"hello world hello world hello");
        c.truncate(c.len() - 1);
        assert!(decompress(&c).is_err());
    }

    #[test]
    fn lying_length_header_errors_instead_of_aborting() {
        // huge claimed length must not drive Vec::with_capacity
        let mut c = compress(b"abc");
        c[4..12].copy_from_slice(&u64::MAX.to_le_bytes());
        let err = decompress(&c).unwrap_err().to_string();
        assert!(err.contains("corrupt"), "{err}");
    }
}
