//! Minimal JSON parser/serializer (offline substitute for serde_json).
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes
//! incl. \uXXXX, numbers, bools, null). Object key order is preserved via
//! an association list so round-trips are stable and diffs readable.

use std::collections::BTreeMap;
use std::fmt;

use crate::error::{MareError, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    // ---------------------------------------------------------- accessors
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| MareError::Json(format!("missing key `{key}`")))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(MareError::Json(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(MareError::Json(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            return Err(MareError::Json(format!("expected unsigned integer, got {f}")));
        }
        Ok(f as u64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    /// Signed integer (negative values allowed, fractions rejected).
    /// Bounded to the f64-exact range like every number in this codec.
    pub fn as_i64(&self) -> Result<i64> {
        let f = self.as_f64()?;
        if f.fract() != 0.0 || f.abs() >= 9.0e15 {
            return Err(MareError::Json(format!("expected integer, got {f}")));
        }
        Ok(f as i64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(MareError::Json(format!("expected bool, got {other:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(MareError::Json(format!("expected array, got {other:?}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Ok(o),
            other => Err(MareError::Json(format!("expected object, got {other:?}"))),
        }
    }

    /// Object fields as a map (for lookups by unknown key sets).
    pub fn obj_map(&self) -> Result<BTreeMap<&str, &Json>> {
        Ok(self.as_obj()?.iter().map(|(k, v)| (k.as_str(), v)).collect())
    }

    // -------------------------------------------------------- constructors
    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // ------------------------------------------------------------- parsing
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(MareError::Json(format!("trailing garbage at byte {}", p.pos)));
        }
        Ok(v)
    }

    // --------------------------------------------------------- serializing
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !items.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(if pretty { ": " } else { ":" });
                    v.write(out, indent + 1, pretty);
                }
                if !fields.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> MareError {
        MareError::Json(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek().ok_or_else(|| self.err("unexpected EOF"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected `{}`", c as char))),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(fields)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(out),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex in \\u"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    c => return Err(self.err(&format!("bad escape `\\{}`", c as char))),
                },
                c if c < 0x80 => out.push(c as char),
                c => {
                    // multi-byte UTF-8: copy the remaining continuation bytes
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump().ok_or_else(|| self.err("truncated UTF-8"))?;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid UTF-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("bad number `{text}`")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi\nthere""#).unwrap(), Json::Str("hi\nthere".into()));
    }

    #[test]
    fn signed_integers_accept_negatives_and_reject_fractions() {
        assert_eq!(Json::Num(-7.0).as_i64().unwrap(), -7);
        assert_eq!(Json::Num(0.0).as_i64().unwrap(), 0);
        assert_eq!(Json::Num(12.0).as_i64().unwrap(), 12);
        assert!(Json::Num(1.5).as_i64().is_err());
        assert!(Json::Num(9.1e15).as_i64().is_err());
        assert!(Json::Num(-7.0).as_u64().is_err(), "unsigned accessor still rejects negatives");
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[0], Json::Num(1.0));
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[1].get("b").unwrap().as_str().unwrap(),
            "c"
        );
        assert!(v.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn parses_unicode_escapes() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(Json::parse("\"snön\"").unwrap(), Json::Str("snön".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip_preserves_structure() {
        let src = r#"{"entries": {"x": {"shape": [128, 256], "sum": -1.5}}, "ok": true}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(2.0).to_string(), "2");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
    }

    #[test]
    fn string_escapes_roundtrip() {
        let s = Json::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(Json::parse(&s.to_string()).unwrap(), s);
    }
}
