//! In-tree substitutes for the usual crates.io utility stack (offline
//! build environment — see the note in Cargo.toml) plus shared helpers.

pub mod bench;
pub mod bytes;
pub mod cli;
pub mod gz;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod rx;
pub mod scan;

pub use json::Json;
pub use rng::Rng;
