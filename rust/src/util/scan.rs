//! SWAR (SIMD-within-a-register) byte scanning — the zero-dependency
//! separator-search kernel under every record split, line scan and
//! shuffle key extraction.
//!
//! The word-at-a-time trick is the classic memchr recipe: broadcast the
//! needle byte across a `u64`, XOR it into an 8-byte chunk of the
//! haystack (matching bytes become zero), then detect a zero byte with
//!
//! ```text
//! (x - 0x0101..) & !x & 0x8080..
//! ```
//!
//! which sets bit 7 of every byte lane that was zero. Subtraction
//! borrows can only corrupt lanes *above* the first zero lane, so the
//! lowest set bit is exact and `trailing_zeros() / 8` is the match
//! offset. Chunks are loaded with `u64::from_le_bytes`, which makes the
//! lane order little-endian on every platform — no `unsafe`, no
//! endian-conditional code.
//!
//! Multi-byte separators go through [`find`]: SWAR-scan for first-byte
//! candidates (restricted to offsets where the whole needle still
//! fits), then confirm the tail with a slice compare. Matches are
//! non-overlapping and leftmost-first, exactly like `str::find` /
//! `str::split`.
//!
//! Every SWAR kernel has a scalar twin (`*_scalar`) that is the
//! reference semantics; `rust/tests/prop_invariants.rs` drives them
//! against each other across random corpora, separator lengths 1–6 and
//! all 8 buffer alignments. Setting `MARE_SCAN_FORCE_SCALAR=1` makes
//! the public entry points dispatch to the scalar twins — CI's
//! bench-smoke job runs once in that mode so the fallback cannot
//! bit-rot.

use std::sync::OnceLock;

const LO: u64 = 0x0101_0101_0101_0101;
const HI: u64 = 0x8080_8080_8080_8080;

/// Bit 7 of every byte lane of `x` that is zero.
#[inline(always)]
fn zero_byte_mask(x: u64) -> u64 {
    x.wrapping_sub(LO) & !x & HI
}

/// True when `MARE_SCAN_FORCE_SCALAR` is set (read once per process).
fn force_scalar() -> bool {
    static FORCE: OnceLock<bool> = OnceLock::new();
    *FORCE.get_or_init(|| {
        std::env::var("MARE_SCAN_FORCE_SCALAR")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false)
    })
}

/// Which kernel the public entry points dispatch to: `"swar"` or
/// `"scalar"`. `mare bench` prints this so CI can assert the fallback
/// path is the one being exercised.
pub fn active_kernel() -> &'static str {
    if force_scalar() {
        "scalar"
    } else {
        "swar"
    }
}

/// First offset of `needle` in `hay`, 8 bytes per iteration.
pub fn memchr_swar(needle: u8, hay: &[u8]) -> Option<usize> {
    let broadcast = (needle as u64).wrapping_mul(LO);
    let mut chunks = hay.chunks_exact(8);
    let mut off = 0usize;
    for c in &mut chunks {
        let x = u64::from_le_bytes(c.try_into().expect("chunks_exact(8)")) ^ broadcast;
        let m = zero_byte_mask(x);
        if m != 0 {
            return Some(off + (m.trailing_zeros() / 8) as usize);
        }
        off += 8;
    }
    chunks.remainder().iter().position(|&b| b == needle).map(|i| off + i)
}

/// Reference semantics for [`memchr_swar`].
pub fn memchr_scalar(needle: u8, hay: &[u8]) -> Option<usize> {
    hay.iter().position(|&b| b == needle)
}

/// First offset of byte `needle` in `hay`.
pub fn memchr(needle: u8, hay: &[u8]) -> Option<usize> {
    if force_scalar() {
        memchr_scalar(needle, hay)
    } else {
        memchr_swar(needle, hay)
    }
}

/// First offset of `needle` in `hay` (empty needle matches at 0):
/// SWAR first-byte candidates + tail confirm.
pub fn find_swar(hay: &[u8], needle: &[u8]) -> Option<usize> {
    match needle.len() {
        0 => return Some(0),
        1 => return memchr_swar(needle[0], hay),
        n if n > hay.len() => return None,
        _ => {}
    }
    // candidate starts are offsets where the whole needle still fits
    let last = hay.len() - needle.len();
    let mut at = 0usize;
    while at <= last {
        let pos = at + memchr_swar(needle[0], &hay[at..=last])?;
        if hay[pos + 1..pos + needle.len()] == needle[1..] {
            return Some(pos);
        }
        at = pos + 1;
    }
    None
}

/// Reference semantics for [`find_swar`].
pub fn find_scalar(hay: &[u8], needle: &[u8]) -> Option<usize> {
    if needle.is_empty() {
        return Some(0);
    }
    if needle.len() > hay.len() {
        return None;
    }
    hay.windows(needle.len()).position(|w| w == needle)
}

/// First offset of `needle` in `hay`.
pub fn find(hay: &[u8], needle: &[u8]) -> Option<usize> {
    if force_scalar() {
        find_scalar(hay, needle)
    } else {
        find_swar(hay, needle)
    }
}

/// Leftmost-first, non-overlapping match offsets of `needle` in `hay`
/// (steps by `needle.len()` past each match, like `str::split`'s
/// separator walk). An empty needle yields nothing.
pub fn find_iter<'h, 'n>(hay: &'h [u8], needle: &'n [u8]) -> FindIter<'h, 'n> {
    FindIter { hay, needle, at: 0 }
}

pub struct FindIter<'h, 'n> {
    hay: &'h [u8],
    needle: &'n [u8],
    at: usize,
}

impl Iterator for FindIter<'_, '_> {
    type Item = usize;

    fn next(&mut self) -> Option<usize> {
        if self.needle.is_empty() || self.at > self.hay.len() {
            return None;
        }
        let pos = self.at + find(&self.hay[self.at..], self.needle)?;
        self.at = pos + self.needle.len();
        Some(pos)
    }
}

/// Byte ranges of the chunks `sep` splits `hay` into — exactly
/// `str::split`'s segmentation: empty input is one empty chunk,
/// adjacent/trailing separators produce empty chunks. `sep` must be
/// non-empty (callers special-case empty separators, which mean "don't
/// split" at the record layer, not the per-char walk `str::split`
/// does).
pub fn split_ranges(hay: &[u8], sep: &[u8]) -> Vec<(usize, usize)> {
    debug_assert!(!sep.is_empty(), "empty separator is a caller-level special case");
    let mut out = Vec::new();
    let mut start = 0usize;
    for pos in find_iter(hay, sep) {
        out.push((start, pos));
        start = pos + sep.len();
    }
    out.push((start, hay.len()));
    out
}

/// Byte ranges of the lines of `hay`, matching `str::lines`: split on
/// `\n`, strip one trailing `\r` per line, and a final `\n` does not
/// open an empty trailing line.
pub fn line_ranges(hay: &[u8]) -> LineRanges<'_> {
    LineRanges { hay, at: 0, done: hay.is_empty() }
}

pub struct LineRanges<'h> {
    hay: &'h [u8],
    at: usize,
    done: bool,
}

impl Iterator for LineRanges<'_> {
    type Item = (usize, usize);

    fn next(&mut self) -> Option<(usize, usize)> {
        if self.done {
            return None;
        }
        let start = self.at;
        match memchr(b'\n', &self.hay[start..]) {
            Some(p) => {
                let mut end = start + p;
                self.at = end + 1;
                if self.at == self.hay.len() {
                    self.done = true;
                }
                if end > start && self.hay[end - 1] == b'\r' {
                    end -= 1;
                }
                Some((start, end))
            }
            None => {
                self.done = true;
                Some((start, self.hay.len()))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn memchr_agrees_with_scalar_on_every_alignment_and_length() {
        let mut rng = Rng::new(0x5CA7);
        let buf: Vec<u8> = (0..257).map(|_| rng.below(7) as u8 + b'a').collect();
        for align in 0..8 {
            for len in 0..64 {
                if align + len > buf.len() {
                    continue;
                }
                let hay = &buf[align..align + len];
                for needle in [b'a', b'c', b'g', b'z'] {
                    assert_eq!(
                        memchr_swar(needle, hay),
                        memchr_scalar(needle, hay),
                        "align {align} len {len} needle {needle}"
                    );
                }
            }
        }
    }

    #[test]
    fn memchr_finds_matches_in_the_tail_remainder() {
        // match past the last full 8-byte chunk
        let hay = b"0123456789abcdeX";
        assert_eq!(memchr_swar(b'X', &hay[..]), Some(15));
        assert_eq!(memchr_swar(b'X', &hay[..15]), None);
    }

    #[test]
    fn find_matches_str_find_semantics() {
        let cases: &[(&str, &str)] = &[
            ("", ""),
            ("abc", ""),
            ("", "x"),
            ("abc", "abc"),
            ("abc", "abcd"),
            ("aaab", "ab"),
            ("xxabxxabxx", "ab"),
            ("ababab", "abab"),
            ("a\n$\nb", "\n$\n"),
        ];
        for (hay, needle) in cases {
            let want = hay.find(needle);
            assert_eq!(find_swar(hay.as_bytes(), needle.as_bytes()), want, "{hay:?}/{needle:?}");
            assert_eq!(find_scalar(hay.as_bytes(), needle.as_bytes()), want, "{hay:?}/{needle:?}");
        }
    }

    #[test]
    fn find_iter_is_non_overlapping() {
        let pos: Vec<usize> = find_iter(b"aaaa", b"aa").collect();
        assert_eq!(pos, vec![0, 2]);
        let none: Vec<usize> = find_iter(b"aaaa", b"").collect();
        assert!(none.is_empty());
    }

    #[test]
    fn split_ranges_matches_str_split() {
        for (hay, sep) in
            [("", "\n"), ("a\nb", "\n"), ("a\nb\n", "\n"), ("\n\n", "\n"), ("x;;y;;", ";;")]
        {
            let want: Vec<&str> = hay.split(sep).collect();
            let got: Vec<&str> = split_ranges(hay.as_bytes(), sep.as_bytes())
                .into_iter()
                .map(|(s, e)| &hay[s..e])
                .collect();
            assert_eq!(got, want, "{hay:?}/{sep:?}");
        }
    }

    #[test]
    fn line_ranges_matches_str_lines() {
        for hay in ["", "\n", "a", "a\n", "a\nb", "a\r\nb\r\n", "\r", "a\r\r\nb", "\n\nx\n"] {
            let want: Vec<&str> = hay.lines().collect();
            let got: Vec<&str> =
                line_ranges(hay.as_bytes()).map(|(s, e)| &hay[s..e]).collect();
            assert_eq!(got, want, "{hay:?}");
        }
    }

    #[test]
    fn active_kernel_names_a_kernel() {
        assert!(["swar", "scalar"].contains(&active_kernel()));
    }
}
