//! Tiny argv parser for the `mare` binary (offline substitute for clap).
//!
//! Grammar: `mare <subcommand> [--flag[=value]|--flag value]... [positional]...`

use std::collections::BTreeMap;

use crate::error::{MareError, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(flag) = arg.strip_prefix("--") {
                if let Some((k, v)) = flag.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    out.flags.insert(flag.to_string(), v);
                } else {
                    out.flags.insert(flag.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn flag_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| MareError::Config(format!("--{name} wants an integer, got `{v}`"))),
        }
    }

    pub fn flag_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| MareError::Config(format!("--{name} wants an integer, got `{v}`"))),
        }
    }

    pub fn flag_bool(&self, name: &str) -> bool {
        matches!(self.flag(name), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_flags_positionals() {
        let a = parse(&["run", "--workers", "8", "--storage=hdfs", "input.sdf", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.flag("workers"), Some("8"));
        assert_eq!(a.flag("storage"), Some("hdfs"));
        assert!(a.flag_bool("verbose"));
        assert_eq!(a.positional, vec!["input.sdf"]);
    }

    #[test]
    fn typed_flags() {
        let a = parse(&["x", "--n", "12"]);
        assert_eq!(a.flag_usize("n", 1).unwrap(), 12);
        assert_eq!(a.flag_usize("m", 7).unwrap(), 7);
        let bad = parse(&["x", "--n", "NaN"]);
        assert!(bad.flag_usize("n", 1).is_err());
    }
}
