//! Minimal stderr logger (offline substitute for the `log` facade —
//! see the note in Cargo.toml).
//!
//! Level comes from `MARE_LOG` (off|error|warn|info|debug|trace);
//! defaults to whatever [`init`] was first called with. Use the
//! crate-level macros [`crate::log_info!`] / [`crate::log_warn!`] /
//! [`crate::log_debug!`] / [`crate::log_error!`].

use std::io::Write;
use std::str::FromStr;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Once;

/// Log verbosity, ordered: `Off < Error < Warn < Info < Debug < Trace`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    fn label(self) -> &'static str {
        match self {
            Level::Off => "OFF",
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl FromStr for Level {
    type Err = ();

    fn from_str(s: &str) -> std::result::Result<Self, ()> {
        match s.to_ascii_lowercase().as_str() {
            "off" => Ok(Level::Off),
            "error" => Ok(Level::Error),
            "warn" | "warning" => Ok(Level::Warn),
            "info" => Ok(Level::Info),
            "debug" => Ok(Level::Debug),
            "trace" => Ok(Level::Trace),
            _ => Err(()),
        }
    }
}

/// Current max level (usize for atomic storage; 0 = off).
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0);
static INIT: Once = Once::new();

/// Install the logger (idempotent). `MARE_LOG` overrides the default.
pub fn init(default_level: Level) {
    INIT.call_once(|| {
        let level = std::env::var("MARE_LOG")
            .ok()
            .and_then(|s| s.parse::<Level>().ok())
            .unwrap_or(default_level);
        MAX_LEVEL.store(level as usize, Ordering::Relaxed);
    });
}

/// Whether a message at `level` would be emitted.
pub fn enabled(level: Level) -> bool {
    level != Level::Off && (level as usize) <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Emit one record (used by the `log_*!` macros; call those instead).
pub fn log(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let mut err = std::io::stderr().lock();
    let _ = writeln!(
        err,
        "[{:<5} {}] {}",
        level.label(),
        target.split("::").last().unwrap_or(""),
        args
    );
}

/// Shared body of the level macros: the `enabled` gate runs BEFORE the
/// format arguments are evaluated (like the `log` crate this replaces),
/// so disabled-level calls cost one atomic load, not an `explain()`.
#[macro_export]
macro_rules! log_at {
    ($level:expr, $($arg:tt)*) => {
        if $crate::util::logging::enabled($level) {
            $crate::util::logging::log($level, module_path!(), format_args!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::log_at!($crate::util::logging::Level::Error, $($arg)*)
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::log_at!($crate::util::logging::Level::Warn, $($arg)*)
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::log_at!($crate::util::logging::Level::Info, $($arg)*)
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::log_at!($crate::util::logging::Level::Debug, $($arg)*)
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent_and_levels_order() {
        init(Level::Warn);
        init(Level::Trace); // second call is a no-op
        assert!(Level::Error < Level::Trace);
        assert!(!enabled(Level::Off));
        crate::log_warn!("logger smoke test");
    }

    #[test]
    fn disabled_levels_do_not_evaluate_arguments() {
        init(Level::Warn);
        let mut evaluated = false;
        // trace is only enabled by an explicit MARE_LOG=trace
        crate::log_at!(Level::Trace, "{}", {
            evaluated = true;
            "x"
        });
        assert!(!evaluated, "format arguments must not run for disabled levels");
    }

    #[test]
    fn level_parses() {
        assert_eq!("info".parse::<Level>(), Ok(Level::Info));
        assert_eq!("WARN".parse::<Level>(), Ok(Level::Warn));
        assert!("verbose".parse::<Level>().is_err());
    }
}
