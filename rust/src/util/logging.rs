//! Minimal stderr logger for the `log` facade.
//!
//! Level comes from `MARE_LOG` (error|warn|info|debug|trace); defaults to
//! `info` for the binary and `warn` under tests.

use std::io::Write;
use std::sync::Once;

struct StderrLogger {
    level: log::LevelFilter,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &log::Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let mut err = std::io::stderr().lock();
        let _ = writeln!(
            err,
            "[{:<5} {}] {}",
            record.level(),
            record.target().split("::").last().unwrap_or(""),
            record.args()
        );
    }

    fn flush(&self) {}
}

static INIT: Once = Once::new();

/// Install the logger (idempotent).
pub fn init(default_level: log::LevelFilter) {
    INIT.call_once(|| {
        let level = std::env::var("MARE_LOG")
            .ok()
            .and_then(|s| s.parse::<log::LevelFilter>().ok())
            .unwrap_or(default_level);
        let logger = Box::new(StderrLogger { level });
        if log::set_boxed_logger(logger).is_ok() {
            log::set_max_level(level);
        }
    });
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init(log::LevelFilter::Warn);
        super::init(log::LevelFilter::Trace);
        log::warn!("logger smoke test");
    }
}
