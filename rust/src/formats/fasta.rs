//! FASTA reference genomes + the `.dict` sequence dictionary
//! (the `/ref/human_g1k_v37.{fasta,dict}` files baked into the paper's
//! alignment image).

use crate::error::{MareError, Result};
use crate::util::scan;

#[derive(Debug, Clone, PartialEq)]
pub struct Contig {
    pub name: String,
    pub seq: Vec<u8>,
}

#[derive(Debug, Clone, Default, PartialEq)]
pub struct Reference {
    pub contigs: Vec<Contig>,
}

impl Reference {
    pub fn parse(text: &str) -> Result<Reference> {
        let mut contigs: Vec<Contig> = Vec::new();
        // contigs stay owned (they're built by concatenation), but the
        // line walk itself goes through the SWAR scanner
        for (s, e) in scan::line_ranges(text.as_bytes()) {
            let line = &text[s..e];
            if let Some(name) = line.strip_prefix('>') {
                contigs.push(Contig {
                    name: name.split_whitespace().next().unwrap_or("").to_string(),
                    seq: Vec::new(),
                });
            } else if let Some(c) = contigs.last_mut() {
                c.seq.extend(line.trim().bytes());
            } else if !line.trim().is_empty() {
                return Err(MareError::Format {
                    format: "fasta",
                    detail: "sequence before first header".into(),
                });
            }
        }
        Ok(Reference { contigs })
    }

    pub fn to_fasta(&self) -> String {
        let mut out = String::new();
        for c in &self.contigs {
            out.push('>');
            out.push_str(&c.name);
            out.push('\n');
            for chunk in c.seq.chunks(70) {
                out.push_str(std::str::from_utf8(chunk).unwrap_or(""));
                out.push('\n');
            }
        }
        out
    }

    /// `.dict` sequence dictionary (SAM-header style, what `cat dict sam`
    /// prepends in Listing 3).
    pub fn to_dict(&self) -> String {
        let mut out = String::from("@HD\tVN:1.6\n");
        for c in &self.contigs {
            out.push_str(&format!("@SQ\tSN:{}\tLN:{}\n", c.name, c.seq.len()));
        }
        out
    }

    pub fn contig(&self, name: &str) -> Option<&Contig> {
        self.contigs.iter().find(|c| c.name == name)
    }

    pub fn total_len(&self) -> usize {
        self.contigs.iter().map(|c| c.seq.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let r = Reference {
            contigs: vec![
                Contig { name: "chr1".into(), seq: b"ACGTACGTAC".repeat(20) },
                Contig { name: "chr2".into(), seq: b"GGGCCC".to_vec() },
            ],
        };
        let parsed = Reference::parse(&r.to_fasta()).unwrap();
        assert_eq!(parsed, r);
        assert_eq!(parsed.total_len(), 206);
    }

    #[test]
    fn dict_has_all_contigs() {
        let r = Reference {
            contigs: vec![Contig { name: "chr9".into(), seq: vec![b'A'; 42] }],
        };
        let d = r.to_dict();
        assert!(d.contains("@SQ\tSN:chr9\tLN:42"), "{d}");
    }

    #[test]
    fn header_with_description() {
        let r = Reference::parse(">chr1 homo sapiens\nACGT\n").unwrap();
        assert_eq!(r.contigs[0].name, "chr1");
    }

    #[test]
    fn rejects_headerless() {
        assert!(Reference::parse("ACGT\n").is_err());
    }
}
