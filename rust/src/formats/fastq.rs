//! FASTQ short reads (interleaved, as the paper ingests from 1KGP).
//!
//! Records are zero-copy: `parse_many` finds line boundaries with the
//! SWAR scanner ([`crate::util::scan::line_ranges`]) and every field is
//! an O(1) slice of the input buffer ([`SharedStr`] / [`Shared`]), not
//! a per-record `to_string` copy.

use crate::error::{MareError, Result};
use crate::util::bytes::{Shared, SharedStr};
use crate::util::scan;

#[derive(Debug, Clone, PartialEq)]
pub struct FastqRead {
    pub id: SharedStr,
    pub seq: Shared,
    pub qual: Shared,
}

impl FastqRead {
    pub fn to_fastq(&self) -> String {
        format!(
            "@{}\n{}\n+\n{}\n",
            self.id,
            String::from_utf8_lossy(&self.seq),
            String::from_utf8_lossy(&self.qual)
        )
    }
}

/// Parse a FASTQ chunk (4 lines per read). Fields are O(1) views of
/// `text`'s buffer.
pub fn parse_many(text: &SharedStr) -> Result<Vec<FastqRead>> {
    let lines: Vec<(usize, usize)> = scan::line_ranges(text.as_shared().as_slice()).collect();
    let mut out = Vec::with_capacity(lines.len() / 4);
    let mut i = 0;
    while i < lines.len() {
        let line = |k: usize| &text[lines[k].0..lines[k].1];
        if line(i).trim().is_empty() {
            i += 1;
            continue;
        }
        if i + 3 >= lines.len() {
            return Err(err(format!("truncated read at line {i}")));
        }
        if !line(i).starts_with('@') {
            return Err(err(format!("expected @ header, got `{}`", line(i))));
        }
        if !line(i + 2).starts_with('+') {
            return Err(err(format!("expected + separator at line {}", i + 2)));
        }
        let id = text.slice(lines[i].0 + 1, lines[i].1);
        let (s0, s1) = trimmed(text, lines[i + 1]);
        let (q0, q1) = trimmed(text, lines[i + 3]);
        if s1 - s0 != q1 - q0 {
            return Err(err(format!("seq/qual length mismatch for `{id}`")));
        }
        out.push(FastqRead {
            id,
            seq: text.as_shared().slice(s0, s1),
            qual: text.as_shared().slice(q0, q1),
        });
        i += 4;
    }
    Ok(out)
}

/// Whitespace-trimmed sub-range of line `(s, e)` within `text`.
fn trimmed(text: &SharedStr, (s, e): (usize, usize)) -> (usize, usize) {
    let t = text[s..e].trim();
    let off = t.as_ptr() as usize - text.as_str().as_ptr() as usize;
    (off, off + t.len())
}

pub fn write_many(reads: &[FastqRead]) -> String {
    reads.iter().map(FastqRead::to_fastq).collect()
}

fn err(detail: String) -> MareError {
    MareError::Format { format: "fastq", detail }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let reads = vec![
            FastqRead { id: "r1/1".into(), seq: b"ACGT".to_vec().into(), qual: b"IIII".to_vec().into() },
            FastqRead { id: "r1/2".into(), seq: b"GGCC".to_vec().into(), qual: b"HHHH".to_vec().into() },
        ];
        let text = write_many(&reads);
        assert_eq!(parse_many(&text.into()).unwrap(), reads);
    }

    #[test]
    fn fields_are_views_of_the_input_buffer() {
        let text = SharedStr::from("@r9\nACGTAC\n+\nIIIIII\n");
        let reads = parse_many(&text).unwrap();
        // text + id + seq + qual = 4 handles on ONE allocation
        assert_eq!(text.as_shared().ref_count(), 4);
        assert_eq!(reads[0].id, "r9");
        assert_eq!(reads[0].seq.as_slice(), b"ACGTAC");
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_many(&"@r1\nACGT\n+\n".into()).is_err()); // truncated
        assert!(parse_many(&"r1\nACGT\n+\nIIII\n".into()).is_err()); // no @
        assert!(parse_many(&"@r1\nACGT\n+\nII\n".into()).is_err()); // qual mismatch
    }
}
