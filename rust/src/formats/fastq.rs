//! FASTQ short reads (interleaved, as the paper ingests from 1KGP).

use crate::error::{MareError, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct FastqRead {
    pub id: String,
    pub seq: Vec<u8>,
    pub qual: Vec<u8>,
}

impl FastqRead {
    pub fn to_fastq(&self) -> String {
        format!(
            "@{}\n{}\n+\n{}\n",
            self.id,
            String::from_utf8_lossy(&self.seq),
            String::from_utf8_lossy(&self.qual)
        )
    }
}

/// Parse a FASTQ chunk (4 lines per read).
pub fn parse_many(text: &str) -> Result<Vec<FastqRead>> {
    let lines: Vec<&str> = text.lines().collect();
    let mut out = Vec::with_capacity(lines.len() / 4);
    let mut i = 0;
    while i < lines.len() {
        if lines[i].trim().is_empty() {
            i += 1;
            continue;
        }
        if i + 3 >= lines.len() {
            return Err(err(format!("truncated read at line {i}")));
        }
        let id = lines[i]
            .strip_prefix('@')
            .ok_or_else(|| err(format!("expected @ header, got `{}`", lines[i])))?;
        if !lines[i + 2].starts_with('+') {
            return Err(err(format!("expected + separator at line {}", i + 2)));
        }
        let seq = lines[i + 1].trim().as_bytes().to_vec();
        let qual = lines[i + 3].trim().as_bytes().to_vec();
        if seq.len() != qual.len() {
            return Err(err(format!("seq/qual length mismatch for `{id}`")));
        }
        out.push(FastqRead { id: id.to_string(), seq, qual });
        i += 4;
    }
    Ok(out)
}

pub fn write_many(reads: &[FastqRead]) -> String {
    reads.iter().map(FastqRead::to_fastq).collect()
}

fn err(detail: String) -> MareError {
    MareError::Format { format: "fastq", detail }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let reads = vec![
            FastqRead { id: "r1/1".into(), seq: b"ACGT".to_vec(), qual: b"IIII".to_vec() },
            FastqRead { id: "r1/2".into(), seq: b"GGCC".to_vec(), qual: b"HHHH".to_vec() },
        ];
        let text = write_many(&reads);
        assert_eq!(parse_many(&text).unwrap(), reads);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_many("@r1\nACGT\n+\n").is_err()); // truncated
        assert!(parse_many("r1\nACGT\n+\nIIII\n").is_err()); // no @
        assert!(parse_many("@r1\nACGT\n+\nII\n").is_err()); // qual mismatch
    }
}
