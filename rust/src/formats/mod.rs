//! Bioinformatics file formats the pipelines move through containers:
//! SDF (molecules), FASTA (+ .dict) (reference genomes), FASTQ (reads),
//! SAM (alignments), VCF (variant calls). Small, real parsers/writers —
//! the mount-point round-trips in the paper's listings depend on them.

pub mod fasta;
pub mod fastq;
pub mod sam;
pub mod sdf;
pub mod vcf;

/// SDF record separator used throughout the paper (Listing 2).
pub const SDF_SEPARATOR: &str = "\n$$$$\n";
