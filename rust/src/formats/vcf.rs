//! VCF variant calls (the SNP pipeline's output format).

use crate::error::{MareError, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct VcfRecord {
    pub chrom: String,
    pub pos: u64,
    pub id: String,
    pub ref_base: String,
    pub alt: String,
    pub qual: f32,
    pub genotype: String, // GT sample field, e.g. "0/1"
}

impl VcfRecord {
    pub fn parse(line: &str) -> Result<VcfRecord> {
        let f: Vec<&str> = line.split('\t').collect();
        if f.len() < 10 {
            return Err(err(format!("{} fields, want >= 10: `{line}`", f.len())));
        }
        Ok(VcfRecord {
            chrom: f[0].to_string(),
            pos: f[1].parse().map_err(|_| err(format!("bad pos `{}`", f[1])))?,
            id: f[2].to_string(),
            ref_base: f[3].to_string(),
            alt: f[4].to_string(),
            qual: f[5].parse().map_err(|_| err(format!("bad qual `{}`", f[5])))?,
            genotype: f[9].to_string(),
        })
    }

    pub fn to_line(&self) -> String {
        format!(
            "{}\t{}\t{}\t{}\t{}\t{:.2}\tPASS\t.\tGT\t{}",
            self.chrom, self.pos, self.id, self.ref_base, self.alt, self.qual, self.genotype
        )
    }
}

pub const HEADER: &str = "##fileformat=VCFv4.2\n##source=MaRe-sim-HaplotypeCaller\n#CHROM\tPOS\tID\tREF\tALT\tQUAL\tFILTER\tINFO\tFORMAT\tSAMPLE\n";

/// Parse a VCF document (header tolerated and skipped).
pub fn parse_many(text: &str) -> Result<Vec<VcfRecord>> {
    text.lines()
        .filter(|l| !l.starts_with('#') && !l.trim().is_empty())
        .map(VcfRecord::parse)
        .collect()
}

/// Serialize with header.
pub fn write_many(records: &[VcfRecord]) -> String {
    let mut out = String::from(HEADER);
    for r in records {
        out.push_str(&r.to_line());
        out.push('\n');
    }
    out
}

/// Concatenate VCF documents, keeping one header (what `vcf-concat`
/// does in Listing 3).
pub fn concat(docs: &[String]) -> Result<String> {
    let mut all = Vec::new();
    for d in docs {
        all.extend(parse_many(d)?);
    }
    all.sort_by(|a, b| (a.chrom.clone(), a.pos).cmp(&(b.chrom.clone(), b.pos)));
    Ok(write_many(&all))
}

fn err(detail: String) -> MareError {
    MareError::Format { format: "vcf", detail }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(chrom: &str, pos: u64) -> VcfRecord {
        VcfRecord {
            chrom: chrom.into(),
            pos,
            id: ".".into(),
            ref_base: "A".into(),
            alt: "C".into(),
            qual: 33.5,
            genotype: "0/1".into(),
        }
    }

    #[test]
    fn roundtrip() {
        let records = vec![rec("chr1", 10), rec("chr2", 5)];
        let text = write_many(&records);
        assert_eq!(parse_many(&text).unwrap(), records);
    }

    #[test]
    fn concat_merges_and_sorts() {
        let a = write_many(&[rec("chr2", 100)]);
        let b = write_many(&[rec("chr1", 50), rec("chr2", 20)]);
        let merged = concat(&[a, b]).unwrap();
        let recs = parse_many(&merged).unwrap();
        assert_eq!(recs.len(), 3);
        assert_eq!(recs[0].chrom, "chr1");
        assert_eq!((recs[1].pos, recs[2].pos), (20, 100));
        // single header survived
        assert_eq!(merged.matches("##fileformat").count(), 1);
    }

    #[test]
    fn rejects_garbage() {
        assert!(VcfRecord::parse("chr1\tx").is_err());
    }
}
