//! VCF variant calls (the SNP pipeline's output format).
//!
//! String fields are [`SharedStr`] views: `parse_many` line-scans with
//! the SWAR kernel and `parse` tab-splits each line into O(1) slices.

use crate::error::{MareError, Result};
use crate::util::bytes::SharedStr;
use crate::util::scan;

#[derive(Debug, Clone, PartialEq)]
pub struct VcfRecord {
    pub chrom: SharedStr,
    pub pos: u64,
    pub id: SharedStr,
    pub ref_base: SharedStr,
    pub alt: SharedStr,
    pub qual: f32,
    pub genotype: SharedStr, // GT sample field, e.g. "0/1"
}

impl VcfRecord {
    /// Parse one record line; string fields are O(1) views of `line`.
    pub fn parse(line: &SharedStr) -> Result<VcfRecord> {
        let f = scan::split_ranges(line.as_shared().as_slice(), b"\t");
        if f.len() < 10 {
            return Err(err(format!("{} fields, want >= 10: `{line}`", f.len())));
        }
        let raw = |i: usize| &line[f[i].0..f[i].1];
        Ok(VcfRecord {
            chrom: line.slice(f[0].0, f[0].1),
            pos: raw(1).parse().map_err(|_| err(format!("bad pos `{}`", raw(1))))?,
            id: line.slice(f[2].0, f[2].1),
            ref_base: line.slice(f[3].0, f[3].1),
            alt: line.slice(f[4].0, f[4].1),
            qual: raw(5).parse().map_err(|_| err(format!("bad qual `{}`", raw(5))))?,
            genotype: line.slice(f[9].0, f[9].1),
        })
    }

    pub fn to_line(&self) -> String {
        format!(
            "{}\t{}\t{}\t{}\t{}\t{:.2}\tPASS\t.\tGT\t{}",
            self.chrom, self.pos, self.id, self.ref_base, self.alt, self.qual, self.genotype
        )
    }
}

pub const HEADER: &str = "##fileformat=VCFv4.2\n##source=MaRe-sim-HaplotypeCaller\n#CHROM\tPOS\tID\tREF\tALT\tQUAL\tFILTER\tINFO\tFORMAT\tSAMPLE\n";

/// Parse a VCF document (header tolerated and skipped). Record fields
/// are views of `text`'s buffer.
pub fn parse_many(text: &SharedStr) -> Result<Vec<VcfRecord>> {
    let mut out = Vec::new();
    for (s, e) in scan::line_ranges(text.as_shared().as_slice()) {
        let l = &text[s..e];
        if l.starts_with('#') || l.trim().is_empty() {
            continue;
        }
        out.push(VcfRecord::parse(&text.slice(s, e))?);
    }
    Ok(out)
}

/// Serialize with header.
pub fn write_many(records: &[VcfRecord]) -> String {
    let mut out = String::from(HEADER);
    for r in records {
        out.push_str(&r.to_line());
        out.push('\n');
    }
    out
}

/// Concatenate VCF documents, keeping one header (what `vcf-concat`
/// does in Listing 3).
pub fn concat(docs: &[String]) -> Result<String> {
    let mut all = Vec::new();
    for d in docs {
        all.extend(parse_many(&d.into())?);
    }
    all.sort_by(|a, b| (a.chrom.clone(), a.pos).cmp(&(b.chrom.clone(), b.pos)));
    Ok(write_many(&all))
}

fn err(detail: String) -> MareError {
    MareError::Format { format: "vcf", detail }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(chrom: &str, pos: u64) -> VcfRecord {
        VcfRecord {
            chrom: chrom.into(),
            pos,
            id: ".".into(),
            ref_base: "A".into(),
            alt: "C".into(),
            qual: 33.5,
            genotype: "0/1".into(),
        }
    }

    #[test]
    fn roundtrip() {
        let records = vec![rec("chr1", 10), rec("chr2", 5)];
        let text = write_many(&records);
        assert_eq!(parse_many(&text.into()).unwrap(), records);
    }

    #[test]
    fn concat_merges_and_sorts() {
        let a = write_many(&[rec("chr2", 100)]);
        let b = write_many(&[rec("chr1", 50), rec("chr2", 20)]);
        let merged = concat(&[a, b]).unwrap();
        let recs = parse_many(&merged.clone().into()).unwrap();
        assert_eq!(recs.len(), 3);
        assert_eq!(recs[0].chrom, "chr1");
        assert_eq!((recs[1].pos, recs[2].pos), (20, 100));
        // single header survived
        assert_eq!(merged.matches("##fileformat").count(), 1);
    }

    #[test]
    fn fields_are_views_not_copies() {
        let text = SharedStr::from(write_many(&[rec("chrX", 7)]));
        let recs = parse_many(&text).unwrap();
        // 5 string fields + the document handle share one buffer
        assert_eq!(text.as_shared().ref_count(), 6);
        assert_eq!(recs[0].genotype, "0/1");
    }

    #[test]
    fn rejects_garbage() {
        assert!(VcfRecord::parse(&"chr1\tx".into()).is_err());
    }
}
