//! SAM alignment records (the text format the paper converts to so the
//! chromosome id is parseable for `repartitionBy` — Listing 3).

use crate::error::{MareError, Result};

pub const FLAG_UNMAPPED: u16 = 0x4;

#[derive(Debug, Clone, PartialEq)]
pub struct SamRecord {
    pub qname: String,
    pub flag: u16,
    /// Reference (chromosome) name, `*` if unmapped.
    pub rname: String,
    /// 1-based leftmost position, 0 if unmapped.
    pub pos: u64,
    pub mapq: u8,
    pub cigar: String,
    pub seq: Vec<u8>,
    pub qual: Vec<u8>,
}

impl SamRecord {
    pub fn is_mapped(&self) -> bool {
        self.flag & FLAG_UNMAPPED == 0 && self.rname != "*"
    }

    pub fn parse(line: &str) -> Result<SamRecord> {
        let f: Vec<&str> = line.split('\t').collect();
        if f.len() < 11 {
            return Err(err(format!("{} fields, want >= 11: `{line}`", f.len())));
        }
        Ok(SamRecord {
            qname: f[0].to_string(),
            flag: f[1].parse().map_err(|_| err(format!("bad flag `{}`", f[1])))?,
            rname: f[2].to_string(),
            pos: f[3].parse().map_err(|_| err(format!("bad pos `{}`", f[3])))?,
            mapq: f[4].parse().map_err(|_| err(format!("bad mapq `{}`", f[4])))?,
            cigar: f[5].to_string(),
            seq: f[9].as_bytes().to_vec(),
            qual: f[10].as_bytes().to_vec(),
        })
    }

    pub fn to_line(&self) -> String {
        format!(
            "{}\t{}\t{}\t{}\t{}\t{}\t*\t0\t0\t{}\t{}",
            self.qname,
            self.flag,
            self.rname,
            self.pos,
            self.mapq,
            self.cigar,
            String::from_utf8_lossy(&self.seq),
            String::from_utf8_lossy(&self.qual),
        )
    }
}

/// Parse SAM text, skipping header (@) lines.
pub fn parse_many(text: &str) -> Result<Vec<SamRecord>> {
    text.lines()
        .filter(|l| !l.starts_with('@') && !l.trim().is_empty())
        .map(SamRecord::parse)
        .collect()
}

/// The chromosome id of one SAM line — the paper's `parseChromosomeId`
/// keyBy function (Listing 3, line 12).
pub fn parse_chromosome_id(sam_line: &str) -> String {
    sam_line.split('\t').nth(2).unwrap_or("*").to_string()
}

fn err(detail: String) -> MareError {
    MareError::Format { format: "sam", detail }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec() -> SamRecord {
        SamRecord {
            qname: "read7".into(),
            flag: 0,
            rname: "chr2".into(),
            pos: 12345,
            mapq: 60,
            cigar: "100M".into(),
            seq: b"ACGT".to_vec(),
            qual: b"IIII".to_vec(),
        }
    }

    #[test]
    fn roundtrip() {
        let line = rec().to_line();
        let parsed = SamRecord::parse(&line).unwrap();
        assert_eq!(parsed, rec());
        assert!(parsed.is_mapped());
    }

    #[test]
    fn chromosome_key_fn() {
        assert_eq!(parse_chromosome_id(&rec().to_line()), "chr2");
        assert_eq!(parse_chromosome_id("garbage"), "*");
    }

    #[test]
    fn header_lines_skipped() {
        let text = format!("@HD\tVN:1.6\n@SQ\tSN:chr2\tLN:100\n{}\n", rec().to_line());
        let recs = parse_many(&text).unwrap();
        assert_eq!(recs.len(), 1);
    }

    #[test]
    fn unmapped_flag() {
        let mut r = rec();
        r.flag = FLAG_UNMAPPED;
        assert!(!r.is_mapped());
    }

    #[test]
    fn rejects_short_lines() {
        assert!(SamRecord::parse("a\tb\tc").is_err());
    }
}
