//! SAM alignment records (the text format the paper converts to so the
//! chromosome id is parseable for `repartitionBy` — Listing 3).
//!
//! Fields are zero-copy [`SharedStr`]/[`Shared`] views: lines come from
//! the SWAR scanner and tab fields are O(1) slices of the input buffer.

use crate::error::{MareError, Result};
use crate::util::bytes::{Shared, SharedStr};
use crate::util::scan;

pub const FLAG_UNMAPPED: u16 = 0x4;

#[derive(Debug, Clone, PartialEq)]
pub struct SamRecord {
    pub qname: SharedStr,
    pub flag: u16,
    /// Reference (chromosome) name, `*` if unmapped.
    pub rname: SharedStr,
    /// 1-based leftmost position, 0 if unmapped.
    pub pos: u64,
    pub mapq: u8,
    pub cigar: SharedStr,
    pub seq: Shared,
    pub qual: Shared,
}

impl SamRecord {
    pub fn is_mapped(&self) -> bool {
        self.flag & FLAG_UNMAPPED == 0 && self.rname != "*"
    }

    /// Parse one alignment line; string/byte fields are O(1) views.
    pub fn parse(line: &SharedStr) -> Result<SamRecord> {
        let f = scan::split_ranges(line.as_shared().as_slice(), b"\t");
        if f.len() < 11 {
            return Err(err(format!("{} fields, want >= 11: `{line}`", f.len())));
        }
        let raw = |i: usize| &line[f[i].0..f[i].1];
        Ok(SamRecord {
            qname: line.slice(f[0].0, f[0].1),
            flag: raw(1).parse().map_err(|_| err(format!("bad flag `{}`", raw(1))))?,
            rname: line.slice(f[2].0, f[2].1),
            pos: raw(3).parse().map_err(|_| err(format!("bad pos `{}`", raw(3))))?,
            mapq: raw(4).parse().map_err(|_| err(format!("bad mapq `{}`", raw(4))))?,
            cigar: line.slice(f[5].0, f[5].1),
            seq: line.as_shared().slice(f[9].0, f[9].1),
            qual: line.as_shared().slice(f[10].0, f[10].1),
        })
    }

    pub fn to_line(&self) -> String {
        format!(
            "{}\t{}\t{}\t{}\t{}\t{}\t*\t0\t0\t{}\t{}",
            self.qname,
            self.flag,
            self.rname,
            self.pos,
            self.mapq,
            self.cigar,
            String::from_utf8_lossy(&self.seq),
            String::from_utf8_lossy(&self.qual),
        )
    }
}

/// Parse SAM text, skipping header (@) lines. Record fields are views
/// of `text`'s buffer.
pub fn parse_many(text: &SharedStr) -> Result<Vec<SamRecord>> {
    let mut out = Vec::new();
    for (s, e) in scan::line_ranges(text.as_shared().as_slice()) {
        let l = &text[s..e];
        if l.starts_with('@') || l.trim().is_empty() {
            continue;
        }
        out.push(SamRecord::parse(&text.slice(s, e))?);
    }
    Ok(out)
}

/// The chromosome id of one SAM line — the paper's `parseChromosomeId`
/// keyBy function (Listing 3, line 12). Two SWAR tab hops, no split
/// allocation.
pub fn parse_chromosome_id(sam_line: &str) -> String {
    let b = sam_line.as_bytes();
    let mut at = 0usize;
    for _ in 0..2 {
        match scan::memchr(b'\t', &b[at..]) {
            Some(i) => at += i + 1,
            None => return "*".to_string(),
        }
    }
    let end = scan::memchr(b'\t', &b[at..]).map_or(b.len(), |i| at + i);
    sam_line[at..end].to_string()
}

fn err(detail: String) -> MareError {
    MareError::Format { format: "sam", detail }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec() -> SamRecord {
        SamRecord {
            qname: "read7".into(),
            flag: 0,
            rname: "chr2".into(),
            pos: 12345,
            mapq: 60,
            cigar: "100M".into(),
            seq: b"ACGT".to_vec().into(),
            qual: b"IIII".to_vec().into(),
        }
    }

    #[test]
    fn roundtrip() {
        let line = rec().to_line();
        let parsed = SamRecord::parse(&line.into()).unwrap();
        assert_eq!(parsed, rec());
        assert!(parsed.is_mapped());
    }

    #[test]
    fn chromosome_key_fn() {
        assert_eq!(parse_chromosome_id(&rec().to_line()), "chr2");
        assert_eq!(parse_chromosome_id("garbage"), "*");
        assert_eq!(parse_chromosome_id("a\tb\t"), "");
    }

    #[test]
    fn header_lines_skipped() {
        let text = format!("@HD\tVN:1.6\n@SQ\tSN:chr2\tLN:100\n{}\n", rec().to_line());
        let recs = parse_many(&text.into()).unwrap();
        assert_eq!(recs.len(), 1);
    }

    #[test]
    fn fields_are_views_of_the_line() {
        let text = SharedStr::from(rec().to_line());
        let recs = parse_many(&text).unwrap();
        // qname + rname + cigar + seq + qual + the text handle
        assert_eq!(text.as_shared().ref_count(), 6);
        assert_eq!(recs[0].rname, "chr2");
    }

    #[test]
    fn unmapped_flag() {
        let mut r = rec();
        r.flag = FLAG_UNMAPPED;
        assert!(!r.is_mapped());
    }

    #[test]
    fn rejects_short_lines() {
        assert!(SamRecord::parse(&"a\tb\tc".into()).is_err());
    }
}
