//! Structure-Data File (SDF) molecules — the VS pipeline's currency.
//!
//! One record = molfile block (header, counts, atoms, `M  END`) followed
//! by `> <tag>` data items. Records are separated by `$$$$` lines; MaRe
//! mounts them with the `"\n$$$$\n"` separator exactly as Listing 2.

use std::collections::BTreeMap;

use crate::error::{MareError, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct Atom {
    pub x: f32,
    pub y: f32,
    pub z: f32,
    pub element: String,
}

#[derive(Debug, Clone, PartialEq, Default)]
pub struct Molecule {
    pub name: String,
    pub atoms: Vec<Atom>,
    pub tags: BTreeMap<String, String>,
}

impl Molecule {
    /// Parse one SDF record (no trailing `$$$$`).
    pub fn parse(record: &str) -> Result<Molecule> {
        let lines: Vec<&str> = record.lines().collect();
        if lines.len() < 4 {
            return Err(fmt_err(format!("record too short: {} lines", lines.len())));
        }
        let name = lines[0].trim().to_string();
        // counts line: aaabbb... (atom count in cols 0-2) — we wrote it,
        // we parse it leniently (whitespace split).
        let counts = lines[3];
        let natoms: usize = counts
            .split_whitespace()
            .next()
            .and_then(|v| v.parse().ok())
            .ok_or_else(|| fmt_err(format!("bad counts line `{counts}`")))?;
        if lines.len() < 4 + natoms {
            return Err(fmt_err(format!("{natoms} atoms declared, record truncated")));
        }
        let mut atoms = Vec::with_capacity(natoms);
        for line in &lines[4..4 + natoms] {
            // no-collect, fast-float parse: atom lines are half the
            // bytes of an SDF and std f32 parsing dominated the profile
            let mut it = line.split_ascii_whitespace();
            let (Some(xs), Some(ys), Some(zs), Some(el)) =
                (it.next(), it.next(), it.next(), it.next())
            else {
                return Err(fmt_err(format!("bad atom line `{line}`")));
            };
            atoms.push(Atom {
                x: parse_f32(xs).ok_or_else(|| fmt_err(format!("bad x in `{line}`")))?,
                y: parse_f32(ys).ok_or_else(|| fmt_err(format!("bad y in `{line}`")))?,
                z: parse_f32(zs).ok_or_else(|| fmt_err(format!("bad z in `{line}`")))?,
                element: el.to_string(),
            });
        }
        // data items after "M  END"
        let mut tags = BTreeMap::new();
        let mut i = 4 + natoms;
        while i < lines.len() {
            let line = lines[i].trim();
            if let Some(tag) = line.strip_prefix("> <").and_then(|l| l.strip_suffix('>')) {
                let mut value = String::new();
                i += 1;
                while i < lines.len() && !lines[i].trim().is_empty() {
                    if !value.is_empty() {
                        value.push('\n');
                    }
                    value.push_str(lines[i].trim_end());
                    i += 1;
                }
                tags.insert(tag.to_string(), value);
            }
            i += 1;
        }
        Ok(Molecule { name, atoms, tags })
    }

    /// Serialize back to one SDF record (no trailing `$$$$`).
    pub fn to_sdf(&self) -> String {
        // hand-rolled atom-line rendering: `{:>10.4}` goes through the
        // exact (Dragon) float formatter and dominated the whole VS
        // pipeline's L3 profile (EXPERIMENTS.md §Perf); fixed-point
        // rendering of the already-4-decimal coordinates is ~10x faster
        let mut out = String::with_capacity(64 + self.atoms.len() * 70);
        out.push_str(&self.name);
        out.push('\n');
        out.push_str("  MaRe-sim\n\n"); // program + comment lines
        out.push_str(&format!("{:>3}{:>3}  0  0  0  0  0  0  0  0999 V2000\n",
            self.atoms.len(), 0));
        for a in &self.atoms {
            push_f4_w10(&mut out, a.x);
            push_f4_w10(&mut out, a.y);
            push_f4_w10(&mut out, a.z);
            out.push(' ');
            out.push_str(&a.element);
            for _ in a.element.len()..3 {
                out.push(' ');
            }
            out.push_str(" 0  0  0  0  0  0  0  0  0  0  0  0\n");
        }
        out.push_str("M  END\n");
        for (tag, value) in &self.tags {
            out.push_str(&format!("> <{tag}>\n{value}\n\n"));
        }
        out.trim_end().to_string()
    }

    /// Numeric tag accessor (e.g. the FRED score).
    pub fn tag_f32(&self, tag: &str) -> Option<f32> {
        self.tags.get(tag).and_then(|v| v.trim().parse().ok())
    }
}

/// Fast decimal f32 parse for the common SDF shape `[-]intpart[.frac]`
/// with few digits; falls back to `str::parse` for anything else
/// (exponents, long mantissas, inf/nan).
pub fn parse_f32(s: &str) -> Option<f32> {
    let b = s.as_bytes();
    if b.is_empty() {
        return None;
    }
    let (neg, mut i) = match b[0] {
        b'-' => (true, 1),
        b'+' => (false, 1),
        _ => (false, 0),
    };
    let mut mant: u64 = 0;
    let mut digits = 0u32;
    let mut frac_digits = 0i32;
    let mut seen_dot = false;
    while i < b.len() {
        match b[i] {
            c @ b'0'..=b'9' => {
                mant = mant * 10 + (c - b'0') as u64;
                digits += 1;
                if seen_dot {
                    frac_digits += 1;
                }
            }
            b'.' if !seen_dot => seen_dot = true,
            // exponent / hex / inf / nan: punt to std
            _ => return s.parse().ok(),
        }
        i += 1;
    }
    if digits == 0 || digits > 15 {
        return s.parse().ok();
    }
    // exact in f64 for <=15 digits; one rounding to f32 like std
    const POW10: [f64; 16] = [
        1e0, 1e1, 1e2, 1e3, 1e4, 1e5, 1e6, 1e7, 1e8, 1e9, 1e10, 1e11, 1e12, 1e13, 1e14,
        1e15,
    ];
    let v = mant as f64 / POW10[frac_digits as usize];
    Some(if neg { -v as f32 } else { v as f32 })
}

/// Fixed-point `{:>10.4}` equivalent: render `v` with exactly 4
/// decimals, right-aligned to width 10, without invoking the generic
/// exact float formatter. Matches `format!("{:>10.4}", v)` for every
/// value the SDF path produces (|v| < 10^5, finite).
pub fn push_f4_w10(out: &mut String, v: f32) {
    debug_assert!(v.is_finite());
    let neg = v.is_sign_negative(); // std keeps the sign even for -0.0000
    // ties-to-even to match std's exact formatter (e.g. 6189.28125
    // renders as 6189.2812, not .2813)
    let n = (f64::from(v).abs() * 1e4).round_ties_even() as u64;
    let (int, frac) = (n / 10_000, n % 10_000);

    // digits, rendered backwards into a stack buffer
    let mut buf = [0u8; 24];
    let mut len = 0;
    let mut f = frac;
    for _ in 0..4 {
        buf[len] = b'0' + (f % 10) as u8;
        f /= 10;
        len += 1;
    }
    buf[len] = b'.';
    len += 1;
    let mut i = int;
    loop {
        buf[len] = b'0' + (i % 10) as u8;
        i /= 10;
        len += 1;
        if i == 0 {
            break;
        }
    }
    if neg {
        buf[len] = b'-';
        len += 1;
    }
    for _ in len..10 {
        out.push(' ');
    }
    for k in (0..len).rev() {
        out.push(buf[k] as char);
    }
}

/// Parse a multi-record SDF chunk (records separated by `$$$$` lines).
pub fn parse_many(text: &str) -> Result<Vec<Molecule>> {
    let mut out = Vec::new();
    for rec in text.split("$$$$") {
        if rec.trim().is_empty() {
            continue;
        }
        out.push(Molecule::parse(rec.trim_matches('\n'))?);
    }
    Ok(out)
}

/// Serialize molecules with `$$$$` separators (paper mount-point format).
pub fn write_many(mols: &[Molecule]) -> String {
    let mut out = String::new();
    for m in mols {
        out.push_str(&m.to_sdf());
        out.push_str("\n$$$$\n");
    }
    out
}

fn fmt_err(detail: String) -> MareError {
    MareError::Format { format: "sdf", detail }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_point_formatter_matches_std() {
        let mut rng = crate::util::rng::Rng::new(99);
        for _ in 0..5000 {
            let v = (rng.range_f32(-9999.0, 9999.0) * 1e4).round() / 1e4;
            let mut fast = String::new();
            push_f4_w10(&mut fast, v);
            assert_eq!(fast, format!("{v:>10.4}"), "v={v}");
        }
        for v in [0.0f32, -0.0, 0.00004, -0.00004, 12345.4999] {
            let mut fast = String::new();
            push_f4_w10(&mut fast, v);
            assert_eq!(fast, format!("{v:>10.4}"), "v={v}");
        }
    }

    fn mol(name: &str) -> Molecule {
        Molecule {
            name: name.into(),
            atoms: vec![
                Atom { x: 0.0, y: 0.0, z: 0.0, element: "C".into() },
                Atom { x: 1.5, y: 0.0, z: 0.0, element: "N".into() },
            ],
            tags: BTreeMap::from([("ZINC_ID".to_string(), name.to_string())]),
        }
    }

    #[test]
    fn roundtrip_single() {
        let m = mol("ZINC001");
        let parsed = Molecule::parse(&m.to_sdf()).unwrap();
        assert_eq!(parsed.name, "ZINC001");
        assert_eq!(parsed.atoms.len(), 2);
        assert_eq!(parsed.atoms[1].element, "N");
        assert!((parsed.atoms[1].x - 1.5).abs() < 1e-4);
        assert_eq!(parsed.tags["ZINC_ID"], "ZINC001");
    }

    #[test]
    fn roundtrip_many() {
        let mols = vec![mol("A"), mol("B"), mol("C")];
        let text = write_many(&mols);
        let parsed = parse_many(&text).unwrap();
        assert_eq!(parsed.len(), 3);
        assert_eq!(parsed[2].name, "C");
        // stable under a second round-trip
        assert_eq!(write_many(&parsed), text);
    }

    #[test]
    fn score_tag_accessor() {
        let mut m = mol("X");
        m.tags.insert("FRED Chemgauss4 score".into(), "-42.25".into());
        assert_eq!(m.tag_f32("FRED Chemgauss4 score"), Some(-42.25));
        assert_eq!(m.tag_f32("missing"), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Molecule::parse("x").is_err());
        assert!(Molecule::parse("name\n\n\nnot-a-count line\n").is_err());
    }

    #[test]
    fn multiline_tag_value() {
        let text = "m\n  p\n\n  1  0  0  0  0  0  0  0  0  0999 V2000\n    0.0 0.0 0.0 C 0\nM  END\n> <NOTES>\nline1\nline2\n\n";
        let m = Molecule::parse(text).unwrap();
        assert_eq!(m.tags["NOTES"], "line1\nline2");
    }
}
