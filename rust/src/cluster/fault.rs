//! Fault injection + lineage-based recovery.
//!
//! The substrate inherits Spark's fault story (§1.2.2 "relies on Apache
//! Spark to provide ... fault tolerance"): a failed task attempt is
//! retried, and when a worker is lost its partitions are recomputed from
//! lineage. Tests and ablation benches inject faults through
//! [`FaultSpec`] to verify both paths end-to-end: results must be
//! byte-identical to a fault-free run, with the extra virtual time
//! showing up in the stage report.

/// What to break during a run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultSpec {
    /// Fail the first `failures` attempts of (stage, partition); the
    /// retry (attempt index >= failures) succeeds.
    TaskFlake { stage: usize, partition: usize, failures: u32 },
    /// Lose a worker right after `after_stage` completes: its stage
    /// outputs are recomputed on the survivors, and the worker takes no
    /// further tasks.
    WorkerLoss { worker: usize, after_stage: usize },
    /// Worker runs `factor` times slower than nominal for the whole
    /// run — a plantable, deterministic straggler (the target of
    /// speculative execution). Nothing *fails*; the worker just drags
    /// every stage it takes tasks in.
    SlowWorker { worker: usize, factor: f64 },
}

impl FaultSpec {
    /// Should this (stage, partition, attempt) fail?
    pub fn fails_task(&self, stage: usize, partition: usize, attempt: u32) -> bool {
        match *self {
            FaultSpec::TaskFlake { stage: s, partition: p, failures } => {
                s == stage && p == partition && attempt < failures
            }
            FaultSpec::WorkerLoss { .. } | FaultSpec::SlowWorker { .. } => false,
        }
    }

    /// Worker lost after this stage, if any.
    pub fn worker_lost_after(&self, stage: usize) -> Option<usize> {
        match *self {
            FaultSpec::WorkerLoss { worker, after_stage } if after_stage == stage => {
                Some(worker)
            }
            _ => None,
        }
    }

    /// The planted straggler, if any: `(worker, slowdown factor)`.
    pub fn slow_worker(&self) -> Option<(usize, f64)> {
        match *self {
            FaultSpec::SlowWorker { worker, factor } => Some((worker, factor)),
            _ => None,
        }
    }

    /// Parse the `mare run --fault` grammar. Today only the straggler
    /// form `W:slow:F` (slow worker W down by factor F > 0) is
    /// CLI-reachable; the other variants are injected by tests.
    pub fn parse(s: &str) -> Result<FaultSpec, String> {
        let parts: Vec<&str> = s.split(':').collect();
        match parts.as_slice() {
            [w, "slow", f] => {
                let worker = w
                    .parse::<usize>()
                    .map_err(|_| format!("--fault {s}: worker must be a number, got {w:?}"))?;
                let factor = f
                    .parse::<f64>()
                    .map_err(|_| format!("--fault {s}: factor must be a number, got {f:?}"))?;
                if !factor.is_finite() || factor <= 0.0 {
                    return Err(format!("--fault {s}: factor must be positive, got {f}"));
                }
                Ok(FaultSpec::SlowWorker { worker, factor })
            }
            _ => Err(format!("--fault {s}: expected W:slow:F (e.g. --fault 0:slow:4)")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_flake_fails_only_configured_attempts() {
        let f = FaultSpec::TaskFlake { stage: 1, partition: 2, failures: 2 };
        assert!(f.fails_task(1, 2, 0));
        assert!(f.fails_task(1, 2, 1));
        assert!(!f.fails_task(1, 2, 2)); // retry succeeds
        assert!(!f.fails_task(0, 2, 0)); // other stage untouched
        assert!(!f.fails_task(1, 3, 0)); // other partition untouched
        assert_eq!(f.worker_lost_after(1), None);
    }

    #[test]
    fn worker_loss_triggers_once() {
        let f = FaultSpec::WorkerLoss { worker: 3, after_stage: 0 };
        assert_eq!(f.worker_lost_after(0), Some(3));
        assert_eq!(f.worker_lost_after(1), None);
        assert!(!f.fails_task(0, 0, 0));
    }

    #[test]
    fn slow_worker_drags_but_never_fails() {
        let f = FaultSpec::SlowWorker { worker: 2, factor: 4.0 };
        assert_eq!(f.slow_worker(), Some((2, 4.0)));
        assert!(!f.fails_task(0, 0, 0));
        assert_eq!(f.worker_lost_after(0), None);
        let flake = FaultSpec::TaskFlake { stage: 0, partition: 0, failures: 1 };
        assert_eq!(flake.slow_worker(), None);
    }

    #[test]
    fn parse_accepts_only_the_straggler_grammar() {
        assert_eq!(
            FaultSpec::parse("0:slow:4").unwrap(),
            FaultSpec::SlowWorker { worker: 0, factor: 4.0 }
        );
        assert_eq!(
            FaultSpec::parse("3:slow:1.5").unwrap(),
            FaultSpec::SlowWorker { worker: 3, factor: 1.5 }
        );
        for bad in ["", "0:slow", "0:slow:0", "0:slow:-2", "x:slow:4", "0:kill:4", "0:slow:nan"] {
            let err = FaultSpec::parse(bad).unwrap_err();
            assert!(err.contains("--fault"), "{bad:?} -> {err}");
        }
    }
}
