//! Fault injection + lineage-based recovery.
//!
//! The substrate inherits Spark's fault story (§1.2.2 "relies on Apache
//! Spark to provide ... fault tolerance"): a failed task attempt is
//! retried, and when a worker is lost its partitions are recomputed from
//! lineage. Tests and ablation benches inject faults through
//! [`FaultSpec`] to verify both paths end-to-end: results must be
//! byte-identical to a fault-free run, with the extra virtual time
//! showing up in the stage report.

/// What to break during a run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultSpec {
    /// Fail the first `failures` attempts of (stage, partition); the
    /// retry (attempt index >= failures) succeeds.
    TaskFlake { stage: usize, partition: usize, failures: u32 },
    /// Lose a worker right after `after_stage` completes: its stage
    /// outputs are recomputed on the survivors, and the worker takes no
    /// further tasks.
    WorkerLoss { worker: usize, after_stage: usize },
}

impl FaultSpec {
    /// Should this (stage, partition, attempt) fail?
    pub fn fails_task(&self, stage: usize, partition: usize, attempt: u32) -> bool {
        match *self {
            FaultSpec::TaskFlake { stage: s, partition: p, failures } => {
                s == stage && p == partition && attempt < failures
            }
            FaultSpec::WorkerLoss { .. } => false,
        }
    }

    /// Worker lost after this stage, if any.
    pub fn worker_lost_after(&self, stage: usize) -> Option<usize> {
        match *self {
            FaultSpec::WorkerLoss { worker, after_stage } if after_stage == stage => {
                Some(worker)
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_flake_fails_only_configured_attempts() {
        let f = FaultSpec::TaskFlake { stage: 1, partition: 2, failures: 2 };
        assert!(f.fails_task(1, 2, 0));
        assert!(f.fails_task(1, 2, 1));
        assert!(!f.fails_task(1, 2, 2)); // retry succeeds
        assert!(!f.fails_task(0, 2, 0)); // other stage untouched
        assert!(!f.fails_task(1, 3, 0)); // other partition untouched
        assert_eq!(f.worker_lost_after(1), None);
    }

    #[test]
    fn worker_loss_triggers_once() {
        let f = FaultSpec::WorkerLoss { worker: 3, after_stage: 0 };
        assert_eq!(f.worker_lost_after(0), Some(3));
        assert_eq!(f.worker_lost_after(1), None);
        assert!(!f.fails_task(0, 0, 0));
    }
}
