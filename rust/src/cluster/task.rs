//! Task execution: really run a stage's op chain over one partition,
//! accounting virtual cost as we go.
//!
//! The virtual duration decomposition follows `simtime::cost`:
//! container start + stage-in (partition -> mount) + compute (tool
//! model) + stage-out, per op in the fused chain. Image *pull* is a
//! per-(worker, image) cost and is charged by the scheduler, not here.

use crate::dataset::{Record, TaskContext};
use crate::error::Result;
use crate::simtime::{DiskModel, Duration, TaskCost};

use super::stage::Stage;

/// Docker `run` overhead for a warm image (measured ~0.4-1.5 s in the
/// wild; the paper's §Data Handling treats it as fixed).
pub const CONTAINER_START: Duration = Duration(900_000); // 0.9 s

/// Outcome of really running one task.
pub struct TaskResult {
    pub records: Vec<Record>,
    pub cost: TaskCost,
    pub bytes_in: u64,
    pub bytes_out: u64,
}

fn bytes_of(records: &[Record]) -> u64 {
    records.iter().map(Record::size_bytes).sum()
}

/// Run the fused op chain over one partition's records.
///
/// Takes the partition's records by shared handle: record payloads are
/// `Arc`-backed ([`crate::util::bytes::Shared`]), so the per-attempt
/// working set below is a vector of refcount bumps — retries never
/// deep-copy the input partition (asserted by the copy-counter tests
/// in `rust/tests/zero_copy.rs`).
pub fn run_task(stage: &Stage, ctx: &TaskContext, input: &[Record]) -> Result<TaskResult> {
    let started = std::time::Instant::now();
    let bytes_in = bytes_of(input);

    let mut cost = TaskCost { cpus: stage.cpus(), ..Default::default() };
    let mut records = input.to_vec();

    for op in &stage.ops {
        let in_bytes = bytes_of(&records);
        let in_records = records.len() as u64;

        // mount-point staging cost: tmpfs by default, disk when the op
        // opts out (Listing 3's TMPDIR override); streamed sides skip
        // materialization entirely (§1.4 future work)
        let mount = if op.uses_disk_mount() { DiskModel::hdd() } else { DiskModel::tmpfs() };
        let (stream_in, stream_out) = op.streams();
        if op.image().is_some() {
            cost.container_start += CONTAINER_START;
            if !stream_in {
                cost.stage_in += mount.rw(in_bytes);
            }
        }

        records = op.apply(ctx, records)?;

        let out_bytes = bytes_of(&records);
        if op.image().is_some() && !stream_out {
            cost.stage_out += mount.rw(out_bytes);
        }
        cost.compute += op.cost_model().compute(in_bytes, in_records);
    }

    let bytes_out = bytes_of(&records);
    cost.real = started.elapsed();
    Ok(TaskResult { records, cost, bytes_in, bytes_out })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::stage::{Stage, StageOutput};
    use crate::dataset::{ClosureOp, PartitionOp};
    use crate::simtime::CostModel;
    use std::sync::Arc;

    struct FakeContainerOp;
    impl PartitionOp for FakeContainerOp {
        fn apply(&self, _: &TaskContext, records: Vec<Record>) -> Result<Vec<Record>> {
            // halve the records (a filter-like tool)
            Ok(records.into_iter().step_by(2).collect())
        }
        fn cost_model(&self) -> CostModel {
            CostModel {
                fixed: Duration::seconds(1.0),
                secs_per_byte: 0.0,
                secs_per_record: 0.5,
                cpus: 2,
            }
        }
        fn image(&self) -> Option<&str> {
            Some("ubuntu")
        }
        fn label(&self) -> String {
            "fake".into()
        }
    }

    fn ctx() -> TaskContext {
        TaskContext { partition: 0, num_partitions: 1, attempt: 0, seed: 1 }
    }

    #[test]
    fn accounts_container_lifecycle_and_compute() {
        let stage = Stage {
            id: 0,
            ops: vec![Arc::new(FakeContainerOp)],
            output: StageOutput::Final,
            combiner: None,
        };
        // records big enough that tmpfs staging is > 1 µs
        let input: Vec<Record> =
            (0..4).map(|_| Record::text("x".repeat(64 * 1024))).collect();
        let r = run_task(&stage, &ctx(), &input).unwrap();
        assert_eq!(r.records.len(), 2);
        assert_eq!(r.cost.container_start, CONTAINER_START);
        // fixed 1.0 + 4 records * 0.5
        assert!((r.cost.compute.as_seconds() - 3.0).abs() < 1e-3);
        assert_eq!(r.cost.cpus, 2);
        assert!(r.cost.stage_in > Duration::ZERO);
        assert!(r.bytes_in > r.bytes_out);
    }

    #[test]
    fn native_ops_have_no_container_cost() {
        let stage = Stage {
            id: 0,
            ops: vec![Arc::new(ClosureOp {
                f: |_: &TaskContext, r| Ok(r),
                name: "native".into(),
            })],
            output: StageOutput::Final,
            combiner: None,
        };
        let r = run_task(&stage, &ctx(), &[Record::text("x")]).unwrap();
        assert_eq!(r.cost.container_start, Duration::ZERO);
        assert_eq!(r.cost.stage_in, Duration::ZERO);
        assert_eq!(r.cost.total(), Duration::ZERO);
    }

    #[test]
    fn chain_costs_accumulate() {
        let stage = Stage {
            id: 0,
            ops: vec![Arc::new(FakeContainerOp), Arc::new(FakeContainerOp)],
            output: StageOutput::Final,
            combiner: None,
        };
        let input: Vec<Record> = (0..4).map(|i| Record::text(format!("{i}"))).collect();
        let r = run_task(&stage, &ctx(), &input).unwrap();
        assert_eq!(r.records.len(), 1);
        assert_eq!(r.cost.container_start, CONTAINER_START + CONTAINER_START);
        // (1.0 + 4*0.5) + (1.0 + 2*0.5)
        assert!((r.cost.compute.as_seconds() - 5.0).abs() < 1e-6);
    }
}
