//! DAG → stage compiler.
//!
//! Walks a [`Plan`] lineage and cuts it into pipelined stages exactly
//! like Spark's DAGScheduler over the ops MaRe emits: consecutive
//! `MapPartitions` fuse into one stage (one task per partition, all ops
//! applied back-to-back in memory); every `Repartition` ends the current
//! stage with a shuffle. Listing 1's `map().reduce()` therefore compiles
//! to K+1 stages for a depth-K tree reduce, matching Figure 2.

use std::sync::Arc;

use crate::dataset::{Partition, Partitioner, PartitionOp, Plan};

/// What happens to a stage's output partitions.
pub enum StageOutput {
    /// Job output: partitions are collected back to the driver.
    Final,
    /// Shuffle into the next stage's input partitioning.
    Shuffle(Partitioner),
}

impl std::fmt::Debug for StageOutput {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StageOutput::Final => write!(f, "Final"),
            StageOutput::Shuffle(p) => write!(f, "Shuffle({p:?})"),
        }
    }
}

/// One pipelined stage: a chain of narrow ops, then an output boundary.
pub struct Stage {
    pub id: usize,
    /// Ops applied in order to each input partition (may be empty: a
    /// pure shuffle stage, e.g. `repartition` directly after a source).
    pub ops: Vec<Arc<dyn PartitionOp>>,
    pub output: StageOutput,
    /// Map-side combiner of this stage's shuffle boundary, if the
    /// optimizer pushed one below it (`Plan::Repartition::combine`):
    /// runs once per output partition before routing, so the shuffle
    /// ships partial aggregates. Only meaningful with
    /// `StageOutput::Shuffle`.
    pub combiner: Option<Arc<dyn PartitionOp>>,
}

impl Stage {
    /// Distinct images the stage's ops run in (pull-cost accounting);
    /// the map-side combiner's image counts — it launches on the same
    /// workers.
    pub fn images(&self) -> Vec<&str> {
        let mut out: Vec<&str> = Vec::new();
        for op in self.ops.iter().chain(self.combiner.iter()) {
            if let Some(img) = op.image() {
                if !out.contains(&img) {
                    out.push(img);
                }
            }
        }
        out
    }

    /// vCPU slots one task of this stage occupies (max over the chain —
    /// ops run sequentially inside the task, Spark allocates the max).
    pub fn cpus(&self) -> u32 {
        self.ops.iter().map(|o| o.cost_model().cpus).max().unwrap_or(1)
    }

    pub fn describe(&self) -> String {
        let ops: Vec<String> = self.ops.iter().map(|o| o.label()).collect();
        let combine = match &self.combiner {
            Some(c) => format!(" +combine[{}]", c.label()),
            None => String::new(),
        };
        format!("stage {} [{}]{} -> {:?}", self.id, ops.join(" | "), combine, self.output)
    }
}

/// A compiled physical plan.
pub struct PhysicalPlan {
    /// Input partitions of stage 0.
    pub source: Vec<Partition>,
    pub source_label: String,
    pub stages: Vec<Stage>,
}

impl PhysicalPlan {
    pub fn describe(&self) -> String {
        let mut s = format!("source[{}] x{}\n", self.source_label, self.source.len());
        for st in &self.stages {
            s.push_str(&st.describe());
            s.push('\n');
        }
        s
    }
}

/// Compile a lineage into stages.
pub fn compile(plan: &Plan) -> PhysicalPlan {
    // Collect lineage source -> root.
    let mut chain: Vec<&Plan> = Vec::new();
    let mut cur = plan;
    loop {
        chain.push(cur);
        match cur {
            Plan::Source { .. } => break,
            Plan::MapPartitions { parent, .. } | Plan::Repartition { parent, .. } => {
                cur = parent.as_ref()
            }
        }
    }
    chain.reverse();

    let (source, source_label) = match chain[0] {
        Plan::Source { partitions, label } => (partitions.clone(), label.clone()),
        _ => unreachable!("lineage must bottom out at a source"),
    };

    let mut stages = Vec::new();
    let mut ops: Vec<Arc<dyn PartitionOp>> = Vec::new();
    for node in &chain[1..] {
        match node {
            Plan::MapPartitions { op, .. } => ops.push(op.clone()),
            Plan::Repartition { partitioner, combine, .. } => {
                stages.push(Stage {
                    id: stages.len(),
                    ops: std::mem::take(&mut ops),
                    output: StageOutput::Shuffle(partitioner.clone()),
                    combiner: combine.clone(),
                });
            }
            Plan::Source { .. } => unreachable!("source can only be the lineage root"),
        }
    }
    stages.push(Stage { id: stages.len(), ops, output: StageOutput::Final, combiner: None });

    PhysicalPlan { source, source_label, stages }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{ClosureOp, Dataset, Record, TaskContext};

    fn ds() -> Dataset {
        Dataset::parallelize((0..8).map(|i| Record::text(format!("{i}"))).collect(), 4)
    }

    fn id_op(name: &str) -> Arc<dyn PartitionOp> {
        let name = name.to_string();
        Arc::new(ClosureOp { f: |_: &TaskContext, r| Ok(r), name })
    }

    #[test]
    fn consecutive_maps_fuse_into_one_stage() {
        let d = ds().map_partitions(id_op("a")).map_partitions(id_op("b"));
        let pp = compile(d.plan());
        assert_eq!(pp.stages.len(), 1);
        assert_eq!(pp.stages[0].ops.len(), 2);
        assert!(matches!(pp.stages[0].output, StageOutput::Final));
        assert_eq!(pp.source.len(), 4);
    }

    #[test]
    fn repartition_cuts_a_stage() {
        // map | shuffle | map  =>  2 stages
        let d = ds()
            .map_partitions(id_op("m1"))
            .repartition(2)
            .map_partitions(id_op("m2"));
        let pp = compile(d.plan());
        assert_eq!(pp.stages.len(), 2);
        assert!(matches!(pp.stages[0].output, StageOutput::Shuffle(_)));
        assert!(matches!(pp.stages[1].output, StageOutput::Final));
        assert_eq!(pp.stages[1].ops.len(), 1);
    }

    #[test]
    fn tree_reduce_shape_matches_figure2() {
        // map + K=2 tree reduce: agg,shrink,agg,shrink,agg => 3 stages
        let d = ds()
            .map_partitions(id_op("map"))
            .map_partitions(id_op("agg"))
            .repartition(2)
            .map_partitions(id_op("agg"))
            .repartition(1)
            .map_partitions(id_op("agg"));
        let pp = compile(d.plan());
        assert_eq!(pp.stages.len(), 3);
        assert_eq!(pp.stages[0].ops.len(), 2); // map fused with first agg
    }

    #[test]
    fn shuffle_only_plan_has_empty_op_stage() {
        let d = ds().repartition(2);
        let pp = compile(d.plan());
        assert_eq!(pp.stages.len(), 2);
        assert!(pp.stages[0].ops.is_empty());
    }

    #[test]
    fn describe_is_readable() {
        let d = ds().map_partitions(id_op("fred")).repartition(1);
        let pp = compile(d.plan());
        let s = pp.describe();
        assert!(s.contains("source[parallelize] x4"), "{s}");
        assert!(s.contains("fred"), "{s}");
        assert!(s.contains("Shuffle"), "{s}");
    }
}
