//! Scoped worker thread pool for *real* task execution.
//!
//! The DES decides *when* tasks run in virtual time; this pool decides
//! how the actual byte-crunching is spread over host cores. No tokio on
//! the hot path (Cargo.toml note): plain scoped threads + a work index.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Run `f(i)` for every `i in 0..n` on up to `threads` host threads,
/// collecting results in input order.
pub fn run_indexed<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Send + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return (0..n).map(f).collect();
    }

    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots: Vec<SendPtr<T>> =
        out.iter_mut().map(|s| SendPtr(s as *mut Option<T>)).collect();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            let next = &next;
            let f = &f;
            let slots = &slots;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                // SAFETY: each index is claimed exactly once via the
                // atomic counter, so no two threads touch the same slot;
                // the scope outlives all writes.
                unsafe { slots[i].0.write(Some(v)) };
            });
        }
    });

    out.into_iter().map(|v| v.expect("worker finished")).collect()
}

/// Raw-pointer wrapper that is Send because slot ownership is made
/// exclusive by the atomic work index.
struct SendPtr<T>(*mut Option<T>);
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Host parallelism for the real-execution pool.
pub fn host_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn preserves_order() {
        let out = run_indexed(100, 8, |i| i * 2);
        assert_eq!(out, (0..100).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn runs_every_index_exactly_once() {
        let hits = AtomicU64::new(0);
        let out = run_indexed(1000, 16, |i| {
            hits.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1000);
        assert_eq!(out.len(), 1000);
    }

    #[test]
    fn zero_and_one_tasks() {
        assert!(run_indexed(0, 4, |i| i).is_empty());
        assert_eq!(run_indexed(1, 4, |i| i + 1), vec![1]);
    }

    #[test]
    fn single_thread_path() {
        assert_eq!(run_indexed(5, 1, |i| i), vec![0, 1, 2, 3, 4]);
    }
}
