//! The Spark-substrate: a cluster that executes [`Dataset`] lineages.
//!
//! Execution is *execution-driven DES* (DESIGN.md §6): every task really
//! runs (real bytes through real tools, including PJRT artifacts) on a
//! host thread pool, while its *duration* is charged to a virtual clock
//! against a calibrated cluster model — N workers × M vCPU slots,
//! locality-aware list scheduling, per-image pull costs, NIC-modelled
//! shuffles. The paper's metrics (WSE, speedup) are ratios of virtual
//! makespans, so the curves are deterministic and hardware-independent,
//! while outputs stay real and verifiable.
//!
//! * [`stage`] — DAG → pipelined-stage compiler (Figure 1/2 semantics)
//! * [`task`] — real execution + per-task cost accounting
//! * [`shuffle`] — routing + data-motion accounting between stages
//! * [`fault`] — fault injection and lineage-based recovery
//! * [`pool`] — host thread pool for the real execution

pub mod fault;
pub mod pool;
pub mod shuffle;
pub mod stage;
pub mod task;

use std::collections::HashSet;
use std::sync::{Arc, Mutex};

use crate::container::Registry;
use crate::dataset::{Dataset, Partition, TaskContext};
use crate::error::{MareError, Result};
use crate::simtime::{Duration, NetModel, SlotSchedule, SlotTask, SpecOutcome, VirtualTime};

pub use crate::simtime::SpeculationPolicy;
pub use fault::FaultSpec;
pub use shuffle::ShuffleStats;
pub use stage::{compile, PhysicalPlan, Stage, StageOutput};

/// Cluster shape + models. Defaults mirror the paper's testbed: 16
/// workers x 8 vCPUs on an OpenStack cloud, 10 GbE-class interconnect.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub workers: usize,
    pub vcpus_per_worker: u32,
    /// Spark's `spark.locality.wait` analogue.
    pub locality_wait: Duration,
    /// Intra-cluster NIC (shuffles, remote partition reads).
    pub net: NetModel,
    /// Pipe to the image registry (Docker Hub analogue).
    pub registry_net: NetModel,
    /// Max attempts per task (Spark default 4 = 3 retries).
    pub max_attempts: u32,
    /// Injected fault, if any.
    pub fault: Option<FaultSpec>,
    /// Speculative execution of straggler tasks (None = off). Racing a
    /// copy launches extra containers, so jobs that pin launch counts
    /// leave this off; the audit weakens to `launches >= tasks`.
    pub speculation: Option<SpeculationPolicy>,
    /// Base seed for per-task deterministic RNG ($RANDOM etc).
    pub seed: u64,
    /// Host threads for real execution (None = all cores).
    pub host_threads: Option<usize>,
}

impl ClusterConfig {
    /// The paper's evaluation cluster: 16 workers x 8 vCPUs.
    pub fn paper() -> Self {
        ClusterConfig::sized(16, 8)
    }

    pub fn sized(workers: usize, vcpus_per_worker: u32) -> Self {
        ClusterConfig {
            workers: workers.max(1),
            vcpus_per_worker: vcpus_per_worker.max(1),
            locality_wait: Duration::seconds(3.0),
            net: NetModel::lan(),
            registry_net: NetModel::new(0.030, 120e6).with_aggregate(1.2e9),
            max_attempts: 4,
            fault: None,
            speculation: None,
            seed: 0x4d6152655f764c,
            host_threads: None,
        }
    }

    pub fn with_fault(mut self, fault: FaultSpec) -> Self {
        self.fault = Some(fault);
        self
    }

    pub fn with_speculation(mut self, policy: SpeculationPolicy) -> Self {
        self.speculation = Some(policy);
        self
    }

    pub fn total_vcpus(&self) -> u32 {
        self.workers as u32 * self.vcpus_per_worker
    }
}

/// Per-stage execution report.
#[derive(Debug, Clone, Default)]
pub struct StageReport {
    pub stage: usize,
    pub tasks: usize,
    /// Task attempts that were failed by injection and retried.
    pub retried: usize,
    /// Tasks recomputed due to worker loss (lineage recovery).
    pub recomputed: usize,
    /// Tasks that ran on their locality-preferred worker.
    pub local_tasks: usize,
    /// Speculative copies launched against stragglers.
    pub speculated: usize,
    /// Races the speculative copy won (original cancelled).
    pub spec_wins: usize,
    /// Attempts cancelled by first-finisher-wins — exactly one loser
    /// per race, so this always equals `speculated`.
    pub spec_cancelled: usize,
    pub makespan: Duration,
    pub shuffle: ShuffleStats,
    /// Sum of virtual task costs (utilization = busy / (makespan*slots)).
    pub busy: Duration,
    pub bytes_in: u64,
    pub bytes_out: u64,
    /// Real wall-clock spent actually executing this stage's tasks.
    pub real: std::time::Duration,
}

/// Whole-job report.
#[derive(Debug, Clone, Default)]
pub struct RunReport {
    pub stages: Vec<StageReport>,
    /// Virtual end-to-end makespan (the paper's measured quantity).
    pub makespan: VirtualTime,
    /// Real wall-clock of the whole run (harness-side, §Perf).
    pub real: std::time::Duration,
}

impl RunReport {
    pub fn total_shuffled_bytes(&self) -> u64 {
        self.stages.iter().map(|s| s.shuffle.bytes_total).sum()
    }

    /// Bytes the map sides produced before any map-side combiner ran —
    /// what the job would have shuffled with combining disabled.
    pub fn total_pre_combine_bytes(&self) -> u64 {
        self.stages.iter().map(|s| s.shuffle.bytes_pre_combine).sum()
    }

    pub fn total_remote_bytes(&self) -> u64 {
        self.stages.iter().map(|s| s.shuffle.bytes_remote).sum()
    }

    pub fn num_shuffles(&self) -> usize {
        self.stages.iter().filter(|s| s.shuffle.bytes_total > 0 || s.shuffle.duration > Duration::ZERO).count()
    }

    pub fn locality_fraction(&self) -> f64 {
        let (local, total) = self
            .stages
            .iter()
            .fold((0usize, 0usize), |(l, t), s| (l + s.local_tasks, t + s.tasks));
        if total == 0 {
            1.0
        } else {
            local as f64 / total as f64
        }
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "makespan {} | {} stages | shuffled {} B ({} B remote) | locality {:.0}%\n",
            self.makespan,
            self.stages.len(),
            self.total_shuffled_bytes(),
            self.total_remote_bytes(),
            self.locality_fraction() * 100.0
        );
        for st in &self.stages {
            s.push_str(&format!(
                "  stage {}: {} tasks ({} local, {} retried, {} recomputed), makespan {}, shuffle {} B\n",
                st.stage, st.tasks, st.local_tasks, st.retried, st.recomputed, st.makespan, st.shuffle.bytes_total
            ));
            if st.speculated > 0 {
                s.push_str(&format!(
                    "    speculation: {} copies launched, {} won, {} attempts cancelled\n",
                    st.speculated, st.spec_wins, st.spec_cancelled
                ));
            }
        }
        s
    }
}

/// Result of [`Cluster::run`]: final partitions + the report.
pub struct RunOutput {
    pub partitions: Vec<Partition>,
    pub report: RunReport,
}

impl RunOutput {
    /// Concatenate all text records (driver-side `collect`).
    pub fn collect_text(&self, sep: &str) -> String {
        let recs: Vec<String> = self
            .partitions
            .iter()
            .flat_map(|p| p.records.iter())
            .filter_map(|r| r.as_text().map(String::from))
            .collect();
        crate::dataset::join_records(&recs, sep)
    }

    /// All records, driver-side.
    pub fn collect_records(&self) -> Vec<crate::dataset::Record> {
        self.partitions.iter().flat_map(|p| p.records.iter().cloned()).collect()
    }
}

/// The cluster: a registry of images + a config, able to run lineages.
pub struct Cluster {
    registry: Arc<Registry>,
    runtime: Option<crate::runtime::ToolRuntime>,
    pub config: ClusterConfig,
    /// (worker, image) pull memory across jobs (warm caches, like a
    /// long-lived Spark + Docker deployment).
    pulled: Mutex<HashSet<(usize, String)>>,
}

/// Stage-boundary persistence seam for [`Cluster::run_checkpointed`].
///
/// `committed(done, parts)` fires after stage `done - 1` finishes with
/// the exact partitions the NEXT stage would consume (post-shuffle), so
/// a later `resume()` returning `(done, parts)` re-enters the stage
/// loop at index `done` with byte-identical inputs. An `Err` from
/// `committed` aborts the run — fault injection uses that channel to
/// model a worker dying between stages.
pub trait StageCheckpointer: Sync {
    /// State left by a previous attempt: `(stages_done, partitions)`.
    /// `None` means start from the source.
    fn resume(&self) -> Option<(usize, Vec<Partition>)>;

    /// Persist the boundary after `done` stages have completed.
    fn committed(&self, done: usize, parts: &[Partition]) -> Result<()>;
}

impl Cluster {
    pub fn new(
        registry: Arc<Registry>,
        runtime: Option<crate::runtime::ToolRuntime>,
        config: ClusterConfig,
    ) -> Self {
        Cluster { registry, runtime, config, pulled: Mutex::new(HashSet::new()) }
    }

    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    pub fn runtime(&self) -> Option<&crate::runtime::ToolRuntime> {
        self.runtime.as_ref()
    }

    pub fn engine(&self) -> crate::container::Engine {
        crate::container::Engine::new(self.registry.clone(), self.runtime.clone())
    }

    /// Execute a dataset's lineage to completion.
    pub fn run(&self, dataset: &Dataset) -> Result<RunOutput> {
        self.run_checkpointed(dataset, None)
    }

    /// [`Self::run`] with a stage-checkpoint seam: after every stage
    /// boundary (post-shuffle — `current` is the next stage's exact
    /// input) the checkpointer sees the committed partitions, and a run
    /// may START from a checkpoint instead of the source, skipping the
    /// stages a previous attempt already committed. Tree-reduce levels
    /// are stages, so a depth-K reduce resumes from the last finished
    /// level. The skipped stages perform no work and no container
    /// launches — the launch-counter audit of a resumed run covers
    /// only the remaining stages.
    pub fn run_checkpointed(
        &self,
        dataset: &Dataset,
        ckpt: Option<&dyn StageCheckpointer>,
    ) -> Result<RunOutput> {
        self.run_inner(dataset, ckpt, &[])
    }

    /// [`Self::run`] over a **streamed** source: `ready[i]` is the
    /// virtual time source partition `i` was sealed by streamed ingest
    /// (`storage::ingest::ingest_text_streamed_as`). The first stage's
    /// map tasks are released per-partition at those times, so they
    /// overlap the tail of materialization instead of waiting for the
    /// whole object; later stages (and shuffles) are gated by data
    /// dependence as usual. With an empty `ready` this is exactly
    /// [`Self::run`].
    pub fn run_streamed(&self, dataset: &Dataset, ready: &[Duration]) -> Result<RunOutput> {
        self.run_inner(dataset, None, ready)
    }

    fn run_inner(
        &self,
        dataset: &Dataset,
        ckpt: Option<&dyn StageCheckpointer>,
        source_release: &[Duration],
    ) -> Result<RunOutput> {
        let wall = std::time::Instant::now();
        let pp = compile(dataset.plan());
        let mut current: Vec<Partition> = pp.source;
        let mut now = VirtualTime::ZERO;
        let mut report = RunReport::default();
        let mut dead: HashSet<usize> = HashSet::new();

        let mut skip = 0usize;
        if let Some(c) = ckpt {
            if let Some((stages_done, parts)) = c.resume() {
                if stages_done <= pp.stages.len() {
                    skip = stages_done;
                    current = parts;
                }
                // a checkpoint claiming more stages than the plan has
                // belongs to some other plan — ignore it, run fresh
            }
        }

        for (si, stage) in pp.stages.iter().enumerate().skip(skip) {
            // seal-time releases only make sense for the stage that
            // consumes the source partitions directly (and a resumed run
            // starts from a checkpoint, whose partitions are all ready)
            let release = if si == 0 && skip == 0 { source_release } else { &[] };
            let (outputs, sreport, placements) =
                self.run_stage(stage, &current, &dead, release, &mut now)?;

            // worker loss after this stage: recompute its outputs on the
            // survivors (lineage recovery), then retire the worker
            let mut outputs = outputs;
            let mut sreport = sreport;
            if let Some(lost) = self.config.fault.as_ref().and_then(|f| f.worker_lost_after(stage.id)) {
                if !dead.contains(&lost) {
                    dead.insert(lost);
                    self.recompute_lost(
                        stage,
                        &current,
                        lost,
                        &placements,
                        &dead,
                        &mut now,
                        &mut outputs,
                        &mut sreport,
                    )?;
                }
            }

            current = match &stage.output {
                StageOutput::Final => outputs
                    .into_iter()
                    .map(|(w, records)| Partition::with_locality(records, w))
                    .collect(),
                StageOutput::Shuffle(partitioner) => {
                    let (parts, stats) = shuffle::shuffle_combined(
                        outputs,
                        partitioner,
                        stage.combiner.as_ref(),
                        self.config.workers,
                        &self.config.net,
                        self.config.seed ^ stage.id as u64,
                    )?;
                    now = now + stats.duration;
                    sreport.shuffle = stats;
                    parts
                }
            };
            report.stages.push(sreport);
            if let Some(c) = ckpt {
                c.committed(stage.id + 1, &current)?;
            }
        }

        report.makespan = now;
        report.real = wall.elapsed();
        Ok(RunOutput { partitions: current, report })
    }

    /// Run one stage: real execution on the host pool, virtual
    /// scheduling onto worker slots. Returns per-task (worker, records),
    /// the stage report, and task placements (for fault recovery).
    #[allow(clippy::type_complexity)]
    fn run_stage(
        &self,
        stage: &Stage,
        inputs: &[Partition],
        dead: &HashSet<usize>,
        release: &[Duration],
        now: &mut VirtualTime,
    ) -> Result<(Vec<(usize, Vec<crate::dataset::Record>)>, StageReport, Vec<usize>)> {
        let n = inputs.len();
        let mut sreport = StageReport { stage: stage.id, tasks: n, ..Default::default() };

        // ---- real execution (with injected flaky attempts + retries)
        let threads = self.config.host_threads.unwrap_or_else(pool::host_threads);
        let results: Vec<Result<(task::TaskResult, u32)>> =
            pool::run_indexed(n, threads, |i| {
                let mut attempt = 0u32;
                loop {
                    let ctx = TaskContext {
                        partition: i,
                        num_partitions: n,
                        attempt,
                        seed: self
                            .config
                            .seed
                            .wrapping_mul(0x9E3779B97F4A7C15)
                            .wrapping_add((stage.id as u64) << 32 | (i as u64) << 8 | attempt as u64),
                    };
                    let injected_fail = self
                        .config
                        .fault
                        .as_ref()
                        .map(|f| f.fails_task(stage.id, i, attempt))
                        .unwrap_or(false);
                    // shared handle: no per-attempt deep copy of the
                    // partition (payloads are Arc-backed; the retry
                    // loop used to clone every record's bytes here)
                    let res = task::run_task(stage, &ctx, &inputs[i].records);
                    match res {
                        Ok(r) if !injected_fail => return Ok((r, attempt)),
                        Ok(_) | Err(_) if attempt + 1 < self.config.max_attempts => {
                            attempt += 1;
                            continue;
                        }
                        Ok(_) => {
                            return Err(MareError::Cluster(format!(
                                "task {}/{} exhausted {} attempts (injected failures)",
                                stage.id, i, self.config.max_attempts
                            )))
                        }
                        Err(e) => return Err(e),
                    }
                }
            });

        let mut task_results = Vec::with_capacity(n);
        for r in results {
            let (tr, attempts_used) = r?;
            sreport.retried += attempts_used as usize;
            sreport.bytes_in += tr.bytes_in;
            sreport.bytes_out += tr.bytes_out;
            sreport.real += tr.cost.real;
            task_results.push(tr);
        }

        // ---- virtual scheduling
        let mut sched =
            SlotSchedule::new(self.config.workers, self.config.vcpus_per_worker)
                .with_locality_wait(self.config.locality_wait);
        for &w in dead {
            sched.kill_worker(w);
        }
        // planted straggler: the slowed worker drags every duration
        // placed on it (the target speculative execution races)
        if let Some((w, factor)) = self.config.fault.as_ref().and_then(|f| f.slow_worker()) {
            sched.set_slowdown(w, factor);
        }
        self.charge_pulls(stage, dead, &mut sched);

        // injected failures before the first success of partition `i`
        let injected_failures = |i: usize| -> u32 {
            self.config
                .fault
                .as_ref()
                .map(|f| {
                    (0..self.config.max_attempts)
                        .take_while(|&a| f.fails_task(stage.id, i, a))
                        .count() as u32
                })
                .unwrap_or(0)
        };

        let slot_tasks: Vec<SlotTask> = task_results
            .iter()
            .enumerate()
            .map(|(i, tr)| {
                // failed attempts re-occupied the slot: charge attempts+1x
                let attempts = 1 + injected_failures(i);
                let d = Duration(tr.cost.total().0 * attempts as u64);
                SlotTask {
                    id: i,
                    duration: d,
                    cpus: tr.cost.cpus.min(self.config.vcpus_per_worker),
                    preferred: inputs[i]
                        .preferred_worker
                        .filter(|w| !dead.contains(w)),
                    remote_penalty: self.config.net.transfer(tr.bytes_in, 1),
                    release: release
                        .get(i)
                        .map(|&d| VirtualTime::ZERO + d)
                        .unwrap_or(VirtualTime::ZERO),
                }
            })
            .collect();
        let (placements, spec) = match &self.config.speculation {
            Some(policy) => sched.run_speculated(&slot_tasks, policy),
            None => (sched.run(&slot_tasks), SpecOutcome::default()),
        };

        // Speculative copies really run: re-execute each raced task
        // with the SAME context as its committed attempt, so the copy's
        // output is byte-identical by determinism (whichever attempt
        // wins the race, the stage commits the same bytes) while the
        // engine's container-launch counter genuinely ticks once per
        // copy — the audit for a speculating run is `launches >= tasks`
        // with the surplus equal to `speculated`.
        for d in &spec.decisions {
            let i = d.id;
            let attempt = injected_failures(i);
            let ctx = TaskContext {
                partition: i,
                num_partitions: n,
                attempt,
                seed: self
                    .config
                    .seed
                    .wrapping_mul(0x9E3779B97F4A7C15)
                    .wrapping_add((stage.id as u64) << 32 | (i as u64) << 8 | attempt as u64),
            };
            let copy = task::run_task(stage, &ctx, &inputs[i].records)?;
            sreport.real += copy.cost.real;
            if d.copy_wins {
                task_results[i] = copy;
            }
        }
        sreport.speculated = spec.speculated();
        sreport.spec_wins = spec.wins();
        sreport.spec_cancelled = spec.cancelled();

        // a task only counts as local when it HAD a locality preference
        // and honored it — tasks with no preference (driver-side
        // parallelize, object-store ingests) have no locality to honor,
        // and counting them inflated the metric to the point where
        // HDFS- and Swift-backed runs were indistinguishable on
        // `local_tasks` (the Figure 3 quantity)
        sreport.local_tasks = placements
            .iter()
            .zip(&slot_tasks)
            .filter(|(p, t)| t.preferred.is_some() && p.local)
            .count();
        sreport.makespan = sched.makespan() - VirtualTime::ZERO;
        sreport.busy = slot_tasks
            .iter()
            .fold(Duration::ZERO, |acc, t| acc + Duration(t.duration.0 * t.cpus as u64));
        *now = *now + sreport.makespan;

        let outputs: Vec<(usize, Vec<crate::dataset::Record>)> = task_results
            .into_iter()
            .zip(&placements)
            .map(|(tr, p)| (p.worker, tr.records))
            .collect();
        let workers: Vec<usize> = placements.iter().map(|p| p.worker).collect();
        Ok((outputs, sreport, workers))
    }

    /// Image pulls: every live worker that has not pulled one of the
    /// stage's images does so before its first task (all pullers share
    /// the registry's aggregate pipe).
    fn charge_pulls(&self, stage: &Stage, dead: &HashSet<usize>, sched: &mut SlotSchedule) {
        let mut pulled = self.pulled.lock().unwrap();
        for img_name in stage.images() {
            let Ok(img) = self.registry.pull(img_name) else { continue };
            let pullers: Vec<usize> = (0..self.config.workers)
                .filter(|w| !dead.contains(w))
                .filter(|w| !pulled.contains(&(*w, img_name.to_string())))
                .collect();
            if pullers.is_empty() {
                continue;
            }
            let dur = self
                .config
                .registry_net
                .transfer(img.size_bytes, pullers.len() as u32);
            for w in pullers {
                sched.delay_worker(w, VirtualTime::ZERO + dur);
                pulled.insert((w, img_name.to_string()));
            }
        }
    }

    /// Lineage recovery: re-run the lost worker's tasks of this stage on
    /// the survivors, appending their virtual time after the stage.
    #[allow(clippy::too_many_arguments)]
    fn recompute_lost(
        &self,
        stage: &Stage,
        inputs: &[Partition],
        lost: usize,
        placements: &[usize],
        dead: &HashSet<usize>,
        now: &mut VirtualTime,
        outputs: &mut [(usize, Vec<crate::dataset::Record>)],
        sreport: &mut StageReport,
    ) -> Result<()> {
        let victims: Vec<usize> = placements
            .iter()
            .enumerate()
            .filter(|(_, &w)| w == lost)
            .map(|(i, _)| i)
            .collect();
        if victims.is_empty() {
            return Ok(());
        }

        let threads = self.config.host_threads.unwrap_or_else(pool::host_threads);
        let redone: Vec<Result<task::TaskResult>> =
            pool::run_indexed(victims.len(), threads, |vi| {
                let i = victims[vi];
                let ctx = TaskContext {
                    partition: i,
                    num_partitions: inputs.len(),
                    attempt: 1000, // recovery attempt namespace
                    seed: self.config.seed.wrapping_add(0xF417 + i as u64),
                };
                task::run_task(stage, &ctx, &inputs[i].records)
            });

        let mut sched =
            SlotSchedule::new(self.config.workers, self.config.vcpus_per_worker)
                .with_locality_wait(self.config.locality_wait);
        for &w in dead {
            sched.kill_worker(w);
        }
        // a planted straggler stays slow during recovery too
        if let Some((w, factor)) = self.config.fault.as_ref().and_then(|f| f.slow_worker()) {
            sched.set_slowdown(w, factor);
        }
        let mut slot_tasks = Vec::with_capacity(victims.len());
        let mut results = Vec::with_capacity(victims.len());
        for (vi, r) in redone.into_iter().enumerate() {
            let tr = r?;
            slot_tasks.push(SlotTask {
                id: vi,
                duration: tr.cost.total(),
                cpus: tr.cost.cpus.min(self.config.vcpus_per_worker),
                preferred: None,
                // recompute must re-read the (remote) source partition
                remote_penalty: self.config.net.transfer(tr.bytes_in, 1),
                release: VirtualTime::ZERO,
            });
            results.push(tr);
        }
        let placements2 = sched.run(&slot_tasks);
        *now = *now + (sched.makespan() - VirtualTime::ZERO);
        sreport.recomputed = victims.len();

        // placements2 is sorted by id == index into `victims`/`results`
        for (tr, p) in results.into_iter().zip(&placements2) {
            outputs[victims[p.id]] = (p.worker, tr.records);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{ClosureOp, Dataset, Record};
    use crate::simtime::CostModel;

    fn cluster(workers: usize) -> Cluster {
        Cluster::new(
            Arc::new(Registry::new()),
            None,
            ClusterConfig::sized(workers, 4),
        )
    }

    fn upper_op() -> Arc<dyn crate::dataset::PartitionOp> {
        Arc::new(ClosureOp {
            f: |_: &TaskContext, recs: Vec<Record>| {
                Ok(recs
                    .into_iter()
                    .map(|r| Record::text(r.as_text().unwrap().to_uppercase()))
                    .collect())
            },
            name: "upper".into(),
        })
    }

    /// container-ish op with a real cost model (native closure inside).
    struct CostlyOp;
    impl crate::dataset::PartitionOp for CostlyOp {
        fn apply(&self, _: &TaskContext, r: Vec<Record>) -> Result<Vec<Record>> {
            Ok(r)
        }
        fn cost_model(&self) -> CostModel {
            CostModel {
                fixed: Duration::seconds(1.0),
                secs_per_byte: 0.0,
                secs_per_record: 1.0,
                cpus: 1,
            }
        }
        fn image(&self) -> Option<&str> {
            None
        }
        fn label(&self) -> String {
            "costly".into()
        }
    }

    #[test]
    fn runs_a_map_only_job() {
        let c = cluster(2);
        let ds = Dataset::parallelize_text("a\nb\nc\nd", "\n", 4).map_partitions(upper_op());
        let out = c.run(&ds).unwrap();
        assert_eq!(out.collect_text("\n"), "A\nB\nC\nD\n");
        assert_eq!(out.report.stages.len(), 1);
        assert_eq!(out.report.stages[0].tasks, 4);
        assert_eq!(out.report.total_shuffled_bytes(), 0);
    }

    #[test]
    fn shuffle_stage_moves_data() {
        let c = cluster(2);
        let ds = Dataset::parallelize_text("a\nb\nc\nd", "\n", 4)
            .map_partitions(upper_op())
            .repartition(1);
        let out = c.run(&ds).unwrap();
        assert_eq!(out.partitions.len(), 1);
        assert_eq!(out.collect_records().len(), 4);
        assert_eq!(out.report.stages.len(), 2);
        assert!(out.report.total_shuffled_bytes() > 0);
    }

    #[test]
    fn weak_scaling_of_parallel_work_is_flat() {
        // 2x data on 2x workers => same virtual makespan (the WSE=1 case)
        let mk = |workers: usize, records: usize| {
            let c = cluster(workers);
            let recs: Vec<Record> =
                (0..records).map(|i| Record::text(format!("{i}"))).collect();
            let ds = Dataset::parallelize(recs, workers * 4)
                .map_partitions(Arc::new(CostlyOp));
            c.run(&ds).unwrap().report.makespan
        };
        let m1 = mk(1, 64);
        let m4 = mk(4, 256);
        let ratio = m1.as_seconds() / m4.as_seconds();
        assert!((ratio - 1.0).abs() < 0.05, "WSE ratio {ratio}");
    }

    #[test]
    fn task_flake_is_retried_and_result_identical() {
        let ds = || {
            Dataset::parallelize_text("a\nb\nc\nd", "\n", 4).map_partitions(upper_op())
        };
        let clean = cluster(2).run(&ds()).unwrap();

        let mut cfg = ClusterConfig::sized(2, 4);
        cfg.fault = Some(FaultSpec::TaskFlake { stage: 0, partition: 1, failures: 1 });
        let flaky = Cluster::new(Arc::new(Registry::new()), None, cfg);
        let out = flaky.run(&ds()).unwrap();

        assert_eq!(out.collect_text("\n"), clean.collect_text("\n"));
        assert_eq!(out.report.stages[0].retried, 1);
    }

    #[test]
    fn task_flake_exhausting_attempts_fails_the_job() {
        let mut cfg = ClusterConfig::sized(2, 4);
        cfg.max_attempts = 2;
        cfg.fault = Some(FaultSpec::TaskFlake { stage: 0, partition: 0, failures: 99 });
        let c = Cluster::new(Arc::new(Registry::new()), None, cfg);
        let ds = Dataset::parallelize_text("a\nb", "\n", 2).map_partitions(upper_op());
        let err = c.run(&ds).err().expect("should fail").to_string();
        assert!(err.contains("exhausted"), "{err}");
    }

    /// uppercases *and* carries a cost model, so tasks spread over
    /// workers in virtual time (zero-cost tasks all pack onto worker 0).
    struct CostlyUpper;
    impl crate::dataset::PartitionOp for CostlyUpper {
        fn apply(&self, _: &TaskContext, recs: Vec<Record>) -> Result<Vec<Record>> {
            Ok(recs
                .into_iter()
                .map(|r| Record::text(r.as_text().unwrap().to_uppercase()))
                .collect())
        }
        fn cost_model(&self) -> CostModel {
            CostModel {
                fixed: Duration::seconds(2.0),
                secs_per_byte: 0.0,
                secs_per_record: 0.0,
                cpus: 1,
            }
        }
        fn label(&self) -> String {
            "costly-upper".into()
        }
    }

    #[test]
    fn worker_loss_recovers_with_identical_output() {
        let ds = || {
            Dataset::parallelize_text("a\nb\nc\nd\ne\nf\ng\nh", "\n", 8)
                .map_partitions(Arc::new(CostlyUpper))
                .repartition(1)
        };
        let clean = cluster(4).run(&ds()).unwrap();

        let cfg = ClusterConfig::sized(4, 4)
            .with_fault(FaultSpec::WorkerLoss { worker: 1, after_stage: 0 });
        let c = Cluster::new(Arc::new(Registry::new()), None, cfg);
        let out = c.run(&ds()).unwrap();

        let mut a = clean.collect_text("\n").split('\n').map(String::from).collect::<Vec<_>>();
        let mut b = out.collect_text("\n").split('\n').map(String::from).collect::<Vec<_>>();
        a.sort();
        b.sort();
        assert_eq!(a, b);
        assert!(out.report.stages[0].recomputed > 0);
        // lost time shows up: recovery makespan >= clean
        assert!(out.report.makespan >= clean.report.makespan);
    }

    #[test]
    fn speculation_races_a_planted_straggler_and_recovers_makespan() {
        // 8 x 2s tasks on 4 workers x 2 slots; worker 0 planted 4x
        // slow. Baseline 2s; straggling 8s; with speculation the two
        // stuck tasks get copies at the 75% watermark (2s) finishing at
        // 4s — >= 2x of the lost makespan won back, bytes identical.
        let ds = || {
            let recs: Vec<Record> = (0..8).map(|i| Record::text(format!("{i}"))).collect();
            Dataset::parallelize(recs, 8).map_partitions(Arc::new(CostlyUpper))
        };
        let shape = || ClusterConfig::sized(4, 2);
        let slow = || shape().with_fault(FaultSpec::SlowWorker { worker: 0, factor: 4.0 });
        let run = |cfg: ClusterConfig| {
            Cluster::new(Arc::new(Registry::new()), None, cfg).run(&ds()).unwrap()
        };
        let base = run(shape());
        let off = run(slow());
        let on = run(slow().with_speculation(SpeculationPolicy::default()));

        // byte-identical output, speculation on or off, straggler or not
        assert_eq!(on.collect_text("\n"), off.collect_text("\n"));
        assert_eq!(on.collect_text("\n"), base.collect_text("\n"));

        let s = &on.report.stages[0];
        assert!(s.speculated >= 1, "the straggler must be raced");
        assert_eq!(s.spec_cancelled, s.speculated, "one loser per race");
        assert!(s.spec_wins <= s.speculated);
        assert_eq!(off.report.stages[0].speculated, 0);

        // >= 2x of the lost makespan is recovered
        let lost = off.report.makespan - base.report.makespan;
        let still = on.report.makespan - base.report.makespan;
        assert!(lost > Duration::ZERO, "the straggler must hurt: {:?}", off.report.makespan);
        assert!(
            lost.0 >= 2 * still.0,
            "speculation must recover >= 2x: base={} off={} on={}",
            base.report.makespan,
            off.report.makespan,
            on.report.makespan
        );
    }

    #[test]
    fn locality_preferred_sources_run_local() {
        let c = cluster(4);
        let parts: Vec<Partition> = (0..8)
            .map(|i| {
                Partition::with_locality(vec![Record::text(format!("{i}"))], i % 4)
            })
            .collect();
        let ds = Dataset::from_partitions(parts, "hdfs").map_partitions(Arc::new(CostlyOp));
        let out = c.run(&ds).unwrap();
        assert_eq!(out.report.stages[0].local_tasks, 8);
        assert_eq!(out.report.locality_fraction(), 1.0);
    }

    #[test]
    fn run_streamed_gates_first_stage_and_preserves_output() {
        let ds = Dataset::parallelize_text("a\nb\nc\nd", "\n", 4).map_partitions(upper_op());
        let batch = cluster(2).run(&ds).unwrap();
        // partitions seal at increasing times; output must be identical,
        // and the last seal bounds the stage from below
        let ready: Vec<Duration> =
            (0..4).map(|i| Duration::seconds(0.5 * (i + 1) as f64)).collect();
        let out = cluster(2).run_streamed(&ds, &ready).unwrap();
        assert_eq!(out.collect_text("\n"), batch.collect_text("\n"));
        assert!(
            out.report.makespan >= VirtualTime::seconds(2.0),
            "{:?}",
            out.report.makespan
        );
        // empty ready == plain run
        let plain = cluster(2).run_streamed(&ds, &[]).unwrap();
        assert_eq!(plain.report.makespan, batch.report.makespan);
    }

    #[test]
    fn utilization_reported() {
        let c = cluster(2);
        let recs: Vec<Record> = (0..16).map(|i| Record::text(format!("{i}"))).collect();
        let ds = Dataset::parallelize(recs, 8).map_partitions(Arc::new(CostlyOp));
        let out = c.run(&ds).unwrap();
        let s = &out.report.stages[0];
        assert!(s.busy > Duration::ZERO);
        assert!(s.makespan > Duration::ZERO);
        let util = s.busy.as_seconds()
            / (s.makespan.as_seconds() * c.config.total_vcpus() as f64);
        assert!(util > 0.1 && util <= 1.0, "{util}");
    }
}
