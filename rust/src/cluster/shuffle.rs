//! Shuffle service: route stage outputs into the next partitioning and
//! account the data motion.
//!
//! Records route per [`Partitioner`] (hash-by-key or balanced). The new
//! partition `p` is assigned to worker `p % workers` — deterministic,
//! spread — and every byte that crosses a worker boundary is charged to
//! the intra-cluster NIC model. The virtual shuffle duration is the
//! bottleneck-endpoint time: the busiest sender or receiver NIC drains
//! its remote bytes at LAN bandwidth (all endpoints in parallel), which
//! is the behaviour behind the paper's "reduce leads to K data shuffles"
//! cost discussion (§1.2.2).

use std::sync::Arc;

use crate::dataset::plan::{
    range_cuts, range_cuts_weighted, range_sample_keys, route_from, route_with_cuts,
};
use crate::dataset::{Partition, Partitioner, PartitionOp, Record, TaskContext};
use crate::error::Result;
use crate::simtime::{Duration, NetModel};

use super::task::CONTAINER_START;

/// Cap on how many distinct keys a shuffle records in
/// [`ShuffleStats::key_freqs`]; past it the heaviest keys are kept
/// (ties broken by key order, so the histogram stays deterministic).
pub const KEY_FREQ_CAP: usize = 4096;

/// Data-motion summary of one shuffle.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ShuffleStats {
    /// Bytes the map side produced BEFORE any map-side combiner ran —
    /// what a combiner-less shuffle would have shipped. Equal to
    /// `bytes_total` when no combiner is attached.
    pub bytes_pre_combine: u64,
    /// Bytes that actually moved through the shuffle (post-combine).
    pub bytes_total: u64,
    pub bytes_remote: u64,
    pub duration: Duration,
    /// Observed (post-combine) key histogram, sorted by key, capped at
    /// [`KEY_FREQ_CAP`] heaviest keys; empty for key-less partitioners.
    /// Feed it back as `Partitioner::RangeByKey { observed }` (via
    /// `Dataset::repartition_by_key_range_observed`) when reshuffling
    /// the same key space: measured frequencies plan strictly better
    /// cuts than the in-shuffle stride sample on skew the stride
    /// misses.
    pub key_freqs: Vec<(String, u64)>,
}

impl ShuffleStats {
    /// `bytes_pre_combine / bytes_total` — how much the map-side
    /// combiner shrank the shuffle (1.0 when no combiner ran).
    pub fn combine_ratio(&self) -> f64 {
        if self.bytes_total == 0 {
            1.0
        } else {
            self.bytes_pre_combine as f64 / self.bytes_total as f64
        }
    }
}

/// [`shuffle_combined`] without a combiner (infallible).
pub fn shuffle(
    outputs: Vec<(usize, Vec<Record>)>,
    partitioner: &Partitioner,
    workers: usize,
    net: &NetModel,
) -> (Vec<Partition>, ShuffleStats) {
    shuffle_combined(outputs, partitioner, None, workers, net, 0)
        .expect("combiner-less shuffle cannot fail")
}

/// Route `outputs` (records + the worker that produced them) into a new
/// set of partitions; returns the partitions and the shuffle account.
///
/// Records MOVE through the buckets: payloads are shared buffers
/// (`util::bytes::Shared`), so a shuffle re-arranges views and charges
/// the *modeled* network — it never re-allocates payload bytes on the
/// host.
///
/// When `combiner` is present (an associative + commutative aggregation
/// the optimizer pushed below this boundary), it runs once per source
/// partition BEFORE routing: the shuffle then ships partial aggregates,
/// and `bytes_pre_combine` vs `bytes_total` records the saving. The
/// combiner containers run in parallel across the map-side workers, so
/// their virtual time charges as the slowest one.
///
/// `RangeByKey` partitioners plan ONE global cut set here from a
/// deterministic stride-sample of the (post-combine) keys across ALL
/// source partitions — every partition routes against the same key
/// ranges, and because sample duplicates are kept, the cuts are
/// frequency-weighted: skewed key distributions spread instead of
/// piling onto one bucket.
pub fn shuffle_combined(
    outputs: Vec<(usize, Vec<Record>)>,
    partitioner: &Partitioner,
    combiner: Option<&Arc<dyn PartitionOp>>,
    workers: usize,
    net: &NetModel,
    seed: u64,
) -> Result<(Vec<Partition>, ShuffleStats)> {
    let num_out = partitioner.num_partitions();
    let workers = workers.max(1);
    let mut stats = ShuffleStats::default();

    // ---- map-side combine (partial aggregation per source partition)
    let num_src = outputs.len();
    let mut combine_time = Duration::ZERO;
    let mut combined: Vec<(usize, Vec<Record>)> = Vec::with_capacity(num_src);
    for (i, (w, records)) in outputs.into_iter().enumerate() {
        let pre: u64 = records.iter().map(Record::size_bytes).sum();
        stats.bytes_pre_combine += pre;
        match combiner {
            Some(op) => {
                let ctx = TaskContext {
                    partition: i,
                    num_partitions: num_src,
                    attempt: 0,
                    seed: seed
                        .wrapping_mul(0x9E3779B97F4A7C15)
                        .wrapping_add(0xC0B1 + ((i as u64) << 16)),
                };
                let out = op.apply(&ctx, records)?;
                let cost = op.cost_model();
                let t = CONTAINER_START
                    + cost.fixed
                    + Duration::seconds(
                        cost.secs_per_byte * pre as f64
                            + cost.secs_per_record * out.len() as f64,
                    );
                // map-side partitions combine in parallel: bottleneck
                if t > combine_time {
                    combine_time = t;
                }
                combined.push((w, out));
            }
            None => combined.push((w, records)),
        }
    }

    // ---- observed key histogram (post-combine, keyed partitioners)
    if let Some(key_fn) = partitioner.key_fn() {
        let mut freqs = std::collections::BTreeMap::<String, u64>::new();
        for (_, records) in &combined {
            for r in records {
                *freqs.entry(key_fn(r)).or_insert(0) += 1;
            }
        }
        stats.key_freqs = freqs.into_iter().collect();
        if stats.key_freqs.len() > KEY_FREQ_CAP {
            // keep the heaviest keys (deterministic tie-break by key),
            // then restore key order
            stats.key_freqs.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
            stats.key_freqs.truncate(KEY_FREQ_CAP);
            stats.key_freqs.sort();
        }
    }

    // ---- range-cut planning (global, post-combine); exact frequencies
    // from a prior shuffle of the same key space win over the sample
    let cuts = match partitioner {
        Partitioner::RangeByKey { key_fn, num, observed } => match observed {
            Some(freqs) => Some(range_cuts_weighted(freqs, *num)),
            None => {
                let total: usize = combined.iter().map(|(_, r)| r.len()).sum();
                let sample = range_sample_keys(
                    combined.iter().map(|(_, r)| r.as_slice()),
                    total,
                    key_fn,
                );
                Some(range_cuts(sample, *num))
            }
        },
        _ => None,
    };

    // ---- routing + data-motion accounting
    let mut buckets: Vec<Vec<Record>> = (0..num_out).map(|_| Vec::new()).collect();
    let mut sent_remote = vec![0u64; workers];
    let mut recv_remote = vec![0u64; workers];
    for (src_part, (src_worker, records)) in combined.into_iter().enumerate() {
        let routed = match (&cuts, partitioner) {
            (Some(cuts), Partitioner::RangeByKey { key_fn, num, .. }) => {
                route_with_cuts(cuts, *num, key_fn, records)
            }
            _ => route_from(partitioner, records, src_part),
        };
        for (p, routed) in routed.into_iter().enumerate() {
            let dst_worker = p % workers;
            let bytes: u64 = routed.iter().map(Record::size_bytes).sum();
            stats.bytes_total += bytes;
            if dst_worker != src_worker {
                stats.bytes_remote += bytes;
                sent_remote[src_worker.min(workers - 1)] += bytes;
                recv_remote[dst_worker] += bytes;
            }
            buckets[p].extend(routed);
        }
    }
    if combiner.is_none() {
        debug_assert_eq!(stats.bytes_pre_combine, stats.bytes_total);
    }

    // bottleneck endpoint: busiest NIC moves its bytes at LAN speed,
    // plus shuffle-file materialization at both ends (Spark writes
    // shuffle blocks to local disk before serving them — the "large
    // amount of data materialized on disk" of §1.4)
    let max_endpoint = sent_remote
        .iter()
        .chain(recv_remote.iter())
        .copied()
        .max()
        .unwrap_or(0);
    let spill = crate::simtime::DiskModel::hdd();
    stats.duration = combine_time
        + net.transfer(max_endpoint, 1)
        + spill.rw(max_endpoint)
        + spill.rw(max_endpoint);

    let partitions = buckets
        .into_iter()
        .enumerate()
        .map(|(p, records)| Partition::with_locality(records, p % workers))
        .collect();
    Ok((partitions, stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn recs(n: usize, tag: &str) -> Vec<Record> {
        (0..n).map(|i| Record::text(format!("{tag}{i}"))).collect()
    }

    #[test]
    fn balanced_shuffle_spreads_and_localizes() {
        let outputs = vec![(0, recs(6, "a")), (1, recs(6, "b"))];
        let (parts, stats) = shuffle(
            outputs,
            &Partitioner::Balanced { num: 3 },
            2,
            &NetModel::lan(),
        );
        assert_eq!(parts.len(), 3);
        assert_eq!(parts.iter().map(|p| p.len()).sum::<usize>(), 12);
        // partition p lives on worker p % 2
        assert_eq!(parts[0].preferred_worker, Some(0));
        assert_eq!(parts[1].preferred_worker, Some(1));
        assert_eq!(parts[2].preferred_worker, Some(0));
        assert!(stats.bytes_remote > 0);
        assert!(stats.bytes_remote < stats.bytes_total);
        assert!(stats.duration > Duration::ZERO);
    }

    #[test]
    fn single_worker_shuffle_is_all_local() {
        let outputs = vec![(0, recs(10, "x"))];
        let (_, stats) =
            shuffle(outputs, &Partitioner::Balanced { num: 2 }, 1, &NetModel::lan());
        assert_eq!(stats.bytes_remote, 0);
        // only NIC latency-free local motion
        assert_eq!(stats.duration, Duration::ZERO);
    }

    #[test]
    fn hash_partitioning_keeps_keys_together() {
        let key_fn: std::sync::Arc<dyn Fn(&Record) -> String + Send + Sync> =
            std::sync::Arc::new(|r: &Record| r.as_text().unwrap()[..1].to_string());
        let outputs = vec![
            (0, vec![Record::text("a1"), Record::text("b1")]),
            (1, vec![Record::text("a2"), Record::text("b2")]),
        ];
        let (parts, _) = shuffle(
            outputs,
            &Partitioner::HashByKey { key_fn, num: 4 },
            2,
            &NetModel::lan(),
        );
        for p in &parts {
            let firsts: std::collections::HashSet<_> =
                p.records.iter().map(|r| &r.as_text().unwrap()[..1]).collect();
            assert!(firsts.len() <= 1);
        }
    }

    #[test]
    fn map_side_combiner_shrinks_shipped_bytes() {
        use crate::dataset::{ClosureOp, TaskContext};
        // combiner: sum each partition's numeric records into ONE record
        let combiner: Arc<dyn PartitionOp> = Arc::new(ClosureOp {
            f: |_: &TaskContext, recs: Vec<Record>| {
                let sum: u64 =
                    recs.iter().filter_map(|r| r.as_text()?.parse::<u64>().ok()).sum();
                Ok(vec![Record::text(sum.to_string())])
            },
            name: "sum-combine".into(),
        });
        let outputs = |n: usize| -> Vec<(usize, Vec<Record>)> {
            (0..n)
                .map(|w| (w, (0..50).map(|i| Record::text(format!("{i}"))).collect()))
                .collect()
        };
        let p = Partitioner::Balanced { num: 2 };
        let (_, plain) = shuffle(outputs(4), &p, 4, &NetModel::lan());
        let (parts, combined) =
            shuffle_combined(outputs(4), &p, Some(&combiner), 4, &NetModel::lan(), 7)
                .unwrap();
        assert_eq!(plain.bytes_pre_combine, plain.bytes_total);
        assert_eq!(combined.bytes_pre_combine, plain.bytes_total);
        assert!(
            combined.bytes_total * 4 <= combined.bytes_pre_combine,
            "pre {} post {}",
            combined.bytes_pre_combine,
            combined.bytes_total
        );
        assert!(combined.combine_ratio() >= 4.0);
        // one partial aggregate per source partition survived
        assert_eq!(parts.iter().map(|p| p.len()).sum::<usize>(), 4);
        // combiner container time is charged to the shuffle clock
        assert!(combined.duration >= CONTAINER_START);
    }

    #[test]
    fn range_partitioner_plans_global_cuts_across_sources() {
        let key_fn: std::sync::Arc<dyn Fn(&Record) -> String + Send + Sync> =
            std::sync::Arc::new(|r: &Record| r.as_text().unwrap()[..1].to_string());
        // the same keys appear on BOTH source partitions; a per-source
        // cut plan could route them apart, the global plan must not
        let outputs = vec![
            (0, vec![Record::text("a1"), Record::text("c1")]),
            (1, vec![Record::text("a2"), Record::text("b1"), Record::text("c2")]),
        ];
        let (parts, stats) = shuffle(
            outputs,
            &Partitioner::RangeByKey { key_fn, num: 3, observed: None },
            2,
            &NetModel::lan(),
        );
        assert_eq!(parts.iter().map(|p| p.len()).sum::<usize>(), 5);
        assert_eq!(stats.bytes_pre_combine, stats.bytes_total);
        for key in ["a", "b", "c"] {
            let holders = parts
                .iter()
                .filter(|p| {
                    p.records.iter().any(|r| r.as_text().unwrap().starts_with(key))
                })
                .count();
            assert_eq!(holders, 1, "key {key} split across partitions");
        }
    }

    #[test]
    fn key_histogram_round_trips_into_observed_cuts() {
        let key_fn = || -> std::sync::Arc<dyn Fn(&Record) -> String + Send + Sync> {
            std::sync::Arc::new(|r: &Record| r.as_text().unwrap()[..1].to_string())
        };
        let outputs = || -> Vec<(usize, Vec<Record>)> {
            vec![
                (0, vec![Record::text("a1"), Record::text("c1"), Record::text("c2")]),
                (1, vec![Record::text("a2"), Record::text("b1"), Record::text("c3")]),
            ]
        };
        let plain = Partitioner::RangeByKey { key_fn: key_fn(), num: 3, observed: None };
        let (parts, stats) = shuffle(outputs(), &plain, 2, &NetModel::lan());
        // the shuffle measured the exact post-combine histogram
        assert_eq!(
            stats.key_freqs,
            vec![("a".to_string(), 2), ("b".to_string(), 1), ("c".to_string(), 3)]
        );
        // key-less partitioners record nothing
        let (_, balanced) =
            shuffle(outputs(), &Partitioner::Balanced { num: 3 }, 2, &NetModel::lan());
        assert!(balanced.key_freqs.is_empty());
        // feeding the histogram back as `observed` replans the same cuts
        // (the in-shuffle sample is exact below RANGE_SAMPLE_CAP), so
        // the partitions are identical — the observed path is a drop-in
        let fed = Partitioner::RangeByKey {
            key_fn: key_fn(),
            num: 3,
            observed: Some(Arc::new(stats.key_freqs.clone())),
        };
        let (parts2, stats2) = shuffle(outputs(), &fed, 2, &NetModel::lan());
        let shape = |ps: &[Partition]| -> Vec<Vec<String>> {
            ps.iter()
                .map(|p| p.records.iter().map(|r| r.as_text().unwrap().to_string()).collect())
                .collect()
        };
        assert_eq!(shape(&parts), shape(&parts2));
        assert_eq!(stats2.key_freqs, stats.key_freqs);
    }

    #[test]
    fn remote_bytes_drive_duration() {
        // all records on worker 0 shuffled into 4 partitions over 4
        // workers: 3/4 of bytes cross the NIC
        let outputs = vec![(0, recs(100, "r"))];
        let (_, s4) =
            shuffle(outputs.clone(), &Partitioner::Balanced { num: 4 }, 4, &NetModel::lan());
        let (_, s1) =
            shuffle(outputs, &Partitioner::Balanced { num: 4 }, 1, &NetModel::lan());
        assert!(s4.duration > s1.duration);
        assert_eq!(s1.bytes_remote, 0);
        assert_eq!(s4.bytes_total, s1.bytes_total);
    }
}
