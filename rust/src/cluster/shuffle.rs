//! Shuffle service: route stage outputs into the next partitioning and
//! account the data motion.
//!
//! Records route per [`Partitioner`] (hash-by-key or balanced). The new
//! partition `p` is assigned to worker `p % workers` — deterministic,
//! spread — and every byte that crosses a worker boundary is charged to
//! the intra-cluster NIC model. The virtual shuffle duration is the
//! bottleneck-endpoint time: the busiest sender or receiver NIC drains
//! its remote bytes at LAN bandwidth (all endpoints in parallel), which
//! is the behaviour behind the paper's "reduce leads to K data shuffles"
//! cost discussion (§1.2.2).

use crate::dataset::{plan::route_from, Partition, Partitioner, Record};
use crate::simtime::{Duration, NetModel};

/// Data-motion summary of one shuffle.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ShuffleStats {
    pub bytes_total: u64,
    pub bytes_remote: u64,
    pub duration: Duration,
}

/// Route `outputs` (records + the worker that produced them) into a new
/// set of partitions; returns the partitions and the shuffle account.
///
/// Records MOVE through the buckets: payloads are shared buffers
/// (`util::bytes::Shared`), so a shuffle re-arranges views and charges
/// the *modeled* network — it never re-allocates payload bytes on the
/// host.
pub fn shuffle(
    outputs: Vec<(usize, Vec<Record>)>,
    partitioner: &Partitioner,
    workers: usize,
    net: &NetModel,
) -> (Vec<Partition>, ShuffleStats) {
    let num_out = partitioner.num_partitions();
    let workers = workers.max(1);

    let mut buckets: Vec<Vec<Record>> = (0..num_out).map(|_| Vec::new()).collect();
    let mut sent_remote = vec![0u64; workers];
    let mut recv_remote = vec![0u64; workers];
    let mut stats = ShuffleStats::default();

    for (src_part, (src_worker, records)) in outputs.into_iter().enumerate() {
        for (p, routed) in route_from(partitioner, records, src_part).into_iter().enumerate() {
            let dst_worker = p % workers;
            let bytes: u64 = routed.iter().map(Record::size_bytes).sum();
            stats.bytes_total += bytes;
            if dst_worker != src_worker {
                stats.bytes_remote += bytes;
                sent_remote[src_worker.min(workers - 1)] += bytes;
                recv_remote[dst_worker] += bytes;
            }
            buckets[p].extend(routed);
        }
    }

    // bottleneck endpoint: busiest NIC moves its bytes at LAN speed,
    // plus shuffle-file materialization at both ends (Spark writes
    // shuffle blocks to local disk before serving them — the "large
    // amount of data materialized on disk" of §1.4)
    let max_endpoint = sent_remote
        .iter()
        .chain(recv_remote.iter())
        .copied()
        .max()
        .unwrap_or(0);
    let spill = crate::simtime::DiskModel::hdd();
    stats.duration = net.transfer(max_endpoint, 1) + spill.rw(max_endpoint) + spill.rw(max_endpoint);

    let partitions = buckets
        .into_iter()
        .enumerate()
        .map(|(p, records)| Partition::with_locality(records, p % workers))
        .collect();
    (partitions, stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn recs(n: usize, tag: &str) -> Vec<Record> {
        (0..n).map(|i| Record::text(format!("{tag}{i}"))).collect()
    }

    #[test]
    fn balanced_shuffle_spreads_and_localizes() {
        let outputs = vec![(0, recs(6, "a")), (1, recs(6, "b"))];
        let (parts, stats) = shuffle(
            outputs,
            &Partitioner::Balanced { num: 3 },
            2,
            &NetModel::lan(),
        );
        assert_eq!(parts.len(), 3);
        assert_eq!(parts.iter().map(|p| p.len()).sum::<usize>(), 12);
        // partition p lives on worker p % 2
        assert_eq!(parts[0].preferred_worker, Some(0));
        assert_eq!(parts[1].preferred_worker, Some(1));
        assert_eq!(parts[2].preferred_worker, Some(0));
        assert!(stats.bytes_remote > 0);
        assert!(stats.bytes_remote < stats.bytes_total);
        assert!(stats.duration > Duration::ZERO);
    }

    #[test]
    fn single_worker_shuffle_is_all_local() {
        let outputs = vec![(0, recs(10, "x"))];
        let (_, stats) =
            shuffle(outputs, &Partitioner::Balanced { num: 2 }, 1, &NetModel::lan());
        assert_eq!(stats.bytes_remote, 0);
        // only NIC latency-free local motion
        assert_eq!(stats.duration, Duration::ZERO);
    }

    #[test]
    fn hash_partitioning_keeps_keys_together() {
        let key_fn: std::sync::Arc<dyn Fn(&Record) -> String + Send + Sync> =
            std::sync::Arc::new(|r: &Record| r.as_text().unwrap()[..1].to_string());
        let outputs = vec![
            (0, vec![Record::text("a1"), Record::text("b1")]),
            (1, vec![Record::text("a2"), Record::text("b2")]),
        ];
        let (parts, _) = shuffle(
            outputs,
            &Partitioner::HashByKey { key_fn, num: 4 },
            2,
            &NetModel::lan(),
        );
        for p in &parts {
            let firsts: std::collections::HashSet<_> =
                p.records.iter().map(|r| &r.as_text().unwrap()[..1]).collect();
            assert!(firsts.len() <= 1);
        }
    }

    #[test]
    fn remote_bytes_drive_duration() {
        // all records on worker 0 shuffled into 4 partitions over 4
        // workers: 3/4 of bytes cross the NIC
        let outputs = vec![(0, recs(100, "r"))];
        let (_, s4) =
            shuffle(outputs.clone(), &Partitioner::Balanced { num: 4 }, 4, &NetModel::lan());
        let (_, s1) =
            shuffle(outputs, &Partitioner::Balanced { num: 4 }, 1, &NetModel::lan());
        assert!(s4.duration > s1.duration);
        assert_eq!(s1.bytes_remote, 0);
        assert_eq!(s4.bytes_total, s1.bytes_total);
    }
}
