//! Workflow-system baseline — the "current best practice" MaRe argues
//! against (§1.1/§1.4): a container-enabled workflow engine that
//! orchestrates the *same* containerized steps, but
//!
//! * synchronizes through a **decoupled shared store** (every stage
//!   writes all of its output there and the next stage reads it back),
//! * schedules **without data locality** (tasks go to any free slot),
//! * runs **batch stages with a submission/polling cadence** instead of
//!   an in-memory pipelined DAG.
//!
//! Outputs are identical to the MaRe pipeline (same tools, same data);
//! only the data motion and scheduling differ — which is exactly the
//! claim the TAB-LOC ablation bench quantifies.

use std::sync::Arc;

use crate::cluster::{pool, ClusterConfig};
use crate::container::Engine;
use crate::dataset::{PartitionOp, Record, TaskContext};
use crate::error::Result;
use crate::mare::{ContainerOp, MountPoint};
use crate::simtime::{Duration, NetModel, SlotSchedule, SlotTask, VirtualTime};

/// One workflow step (a node in the workflow DAG; our pipelines are
/// linear, like the paper's two applications).
pub struct WfStep {
    pub name: String,
    pub input_mount: MountPoint,
    pub output_mount: MountPoint,
    pub image: String,
    pub command: String,
    /// Tasks this step fans out to (the workflow engine's scatter width).
    pub tasks: usize,
}

/// Virtual-time account of a workflow run.
#[derive(Debug, Clone, Default)]
pub struct WorkflowReport {
    pub makespan: VirtualTime,
    /// Bytes that crossed the shared store (all of them, twice per
    /// stage boundary: write + read).
    pub store_bytes: u64,
    pub steps: Vec<(String, Duration)>,
}

/// The workflow engine.
pub struct WorkflowEngine {
    engine: Arc<Engine>,
    pub config: ClusterConfig,
    /// The shared store's pipe (NFS/object-store-ish; all workers share
    /// its aggregate bandwidth).
    pub store_net: NetModel,
    /// Batch-system submission + polling overhead per step.
    pub step_overhead: Duration,
}

impl WorkflowEngine {
    pub fn new(engine: Arc<Engine>, config: ClusterConfig) -> Self {
        WorkflowEngine {
            engine,
            config,
            // a decoupled store: good per-connection pipe, shared cap
            store_net: NetModel::new(0.002, 300e6).with_aggregate(1.5e9),
            step_overhead: Duration::seconds(5.0),
        }
    }

    /// Run a linear workflow over `records`, scattering each step into
    /// `step.tasks` chunks.
    pub fn run(&self, steps: &[WfStep], records: Vec<Record>) -> Result<(Vec<Record>, WorkflowReport)> {
        let mut report = WorkflowReport::default();
        let mut now = VirtualTime::ZERO;
        let mut current = records;

        for step in steps {
            let step_started = now;
            let op = ContainerOp::new(
                self.engine.clone(),
                step.input_mount.clone(),
                step.output_mount.clone(),
                &step.image,
                &step.command,
            );

            // scatter: contiguous chunks, one per task
            let n = step.tasks.max(1);
            let chunks = chop(&current, n);

            // every task first STAGES IN its chunk from the shared store
            // and finally STAGES OUT its results — both over the store
            // pipe, all tasks concurrently
            let in_bytes: Vec<u64> =
                chunks.iter().map(|c| c.iter().map(Record::size_bytes).sum()).collect();

            let threads = self.config.host_threads.unwrap_or_else(pool::host_threads);
            let results: Vec<Result<Vec<Record>>> =
                pool::run_indexed(chunks.len(), threads, |i| {
                    let ctx = TaskContext {
                        partition: i,
                        num_partitions: n,
                        attempt: 0,
                        seed: self.config.seed ^ (i as u64) << 16,
                    };
                    op.apply(&ctx, chunks[i].clone())
                });
            let mut outputs = Vec::with_capacity(results.len());
            for r in results {
                outputs.push(r?);
            }
            let out_bytes: Vec<u64> =
                outputs.iter().map(|c| c.iter().map(Record::size_bytes).sum()).collect();

            // virtual schedule: NO locality (preferred=None), store
            // transfers folded into each task's duration
            let concurrency = chunks.len() as u32;
            let mut sched =
                SlotSchedule::new(self.config.workers, self.config.vcpus_per_worker)
                    .with_locality_wait(Duration::ZERO);
            let tasks: Vec<SlotTask> = (0..chunks.len())
                .map(|i| {
                    let stage_in = self.store_net.transfer(in_bytes[i], concurrency);
                    let stage_out = self.store_net.transfer(out_bytes[i], concurrency);
                    let compute = op.cost_model().compute(in_bytes[i], chunks[i].len() as u64)
                        + crate::cluster::task::CONTAINER_START;
                    SlotTask {
                        id: i,
                        duration: stage_in + compute + stage_out,
                        cpus: op.cost_model().cpus.min(self.config.vcpus_per_worker),
                        preferred: None,
                        remote_penalty: Duration::ZERO,
                        release: VirtualTime::ZERO,
                    }
                })
                .collect();
            sched.run(&tasks);

            report.store_bytes +=
                in_bytes.iter().sum::<u64>() + out_bytes.iter().sum::<u64>();
            now = now + (sched.makespan() - VirtualTime::ZERO) + self.step_overhead;
            report.steps.push((step.name.clone(), now - step_started));
            current = outputs.into_iter().flatten().collect();
        }

        report.makespan = now;
        Ok((current, report))
    }
}

/// Contiguous chop into n chunks (workflow scatter).
fn chop(records: &[Record], n: usize) -> Vec<Vec<Record>> {
    let n = n.max(1);
    let total = records.len();
    let mut out = Vec::with_capacity(n);
    let mut it = records.iter().cloned();
    for i in 0..n {
        let count = total / n + usize::from(i < total % n);
        out.push(it.by_ref().take(count).collect());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::Registry;
    use crate::tools::images;

    fn engine() -> Arc<Engine> {
        let mut reg = Registry::new();
        reg.push(images::ubuntu());
        Arc::new(Engine::new(Arc::new(reg), None))
    }

    fn gc_steps() -> Vec<WfStep> {
        vec![
            WfStep {
                name: "gc-map".into(),
                input_mount: MountPoint::text("/dna"),
                output_mount: MountPoint::text("/count"),
                image: "ubuntu".into(),
                command: "grep -o '[GC]' /dna | wc -l > /count".into(),
                tasks: 4,
            },
            WfStep {
                name: "gc-sum".into(),
                input_mount: MountPoint::text("/counts"),
                output_mount: MountPoint::text("/sum"),
                image: "ubuntu".into(),
                command: "awk '{s+=$1} END {print s}' /counts > /sum".into(),
                tasks: 1,
            },
        ]
    }

    #[test]
    fn workflow_produces_same_answer_as_mare() {
        let genome = crate::workloads::gc::genome_text(5, 32, 60);
        let want = crate::workloads::gc::oracle(&genome);
        let records: Vec<Record> =
            genome.lines().map(Record::text).collect();
        let wf = WorkflowEngine::new(engine(), ClusterConfig::sized(4, 2));
        let (out, report) = wf.run(&gc_steps(), records).unwrap();
        assert_eq!(out, vec![Record::text(want.to_string())]);
        assert!(report.store_bytes > 0);
        assert_eq!(report.steps.len(), 2);
    }

    #[test]
    fn workflow_charges_store_traffic_and_step_overhead() {
        let records: Vec<Record> = (0..64).map(|i| Record::text(format!("G{i}"))).collect();
        let wf = WorkflowEngine::new(engine(), ClusterConfig::sized(4, 2));
        let (_, report) = wf.run(&gc_steps(), records).unwrap();
        // at minimum 2 steps x 5 s overhead
        assert!(report.makespan >= VirtualTime::seconds(10.0), "{}", report.makespan);
    }

    #[test]
    fn chop_is_contiguous_and_complete() {
        let recs: Vec<Record> = (0..10).map(|i| Record::text(format!("{i}"))).collect();
        let chunks = chop(&recs, 3);
        assert_eq!(chunks.len(), 3);
        let flat: Vec<Record> = chunks.into_iter().flatten().collect();
        assert_eq!(flat, recs);
    }
}
