//! Interactive session — the paper's interactivity claim ("scientists
//! increasingly demand being able to run interactive analyses rather
//! than submitting jobs to batch systems", §1.1; the evaluation drove
//! everything from Apache Zeppelin notebooks).
//!
//! `mare shell` wraps a [`Session`]: a logical pipeline is built
//! incrementally with `map` / `reduce` / `repartition` through the
//! fluent [`PipelineBuilder`], inspected with `plan` (logical →
//! optimized → physical, via the optimizer), and executed (repeatedly,
//! lazily) with `run` — the Zeppelin-cell workflow without leaving the
//! terminal.
//!
//! ```text
//! mare> gen gc 512
//! mare> map ubuntu /dna /count :: grep -o '[GC]' /dna | wc -l > /count
//! mare> reduce ubuntu /counts /sum :: awk '{s+=$1} END {print s}' /counts > /sum
//! mare> plan
//! mare> run
//! ```

use std::sync::Arc;

use crate::cluster::{Cluster, ClusterConfig};
use crate::dataset::{Dataset, Record};
use crate::error::{MareError, Result};
use crate::mare::{wire, Job, MaRe, MountPoint, Pipeline, PipelineBuilder, PipelineOp};
use crate::storage::StorageCatalog;
use crate::submit::{
    ingest_of, JobQueue, PoolConfig, SourceSpec, Submitter, WorkerPool, DEFAULT_QUEUE_DIR,
};

const HELP: &str = "\
commands:
  gen gc <lines>            generate a synthetic genome dataset
  gen vs <molecules>        generate a synthetic SDF library dataset
  ingest <uri>              ingest from a storage backend (hdfs://k, swift://k,
                            s3://k, local://k; sizing params ?lines=N, ?molecules=N)
  load <text> [sep]         load inline text as a dataset (records on sep, default \\n)
  map <image> <in> <out> :: <command>
                            add a map step (mounts: /path, /path:SEP, 'stdio')
  reduce <image> <in> <out> [depth] :: <command>
                            add a tree-reduce step (depth omitted = auto-planned)
  repartition <n>           rebalance into n partitions
  plan                      show logical -> optimized -> physical plans
  run                       execute; print report + first records
  collect                   execute; print all text records
  :save <file>              persist the pipeline as wire JSON (docs/WIRE_FORMAT.md);
                            submit it later with `mare submit <file>`
  :load <file>              restore a saved plan (regenerates gen:/inline: sources)
  :submit [dir]             enqueue the pipeline on the job spool [.mare/queue]
  :work [n] [dir]           drain the spool with n worker threads [2]
  reset                     drop the pipeline, keep the dataset
  status                    cluster + pipeline summary
  help                      this text
  quit / exit               leave";

/// One interactive session.
pub struct Session {
    cluster: Arc<Cluster>,
    dataset: Option<Dataset>,
    builder: Option<PipelineBuilder>,
    partitions: usize,
}

impl Session {
    pub fn new(cluster: Arc<Cluster>) -> Self {
        let partitions = cluster.config.workers * 2;
        Session { cluster, dataset: None, builder: None, partitions }
    }

    pub fn with_config(config: ClusterConfig, runtime_dir: Option<&str>) -> Result<Self> {
        let cluster = crate::workloads::make_cluster(config, runtime_dir, None)?;
        Ok(Self::new(cluster))
    }

    fn builder(&mut self) -> Result<PipelineBuilder> {
        self.builder
            .take()
            .ok_or_else(|| MareError::Config("no dataset loaded (try `gen gc 512`)".into()))
    }

    /// Validate + optimize + lower the pipeline recorded so far.
    fn job(&self) -> Result<Job> {
        self.builder
            .clone()
            .ok_or_else(|| MareError::Config("no dataset loaded (try `gen gc 512`)".into()))?
            .build()
    }

    fn set_dataset(&mut self, ds: Dataset) {
        self.builder = Some(MaRe::source(self.cluster.clone(), ds.clone()));
        self.dataset = Some(ds);
    }

    /// Evaluate one line; returns the text to display.
    pub fn eval(&mut self, line: &str) -> Result<String> {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            return Ok(String::new());
        }
        let (head, rest) = match line.split_once(char::is_whitespace) {
            Some((h, r)) => (h, r.trim()),
            None => (line, ""),
        };
        match head {
            "help" => Ok(HELP.to_string()),
            "gen" => self.cmd_gen(rest),
            "ingest" => self.cmd_ingest(rest),
            "load" => self.cmd_load(rest),
            "map" => self.cmd_map(rest),
            "reduce" => self.cmd_reduce(rest),
            "repartition" => self.cmd_repartition(rest),
            "plan" => self.cmd_plan(),
            "run" => self.cmd_run(false),
            "collect" => self.cmd_run(true),
            ":save" => self.cmd_save(rest),
            ":load" => self.cmd_load_plan(rest),
            ":submit" => self.cmd_submit(rest),
            ":work" => self.cmd_work(rest),
            "reset" => {
                match self.dataset.clone() {
                    Some(ds) => {
                        self.set_dataset(ds);
                        Ok("pipeline dropped (dataset kept)".into())
                    }
                    None => {
                        self.builder = None;
                        Ok("pipeline dropped".into())
                    }
                }
            }
            "status" => Ok(self.status()),
            "quit" | "exit" => Err(MareError::Config("__quit__".into())),
            other => Err(MareError::Config(format!(
                "unknown command `{other}` (try `help`)"
            ))),
        }
    }

    fn pipeline_summary(&self) -> String {
        match &self.builder {
            Some(b) => {
                let ops = b.logical();
                if ops.ops().len() <= 1 {
                    "(none)".into()
                } else {
                    ops.ops()
                        .iter()
                        .map(|o| o.label())
                        .collect::<Vec<_>>()
                        .join(" -> ")
                }
            }
            None => "(none)".into(),
        }
    }

    pub fn status(&self) -> String {
        format!(
            "cluster: {} workers x {} vCPUs | pipeline: {}",
            self.cluster.config.workers,
            self.cluster.config.vcpus_per_worker,
            self.pipeline_summary(),
        )
    }

    fn cmd_gen(&mut self, rest: &str) -> Result<String> {
        let mut it = rest.split_whitespace();
        let kind = it.next().unwrap_or("");
        let n: usize = it
            .next()
            .unwrap_or("256")
            .parse()
            .map_err(|_| MareError::Config("gen wants a count".into()))?;
        // sessions generate through SourceSpec — the same path `mare
        // work` and `:load` use — so a `:save`d plan regenerates
        // byte-identical records on any driver
        let (spec, what) = match kind {
            "gc" => (SourceSpec::GenGc { lines: n }, format!("genome, {n} lines")),
            "vs" => (SourceSpec::GenVs { molecules: n }, format!("SDF library, {n} molecules")),
            other => {
                return Err(MareError::Config(format!("gen gc|vs, not `{other}`")))
            }
        };
        let ds = spec.materialize(self.partitions, self.cluster.config.workers)?;
        let parts = ds.num_partitions();
        self.set_dataset(ds);
        Ok(format!("loaded {what} in {parts} partitions"))
    }

    /// `ingest <uri>` — resolve a storage URI through the catalog (the
    /// same path `mare work` drivers use for storage-backed plans), so
    /// a `:save`d session plan over it stays executable anywhere.
    fn cmd_ingest(&mut self, rest: &str) -> Result<String> {
        let label = rest.trim();
        if label.is_empty() {
            return Err(MareError::Config(format!(
                "ingest wants a storage URI (schemes: {})",
                StorageCatalog::schemes().join(", ")
            )));
        }
        let catalog = StorageCatalog::simulated(self.cluster.config.workers);
        let (ds, report) = catalog.resolve_label(label, self.partitions)?;
        let parts = ds.num_partitions();
        self.set_dataset(ds);
        Ok(format!(
            "ingested {label}: {} B in {parts} partitions \
             ({} local / {} remote reads, virtual {})",
            report.bytes, report.local_reads, report.remote_reads, report.duration
        ))
    }

    fn cmd_load(&mut self, rest: &str) -> Result<String> {
        if rest.is_empty() {
            return Err(MareError::Config("load wants text".into()));
        }
        let ds = Dataset::parallelize_text_labeled(
            rest,
            "\n",
            self.partitions.min(4),
            format!("inline:{rest}"),
        );
        let parts = ds.num_partitions();
        self.set_dataset(ds);
        Ok(format!("loaded inline text in {parts} partitions"))
    }

    /// The session pipeline as a v1 wire envelope, bracketed with its
    /// `collect` marker — the ONE encoding both `:save` writes and
    /// `:submit` enqueues, so a saved plan and a submitted plan can
    /// never drift apart.
    fn encoded_pipeline(&self) -> Result<String> {
        let b = self.builder.as_ref().ok_or_else(|| {
            MareError::Config("no dataset loaded (try `gen gc 512`)".into())
        })?;
        let mut ops = b.logical().ops().to_vec();
        ops.push(PipelineOp::Collect);
        wire::encode_string(&Pipeline::new(ops))
    }

    /// `:save <file>` — persist the recorded pipeline (bracketed with
    /// its `collect` marker) as a v1 wire envelope.
    fn cmd_save(&self, rest: &str) -> Result<String> {
        let path = rest.trim();
        if path.is_empty() {
            return Err(MareError::Config(":save wants a file path".into()));
        }
        std::fs::write(path, self.encoded_pipeline()?)?;
        Ok(format!("saved plan to {path} (submit with `mare submit {path}`)"))
    }

    /// `:load <file>` — restore a saved plan. `gen:`/`inline:` sources
    /// are regenerated; other sources need a dataset loaded first.
    fn cmd_load_plan(&mut self, rest: &str) -> Result<String> {
        let path = rest.trim();
        if path.is_empty() {
            return Err(MareError::Config(":load wants a file path".into()));
        }
        let text = std::fs::read_to_string(path)?;
        let pipeline = wire::decode_str(&text)?;
        let (label, partitions) = ingest_of(&pipeline)?;
        let spec = SourceSpec::parse(&label);
        if spec.is_executable() {
            self.set_dataset(spec.materialize(partitions, self.cluster.config.workers)?);
        } else {
            match self.dataset.clone() {
                // keep the current dataset, apply the plan's steps to it
                Some(ds) => self.set_dataset(ds),
                None => {
                    return Err(MareError::Config(format!(
                        "plan source `{label}` is not resolvable — load a dataset first \
                         (`gen`/`load`), then `:load` applies the plan's steps to it"
                    )))
                }
            }
        }
        let b = self
            .builder
            .take()
            .expect("set_dataset installs a builder")
            .append_pipeline(&pipeline);
        self.builder = Some(b);
        Ok(format!("loaded plan from {path} | {}", self.pipeline_summary()))
    }

    /// `:submit [dir]` — run the session's pipeline through the SAME
    /// admission control as `mare submit` (decode → dry-run build →
    /// canonical re-encode) and enqueue it on the spool, where any
    /// `mare work` pool (or `:work` here) can pick it up.
    fn cmd_submit(&self, rest: &str) -> Result<String> {
        let dir = match rest.trim() {
            "" => DEFAULT_QUEUE_DIR,
            dir => dir,
        };
        let text = self.encoded_pipeline()?;
        let queue = JobQueue::open(dir)?;
        let submitter = Submitter::new(self.cluster.config.clone());
        let (id, plan) = submitter.submit(&queue, &text)?;
        Ok(format!("job {id} queued in {} ({})", queue.dir().display(), plan.summary))
    }

    /// `:work [n] [dir]` — drain the spool with a threaded worker pool
    /// (the `mare work` path), sized `n` threads.
    fn cmd_work(&self, rest: &str) -> Result<String> {
        let mut workers = 2usize;
        let mut dir = DEFAULT_QUEUE_DIR.to_string();
        let mut parts = rest.split_whitespace();
        if let Some(first) = parts.next() {
            match first.parse::<usize>() {
                Ok(n) => {
                    workers = n.max(1);
                    if let Some(second) = parts.next() {
                        dir = second.to_string();
                    }
                }
                Err(_) => dir = first.to_string(),
            }
        }
        let queue = JobQueue::open(dir)?;
        let pool = WorkerPool::new(PoolConfig::new(workers, self.cluster.config.clone()));
        let outcome = pool.run(&queue)?;
        if outcome.finished.is_empty() {
            return Ok(format!("queue {} is empty", queue.dir().display()));
        }
        let mut s = String::new();
        for job in &outcome.finished {
            let r = job.result.as_ref().expect("drained jobs carry a result");
            s.push_str(&format!(
                "job {} -> {} on {} (launches={})\n",
                job.id,
                job.status.name(),
                r.driver,
                r.launches
            ));
        }
        for report in &outcome.reports {
            s.push_str(&format!("  {}\n", report.summary()));
        }
        Ok(s)
    }

    fn parse_mount(spec: &str) -> MountPoint {
        if spec == "stdio" {
            return MountPoint::stream();
        }
        match spec.split_once(':') {
            Some((path, sep)) => {
                MountPoint::text_sep(path, sep.replace("\\n", "\n"))
            }
            None => MountPoint::text(spec),
        }
    }

    fn split_step(rest: &str) -> Result<(Vec<&str>, &str)> {
        let (head, cmd) = rest
            .split_once("::")
            .ok_or_else(|| MareError::Config("missing `:: <command>`".into()))?;
        Ok((head.split_whitespace().collect(), cmd.trim()))
    }

    fn cmd_map(&mut self, rest: &str) -> Result<String> {
        let (args, cmd) = Self::split_step(rest)?;
        let [image, in_mp, out_mp] = args.as_slice() else {
            return Err(MareError::Config(
                "map <image> <in> <out> :: <command>".into(),
            ));
        };
        let b = self
            .builder()?
            .map(*image, cmd)
            .input_mount(Self::parse_mount(in_mp))
            .output_mount(Self::parse_mount(out_mp));
        self.builder = Some(b);
        Ok(format!("+map    | {}", self.pipeline_summary()))
    }

    fn cmd_reduce(&mut self, rest: &str) -> Result<String> {
        let (args, cmd) = Self::split_step(rest)?;
        let (image, in_mp, out_mp, depth) = match args.as_slice() {
            [i, a, b] => (i, a, b, None),
            [i, a, b, d] => (
                i,
                a,
                b,
                Some(d.parse::<usize>().map_err(|_| {
                    MareError::Config(format!("bad depth `{d}`"))
                })?),
            ),
            _ => {
                return Err(MareError::Config(
                    "reduce <image> <in> <out> [depth] :: <command>".into(),
                ))
            }
        };
        let mut b = self
            .builder()?
            .reduce(*image, cmd)
            .input_mount(Self::parse_mount(in_mp))
            .output_mount(Self::parse_mount(out_mp));
        if let Some(k) = depth {
            b = b.depth(k);
        }
        self.builder = Some(b);
        let k = depth.map(|k| k.to_string()).unwrap_or_else(|| "auto".into());
        Ok(format!("+reduce(K={k}) | {}", self.pipeline_summary()))
    }

    fn cmd_repartition(&mut self, rest: &str) -> Result<String> {
        let n: usize = rest
            .trim()
            .parse()
            .map_err(|_| MareError::Config("repartition wants a count".into()))?;
        let b = self.builder()?.repartition(n);
        self.builder = Some(b);
        Ok(format!("repartitioned into {n}"))
    }

    fn cmd_plan(&self) -> Result<String> {
        Ok(self.job()?.explain())
    }

    fn cmd_run(&self, all: bool) -> Result<String> {
        let out = self.job()?.run()?;
        let mut s = out.report.summary();
        let records: Vec<Record> = out.collect_records();
        let shown = if all { records.len() } else { records.len().min(5) };
        s.push_str(&format!("records: {}\n", records.len()));
        for r in records.iter().take(shown) {
            match r {
                Record::Text(t) => {
                    let mut t = t.as_str();
                    if !all && t.len() > 100 {
                        t = &t[..100];
                    }
                    s.push_str(&format!("  {t}\n"));
                }
                Record::Binary { name, bytes } => {
                    s.push_str(&format!("  <binary {name}: {} B>\n", bytes.len()))
                }
            }
        }
        if shown < records.len() {
            s.push_str(&format!("  ... ({} more)\n", records.len() - shown));
        }
        Ok(s)
    }
}

/// True when eval returned the quit sentinel.
pub fn is_quit(err: &MareError) -> bool {
    matches!(err, MareError::Config(m) if m == "__quit__")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::Registry;
    use crate::tools::images;

    fn session() -> Session {
        let mut reg = Registry::new();
        reg.push(images::ubuntu());
        let cluster =
            Arc::new(Cluster::new(Arc::new(reg), None, ClusterConfig::sized(2, 2)));
        Session::new(cluster)
    }

    #[test]
    fn full_interactive_gc_session() {
        let mut s = session();
        assert!(s.eval("gen gc 64").unwrap().contains("64 lines"));
        assert!(s
            .eval("map ubuntu /dna /count :: grep -o '[GC]' /dna | wc -l > /count")
            .unwrap()
            .contains("+map"));
        assert!(s
            .eval("reduce ubuntu /counts /sum 2 :: awk '{s+=$1} END {print s}' /counts > /sum")
            .unwrap()
            .contains("+reduce(K=2)"));
        let plan = s.eval("plan").unwrap();
        assert!(plan.contains("logical plan:"), "{plan}");
        assert!(plan.contains("stage 0"), "{plan}");
        let run = s.eval("run").unwrap();
        assert!(run.contains("records: 1"), "{run}");
        // re-running works (lazy lineage, Zeppelin-style) and yields the
        // same records (the report differs: image pulls are warm now)
        let again = s.eval("run").unwrap();
        let result_of = |s: &str| s.split("records:").nth(1).map(str::to_string);
        assert_eq!(result_of(&again), result_of(&run));
    }

    #[test]
    fn reduce_without_depth_is_auto_planned() {
        let mut s = session();
        s.eval("gen gc 32").unwrap();
        let msg = s
            .eval("reduce ubuntu /counts /sum :: awk '{s+=$1} END {print s}' /counts > /sum")
            .unwrap();
        assert!(msg.contains("+reduce(K=auto)"), "{msg}");
        let plan = s.eval("plan").unwrap();
        assert!(plan.contains("depth=auto"), "{plan}");
        assert!(plan.contains("auto-planned to"), "{plan}");
    }

    #[test]
    fn streamed_map_via_stdio_mounts() {
        let mut s = session();
        s.eval("load GATTACA\nGCGC").unwrap();
        s.eval("map ubuntu stdio stdio :: grep -o '[GC]' | wc -l").unwrap();
        let out = s.eval("collect").unwrap();
        // per-partition GC counts; the two non-empty partitions hold the
        // two records (2 and 4 GC bases)
        let total: u64 = out
            .lines()
            .filter_map(|l| l.trim().parse::<u64>().ok())
            .sum();
        assert_eq!(total, 6, "{out}");
    }

    #[test]
    fn builder_validation_errors_surface_at_plan_time() {
        let mut s = session();
        s.eval("gen gc 16").unwrap();
        s.eval("reduce ubuntu /in /out 0 :: awk '{s+=$1} END {print s}' /in > /out")
            .unwrap();
        let err = s.eval("plan").unwrap_err().to_string();
        assert!(err.contains("depth(0)"), "{err}");
    }

    #[test]
    fn errors_are_friendly() {
        let mut s = session();
        assert!(s.eval("run").unwrap_err().to_string().contains("no dataset"));
        assert!(s.eval("map ubuntu /a /b").unwrap_err().to_string().contains("::"));
        assert!(s.eval("frobnicate").unwrap_err().to_string().contains("help"));
        assert!(s.eval("").unwrap().is_empty());
        assert!(is_quit(&s.eval("quit").unwrap_err()));
    }

    #[test]
    fn reset_keeps_dataset_and_drops_pipeline() {
        let mut s = session();
        s.eval("gen gc 16").unwrap();
        s.eval("map ubuntu /dna /out :: cat /dna > /out").unwrap();
        assert!(s.eval("status").unwrap().contains("map"));
        s.eval("reset").unwrap();
        assert!(s.eval("status").unwrap().contains("(none)"));
        // the dataset survives: a new step can be added right away
        assert!(s
            .eval("map ubuntu /dna /out :: cat /dna > /out")
            .unwrap()
            .contains("+map"));
    }

    #[test]
    fn save_and_load_roundtrip_a_session_plan() {
        let path = std::env::temp_dir()
            .join(format!("mare-repl-plan-{}.json", std::process::id()));
        let path_s = path.to_string_lossy().to_string();

        let mut s = session();
        s.eval("gen gc 32").unwrap();
        s.eval("map ubuntu /dna /count :: grep -o '[GC]' /dna | wc -l > /count").unwrap();
        s.eval("reduce ubuntu /counts /sum 2 :: awk '{s+=$1} END {print s}' /counts > /sum")
            .unwrap();
        let plan_before = s.eval("plan").unwrap();
        let run_before = s.eval("run").unwrap();
        assert!(s.eval(&format!(":save {path_s}")).unwrap().contains("saved"), "{path_s}");

        // a FRESH session restores plan AND regenerated source
        let mut s2 = session();
        assert!(s2.eval(&format!(":load {path_s}")).unwrap().contains("loaded"));
        assert_eq!(s2.eval("plan").unwrap(), plan_before);
        let run_after = s2.eval("run").unwrap();
        let result_of = |s: &str| s.split("records:").nth(1).map(str::to_string);
        assert_eq!(result_of(&run_after), result_of(&run_before));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn save_without_dataset_and_load_of_missing_file_error() {
        let mut s = session();
        assert!(s.eval(":save /tmp/x.json").unwrap_err().to_string().contains("no dataset"));
        assert!(s.eval(":save").unwrap_err().to_string().contains("file path"));
        assert!(s.eval(":load /no/such/mare-plan.json").is_err());
    }

    #[test]
    fn ingest_command_loads_storage_backed_datasets() {
        let mut s = session();
        let msg = s.eval("ingest hdfs://genome.txt?lines=64").unwrap();
        assert!(msg.contains("ingested hdfs://genome.txt?lines=64"), "{msg}");
        assert!(msg.contains("local"), "{msg}");
        s.eval("map ubuntu /dna /count :: grep -o '[GC]' /dna | wc -l > /count").unwrap();
        let plan = s.eval("plan").unwrap();
        assert!(plan.contains("ingest[hdfs://genome.txt?lines=64]"), "{plan}");
        let run = s.eval("run").unwrap();
        assert!(run.contains("records:"), "{run}");

        // storage plans save/load like gen plans: the catalog's seeded
        // population regenerates the same store in a fresh session
        let path = std::env::temp_dir()
            .join(format!("mare-repl-storage-{}.json", std::process::id()));
        let path_s = path.to_string_lossy().to_string();
        s.eval(&format!(":save {path_s}")).unwrap();
        let mut s2 = session();
        assert!(s2.eval(&format!(":load {path_s}")).unwrap().contains("loaded"));
        assert_eq!(s2.eval("plan").unwrap(), s.eval("plan").unwrap());
        let _ = std::fs::remove_file(&path);

        // bad URIs error helpfully
        let err = s.eval("ingest nope://x").unwrap_err().to_string();
        assert!(err.contains("not a storage URI"), "{err}");
        let err = s.eval("ingest").unwrap_err().to_string();
        assert!(err.contains("storage URI"), "{err}");
    }

    #[test]
    fn submit_and_work_drain_the_session_pipeline_through_a_pool() {
        let dir = std::env::temp_dir()
            .join(format!("mare-repl-queue-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let dir_s = dir.to_string_lossy().to_string();

        let mut s = session();
        assert!(s.eval(":submit").unwrap_err().to_string().contains("no dataset"));
        s.eval("gen gc 32").unwrap();
        s.eval("map ubuntu /dna /count :: grep -o '[GC]' /dna | wc -l > /count").unwrap();
        let msg = s.eval(&format!(":submit {dir_s}")).unwrap();
        assert!(msg.contains("queued"), "{msg}");

        // a threaded pool (the `mare work` path) picks the job up
        let out = s.eval(&format!(":work 2 {dir_s}")).unwrap();
        assert!(out.contains("done on pool-"), "{out}");
        let again = s.eval(&format!(":work 2 {dir_s}")).unwrap();
        assert!(again.contains("is empty"), "{again}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn custom_separator_mounts() {
        let mp = Session::parse_mount("/in.sdf:\\n$$$$\\n");
        assert_eq!(mp, MountPoint::text_sep("/in.sdf", "\n$$$$\n"));
        assert_eq!(Session::parse_mount("stdio"), MountPoint::stream());
    }
}
