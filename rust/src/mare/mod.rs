//! The MaRe programming model — the paper's contribution.
//!
//! The user-facing API is the three-primitive surface of §1.2.1 —
//! `map`, `reduce`, `repartitionBy` — expressed through a fluent,
//! validating builder that records a **logical pipeline IR**
//! ([`pipeline::Pipeline`]) instead of eagerly mutating dataset
//! lineage:
//!
//! * [`MaRe::source`] opens a [`PipelineBuilder`] over a cluster and a
//!   dataset;
//! * `.map(image, command)` / `.reduce(image, command)` append
//!   containerized steps, configured by `.mounts(..)`, `.stdio()`,
//!   `.depth(K)` etc;
//! * `.build()` validates the WHOLE job (empty images/commands,
//!   `depth(0)`, missing mounts and reduce mount-kind mismatches are
//!   errors, not silent clamps), runs the optimizer passes
//!   ([`opt`]: map fusion, reduce-depth planning) and lowers the
//!   optimized plan into the physical lineage held by a [`Job`];
//! * [`Job::run`] / [`Job::collect_text`] execute (repeatedly — the
//!   lineage is immutable), and [`Job::explain`] renders
//!   logical → optimized → physical plans.
//!
//! Listing 1 (GC count) in this API:
//!
//! ```no_run
//! # use std::sync::Arc;
//! # use mare::mare::MaRe;
//! # use mare::cluster::{Cluster, ClusterConfig};
//! # use mare::container::Registry;
//! # use mare::dataset::Dataset;
//! # fn main() -> mare::Result<()> {
//! # let mut reg = Registry::new();
//! # reg.push(mare::tools::images::ubuntu());
//! # let cluster = Arc::new(Cluster::new(Arc::new(reg), None, ClusterConfig::sized(2, 4)));
//! # let genome = Dataset::parallelize_text("GATTACA", "\n", 2);
//! let gc_count = MaRe::source(cluster, genome)
//!     .map("ubuntu", "grep -o '[GC]' /dna | wc -l > /count")
//!     .mounts("/dna", "/count")
//!     .reduce("ubuntu", "awk '{s+=$1} END {print s}' /counts > /sum")
//!     .mounts("/counts", "/sum")
//!     .depth(2)
//!     .build()?
//!     .collect_text()?;
//! # Ok(())
//! # }
//! ```
//!
//! The pre-IR eager API ([`MaRe::new`] + [`MapSpec`] / [`ReduceSpec`])
//! still compiles as thin deprecated shims over the same lowering (the
//! migration recipe is `docs/MIGRATION.md`).
//!
//! Because the IR is a plain engine-agnostic value, a whole plan can
//! also leave the driver: [`wire`] round-trips `Pipeline` ⇄ JSON under
//! the documented v1 envelope (`docs/WIRE_FORMAT.md`), and
//! [`crate::submit`] queues encoded plans so any driver can rebuild
//! and execute them identically.

pub mod builder;
pub mod cost;
pub mod mount;
pub mod op;
pub mod opt;
pub mod pipeline;
pub mod wire;

use std::sync::Arc;

use crate::cluster::{Cluster, RunOutput};
use crate::dataset::{Dataset, Record};
use crate::error::Result;

pub use builder::{Job, PipelineBuilder};
pub use mount::MountPoint;
pub use op::ContainerOp;
pub use pipeline::{KeySelector, MapStep, Pipeline, PipelineOp, ReduceStep};

use pipeline::Lowering;

/// Default tree-reduce depth (§1.2.2: "By default MaRe sets K to 2").
/// The builder's `depth=auto` plans K instead; this constant remains
/// the pinned default of the deprecated eager API and the REPL.
pub const DEFAULT_REDUCE_DEPTH: usize = 2;

/// A `map` primitive invocation (pre-IR eager API).
#[deprecated(
    note = "use the fluent builder: MaRe::source(..).map(image, command).mounts(..)"
)]
#[derive(Debug, Clone)]
pub struct MapSpec {
    pub input_mount: MountPoint,
    pub output_mount: MountPoint,
    pub image: String,
    pub command: String,
}

/// A `reduce` primitive invocation (pre-IR eager API). The command MUST
/// be associative and commutative and should shrink its input (§1.2.2).
#[deprecated(
    note = "use the fluent builder: MaRe::source(..).reduce(image, command).mounts(..).depth(K)"
)]
#[derive(Debug, Clone)]
pub struct ReduceSpec {
    pub input_mount: MountPoint,
    pub output_mount: MountPoint,
    pub image: String,
    pub command: String,
    /// Tree depth K.
    pub depth: usize,
}

#[allow(deprecated)]
impl ReduceSpec {
    pub fn with_default_depth(
        input_mount: MountPoint,
        output_mount: MountPoint,
        image: impl Into<String>,
        command: impl Into<String>,
    ) -> Self {
        ReduceSpec {
            input_mount,
            output_mount,
            image: image.into(),
            command: command.into(),
            depth: DEFAULT_REDUCE_DEPTH,
        }
    }
}

/// The MaRe handle: a dataset + the cluster that will run it.
///
/// [`MaRe::source`] is the entry point of the fluent pipeline API; the
/// eager methods below survive as deprecated shims over the same
/// lowering code.
#[derive(Clone)]
pub struct MaRe {
    cluster: Arc<Cluster>,
    dataset: Dataset,
    /// Mount points disk-backed instead of tmpfs (Listing 3's TMPDIR
    /// override for chromosome-sized partitions).
    disk_mounts: bool,
}

impl MaRe {
    /// Open a fluent [`PipelineBuilder`] over `dataset` — the preferred
    /// way to express a job.
    pub fn source(cluster: Arc<Cluster>, dataset: Dataset) -> PipelineBuilder {
        PipelineBuilder::new(cluster, dataset)
    }

    pub fn new(cluster: Arc<Cluster>, dataset: Dataset) -> Self {
        MaRe { cluster, dataset, disk_mounts: false }
    }

    /// Write temporary mount-point data to disk instead of tmpfs.
    pub fn with_disk_mounts(mut self, disk: bool) -> Self {
        self.disk_mounts = disk;
        self
    }

    pub fn dataset(&self) -> &Dataset {
        &self.dataset
    }

    pub fn cluster(&self) -> &Arc<Cluster> {
        &self.cluster
    }

    pub fn num_partitions(&self) -> usize {
        self.dataset.num_partitions()
    }

    /// Apply a containerized command to each partition (Figure 1).
    #[deprecated(note = "use MaRe::source(..).map(image, command).mounts(..).build()")]
    #[allow(deprecated)]
    pub fn map(self, spec: MapSpec) -> MaRe {
        let step = MapStep {
            input_mount: spec.input_mount,
            output_mount: spec.output_mount,
            image: spec.image,
            command: spec.command,
            disk_mounts: self.disk_mounts,
        };
        let lowering = Lowering::for_cluster(&self.cluster);
        let dataset = lowering.lower_op(self.dataset, &PipelineOp::Map(step));
        MaRe { dataset, cluster: self.cluster, disk_mounts: self.disk_mounts }
    }

    /// Tree-aggregate all partitions into one (Figure 2): K levels of
    /// aggregate-within-partitions + shrink, at most K shuffles.
    ///
    /// A `depth` of 0 is clamped to 1 here for backwards compatibility;
    /// the fluent builder rejects it instead.
    #[deprecated(note = "use MaRe::source(..).reduce(image, command).mounts(..).depth(K).build()")]
    #[allow(deprecated)]
    pub fn reduce(self, spec: ReduceSpec) -> MaRe {
        let step = ReduceStep {
            input_mount: spec.input_mount,
            output_mount: spec.output_mount,
            image: spec.image,
            command: spec.command,
            depth: Some(spec.depth.max(1)),
            disk_mounts: self.disk_mounts,
            fused: None,
            combine: false,
        };
        let lowering = Lowering::for_cluster(&self.cluster);
        let dataset = lowering.lower_op(self.dataset, &PipelineOp::Reduce(step));
        MaRe { dataset, cluster: self.cluster, disk_mounts: self.disk_mounts }
    }

    /// Regroup records so those with equal keys share a partition
    /// (keyBy + HashPartitioner, §1.2.2).
    pub fn repartition_by(
        self,
        key_by: Arc<dyn Fn(&Record) -> String + Send + Sync>,
        num_partitions: usize,
    ) -> MaRe {
        MaRe {
            dataset: self.dataset.repartition_by_key(key_by, num_partitions),
            ..self
        }
    }

    /// Execute the lineage on the cluster.
    pub fn run(&self) -> Result<RunOutput> {
        self.cluster.run(&self.dataset)
    }

    /// Execute and join all text records with `\n` (driver-side collect).
    pub fn collect_text(&self) -> Result<String> {
        Ok(self.run()?.collect_text("\n").trim_end().to_string())
    }

    /// Execute and return all records.
    pub fn collect(&self) -> Result<Vec<Record>> {
        Ok(self.run()?.collect_records())
    }
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterConfig, StageOutput};
    use crate::container::Registry;
    use crate::tools::images;

    fn cluster(workers: usize) -> Arc<Cluster> {
        let mut reg = Registry::new();
        reg.push(images::ubuntu());
        Arc::new(Cluster::new(Arc::new(reg), None, ClusterConfig::sized(workers, 4)))
    }

    fn gc_spec() -> MapSpec {
        MapSpec {
            input_mount: MountPoint::text("/dna"),
            output_mount: MountPoint::text("/count"),
            image: "ubuntu".into(),
            command: "grep -o '[GC]' /dna | wc -l > /count".into(),
        }
    }

    fn sum_spec(depth: usize) -> ReduceSpec {
        ReduceSpec {
            input_mount: MountPoint::text("/counts"),
            output_mount: MountPoint::text("/sum"),
            image: "ubuntu".into(),
            command: "awk '{s+=$1} END {print s}' /counts > /sum".into(),
            depth,
        }
    }

    /// Listing 1 end-to-end: the GC count of a genome, distributed.
    #[test]
    fn listing1_gc_count_end_to_end() {
        let genome = "GATTACAGGCC\nTTGGCCAA\nGCGCGCGC\nAAAA";
        let expected = genome.chars().filter(|c| *c == 'G' || *c == 'C').count();

        let ds = Dataset::parallelize_text(genome, "\n", 4);
        let out = MaRe::new(cluster(2), ds)
            .map(gc_spec())
            .reduce(sum_spec(2))
            .collect_text()
            .unwrap();
        assert_eq!(out, expected.to_string());
    }

    #[test]
    fn reduce_depth_controls_shuffle_count() {
        for k in 1..=3usize {
            let ds = Dataset::parallelize_text(&"G\n".repeat(64), "\n", 16);
            let m = MaRe::new(cluster(4), ds).map(gc_spec()).reduce(sum_spec(k));
            let shuffles = m.dataset().plan().num_shuffles();
            assert!(
                shuffles <= k,
                "depth {k} gave {shuffles} shuffles: {}",
                m.dataset().describe()
            );
            // deeper tree, same answer
            assert_eq!(m.collect_text().unwrap(), "64");
        }
    }

    #[test]
    fn reduce_always_ends_single_partition() {
        for parts in [1usize, 2, 5, 16, 33] {
            let ds = Dataset::parallelize_text(&"G\n".repeat(33), "\n", parts);
            let m = MaRe::new(cluster(4), ds).map(gc_spec()).reduce(sum_spec(2));
            let out = m.run().unwrap();
            assert_eq!(out.partitions.len(), 1, "parts={parts}");
            assert_eq!(out.collect_text("\n").trim(), "33", "parts={parts}");
        }
    }

    #[test]
    fn repartition_by_groups_keys() {
        // records "chrN:value"; group by chromosome, then count per
        // partition — every partition must see exactly one chromosome
        let recs: Vec<String> = (0..24)
            .map(|i| format!("chr{}:r{}", i % 3, i))
            .collect();
        let ds = Dataset::parallelize_text(&recs.join("\n"), "\n", 8);
        let m = MaRe::new(cluster(4), ds).repartition_by(
            Arc::new(|r: &Record| r.as_text().unwrap().split(':').next().unwrap().into()),
            3,
        );
        let out = m.run().unwrap();
        assert_eq!(out.partitions.len(), 3);
        let mut seen_chroms = std::collections::HashSet::new();
        for p in &out.partitions {
            let chroms: std::collections::HashSet<String> = p
                .records
                .iter()
                .map(|r| r.as_text().unwrap().split(':').next().unwrap().to_string())
                .collect();
            assert!(chroms.len() <= 1, "mixed partition: {chroms:?}");
            seen_chroms.extend(chroms);
        }
        assert_eq!(seen_chroms.len(), 3);
    }

    #[test]
    fn map_generates_single_stage() {
        let ds = Dataset::parallelize_text("G\nC", "\n", 2);
        let m = MaRe::new(cluster(2), ds).map(gc_spec()).map(gc_spec());
        let pp = crate::cluster::compile(m.dataset().plan());
        assert_eq!(pp.stages.len(), 1, "maps must fuse (Figure 1)");
        assert!(matches!(pp.stages[0].output, StageOutput::Final));
    }

    #[test]
    fn disk_mounts_propagate_to_ops() {
        let ds = Dataset::parallelize_text("G", "\n", 1);
        let m = MaRe::new(cluster(1), ds).with_disk_mounts(true).map(gc_spec());
        let pp = crate::cluster::compile(m.dataset().plan());
        assert!(pp.stages[0].ops[0].uses_disk_mount());
    }

    #[test]
    fn interactive_reuse_same_mare_multiple_actions() {
        // the paper's interactivity claim: actions can be re-run and
        // extended from the same handle (lineage is immutable)
        let ds = Dataset::parallelize_text("GG\nCC", "\n", 2);
        let m = MaRe::new(cluster(2), ds).map(gc_spec());
        let a = m.clone().reduce(sum_spec(2)).collect_text().unwrap();
        let b = m.reduce(sum_spec(1)).collect_text().unwrap();
        assert_eq!(a, "4");
        assert_eq!(b, "4");
    }

    /// The shim and the fluent builder must lower identically.
    #[test]
    fn shim_and_builder_agree() {
        let genome = "GGCC\nAATT\nGCGC\nTTAA\nCCGG\nATAT";
        let ds = || Dataset::parallelize_text(genome, "\n", 3);
        let old = MaRe::new(cluster(2), ds())
            .map(gc_spec())
            .reduce(sum_spec(2))
            .collect_text()
            .unwrap();
        let new = MaRe::source(cluster(2), ds())
            .map("ubuntu", "grep -o '[GC]' /dna | wc -l > /count")
            .mounts("/dna", "/count")
            .reduce("ubuntu", "awk '{s+=$1} END {print s}' /counts > /sum")
            .mounts("/counts", "/sum")
            .depth(2)
            .build()
            .unwrap()
            .collect_text()
            .unwrap();
        assert_eq!(old, new);
        assert_eq!(old, "10");
    }
}
