//! Optimizer passes over the logical [`Pipeline`].
//!
//! Because primitives now build an IR instead of mutating lineage, the
//! framework sees the whole job before anything is lowered and can:
//!
//! 1. **Fuse consecutive containerized maps** on the same image whose
//!    mounts chain (`a` writes exactly where `b` reads) into ONE shell
//!    invocation — fewer simulated container launches, fewer stage-in/
//!    stage-out staging rounds (measurable in `micro_hotpath` and the
//!    launch-count assertions below).
//! 2. **Plan the reduce tree depth K** from the command's cost model
//!    and the cluster size when the user did not pin it (`depth=auto`).
//!
//! A third rewrite — eliding the redundant final aggregation the seed
//! appended after an already-converged tree — lives in the lowering
//! itself (`pipeline::Lowering::lower_reduce`), where the partition
//! count is known exactly.

use crate::cluster::task::CONTAINER_START;
use crate::simtime::{CostModel, Duration};

use super::pipeline::{MapStep, Pipeline, PipelineOp, ReduceStep};

/// What the optimizer knows about the job's environment.
#[derive(Debug, Clone)]
pub struct OptEnv {
    pub workers: usize,
    pub source_partitions: usize,
    /// Observed per-partition ingested byte sizes, in partition order
    /// (what `IngestReport::partition_bytes` measured, or equivalently
    /// the materialized source's partition payload sizes). `None` —
    /// e.g. during O(1) stub validation — falls back to the nominal
    /// `PLAN_RECORD_BYTES` the planner used before observation.
    pub partition_bytes: Option<Vec<u64>>,
}

impl OptEnv {
    /// The environment for a job over `source` on a `workers`-wide
    /// cluster, observing the source's actual per-partition byte sizes
    /// (source datasets are always fully materialized `Plan::Source`
    /// nodes; anything else planned against nominal sizes).
    pub fn for_source(workers: usize, source: &crate::dataset::Dataset) -> OptEnv {
        let partition_bytes = match source.plan().as_ref() {
            crate::dataset::Plan::Source { partitions, .. } => {
                Some(partitions.iter().map(|p| p.size_bytes()).collect())
            }
            _ => None,
        };
        OptEnv {
            workers,
            source_partitions: source.num_partitions(),
            partition_bytes,
        }
    }

    /// Bytes one aggregation unit is planned at: the observed mean
    /// partition size when ingestion measured one, else nominal.
    fn unit_bytes(&self) -> f64 {
        match &self.partition_bytes {
            Some(bytes) if !bytes.is_empty() => {
                let mean = bytes.iter().sum::<u64>() as f64 / bytes.len() as f64;
                if mean > 0.0 {
                    mean
                } else {
                    PLAN_RECORD_BYTES
                }
            }
            _ => PLAN_RECORD_BYTES,
        }
    }
}

/// What the passes did (surfaced by `explain()`).
#[derive(Debug, Clone, Default)]
pub struct OptReport {
    /// Map nodes eliminated by map-map fusion.
    pub fused_maps: usize,
    /// Map nodes folded into the first level of a following reduce.
    pub maps_fused_into_reduce: usize,
    /// Combiners pushed below a preceding shuffle boundary (a
    /// `.combine()`-declared reduce directly after a `repartitionBy`).
    pub pushed_combiners: usize,
    /// Depths chosen for `depth=auto` reduces, in pipeline order.
    pub planned_depths: Vec<usize>,
}

impl OptReport {
    pub fn summary(&self) -> String {
        let mut parts = Vec::new();
        if self.fused_maps > 0 {
            parts.push(format!(
                "{} map{} fused",
                self.fused_maps,
                if self.fused_maps == 1 { "" } else { "s" }
            ));
        }
        if self.maps_fused_into_reduce > 0 {
            parts.push(format!(
                "{} map{} fused into reduce level 0",
                self.maps_fused_into_reduce,
                if self.maps_fused_into_reduce == 1 { "" } else { "s" }
            ));
        }
        if self.pushed_combiners > 0 {
            parts.push(format!(
                "{} combiner{} pushed below the shuffle",
                self.pushed_combiners,
                if self.pushed_combiners == 1 { "" } else { "s" }
            ));
        }
        for k in &self.planned_depths {
            parts.push(format!("reduce depth auto-planned to {k}"));
        }
        if parts.is_empty() {
            parts.push("no rewrites".into());
        }
        parts.join(", ")
    }
}

/// Run all passes; returns the rewritten pipeline and a report.
pub fn optimize(pipeline: &Pipeline, env: &OptEnv) -> (Pipeline, OptReport) {
    let mut report = OptReport::default();
    let fused = fuse_maps(pipeline, &mut report);
    let folded = fuse_maps_into_reduces(&fused, &mut report);
    let combined = push_combiners(&folded, &mut report);
    let planned = plan_depths(&combined, env, &mut report);
    (planned, report)
}

/// Whether `a` then `b` can run as one container invocation: same
/// image, same mount backing, and `b` reads exactly the file/dir `a`
/// wrote (streamed mounts are excluded — the middle stdout capture
/// would be lost).
///
/// Known semantic relaxation (same family as Spark's stage pipelining
/// of side-effecting ops): the unfused boundary round-trips records
/// through `dataset::Splitter`, which drops whitespace-only chunks, while
/// the fused command reads `a`'s raw output file in place. A map whose
/// output is entirely whitespace can therefore yield a different
/// downstream result fused vs unfused. None of the paper's commands
/// emit whitespace-only records; use `.no_optimize()` to pin the
/// unfused boundary semantics when yours do.
pub fn can_fuse(a: &MapStep, b: &MapStep) -> bool {
    a.image == b.image
        && a.disk_mounts == b.disk_mounts
        && !a.output_mount.is_stream()
        && a.output_mount == b.input_mount
        // fused, `a`'s input partition is staged at a.input_mount in the
        // SAME container fs that stage_out reads b.output_mount from; if
        // the paths collide, a command that writes nothing would read the
        // staged input back as its "output" (unfused it reads nothing).
        // Streams stage no file / read captured stdout, so a stream on
        // either side cannot collide (their shared "<stdio>" sentinel
        // path must not trip the guard)
        && (a.input_mount.is_stream()
            || b.output_mount.is_stream()
            || a.input_mount.path() != b.output_mount.path())
}

fn fuse_two(a: &MapStep, b: &MapStep) -> MapStep {
    MapStep {
        input_mount: a.input_mount.clone(),
        output_mount: b.output_mount.clone(),
        image: a.image.clone(),
        // the mini-shell runs newline-separated commands sequentially
        // in the same container fs, so `b` sees `a`'s output in place
        command: format!("{}\n{}", a.command, b.command),
        disk_mounts: a.disk_mounts,
    }
}

/// Pass 1: fold chains of fusable maps left-to-right.
fn fuse_maps(pipeline: &Pipeline, report: &mut OptReport) -> Pipeline {
    let mut out: Vec<PipelineOp> = Vec::with_capacity(pipeline.ops().len());
    for op in pipeline.ops() {
        if let PipelineOp::Map(next) = op {
            let fusable =
                matches!(out.last(), Some(PipelineOp::Map(prev)) if can_fuse(prev, next));
            if fusable {
                let Some(PipelineOp::Map(prev)) = out.pop() else {
                    unreachable!("last element was checked to be a Map");
                };
                out.push(PipelineOp::Map(fuse_two(&prev, next)));
                report.fused_maps += 1;
                continue;
            }
        }
        out.push(op.clone());
    }
    Pipeline::new(out)
}

/// Whether map `m` can fold into the FIRST tree level of reduce `r`:
/// same image, same mount backing, `r` reads exactly the file `m`
/// wrote, and neither boundary streams (the chained file lives in the
/// shared container fs). Same whitespace-only-record relaxation as
/// [`can_fuse`].
pub fn can_fuse_into_reduce(m: &MapStep, r: &ReduceStep) -> bool {
    m.image == r.image
        && m.disk_mounts == r.disk_mounts
        && !m.output_mount.is_stream()
        && !m.input_mount.is_stream()
        && m.output_mount == r.input_mount
        // same collision guard as `can_fuse`: level 0 stages the input
        // partition at m.input_mount (non-stream, per above) in the
        // container fs stage_out reads r.output_mount from; a streamed
        // reduce output cannot collide
        && (r.output_mount.is_stream() || m.input_mount.path() != r.output_mount.path())
}

/// Pass 1b (ROADMAP item): fold a map into the first level of the
/// reduce that follows it. Level 0 of the tree then runs
/// `map.command` + reduce command in ONE container per partition —
/// saving one container start per source partition per job — while
/// later levels (which aggregate reducer outputs, not map inputs) run
/// the plain reduce command. The launch-count delta is asserted in the
/// tests below and rendered by `Job::explain()`.
fn fuse_maps_into_reduces(pipeline: &Pipeline, report: &mut OptReport) -> Pipeline {
    let mut out: Vec<PipelineOp> = Vec::with_capacity(pipeline.ops().len());
    for op in pipeline.ops() {
        if let PipelineOp::Reduce(next) = op {
            let fusable = next.fused.is_none()
                && matches!(out.last(), Some(PipelineOp::Map(prev)) if can_fuse_into_reduce(prev, next));
            if fusable {
                let Some(PipelineOp::Map(prev)) = out.pop() else {
                    unreachable!("last element was checked to be a Map");
                };
                let mut folded = next.clone();
                folded.fused = Some(prev);
                out.push(PipelineOp::Reduce(folded));
                report.maps_fused_into_reduce += 1;
                continue;
            }
        }
        out.push(op.clone());
    }
    Pipeline::new(out)
}

/// Pass 1c (the shuffle-path tentpole): push a `.combine()`-declared
/// reduce's command BELOW the shuffle boundary that feeds it. The
/// pattern `repartitionBy` → `reduce{combine}` rewrites the
/// repartition node to carry the reduce step as a map-side combiner:
/// at execution time the shuffle service runs that command once per
/// map-side partition before routing (`shuffle::shuffle_combined`), so
/// partial aggregates — not raw records — cross the interconnect. The
/// reduce node itself stays in place and re-aggregates the partials
/// (sound exactly because `.combine()` asserts associativity +
/// commutativity).
///
/// A reduce that already carries a fused map is skipped: the fused map
/// runs AFTER the shuffle at tree level 0, so combining its *input*
/// records map-side would aggregate pre-map data. (The fusion pass
/// only folds maps adjacent to the reduce, so this pattern cannot
/// arise today — the guard is load-bearing against pass reordering.)
fn push_combiners(pipeline: &Pipeline, report: &mut OptReport) -> Pipeline {
    let ops = pipeline.ops();
    let mut out: Vec<PipelineOp> = Vec::with_capacity(ops.len());
    for (i, op) in ops.iter().enumerate() {
        if let PipelineOp::RepartitionBy { key, partitions, combine: None } = op {
            if let Some(PipelineOp::Reduce(r)) = ops.get(i + 1) {
                if r.combine && r.fused.is_none() {
                    out.push(PipelineOp::RepartitionBy {
                        key: key.clone(),
                        partitions: *partitions,
                        combine: Some(Box::new(r.clone())),
                    });
                    report.pushed_combiners += 1;
                    continue;
                }
            }
        }
        out.push(op.clone());
    }
    Pipeline::new(out)
}

/// Pass 2: resolve `depth=auto` reduces via the cost model, tracking
/// the partition count as it evolves through the pipeline.
fn plan_depths(pipeline: &Pipeline, env: &OptEnv, report: &mut OptReport) -> Pipeline {
    let mut parts = env.source_partitions.max(1);
    let mut out = Vec::with_capacity(pipeline.ops().len());
    for op in pipeline.ops() {
        match op {
            PipelineOp::Reduce(r) => {
                let mut r = r.clone();
                if r.depth.is_none() {
                    let k = plan_reduce_depth_bytes(
                        &super::cost::infer(&r.command),
                        parts,
                        env.workers,
                        env.unit_bytes(),
                    );
                    report.planned_depths.push(k);
                    r.depth = Some(k);
                }
                parts = 1;
                out.push(PipelineOp::Reduce(r));
            }
            PipelineOp::RepartitionBy { partitions, .. }
            | PipelineOp::Repartition { partitions } => {
                parts = (*partitions).max(1);
                out.push(op.clone());
            }
            other => out.push(other.clone()),
        }
    }
    Pipeline::new(out)
}

/// Nominal aggregated-record size for depth planning (one reducer
/// output per partition; molecule/VCF-sized rather than line-sized).
const PLAN_RECORD_BYTES: f64 = 64.0 * 1024.0;
/// Nominal per-shuffle latency charged per tree level.
const PLAN_SHUFFLE: Duration = Duration(1_000_000); // 1 s

/// Choose the tree depth K minimizing the modeled virtual makespan of
/// the reduce: deeper trees add shuffles and container launches but cap
/// how many partition outputs any single task must aggregate. Cheap
/// POSIX reducers on small clusters plan K=1; per-record-expensive
/// reducers over many partitions plan deeper trees.
///
/// Plans against the nominal aggregated-record size; prefer
/// [`plan_reduce_depth_bytes`] when ingestion observed real sizes.
pub fn plan_reduce_depth(cost: &CostModel, partitions: usize, workers: usize) -> usize {
    plan_reduce_depth_bytes(cost, partitions, workers, PLAN_RECORD_BYTES)
}

/// [`plan_reduce_depth`] with the aggregation-unit size measured by
/// ingestion (`IngestReport::partition_bytes` mean) instead of nominal —
/// byte-dominated reducers over fat partitions plan deeper trees than
/// the same command over thin ones.
pub fn plan_reduce_depth_bytes(
    cost: &CostModel,
    partitions: usize,
    workers: usize,
    unit_bytes: f64,
) -> usize {
    let parts = partitions.max(1);
    let workers = workers.max(1);
    let k_max = (parts as f64).log2().ceil().max(1.0) as usize;

    let per_unit = cost.secs_per_record + cost.secs_per_byte * unit_bytes;
    let mut best = (1usize, f64::INFINITY);
    for k in 1..=k_max {
        let scale = (parts as f64).powf(1.0 / k as f64).ceil().max(2.0) as usize;
        let mut p = parts;
        let mut units_per_task = 1f64;
        let mut total = 0f64;
        loop {
            let waves = p.div_ceil(workers) as f64;
            let task = (CONTAINER_START + cost.fixed).as_seconds() + units_per_task * per_unit;
            total += waves * task;
            if p == 1 {
                break;
            }
            let next = p.div_ceil(scale).max(1);
            units_per_task = (p as f64 / next as f64).ceil();
            p = next;
            total += PLAN_SHUFFLE.as_seconds();
        }
        if total < best.1 {
            best = (k, total);
        }
    }
    best.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mare::mount::MountPoint;
    use crate::mare::pipeline::ReduceStep;

    fn map(image: &str, command: &str, input: &str, output: &str) -> MapStep {
        MapStep {
            input_mount: MountPoint::text(input),
            output_mount: MountPoint::text(output),
            image: image.into(),
            command: command.into(),
            disk_mounts: false,
        }
    }

    fn wrap(ops: Vec<PipelineOp>) -> Pipeline {
        let mut all = vec![PipelineOp::Ingest { label: "test".into(), partitions: 8 }];
        all.extend(ops);
        all.push(PipelineOp::Collect);
        Pipeline::new(all)
    }

    const ENV: OptEnv =
        OptEnv { workers: 4, source_partitions: 8, partition_bytes: None };

    #[test]
    fn chained_maps_on_same_image_fuse() {
        let p = wrap(vec![
            PipelineOp::Map(map("ubuntu", "grep -o G /dna > /a", "/dna", "/a")),
            PipelineOp::Map(map("ubuntu", "cat /a > /b", "/a", "/b")),
            PipelineOp::Map(map("ubuntu", "wc -l /b > /count", "/b", "/count")),
        ]);
        let (opt, report) = optimize(&p, &ENV);
        assert_eq!(opt.num_maps(), 1, "{}", opt.describe());
        assert_eq!(report.fused_maps, 2);
        let fused = opt
            .ops()
            .iter()
            .find_map(|o| match o {
                PipelineOp::Map(m) => Some(m.clone()),
                _ => None,
            })
            .unwrap();
        assert_eq!(fused.input_mount, MountPoint::text("/dna"));
        assert_eq!(fused.output_mount, MountPoint::text("/count"));
        assert_eq!(fused.command, "grep -o G /dna > /a\ncat /a > /b\nwc -l /b > /count");
    }

    #[test]
    fn different_image_or_broken_chain_does_not_fuse() {
        // different image
        let p = wrap(vec![
            PipelineOp::Map(map("ubuntu", "cat /a > /b", "/a", "/b")),
            PipelineOp::Map(map("other", "cat /b > /c", "/b", "/c")),
        ]);
        assert_eq!(optimize(&p, &ENV).0.num_maps(), 2);

        // mounts don't chain
        let p = wrap(vec![
            PipelineOp::Map(map("ubuntu", "cat /a > /b", "/a", "/b")),
            PipelineOp::Map(map("ubuntu", "cat /x > /c", "/x", "/c")),
        ]);
        assert_eq!(optimize(&p, &ENV).0.num_maps(), 2);

        // a repartition between them is a hard barrier
        let p = wrap(vec![
            PipelineOp::Map(map("ubuntu", "cat /a > /b", "/a", "/b")),
            PipelineOp::Repartition { partitions: 2 },
            PipelineOp::Map(map("ubuntu", "cat /b > /c", "/b", "/c")),
        ]);
        assert_eq!(optimize(&p, &ENV).0.num_maps(), 2);
    }

    #[test]
    fn stream_mounts_do_not_fuse() {
        let a = MapStep {
            input_mount: MountPoint::stream(),
            output_mount: MountPoint::stream(),
            image: "ubuntu".into(),
            command: "grep -o G".into(),
            disk_mounts: false,
        };
        let b = a.clone();
        assert!(!can_fuse(&a, &b));
    }

    #[test]
    fn auto_depth_resolves_and_pinned_depth_is_untouched() {
        let reduce = |depth| {
            PipelineOp::Reduce(ReduceStep {
                input_mount: MountPoint::text("/in"),
                output_mount: MountPoint::text("/out"),
                image: "ubuntu".into(),
                command: "awk '{s+=$1} END {print s}' /in > /out".into(),
                depth,
                disk_mounts: false,
                fused: None,
                combine: false,
            })
        };
        let (opt, report) = optimize(&wrap(vec![reduce(None)]), &ENV);
        let planned = match &opt.ops()[1] {
            PipelineOp::Reduce(r) => r.depth,
            other => panic!("expected reduce, got {other:?}"),
        };
        assert!(planned.is_some());
        assert_eq!(report.planned_depths, vec![planned.unwrap()]);

        let (opt, report) = optimize(&wrap(vec![reduce(Some(3))]), &ENV);
        match &opt.ops()[1] {
            PipelineOp::Reduce(r) => assert_eq!(r.depth, Some(3)),
            other => panic!("expected reduce, got {other:?}"),
        }
        assert!(report.planned_depths.is_empty());
    }

    #[test]
    fn planned_depth_is_bounded_and_scales_with_cost() {
        let posix = CostModel {
            fixed: Duration::seconds(0.01),
            secs_per_byte: 1.5e-9,
            secs_per_record: 0.0,
            cpus: 1,
        };
        for parts in [1usize, 2, 8, 64, 256] {
            for workers in [1usize, 4, 16] {
                let k = plan_reduce_depth(&posix, parts, workers);
                let bound = (parts as f64).log2().ceil().max(1.0) as usize;
                assert!(k >= 1 && k <= bound, "parts={parts} workers={workers} k={k}");
            }
        }
        // cheap reducer, few partitions: flat tree
        assert_eq!(plan_reduce_depth(&posix, 8, 16), 1);
        // per-record-expensive reducer over many partitions: deeper tree
        let heavy = CostModel {
            fixed: Duration::seconds(0.1),
            secs_per_byte: 0.0,
            secs_per_record: 2.0,
            cpus: 1,
        };
        assert!(plan_reduce_depth(&heavy, 256, 16) > 1);
    }

    #[test]
    fn observed_partition_bytes_drive_auto_depth() {
        // a byte-dominated reducer: unit size decides the tree shape
        let byte_bound = CostModel {
            fixed: Duration::seconds(0.01),
            secs_per_byte: 1e-6,
            secs_per_record: 0.0,
            cpus: 1,
        };
        let thin = plan_reduce_depth_bytes(&byte_bound, 256, 4, 512.0);
        let fat = plan_reduce_depth_bytes(&byte_bound, 256, 4, 512.0 * 1024.0);
        assert!(
            fat > thin,
            "fat partitions must plan a deeper tree (thin K={thin}, fat K={fat})"
        );

        // the same distinction flows through optimize() via OptEnv
        let reduce = PipelineOp::Reduce(ReduceStep {
            input_mount: MountPoint::text("/in"),
            output_mount: MountPoint::text("/out"),
            image: "ubuntu".into(),
            command: "awk '{s+=$1} END {print s}' /in > /out".into(),
            depth: None,
            disk_mounts: false,
            fused: None,
            combine: false,
        });
        let plan_with = |bytes: Option<Vec<u64>>| {
            let env = OptEnv { workers: 4, source_partitions: 256, partition_bytes: bytes };
            let mut p = vec![PipelineOp::Ingest { label: "test".into(), partitions: 256 }];
            p.push(reduce.clone());
            p.push(PipelineOp::Collect);
            let (_, report) = optimize(&Pipeline::new(p), &env);
            report.planned_depths[0]
        };
        let observed_fat = plan_with(Some(vec![8 << 20; 256]));
        let nominal = plan_with(None);
        assert!(
            observed_fat >= nominal,
            "observed 8 MiB partitions must not plan a flatter tree than \
             the 64 KiB nominal (observed K={observed_fat}, nominal K={nominal})"
        );
        // and the observed sizes are actually consumed: zero-byte
        // observations fall back to nominal rather than planning K for
        // an empty job
        assert_eq!(plan_with(Some(vec![0; 256])), nominal);
    }

    #[test]
    fn report_summary_reads_well() {
        let mut r = OptReport::default();
        assert_eq!(r.summary(), "no rewrites");
        r.fused_maps = 2;
        r.maps_fused_into_reduce = 1;
        r.planned_depths.push(2);
        let s = r.summary();
        assert!(s.contains("2 maps fused"), "{s}");
        assert!(s.contains("1 map fused into reduce level 0"), "{s}");
        assert!(s.contains("auto-planned to 2"), "{s}");
    }

    // ------------------------------------------- map-into-reduce fusion

    fn chaining_reduce(depth: Option<usize>) -> ReduceStep {
        ReduceStep {
            input_mount: MountPoint::text("/gc"),
            output_mount: MountPoint::text("/sum"),
            image: "ubuntu".into(),
            command: "awk '{s+=$1} END {print s}' /gc > /sum".into(),
            depth,
            disk_mounts: false,
            fused: None,
            combine: false,
        }
    }

    #[test]
    fn map_folds_into_following_reduce_when_mounts_chain() {
        let p = wrap(vec![
            PipelineOp::Map(map("ubuntu", "grep -c G /dna > /gc", "/dna", "/gc")),
            PipelineOp::Reduce(chaining_reduce(Some(1))),
        ]);
        let (opt, report) = optimize(&p, &ENV);
        assert_eq!(report.maps_fused_into_reduce, 1);
        assert_eq!(opt.num_maps(), 0, "{}", opt.describe());
        let folded = opt
            .ops()
            .iter()
            .find_map(|o| match o {
                PipelineOp::Reduce(r) => Some(r.clone()),
                _ => None,
            })
            .unwrap();
        let m = folded.fused.expect("carries the folded map");
        assert_eq!(m.input_mount, MountPoint::text("/dna"));
        // the optimized-plan rendering surfaces the fold
        assert!(opt.describe().contains("+map grep"), "{}", opt.describe());
    }

    #[test]
    fn map_into_reduce_requires_image_and_mount_chain() {
        // different image: no fold
        let p = wrap(vec![
            PipelineOp::Map(map("other", "grep -c G /dna > /gc", "/dna", "/gc")),
            PipelineOp::Reduce(chaining_reduce(Some(1))),
        ]);
        let (opt, report) = optimize(&p, &ENV);
        assert_eq!(report.maps_fused_into_reduce, 0);
        assert_eq!(opt.num_maps(), 1);

        // mounts don't chain (the gc workload's /count vs /counts): no fold
        let p = wrap(vec![
            PipelineOp::Map(map("ubuntu", "grep -c G /dna > /count", "/dna", "/count")),
            PipelineOp::Reduce(chaining_reduce(Some(1))),
        ]);
        let (opt, report) = optimize(&p, &ENV);
        assert_eq!(report.maps_fused_into_reduce, 0);
        assert_eq!(opt.num_maps(), 1);

        // a shuffle between them is a hard barrier
        let p = wrap(vec![
            PipelineOp::Map(map("ubuntu", "grep -c G /dna > /gc", "/dna", "/gc")),
            PipelineOp::Repartition { partitions: 2 },
            PipelineOp::Reduce(chaining_reduce(Some(1))),
        ]);
        let (opt, report) = optimize(&p, &ENV);
        assert_eq!(report.maps_fused_into_reduce, 0);
        assert_eq!(opt.num_maps(), 1);

        // reduce output path colliding with the map's input path: the
        // fused container would stage the input partition exactly where
        // stage_out reads the result — no fold
        let colliding = ReduceStep {
            input_mount: MountPoint::text("/gc"),
            output_mount: MountPoint::text("/dna"),
            image: "ubuntu".into(),
            command: "awk '{s+=$1} END {print s}' /gc > /dna".into(),
            depth: Some(1),
            disk_mounts: false,
            fused: None,
            combine: false,
        };
        let p = wrap(vec![
            PipelineOp::Map(map("ubuntu", "grep -c G /dna > /gc", "/dna", "/gc")),
            PipelineOp::Reduce(colliding),
        ]);
        let (opt, report) = optimize(&p, &ENV);
        assert_eq!(report.maps_fused_into_reduce, 0);
        assert_eq!(opt.num_maps(), 1);

        // same guard on map-map fusion
        let a = map("ubuntu", "cat /x > /mid", "/x", "/mid");
        let b = map("ubuntu", "cat /mid > /x", "/mid", "/x");
        assert!(!can_fuse(&a, &b));

        // ...but stream boundary mounts share the "<stdio>" sentinel
        // path and stage no file — they must NOT read as a collision
        let mut stream_in = map("ubuntu", "grep -o G > /mid", "/x", "/mid");
        stream_in.input_mount = MountPoint::stream();
        let mut stream_out = map("ubuntu", "wc -l /mid", "/mid", "/x");
        stream_out.output_mount = MountPoint::stream();
        assert!(can_fuse(&stream_in, &stream_out));
    }

    /// The headline: folding the map into reduce level 0 launches
    /// exactly one fewer container per source partition, with an
    /// identical result.
    #[test]
    fn map_into_reduce_fusion_saves_one_launch_per_partition() {
        use crate::cluster::{Cluster, ClusterConfig};
        use crate::container::Registry;
        use crate::dataset::Dataset;
        use crate::mare::MaRe;
        use std::sync::Arc;

        let cluster = || {
            let mut reg = Registry::new();
            reg.push(crate::tools::images::ubuntu());
            Arc::new(Cluster::new(Arc::new(reg), None, ClusterConfig::sized(2, 4)))
        };
        const PARTS: usize = 4;
        let run = |optimize: bool| {
            let ds = Dataset::parallelize_text(&"G\nA\nG\n".repeat(8), "\n", PARTS);
            let mut b = MaRe::source(cluster(), ds)
                .map("ubuntu", "grep -c G /dna > /gc")
                .mounts("/dna", "/gc")
                .reduce("ubuntu", "awk '{s+=$1} END {print s}' /gc > /sum")
                .mounts("/gc", "/sum")
                .depth(1);
            if !optimize {
                b = b.no_optimize();
            }
            let job = b.build().unwrap();
            let text = job.collect_text().unwrap();
            (text, job.container_launches(), job.explain())
        };
        let (plain_text, plain_launches, _) = run(false);
        let (fused_text, fused_launches, fused_explain) = run(true);
        assert_eq!(plain_text, fused_text, "fusion must not change results");
        assert_eq!(plain_text, "16");
        // depth-1 tree over 4 partitions: level 0 (4) + final merge (1);
        // unfused additionally launches the 4 map containers
        assert_eq!(plain_launches, PARTS as u64 + PARTS as u64 + 1);
        assert_eq!(fused_launches, PARTS as u64 + 1);
        assert_eq!(
            plain_launches - fused_launches,
            PARTS as u64,
            "one container start saved per partition"
        );
        assert!(fused_explain.contains("fused into reduce level 0"), "{fused_explain}");
    }

    // ------------------------------------------------- combiner pushdown

    use crate::mare::pipeline::KeySelector;

    fn assoc_reduce(combine: bool) -> ReduceStep {
        ReduceStep {
            input_mount: MountPoint::text("/in"),
            output_mount: MountPoint::text("/out"),
            image: "ubuntu".into(),
            command: "awk '{s+=$1} END {print s}' /in > /out".into(),
            depth: Some(1),
            disk_mounts: false,
            fused: None,
            combine,
        }
    }

    fn repart(partitions: usize) -> PipelineOp {
        PipelineOp::RepartitionBy {
            key: KeySelector::named("first_word").unwrap(),
            partitions,
            combine: None,
        }
    }

    #[test]
    fn declared_combine_is_pushed_below_the_shuffle() {
        let p = wrap(vec![repart(4), PipelineOp::Reduce(assoc_reduce(true))]);
        let (opt, report) = optimize(&p, &ENV);
        assert_eq!(report.pushed_combiners, 1);
        assert!(report.summary().contains("1 combiner pushed below the shuffle"));
        let carried = opt
            .ops()
            .iter()
            .find_map(|o| match o {
                PipelineOp::RepartitionBy { combine, .. } => combine.as_ref(),
                _ => None,
            })
            .expect("repartitionBy carries the combiner");
        assert_eq!(carried.command, assoc_reduce(true).command);
        // the reduce node stays in place to re-aggregate the partials
        assert_eq!(opt.num_reduces(), 1);
        assert!(opt.describe().contains("+combine awk"), "{}", opt.describe());
    }

    #[test]
    fn combiner_pushdown_requires_declaration_and_adjacency() {
        // no `.combine()` declaration: no pushdown
        let p = wrap(vec![repart(4), PipelineOp::Reduce(assoc_reduce(false))]);
        let (opt, report) = optimize(&p, &ENV);
        assert_eq!(report.pushed_combiners, 0);
        assert!(!opt.describe().contains("+combine"), "{}", opt.describe());

        // a map between the shuffle and the reduce: no pushdown (the
        // combiner would aggregate pre-map records)
        let p = wrap(vec![
            repart(4),
            PipelineOp::Map(map("other", "cat /x > /in", "/x", "/in")),
            PipelineOp::Reduce(assoc_reduce(true)),
        ]);
        let (_, report) = optimize(&p, &ENV);
        assert_eq!(report.pushed_combiners, 0);

        // balanced repartition (no keys): no pushdown — the combiner is
        // only sound below a keyed regrouping feeding the reduce
        let p = wrap(vec![
            PipelineOp::Repartition { partitions: 4 },
            PipelineOp::Reduce(assoc_reduce(true)),
        ]);
        let (_, report) = optimize(&p, &ENV);
        assert_eq!(report.pushed_combiners, 0);
    }

    #[test]
    fn combiner_and_map_fusion_compose() {
        // map | repartitionBy | reduce{combine}: the map cannot fold
        // into the reduce (shuffle barrier) but the combiner pushes
        let p = wrap(vec![
            PipelineOp::Map(map("ubuntu", "grep -c G /dna > /in", "/dna", "/in")),
            repart(4),
            PipelineOp::Reduce(assoc_reduce(true)),
        ]);
        let (opt, report) = optimize(&p, &ENV);
        assert_eq!(report.maps_fused_into_reduce, 0);
        assert_eq!(report.pushed_combiners, 1);
        assert_eq!(opt.num_maps(), 1);
    }
}
