//! Cost-model inference for containerized commands.
//!
//! The user writes a shell command (Listings 1–3); the DES needs a
//! virtual-time model for it. We scan the command for the tools it
//! invokes and sum their calibrated models (`tools/*::cost_model`,
//! calibrated against the paper's reported wall-clocks); a pipeline's
//! slot occupancy is the max `cpus` over its parts (`bwa -t 8` ⇒ 8).

use crate::simtime::{CostModel, Duration};
use crate::tools::{
    bwa::Bwa,
    fred::Fred,
    gatk::Gatk,
    kmer::{KmerAgg, Kmerize},
    sdsorter::SdSorter,
    vcf_concat::VcfConcat,
};

/// POSIX text tools: cheap, IO-bound.
fn posix_model() -> CostModel {
    CostModel {
        fixed: Duration::seconds(0.01),
        secs_per_byte: 1.5e-9,
        secs_per_record: 0.0,
        cpus: 1,
    }
}

/// `-t N` / `--threads N` style thread count, defaulting to 1.
fn threads_of(tokens: &[&str], flag: &str) -> u32 {
    tokens
        .iter()
        .position(|t| *t == flag)
        .and_then(|i| tokens.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(1)
}

/// Infer the cost model of a full container command (may be a pipeline
/// of several tools over several lines).
pub fn infer(command: &str) -> CostModel {
    let tokens: Vec<&str> = command.split_whitespace().collect();
    let mut total = CostModel::free();
    let mut cpus = 1u32;
    let mut matched = false;

    for (i, t) in tokens.iter().enumerate() {
        let model = match *t {
            "fred" => Some(Fred::cost_model()),
            "sdsorter" => Some(SdSorter::cost_model()),
            "bwa" => Some(Bwa::cost_model(threads_of(&tokens[i..], "-t"))),
            "gatk" => {
                // HaplotypeCaller dominates; the helper subcommands are
                // folded into its fixed cost
                match tokens.get(i + 1).copied() {
                    Some("HaplotypeCallerSpark") | Some("HaplotypeCaller") => {
                        Some(Gatk::cost_model(8))
                    }
                    _ => Some(CostModel {
                        fixed: Duration::seconds(6.0), // JVM startup
                        secs_per_byte: 4e-9,
                        secs_per_record: 0.0,
                        cpus: 1,
                    }),
                }
            }
            "vcf-concat" => Some(VcfConcat::cost_model()),
            "kmerize" => Some(Kmerize::cost_model()),
            "kmeragg" => Some(KmerAgg::cost_model()),
            "grep" | "awk" | "wc" | "sort" | "cat" | "gzip" | "gunzip" | "zcat"
            | "samtools" | "head" | "tail" | "uniq" | "tr" | "sed" | "cut" | "echo"
            | "tee" => Some(posix_model()),
            _ => None,
        };
        if let Some(m) = model {
            matched = true;
            total.fixed += m.fixed;
            total.secs_per_byte += m.secs_per_byte;
            total.secs_per_record += m.secs_per_record;
            cpus = cpus.max(m.cpus);
        }
    }

    if !matched {
        total = posix_model();
    }
    total.cpus = cpus;
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn listing1_commands_are_posix_cheap() {
        let m = infer("grep -o '[GC]' /dna | wc -l > /count");
        assert_eq!(m.cpus, 1);
        assert!(m.fixed < Duration::seconds(0.1));
        assert!(m.secs_per_record == 0.0);
    }

    #[test]
    fn listing2_fred_dominates() {
        let m = infer("fred -receptor /var/openeye/hiv1_protease.oeb -dbase /in.sdf");
        assert!(m.secs_per_record >= 0.5); // ~0.6 core-s per molecule
        assert_eq!(m.cpus, 1);
    }

    #[test]
    fn listing3_bwa_parses_threads() {
        let m = infer("bwa mem -t 8 -p /ref/x.fasta /in.fastq | samtools view > /out.sam");
        assert_eq!(m.cpus, 8);
    }

    #[test]
    fn listing3_gatk_haplotypecaller_is_multithreaded() {
        let m = infer(
            "gatk AddOrReplaceReadGroups --INPUT=/a --OUTPUT=/b\n\
             gatk BuildBamIndex --INPUT=/b\n\
             gatk HaplotypeCallerSpark -R /ref -I /b -O /out/x.vcf\n\
             gzip /out/*",
        );
        assert_eq!(m.cpus, 8);
        // helper JVMs + HC fixed costs accumulate
        assert!(m.fixed >= Duration::seconds(12.0));
    }

    #[test]
    fn kmer_tools_have_explicit_models() {
        let m = infer("kmerize -k 4 /seq > /kmers");
        assert_eq!(m.cpus, 1);
        assert!(m.secs_per_byte > posix_model().secs_per_byte);
        let m = infer("kmeragg /kmers > /counts");
        assert!(m.secs_per_byte > posix_model().secs_per_byte);
    }

    #[test]
    fn unknown_commands_default_posix() {
        let m = infer("./my-custom-binary --do-things");
        assert_eq!(m, posix_model());
    }
}
