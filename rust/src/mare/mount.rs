//! Mount points: how partitions shuttle between RDD records and
//! container volumes (§1.2.1).
//!
//! * [`MountPoint::TextFile`] — the partition's text records joined by a
//!   (configurable) separator into ONE file; results split back on the
//!   same separator. Default separator is `\n` ("each line is a
//!   record"); Listing 2 uses `\n$$$$\n` for SDF.
//! * [`MountPoint::BinaryFiles`] — each record is a DISTINCT file in a
//!   mount *directory*; results are every file found under the output
//!   directory.

use crate::container::Vfs;
use crate::dataset::{join_records, split_records, Record};
use crate::error::{MareError, Result};

/// A configured mount point.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MountPoint {
    TextFile { path: String, sep: String },
    BinaryFiles { dir: String },
    /// Stream records over the command's stdin/stdout instead of
    /// materializing a mount file — the §1.4 future-work improvement
    /// ("enabling data streams via standard input and output between
    /// MaRe and containers"). Avoids tmpfs/disk staging entirely; the
    /// command must read stdin / write stdout.
    StdStream { sep: String },
}

impl MountPoint {
    /// `TextFile("/dna")` — newline records.
    pub fn text(path: impl Into<String>) -> Self {
        MountPoint::TextFile { path: path.into(), sep: "\n".into() }
    }

    /// `TextFile("/in.sdf", "\n$$$$\n")` — custom record separator.
    pub fn text_sep(path: impl Into<String>, sep: impl Into<String>) -> Self {
        MountPoint::TextFile { path: path.into(), sep: sep.into() }
    }

    /// `BinaryFiles("/out")`.
    pub fn binary(dir: impl Into<String>) -> Self {
        MountPoint::BinaryFiles { dir: dir.into() }
    }

    /// Stream with newline records.
    pub fn stream() -> Self {
        MountPoint::StdStream { sep: "\n".into() }
    }

    /// Stream with a custom record separator.
    pub fn stream_sep(sep: impl Into<String>) -> Self {
        MountPoint::StdStream { sep: sep.into() }
    }

    pub fn is_stream(&self) -> bool {
        matches!(self, MountPoint::StdStream { .. })
    }

    pub fn path(&self) -> &str {
        match self {
            MountPoint::TextFile { path, .. } => path,
            MountPoint::BinaryFiles { dir } => dir,
            MountPoint::StdStream { .. } => "<stdio>",
        }
    }

    /// Bytes to stream to the command's stdin (StdStream input only).
    pub fn stage_stdin(&self, records: &[Record]) -> Result<Option<Vec<u8>>> {
        match self {
            MountPoint::StdStream { sep } => {
                let texts: Vec<String> = records
                    .iter()
                    .map(|r| {
                        r.as_text().map(String::from).ok_or_else(|| {
                            MareError::Container(
                                "binary record in StdStream mount (use BinaryFiles)".into(),
                            )
                        })
                    })
                    .collect::<Result<_>>()?;
                Ok(Some(join_records(&texts, sep).into_bytes()))
            }
            _ => Ok(None),
        }
    }

    /// Records from the command's captured stdout (StdStream output only).
    pub fn stage_stdout(&self, stdout: &[u8]) -> Result<Option<Vec<Record>>> {
        match self {
            MountPoint::StdStream { sep } => {
                let text = std::str::from_utf8(stdout).map_err(|_| {
                    MareError::Container("streamed stdout is not UTF-8".into())
                })?;
                Ok(Some(split_records(text, sep).into_iter().map(Record::text).collect()))
            }
            _ => Ok(None),
        }
    }

    /// Materialize records into container input files (none for
    /// streams — see [`Self::stage_stdin`]).
    pub fn stage_in(&self, records: &[Record]) -> Result<Vec<(String, Vec<u8>)>> {
        match self {
            MountPoint::StdStream { .. } => Ok(Vec::new()),
            MountPoint::TextFile { path, sep } => {
                let texts: Vec<String> = records
                    .iter()
                    .map(|r| {
                        r.as_text().map(String::from).ok_or_else(|| {
                            MareError::Container(format!(
                                "binary record `{}` in TextFile mount {path} \
                                 (use BinaryFiles)",
                                match r {
                                    Record::Binary { name, .. } => name.as_str(),
                                    _ => "?",
                                }
                            ))
                        })
                    })
                    .collect::<Result<_>>()?;
                Ok(vec![(path.clone(), join_records(&texts, sep).into_bytes())])
            }
            MountPoint::BinaryFiles { dir } => {
                let mut files = Vec::with_capacity(records.len());
                let mut seen = std::collections::HashSet::new();
                for (i, r) in records.iter().enumerate() {
                    let (name, bytes) = match r {
                        Record::Binary { name, bytes } => (basename(name), bytes.clone()),
                        Record::Text(t) => {
                            (format!("part-{i:05}.txt"), t.clone().into_bytes())
                        }
                    };
                    // de-clash names merged from different partitions
                    let name = if seen.insert(name.clone()) {
                        name
                    } else {
                        format!("{i:05}-{name}")
                    };
                    files.push((format!("{dir}/{name}"), bytes));
                }
                Ok(files)
            }
        }
    }

    /// Read the tool's output back into records (streams are read from
    /// captured stdout instead — see [`Self::stage_stdout`]).
    pub fn stage_out(&self, fs: &mut Vfs) -> Result<Vec<Record>> {
        match self {
            MountPoint::StdStream { .. } => Ok(Vec::new()),
            MountPoint::TextFile { path, sep } => {
                if !fs.exists(path) {
                    return Ok(vec![]); // tool produced nothing
                }
                let text = fs.read_string(path)?;
                Ok(split_records(&text, sep).into_iter().map(Record::text).collect())
            }
            MountPoint::BinaryFiles { dir } => {
                let files = fs.take_dir(dir)?;
                Ok(files
                    .into_iter()
                    .map(|(path, bytes)| {
                        let name = path
                            .strip_prefix(&format!("{dir}/"))
                            .unwrap_or(&path)
                            .to_string();
                        Record::binary(name, bytes)
                    })
                    .collect())
            }
        }
    }
}

fn basename(p: &str) -> String {
    p.rsplit('/').next().unwrap_or(p).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::Vfs;

    #[test]
    fn textfile_roundtrip_with_custom_sep() {
        let mp = MountPoint::text_sep("/in.sdf", "\n$$$$\n");
        let records = vec![Record::text("molA"), Record::text("molB")];
        let files = mp.stage_in(&records).unwrap();
        assert_eq!(files.len(), 1);
        assert_eq!(files[0].0, "/in.sdf");
        let mut fs = Vfs::disk();
        fs.write("/in.sdf", files[0].1.clone()).unwrap();
        // pretend the tool copied input to output unchanged
        let out = MountPoint::text_sep("/in.sdf", "\n$$$$\n").stage_out(&mut fs).unwrap();
        assert_eq!(out, records);
    }

    #[test]
    fn textfile_missing_output_is_empty() {
        let mp = MountPoint::text("/nope");
        let mut fs = Vfs::disk();
        assert!(mp.stage_out(&mut fs).unwrap().is_empty());
    }

    #[test]
    fn textfile_rejects_binary_records() {
        let mp = MountPoint::text("/t");
        let err = mp.stage_in(&[Record::binary("x.gz", vec![1])]).err().unwrap();
        assert!(err.to_string().contains("BinaryFiles"), "{err}");
    }

    #[test]
    fn binaryfiles_roundtrip_and_declash() {
        let mp = MountPoint::binary("/in");
        let records = vec![
            Record::binary("a.vcf.gz", vec![1]),
            Record::binary("sub/a.vcf.gz", vec![2]), // same basename
            Record::text("loose text"),
        ];
        let files = mp.stage_in(&records).unwrap();
        assert_eq!(files.len(), 3);
        let mut fs = Vfs::disk();
        for (p, b) in &files {
            fs.write(p, b.clone()).unwrap();
        }
        let out = MountPoint::binary("/in").stage_out(&mut fs).unwrap();
        assert_eq!(out.len(), 3);
        // all names distinct
        let names: std::collections::HashSet<_> = out
            .iter()
            .map(|r| match r {
                Record::Binary { name, .. } => name.clone(),
                _ => panic!(),
            })
            .collect();
        assert_eq!(names.len(), 3);
        // mount dir is drained after stage_out
        assert!(fs.list_dir("/in").unwrap().is_empty());
    }

    #[test]
    fn empty_partition_stages_empty_file() {
        let mp = MountPoint::text("/in");
        let files = mp.stage_in(&[]).unwrap();
        assert_eq!(files[0].1.len(), 0);
    }

    #[test]
    fn stream_mount_roundtrips_via_stdio() {
        let mp = MountPoint::stream_sep("\n$$$$\n");
        let records = vec![Record::text("molA"), Record::text("molB")];
        // no files materialized
        assert!(mp.stage_in(&records).unwrap().is_empty());
        let stdin = mp.stage_stdin(&records).unwrap().unwrap();
        // pretend the tool echoed its input
        let out = mp.stage_stdout(&stdin).unwrap().unwrap();
        assert_eq!(out, records);
        assert!(mp.is_stream());
    }

    #[test]
    fn stream_mount_rejects_binary_records() {
        let mp = MountPoint::stream();
        assert!(mp.stage_stdin(&[Record::binary("x", vec![1])]).is_err());
    }

    #[test]
    fn non_stream_mounts_have_no_stdio() {
        let mp = MountPoint::text("/in");
        assert!(mp.stage_stdin(&[Record::text("x")]).unwrap().is_none());
        assert!(mp.stage_stdout(b"y").unwrap().is_none());
        assert!(!mp.is_stream());
    }
}
