//! Mount points: how partitions shuttle between RDD records and
//! container volumes (§1.2.1).
//!
//! * [`MountPoint::TextFile`] — the partition's text records joined by a
//!   (configurable) separator into ONE file; results split back on the
//!   same separator. Default separator is `\n` ("each line is a
//!   record"); Listing 2 uses `\n$$$$\n` for SDF.
//! * [`MountPoint::BinaryFiles`] — each record is a DISTINCT file in a
//!   mount *directory*; results are every file found under the output
//!   directory.
//!
//! Staging is allocation-light: a TextFile mount is materialized by a
//! [`SegmentWriter`] straight from the record slices (one exact-capacity
//! buffer, instead of the old per-record `String` clone + `join` +
//! `into_bytes` triple copy); a BinaryFiles mount binds each record's
//! [`Shared`] payload into the VFS by refcount. Stage-out goes the
//! other way zero-copy: output records are O(1) slices of the VFS file
//! buffers ([`Splitter::split`] / `take_dir`).

use crate::container::Vfs;
use crate::dataset::{Record, Splitter};
use crate::error::{MareError, Result};
use crate::util::bytes::{SegmentWriter, Shared, SharedStr};

/// A configured mount point.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MountPoint {
    TextFile { path: String, sep: String },
    BinaryFiles { dir: String },
    /// Stream records over the command's stdin/stdout instead of
    /// materializing a mount file — the §1.4 future-work improvement
    /// ("enabling data streams via standard input and output between
    /// MaRe and containers"). Avoids tmpfs/disk staging entirely; the
    /// command must read stdin / write stdout.
    StdStream { sep: String },
}

impl MountPoint {
    /// `TextFile("/dna")` — newline records.
    pub fn text(path: impl Into<String>) -> Self {
        MountPoint::TextFile { path: path.into(), sep: "\n".into() }
    }

    /// `TextFile("/in.sdf", "\n$$$$\n")` — custom record separator.
    pub fn text_sep(path: impl Into<String>, sep: impl Into<String>) -> Self {
        MountPoint::TextFile { path: path.into(), sep: sep.into() }
    }

    /// `BinaryFiles("/out")`.
    pub fn binary(dir: impl Into<String>) -> Self {
        MountPoint::BinaryFiles { dir: dir.into() }
    }

    /// Stream with newline records.
    pub fn stream() -> Self {
        MountPoint::StdStream { sep: "\n".into() }
    }

    /// Stream with a custom record separator.
    pub fn stream_sep(sep: impl Into<String>) -> Self {
        MountPoint::StdStream { sep: sep.into() }
    }

    pub fn is_stream(&self) -> bool {
        matches!(self, MountPoint::StdStream { .. })
    }

    pub fn path(&self) -> &str {
        match self {
            MountPoint::TextFile { path, .. } => path,
            MountPoint::BinaryFiles { dir } => dir,
            MountPoint::StdStream { .. } => "<stdio>",
        }
    }

    /// Bytes to stream to the command's stdin (StdStream input only).
    pub fn stage_stdin(&self, records: &[Record]) -> Result<Option<Vec<u8>>> {
        match self {
            MountPoint::StdStream { sep } => {
                Ok(Some(join_text_records(records, sep, "StdStream", "BinaryFiles")?.into_vec()))
            }
            _ => Ok(None),
        }
    }

    /// Records from the command's captured stdout (StdStream output
    /// only). Takes the buffer by value: the records are O(1) slices of
    /// it, no copy.
    pub fn stage_stdout(&self, stdout: Vec<u8>) -> Result<Option<Vec<Record>>> {
        match self {
            MountPoint::StdStream { sep } => {
                let text = SharedStr::from_shared(Shared::from_vec(stdout))
                    .map_err(|_| MareError::Container("streamed stdout is not UTF-8".into()))?;
                Ok(Some(Splitter::new(sep.as_str()).split(&text).into_iter().map(Record::Text).collect()))
            }
            _ => Ok(None),
        }
    }

    /// Materialize records into container input files (none for
    /// streams — see [`Self::stage_stdin`]). The returned buffers are
    /// [`Shared`]: a TextFile mount is ONE segment-written file, a
    /// BinaryFiles mount binds the record payloads themselves.
    pub fn stage_in(&self, records: &[Record]) -> Result<Vec<(String, Shared)>> {
        match self {
            MountPoint::StdStream { .. } => Ok(Vec::new()),
            MountPoint::TextFile { path, sep } => {
                let joined = join_text_records(records, sep, &format!("TextFile mount {path}"), "BinaryFiles")?;
                Ok(vec![(path.clone(), joined.finish())])
            }
            MountPoint::BinaryFiles { dir } => {
                let mut files = Vec::with_capacity(records.len());
                let mut seen = std::collections::HashSet::new();
                for (i, r) in records.iter().enumerate() {
                    let (name, bytes) = match r {
                        Record::Binary { name, bytes } => (basename(name), bytes.clone()),
                        Record::Text(t) => {
                            (format!("part-{i:05}.txt"), t.as_shared().clone())
                        }
                    };
                    // de-clash names merged from different partitions
                    let name = if seen.insert(name.clone()) {
                        name
                    } else {
                        format!("{i:05}-{name}")
                    };
                    files.push((format!("{dir}/{name}"), bytes));
                }
                Ok(files)
            }
        }
    }

    /// Read the tool's output back into records (streams are read from
    /// captured stdout instead — see [`Self::stage_stdout`]). Text
    /// records are zero-copy slices of the output file's buffer.
    pub fn stage_out(&self, fs: &mut Vfs) -> Result<Vec<Record>> {
        match self {
            MountPoint::StdStream { .. } => Ok(Vec::new()),
            MountPoint::TextFile { path, sep } => {
                if !fs.exists(path) {
                    return Ok(vec![]); // tool produced nothing
                }
                let text = SharedStr::from_shared(fs.read_shared(path)?)
                    .map_err(|_| MareError::Container(format!("{path}: not UTF-8")))?;
                Ok(Splitter::new(sep.as_str()).split(&text).into_iter().map(Record::Text).collect())
            }
            MountPoint::BinaryFiles { dir } => {
                let files = fs.take_dir(dir)?;
                Ok(files
                    .into_iter()
                    .map(|(path, bytes)| {
                        let name = path
                            .strip_prefix(&format!("{dir}/"))
                            .unwrap_or(&path)
                            .to_string();
                        Record::binary(name, bytes)
                    })
                    .collect())
            }
        }
    }
}

/// Join text records with `sep` (and a trailing `sep`, matching
/// [`crate::dataset::join_records`]) into one segment-written buffer.
/// A binary record is an error naming the offending mount kind.
fn join_text_records(
    records: &[Record],
    sep: &str,
    where_: &str,
    use_instead: &str,
) -> Result<SegmentWriter> {
    let mut payload = 0usize;
    for r in records {
        match r {
            Record::Text(t) => payload += t.len(),
            Record::Binary { name, .. } => {
                return Err(MareError::Container(format!(
                    "binary record `{name}` in {where_} (use {use_instead})"
                )))
            }
        }
    }
    if records.is_empty() {
        return Ok(SegmentWriter::with_capacity(0));
    }
    let mut w = SegmentWriter::with_capacity(payload + records.len() * sep.len());
    for r in records {
        if let Record::Text(t) = r {
            w.push(t.as_shared().as_slice());
            w.push(sep.as_bytes());
        }
    }
    Ok(w)
}

fn basename(p: &str) -> String {
    p.rsplit('/').next().unwrap_or(p).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::Vfs;

    #[test]
    fn textfile_roundtrip_with_custom_sep() {
        let mp = MountPoint::text_sep("/in.sdf", "\n$$$$\n");
        let records = vec![Record::text("molA"), Record::text("molB")];
        let files = mp.stage_in(&records).unwrap();
        assert_eq!(files.len(), 1);
        assert_eq!(files[0].0, "/in.sdf");
        let mut fs = Vfs::disk();
        fs.write("/in.sdf", files[0].1.clone()).unwrap();
        // pretend the tool copied input to output unchanged
        let out = MountPoint::text_sep("/in.sdf", "\n$$$$\n").stage_out(&mut fs).unwrap();
        assert_eq!(out, records);
    }

    #[test]
    fn textfile_materializes_exactly_like_join_records() {
        // the segmented writer must produce the same bytes as the old
        // owned join (trailing separator included)
        let records = vec![Record::text("a"), Record::text("bb"), Record::text("")];
        let texts: Vec<String> = vec!["a".into(), "bb".into(), "".into()];
        let mp = MountPoint::text_sep("/f", ";;");
        let files = mp.stage_in(&records).unwrap();
        assert_eq!(
            files[0].1.as_slice(),
            crate::dataset::join_records(&texts, ";;").as_bytes()
        );
    }

    #[test]
    fn textfile_missing_output_is_empty() {
        let mp = MountPoint::text("/nope");
        let mut fs = Vfs::disk();
        assert!(mp.stage_out(&mut fs).unwrap().is_empty());
    }

    #[test]
    fn textfile_rejects_binary_records() {
        let mp = MountPoint::text("/t");
        let err = mp.stage_in(&[Record::binary("x.gz", vec![1])]).err().unwrap();
        assert!(err.to_string().contains("BinaryFiles"), "{err}");
    }

    #[test]
    fn binaryfiles_roundtrip_and_declash() {
        let mp = MountPoint::binary("/in");
        let records = vec![
            Record::binary("a.vcf.gz", vec![1]),
            Record::binary("sub/a.vcf.gz", vec![2]), // same basename
            Record::text("loose text"),
        ];
        let files = mp.stage_in(&records).unwrap();
        assert_eq!(files.len(), 3);
        let mut fs = Vfs::disk();
        for (p, b) in &files {
            fs.write(p, b.clone()).unwrap();
        }
        let out = MountPoint::binary("/in").stage_out(&mut fs).unwrap();
        assert_eq!(out.len(), 3);
        // all names distinct
        let names: std::collections::HashSet<_> = out
            .iter()
            .map(|r| match r {
                Record::Binary { name, .. } => name.clone(),
                _ => panic!(),
            })
            .collect();
        assert_eq!(names.len(), 3);
        // mount dir is drained after stage_out
        assert!(fs.list_dir("/in").unwrap().is_empty());
    }

    #[test]
    fn binaryfiles_staging_shares_payloads() {
        let payload = Shared::from_vec(vec![3u8; 128]);
        let records = vec![Record::binary("x.bin", payload.clone())];
        let files = MountPoint::binary("/in").stage_in(&records).unwrap();
        // payload + record + staged file = 3 views of one allocation
        assert_eq!(payload.ref_count(), 3);
        assert_eq!(files[0].1, payload);
    }

    #[test]
    fn empty_partition_stages_empty_file() {
        let mp = MountPoint::text("/in");
        let files = mp.stage_in(&[]).unwrap();
        assert_eq!(files[0].1.len(), 0);
    }

    #[test]
    fn stream_mount_roundtrips_via_stdio() {
        let mp = MountPoint::stream_sep("\n$$$$\n");
        let records = vec![Record::text("molA"), Record::text("molB")];
        // no files materialized
        assert!(mp.stage_in(&records).unwrap().is_empty());
        let stdin = mp.stage_stdin(&records).unwrap().unwrap();
        // pretend the tool echoed its input
        let out = mp.stage_stdout(stdin).unwrap().unwrap();
        assert_eq!(out, records);
        assert!(mp.is_stream());
    }

    #[test]
    fn stream_mount_rejects_binary_records() {
        let mp = MountPoint::stream();
        assert!(mp.stage_stdin(&[Record::binary("x", vec![1])]).is_err());
    }

    #[test]
    fn non_stream_mounts_have_no_stdio() {
        let mp = MountPoint::text("/in");
        assert!(mp.stage_stdin(&[Record::text("x")]).unwrap().is_none());
        assert!(mp.stage_stdout(b"y".to_vec()).unwrap().is_none());
        assert!(!mp.is_stream());
    }
}
