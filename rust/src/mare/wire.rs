//! The v1 wire format: lossless `Pipeline` ⇄ JSON codec.
//!
//! A logical plan ([`Pipeline`]) is an engine-agnostic value, so it can
//! leave the driver that built it: `mare submit` ships encoded plans
//! into a job queue, `mare shell` persists them with `:save`/`:load`,
//! and any driver can [`decode`] and rebuild an identical job
//! ([`crate::submit`]). The normative spec — every node kind, field,
//! mount kind and error condition — is `docs/WIRE_FORMAT.md`; this
//! module is its reference implementation, and the golden-file tests in
//! `rust/tests/wire_golden.rs` pin the two together.
//!
//! Guarantees:
//!
//! * **Lossless**: `encode → decode → encode` is a fixed point for every
//!   serializable pipeline (property-tested).
//! * **Strict**: decoding never panics; unknown node kinds, unknown
//!   mount kinds, missing fields and malformed values are typed
//!   [`WireError`]s.
//! * **Forward-compatible**: unknown *envelope* keys and unknown *node
//!   fields* are ignored (a v1 reader accepts envelopes with additive
//!   extensions), while unknown node kinds, mount kinds and versions
//!   are rejected (a v1 reader never mis-executes a plan it does not
//!   fully understand).
//!
//! ```
//! use mare::mare::wire;
//!
//! let text = r#"{
//!   "version": 1,
//!   "ops": [
//!     {"op": "ingest", "label": "gen:gc:8", "partitions": 2},
//!     {"op": "map", "image": "ubuntu", "command": "wc -l /in > /out",
//!      "input": {"kind": "text", "path": "/in"},
//!      "output": {"kind": "text", "path": "/out"}},
//!     {"op": "collect"}
//!   ]
//! }"#;
//! let pipeline = wire::decode_str(text).unwrap();
//! assert_eq!(pipeline.num_maps(), 1);
//!
//! // encode -> decode -> encode is a fixed point
//! let encoded = wire::encode(&pipeline).unwrap();
//! assert_eq!(wire::encode(&wire::decode(&encoded).unwrap()).unwrap(), encoded);
//! ```

use std::fmt;

use crate::error::MareError;
use crate::storage::StorageUri;
use crate::util::json::Json;

use super::mount::MountPoint;
use super::pipeline::{KeySelector, MapStep, Pipeline, PipelineOp, ReduceStep};

/// The envelope version this build reads and writes.
pub const WIRE_VERSION: u64 = 1;

/// The envelope `"kind"` tag (optional on input, always written).
pub const WIRE_KIND: &str = "mare/pipeline";

/// Everything that can go wrong crossing the wire. Decoding is total:
/// every malformed input maps to one of these — never a panic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// The top-level value is not a JSON object.
    NotAnEnvelope(String),
    /// `"version"` is not a version this build speaks.
    UnsupportedVersion(u64),
    /// `"kind"` is present but is not [`WIRE_KIND`].
    WrongKind(String),
    /// A required field is absent.
    MissingField { at: String, field: &'static str },
    /// A field is present but malformed.
    BadField { at: String, field: &'static str, detail: String },
    /// `"op"` names a node kind unknown to this version.
    UnknownOp { at: String, op: String },
    /// A mount `"kind"` unknown to this version.
    UnknownMountKind { at: String, kind: String },
    /// `"key"` names an unregistered key function.
    UnknownKeyFn { at: String, name: String },
    /// Encoding hit a `repartitionBy` keyed by a driver-local closure.
    OpaqueKeyFn { at: String },
    /// Plan bracketing broken (must be `ingest … collect`).
    Structure(String),
    /// The input is not valid JSON at all.
    Syntax(String),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::NotAnEnvelope(d) => write!(f, "not a plan envelope: {d}"),
            WireError::UnsupportedVersion(v) => write!(
                f,
                "unsupported wire version {v} (this build speaks version {WIRE_VERSION})"
            ),
            WireError::WrongKind(k) => write!(f, "envelope kind `{k}` is not `{WIRE_KIND}`"),
            WireError::MissingField { at, field } => {
                write!(f, "{at}: missing field `{field}`")
            }
            WireError::BadField { at, field, detail } => {
                write!(f, "{at}: bad field `{field}`: {detail}")
            }
            WireError::UnknownOp { at, op } => write!(f, "{at}: unknown node kind `{op}`"),
            WireError::UnknownMountKind { at, kind } => {
                write!(f, "{at}: unknown mount kind `{kind}`")
            }
            WireError::UnknownKeyFn { at, name } => write!(
                f,
                "{at}: unknown key function `{name}` (registered: {})",
                KeySelector::known().join(", ")
            ),
            WireError::OpaqueKeyFn { at } => write!(
                f,
                "{at}: repartitionBy is keyed by a driver-local closure and cannot be \
                 serialized — use a registered key function (repartition_by_named)"
            ),
            WireError::Structure(d) => write!(f, "bad plan structure: {d}"),
            WireError::Syntax(d) => write!(f, "json syntax: {d}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<WireError> for MareError {
    fn from(e: WireError) -> Self {
        MareError::Wire(e)
    }
}

// ------------------------------------------------------------- encoding

/// Encode a complete (bracketed) pipeline into a v1 envelope.
pub fn encode(pipeline: &Pipeline) -> Result<Json, WireError> {
    check_structure(pipeline.ops())?;
    let mut ops = Vec::with_capacity(pipeline.ops().len());
    for (i, op) in pipeline.ops().iter().enumerate() {
        ops.push(encode_op(op, &format!("ops[{i}]"))?);
    }
    Ok(Json::obj(vec![
        ("version", Json::Num(WIRE_VERSION as f64)),
        ("kind", Json::str(WIRE_KIND)),
        ("ops", Json::Arr(ops)),
    ]))
}

/// [`encode`] rendered as pretty JSON — what `:save` and `mare plan
/// --json` emit, and what the golden files under `rust/tests/golden/`
/// hold.
pub fn encode_string(pipeline: &Pipeline) -> Result<String, WireError> {
    Ok(encode(pipeline)?.to_string_pretty())
}

// ---------------------------------------------------- envelope metadata

/// The tenant jobs land in when the envelope names none.
pub const DEFAULT_TENANT: &str = "default";

/// Optional scheduling metadata carried on the envelope itself:
/// `tenant` (admission/accounting bucket for the `mare serve`
/// fair-share scheduler) and `priority` (claim-order tie-break within
/// a tenant; higher first; may be negative). Both are envelope keys,
/// so every pre-serve decoder ignores them under the
/// unknown-envelope-key rule — old readers, new envelopes, same plan.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct EnvelopeMeta {
    pub tenant: Option<String>,
    pub priority: Option<i64>,
}

impl EnvelopeMeta {
    pub fn is_empty(&self) -> bool {
        self.tenant.is_none() && self.priority.is_none()
    }

    pub fn tenant_or_default(&self) -> &str {
        self.tenant.as_deref().unwrap_or(DEFAULT_TENANT)
    }

    pub fn priority_or_default(&self) -> i64 {
        self.priority.unwrap_or(0)
    }
}

/// Extract the optional scheduling metadata from a v1 envelope. Absent
/// keys mean "no metadata"; present keys are validated strictly, so a
/// mistyped tenant fails admission instead of silently landing in the
/// default bucket.
pub fn decode_meta(envelope: &Json) -> Result<EnvelopeMeta, WireError> {
    if !matches!(envelope, Json::Obj(_)) {
        return Err(WireError::NotAnEnvelope(format!(
            "expected a JSON object, got {envelope}"
        )));
    }
    let mut meta = EnvelopeMeta::default();
    if let Some(t) = envelope.get("tenant") {
        let t = t.as_str().map_err(|e| WireError::BadField {
            at: "envelope".into(),
            field: "tenant",
            detail: e.to_string(),
        })?;
        if t.is_empty() {
            return Err(WireError::BadField {
                at: "envelope".into(),
                field: "tenant",
                detail: "must be a non-empty string".into(),
            });
        }
        meta.tenant = Some(t.to_string());
    }
    if let Some(p) = envelope.get("priority") {
        let p = p.as_i64().map_err(|e| WireError::BadField {
            at: "envelope".into(),
            field: "priority",
            detail: e.to_string(),
        })?;
        meta.priority = Some(p);
    }
    Ok(meta)
}

/// [`encode`] plus the optional scheduling metadata. With empty
/// metadata this IS [`encode`] — the canonical envelope never grows
/// keys it doesn't need, so plans without metadata re-encode
/// byte-identically to every prior release.
pub fn encode_with_meta(pipeline: &Pipeline, meta: &EnvelopeMeta) -> Result<Json, WireError> {
    let encoded = encode(pipeline)?;
    if meta.is_empty() {
        return Ok(encoded);
    }
    let mut fields = match encoded {
        Json::Obj(fields) => fields,
        _ => unreachable!("encode always returns an envelope object"),
    };
    // canonical key order: version, kind, tenant?, priority?, ops
    let ops = fields.pop().expect("ops is the last envelope key");
    if let Some(t) = &meta.tenant {
        fields.push(("tenant".to_string(), Json::str(t.as_str())));
    }
    if let Some(p) = meta.priority {
        fields.push(("priority".to_string(), Json::Num(p as f64)));
    }
    fields.push(ops);
    Ok(Json::Obj(fields))
}

/// Encode-side twin of the decoder's `req_count`: a plan that encodes
/// must decode, so zero counts are rejected symmetrically and the
/// fixed-point guarantee holds for every envelope we ever emit.
fn check_count(at: &str, field: &'static str, n: usize) -> Result<(), WireError> {
    if n == 0 {
        return Err(WireError::BadField { at: at.into(), field, detail: "must be >= 1".into() });
    }
    Ok(())
}

fn encode_op(op: &PipelineOp, at: &str) -> Result<Json, WireError> {
    Ok(match op {
        PipelineOp::Ingest { label, partitions } => {
            check_count(at, "partitions", *partitions)?;
            let mut fields = vec![
                ("op", Json::str("ingest")),
                ("label", Json::str(label.as_str())),
                ("partitions", Json::Num(*partitions as f64)),
            ];
            // storage-backed labels carry an explicit storage envelope
            // (backend scheme, object key, partitioning) so readers
            // need not re-derive the URI grammar; derived from the
            // label, so the fixed-point property holds
            if let Some(uri) = StorageUri::parse(label) {
                fields.push(("storage", storage_json(&uri)));
            }
            Json::obj(fields)
        }
        PipelineOp::Map(m) => Json::obj(vec![
            ("op", Json::str("map")),
            ("image", Json::str(m.image.as_str())),
            ("command", Json::str(m.command.as_str())),
            ("input", encode_mount(&m.input_mount)),
            ("output", encode_mount(&m.output_mount)),
            ("disk_mounts", Json::Bool(m.disk_mounts)),
        ]),
        PipelineOp::Reduce(r) => {
            if r.fused.is_some() {
                // an optimizer-folded map has no wire representation;
                // silently dropping it would ship a reduce-only plan
                // that computes the wrong thing — encode the LOGICAL
                // plan (Job::logical()), not the optimized one
                return Err(WireError::Structure(format!(
                    "{at}: reduce carries an optimizer-fused map; \
                     only logical plans are serializable"
                )));
            }
            if let Some(k) = r.depth {
                check_count(at, "depth", k)?;
            }
            let mut fields = vec![
                ("op", Json::str("reduce")),
                ("image", Json::str(r.image.as_str())),
                ("command", Json::str(r.command.as_str())),
                ("input", encode_mount(&r.input_mount)),
                ("output", encode_mount(&r.output_mount)),
                (
                    "depth",
                    match r.depth {
                        Some(k) => Json::Num(k as f64),
                        None => Json::str("auto"),
                    },
                ),
                ("disk_mounts", Json::Bool(r.disk_mounts)),
            ];
            // absent-means-false: plans without the declaration encode
            // byte-identically to every pre-combine release, and old
            // decoders read new plans via the unknown-node-field rule
            // (they lose only the optimization, never correctness —
            // the combiner is a clone of this very reduce)
            if r.combine {
                fields.push(("combine", Json::Bool(true)));
            }
            Json::obj(fields)
        }
        PipelineOp::RepartitionBy { key, partitions, combine } => {
            if combine.is_some() {
                // the pushed combiner is derived optimizer metadata
                // (a clone of the downstream reduce); shipping it would
                // double-encode the step — encode the LOGICAL plan
                // (Job::logical()), not the optimized one
                return Err(WireError::Structure(format!(
                    "{at}: repartitionBy carries an optimizer-pushed combiner; \
                     only logical plans are serializable"
                )));
            }
            let name = key.name().ok_or_else(|| WireError::OpaqueKeyFn { at: at.into() })?;
            check_count(at, "partitions", *partitions)?;
            Json::obj(vec![
                ("op", Json::str("repartition_by")),
                ("key", Json::str(name)),
                ("partitions", Json::Num(*partitions as f64)),
            ])
        }
        PipelineOp::Repartition { partitions } => {
            check_count(at, "partitions", *partitions)?;
            Json::obj(vec![
                ("op", Json::str("repartition")),
                ("partitions", Json::Num(*partitions as f64)),
            ])
        }
        PipelineOp::Collect => Json::obj(vec![("op", Json::str("collect"))]),
    })
}

/// The `"storage"` envelope of a storage-backed ingest node
/// (docs/WIRE_FORMAT.md §2.1): backend scheme + object key + how the
/// object partitions into records (`sep` for text objects, `glob` for
/// `BinaryFiles`-style object sets).
fn storage_json(uri: &StorageUri) -> Json {
    Json::obj(vec![
        ("scheme", Json::str(uri.kind.name())),
        ("key", Json::str(uri.key.as_str())),
        ("sep", Json::str(uri.sep())),
        ("glob", Json::Bool(uri.is_glob())),
    ])
}

fn encode_mount(m: &MountPoint) -> Json {
    match m {
        MountPoint::TextFile { path, sep } => Json::obj(vec![
            ("kind", Json::str("text")),
            ("path", Json::str(path.as_str())),
            ("sep", Json::str(sep.as_str())),
        ]),
        MountPoint::BinaryFiles { dir } => Json::obj(vec![
            ("kind", Json::str("binary")),
            ("dir", Json::str(dir.as_str())),
        ]),
        MountPoint::StdStream { sep } => Json::obj(vec![
            ("kind", Json::str("stream")),
            ("sep", Json::str(sep.as_str())),
        ]),
    }
}

// ------------------------------------------------------------- decoding

/// Decode a v1 envelope into a [`Pipeline`]. Strict: see [`WireError`].
pub fn decode(envelope: &Json) -> Result<Pipeline, WireError> {
    if !matches!(envelope, Json::Obj(_)) {
        return Err(WireError::NotAnEnvelope(format!(
            "expected a JSON object, got {envelope}"
        )));
    }
    let version = req(envelope, "envelope", "version")?;
    let version = version.as_u64().map_err(|e| WireError::BadField {
        at: "envelope".into(),
        field: "version",
        detail: e.to_string(),
    })?;
    if version != WIRE_VERSION {
        return Err(WireError::UnsupportedVersion(version));
    }
    if let Some(kind) = envelope.get("kind") {
        let kind = kind.as_str().map_err(|e| WireError::BadField {
            at: "envelope".into(),
            field: "kind",
            detail: e.to_string(),
        })?;
        if kind != WIRE_KIND {
            return Err(WireError::WrongKind(kind.to_string()));
        }
    }
    // any other envelope key is ignored (forward compatibility)
    let ops_json = req(envelope, "envelope", "ops")?;
    let ops_json = ops_json.as_arr().map_err(|e| WireError::BadField {
        at: "envelope".into(),
        field: "ops",
        detail: e.to_string(),
    })?;

    let mut ops = Vec::with_capacity(ops_json.len());
    for (i, node) in ops_json.iter().enumerate() {
        ops.push(decode_op(node, &format!("ops[{i}]"))?);
    }
    check_structure(&ops)?;
    Ok(Pipeline::new(ops))
}

/// Parse JSON text and [`decode`] it.
pub fn decode_str(text: &str) -> Result<Pipeline, WireError> {
    let json = Json::parse(text).map_err(|e| WireError::Syntax(e.to_string()))?;
    decode(&json)
}

fn decode_op(node: &Json, at: &str) -> Result<PipelineOp, WireError> {
    if !matches!(node, Json::Obj(_)) {
        return Err(WireError::Structure(format!("{at}: node must be a JSON object")));
    }
    let op = req_str(node, at, "op")?;
    match op.as_str() {
        "ingest" => {
            let label = req_str(node, at, "label")?;
            let partitions = req_count(node, at, "partitions")?;
            // the storage envelope is derived metadata: when present it
            // must agree with the label, or the plan is rejected rather
            // than mis-executed against the wrong backend/object
            if let Some(storage) = node.get("storage") {
                check_storage(storage, &label, at)?;
            }
            Ok(PipelineOp::Ingest { label, partitions })
        }
        "map" => Ok(PipelineOp::Map(MapStep {
            image: req_str(node, at, "image")?,
            command: req_str(node, at, "command")?,
            input_mount: decode_mount(req(node, at, "input")?, &format!("{at}.input"))?,
            output_mount: decode_mount(req(node, at, "output")?, &format!("{at}.output"))?,
            disk_mounts: opt_bool(node, at, "disk_mounts", false)?,
        })),
        "reduce" => Ok(PipelineOp::Reduce(ReduceStep {
            image: req_str(node, at, "image")?,
            command: req_str(node, at, "command")?,
            input_mount: decode_mount(req(node, at, "input")?, &format!("{at}.input"))?,
            output_mount: decode_mount(req(node, at, "output")?, &format!("{at}.output"))?,
            depth: decode_depth(req(node, at, "depth")?, at)?,
            disk_mounts: opt_bool(node, at, "disk_mounts", false)?,
            // derived optimizer metadata: never on the wire
            fused: None,
            combine: opt_bool(node, at, "combine", false)?,
        })),
        "repartition_by" => {
            let name = req_str(node, at, "key")?;
            let key = KeySelector::named(&name)
                .ok_or_else(|| WireError::UnknownKeyFn { at: at.into(), name })?;
            Ok(PipelineOp::RepartitionBy {
                key,
                partitions: req_count(node, at, "partitions")?,
                // derived optimizer metadata: never on the wire
                combine: None,
            })
        }
        "repartition" => Ok(PipelineOp::Repartition {
            partitions: req_count(node, at, "partitions")?,
        }),
        "collect" => Ok(PipelineOp::Collect),
        other => Err(WireError::UnknownOp { at: at.into(), op: other.to_string() }),
    }
}

fn decode_mount(mount: &Json, at: &str) -> Result<MountPoint, WireError> {
    if !matches!(mount, Json::Obj(_)) {
        return Err(WireError::Structure(format!("{at}: mount must be a JSON object")));
    }
    let kind = req_str(mount, at, "kind")?;
    match kind.as_str() {
        "text" => Ok(MountPoint::TextFile {
            path: req_str(mount, at, "path")?,
            sep: opt_str(mount, at, "sep", "\n")?,
        }),
        "binary" => Ok(MountPoint::BinaryFiles { dir: req_str(mount, at, "dir")? }),
        "stream" => Ok(MountPoint::StdStream { sep: opt_str(mount, at, "sep", "\n")? }),
        other => Err(WireError::UnknownMountKind { at: at.into(), kind: other.to_string() }),
    }
}

/// Validate an ingest node's `"storage"` envelope against its label
/// (the label is authoritative; the envelope is derived, §2.1).
///
/// An envelope on a label THIS reader cannot parse as a storage URI
/// (a scheme outside its registry — e.g. written by an implementation
/// with more backends) is ignored like any unknown node field: this
/// reader resolves sources from the label alone, so the label decodes
/// as opaque and the plan still validates and enqueues for capable
/// drivers. Only when the reader WILL resolve the label does a
/// disagreeing envelope reject — it must never ingest from a
/// different backend/object than the label names.
fn check_storage(storage: &Json, label: &str, at: &str) -> Result<(), WireError> {
    let bad = |detail: String| WireError::BadField {
        at: at.into(),
        field: "storage",
        detail,
    };
    // order matters: an unparseable label means the envelope is a
    // foreign writer's field and is ignored WHATEVER its shape, per
    // the unknown-node-field rule — only then is the shape enforced
    let Some(uri) = StorageUri::parse(label) else {
        return Ok(());
    };
    if !matches!(storage, Json::Obj(_)) {
        return Err(bad("must be a JSON object".into()));
    }
    for (field, want) in [
        ("scheme", uri.kind.name().to_string()),
        ("key", uri.key.clone()),
        ("sep", uri.sep().to_string()),
    ] {
        if let Some(v) = storage.get(field) {
            let got = v.as_str().map_err(|e| bad(format!("{field}: {e}")))?;
            if got != want {
                return Err(bad(format!(
                    "{field} `{got}` does not match the label's `{want}`"
                )));
            }
        }
    }
    if let Some(v) = storage.get("glob") {
        let got = v.as_bool().map_err(|e| bad(format!("glob: {e}")))?;
        if got != uri.is_glob() {
            return Err(bad(format!(
                "glob `{got}` does not match the label's `{}`",
                uri.is_glob()
            )));
        }
    }
    Ok(())
}

/// `"depth"`: a positive integer, or the string `"auto"` for
/// optimizer-planned depth.
fn decode_depth(depth: &Json, at: &str) -> Result<Option<usize>, WireError> {
    match depth {
        Json::Str(s) if s == "auto" => Ok(None),
        Json::Num(_) => {
            let k = depth.as_u64().map_err(|e| WireError::BadField {
                at: at.into(),
                field: "depth",
                detail: e.to_string(),
            })?;
            if k == 0 {
                return Err(WireError::BadField {
                    at: at.into(),
                    field: "depth",
                    detail: "must be >= 1 (or the string \"auto\")".into(),
                });
            }
            Ok(Some(k as usize))
        }
        other => Err(WireError::BadField {
            at: at.into(),
            field: "depth",
            detail: format!("expected a positive integer or \"auto\", got {other}"),
        }),
    }
}

// ------------------------------------------------------------- helpers

fn req<'a>(obj: &'a Json, at: &str, field: &'static str) -> Result<&'a Json, WireError> {
    obj.get(field).ok_or_else(|| WireError::MissingField { at: at.into(), field })
}

fn req_str(obj: &Json, at: &str, field: &'static str) -> Result<String, WireError> {
    req(obj, at, field)?
        .as_str()
        .map(str::to_string)
        .map_err(|e| WireError::BadField { at: at.into(), field, detail: e.to_string() })
}

/// A required partition count: an integer >= 1.
fn req_count(obj: &Json, at: &str, field: &'static str) -> Result<usize, WireError> {
    let n = req(obj, at, field)?
        .as_u64()
        .map_err(|e| WireError::BadField { at: at.into(), field, detail: e.to_string() })?;
    if n == 0 {
        return Err(WireError::BadField {
            at: at.into(),
            field,
            detail: "must be >= 1".into(),
        });
    }
    Ok(n as usize)
}

fn opt_bool(obj: &Json, at: &str, field: &'static str, default: bool) -> Result<bool, WireError> {
    match obj.get(field) {
        None => Ok(default),
        Some(v) => v
            .as_bool()
            .map_err(|e| WireError::BadField { at: at.into(), field, detail: e.to_string() }),
    }
}

fn opt_str(
    obj: &Json,
    at: &str,
    field: &'static str,
    default: &str,
) -> Result<String, WireError> {
    match obj.get(field) {
        None => Ok(default.to_string()),
        Some(v) => v
            .as_str()
            .map(str::to_string)
            .map_err(|e| WireError::BadField { at: at.into(), field, detail: e.to_string() }),
    }
}

/// A complete plan is bracketed: exactly one `ingest` (first), exactly
/// one `collect` (last), computational nodes in between.
fn check_structure(ops: &[PipelineOp]) -> Result<(), WireError> {
    if ops.len() < 2 {
        return Err(WireError::Structure(format!(
            "a plan needs at least `ingest` and `collect`, got {} node(s)",
            ops.len()
        )));
    }
    if !matches!(ops.first(), Some(PipelineOp::Ingest { .. })) {
        return Err(WireError::Structure("the first node must be `ingest`".into()));
    }
    if !matches!(ops.last(), Some(PipelineOp::Collect)) {
        return Err(WireError::Structure("the last node must be `collect`".into()));
    }
    for (i, op) in ops.iter().enumerate().take(ops.len() - 1).skip(1) {
        match op {
            PipelineOp::Ingest { .. } => {
                return Err(WireError::Structure(format!(
                    "ops[{i}]: `ingest` is only allowed as the first node"
                )));
            }
            PipelineOp::Collect => {
                return Err(WireError::Structure(format!(
                    "ops[{i}]: `collect` is only allowed as the last node"
                )));
            }
            _ => {}
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::dataset::Record;

    fn text_mount(path: &str) -> MountPoint {
        MountPoint::text(path)
    }

    /// The decode error of `text` (panics if decoding succeeds).
    fn err_of(text: &str) -> WireError {
        match decode_str(text) {
            Ok(p) => panic!("expected a decode error, got plan:\n{}", p.describe()),
            Err(e) => e,
        }
    }

    /// One pipeline exercising every node kind and every mount kind.
    fn kitchen_sink() -> Pipeline {
        Pipeline::new(vec![
            PipelineOp::Ingest { label: "gen:gc:64".into(), partitions: 8 },
            PipelineOp::Map(MapStep {
                input_mount: MountPoint::text_sep("/in.sdf", "\n$$$$\n"),
                output_mount: MountPoint::text_sep("/out.sdf", "\n$$$$\n"),
                image: "mcapuccini/oe:latest".into(),
                command: "fred -dbase /in.sdf".into(),
                disk_mounts: true,
            }),
            PipelineOp::RepartitionBy {
                key: KeySelector::named("chromosome").unwrap(),
                partitions: 3,
                combine: None,
            },
            PipelineOp::Map(MapStep {
                input_mount: MountPoint::stream(),
                output_mount: MountPoint::stream_sep("\t"),
                image: "ubuntu".into(),
                command: "grep -o '[GC]' | wc -l".into(),
                disk_mounts: false,
            }),
            PipelineOp::Repartition { partitions: 2 },
            PipelineOp::Reduce(ReduceStep {
                input_mount: MountPoint::binary("/in"),
                output_mount: MountPoint::binary("/out"),
                image: "opengenomics/vcftools-tools:latest".into(),
                command: "vcf-concat /in/*.vcf.gz | gzip -c > /out/m.vcf.gz".into(),
                depth: Some(3),
                disk_mounts: false,
                fused: None,
                combine: false,
            }),
            PipelineOp::Reduce(ReduceStep {
                input_mount: text_mount("/counts"),
                output_mount: text_mount("/sum"),
                image: "ubuntu".into(),
                command: "awk '{s+=$1} END {print s}' /counts > /sum".into(),
                depth: None,
                disk_mounts: false,
                fused: None,
                combine: true,
            }),
            PipelineOp::Collect,
        ])
    }

    #[test]
    fn kitchen_sink_roundtrips_losslessly() {
        let p = kitchen_sink();
        let encoded = encode(&p).unwrap();
        let decoded = decode(&encoded).unwrap();
        // same rendering, same re-encoding: nothing was lost
        assert_eq!(decoded.describe(), p.describe());
        assert_eq!(encode(&decoded).unwrap(), encoded);
        // and through text too
        let text = encode_string(&p).unwrap();
        let from_text = decode_str(&text).unwrap();
        assert_eq!(encode(&from_text).unwrap(), encoded);
    }

    #[test]
    fn envelope_meta_roundtrips_and_decode_ignores_it() {
        let p = kitchen_sink();
        let plain = encode(&p).unwrap();
        let meta = EnvelopeMeta { tenant: Some("alpha".into()), priority: Some(-2) };
        let tagged = encode_with_meta(&p, &meta).unwrap();

        // the metadata survives its own decode path
        assert_eq!(decode_meta(&tagged).unwrap(), meta);
        // ...while the plan decode path ignores it entirely (the
        // unknown-envelope-key rule): same plan as the untagged form
        let via_tagged = decode(&tagged).unwrap();
        assert_eq!(encode(&via_tagged).unwrap(), plain);
        assert_eq!(via_tagged.describe(), p.describe());
        // untagged envelopes carry no metadata...
        assert_eq!(decode_meta(&plain).unwrap(), EnvelopeMeta::default());
        // ...and empty metadata encodes to exactly the plain envelope
        assert_eq!(encode_with_meta(&p, &EnvelopeMeta::default()).unwrap(), plain);

        // canonical key order: version, kind, tenant, priority, ops
        let keys: Vec<&str> = match &tagged {
            Json::Obj(fields) => fields.iter().map(|(k, _)| k.as_str()).collect(),
            _ => panic!("envelope must be an object"),
        };
        assert_eq!(keys, vec!["version", "kind", "tenant", "priority", "ops"]);
    }

    #[test]
    fn envelope_meta_is_validated_strictly_when_present() {
        let bad_tenant = Json::parse(
            r#"{"version": 1, "tenant": 7,
                "ops": [{"op": "ingest", "label": "x", "partitions": 1}, {"op": "collect"}]}"#,
        )
        .unwrap();
        assert!(matches!(
            decode_meta(&bad_tenant),
            Err(WireError::BadField { field: "tenant", .. })
        ));

        let empty_tenant = Json::parse(r#"{"version": 1, "tenant": "", "ops": []}"#).unwrap();
        assert!(matches!(
            decode_meta(&empty_tenant),
            Err(WireError::BadField { field: "tenant", .. })
        ));

        let frac_priority =
            Json::parse(r#"{"version": 1, "priority": 1.5, "ops": []}"#).unwrap();
        assert!(matches!(
            decode_meta(&frac_priority),
            Err(WireError::BadField { field: "priority", .. })
        ));

        // negative priorities are legal (lower-than-default urgency)
        let neg = Json::parse(r#"{"version": 1, "priority": -3, "ops": []}"#).unwrap();
        assert_eq!(decode_meta(&neg).unwrap().priority, Some(-3));
        assert_eq!(decode_meta(&neg).unwrap().tenant_or_default(), DEFAULT_TENANT);
    }

    #[test]
    fn defaults_are_applied_and_canonicalized() {
        // sep and disk_mounts omitted -> "\n" and false
        let text = r#"{
          "version": 1,
          "ops": [
            {"op": "ingest", "label": "x", "partitions": 1},
            {"op": "map", "image": "ubuntu", "command": "cat /a > /b",
             "input": {"kind": "text", "path": "/a"},
             "output": {"kind": "text", "path": "/b"}},
            {"op": "collect"}
          ]
        }"#;
        let p = decode_str(text).unwrap();
        let PipelineOp::Map(m) = &p.ops()[1] else { panic!("expected map") };
        assert_eq!(m.input_mount, MountPoint::text("/a"));
        assert!(!m.disk_mounts);
        // canonical re-encoding carries the defaults explicitly
        let encoded = encode(&p).unwrap();
        let node = &encoded.get("ops").unwrap().as_arr().unwrap()[1];
        assert_eq!(node.get("disk_mounts").unwrap(), &Json::Bool(false));
        assert_eq!(
            node.get("input").unwrap().get("sep").unwrap(),
            &Json::Str("\n".into())
        );
    }

    #[test]
    fn unknown_envelope_keys_are_ignored_unknown_ops_rejected() {
        let ok = r#"{
          "version": 1,
          "kind": "mare/pipeline",
          "submitted_by": "driver-7",
          "future_extension": {"x": 1},
          "ops": [
            {"op": "ingest", "label": "x", "partitions": 1},
            {"op": "collect"}
          ]
        }"#;
        assert!(decode_str(ok).is_ok());

        let bad = r#"{
          "version": 1,
          "ops": [
            {"op": "ingest", "label": "x", "partitions": 1},
            {"op": "teleport", "where": "/moon"},
            {"op": "collect"}
          ]
        }"#;
        assert_eq!(
            err_of(bad),
            WireError::UnknownOp { at: "ops[1]".into(), op: "teleport".into() }
        );
    }

    #[test]
    fn unknown_node_fields_are_ignored() {
        let text = r#"{
          "version": 1,
          "ops": [
            {"op": "ingest", "label": "x", "partitions": 2, "hint": "future"},
            {"op": "repartition", "partitions": 4, "shuffle_codec": "zstd"},
            {"op": "collect"}
          ]
        }"#;
        let p = decode_str(text).unwrap();
        assert!(matches!(p.ops()[1], PipelineOp::Repartition { partitions: 4 }));
    }

    #[test]
    fn version_and_kind_are_checked() {
        let v2 = r#"{"version": 2, "ops": []}"#;
        assert_eq!(err_of(v2), WireError::UnsupportedVersion(2));

        let missing = r#"{"ops": []}"#;
        assert_eq!(
            err_of(missing),
            WireError::MissingField { at: "envelope".into(), field: "version" }
        );

        let wrong_kind = r#"{"version": 1, "kind": "mare/cluster", "ops": []}"#;
        assert_eq!(err_of(wrong_kind), WireError::WrongKind("mare/cluster".into()));

        assert!(matches!(err_of("[1, 2]"), WireError::NotAnEnvelope(_)));
        assert!(matches!(err_of("{nope"), WireError::Syntax(_)));
    }

    #[test]
    fn missing_and_malformed_fields_are_typed_errors() {
        let missing_cmd = r#"{
          "version": 1,
          "ops": [
            {"op": "ingest", "label": "x", "partitions": 1},
            {"op": "map", "image": "ubuntu",
             "input": {"kind": "text", "path": "/a"},
             "output": {"kind": "text", "path": "/b"}},
            {"op": "collect"}
          ]
        }"#;
        assert_eq!(
            err_of(missing_cmd),
            WireError::MissingField { at: "ops[1]".into(), field: "command" }
        );

        let bad_mount = r#"{
          "version": 1,
          "ops": [
            {"op": "ingest", "label": "x", "partitions": 1},
            {"op": "map", "image": "ubuntu", "command": "c",
             "input": {"kind": "quantum", "path": "/a"},
             "output": {"kind": "text", "path": "/b"}},
            {"op": "collect"}
          ]
        }"#;
        assert_eq!(
            err_of(bad_mount),
            WireError::UnknownMountKind { at: "ops[1].input".into(), kind: "quantum".into() }
        );

        let zero_parts = r#"{
          "version": 1,
          "ops": [
            {"op": "ingest", "label": "x", "partitions": 0},
            {"op": "collect"}
          ]
        }"#;
        assert!(matches!(
            err_of(zero_parts),
            WireError::BadField { field: "partitions", .. }
        ));
    }

    #[test]
    fn depth_accepts_auto_and_positive_integers_only() {
        let plan = |depth: &str| {
            format!(
                r#"{{
                  "version": 1,
                  "ops": [
                    {{"op": "ingest", "label": "x", "partitions": 4}},
                    {{"op": "reduce", "image": "ubuntu", "command": "c",
                      "input": {{"kind": "text", "path": "/a"}},
                      "output": {{"kind": "text", "path": "/a"}},
                      "depth": {depth}}},
                    {{"op": "collect"}}
                  ]
                }}"#
            )
        };
        let auto = decode_str(&plan("\"auto\"")).unwrap();
        let PipelineOp::Reduce(r) = &auto.ops()[1] else { panic!("expected reduce") };
        assert_eq!(r.depth, None);

        let pinned = decode_str(&plan("3")).unwrap();
        let PipelineOp::Reduce(r) = &pinned.ops()[1] else { panic!("expected reduce") };
        assert_eq!(r.depth, Some(3));

        assert!(matches!(err_of(&plan("0")), WireError::BadField { field: "depth", .. }));
        assert!(matches!(err_of(&plan("1.5")), WireError::BadField { field: "depth", .. }));
        assert!(matches!(
            err_of(&plan("\"deep\"")),
            WireError::BadField { field: "depth", .. }
        ));
    }

    #[test]
    fn storage_labels_carry_a_consistent_storage_envelope() {
        let p = Pipeline::new(vec![
            PipelineOp::Ingest { label: "hdfs://genome.txt?lines=64".into(), partitions: 4 },
            PipelineOp::Collect,
        ]);
        let encoded = encode(&p).unwrap();
        let node = &encoded.get("ops").unwrap().as_arr().unwrap()[0];
        let storage = node.get("storage").expect("storage envelope on a storage label");
        assert_eq!(storage.get("scheme").unwrap(), &Json::Str("hdfs".into()));
        assert_eq!(storage.get("key").unwrap(), &Json::Str("genome.txt".into()));
        assert_eq!(storage.get("sep").unwrap(), &Json::Str("\n".into()));
        assert_eq!(storage.get("glob").unwrap(), &Json::Bool(false));
        // the envelope is derived from the label: fixed point holds
        assert_eq!(encode(&decode(&encoded).unwrap()).unwrap(), encoded);

        // non-storage labels carry no envelope
        let gen = Pipeline::new(vec![
            PipelineOp::Ingest { label: "gen:gc:8".into(), partitions: 2 },
            PipelineOp::Collect,
        ]);
        let gen_node = encode(&gen).unwrap();
        assert!(gen_node.get("ops").unwrap().as_arr().unwrap()[0].get("storage").is_none());

        // a mismatched envelope is rejected, not mis-executed
        let lying = r#"{
          "version": 1,
          "ops": [
            {"op": "ingest", "label": "hdfs://genome.txt", "partitions": 2,
             "storage": {"scheme": "s3", "key": "genome.txt"}},
            {"op": "collect"}
          ]
        }"#;
        assert!(matches!(
            err_of(lying),
            WireError::BadField { field: "storage", .. }
        ));

        // an envelope on a label this reader cannot parse as a URI is
        // ignored like an unknown node field (the label alone decides
        // resolution, so a foreign-scheme plan still enqueues as
        // opaque for drivers that do register the scheme)
        let foreign = lying.replace("hdfs://genome.txt", "gcs://genome.txt");
        assert!(decode_str(&foreign).is_ok());
        let on_gen = lying.replace("hdfs://genome.txt", "gen:gc:8");
        assert!(decode_str(&on_gen).is_ok());
        // ...whatever its shape — a foreign envelope need not even be
        // an object (but a malformed one on a label WE resolve is bad)
        let foreign_str = foreign
            .replace("{\"scheme\": \"s3\", \"key\": \"genome.txt\"}", "\"gcs\"");
        assert!(decode_str(&foreign_str).is_ok());
        let local_str = lying
            .replace("{\"scheme\": \"s3\", \"key\": \"genome.txt\"}", "\"hdfs\"");
        assert!(matches!(
            err_of(&local_str),
            WireError::BadField { field: "storage", .. }
        ));

        // an agreeing envelope (even a partial one) decodes fine
        let truthful = r#"{
          "version": 1,
          "ops": [
            {"op": "ingest", "label": "swift://library.sdf", "partitions": 2,
             "storage": {"scheme": "swift", "key": "library.sdf"}},
            {"op": "collect"}
          ]
        }"#;
        assert!(decode_str(truthful).is_ok());
    }

    #[test]
    fn unknown_key_fn_is_rejected_opaque_cannot_encode() {
        let unknown = r#"{
          "version": 1,
          "ops": [
            {"op": "ingest", "label": "x", "partitions": 4},
            {"op": "repartition_by", "key": "by-zodiac-sign", "partitions": 12},
            {"op": "collect"}
          ]
        }"#;
        assert_eq!(
            err_of(unknown),
            WireError::UnknownKeyFn { at: "ops[1]".into(), name: "by-zodiac-sign".into() }
        );

        let opaque = Pipeline::new(vec![
            PipelineOp::Ingest { label: "x".into(), partitions: 2 },
            PipelineOp::RepartitionBy {
                key: KeySelector::opaque(Arc::new(|_: &Record| "k".into())),
                partitions: 2,
                combine: None,
            },
            PipelineOp::Collect,
        ]);
        assert_eq!(encode(&opaque), Err(WireError::OpaqueKeyFn { at: "ops[1]".into() }));
    }

    #[test]
    fn encode_rejects_what_decode_would_reject() {
        // a directly built IR with zero counts must fail at encode with
        // the same typed error decode gives — every emitted envelope
        // is guaranteed decodable
        let zero_ingest = Pipeline::new(vec![
            PipelineOp::Ingest { label: "x".into(), partitions: 0 },
            PipelineOp::Collect,
        ]);
        assert!(matches!(
            encode(&zero_ingest),
            Err(WireError::BadField { field: "partitions", .. })
        ));

        let zero_depth = Pipeline::new(vec![
            PipelineOp::Ingest { label: "x".into(), partitions: 2 },
            PipelineOp::Reduce(ReduceStep {
                input_mount: MountPoint::text("/a"),
                output_mount: MountPoint::text("/a"),
                image: "ubuntu".into(),
                command: "c".into(),
                depth: Some(0),
                disk_mounts: false,
                fused: None,
                combine: false,
            }),
            PipelineOp::Collect,
        ]);
        assert!(matches!(
            encode(&zero_depth),
            Err(WireError::BadField { field: "depth", .. })
        ));
    }

    #[test]
    fn encode_rejects_optimizer_fused_reduce() {
        // a reduce carrying an optimizer-folded map has no wire
        // representation; dropping the map silently would ship a plan
        // that computes something else — typed error instead
        let fused = Pipeline::new(vec![
            PipelineOp::Ingest { label: "x".into(), partitions: 2 },
            PipelineOp::Reduce(ReduceStep {
                input_mount: MountPoint::text("/gc"),
                output_mount: MountPoint::text("/sum"),
                image: "ubuntu".into(),
                command: "awk '{s+=$1} END {print s}' /gc > /sum".into(),
                depth: Some(1),
                disk_mounts: false,
                fused: Some(MapStep {
                    input_mount: MountPoint::text("/dna"),
                    output_mount: MountPoint::text("/gc"),
                    image: "ubuntu".into(),
                    command: "grep -c G /dna > /gc".into(),
                    disk_mounts: false,
                }),
                combine: false,
            }),
            PipelineOp::Collect,
        ]);
        match encode(&fused) {
            Err(WireError::Structure(msg)) => {
                assert!(msg.contains("fused"), "{msg}")
            }
            other => panic!("expected a Structure error, got {other:?}"),
        }
    }

    #[test]
    fn combine_is_absent_unless_declared_and_roundtrips() {
        let reduce = |combine: bool| {
            Pipeline::new(vec![
                PipelineOp::Ingest { label: "x".into(), partitions: 4 },
                PipelineOp::Reduce(ReduceStep {
                    input_mount: text_mount("/counts"),
                    output_mount: text_mount("/sum"),
                    image: "ubuntu".into(),
                    command: "awk '{s+=$1} END {print s}' /counts > /sum".into(),
                    depth: None,
                    disk_mounts: false,
                    fused: None,
                    combine,
                }),
                PipelineOp::Collect,
            ])
        };

        // undeclared: no `combine` key at all — byte-identical to every
        // pre-combine release of the envelope
        let plain = encode(&reduce(false)).unwrap();
        let node = &plain.get("ops").unwrap().as_arr().unwrap()[1];
        assert!(node.get("combine").is_none());

        // declared: `"combine": true` on the wire, and it survives the
        // round trip
        let tagged = encode(&reduce(true)).unwrap();
        let node = &tagged.get("ops").unwrap().as_arr().unwrap()[1];
        assert_eq!(node.get("combine").unwrap(), &Json::Bool(true));
        let decoded = decode(&tagged).unwrap();
        let PipelineOp::Reduce(r) = &decoded.ops()[1] else { panic!("expected reduce") };
        assert!(r.combine);
        assert_eq!(encode(&decoded).unwrap(), tagged);

        // an explicit `"combine": false` decodes, then canonicalizes
        // back to the absent form
        let explicit = r#"{
          "version": 1,
          "ops": [
            {"op": "ingest", "label": "x", "partitions": 4},
            {"op": "reduce", "image": "ubuntu", "command": "c",
             "input": {"kind": "text", "path": "/a"},
             "output": {"kind": "text", "path": "/a"},
             "depth": "auto", "combine": false},
            {"op": "collect"}
          ]
        }"#;
        let p = decode_str(explicit).unwrap();
        let PipelineOp::Reduce(r) = &p.ops()[1] else { panic!("expected reduce") };
        assert!(!r.combine);
        let re = encode(&p).unwrap();
        assert!(re.get("ops").unwrap().as_arr().unwrap()[1].get("combine").is_none());
    }

    #[test]
    fn encode_rejects_optimizer_pushed_combiner() {
        // the pushed combiner on a shuffle node is derived metadata,
        // exactly like a fused map on a reduce: encoding the optimized
        // plan is a caller bug, reported as a typed error
        let pushed = Pipeline::new(vec![
            PipelineOp::Ingest { label: "x".into(), partitions: 4 },
            PipelineOp::RepartitionBy {
                key: KeySelector::named("first_word").unwrap(),
                partitions: 2,
                combine: Some(Box::new(ReduceStep {
                    input_mount: text_mount("/counts"),
                    output_mount: text_mount("/sum"),
                    image: "ubuntu".into(),
                    command: "awk '{s+=$1} END {print s}' /counts > /sum".into(),
                    depth: None,
                    disk_mounts: false,
                    fused: None,
                    combine: true,
                })),
            },
            PipelineOp::Collect,
        ]);
        match encode(&pushed) {
            Err(WireError::Structure(msg)) => {
                assert!(msg.contains("optimizer-pushed combiner"), "{msg}")
            }
            other => panic!("expected a Structure error, got {other:?}"),
        }
    }

    #[test]
    fn structure_is_enforced_on_both_sides() {
        let no_collect = r#"{
          "version": 1,
          "ops": [{"op": "ingest", "label": "x", "partitions": 1}]
        }"#;
        assert!(matches!(err_of(no_collect), WireError::Structure(_)));

        let ingest_not_first = r#"{
          "version": 1,
          "ops": [
            {"op": "repartition", "partitions": 2},
            {"op": "ingest", "label": "x", "partitions": 1},
            {"op": "collect"}
          ]
        }"#;
        assert!(matches!(err_of(ingest_not_first), WireError::Structure(_)));

        let ingest_mid = r#"{
          "version": 1,
          "ops": [
            {"op": "ingest", "label": "x", "partitions": 1},
            {"op": "ingest", "label": "y", "partitions": 1},
            {"op": "collect"}
          ]
        }"#;
        assert!(matches!(err_of(ingest_mid), WireError::Structure(_)));

        // encode refuses unbracketed pipelines too
        let bare = Pipeline::new(vec![PipelineOp::Repartition { partitions: 2 }]);
        assert!(matches!(encode(&bare), Err(WireError::Structure(_))));
    }

    #[test]
    fn errors_display_helpfully() {
        let e = WireError::UnknownOp { at: "ops[3]".into(), op: "warp".into() };
        assert_eq!(e.to_string(), "ops[3]: unknown node kind `warp`");
        let e = WireError::UnsupportedVersion(9);
        assert!(e.to_string().contains("version 9"), "{e}");
        assert!(e.to_string().contains("version 1"), "{e}");
        let e = WireError::UnknownKeyFn { at: "ops[1]".into(), name: "zz".into() };
        assert!(e.to_string().contains("chromosome"), "{e}");
    }
}
