//! The logical pipeline IR.
//!
//! User-facing primitives no longer extend [`Dataset`] lineage eagerly:
//! the fluent builder ([`super::builder`]) records an immutable
//! [`Pipeline`] of typed [`PipelineOp`] nodes, the optimizer
//! ([`super::opt`]) rewrites it while it can still *see the whole job*
//! (map fusion, reduce-depth planning), and [`Lowering`] translates the
//! optimized plan into the physical [`Dataset`] lineage the cluster's
//! stage compiler consumes. This is the logical/physical-plan seam that
//! Spark-class engines hang their optimizers off — and because the IR
//! holds no engine handles, it is also the unit of serialization
//! ([`super::wire`]) and job submission ([`crate::submit`]).
//!
//! The IR is plain data; plans can be built directly (the fluent
//! builder is sugar over exactly this):
//!
//! ```
//! use mare::mare::{MapStep, MountPoint, Pipeline, PipelineOp};
//!
//! let plan = Pipeline::new(vec![
//!     PipelineOp::Ingest { label: "gen:gc:8".into(), partitions: 2 },
//!     PipelineOp::Map(MapStep {
//!         input_mount: MountPoint::text("/dna"),
//!         output_mount: MountPoint::text("/gc"),
//!         image: "ubuntu".into(),
//!         command: "grep -o '[GC]' /dna > /gc".into(),
//!         disk_mounts: false,
//!     }),
//!     PipelineOp::Collect,
//! ]);
//! assert_eq!(plan.num_maps(), 1);
//! assert!(plan.describe().contains("map[grep@ubuntu /dna -> /gc]"));
//! ```

use std::sync::Arc;

use crate::cluster::Cluster;
use crate::container::Engine;
use crate::dataset::{Dataset, Plan, Record};

use super::mount::MountPoint;
use super::op::ContainerOp;

/// Key-extraction closure for `repartitionBy`.
pub type KeyFn = Arc<dyn Fn(&Record) -> String + Send + Sync>;

/// How `repartitionBy` extracts a record's key.
///
/// Named selectors come from the registry behind [`KeySelector::named`]
/// and are serializable by [`super::wire`] (the wire format's `"key"`
/// values); opaque selectors carry an arbitrary driver-local closure
/// and cannot cross the wire — encoding a plan that contains one is a
/// typed error, not a panic.
#[derive(Clone)]
pub enum KeySelector {
    /// A registered key function, referenced by wire name.
    Named { name: &'static str, key_fn: KeyFn },
    /// An arbitrary driver-local closure (not serializable).
    Opaque(KeyFn),
}

/// SAM RNAME field — the SNP pipeline's `parseChromosomeId` keyBy
/// (Listing 3); `*` for non-text records.
fn key_chromosome(r: &Record) -> String {
    match r.as_text() {
        Some(sam) => crate::formats::sam::parse_chromosome_id(sam),
        None => "*".to_string(),
    }
}

/// First whitespace-separated token.
fn key_first_word(r: &Record) -> String {
    r.as_text().and_then(|t| t.split_whitespace().next()).unwrap_or("").to_string()
}

/// Text before the first `:` (SWAR byte scan — this runs once per
/// record on the shuffle path).
fn key_prefix_colon(r: &Record) -> String {
    r.as_text()
        .map(|t| {
            let end = crate::util::scan::memchr(b':', t.as_bytes()).unwrap_or(t.len());
            t[..end].to_string()
        })
        .unwrap_or_default()
}

/// First [`KMER_PREFIX_LEN`] characters of the first whitespace-separated
/// token — the k-mer statistics workload's bucketing key
/// (`workloads::kmer`): `<kmer>\t<count>` records sharing a prefix group
/// into the same partition. Shorter tokens key on the whole token; `*`
/// for non-text records.
fn key_kmer_prefix(r: &Record) -> String {
    match r.as_text().and_then(|t| t.split_whitespace().next()) {
        Some(tok) => {
            let end = tok
                .char_indices()
                .nth(KMER_PREFIX_LEN)
                .map(|(i, _)| i)
                .unwrap_or(tok.len());
            tok[..end].to_string()
        }
        None => "*".to_string(),
    }
}

/// Prefix length of the `kmer_prefix` named key.
pub const KMER_PREFIX_LEN: usize = 4;

/// The single registry table — [`KeySelector::known`] and
/// [`KeySelector::named`] both derive from it, so the name list and
/// the lookups cannot drift apart.
const KEY_REGISTRY: &[(&str, fn(&Record) -> String)] = &[
    ("chromosome", key_chromosome),
    ("first_word", key_first_word),
    ("prefix_colon", key_prefix_colon),
    ("kmer_prefix", key_kmer_prefix),
];

impl KeySelector {
    /// Wire names of every registered key function, in registry order.
    pub fn known() -> Vec<&'static str> {
        KEY_REGISTRY.iter().map(|(name, _)| *name).collect()
    }

    /// Look up a registered key function by wire name (the per-name
    /// semantics are documented on the `key_*` functions above and in
    /// `docs/WIRE_FORMAT.md` §5).
    pub fn named(name: &str) -> Option<KeySelector> {
        KEY_REGISTRY
            .iter()
            .find(|(n, _)| *n == name)
            .map(|&(n, f)| KeySelector::Named { name: n, key_fn: Arc::new(f) })
    }

    /// Wrap a driver-local closure (not serializable).
    pub fn opaque(key_fn: KeyFn) -> KeySelector {
        KeySelector::Opaque(key_fn)
    }

    /// The wire name, if this selector is serializable.
    pub fn name(&self) -> Option<&'static str> {
        match self {
            KeySelector::Named { name, .. } => Some(name),
            KeySelector::Opaque(_) => None,
        }
    }

    /// The executable key function.
    pub fn key_fn(&self) -> &KeyFn {
        match self {
            KeySelector::Named { key_fn, .. } | KeySelector::Opaque(key_fn) => key_fn,
        }
    }
}

impl std::fmt::Debug for KeySelector {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name().unwrap_or("keyBy"))
    }
}

/// A containerized map step (Figure 1).
#[derive(Debug, Clone)]
pub struct MapStep {
    pub input_mount: MountPoint,
    pub output_mount: MountPoint,
    pub image: String,
    pub command: String,
    /// Disk-backed mount points (the paper's `TMPDIR` override).
    pub disk_mounts: bool,
}

/// A containerized tree-reduce step (Figure 2). `depth: None` means the
/// optimizer plans K from the cost model and cluster size.
#[derive(Debug, Clone)]
pub struct ReduceStep {
    pub input_mount: MountPoint,
    pub output_mount: MountPoint,
    pub image: String,
    pub command: String,
    pub depth: Option<usize>,
    pub disk_mounts: bool,
    /// A map the optimizer fused into this reduce's FIRST tree level
    /// (same image, chaining mounts — `opt::can_fuse_into_reduce`):
    /// level 0 runs `map.command` then the reduce command in ONE
    /// container, saving one container start per partition. Always
    /// `None` in user-written logical plans; derived metadata that is
    /// not serialized by [`super::wire`].
    pub fused: Option<MapStep>,
    /// Declares the reducer associative + commutative: aggregating
    /// partial aggregates yields the same result as aggregating raw
    /// records, so the optimizer may run this command as a map-side
    /// combiner BELOW the preceding shuffle boundary
    /// (`opt::push_combiners`). Set by the builder's `.combine()`;
    /// serialized by [`super::wire`] as the `"combine"` field.
    pub combine: bool,
}

/// One node of the logical plan.
#[derive(Clone)]
pub enum PipelineOp {
    /// Source marker: where the records come from.
    Ingest { label: String, partitions: usize },
    Map(MapStep),
    Reduce(ReduceStep),
    /// keyBy + sample-based range partitioner regrouping (§1.2.2).
    RepartitionBy {
        key: KeySelector,
        partitions: usize,
        /// A combiner the optimizer pushed below this shuffle boundary
        /// (`opt::push_combiners`): the following reduce's command runs
        /// once per map-side partition BEFORE records are routed, so
        /// the shuffle ships partial aggregates instead of raw records.
        /// Always `None` in user-written logical plans; derived
        /// metadata that is not serialized by [`super::wire`].
        combine: Option<Box<ReduceStep>>,
    },
    /// Balanced rebalance into `partitions` (no keys).
    Repartition { partitions: usize },
    /// Terminal marker: results are collected to the driver.
    Collect,
}

impl std::fmt::Debug for PipelineOp {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

fn first_word(command: &str) -> &str {
    command.split_whitespace().next().unwrap_or("container")
}

impl PipelineOp {
    /// Human-readable node label for [`Pipeline::describe`].
    pub fn label(&self) -> String {
        match self {
            PipelineOp::Ingest { label, partitions } => {
                format!("ingest[{label}] x{partitions}")
            }
            PipelineOp::Map(m) => format!(
                "map[{}@{} {} -> {}{}]",
                first_word(&m.command),
                m.image,
                m.input_mount.path(),
                m.output_mount.path(),
                if m.disk_mounts { ", disk" } else { "" },
            ),
            PipelineOp::Reduce(r) => format!(
                "reduce[{}@{} {} -> {}, depth={}{}{}]",
                first_word(&r.command),
                r.image,
                match &r.fused {
                    Some(m) => m.input_mount.path(),
                    None => r.input_mount.path(),
                },
                r.output_mount.path(),
                match r.depth {
                    Some(k) => k.to_string(),
                    None => "auto".into(),
                },
                if r.disk_mounts { ", disk" } else { "" },
                format!(
                    "{}{}",
                    match &r.fused {
                        Some(m) => format!(", +map {}", first_word(&m.command)),
                        None => String::new(),
                    },
                    if r.combine { ", combine" } else { "" },
                ),
            ),
            PipelineOp::RepartitionBy { key, partitions, combine } => {
                format!(
                    "repartitionBy[{} -> {partitions}{}]",
                    key.name().unwrap_or("keyBy"),
                    match combine {
                        Some(c) => format!(", +combine {}", first_word(&c.command)),
                        None => String::new(),
                    },
                )
            }
            PipelineOp::Repartition { partitions } => {
                format!("repartition[{partitions}]")
            }
            PipelineOp::Collect => "collect".into(),
        }
    }
}

/// An immutable logical plan: a list of [`PipelineOp`] nodes bracketed
/// by `Ingest` and `Collect`.
#[derive(Debug, Clone)]
pub struct Pipeline {
    ops: Vec<PipelineOp>,
}

impl Pipeline {
    pub fn new(ops: Vec<PipelineOp>) -> Self {
        Pipeline { ops }
    }

    pub fn ops(&self) -> &[PipelineOp] {
        &self.ops
    }

    /// Number of containerized map nodes (fusion shrinks this).
    pub fn num_maps(&self) -> usize {
        self.ops.iter().filter(|o| matches!(o, PipelineOp::Map(_))).count()
    }

    pub fn num_reduces(&self) -> usize {
        self.ops.iter().filter(|o| matches!(o, PipelineOp::Reduce(_))).count()
    }

    /// One node per line, indented — the `logical plan:` rendering.
    pub fn describe(&self) -> String {
        let mut out = String::new();
        for op in &self.ops {
            out.push_str("  ");
            out.push_str(&op.label());
            out.push('\n');
        }
        out
    }

    /// Full report: logical plan → optimized plan → physical plan
    /// (rendered like `cluster::compile(...).describe()`), for this
    /// pipeline run against `cluster` over `source`.
    pub fn explain(&self, cluster: &Arc<Cluster>, source: &Dataset) -> String {
        // same environment derivation as `PipelineBuilder::build`, so
        // this rendering matches what a built job would plan
        let env = super::opt::OptEnv::for_source(cluster.config.workers, source);
        let (optimized, report) = super::opt::optimize(self, &env);
        let lowering = Lowering::for_cluster(cluster);
        let lowered = lowering.lower(&optimized, source);
        render_explain(self, &report, &optimized, &lowered)
    }
}

/// The one three-plan rendering, shared by [`Pipeline::explain`] and
/// `Job::explain` so the two cannot drift apart.
pub(crate) fn render_explain(
    logical: &Pipeline,
    report: &super::opt::OptReport,
    optimized: &Pipeline,
    lowered: &Dataset,
) -> String {
    let pp = crate::cluster::compile(lowered.plan());
    format!(
        "logical plan:\n{}optimized plan ({}):\n{}physical plan:\n{}",
        logical.describe(),
        report.summary(),
        optimized.describe(),
        pp.describe(),
    )
}

/// Label of the lineage's root source (for the `Ingest` node).
pub fn source_label(plan: &Plan) -> String {
    match plan {
        Plan::Source { label, .. } => label.clone(),
        Plan::MapPartitions { parent, .. } | Plan::Repartition { parent, .. } => {
            source_label(parent)
        }
    }
}

/// Lowering context: logical plan -> physical [`Dataset`] lineage.
///
/// All [`ContainerOp`]s of one lowering share one [`Engine`] (and hence
/// one launch counter), which is how jobs and tests observe how many
/// simulated containers a plan actually started.
pub struct Lowering {
    engine: Arc<Engine>,
    workers: usize,
}

impl Lowering {
    pub fn for_cluster(cluster: &Cluster) -> Self {
        Lowering { engine: Arc::new(cluster.engine()), workers: cluster.config.workers }
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    fn container_op(
        &self,
        input: MountPoint,
        output: MountPoint,
        image: &str,
        command: &str,
        disk: bool,
    ) -> Arc<ContainerOp> {
        let mut op = ContainerOp::new(self.engine.clone(), input, output, image, command);
        op.disk_mounts = disk;
        Arc::new(op)
    }

    /// Lower a whole pipeline over `source`.
    pub fn lower(&self, pipeline: &Pipeline, source: &Dataset) -> Dataset {
        let mut ds = source.clone();
        for op in pipeline.ops() {
            ds = self.lower_op(ds, op);
        }
        ds
    }

    /// Lower one logical node onto the lineage so far.
    pub fn lower_op(&self, ds: Dataset, op: &PipelineOp) -> Dataset {
        match op {
            PipelineOp::Ingest { .. } | PipelineOp::Collect => ds,
            PipelineOp::Map(m) => ds.map_partitions(self.container_op(
                m.input_mount.clone(),
                m.output_mount.clone(),
                &m.image,
                &m.command,
                m.disk_mounts,
            )),
            PipelineOp::RepartitionBy { key, partitions, combine } => {
                // the skew-aware sample-based range partitioner (cuts
                // planned from the observed key distribution at shuffle
                // time), with the optimizer-pushed combiner — if any —
                // lowered to a container op that runs per map-side
                // partition before routing
                let combiner = combine.as_ref().map(|c| {
                    self.container_op(
                        c.input_mount.clone(),
                        c.output_mount.clone(),
                        &c.image,
                        &c.command,
                        c.disk_mounts,
                    ) as Arc<dyn crate::dataset::PartitionOp>
                });
                ds.repartition_by_key_range(key.key_fn().clone(), *partitions, combiner)
            }
            PipelineOp::Repartition { partitions } => ds.repartition(*partitions),
            PipelineOp::Reduce(r) => self.lower_reduce(ds, r),
        }
    }

    /// Tree-aggregate all partitions into one (Figure 2).
    ///
    /// K levels: aggregate within partitions (mapPartitions), shrink the
    /// partition count (repartition ⇒ shuffle), repeat until a single
    /// aggregated partition remains — at most K shuffles.
    ///
    /// Unlike the seed implementation, the loop terminates exactly when
    /// the last aggregation has run: a reduce over an already-single
    /// partition launches ONE reducer container, not two, and a tree
    /// that converges early skips the redundant final aggregation stage.
    ///
    /// When the optimizer fused a preceding map into this reduce
    /// (`ReduceStep::fused`), level 0 runs `map.command` then the reduce
    /// command in the SAME container — reading the map's input mount,
    /// with the intermediate file chained in the shared container fs —
    /// which saves one container start per source partition. Later
    /// levels aggregate reducer outputs and run the plain command.
    fn lower_reduce(&self, ds: Dataset, r: &ReduceStep) -> Dataset {
        let k = r
            .depth
            .unwrap_or_else(|| {
                super::opt::plan_reduce_depth(
                    &super::cost::infer(&r.command),
                    ds.num_partitions(),
                    self.workers,
                )
            })
            .max(1);
        let mut parts = ds.num_partitions().max(1);
        // per-level shrink factor: N^(1/K), so K levels reach 1
        let scale = (parts as f64).powf(1.0 / k as f64).ceil().max(2.0) as usize;

        let mut ds = ds;
        let mut level = 0usize;
        loop {
            let op = match (&r.fused, level) {
                (Some(m), 0) => self.container_op(
                    m.input_mount.clone(),
                    r.output_mount.clone(),
                    &r.image,
                    &format!("{}\n{}", m.command, r.command),
                    r.disk_mounts,
                ),
                _ => self.container_op(
                    r.input_mount.clone(),
                    r.output_mount.clone(),
                    &r.image,
                    &r.command,
                    r.disk_mounts,
                ),
            };
            ds = ds.map_partitions(op);
            if parts == 1 {
                break;
            }
            parts = parts.div_ceil(scale).max(1);
            ds = ds.repartition(parts);
            level += 1;
        }
        ds
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;
    use crate::container::Registry;
    use crate::tools::images;

    fn cluster(workers: usize) -> Arc<Cluster> {
        let mut reg = Registry::new();
        reg.push(images::ubuntu());
        Arc::new(Cluster::new(Arc::new(reg), None, ClusterConfig::sized(workers, 4)))
    }

    fn sum_reduce(depth: Option<usize>) -> ReduceStep {
        ReduceStep {
            input_mount: MountPoint::text("/in"),
            output_mount: MountPoint::text("/out"),
            image: "ubuntu".into(),
            command: "awk '{s+=$1} END {print s}' /in > /out".into(),
            depth,
            disk_mounts: false,
            fused: None,
            combine: false,
        }
    }

    #[test]
    fn reduce_lowering_reaches_one_partition_within_k_shuffles() {
        for (parts, k) in [(1usize, 1usize), (1, 3), (2, 2), (16, 1), (16, 2), (33, 2), (5, 4)] {
            let ds = Dataset::parallelize_text(&"1\n".repeat(64), "\n", parts);
            let lowering = Lowering::for_cluster(&cluster(4));
            let lowered = lowering.lower_op(ds, &PipelineOp::Reduce(sum_reduce(Some(k))));
            assert_eq!(lowered.num_partitions(), 1, "parts={parts} k={k}");
            assert!(
                lowered.plan().num_shuffles() <= k,
                "parts={parts} k={k}: {} shuffles",
                lowered.plan().num_shuffles()
            );
        }
    }

    #[test]
    fn single_partition_reduce_launches_one_container() {
        // the seed double-ran the reducer when the tree had already
        // converged; the corrected lowering launches exactly one
        let c = cluster(2);
        let ds = Dataset::parallelize_text("1\n1\n1", "\n", 1);
        let lowering = Lowering::for_cluster(&c);
        let lowered = lowering.lower_op(ds, &PipelineOp::Reduce(sum_reduce(Some(2))));
        let out = c.run(&lowered).unwrap();
        assert_eq!(out.collect_text("\n").trim(), "3");
        assert_eq!(lowering.engine().launch_count(), 1);
    }

    #[test]
    fn early_converging_tree_skips_redundant_final_stage() {
        // 2 partitions, K=2: level 1 merges to a single partition and
        // aggregates it — no second aggregation of the same partition
        let c = cluster(2);
        let ds = Dataset::parallelize_text("1\n1\n1\n1", "\n", 2);
        let lowering = Lowering::for_cluster(&c);
        let lowered = lowering.lower_op(ds, &PipelineOp::Reduce(sum_reduce(Some(2))));
        let out = c.run(&lowered).unwrap();
        assert_eq!(out.collect_text("\n").trim(), "4");
        // level 0: 2 containers; level 1 (merged): 1 container
        assert_eq!(lowering.engine().launch_count(), 3);
    }

    #[test]
    fn pipeline_explain_renders_all_three_plans() {
        let c = cluster(2);
        let ds = Dataset::parallelize_text("1\n1\n1\n1", "\n", 2);
        let p = Pipeline::new(vec![
            PipelineOp::Ingest { label: "parallelize".into(), partitions: 2 },
            PipelineOp::Reduce(sum_reduce(None)),
            PipelineOp::Collect,
        ]);
        let s = p.explain(&c, &ds);
        assert!(s.contains("logical plan:"), "{s}");
        assert!(s.contains("optimized plan"), "{s}");
        assert!(s.contains("physical plan:"), "{s}");
        // the logical node shows auto; the optimizer pins it
        assert!(s.contains("depth=auto"), "{s}");
        assert!(s.contains("auto-planned to"), "{s}");
    }

    #[test]
    fn named_key_selectors_resolve_and_compute() {
        for name in KeySelector::known() {
            let k = KeySelector::named(name).expect("registered key fn");
            assert_eq!(k.name(), Some(name));
        }
        assert!(KeySelector::named("no-such-key").is_none());

        let key_of = |name: &str, r: &Record| {
            let f: KeyFn = KeySelector::named(name).unwrap().key_fn().clone();
            f(r)
        };
        let sam = Record::text("read1\t0\tchr7\t100\tACGT");
        assert_eq!(key_of("chromosome", &sam), "chr7");
        assert_eq!(key_of("first_word", &sam), "read1");
        assert_eq!(key_of("prefix_colon", &Record::text("chr2:r9")), "chr2");
        assert_eq!(key_of("kmer_prefix", &Record::text("ACGTAAGG\t3")), "ACGT");
        assert_eq!(key_of("kmer_prefix", &Record::text("AC\t1")), "AC");
        // non-text records fall back rather than panic
        assert_eq!(key_of("chromosome", &Record::binary("x.gz", vec![1])), "*");
        assert_eq!(key_of("kmer_prefix", &Record::binary("x.gz", vec![1])), "*");

        let p = Pipeline::new(vec![PipelineOp::RepartitionBy {
            key: KeySelector::named("chromosome").unwrap(),
            partitions: 4,
            combine: None,
        }]);
        assert!(p.describe().contains("repartitionBy[chromosome -> 4]"), "{}", p.describe());
    }

    #[test]
    fn describe_renders_every_node_kind() {
        let p = Pipeline::new(vec![
            PipelineOp::Ingest { label: "parallelize".into(), partitions: 8 },
            PipelineOp::Map(MapStep {
                input_mount: MountPoint::text("/dna"),
                output_mount: MountPoint::text("/count"),
                image: "ubuntu".into(),
                command: "grep -o '[GC]' /dna > /count".into(),
                disk_mounts: false,
            }),
            PipelineOp::RepartitionBy {
                key: KeySelector::opaque(Arc::new(|_: &Record| "k".into())),
                partitions: 3,
                combine: None,
            },
            PipelineOp::Repartition { partitions: 2 },
            PipelineOp::Reduce(sum_reduce(None)),
            PipelineOp::Collect,
        ]);
        let s = p.describe();
        assert!(s.contains("ingest[parallelize] x8"), "{s}");
        assert!(s.contains("map[grep@ubuntu /dna -> /count]"), "{s}");
        assert!(s.contains("repartitionBy[keyBy -> 3]"), "{s}");
        assert!(s.contains("repartition[2]"), "{s}");
        assert!(s.contains("depth=auto"), "{s}");
        assert!(s.trim_end().ends_with("collect"), "{s}");
        assert_eq!(p.num_maps(), 1);
        assert_eq!(p.num_reduces(), 1);
    }
}
