//! [`ContainerOp`]: the [`PartitionOp`] that runs a containerized
//! command over one partition — the heart of MaRe's map/reduce.
//!
//! Per Figure 1: (i) make the partition available at the input mount
//! point, (ii) run the Docker container, (iii) retrieve the results from
//! the output mount point. Steps (i)/(iii) are the mount-point staging
//! of [`super::mount`]; step (ii) is the in-process container engine.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::container::{Engine, RunConfig, DEFAULT_TMPFS_CAPACITY};
use crate::dataset::{PartitionOp, Record, TaskContext};
use crate::error::Result;
use crate::simtime::CostModel;

use super::mount::MountPoint;

/// A containerized per-partition transformation.
pub struct ContainerOp {
    pub engine: Arc<Engine>,
    pub input_mount: MountPoint,
    pub output_mount: MountPoint,
    pub image: String,
    pub command: String,
    /// Disk-backed mounts (the paper's `TMPDIR` override for partitions
    /// larger than tmpfs).
    pub disk_mounts: bool,
    /// tmpfs capacity when not disk-backed.
    pub tmpfs_capacity: u64,
    /// Virtual-time model (inferred from the command by default).
    pub cost: CostModel,
    /// Short label for plans/reports ("fred", "sdsorter", ...).
    pub name: String,
}

impl ContainerOp {
    pub fn new(
        engine: Arc<Engine>,
        input_mount: MountPoint,
        output_mount: MountPoint,
        image: impl Into<String>,
        command: impl Into<String>,
    ) -> Self {
        let command = command.into();
        let image = image.into();
        let cost = super::cost::infer(&command);
        let name = command
            .split_whitespace()
            .next()
            .unwrap_or("container")
            .to_string();
        ContainerOp {
            engine,
            input_mount,
            output_mount,
            image,
            command,
            disk_mounts: false,
            tmpfs_capacity: DEFAULT_TMPFS_CAPACITY,
            cost,
            name,
        }
    }
}

impl PartitionOp for ContainerOp {
    fn apply(&self, ctx: &TaskContext, records: Vec<Record>) -> Result<Vec<Record>> {
        let mut env = BTreeMap::new();
        env.insert("MARE_PARTITION".to_string(), ctx.partition.to_string());
        env.insert("MARE_NUM_PARTITIONS".to_string(), ctx.num_partitions.to_string());
        if self.disk_mounts {
            env.insert("TMPDIR".to_string(), "/scratch".to_string());
        }

        let mut cfg = RunConfig::new(&self.image, &self.command)
            .seed(ctx.seed)
            .disk(self.disk_mounts);
        cfg.env = env;
        cfg.tmpfs_capacity = self.tmpfs_capacity;
        cfg.input_files = self.input_mount.stage_in(&records)?;
        if let Some(stdin) = self.input_mount.stage_stdin(&records)? {
            cfg.stdin = stdin;
        }

        let mut outcome = self.engine.run(&cfg)?;
        match self.output_mount.stage_stdout(std::mem::take(&mut outcome.stdout))? {
            Some(streamed) => Ok(streamed),
            None => self.output_mount.stage_out(&mut outcome.fs),
        }
    }

    fn cost_model(&self) -> CostModel {
        self.cost
    }

    fn image(&self) -> Option<&str> {
        Some(&self.image)
    }

    fn uses_disk_mount(&self) -> bool {
        self.disk_mounts
    }

    fn streams(&self) -> (bool, bool) {
        (self.input_mount.is_stream(), self.output_mount.is_stream())
    }

    fn label(&self) -> String {
        format!("{}@{}", self.name, self.image)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::Registry;
    use crate::tools::images;

    fn engine() -> Arc<Engine> {
        let mut reg = Registry::new();
        reg.push(images::ubuntu());
        Arc::new(Engine::new(Arc::new(reg), None))
    }

    fn ctx() -> TaskContext {
        TaskContext { partition: 0, num_partitions: 2, attempt: 0, seed: 42 }
    }

    #[test]
    fn listing1_gc_count_map_phase() {
        let op = ContainerOp::new(
            engine(),
            MountPoint::text("/dna"),
            MountPoint::text("/count"),
            "ubuntu",
            "grep -o '[GC]' /dna | wc -l > /count",
        );
        let recs = vec![Record::text("GATTACA"), Record::text("GCGC")];
        let out = op.apply(&ctx(), recs).unwrap();
        assert_eq!(out, vec![Record::text("6")]);
        assert_eq!(op.image(), Some("ubuntu"));
        assert!(op.label().contains("grep"));
    }

    #[test]
    fn listing1_sum_reduce_phase() {
        let op = ContainerOp::new(
            engine(),
            MountPoint::text("/counts"),
            MountPoint::text("/sum"),
            "ubuntu",
            "awk '{s+=$1} END {print s}' /counts > /sum",
        );
        let recs = vec![Record::text("6"), Record::text("3"), Record::text("1")];
        let out = op.apply(&ctx(), recs).unwrap();
        assert_eq!(out, vec![Record::text("10")]);
    }

    #[test]
    fn empty_partition_runs_and_returns_empty() {
        let op = ContainerOp::new(
            engine(),
            MountPoint::text("/in"),
            MountPoint::text("/out"),
            "ubuntu",
            "grep -o x /in > /out",
        );
        let out = op.apply(&ctx(), vec![]).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn streamed_op_runs_without_mount_files() {
        // Listing 1's map phase, streaming: stdin -> grep|wc -> stdout
        let op = ContainerOp::new(
            engine(),
            MountPoint::stream(),
            MountPoint::stream(),
            "ubuntu",
            "grep -o '[GC]' | wc -l",
        );
        let recs = vec![Record::text("GATTACA"), Record::text("GCGC")];
        let out = op.apply(&ctx(), recs).unwrap();
        assert_eq!(out, vec![Record::text("6")]);
        assert_eq!(op.streams(), (true, true));
    }

    #[test]
    fn mixed_stream_and_file_mounts() {
        // stream in, file out
        let op = ContainerOp::new(
            engine(),
            MountPoint::stream(),
            MountPoint::text("/out"),
            "ubuntu",
            "grep -c G > /out",
        );
        let out = op
            .apply(&ctx(), vec![Record::text("GG"), Record::text("AA")])
            .unwrap();
        assert_eq!(out, vec![Record::text("1")]);
        assert_eq!(op.streams(), (true, false));
    }

    #[test]
    fn random_in_command_is_deterministic_per_seed() {
        let run = |seed: u64| {
            let op = ContainerOp::new(
                engine(),
                MountPoint::text("/in"),
                MountPoint::binary("/out"),
                "ubuntu",
                "cat /in > /out/f.$RANDOM",
            );
            let c = TaskContext { partition: 0, num_partitions: 1, attempt: 0, seed };
            op.apply(&c, vec![Record::text("x")]).unwrap()
        };
        assert_eq!(run(5), run(5));
        assert_ne!(run(5), run(6));
    }
}
